"""Generate cross-language BFP fixtures: inputs + expected quantized outputs
from the python oracle (ref.py), consumed by the rust test
``tests/bfp_cross.rs`` to pin the two implementations to identical
semantics (same exponent convention, same RNE rounding, same saturation).

Usage: python tools/gen_fixtures.py ../artifacts/fixtures/bfp_cases.json
"""

from __future__ import annotations

import json
import os
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from compile.kernels import ref  # noqa: E402


def main() -> None:
    out_path = sys.argv[1] if len(sys.argv) > 1 else "../artifacts/fixtures/bfp_cases.json"
    rng = np.random.default_rng(0xB0F)
    cases = []
    # quantization cases across widths/tiles/scales, incl. edge cases
    for m in (2, 4, 8, 12, 16, 24):
        for tile in (4, 8, 24):
            for scale in (1e-6, 1.0, 1e6):
                rows, cols = int(rng.integers(1, 30)), int(rng.integers(1, 30))
                x = (rng.normal(size=(rows, cols)) * scale).astype(np.float32)
                q = np.asarray(ref.bfp_quantize_tiled(jnp.array(x), m, tile))
                cases.append(
                    {
                        "kind": "quantize",
                        "mantissa": m,
                        "tile": tile,
                        "rows": rows,
                        "cols": cols,
                        "x": x.flatten().tolist(),
                        "q": q.flatten().tolist(),
                    }
                )
    # explicit edge cases
    for x in ([0.0, 0.0, 0.0, 0.0], [1.0, -1.0, 0.5, -0.5], [3.4e38, -3.4e38, 1e-30, 0.0]):
        arr = np.array(x, np.float32).reshape(2, 2)
        q = np.asarray(ref.bfp_quantize_tiled(jnp.array(arr), 8, 24))
        cases.append(
            {
                "kind": "quantize",
                "mantissa": 8,
                "tile": 24,
                "rows": 2,
                "cols": 2,
                "x": arr.flatten().tolist(),
                "q": q.flatten().tolist(),
            }
        )
    # matmul cases (grid semantics == rust tile loops)
    for m in (4, 8, 12):
        for tile in (4, 8):
            M, K, N = (int(v) for v in rng.integers(1, 20, size=3))
            a = rng.normal(size=(M, K)).astype(np.float32)
            b = rng.normal(size=(K, N)).astype(np.float32)
            c = np.asarray(ref.bfp_matmul_grid(jnp.array(a), jnp.array(b), m, tile))
            cases.append(
                {
                    "kind": "matmul",
                    "mantissa": m,
                    "tile": tile,
                    "m": M,
                    "k": K,
                    "n": N,
                    "a": a.flatten().tolist(),
                    "b": b.flatten().tolist(),
                    "c": c.flatten().tolist(),
                }
            )
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"cases": cases}, f)
    print(f"wrote {len(cases)} fixture cases to {out_path}")


if __name__ == "__main__":
    main()
