fn main() {}
