"""AOT compiler: lower every (model x dataset x numeric-config) combo to
HLO text + a manifest the rust runtime consumes.

Interchange is HLO *text*, not serialized protos — jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1 (the
version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each combo produces three artifacts:

- ``<combo>__init.hlo.txt``   seed:i32 -> state leaves
- ``<combo>__train.hlo.txt``  state..., x, y, lr -> state'..., loss, acc
- ``<combo>__eval.hlo.txt``   state..., x, y -> loss_sum, correct_sum

``manifest.json`` records, per artifact: the file, role, flat input/output
specs (name/shape/dtype), the state leaf count, and the dataset dims the
rust data pipeline needs. Re-running skips artifacts whose files already
exist unless --force; combos can be filtered with --only <substring>.

Usage: python -m compile.aot [--out-dir ../artifacts] [--only lstm] [--force]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .models import MODELS
from .numerics import parse_config
from .train import StepBuilder

BATCH = 32

# ---------------------------------------------------------------- datasets

DATASETS = {
    # scaled-down stand-ins; see DESIGN.md §5 (substitutions)
    "cifar10like": dict(kind="image", hw=16, channels=3, classes=10),
    "cifar100like": dict(kind="image", hw=16, channels=3, classes=20),
    "svhnlike": dict(kind="image", hw=16, channels=3, classes=10),
    "imagenetlike": dict(kind="image", hw=24, channels=3, classes=30),
    "ptblike": dict(kind="text", vocab=32, seq=48),
}

# ------------------------------------------------------------ experiment set
# Every (model, dataset, config) combo any repro harness needs. Kept in one
# place so `make artifacts` builds the closure of all experiments.

_T2_CFGS = ["fp32", "hbfp8_16_t24", "hbfp12_16_t24"]

COMBOS: list[tuple[str, str, str]] = []
# quickstart / pallas-bearing path
COMBOS += [("mlp", "cifar10like", c) for c in ["fp32", "hbfpp8_16_t24"]]
# Table 1: narrow-FP sweep (fp32 doubles as the m=24,e=8 cell)
COMBOS += [
    ("resnet_mini", "cifar10like", c)
    for c in ["fp32", "fp_m2_e8", "fp_m4_e8", "fp_m8_e8", "fp_m24_e6", "fp_m24_e2"]
]
# Table 2: image classification grid
COMBOS += [
    (m, d, c)
    for m in ["resnet_mini", "wrn_mini", "densenet_mini"]
    for d in ["cifar100like", "svhnlike"]
    for c in _T2_CFGS
]
COMBOS += [("resnet_mini", "imagenetlike", c) for c in _T2_CFGS]
# Table 3 / Figure 3: language model
COMBOS += [("lstm", "ptblike", c) for c in _T2_CFGS]
# Design space: mantissa width sweep (plus narrow-storage counterparts)
COMBOS += [
    ("wrn_mini", "cifar100like", c)
    for c in ["hbfp4_4_t24", "hbfp4_16_t24", "hbfp8_8_t24", "hbfp12_12_t24", "hbfp16_16_t24"]
]
# Design space: tile size sweep
COMBOS += [
    ("wrn_mini", "cifar100like", c)
    for c in ["hbfp8_16_tnone", "hbfp8_16_t8", "hbfp8_16_t64"]
]
# Extension: HBFP on attention (weight-matmul quantization; DESIGN.md)
COMBOS += [("transformer_mini", "ptblike", c) for c in _T2_CFGS]


def combo_name(model: str, dataset: str, cfg: str) -> str:
    return f"{model}-{dataset}-{cfg}"


# ---------------------------------------------------------------- lowering


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned, 32-bit safe)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _dtype_str(dt) -> str:
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}[jnp.dtype(dt).name]


def _specs(avals, names):
    return [
        {"name": n, "shape": [int(d) for d in a.shape], "dtype": _dtype_str(a.dtype)}
        for n, a in zip(names, avals)
    ]


def build_combo(model: str, dataset: str, cfg_name: str, out_dir: str, force: bool):
    """Lower init/train/eval for one combo. Returns manifest entries."""
    ds = DATASETS[dataset]
    spec = MODELS[model]
    if spec.kind != ds["kind"]:
        raise ValueError(f"{model} ({spec.kind}) incompatible with {dataset} ({ds['kind']})")
    cfg = parse_config(cfg_name)
    dims = {k: v for k, v in ds.items() if k != "kind"}
    sb = StepBuilder(spec, cfg, batch=BATCH, **dims)

    name = combo_name(model, dataset, cfg_name)
    x_aval, y_aval = sb.batch_avals()
    lr_aval = jax.ShapeDtypeStruct((), jnp.float32)
    seed_aval = jax.ShapeDtypeStruct((), jnp.int32)
    state_names = [f"state/{p}" for p in sb.state_paths]

    entries = {}
    jobs = [
        ("init", sb.init_fn(), [seed_aval], ["seed"], state_names),
        (
            "train",
            sb.train_fn(),
            sb.state_avals + [x_aval, y_aval, lr_aval],
            state_names + ["x", "y", "lr"],
            state_names + ["loss", "acc"],
        ),
        (
            "eval",
            sb.eval_fn(),
            sb.state_avals + [x_aval, y_aval],
            state_names + ["x", "y"],
            ["loss_sum", "correct_sum"],
        ),
    ]
    for role, fn, in_avals, in_names, out_names in jobs:
        fname = f"{name}__{role}.hlo.txt"
        path = os.path.join(out_dir, fname)
        t0 = time.time()
        if force or not os.path.exists(path):
            # keep_unused: eval ignores the momentum leaves, but the HLO
            # signature must keep them so rust can pass one uniform state
            # list to both train and eval.
            lowered = jax.jit(fn, keep_unused=True).lower(*in_avals)
            text = to_hlo_text(lowered)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                f.write(text)
            os.replace(tmp, path)
            status = f"lowered in {time.time() - t0:.1f}s ({len(text) / 1e6:.1f} MB)"
        else:
            status = "cached"
        out_avals = jax.eval_shape(fn, *in_avals)
        entries[f"{name}__{role}"] = {
            "file": fname,
            "role": role,
            "model": model,
            "dataset": dataset,
            "config": cfg_name,
            "state_len": len(sb.state_avals),
            "batch": BATCH,
            "inputs": _specs(in_avals, in_names),
            "outputs": _specs(out_avals, out_names),
        }
        print(f"  {fname}: {status}", flush=True)
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "../../artifacts"))
    ap.add_argument("--only", default=None, help="substring filter on combo names")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"version": 1, "datasets": DATASETS, "artifacts": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = json.load(f)
            manifest["artifacts"] = old.get("artifacts", {})

    t0 = time.time()
    n = 0
    for model, dataset, cfg in COMBOS:
        name = combo_name(model, dataset, cfg)
        if args.only and args.only not in name:
            continue
        print(f"[{n}] {name}", flush=True)
        manifest["artifacts"].update(build_combo(model, dataset, cfg, out_dir, args.force))
        n += 1
        # checkpoint the manifest as we go so partial runs are usable
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"built {n} combos in {time.time() - t0:.0f}s -> {out_dir}")


if __name__ == "__main__":
    main()
