"""L1: Pallas kernels for HBFP's compute hot-spot + the pure-jnp oracle."""

from . import ref  # noqa: F401
from .bfp_matmul import bfp_matmul  # noqa: F401
from .bfp_quantize import bfp_quantize_tiled, bfp_quantize_whole  # noqa: F401
