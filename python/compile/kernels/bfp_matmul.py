"""Pallas kernel: tiled HBFP matmul — the paper's MatMul unit (Figure 2).

The hot-spot of HBFP training: C = Q_m(A) @ Q_m(B) where Q_m quantizes each
(t x t) tile onto a shared-exponent BFP grid, the tile-products are exact
fixed-point arithmetic (m-bit mantissas multiply exactly inside f32 for
m <= 12), and tile-partials accumulate in FP32 — "tile multiplications are
performed in fixed point, and their results are accumulated in floating
point" (§4).

TPU mapping (DESIGN.md §6):
- BlockSpec (bm, bk) x (bk, bn) VMEM blocks == the shared-exponent tiles;
  the numeric format's granularity IS the memory schedule's granularity.
- grid = (M/bm, N/bn, K/bk) with K innermost so the f32 accumulator block
  stays resident in VMEM across the K sweep (revisiting semantics).
- the max-reduce + round before the MAC is the FP→BFP converter; the final
  write-out is the BFP→FP unit.

interpret=True: CPU PJRT cannot run Mosaic custom-calls; the interpreter
lowers to plain HLO (grid while-loop), which the rust runtime executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _quant_tile(x, mantissa_bits: int):
    """FP→BFP on one VMEM-resident tile (shared exponent, RNE, saturate)."""
    amax = jnp.max(jnp.abs(x))
    _, ex = jnp.frexp(amax)
    e = jnp.where(amax > 0, jnp.clip(ex, ref.E_MIN, ref.E_MAX), ref.E_MIN).astype(jnp.int32)
    m = mantissa_bits
    step = jnp.ldexp(jnp.float32(1.0), e - (m - 1))  # exact (exp2 is not, on CPU)
    lo = -(2.0 ** (m - 1))
    hi = 2.0 ** (m - 1) - 1.0
    q = jnp.clip(jnp.round(x / step), lo, hi)
    return (q * step).astype(jnp.float32)


def _matmul_kernel(a_ref, b_ref, o_ref, *, mantissa_bits: int, k_steps: int):
    """Grid step (i, j, k): o[i,j] += Q(a[i,k]) @ Q(b[k,j]).

    The accumulator lives in the output block, which Pallas keeps resident
    across the innermost k dimension (same (i, j) index map), mirroring the
    wide accumulators inside the paper's MatMul unit.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    qa = _quant_tile(a_ref[...], mantissa_bits)
    qb = _quant_tile(b_ref[...], mantissa_bits)
    # Fixed-point MAC: qa/qb are exact multiples of their tile steps, so this
    # f32 dot is bit-identical to an integer mantissa dot scaled by 2^(ea+eb)
    # for mantissa widths <= 12.
    o_ref[...] += jnp.dot(qa, qb, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("mantissa_bits", "tile"))
def bfp_matmul(a: jnp.ndarray, b: jnp.ndarray, mantissa_bits: int, tile: int) -> jnp.ndarray:
    """Tiled HBFP matmul, one shared exponent per (tile x tile) tile.

    a: (M, K) f32, b: (K, N) f32 -> (M, N) f32.

    Padding note: operands are zero-padded up to tile multiples before the
    kernel (Pallas interpret mode fills out-of-bounds lanes with NaN, so
    block padding cannot be relied on); zeros never change a tile's max-abs
    nor contribute to the dot, so results match ref.bfp_matmul with ragged
    tiles exactly (property-tested in test_kernels.py).
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"expected 2-D operands, got {a.shape} @ {b.shape}")
    m_dim, k_dim = a.shape
    k2, n_dim = b.shape
    if k_dim != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    ap = jnp.pad(a, ((0, (-m_dim) % tile), (0, (-k_dim) % tile)))
    bp = jnp.pad(b, ((0, (-k_dim) % tile), (0, (-n_dim) % tile)))
    k_steps = ap.shape[1] // tile
    grid = (ap.shape[0] // tile, bp.shape[1] // tile, k_steps)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, mantissa_bits=mantissa_bits, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[1]), jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m_dim, :n_dim]
