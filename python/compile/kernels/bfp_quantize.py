"""Pallas kernel: tile-granular BFP quantization (the paper's FP→BFP unit).

Maps the accelerator's FP-to-BFP converter (Figure 2 of the paper) onto a
TPU-style Pallas grid: each grid step owns one (tile x tile) VMEM block,
computes the block's shared exponent with a max-reduce, and rounds every
element onto the BFP grid. ``interpret=True`` everywhere — the CPU PJRT
backend cannot execute Mosaic custom-calls (see DESIGN.md §2).

Semantics are defined by :mod:`ref` and asserted identical in
``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _quantize_kernel(x_ref, o_ref, *, mantissa_bits: int):
    """One grid step = one exponent block.

    The FP→BFP unit in hardware: max-abs reduce over the block (exponent
    detect), then normalize+round every mantissa. Zero blocks fall through
    via the same E_MIN path as ref.block_exponent.
    """
    x = x_ref[...]
    amax = jnp.max(jnp.abs(x))
    # frexp exponent = floor(log2(amax)) + 1 (exact); E_MIN for zero blocks.
    _, ex = jnp.frexp(amax)
    e = jnp.where(amax > 0, jnp.clip(ex, ref.E_MIN, ref.E_MAX), ref.E_MIN).astype(jnp.int32)
    m = mantissa_bits
    step = jnp.ldexp(jnp.float32(1.0), e - (m - 1))  # exact (exp2 is not, on CPU)
    lo = -(2.0 ** (m - 1))
    hi = 2.0 ** (m - 1) - 1.0
    q = jnp.clip(jnp.round(x / step), lo, hi)
    o_ref[...] = (q * step).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("mantissa_bits", "tile"))
def bfp_quantize_tiled(x: jnp.ndarray, mantissa_bits: int, tile: int) -> jnp.ndarray:
    """Quantize a 2-D array with one shared exponent per (tile x tile) tile.

    Ragged edges are zero-padded up to a tile multiple before the kernel
    (Pallas interpret mode fills out-of-bounds lanes with NaN, so we must
    not rely on block padding): zeros never perturb a block's max-abs, so
    ragged and padded tilings agree exactly (property-tested).
    """
    if x.ndim != 2:
        raise ValueError(f"expected 2-D input, got shape {x.shape}")
    rows, cols = x.shape
    pr, pc = (-rows) % tile, (-cols) % tile
    xp = jnp.pad(x, ((0, pr), (0, pc)))
    grid = (xp.shape[0] // tile, xp.shape[1] // tile)
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, mantissa_bits=mantissa_bits),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, tile), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=True,
    )(xp)
    return out[:rows, :cols]


@functools.partial(jax.jit, static_argnames=("mantissa_bits",))
def bfp_quantize_whole(x: jnp.ndarray, mantissa_bits: int) -> jnp.ndarray:
    """Whole-tensor shared exponent (the paper's untiled configuration)."""
    shape = x.shape
    x2 = x.reshape(1, -1)
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, mantissa_bits=mantissa_bits),
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        interpret=True,
    )(x2)
    return out.reshape(shape)
