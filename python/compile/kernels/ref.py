"""Pure-jnp reference semantics for BFP quantization and BFP matmul.

This module is the *numeric oracle* for the whole stack:

- the Pallas kernels in :mod:`bfp_quantize` / :mod:`bfp_matmul` must agree
  with it bit-for-bit (asserted in ``python/tests/``),
- the L2 HBFP layers (:mod:`compile.hbfp`) call these functions directly for
  the large CNN/LSTM artifacts (see DESIGN.md §2), and
- the rust BFP library (``rust/src/bfp``) implements the same semantics and
  is cross-checked against HLO artifacts built from these functions.

Numeric contract (DESIGN.md §3)
-------------------------------
A BFP block with mantissa width ``m`` and shared exponent ``e`` represents

    x_i = q_i * 2^(e - (m - 1)),   q_i integer in [-2^(m-1), 2^(m-1) - 1]

``e = floor(log2(max|x|)) + 1`` over the block (the frexp exponent), so the
max element's mantissa lands in [2^(m-2), 2^(m-1)) and never saturates on
rounding except the half-ulp round-up to exactly 2^(m-1). All-zero blocks
use ``E_MIN``. Rounding is round-to-nearest-even; out-of-range rounded
mantissas saturate (clamp), mirroring the paper's hardware converter, which
"normalizes and truncates" into a fixed-width register.
"""

from __future__ import annotations

import jax.numpy as jnp

# Exponent assigned to all-zero blocks, and the clamp floor for real blocks
# (prevents 2^(e-m+1) from flushing to zero in f32 for any m <= 24). Matches
# rust/src/bfp/quant.rs::E_MIN.
E_MIN = -100
# Clamp ceiling: with e = 128 (max|x| near f32-max) the most negative
# mantissa -2^(m-1) would dequantize to -2^128 = -inf; clamping to 127
# saturates such blocks instead (hardware converters do the same).
E_MAX = 127


def block_exponent(x: jnp.ndarray, axis, keepdims: bool = True) -> jnp.ndarray:
    """Shared exponent of a block: floor(log2(max|x|)) + 1 (frexp exponent),
    clamped to [E_MIN, E_MAX]; E_MIN for all-zero blocks.

    ``axis`` follows jnp.max semantics; with ``keepdims`` the result
    broadcasts back over the block.
    """
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    # frexp returns f in [0.5, 1) and e with x = f * 2^e; e is exactly
    # floor(log2(x)) + 1 for x > 0.
    _, exp = jnp.frexp(amax)
    e = jnp.clip(exp, E_MIN, E_MAX)
    return jnp.where(amax > 0, e, E_MIN).astype(jnp.int32)


def quantize_block(x: jnp.ndarray, e: jnp.ndarray, mantissa_bits: int) -> jnp.ndarray:
    """Round ``x`` onto the BFP grid defined by shared exponent ``e``.

    Returns the *dequantized* f32 values (exact multiples of the step); the
    integer mantissas are ``result / step``. Round-to-nearest-even with
    saturation to the two's-complement mantissa range.
    """
    m = mantissa_bits
    step = jnp.ldexp(jnp.float32(1.0), e - (m - 1))  # exact (exp2 is not, on CPU)
    lo = -(2.0 ** (m - 1))
    hi = 2.0 ** (m - 1) - 1.0
    q = jnp.clip(jnp.round(x / step), lo, hi)  # jnp.round is RNE
    return (q * step).astype(jnp.float32)


def bfp_quantize(x: jnp.ndarray, mantissa_bits: int, axis=None) -> jnp.ndarray:
    """Quantize ``x`` to BFP with one exponent per slice along ``axis``.

    ``axis=None`` shares a single exponent across the whole tensor.
    """
    if axis is None:
        axis = tuple(range(x.ndim))
    e = block_exponent(x, axis=axis, keepdims=True)
    return quantize_block(x, e, mantissa_bits)


def _tile_quantize_2d(x: jnp.ndarray, mantissa_bits: int, tile: int) -> jnp.ndarray:
    """Quantize a 2-D tensor with one exponent per (tile x tile) tile.

    Ragged edges get their own (smaller) tiles, matching the rust library
    and the Pallas kernel's padded-block behaviour (padding with zeros never
    changes a tile's max-abs, so padded and ragged tilings agree).
    """
    rows, cols = x.shape
    pr = (-rows) % tile
    pc = (-cols) % tile
    xp = jnp.pad(x, ((0, pr), (0, pc)))
    nr, nc = xp.shape[0] // tile, xp.shape[1] // tile
    xt = xp.reshape(nr, tile, nc, tile).transpose(0, 2, 1, 3)  # nr,nc,t,t
    e = block_exponent(xt, axis=(2, 3), keepdims=True)
    qt = quantize_block(xt, e, mantissa_bits)
    q = qt.transpose(0, 2, 1, 3).reshape(nr * tile, nc * tile)
    return q[:rows, :cols]


def bfp_quantize_tiled(x: jnp.ndarray, mantissa_bits: int, tile) -> jnp.ndarray:
    """Tile-granular BFP quantization over the last two dims of ``x``.

    ``tile=None`` shares one exponent over the last two dims (the paper's
    "no tiles" configuration); otherwise exponents are shared per
    (tile x tile) tile. Leading dims are batch dims, one exponent set each.
    """
    if x.ndim < 2:
        return bfp_quantize(x, mantissa_bits)
    lead = x.shape[:-2]
    x2 = x.reshape((-1,) + x.shape[-2:])
    if tile is None:
        e = block_exponent(x2, axis=(1, 2), keepdims=True)
        q = quantize_block(x2, e, mantissa_bits)
    else:
        import jax

        q = jax.vmap(lambda t: _tile_quantize_2d(t, mantissa_bits, tile))(x2)
    return q.reshape(lead + x.shape[-2:])


def bfp_matmul(a: jnp.ndarray, b: jnp.ndarray, mantissa_bits: int, tile=None) -> jnp.ndarray:
    """Reference BFP matmul: quantize A row-blocks / B col-blocks, FP32 accum.

    With ``tile=t``: A is quantized with one exponent per (t x t) tile, B the
    same; products of mantissas are exact in f32 for m <= 12 (2m-1 <= 24
    significand bits), and tile-partials are accumulated in f32 — exactly the
    paper's "tile multiplications in fixed point, accumulated in floating
    point".

    a: (..., M, K), b: (K, N) or (..., K, N).

    Accumulation order: with tiles, partial products are summed *per k-tile*
    in FP32, in increasing k order — the paper's "tile multiplications in
    fixed point, accumulated in floating point", and bit-identical to the
    Pallas kernel's k-innermost grid accumulation.
    """
    qa = bfp_quantize_tiled(a, mantissa_bits, tile)
    qb = bfp_quantize_tiled(b, mantissa_bits, tile)
    if tile is None:
        return jnp.matmul(qa, qb)
    k_dim = qa.shape[-1]
    pk = (-k_dim) % tile
    qa = jnp.pad(qa, [(0, 0)] * (qa.ndim - 1) + [(0, pk)])
    qb = jnp.pad(qb, [(0, 0)] * (qb.ndim - 2) + [(0, pk), (0, 0)])
    acc = None
    for k0 in range(0, k_dim + pk, tile):
        part = jnp.matmul(qa[..., :, k0 : k0 + tile], qb[..., k0 : k0 + tile, :])
        acc = part if acc is None else acc + part
    return acc


def bfp_matmul_grid(a: jnp.ndarray, b: jnp.ndarray, mantissa_bits: int, tile: int) -> jnp.ndarray:
    """Grid-exact emulation of the Pallas kernel, for the test oracle.

    Replays the kernel's exact structure — zero-pad to tile multiples, then
    (t x t) @ (t x t) dots accumulated in increasing-k order per output tile
    — so the result is bit-identical to ``bfp_matmul.bfp_matmul`` on every
    shape and mantissa width (same dot shapes => same XLA reduction order).
    Quadratic trace size; use only on test-sized inputs. ``bfp_matmul``
    (slab accumulation) is the semantics used in L2 models; it agrees with
    this to f32 summation-order tolerance, exactly for m <= 8 where tile
    dots are exact.
    """
    m_dim, k_dim = a.shape
    _, n_dim = b.shape
    t = tile
    ap = jnp.pad(a, ((0, (-m_dim) % t), (0, (-k_dim) % t)))
    bp = jnp.pad(b, ((0, (-k_dim) % t), (0, (-n_dim) % t)))
    mt, kt, nt = ap.shape[0] // t, ap.shape[1] // t, bp.shape[1] // t
    rows = []
    for i in range(mt):
        row = []
        for j in range(nt):
            acc = jnp.zeros((t, t), jnp.float32)
            for k in range(kt):
                qa = bfp_quantize(ap[i * t : (i + 1) * t, k * t : (k + 1) * t], mantissa_bits)
                qb = bfp_quantize(bp[k * t : (k + 1) * t, j * t : (j + 1) * t], mantissa_bits)
                acc = acc + jnp.dot(qa, qb, preferred_element_type=jnp.float32)
            row.append(acc)
        rows.append(jnp.concatenate(row, axis=1))
    return jnp.concatenate(rows, axis=0)[:m_dim, :n_dim]


# --- Table-1 mode: custom narrow floating point ---------------------------


def fp_custom_quantize(x: jnp.ndarray, mantissa_bits: int, exponent_bits: int) -> jnp.ndarray:
    """Simulate a narrow FP format with ``mantissa_bits`` total significand
    bits (including the implicit leading 1, FP32-style counting: FP32 has 24)
    and ``exponent_bits`` of exponent, bias 2^(e-1)-1.

    Per-element exponents (this is FP, not BFP). Overflow saturates to the
    max finite value; underflow flushes to zero (no denormals) — the simplest
    hardware-honest choice and the one that makes 2-bit exponents diverge the
    way Table 1 reports.
    """
    m = mantissa_bits
    eb = exponent_bits
    bias = 2 ** (eb - 1) - 1
    e_max = 2**eb - 2 - bias  # all-ones exponent reserved (inf/nan)
    e_min = 1 - bias
    zero = x == 0
    _, ex = jnp.frexp(jnp.where(zero, 1.0, x))
    e = ex - 1  # floor(log2|x|)
    e_clamped = jnp.clip(e, e_min, e_max)
    step = jnp.ldexp(jnp.float32(1.0), e_clamped - (m - 1))
    q = jnp.round(x / step)
    # Rounding may cross a binade (|q| == 2^m): that value is exact in the
    # next binade, so keep it unless already at e_max — then clamp to the
    # max finite value.
    max_finite = (2.0 - 2.0 ** (1 - m)) * jnp.ldexp(jnp.float32(1.0), e_max)
    y = jnp.clip(q * step, -max_finite, max_finite)
    # flush-to-zero below half the smallest normal
    tiny = jnp.ldexp(jnp.float32(1.0), e_min)
    y = jnp.where(jnp.abs(x) < tiny * 0.5, 0.0, y)
    return jnp.where(zero, 0.0, y).astype(jnp.float32)
