"""MLP classifier — the quickstart model and the Pallas-kernel-bearing path.

Three dense layers over flattened images. With ``use_pallas`` configs the
matmuls lower through the L1 Pallas kernel, so the artifacts built from this
model prove L1 -> L2 -> L3 composition end-to-end (examples/quickstart.rs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L


def make(hidden: tuple[int, ...] = (128, 64)):
    def init(key, num_classes: int, hw: int, channels: int):
        in_dim = hw * hw * channels
        dims = (in_dim,) + hidden + (num_classes,)
        keys = jax.random.split(key, len(dims) - 1)
        p = {f"fc{i}": L.dense_init(keys[i], dims[i], dims[i + 1]) for i in range(len(dims) - 1)}
        return p, {}

    def apply(qmm, cfg, p, s, x, train: bool):
        del train
        y = x.reshape(x.shape[0], -1)
        n = len(p)
        for i in range(n):
            y = L.dense_apply(qmm, p[f"fc{i}"], y)
            if i != n - 1:
                y = L.relu(y, cfg)
        return y, s

    return init, apply
