"""LSTM character language model (scaled-down Merity-style LSTM on PTB).

Embedding lookup is a gather (FP32 — not a dot product); the LSTM gate
matmuls and the output projection run through the quantized matmul, so the
recurrence exercises the paper's BFP path at every timestep in both the
forward scan and BPTT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L


def make(embed: int = 32, hidden: int = 64):
    def init(key, vocab: int, seq: int):
        del seq
        k1, k2, k3 = jax.random.split(key, 3)
        return (
            {
                "embed": jax.random.normal(k1, (vocab, embed), jnp.float32) * 0.1,
                "lstm": L.lstm_init(k2, embed, hidden),
                "fc": L.dense_init(k3, hidden, vocab, scale=(1.0 / hidden) ** 0.5),
            },
            {},  # no BN state
        )

    def apply(qmm, cfg, p, s, tokens, train: bool):
        """tokens: (B, T) int32 -> logits (B, T, vocab)."""
        del train
        x = jnp.take(p["embed"], tokens, axis=0)  # (B, T, E), FP32 gather
        h = L.lstm_apply(qmm, p["lstm"], x, cfg)  # (B, T, H)
        b, t, hd = h.shape
        logits = L.dense_apply(qmm, p["fc"], h.reshape(b * t, hd))
        return logits.reshape(b, t, -1), s

    return init, apply
