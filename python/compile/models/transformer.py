"""Decoder-only transformer LM — the repo's *extension* experiment.

The paper (2018) evaluates CNNs and LSTMs; the obvious follow-up question
is whether HBFP survives attention. This model quantizes every *weight*
matmul (QKV projection, output projection, both MLP matmuls, the LM head)
through the same qmatmul custom-VJP path as the paper's ops. The two
activation-activation matmuls (Q·Kᵀ and A·V) stay FP32: they are batched
per-head contractions with no long-lived operand, i.e. exactly the
"other operations" bucket of the hybrid scheme (documented as HBFP-W in
DESIGN.md; the ablation harness compares it against fp32).

Pre-LN blocks, learned positional embeddings, causal mask.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L
from ..numerics import q_act


def layer_norm(x, p, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def ln_init(d):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def make(d_model: int = 64, n_heads: int = 2, n_layers: int = 2, d_ff: int = 128):
    head = d_model // n_heads

    def init(key, vocab: int, seq: int):
        keys = jax.random.split(key, 3 + 4 * n_layers)
        p = {
            "embed": jax.random.normal(keys[0], (vocab, d_model), jnp.float32) * 0.08,
            "pos": jax.random.normal(keys[1], (seq, d_model), jnp.float32) * 0.02,
            "ln_f": ln_init(d_model),
            "head": L.dense_init(keys[2], d_model, vocab, scale=(1.0 / d_model) ** 0.5),
        }
        for i in range(n_layers):
            k = keys[3 + 4 * i : 7 + 4 * i]
            p[f"blk{i}"] = {
                "ln1": ln_init(d_model),
                "ln2": ln_init(d_model),
                "qkv": L.dense_init(k[0], d_model, 3 * d_model, scale=(1.0 / d_model) ** 0.5),
                "proj": L.dense_init(k[1], d_model, d_model, scale=(1.0 / d_model) ** 0.5),
                "ff1": L.dense_init(k[2], d_model, d_ff),
                "ff2": L.dense_init(k[3], d_ff, d_model, scale=(1.0 / d_ff) ** 0.5),
            }
        return p, {}

    def attention(qmm, cfg, bp, x):
        """x: (B, T, D). Weight matmuls quantized; score/AV matmuls FP32."""
        b, t, d = x.shape
        qkv = L.dense_apply(qmm, bp["qkv"], x.reshape(b * t, d)).reshape(b, t, 3, n_heads, head)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # (B, T, H, h)
        q = q.transpose(0, 2, 1, 3)  # (B, H, T, h)
        k = k.transpose(0, 2, 3, 1)  # (B, H, h, T)
        v = v.transpose(0, 2, 1, 3)
        scores = jnp.matmul(q, k) / (head**0.5)  # FP32 activation matmul
        mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
        scores = jnp.where(mask, scores, -1e9)
        attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.matmul(attn, v)  # (B, H, T, h), FP32
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b * t, d)
        out = L.dense_apply(qmm, bp["proj"], ctx)
        return q_act(out.reshape(b, t, d), cfg)

    def mlp(qmm, cfg, bp, x):
        b, t, d = x.shape
        h = L.dense_apply(qmm, bp["ff1"], x.reshape(b * t, d))
        h = q_act(jax.nn.gelu(h), cfg)
        out = L.dense_apply(qmm, bp["ff2"], h)
        return q_act(out.reshape(b, t, d), cfg)

    def apply(qmm, cfg, p, s, tokens, train: bool):
        del train
        b, t = tokens.shape
        x = jnp.take(p["embed"], tokens, axis=0) + p["pos"][:t]
        for i in range(n_layers):
            bp = p[f"blk{i}"]
            x = x + attention(qmm, cfg, bp, layer_norm(x, bp["ln1"]))
            x = x + mlp(qmm, cfg, bp, layer_norm(x, bp["ln2"]))
        x = layer_norm(x, p["ln_f"])
        logits = L.dense_apply(qmm, p["head"], x.reshape(b * t, -1))
        return logits.reshape(b, t, -1), s

    return init, apply
