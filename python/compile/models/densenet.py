"""DenseNet-mini: densely connected CNN (scaled-down DenseNet-40).

BN→ReLU→conv3x3 layers whose outputs concatenate onto the running feature
stack; a 1x1 transition conv + 2x2 average pool between blocks. Convs and
the classifier run through the quantized matmul, concatenation and pooling
are pure data movement / FP32 reductions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L


def make(growth: int = 8, layers_per_block: int = 3, num_blocks: int = 2, stem: int = 16):
    def init(key, num_classes: int, hw: int, channels: int):
        del hw
        n_layers = num_blocks * layers_per_block + (num_blocks - 1) + 2
        keys = jax.random.split(key, n_layers + 1)
        ki = 0
        p = {"stem": L.conv_init(keys[ki], 3, 3, channels, stem)}
        ki += 1
        s = {}
        ch = stem
        for b in range(num_blocks):
            for l in range(layers_per_block):
                name = f"b{b}l{l}"
                bnp, bns = L.bn_init(ch)
                p[name] = {"bn": bnp, "conv": L.conv_init(keys[ki], 3, 3, ch, growth)}
                s[name] = bns
                ch += growth
                ki += 1
            if b != num_blocks - 1:
                name = f"t{b}"
                bnp, bns = L.bn_init(ch)
                out_ch = ch // 2
                p[name] = {"bn": bnp, "conv": L.conv_init(keys[ki], 1, 1, ch, out_ch)}
                s[name] = bns
                ch = out_ch
                ki += 1
        bnp, bns = L.bn_init(ch)
        p["bn_final"] = bnp
        s["bn_final"] = bns
        p["fc"] = L.dense_init(keys[ki], ch, num_classes, scale=(1.0 / ch) ** 0.5)
        return p, s

    def apply(qmm, cfg, p, s, x, train: bool):
        y = L.conv_apply(qmm, p["stem"], x)
        new_s = {}
        for b in range(num_blocks):
            for l in range(layers_per_block):
                name = f"b{b}l{l}"
                h, bs = L.bn_apply(p[name]["bn"], s[name], y, train)
                h = L.relu(h, cfg)
                h = L.conv_apply(qmm, p[name]["conv"], h)
                y = jnp.concatenate([y, h], axis=-1)
                new_s[name] = bs
            if b != num_blocks - 1:
                name = f"t{b}"
                h, bs = L.bn_apply(p[name]["bn"], s[name], y, train)
                h = L.relu(h, cfg)
                y = L.conv_apply(qmm, p[name]["conv"], h)
                y = L.avg_pool2(y)
                new_s[name] = bs
        y, bs = L.bn_apply(p["bn_final"], s["bn_final"], y, train)
        new_s["bn_final"] = bs
        y = L.relu(y, cfg)
        y = L.global_avg_pool(y)
        return L.dense_apply(qmm, p["fc"], y), new_s

    return init, apply
