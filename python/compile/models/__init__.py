"""Model registry: name -> (init, apply, kind, hyperparams).

``init(key, ...)`` returns ``(params, bn_state)`` pytrees;
``apply(qmm, cfg, params, bn_state, x, train)`` returns
``(logits, new_bn_state)``. Image models take ``(num_classes, hw,
channels)``; text models take ``(vocab, seq)``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from . import densenet, lstm_lm, mlp, resnet, transformer


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    kind: str  # "image" | "text"
    init: Callable
    apply: Callable
    weight_decay: float
    momentum: float = 0.9


def _spec(name, kind, make_fn, wd, **kw):
    init, apply = make_fn(**kw)
    return ModelSpec(name=name, kind=kind, init=init, apply=apply, weight_decay=wd)


MODELS = {
    "mlp": _spec("mlp", "image", mlp.make, 1e-4),
    "resnet_mini": _spec("resnet_mini", "image", resnet.make, 5e-4, width=8, blocks=(1, 1, 1)),
    "wrn_mini": _spec("wrn_mini", "image", resnet.make, 5e-4, width=16, blocks=(1, 1, 1)),
    "densenet_mini": _spec("densenet_mini", "image", densenet.make, 5e-4),
    "lstm": _spec("lstm", "text", lstm_lm.make, 0.0),
    # extension: HBFP on attention (weight matmuls quantized — see
    # models/transformer.py docstring and DESIGN.md §Extension)
    "transformer_mini": _spec("transformer_mini", "text", transformer.make, 1e-4),
}
