"""ResNet-mini / WideResNet-mini: post-activation residual CNNs.

Scaled-down counterparts of the paper's ResNet-50 / WideResNet-28-10
(DESIGN.md §5 substitutions): same op mix — 3x3 convs, BN, ReLU, identity
and 1x1-projection shortcuts, global average pool, dense classifier — with
widths/depths sized for CPU training. Every conv and the classifier run
through the quantized matmul; BN and activations are FP32 (hybrid).

``make(width, blocks)`` builds the family; the registry exposes
``resnet_mini`` (w=8, 1 block/stage) and ``wrn_mini`` (w=16, 2 blocks/stage).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L


def _block_init(key, cin, cout, stride):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "conv1": L.conv_init(k1, 3, 3, cin, cout),
        "conv2": L.conv_init(k2, 3, 3, cout, cout),
    }
    bn1p, bn1s = L.bn_init(cout)
    bn2p, bn2s = L.bn_init(cout)
    p["bn1"], p["bn2"] = bn1p, bn2p
    s = {"bn1": bn1s, "bn2": bn2s}
    if stride != 1 or cin != cout:
        p["proj"] = L.conv_init(k3, 1, 1, cin, cout)
    return p, s


def _block_apply(qmm, cfg, p, s, x, stride, train):
    y = L.conv_apply(qmm, p["conv1"], x, stride=stride)
    y, s1 = L.bn_apply(p["bn1"], s["bn1"], y, train)
    y = L.relu(y, cfg)
    y = L.conv_apply(qmm, p["conv2"], y)
    y, s2 = L.bn_apply(p["bn2"], s["bn2"], y, train)
    sc = L.conv_apply(qmm, p["proj"], x, stride=stride) if "proj" in p else x
    out = L.relu(y + sc, cfg)
    return out, {"bn1": s1, "bn2": s2}


def make(width: int, blocks: tuple[int, int, int]):
    """Residual CNN with stage widths (w, 2w, 4w) and the given block counts."""

    def init(key, num_classes: int, hw: int, channels: int):
        del hw
        keys = jax.random.split(key, 2 + sum(blocks))
        p = {"stem": L.conv_init(keys[0], 3, 3, channels, width)}
        bnp, bns = L.bn_init(width)
        p["bn0"] = bnp
        s = {"bn0": bns}
        cin = width
        ki = 1
        for si, nb in enumerate(blocks):
            cout = width * (2**si)
            for bi in range(nb):
                stride = 2 if (si > 0 and bi == 0) else 1
                bp, bs = _block_init(keys[ki], cin, cout, stride)
                p[f"s{si}b{bi}"] = bp
                s[f"s{si}b{bi}"] = bs
                cin = cout
                ki += 1
        p["fc"] = L.dense_init(keys[ki], cin, num_classes, scale=(1.0 / cin) ** 0.5)
        return p, s

    def apply(qmm, cfg, p, s, x, train: bool):
        y = L.conv_apply(qmm, p["stem"], x)
        y, s0 = L.bn_apply(p["bn0"], s["bn0"], y, train)
        y = L.relu(y, cfg)
        new_s = {"bn0": s0}
        for si, nb in enumerate(blocks):
            for bi in range(nb):
                stride = 2 if (si > 0 and bi == 0) else 1
                y, bs = _block_apply(qmm, cfg, p[f"s{si}b{bi}"], s[f"s{si}b{bi}"], y, stride, train)
                new_s[f"s{si}b{bi}"] = bs
        y = L.global_avg_pool(y)
        logits = L.dense_apply(qmm, p["fc"], y)
        return logits, new_s

    return init, apply
