"""Functional NN layers routed through the HBFP quantized matmul.

Every dot-product-shaped op (dense, conv2d, LSTM gate matmuls) is expressed
as a 2-D ``qmatmul`` so the paper's BFP conversion happens exactly at dot
product boundaries; everything else (bias adds, BN, activations) is FP32.

Convolutions are lowered to im2col + matmul: the patch extraction / scatter
(pure data movement) stays FP32 while the three contraction passes (fwd,
dgrad, wgrad) inherit qmatmul's custom VJP — matching §5.1's simulation and
the paper's accelerator, whose MatMul unit serves convs via the same
dataflow.

Parameters are plain dicts of jnp arrays; layer functions are pure.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .numerics import NumericConfig, q_act


def dense_init(key, in_dim: int, out_dim: int, scale: float | None = None):
    """He-normal weight + zero bias."""
    wkey, _ = jax.random.split(key)
    s = scale if scale is not None else (2.0 / in_dim) ** 0.5
    return {
        "w": jax.random.normal(wkey, (in_dim, out_dim), jnp.float32) * s,
        "b": jnp.zeros((out_dim,), jnp.float32),
    }


def dense_apply(qmm, p, x):
    """x: (B, in) -> (B, out). The matmul is quantized, the bias add FP32."""
    return qmm(x, p["w"]) + p["b"]


def conv_init(key, kh: int, kw: int, cin: int, cout: int):
    """He-normal conv kernel stored as (kh, kw, cin, cout)."""
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * (2.0 / fan_in) ** 0.5
    return {"w": w}


def conv_apply(qmm, p, x, stride: int = 1, padding: str = "SAME"):
    """2-D conv, NHWC, via im2col + quantized matmul.

    x: (B, H, W, Cin) -> (B, H', W', Cout).

    ``conv_general_dilated_patches`` returns patch channels ordered as
    (cin, kh, kw) — verified in test_layers.py — so the kernel is permuted
    to match before flattening.
    """
    w = p["w"]
    kh, kw, cin, cout = w.shape
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, H', W', cin*kh*kw)
    b, ho, wo, _ = patches.shape
    cols = patches.reshape(b * ho * wo, cin * kh * kw)
    wmat = w.transpose(2, 0, 1, 3).reshape(cin * kh * kw, cout)
    out = qmm(cols, wmat)
    return out.reshape(b, ho, wo, cout)


# ----------------------------------------------------------- batch norm


def bn_init(ch: int):
    """Returns (params, state): learnable scale/shift + running stats."""
    params = {"gamma": jnp.ones((ch,), jnp.float32), "beta": jnp.zeros((ch,), jnp.float32)}
    state = {"mean": jnp.zeros((ch,), jnp.float32), "var": jnp.ones((ch,), jnp.float32)}
    return params, state


def bn_apply(p, s, x, train: bool, momentum: float = 0.9, eps: float = 1e-5):
    """BN over all but the channel (last) axis. FP32 throughout (§4.1:
    "facilitates ... batch normalization without the restrictions imposed
    by BFP"). Returns (y, new_state)."""
    axes = tuple(range(x.ndim - 1))
    if train:
        mean = jnp.mean(x, axis=axes)
        var = jnp.var(x, axis=axes)
        new_s = {
            "mean": momentum * s["mean"] + (1 - momentum) * mean,
            "var": momentum * s["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = s["mean"], s["var"]
        new_s = s
    y = (x - mean) * lax.rsqrt(var + eps) * p["gamma"] + p["beta"]
    return y, new_s


def relu(x, cfg: NumericConfig):
    """ReLU with a (Table-1 mode only) narrow-FP activation edge."""
    return q_act(jax.nn.relu(x), cfg)


# ----------------------------------------------------------------- LSTM


def lstm_init(key, in_dim: int, hidden: int):
    """Standard LSTM cell parameters; gate order (i, f, g, o)."""
    k1, k2 = jax.random.split(key)
    s_in = (1.0 / in_dim) ** 0.5
    s_h = (1.0 / hidden) ** 0.5
    return {
        "wx": jax.random.normal(k1, (in_dim, 4 * hidden), jnp.float32) * s_in,
        "wh": jax.random.normal(k2, (hidden, 4 * hidden), jnp.float32) * s_h,
        # forget-gate bias 1.0: standard trick, used by the AWD-LSTM line
        "b": jnp.concatenate(
            [
                jnp.zeros((hidden,), jnp.float32),
                jnp.ones((hidden,), jnp.float32),
                jnp.zeros((2 * hidden,), jnp.float32),
            ]
        ),
    }


def lstm_step(qmm, p, carry, x_t, cfg: NumericConfig):
    """One LSTM step. The two gate matmuls are quantized; the elementwise
    gate math is FP32 (activations stay FP in HBFP)."""
    h, c = carry
    hidden = h.shape[-1]
    gates = qmm(x_t, p["wx"]) + qmm(h, p["wh"]) + p["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f), jax.nn.sigmoid(o)
    g = jnp.tanh(g)
    c2 = q_act(f * c + i * g, cfg)
    h2 = q_act(o * jnp.tanh(c2), cfg)
    del hidden
    return (h2, c2), h2


def lstm_apply(qmm, p, x, cfg: NumericConfig):
    """x: (B, T, in) -> outputs (B, T, hidden), scanning over time."""
    hidden = p["wh"].shape[0]
    b = x.shape[0]
    carry0 = (
        jnp.zeros((b, hidden), jnp.float32),
        jnp.zeros((b, hidden), jnp.float32),
    )
    xs = jnp.swapaxes(x, 0, 1)  # (T, B, in)

    def step(carry, x_t):
        return lstm_step(qmm, p, carry, x_t, cfg)

    _, ys = jax.lax.scan(step, carry0, xs)
    return jnp.swapaxes(ys, 0, 1)


# ------------------------------------------------------------- pooling


def global_avg_pool(x):
    """(B, H, W, C) -> (B, C). FP32 (a reduction, not a dot product —
    the paper folds it into the activation unit)."""
    return jnp.mean(x, axis=(1, 2))


def avg_pool2(x):
    """2x2 average pooling, stride 2."""
    return lax.reduce_window(x, 0.0, lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
