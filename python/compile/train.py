"""Build the train/eval/init step functions lowered by aot.py.

All three functions take and return *flat lists* of arrays so the HLO
parameter order is pinned and recorded in the manifest:

- ``init(seed)``                         -> state leaves
- ``train(state..., x, y, lr)``          -> state' leaves ++ [loss, acc]
- ``eval(state..., x, y)``               -> [loss_sum, correct_sum]

"state" is the concatenation of param leaves, momentum leaves and BN-state
leaves, in ``jax.tree_util`` flattening order. eval receives the full state
(momentum included) so the rust trainer keeps ONE device-resident buffer
list for both steps; XLA dead-code-eliminates the unused momentum inputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import optim
from .models import ModelSpec
from .numerics import NumericConfig, make_qmatmul


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean cross entropy. logits (..., C), labels (...) int32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


class StepBuilder:
    """Holds the (model, numeric config, dataset dims) triple and builds the
    three flat-signature functions plus their example arguments."""

    def __init__(self, spec: ModelSpec, cfg: NumericConfig, *, batch: int, **dims):
        self.spec = spec
        self.cfg = cfg
        self.batch = batch
        self.dims = dims  # image: classes/hw/channels; text: vocab/seq
        self.qmm = make_qmatmul(cfg)
        # A throwaway init defines the state treedef and leaf metadata.
        if spec.kind == "image":
            p, s = spec.init(jax.random.PRNGKey(0), dims["classes"], dims["hw"], dims["channels"])
        else:
            p, s = spec.init(jax.random.PRNGKey(0), dims["vocab"], dims["seq"])
        m = optim.momentum_init(p)
        self.state_tree = (p, m, s)
        leaves, self.treedef = jax.tree_util.tree_flatten(self.state_tree)
        self.state_avals = [jax.ShapeDtypeStruct(l.shape, l.dtype) for l in leaves]
        self.state_paths = [
            "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(self.state_tree)[0]
        ]

    # ------------------------------------------------------------ shapes

    def batch_avals(self):
        if self.spec.kind == "image":
            x = jax.ShapeDtypeStruct(
                (self.batch, self.dims["hw"], self.dims["hw"], self.dims["channels"]), jnp.float32
            )
            y = jax.ShapeDtypeStruct((self.batch,), jnp.int32)
        else:
            x = jax.ShapeDtypeStruct((self.batch, self.dims["seq"]), jnp.int32)
            y = jax.ShapeDtypeStruct((self.batch, self.dims["seq"]), jnp.int32)
        return x, y

    # --------------------------------------------------------- functions

    def init_fn(self):
        spec, dims = self.spec, self.dims

        def init(seed):
            key = jax.random.PRNGKey(seed)
            if spec.kind == "image":
                p, s = spec.init(key, dims["classes"], dims["hw"], dims["channels"])
            else:
                p, s = spec.init(key, dims["vocab"], dims["seq"])
            m = optim.momentum_init(p)
            return jax.tree_util.tree_leaves((p, m, s))

        return init

    def _loss(self, p, s, x, y, train: bool):
        logits, new_s = self.spec.apply(self.qmm, self.cfg, p, s, x, train)
        return cross_entropy(logits, y), (new_s, accuracy(logits, y))

    def train_fn(self):
        treedef = self.treedef

        def train(*args):
            n = len(self.state_avals)
            state_leaves, (x, y, lr) = list(args[:n]), args[n:]
            p, m, s = jax.tree_util.tree_unflatten(treedef, state_leaves)
            (loss, (new_s, acc)), grads = jax.value_and_grad(
                lambda pp: self._loss(pp, s, x, y, True), has_aux=True
            )(p)
            new_p, new_m = optim.sgd_update(
                p, m, grads, lr, self.cfg, self.spec.momentum, self.spec.weight_decay
            )
            return jax.tree_util.tree_leaves((new_p, new_m, new_s)) + [loss, acc]

        return train

    def eval_fn(self):
        treedef = self.treedef

        def evaluate(*args):
            n = len(self.state_avals)
            state_leaves, (x, y) = list(args[:n]), args[n:]
            p, _, s = jax.tree_util.tree_unflatten(treedef, state_leaves)
            logits, _ = self.spec.apply(self.qmm, self.cfg, p, s, x, False)
            logp = jax.nn.log_softmax(logits, axis=-1)
            ll = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
            loss_sum = -jnp.sum(ll) / (1 if self.spec.kind == "image" else y.shape[-1])
            correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)) / (
                1 if self.spec.kind == "image" else y.shape[-1]
            )
            return [loss_sum, correct]

        return evaluate
