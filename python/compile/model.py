"""L2 entry point (structure contract): re-exports the model zoo and the
step builders. The real definitions live in :mod:`compile.models`,
:mod:`compile.train`, :mod:`compile.numerics` and :mod:`compile.layers`;
this module exists so the documented layout (``python/compile/model.py``)
has a stable import path.
"""

from .models import MODELS, ModelSpec  # noqa: F401
from .numerics import FP32, NumericConfig, parse_config  # noqa: F401
from .train import StepBuilder, accuracy, cross_entropy  # noqa: F401
