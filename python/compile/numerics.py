"""HBFP numeric configurations and the quantized dot-product primitive.

This is the paper's §4.1 as a reusable JAX layer: *all* dot-product-shaped
computations (matmul, conv-as-im2col-matmul, LSTM gate matmuls — forward,
input-gradient and weight-gradient passes) run through :func:`qmatmul`,
which quantizes both operands to tiled BFP before the contraction and
accumulates in FP32. Everything else (activations, norms, losses, optimizer)
stays FP32.

Three numeric modes (``NumericConfig.kind``):

- ``fp32``      — identity; the baseline.
- ``hbfp``      — the paper's format: BFP with ``mantissa``-bit two's
                  complement mantissas, one shared exponent per
                  ``tile`` × ``tile`` tile (``tile=None`` = whole tensor),
                  and ``storage``-bit wide weight storage (applied by
                  :mod:`compile.optim` at update time).
- ``fp_custom`` — Table-1 mode: *every* tensor edge (operands, gradients,
                  activations, updated weights) is quantized to a narrow
                  per-element floating point with ``mantissa`` significand
                  bits and ``exponent_bits`` exponent bits.

The custom-VJP wiring mirrors the paper's GPU simulation (§5.1): quantize
the inputs/outputs of both forward and backward passes around a native
FP32 op.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.bfp_matmul import bfp_matmul as pallas_bfp_matmul


@dataclasses.dataclass(frozen=True)
class NumericConfig:
    """A numeric representation for training; see module docstring."""

    kind: str = "fp32"  # "fp32" | "hbfp" | "fp_custom"
    mantissa: int = 8  # dot-product mantissa bits (incl. sign, 2's compl.)
    storage: int = 16  # weight-storage mantissa bits (hbfp only)
    tile: Optional[int] = 24  # exponent-sharing tile; None = whole tensor
    exponent_bits: int = 8  # fp_custom only
    use_pallas: bool = False  # route matmuls through the L1 Pallas kernel

    @property
    def name(self) -> str:
        if self.kind == "fp32":
            return "fp32"
        if self.kind == "hbfp":
            t = "none" if self.tile is None else str(self.tile)
            p = "p" if self.use_pallas else ""
            return f"hbfp{p}{self.mantissa}_{self.storage}_t{t}"
        if self.kind == "fp_custom":
            return f"fp_m{self.mantissa}_e{self.exponent_bits}"
        raise ValueError(self.kind)

    def validate(self) -> "NumericConfig":
        if self.kind not in ("fp32", "hbfp", "fp_custom"):
            raise ValueError(f"unknown numeric kind {self.kind!r}")
        if self.kind == "hbfp":
            if not 2 <= self.mantissa <= 24:
                raise ValueError(f"hbfp mantissa {self.mantissa} out of range")
            if self.storage < self.mantissa:
                raise ValueError("storage mantissa must be >= dot-product mantissa")
            if self.tile is not None and self.tile < 2:
                raise ValueError(f"tile {self.tile} too small")
            if self.use_pallas and self.tile is None:
                raise ValueError("pallas path requires a concrete tile size")
        if self.kind == "fp_custom" and not 2 <= self.exponent_bits <= 8:
            raise ValueError(f"exponent_bits {self.exponent_bits} out of range")
        return self


FP32 = NumericConfig()


def parse_config(name: str) -> NumericConfig:
    """Inverse of ``NumericConfig.name`` (used by aot.py and the CLI docs).

    Examples: ``fp32``, ``hbfp8_16_t24``, ``hbfp12_16_tnone``,
    ``hbfpp8_16_t24`` (pallas), ``fp_m4_e8``.
    """
    if name == "fp32":
        return FP32
    if name.startswith("fp_m"):
        m, e = name[4:].split("_e")
        return NumericConfig(kind="fp_custom", mantissa=int(m), exponent_bits=int(e)).validate()
    if name.startswith("hbfp"):
        body = name[4:]
        use_pallas = body.startswith("p")
        if use_pallas:
            body = body[1:]
        mant_store, tile_s = body.split("_t")
        m, s = mant_store.split("_")
        tile = None if tile_s == "none" else int(tile_s)
        return NumericConfig(
            kind="hbfp", mantissa=int(m), storage=int(s), tile=tile, use_pallas=use_pallas
        ).validate()
    raise ValueError(f"cannot parse numeric config {name!r}")


# ------------------------------------------------------------ quantizers


def q_operand(x: jnp.ndarray, cfg: NumericConfig) -> jnp.ndarray:
    """Quantize a dot-product operand (2-D) per the config."""
    if cfg.kind == "fp32":
        return x
    if cfg.kind == "hbfp":
        return ref.bfp_quantize_tiled(x, cfg.mantissa, cfg.tile)
    return ref.fp_custom_quantize(x, cfg.mantissa, cfg.exponent_bits)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _fp_custom_ste(x, mantissa, exponent_bits):
    """fp_custom quantization with a straight-through gradient.

    ``round`` has zero derivative; without STE every activation
    quantization kills the upstream gradient and only the classifier head
    trains (observed: Table-1 runs stuck at ~2.0 loss). The dot-product
    operands don't need this — qmatmul's custom VJP already bypasses the
    rounding — but activation edges are differentiated through.
    """
    return ref.fp_custom_quantize(x, mantissa, exponent_bits)


def _fp_custom_ste_fwd(x, mantissa, exponent_bits):
    return ref.fp_custom_quantize(x, mantissa, exponent_bits), None


def _fp_custom_ste_bwd(mantissa, exponent_bits, res, ct):
    del mantissa, exponent_bits, res
    return (ct,)


_fp_custom_ste.defvjp(_fp_custom_ste_fwd, _fp_custom_ste_bwd)


def q_act(x: jnp.ndarray, cfg: NumericConfig) -> jnp.ndarray:
    """Quantize an activation edge.

    HBFP stores activations in FP (hybrid — §4.1), so this is the identity
    for both fp32 and hbfp; fp_custom narrows every edge (Table-1 mode)
    with a straight-through gradient.
    """
    if cfg.kind == "fp_custom":
        return _fp_custom_ste(x, cfg.mantissa, cfg.exponent_bits)
    return x


def q_storage(w: jnp.ndarray, cfg: NumericConfig) -> jnp.ndarray:
    """Wide weight-storage quantization (§4.2), applied after each update."""
    if cfg.kind == "hbfp":
        w2 = w.reshape(w.shape if w.ndim >= 2 else (1, -1))
        q = ref.bfp_quantize_tiled(w2, cfg.storage, cfg.tile)
        return q.reshape(w.shape)
    if cfg.kind == "fp_custom":
        return ref.fp_custom_quantize(w, cfg.mantissa, cfg.exponent_bits)
    return w


def _dot(qa: jnp.ndarray, qb: jnp.ndarray, cfg: NumericConfig) -> jnp.ndarray:
    """FP32 contraction of already-quantized operands.

    One native FP32 matmul — exactly the paper's own GPU simulation (§5.1:
    quantize inputs, "execute the target operation in native floating-point
    arithmetic"). Explicit per-k-tile FP32 partial accumulation (what the
    hardware's tile adders do) is semantically equivalent at the precision
    relevant to convergence, but blows the XLA graph up into K/t tiny
    matmuls (measured: ~12x step time, 2-minute compiles), so the jnp
    simulation does not model summation order; the Pallas kernel does.
    """
    del cfg
    return jnp.matmul(qa, qb)


# --------------------------------------------------------- qmatmul (VJP)


def make_qmatmul(cfg: NumericConfig):
    """Build the quantized 2-D matmul for ``cfg`` with the paper's VJP.

    forward:   y  = Q(x) · Q(w)
    backward:  dx = Q(g) · Q(w)ᵀ        (BFP dot product)
               dw = Q(x)ᵀ · Q(g)        (BFP dot product)

    Square exponent tiles make "quantize then transpose" identical to
    "transpose then quantize", so quantizing before the transpose matches
    the paper's one-exponent-per-row/column convention in all three passes.
    """
    cfg.validate()

    if cfg.kind == "fp32":
        # No custom VJP needed; XLA differentiates the plain matmul.
        return jnp.matmul

    if cfg.use_pallas:
        # L1 kernel path: quantization + tiled fixed-point MAC fused in the
        # Pallas kernel; same semantics as the jnp path (pytest-asserted).
        @jax.custom_vjp
        def qmatmul(x, w):
            return pallas_bfp_matmul(x, w, cfg.mantissa, cfg.tile)

        def qmatmul_fwd(x, w):
            return qmatmul(x, w), (x, w)

        def qmatmul_bwd(res, g):
            x, w = res
            dx = pallas_bfp_matmul(g, w.T, cfg.mantissa, cfg.tile)
            dw = pallas_bfp_matmul(x.T, g, cfg.mantissa, cfg.tile)
            return dx, dw

        qmatmul.defvjp(qmatmul_fwd, qmatmul_bwd)
        return qmatmul

    @jax.custom_vjp
    def qmatmul(x, w):
        return _dot(q_operand(x, cfg), q_operand(w, cfg), cfg)

    def qmatmul_fwd(x, w):
        # Residuals are the *already quantized* operands: Q is idempotent
        # and square tiles commute with transpose, so the backward pass can
        # reuse them directly — 3 quantizations per layer per step instead
        # of 5 (§Perf L2; measured ~15% step-time win on the CNNs).
        qx = q_operand(x, cfg)
        qw = q_operand(w, cfg)
        return _dot(qx, qw, cfg), (qx, qw)

    def qmatmul_bwd(res, g):
        qx, qw = res
        qg = q_operand(g, cfg)
        dx = _dot(qg, qw.T, cfg)
        dw = _dot(qx.T, qg, cfg)
        return dx, dw

    qmatmul.defvjp(qmatmul_fwd, qmatmul_bwd)
    return qmatmul
