"""SGD with momentum + the paper's wide weight storage (§4.2, §5.1).

The paper's "shell optimizer": the update itself runs in FP32, then the
weights are written back in *two* BFP views — a wide-mantissa one
(``cfg.storage`` bits) that future updates read, and the narrow view
(``cfg.mantissa`` bits) that forward/backward passes consume. Here the wide
view is materialized by quantizing the updated master weights with
``q_storage``; the narrow view is produced on the fly inside ``qmatmul``
(quantizing its weight operand), so no separate narrow copy is stored.

Only dot-product weight tensors (keys ``w``/``wx``/``wh``/``embed``) are
BFP-stored; biases and BN parameters stay FP32 (they never feed the MatMul
unit). Weight decay likewise applies only to dot-product weights — the
standard no-decay-on-BN/bias convention the original papers use.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .numerics import NumericConfig, q_storage

# Parameter leaf names that are dot-product operands (stored in BFP).
DOT_WEIGHT_KEYS = ("w", "wx", "wh", "embed")


def _leaf_name(path) -> str:
    last = path[-1]
    return getattr(last, "key", getattr(last, "idx", ""))


def is_dot_weight(path) -> bool:
    return _leaf_name(path) in DOT_WEIGHT_KEYS


def momentum_init(params):
    """Momentum buffers: FP32 zeros shaped like params."""
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def sgd_update(params, moms, grads, lr, cfg: NumericConfig, momentum: float, weight_decay: float):
    """One SGD+momentum step with wide-BFP weight write-back.

    v' = mu * v + (g + wd * w);  w_fp32 = w - lr * v';  w' = Q_storage(w_fp32)
    """

    def upd(path, w, v, g):
        dot = is_dot_weight(path)
        g_eff = g + weight_decay * w if (dot and weight_decay > 0.0) else g
        v2 = momentum * v + g_eff
        w2 = w - lr * v2
        if dot:
            w2 = q_storage(w2, cfg)
        return w2, v2

    flat = jax.tree_util.tree_map_with_path(upd, params, moms, grads)
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_moms = jax.tree_util.tree_map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, new_moms
