"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

The CORE correctness signal of the stack: hypothesis sweeps shapes, scales,
mantissa widths and tile sizes, asserting the Pallas kernels agree with the
grid-exact oracle bit-for-bit and with the slab reference to f32
summation-order tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bfp_matmul import bfp_matmul
from compile.kernels.bfp_quantize import bfp_quantize_tiled, bfp_quantize_whole


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=shape) * scale).astype(np.float32)


# ---------------------------------------------------------------- quantize


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 70),
    cols=st.integers(1, 70),
    m=st.sampled_from([2, 4, 8, 12, 16]),
    tile=st.sampled_from([8, 16, 24, 32]),
    scale=st.sampled_from([1e-4, 1.0, 1e4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_kernel_matches_ref(rows, cols, m, tile, scale, seed):
    x = rand((rows, cols), seed, scale)
    got = np.asarray(bfp_quantize_tiled(jnp.array(x), m, tile))
    want = np.asarray(ref.bfp_quantize_tiled(jnp.array(x), m, tile))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 300),
    m=st.sampled_from([4, 8, 12]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_whole_matches_ref(n, m, seed):
    x = rand((n,), seed)
    got = np.asarray(bfp_quantize_whole(jnp.array(x), m))
    want = np.asarray(ref.bfp_quantize(jnp.array(x), m))
    np.testing.assert_array_equal(got, want)


def test_quantize_zero_block():
    x = jnp.zeros((16, 16), jnp.float32)
    got = np.asarray(bfp_quantize_tiled(x, 8, 8))
    assert np.all(got == 0.0)


def test_quantize_idempotent():
    x = rand((48, 48), 3)
    q1 = np.asarray(bfp_quantize_tiled(jnp.array(x), 8, 24))
    q2 = np.asarray(bfp_quantize_tiled(jnp.array(q1), 8, 24))
    np.testing.assert_array_equal(q1, q2)


@pytest.mark.parametrize("m", [2, 4, 8, 12])
def test_quantize_error_bound(m):
    """RNE error <= step/2 for unsaturated lanes, <= step at the positive
    clamp (two's complement: hi = 2^(m-1)-1 while rounding can hit 2^(m-1))."""
    x = rand((64, 64), 7, scale=3.0)
    q = np.asarray(ref.bfp_quantize_tiled(jnp.array(x), m, 16))
    for i in range(0, 64, 16):
        for j in range(0, 64, 16):
            tx, tq = x[i : i + 16, j : j + 16], q[i : i + 16, j : j + 16]
            e = np.floor(np.log2(np.abs(tx).max())) + 1  # frexp exponent
            step = 2.0 ** (e - (m - 1))
            saturated = tq >= (2 ** (m - 1) - 1) * step - 1e-9
            err = np.abs(tx - tq)
            assert err[~saturated].max(initial=0.0) <= step * (0.5 + 1e-6)
            assert err.max() <= step * (1.0 + 1e-6)


def test_quantize_preserves_sign_and_monotone():
    x = rand((32, 32), 11)
    q = np.asarray(ref.bfp_quantize_tiled(jnp.array(x), 8, 16))
    assert np.all(np.sign(q) * np.sign(x) >= 0)  # never flips sign


def test_quantize_high_precision_near_exact():
    """m=24 quantization of values already on a coarse grid is exact."""
    x = (np.round(rand((24, 24), 5) * 16) / 16).astype(np.float32)
    q = np.asarray(ref.bfp_quantize_tiled(jnp.array(x), 24, 24))
    np.testing.assert_array_equal(q, x)


# ---------------------------------------------------------------- matmul


@settings(max_examples=20, deadline=None)
@given(
    m_dim=st.integers(1, 48),
    k_dim=st.integers(1, 64),
    n_dim=st.integers(1, 48),
    m=st.sampled_from([4, 8, 12, 16]),
    tile=st.sampled_from([8, 16, 24]),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_kernel_bitexact_vs_grid_oracle(m_dim, k_dim, n_dim, m, tile, scale, seed):
    a = rand((m_dim, k_dim), seed, scale)
    b = rand((k_dim, n_dim), seed + 1)
    got = np.asarray(bfp_matmul(jnp.array(a), jnp.array(b), m, tile))
    want = np.asarray(ref.bfp_matmul_grid(jnp.array(a), jnp.array(b), m, tile))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(
    m_dim=st.integers(1, 48),
    k_dim=st.integers(1, 80),
    n_dim=st.integers(1, 48),
    m=st.sampled_from([8, 12]),
    tile=st.sampled_from([16, 24]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_kernel_close_to_slab_ref(m_dim, k_dim, n_dim, m, tile, seed):
    """The L2-facing slab reference agrees to f32 summation-order tolerance."""
    a = rand((m_dim, k_dim), seed)
    b = rand((k_dim, n_dim), seed + 1)
    got = np.asarray(bfp_matmul(jnp.array(a), jnp.array(b), m, tile))
    want = np.asarray(ref.bfp_matmul(jnp.array(a), jnp.array(b), m, tile))
    tol = 1e-5 * max(1.0, np.abs(want).max())
    assert np.abs(got - want).max() <= tol


@pytest.mark.parametrize("m,rel", [(4, 0.25), (8, 0.02), (12, 2e-3), (16, 2e-4)])
def test_matmul_error_decays_with_mantissa(m, rel):
    """BFP matmul converges to the FP32 product as mantissa width grows."""
    a = rand((64, 96), 1)
    b = rand((96, 64), 2)
    exact = a @ b
    got = np.asarray(bfp_matmul(jnp.array(a), jnp.array(b), m, 16))
    err = np.abs(got - exact).max() / np.abs(exact).max()
    assert err < rel, f"m={m}: rel err {err}"


def test_matmul_tiling_reduces_error_on_mixed_scales():
    """A matrix with per-block scale spread: tiled BFP beats whole-tensor."""
    rng = np.random.default_rng(0)
    a = rng.normal(size=(64, 64)).astype(np.float32)
    a[:32] *= 1e-3  # two very different exponent regimes in one tensor
    b = rng.normal(size=(64, 64)).astype(np.float32)
    exact = a @ b
    tiled = np.asarray(ref.bfp_matmul(jnp.array(a), jnp.array(b), 8, 16))
    whole = np.asarray(ref.bfp_matmul(jnp.array(a), jnp.array(b), 8, None))
    err_t = np.abs(tiled - exact).mean()
    err_w = np.abs(whole - exact).mean()
    assert err_t < err_w


def test_matmul_zero_inputs():
    a = jnp.zeros((24, 24), jnp.float32)
    b = jnp.zeros((24, 24), jnp.float32)
    got = np.asarray(bfp_matmul(a, b, 8, 8))
    assert np.all(got == 0)


def test_matmul_identity_power_of_two():
    """Powers of two quantize exactly; identity matmul is then exact."""
    a = np.diag(np.full(24, 2.0)).astype(np.float32)
    b = rand((24, 24), 9)
    qb = np.asarray(ref.bfp_quantize_tiled(jnp.array(b), 8, 24))
    got = np.asarray(bfp_matmul(jnp.array(a), jnp.array(b), 8, 24))
    np.testing.assert_allclose(got, 2 * qb, rtol=0, atol=0)


# ------------------------------------------------------------- fp_custom


def test_fp_custom_fp32_is_identity():
    x = rand((128,), 21, 10.0)
    y = np.asarray(ref.fp_custom_quantize(jnp.array(x), 24, 8))
    np.testing.assert_array_equal(x, y)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([2, 4, 8, 11, 24]),
    eb=st.sampled_from([2, 5, 6, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fp_custom_relative_error_bound(m, eb, seed):
    """Within representable range, rel. error <= 2^-m (half-ulp of m-bit)."""
    x = rand((256,), seed)
    y = np.asarray(ref.fp_custom_quantize(jnp.array(x), m, eb))
    bias = 2 ** (eb - 1) - 1
    e_max, e_min = 2**eb - 2 - bias, 1 - bias
    in_range = (np.abs(x) < 2.0 ** (e_max + 1) * (1 - 2.0 ** (-m))) & (np.abs(x) >= 2.0**e_min)
    rel = np.abs(y[in_range] - x[in_range]) / np.abs(x[in_range])
    assert rel.max(initial=0.0) <= 2.0**-m + 1e-9


def test_fp_custom_flush_to_zero():
    # 2-bit exponent: bias 1, e_min = 0 -> anything below 0.5 flushes
    x = jnp.array([0.2, -0.3, 0.9], jnp.float32)
    y = np.asarray(ref.fp_custom_quantize(x, 8, 2))
    assert y[0] == 0.0 and y[1] == 0.0 and y[2] != 0.0


def test_fp_custom_saturates():
    x = jnp.array([1e30, -1e30], jnp.float32)
    y = np.asarray(ref.fp_custom_quantize(x, 8, 5))
    # FP16-like: max finite ~ 2^15 * (2 - 2^-7)
    assert np.isfinite(y).all() and y[0] > 0 and y[1] < 0 and abs(y[0]) < 1e5
