"""L2 numerics: NumericConfig parsing, qmatmul forward/backward semantics,
weight-storage quantization, fp_custom mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import numerics
from compile.kernels import ref
from compile.numerics import NumericConfig, make_qmatmul, parse_config, q_storage


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.array((rng.normal(size=shape) * scale).astype(np.float32))


# ------------------------------------------------------------- parsing


@pytest.mark.parametrize(
    "name",
    ["fp32", "hbfp8_16_t24", "hbfp12_16_t24", "hbfp4_4_t8", "hbfp8_16_tnone", "hbfpp8_16_t24", "fp_m4_e8", "fp_m24_e2"],
)
def test_parse_roundtrip(name):
    cfg = parse_config(name)
    assert cfg.name == name


def test_parse_rejects_garbage():
    with pytest.raises(ValueError):
        parse_config("hbfp_banana")
    with pytest.raises(ValueError):
        parse_config("nope")


def test_validate_rejects_bad_configs():
    with pytest.raises(ValueError):
        NumericConfig(kind="hbfp", mantissa=1).validate()
    with pytest.raises(ValueError):
        NumericConfig(kind="hbfp", mantissa=12, storage=8).validate()
    with pytest.raises(ValueError):
        NumericConfig(kind="hbfp", use_pallas=True, tile=None).validate()
    with pytest.raises(ValueError):
        NumericConfig(kind="wat").validate()


# ------------------------------------------------------------- forward


def test_fp32_qmatmul_is_plain_matmul():
    qmm = make_qmatmul(parse_config("fp32"))
    a, b = rand((8, 16), 0), rand((16, 4), 1)
    np.testing.assert_array_equal(np.asarray(qmm(a, b)), np.asarray(a @ b))


def test_hbfp_forward_matches_ref_semantics():
    cfg = parse_config("hbfp8_16_t24")
    qmm = make_qmatmul(cfg)
    a, b = rand((30, 50), 2), rand((50, 20), 3)
    got = np.asarray(qmm(a, b))
    want = np.asarray(
        jnp.matmul(ref.bfp_quantize_tiled(a, 8, 24), ref.bfp_quantize_tiled(b, 8, 24))
    )
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_pallas_and_jnp_paths_agree():
    a, b = rand((20, 30), 4), rand((30, 10), 5)
    jp = make_qmatmul(parse_config("hbfp8_16_t24"))(a, b)
    pal = make_qmatmul(parse_config("hbfpp8_16_t24"))(a, b)
    scale = float(jnp.abs(jp).max())
    np.testing.assert_allclose(np.asarray(jp), np.asarray(pal), atol=2e-6 * max(scale, 1.0))


# ------------------------------------------------------------ backward


def test_hbfp_vjp_quantizes_all_three_passes():
    """dx must equal Q(g) @ Q(w)^T and dw must equal Q(x)^T @ Q(g)."""
    cfg = parse_config("hbfp8_16_t24")
    qmm = make_qmatmul(cfg)
    x, w = rand((12, 25), 6), rand((25, 7), 7)

    y, vjp = jax.vjp(qmm, x, w)
    g = rand(y.shape, 8)
    dx, dw = vjp(g)

    qg = ref.bfp_quantize_tiled(g, 8, 24)
    want_dx = jnp.matmul(qg, ref.bfp_quantize_tiled(w, 8, 24).T)
    want_dw = jnp.matmul(ref.bfp_quantize_tiled(x, 8, 24).T, qg)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(want_dx), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(want_dw), rtol=1e-5, atol=1e-6)


def test_fp32_grads_differ_from_hbfp4():
    """Sanity: aggressive quantization visibly perturbs gradients."""
    x, w = rand((16, 24), 9), rand((24, 8), 10)
    g = rand((16, 8), 11)

    def grads(cfg_name):
        qmm = make_qmatmul(parse_config(cfg_name))
        _, vjp = jax.vjp(qmm, x, w)
        return vjp(g)

    dx32, _ = grads("fp32")
    dx4, _ = grads("hbfp4_4_t24")
    assert float(jnp.abs(dx32 - dx4).max()) > 1e-3


def test_gradcheck_hbfp_close_to_fp32_at_high_mantissa():
    """hbfp16 gradients approach FP32 gradients (quantization -> 0)."""
    x, w = rand((10, 20), 12), rand((20, 5), 13)
    g = rand((10, 5), 14)
    _, vjp32 = jax.vjp(make_qmatmul(parse_config("fp32")), x, w)
    _, vjp16 = jax.vjp(make_qmatmul(parse_config("hbfp16_16_t24")), x, w)
    dx32, dw32 = vjp32(g)
    dx16, dw16 = vjp16(g)
    np.testing.assert_allclose(np.asarray(dx32), np.asarray(dx16), rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dw32), np.asarray(dw16), rtol=2e-3, atol=2e-4)


# -------------------------------------------------------- weight storage


def test_q_storage_hbfp_uses_wide_mantissa():
    cfg = parse_config("hbfp8_16_t24")
    w = rand((26, 26), 15)
    stored = q_storage(w, cfg)
    # 16-bit storage: much closer to w than the 8-bit working precision
    err16 = float(jnp.abs(stored - w).max())
    err8 = float(jnp.abs(ref.bfp_quantize_tiled(w, 8, 24) - w).max())
    assert err16 < err8 / 16
    # and idempotent
    np.testing.assert_array_equal(np.asarray(q_storage(stored, cfg)), np.asarray(stored))


def test_q_storage_fp32_identity():
    w = rand((5, 5), 16)
    np.testing.assert_array_equal(np.asarray(q_storage(w, parse_config("fp32"))), np.asarray(w))


def test_q_storage_handles_1d():
    cfg = parse_config("hbfp8_16_t24")
    w = rand((17,), 17)
    out = q_storage(w, cfg)
    assert out.shape == w.shape


# ------------------------------------------------------------ fp_custom


def test_fp_custom_qmatmul_quantizes_operands():
    cfg = parse_config("fp_m4_e8")
    qmm = make_qmatmul(cfg)
    a, b = rand((6, 6), 18), rand((6, 6), 19)
    got = np.asarray(qmm(a, b))
    want = np.asarray(
        jnp.matmul(ref.fp_custom_quantize(a, 4, 8), ref.fp_custom_quantize(b, 4, 8))
    )
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_q_act_only_active_for_fp_custom():
    x = rand((4, 4), 20)
    assert numerics.q_act(x, parse_config("fp32")) is x
    assert numerics.q_act(x, parse_config("hbfp8_16_t24")) is x
    y = numerics.q_act(x, parse_config("fp_m4_e8"))
    assert float(jnp.abs(y - x).max()) > 0  # actually quantized
