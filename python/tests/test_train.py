"""L2 training step: StepBuilder contracts (flat I/O order, state treedef),
optimizer semantics (momentum, weight decay, wide storage), eval metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim
from compile.models import MODELS
from compile.numerics import parse_config
from compile.train import StepBuilder, accuracy, cross_entropy

FP32 = parse_config("fp32")
HBFP = parse_config("hbfp8_16_t24")


def sb(model="mlp", cfg=FP32, **kw):
    dims = dict(classes=4, hw=8, channels=3)
    dims.update(kw)
    return StepBuilder(MODELS[model], cfg, batch=8, **dims)


# ------------------------------------------------------------ loss/metric


def test_cross_entropy_uniform():
    logits = jnp.zeros((5, 4))
    labels = jnp.array([0, 1, 2, 3, 0])
    assert abs(float(cross_entropy(logits, labels)) - np.log(4)) < 1e-5


def test_accuracy():
    logits = jnp.array([[3.0, 0, 0], [0, 3.0, 0], [0, 3.0, 0]])
    labels = jnp.array([0, 1, 2])
    assert abs(float(accuracy(logits, labels)) - 2 / 3) < 1e-6


# ---------------------------------------------------------------- builder


def test_flat_io_contract():
    b = sb()
    init = b.init_fn()
    leaves = init(jnp.int32(0))
    assert len(leaves) == len(b.state_avals) == len(b.state_paths)
    for leaf, aval in zip(leaves, b.state_avals):
        assert leaf.shape == aval.shape and leaf.dtype == aval.dtype
    train = b.train_fn()
    x = jnp.zeros((8, 8, 8, 3), jnp.float32)
    y = jnp.zeros((8,), jnp.int32)
    out = train(*leaves, x, y, jnp.float32(0.1))
    assert len(out) == len(leaves) + 2
    ev = b.eval_fn()(*leaves, x, y)
    assert len(ev) == 2


def test_state_paths_are_descriptive():
    b = sb()
    assert any("fc0" in p and p.endswith("w") for p in b.state_paths), b.state_paths


def test_train_step_changes_params_not_shapes():
    b = sb()
    leaves = b.init_fn()(jnp.int32(1))
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(8, 8, 8, 3)).astype(np.float32))
    y = jnp.array((np.arange(8) % 4).astype(np.int32))
    out = b.train_fn()(*leaves, x, y, jnp.float32(0.1))
    new_leaves = out[:-2]
    changed = sum(
        float(jnp.abs(a - b2).max()) > 0 for a, b2 in zip(leaves, new_leaves)
    )
    assert changed >= len(leaves) // 3  # params + momenta moved
    for a, b2 in zip(leaves, new_leaves):
        assert a.shape == b2.shape


def test_zero_lr_freezes_params_but_not_momentum():
    b = sb()
    leaves = b.init_fn()(jnp.int32(1))
    rng = np.random.default_rng(1)
    x = jnp.array(rng.normal(size=(8, 8, 8, 3)).astype(np.float32))
    y = jnp.zeros((8,), jnp.int32)
    out = b.train_fn()(*leaves, x, y, jnp.float32(0.0))
    n_params = len(jax.tree_util.tree_leaves(b.state_tree[0]))
    for i in range(n_params):
        np.testing.assert_array_equal(np.asarray(out[i]), np.asarray(leaves[i]))
    mom = out[n_params : 2 * n_params]
    assert any(float(jnp.abs(m).max()) > 0 for m in mom)


# --------------------------------------------------------------- optimizer


def test_momentum_accumulates():
    p = {"w": jnp.ones((4, 4))}
    m = optim.momentum_init(p)
    g = {"w": jnp.full((4, 4), 0.5)}
    p1, m1 = optim.sgd_update(p, m, g, 0.1, FP32, momentum=0.9, weight_decay=0.0)
    p2, m2 = optim.sgd_update(p1, m1, g, 0.1, FP32, momentum=0.9, weight_decay=0.0)
    # v1 = 0.5; v2 = 0.9*0.5 + 0.5 = 0.95
    np.testing.assert_allclose(np.asarray(m1["w"]), 0.5)
    np.testing.assert_allclose(np.asarray(m2["w"]), 0.95, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(p2["w"]), 1.0 - 0.05 - 0.095, rtol=1e-5)


def test_weight_decay_only_on_dot_weights():
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,)), "gamma": jnp.ones((2,))}
    m = optim.momentum_init(p)
    g = jax.tree_util.tree_map(jnp.zeros_like, p)
    p1, _ = optim.sgd_update(p, m, g, 1.0, FP32, momentum=0.0, weight_decay=0.1)
    assert float(p1["w"][0, 0]) < 1.0  # decayed
    assert float(p1["b"][0]) == 1.0  # untouched
    assert float(p1["gamma"][0]) == 1.0


def test_wide_storage_quantizes_weights_after_update():
    cfg = HBFP  # storage = 16
    p = {"w": jnp.array(np.random.default_rng(2).normal(size=(30, 30)).astype(np.float32))}
    m = optim.momentum_init(p)
    g = jax.tree_util.tree_map(jnp.zeros_like, p)
    p1, _ = optim.sgd_update(p, m, g, 0.0, cfg, momentum=0.9, weight_decay=0.0)
    from compile.kernels import ref

    want = ref.bfp_quantize_tiled(p["w"], 16, 24)
    np.testing.assert_array_equal(np.asarray(p1["w"]), np.asarray(want))


# ------------------------------------------------------------------ eval


def test_eval_counts_scale_with_batch():
    b = sb()
    leaves = b.init_fn()(jnp.int32(0))
    x = jnp.zeros((8, 8, 8, 3), jnp.float32)
    y = jnp.zeros((8,), jnp.int32)
    loss_sum, correct = b.eval_fn()(*leaves, x, y)
    assert 0.0 <= float(correct) <= 8.0
    # untrained: per-example loss near ln(4)
    assert abs(float(loss_sum) / 8 - np.log(4)) < 1.0


def test_lstm_eval_normalizes_by_seq():
    b = StepBuilder(MODELS["lstm"], FP32, batch=4, vocab=8, seq=6)
    leaves = b.init_fn()(jnp.int32(0))
    x = jnp.zeros((4, 6), jnp.int32)
    y = jnp.zeros((4, 6), jnp.int32)
    loss_sum, correct = b.eval_fn()(*leaves, x, y)
    # per-sequence mean-over-T: loss_sum ~ 4 * ln(8)
    assert abs(float(loss_sum) / 4 - np.log(8)) < 1.0
    assert 0.0 <= float(correct) <= 4.0


@pytest.mark.parametrize("cfgname", ["fp32", "hbfp8_16_t24"])
def test_full_loop_loss_decreases(cfgname):
    b = sb(cfg=parse_config(cfgname))
    train = jax.jit(b.train_fn())
    leaves = jax.jit(b.init_fn())(jnp.int32(0))
    rng = np.random.default_rng(3)
    x = jnp.array(rng.normal(size=(8, 8, 8, 3)).astype(np.float32))
    y = jnp.array((np.arange(8) % 4).astype(np.int32))
    first = None
    for _ in range(30):
        out = train(*leaves, x, y, jnp.float32(0.1))
        leaves, loss = out[:-2], float(out[-2])
        first = first if first is not None else loss
    assert loss < first * 0.5, f"{first} -> {loss}"
