"""AOT compiler: lowering produces parseable HLO text with the right
parameter arity; the manifest (if built) is consistent with COMBOS."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile.models import MODELS
from compile.numerics import parse_config

ART_DIR = os.path.join(os.path.dirname(__file__), "../../artifacts")


def test_combo_list_is_wellformed():
    assert len(aot.COMBOS) >= 40
    seen = set()
    for model, dataset, cfg in aot.COMBOS:
        assert model in MODELS, model
        assert dataset in aot.DATASETS, dataset
        parse_config(cfg)  # must parse
        key = aot.combo_name(model, dataset, cfg)
        assert key not in seen, f"duplicate combo {key}"
        seen.add(key)
        # kind compatibility
        assert MODELS[model].kind == aot.DATASETS[dataset]["kind"]


def test_to_hlo_text_basic():
    def fn(x, y):
        return [x @ y, jnp.sum(x)]

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "parameter(0)" in text and "parameter(1)" in text
    # tuple root with two leaves
    assert "tuple(" in text or "ROOT" in text


def test_dtype_str_mapping():
    assert aot._dtype_str(jnp.float32) == "f32"
    assert aot._dtype_str(jnp.int32) == "i32"
    with pytest.raises(KeyError):
        aot._dtype_str(jnp.float64)


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.json")), reason="run `make artifacts` first")
def test_manifest_consistent_with_combos():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        manifest = json.load(f)
    arts = manifest["artifacts"]
    for model, dataset, cfg in aot.COMBOS:
        name = aot.combo_name(model, dataset, cfg)
        for role in ("init", "train", "eval"):
            key = f"{name}__{role}"
            assert key in arts, f"missing {key}"
            entry = arts[key]
            assert os.path.exists(os.path.join(ART_DIR, entry["file"])), entry["file"]
            # train I/O contract: state' + [loss, acc]; inputs state + x,y,lr
            if role == "train":
                assert len(entry["outputs"]) == entry["state_len"] + 2
                assert len(entry["inputs"]) == entry["state_len"] + 3
                assert entry["outputs"][-2]["name"] == "loss"
            if role == "eval":
                assert [o["name"] for o in entry["outputs"]] == ["loss_sum", "correct_sum"]


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.json")), reason="run `make artifacts` first")
def test_state_names_stable_across_roles():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        arts = json.load(f)["artifacts"]
    name = aot.combo_name(*aot.COMBOS[0])
    tr = arts[f"{name}__train"]
    ev = arts[f"{name}__eval"]
    n = tr["state_len"]
    assert [i["name"] for i in tr["inputs"][:n]] == [i["name"] for i in ev["inputs"][:n]]
