"""L2 layers: conv-as-im2col correctness vs lax.conv, BN semantics, LSTM
shape/grad sanity — with both fp32 and hbfp qmatmuls."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from compile import layers as L
from compile.numerics import make_qmatmul, parse_config

FP32 = parse_config("fp32")
HBFP = parse_config("hbfp8_16_t24")


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.array((rng.normal(size=shape) * scale).astype(np.float32))


# ----------------------------------------------------------------- conv


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("kh,kw", [(3, 3), (1, 1)])
def test_conv_im2col_matches_lax_conv(stride, kh, kw):
    qmm = make_qmatmul(FP32)
    x = rand((2, 8, 8, 3), 0)
    p = {"w": rand((kh, kw, 3, 5), 1)}
    got = L.conv_apply(qmm, p, x, stride=stride)
    want = lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_conv_grad_flows_through_qmatmul():
    qmm = make_qmatmul(HBFP)
    x = rand((2, 8, 8, 3), 2)
    p = {"w": rand((3, 3, 3, 4), 3)}

    def loss(p):
        return jnp.sum(L.conv_apply(qmm, p, x) ** 2)

    g = jax.grad(loss)(p)
    assert g["w"].shape == p["w"].shape
    assert float(jnp.abs(g["w"]).max()) > 0
    assert np.isfinite(np.asarray(g["w"])).all()


# ------------------------------------------------------------------- bn


def test_bn_train_normalizes_batch():
    p, s = L.bn_init(4)
    x = rand((16, 6, 6, 4), 4, scale=3.0) + 2.0
    y, s2 = L.bn_apply(p, s, x, train=True)
    got_mean = np.asarray(jnp.mean(y, axis=(0, 1, 2)))
    got_var = np.asarray(jnp.var(y, axis=(0, 1, 2)))
    np.testing.assert_allclose(got_mean, 0.0, atol=1e-4)
    np.testing.assert_allclose(got_var, 1.0, atol=1e-2)
    # running stats moved toward batch stats
    assert float(jnp.abs(s2["mean"]).max()) > 0


def test_bn_eval_uses_running_stats():
    p, s = L.bn_init(2)
    s = {"mean": jnp.array([1.0, -1.0]), "var": jnp.array([4.0, 0.25])}
    x = jnp.ones((3, 2, 2, 2), jnp.float32)
    y, s2 = L.bn_apply(p, s, x, train=False)
    assert s2 is s  # eval must not touch state
    want0 = (1.0 - 1.0) / np.sqrt(4.0 + 1e-5)
    want1 = (1.0 + 1.0) / np.sqrt(0.25 + 1e-5)
    np.testing.assert_allclose(np.asarray(y[0, 0, 0]), [want0, want1], rtol=1e-4)


# ----------------------------------------------------------------- lstm


def test_lstm_shapes_and_state_evolution():
    qmm = make_qmatmul(FP32)
    p = L.lstm_init(jax.random.PRNGKey(0), 6, 10)
    x = rand((4, 7, 6), 5)
    y = L.lstm_apply(qmm, p, x, FP32)
    assert y.shape == (4, 7, 10)
    # outputs at different timesteps must differ (state actually carried)
    assert float(jnp.abs(y[:, 0] - y[:, -1]).max()) > 1e-4


def test_lstm_grad_through_scan_and_qmatmul():
    qmm = make_qmatmul(HBFP)
    p = L.lstm_init(jax.random.PRNGKey(1), 4, 8)
    x = rand((2, 5, 4), 6)

    def loss(p):
        return jnp.sum(L.lstm_apply(qmm, p, x, HBFP) ** 2)

    g = jax.grad(loss)(p)
    for k in ("wx", "wh", "b"):
        assert np.isfinite(np.asarray(g[k])).all(), k
        assert float(jnp.abs(g[k]).max()) > 0, k


def test_lstm_forget_bias_initialized_to_one():
    p = L.lstm_init(jax.random.PRNGKey(2), 3, 5)
    b = np.asarray(p["b"])
    assert (b[5:10] == 1.0).all()  # forget gate block
    assert (b[:5] == 0.0).all()


# -------------------------------------------------------------- pooling


def test_global_avg_pool():
    x = rand((2, 4, 4, 3), 7)
    got = np.asarray(L.global_avg_pool(x))
    want = np.asarray(jnp.mean(x, axis=(1, 2)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_avg_pool2_halves_spatial():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
    y = L.avg_pool2(x)
    assert y.shape == (1, 2, 2, 1)
    np.testing.assert_allclose(np.asarray(y[0, 0, 0, 0]), (0 + 1 + 4 + 5) / 4.0)
