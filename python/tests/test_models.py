"""L2 models: init/apply shape contracts, BN-state plumbing, parameter
counts, and a single-batch overfit smoke for each model family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import MODELS
from compile.numerics import make_qmatmul, parse_config

FP32 = parse_config("fp32")


def n_params(tree):
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))


@pytest.mark.parametrize("name", ["mlp", "resnet_mini", "wrn_mini", "densenet_mini"])
def test_image_model_contract(name):
    spec = MODELS[name]
    p, s = spec.init(jax.random.PRNGKey(0), 10, 16, 3)
    qmm = make_qmatmul(FP32)
    x = jnp.ones((4, 16, 16, 3), jnp.float32)
    logits, s2 = spec.apply(qmm, FP32, p, s, x, True)
    assert logits.shape == (4, 10)
    assert jax.tree_util.tree_structure(s) == jax.tree_util.tree_structure(s2)
    assert np.isfinite(np.asarray(logits)).all()
    # eval mode must not mutate state
    _, s3 = spec.apply(qmm, FP32, p, s, x, False)
    for a, b in zip(jax.tree_util.tree_leaves(s), jax.tree_util.tree_leaves(s3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_model_sizes_ordered():
    sizes = {}
    for name in ["mlp", "resnet_mini", "wrn_mini", "densenet_mini"]:
        p, _ = MODELS[name].init(jax.random.PRNGKey(0), 10, 16, 3)
        sizes[name] = n_params(p)
    assert sizes["wrn_mini"] > sizes["resnet_mini"], sizes
    assert all(1_000 < v < 5_000_000 for v in sizes.values()), sizes


def test_lstm_contract():
    spec = MODELS["lstm"]
    p, s = spec.init(jax.random.PRNGKey(0), 32, 48)
    qmm = make_qmatmul(FP32)
    tokens = jnp.zeros((4, 48), jnp.int32)
    logits, _ = spec.apply(qmm, FP32, p, s, tokens, True)
    assert logits.shape == (4, 48, 32)


@pytest.mark.parametrize("name", ["resnet_mini", "densenet_mini"])
def test_overfit_single_batch(name):
    """Each CNN must be able to drive training loss down on one batch."""
    from compile.train import StepBuilder

    sb = StepBuilder(MODELS[name], FP32, batch=8, classes=4, hw=8, channels=3)
    init = jax.jit(sb.init_fn())
    train = jax.jit(sb.train_fn())
    state = init(jnp.int32(0))
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(8, 8, 8, 3)).astype(np.float32))
    y = jnp.array((np.arange(8) % 4).astype(np.int32))
    losses = []
    for _ in range(25):
        out = train(*state, x, y, jnp.float32(0.1))
        state, loss = out[:-2], float(out[-2])
        losses.append(loss)
    assert losses[-1] < losses[0] * 0.5, f"{name}: {losses[0]} -> {losses[-1]}"


def test_transformer_contract_and_causality():
    spec = MODELS["transformer_mini"]
    p, s = spec.init(jax.random.PRNGKey(0), 32, 48)
    qmm = make_qmatmul(FP32)
    t1 = jnp.zeros((2, 48), jnp.int32)
    l1, _ = spec.apply(qmm, FP32, p, s, t1, True)
    assert l1.shape == (2, 48, 32)
    # causality: changing token t must not affect logits before t
    t2 = t1.at[:, 30].set(5)
    l2, _ = spec.apply(qmm, FP32, p, s, t2, True)
    np.testing.assert_array_equal(np.asarray(l1[:, :30]), np.asarray(l2[:, :30]))
    assert float(jnp.abs(l1[:, 30:] - l2[:, 30:]).max()) > 0


def test_transformer_hbfp_grads_finite():
    from compile.train import StepBuilder
    from compile.numerics import parse_config

    sb = StepBuilder(MODELS["transformer_mini"], parse_config("hbfp8_16_t24"), batch=4, vocab=16, seq=12)
    leaves = sb.init_fn()(jnp.int32(0))
    x = jnp.zeros((4, 12), jnp.int32)
    y = jnp.ones((4, 12), jnp.int32)
    out = sb.train_fn()(*leaves, x, y, jnp.float32(0.1))
    assert np.isfinite(float(out[-2]))
    for leaf in out[:-2]:
        assert np.isfinite(np.asarray(leaf)).all()
