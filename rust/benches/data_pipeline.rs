//! Bench: data pipeline — dataset generation and batch assembly rates.
//! Batch assembly must comfortably outrun the training step (~100ms) or
//! the prefetcher becomes the bottleneck.

mod common;

use common::{bench, header, BenchOpts};
use hbfp::data::{ImageDataset, ImageGenConfig, TextDataset};
use hbfp::util::rng::SplitMix64;

fn main() {
    let opts = BenchOpts::from_env();

    header("dataset generation (once per run; amortized)");
    bench(&opts, "ImageDataset 4096+1024 x 16x16x3", 5120.0, || {
        std::hint::black_box(ImageDataset::generate(
            16,
            3,
            20,
            1,
            ImageGenConfig::default(),
        ));
    });
    bench(&opts, "TextDataset 60k+12k chars (order-2 markov)", 72_000.0, || {
        std::hint::black_box(TextDataset::generate(32, 48, 1, 60_000, 12_000));
    });

    header("batch assembly (per training step)");
    let img = ImageDataset::generate(16, 3, 20, 1, ImageGenConfig::default());
    let mut rng = SplitMix64::new(2);
    bench(&opts, "image train_batch(32) + flip aug", 32.0, || {
        std::hint::black_box(img.train_batch(32, &mut rng));
    });
    let txt = TextDataset::generate(32, 48, 1, 60_000, 12_000);
    bench(&opts, "text train_batch(32) windows", 32.0, || {
        std::hint::black_box(txt.train_batch(32, &mut rng));
    });
    bench(&opts, "image val_batches(32) full epoch", 1024.0, || {
        std::hint::black_box(img.val_batches(32));
    });
}
