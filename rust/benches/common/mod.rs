//! Shared mini bench harness (criterion is not in the vendored crate set):
//! warmup + timed reps, median/p10/p90 reporting, ops/sec helpers, and an
//! opt-in JSON sink (`--json` / `HBFP_BENCH_JSON=1`) that records
//! elements-per-second per kernel at the repo root so PRs leave a perf
//! trajectory (`BENCH_<name>.json`).

use std::time::Instant;

pub struct BenchOpts {
    pub reps: usize,
    pub warmup: usize,
}

impl BenchOpts {
    pub fn from_env() -> BenchOpts {
        // `cargo bench -- --quick` (or HBFP_BENCH_QUICK=1) for smoke runs
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("HBFP_BENCH_QUICK").is_ok();
        if quick {
            BenchOpts { reps: 3, warmup: 1 }
        } else {
            BenchOpts { reps: 15, warmup: 3 }
        }
    }
}

pub struct BenchResult {
    pub name: String,
    pub median_secs: f64,
    pub p10_secs: f64,
    pub p90_secs: f64,
}

/// Run `f` under the harness and print one table row. Returns the median.
pub fn bench<F: FnMut()>(opts: &BenchOpts, name: &str, work_items: f64, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup {
        f();
    }
    let mut samples = Vec::with_capacity(opts.reps);
    for _ in 0..opts.reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p).round() as usize];
    let median = q(0.5);
    let throughput = if work_items > 0.0 {
        format!("{:>14}", human_rate(work_items / median))
    } else {
        " ".repeat(14)
    };
    println!(
        "{name:<44} {:>10} {:>10} {:>10} {throughput}",
        human_time(q(0.5)),
        human_time(q(0.1)),
        human_time(q(0.9)),
    );
    BenchResult { name: name.to_string(), median_secs: median, p10_secs: q(0.1), p90_secs: q(0.9) }
}

pub fn header(title: &str) {
    println!("\n== {title} ==");
    println!("{:<44} {:>10} {:>10} {:>10} {:>14}", "benchmark", "median", "p10", "p90", "rate");
}

pub fn human_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Opt-in JSON result sink. Construct once per bench binary, `push` every
/// result worth tracking, `finish` at the end: with `--json` on the
/// command line (or `HBFP_BENCH_JSON` set) it writes
/// `BENCH_<bench>.json` at the repo root; otherwise it is a no-op.
pub struct JsonSink {
    bench: String,
    rows: Vec<(String, f64, f64, f64)>, // (name, median_secs, p10_secs, rate/s)
    enabled: bool,
}

impl JsonSink {
    pub fn new(bench: &str) -> JsonSink {
        let enabled =
            std::env::args().any(|a| a == "--json") || std::env::var("HBFP_BENCH_JSON").is_ok();
        JsonSink { bench: bench.to_string(), rows: Vec::new(), enabled }
    }

    /// Record one result; `work_items / median` becomes the tracked rate.
    pub fn push(&mut self, r: &BenchResult, work_items: f64) {
        let rate = if r.median_secs > 0.0 { work_items / r.median_secs } else { 0.0 };
        self.rows.push((r.name.clone(), r.median_secs, r.p10_secs, rate));
    }

    pub fn finish(&self) {
        if !self.enabled {
            return;
        }
        use hbfp::util::json::Json;
        let rows = self
            .rows
            .iter()
            .map(|(name, med, p10, rate)| {
                Json::obj(vec![
                    ("name", Json::str(name.clone())),
                    ("median_secs", Json::num(*med)),
                    ("p10_secs", Json::num(*p10)),
                    ("rate_per_sec", Json::num(*rate)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("bench", Json::str(self.bench.clone())),
            ("results", Json::Arr(rows)),
        ]);
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join(format!("BENCH_{}.json", self.bench));
        match std::fs::write(&path, doc.to_string()) {
            Ok(()) => println!("\nwrote {}", path.display()),
            Err(e) => eprintln!("failed to write {}: {e}", path.display()),
        }
    }
}

pub fn human_rate(r: f64) -> String {
    if r > 1e9 {
        format!("{:.2}G/s", r / 1e9)
    } else if r > 1e6 {
        format!("{:.2}M/s", r / 1e6)
    } else if r > 1e3 {
        format!("{:.2}K/s", r / 1e3)
    } else {
        format!("{r:.1}/s")
    }
}
