//! Bench: software BFP library hot paths — quantization (the FP→BFP
//! converter) and the packed integer-MAC matmul vs the FP32 baseline.
//! These are the §Perf targets for the rust BFP substrate (see PERF.md).
//!
//! The matmul section prints the full before/after ladder on the same
//! operands: `naive` (j-innermost, the original kernel), `row-major 1T`
//! (cache-blocked, single thread — the pre-packing seed kernel shape),
//! `row-major packed-parallel` (width-packed storage + row-band
//! threading), `packed-panel` warm/cold (the k-tile-major B relayout,
//! cached vs repacked per call — the default path, running the active
//! SIMD kernel family), `packed-panel warm, simd off` (the same panel
//! path forced onto the scalar kernels — the SIMD margin), and `fused`
//! (convert+matmul in one pass). A dispatch section compares the
//! persistent pool against per-call scoped spawns at 128^3, and a skinny
//! m=8 section measures the resident-weight case (small activation batch
//! against big cached weights) where panel reuse pays every step, with
//! its own simd-off partner rung. The active family prints in the
//! header (`HBFP_SIMD` to override). Run with `--json` to write
//! `BENCH_bfp_ops.json` at the repo root.

mod common;

use common::{bench, header, BenchOpts, JsonSink};
use hbfp::bfp::{
    bfp_matmul_naive, bfp_matmul_rowmajor_with_threads, bfp_matmul_with_backend,
    bfp_matmul_with_simd, bfp_matmul_with_threads, fp32_matmul, kernels, quantize_matmul,
    BfpTensor, Isa, Rounding, TileSize,
};
use hbfp::util::pool::ParBackend;
use hbfp::util::rng::{SplitMix64, Xorshift32};
use hbfp::util::worker_threads;

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

fn main() {
    let opts = BenchOpts::from_env();
    let mut sink = JsonSink::new("bfp_ops");
    let nt = worker_threads();
    let isa = kernels::active();
    println!(
        "SIMD kernel family: {} (panel width {}; HBFP_SIMD=off|sse|avx2|neon|auto to override)",
        isa.name(),
        isa.panel_nr()
    );

    header(&format!("BFP quantization (FP->BFP converter), {nt} threads"));
    for &(n, m, tile) in &[
        (256 * 256usize, 8u32, 24usize),
        (256 * 256, 12, 24),
        (256 * 256, 8, 64),
        (1024 * 1024, 8, 24),
    ] {
        let rows = (n as f64).sqrt() as usize;
        let data = randv(rows * rows, 1);
        let r = bench(
            &opts,
            &format!("quantize {rows}x{rows} m={m} t={tile}"),
            (rows * rows) as f64,
            || {
                let t = BfpTensor::from_f32(
                    &data,
                    rows,
                    rows,
                    m,
                    TileSize::Edge(tile),
                    &mut Rounding::NearestEven,
                )
                .unwrap();
                std::hint::black_box(&t);
            },
        );
        sink.push(&r, (rows * rows) as f64);
    }
    // single-thread reference for the parallel-speedup row
    {
        let data = randv(1024 * 1024, 1);
        let r = bench(&opts, "quantize 1024x1024 m=8 t=24 (1 thread)", (1024 * 1024) as f64, || {
            let t = BfpTensor::from_f32_with_threads(
                &data,
                1024,
                1024,
                8,
                TileSize::Edge(24),
                &mut Rounding::NearestEven,
                1,
            )
            .unwrap();
            std::hint::black_box(&t);
        });
        sink.push(&r, (1024 * 1024) as f64);
    }

    header("BFP quantization, stochastic rounding (hardware converter)");
    let data = randv(256 * 256, 2);
    let mut rng = Xorshift32::new(7);
    let r = bench(&opts, "quantize 256x256 m=8 t=24 stochastic", (256 * 256) as f64, || {
        let t = BfpTensor::from_f32(
            &data,
            256,
            256,
            8,
            TileSize::Edge(24),
            &mut Rounding::Stochastic(&mut rng),
        )
        .unwrap();
        std::hint::black_box(&t);
    });
    sink.push(&r, (256 * 256) as f64);

    header(&format!("matmul 256x256x256: packed int MAC ladder, {nt} threads"));
    let (m, k, n) = (256usize, 256usize, 256usize);
    let a = randv(m * k, 3);
    let b = randv(k * n, 4);
    let flops = (2 * m * k * n) as f64;
    let r = bench(&opts, "fp32_matmul", flops, || {
        std::hint::black_box(fp32_matmul(&a, &b, m, k, n));
    });
    sink.push(&r, flops);
    for &(bits, tile) in &[(8u32, 24usize), (8, 64), (12, 24), (16, 24)] {
        let qa =
            BfpTensor::from_f32(&a, m, k, bits, TileSize::Edge(tile), &mut Rounding::NearestEven)
                .unwrap();
        let qb =
            BfpTensor::from_f32(&b, k, n, bits, TileSize::Edge(tile), &mut Rounding::NearestEven)
                .unwrap();
        if bits == 8 && tile == 24 {
            // §Perf before/after ladder at the paper's hbfp8 config
            let r = bench(&opts, "bfp_matmul m=8 t=24 (naive, before)", flops, || {
                std::hint::black_box(bfp_matmul_naive(&qa, &qb).unwrap());
            });
            sink.push(&r, flops);
            let r = bench(&opts, "bfp_matmul m=8 t=24 (row-major, 1 thread)", flops, || {
                std::hint::black_box(bfp_matmul_rowmajor_with_threads(&qa, &qb, 1).unwrap());
            });
            sink.push(&r, flops);
            let r =
                bench(&opts, "bfp_matmul m=8 t=24 (row-major packed-parallel)", flops, || {
                    std::hint::black_box(bfp_matmul_rowmajor_with_threads(&qa, &qb, nt).unwrap());
                });
            sink.push(&r, flops);
        }
        qb.packed_panels(); // warm the panel cache outside the timed region
        let r = bench(
            &opts,
            &format!("bfp_matmul m={bits} t={tile} (packed-panel, warm)"),
            flops,
            || {
                std::hint::black_box(bfp_matmul_with_threads(&qa, &qb, nt).unwrap());
            },
        );
        sink.push(&r, flops);
        if bits == 8 && tile == 24 {
            // scalar-kernel partner of the warm rung: same panel path,
            // panels re-packed at the scalar width (8) — the margin over
            // this row is the SIMD win at 256^3
            let r = bench(&opts, "bfp_matmul m=8 t=24 (packed-panel warm, simd off)", flops, || {
                std::hint::black_box(bfp_matmul_with_simd(&qa, &qb, nt, Isa::Scalar).unwrap());
            });
            sink.push(&r, flops);
            qb.packed_panels(); // restore the active family's panels
            let r = bench(&opts, "bfp_matmul m=8 t=24 (packed-panel, cold-pack)", flops, || {
                qb.clear_panel_cache();
                std::hint::black_box(bfp_matmul_with_threads(&qa, &qb, nt).unwrap());
            });
            sink.push(&r, flops);
            qb.packed_panels();
            let r = bench(&opts, "quantize_matmul m=8 t=24 (fused A-convert)", flops, || {
                std::hint::black_box(
                    quantize_matmul(&a, m, 8, &mut Rounding::NearestEven, &qb).unwrap(),
                );
            });
            sink.push(&r, flops);
        }
    }

    header(&format!("matmul dispatch: pooled vs per-call scoped spawns, {nt} threads"));
    {
        let (m, k, n) = (128usize, 128usize, 128usize);
        let a = randv(m * k, 6);
        let b = randv(k * n, 7);
        let flops = (2 * m * k * n) as f64;
        let qa =
            BfpTensor::from_f32(&a, m, k, 8, TileSize::Edge(24), &mut Rounding::NearestEven)
                .unwrap();
        let qb =
            BfpTensor::from_f32(&b, k, n, 8, TileSize::Edge(24), &mut Rounding::NearestEven)
                .unwrap();
        qb.packed_panels(); // both rungs warm: isolate dispatch cost
        let r = bench(&opts, "bfp_matmul 128^3 m=8 t=24 (scoped-spawn)", flops, || {
            std::hint::black_box(
                bfp_matmul_with_backend(&qa, &qb, nt, ParBackend::Scoped).unwrap(),
            );
        });
        sink.push(&r, flops);
        let r = bench(&opts, "bfp_matmul 128^3 m=8 t=24 (pooled)", flops, || {
            std::hint::black_box(
                bfp_matmul_with_backend(&qa, &qb, nt, ParBackend::Pooled).unwrap(),
            );
        });
        sink.push(&r, flops);
    }

    header("resident weights: skinny activation GEMM (8x256x256), panel reuse per step");
    {
        let (m, k, n) = (8usize, 256usize, 256usize);
        let a = randv(m * k, 8);
        let b = randv(k * n, 9);
        let flops = (2 * m * k * n) as f64;
        let qa =
            BfpTensor::from_f32(&a, m, k, 8, TileSize::Edge(24), &mut Rounding::NearestEven)
                .unwrap();
        let qb =
            BfpTensor::from_f32(&b, k, n, 8, TileSize::Edge(24), &mut Rounding::NearestEven)
                .unwrap();
        let r = bench(&opts, "bfp_matmul 8x256x256 (row-major)", flops, || {
            std::hint::black_box(bfp_matmul_rowmajor_with_threads(&qa, &qb, nt).unwrap());
        });
        sink.push(&r, flops);
        qb.packed_panels();
        let r = bench(&opts, "bfp_matmul 8x256x256 (packed-panel, warm)", flops, || {
            std::hint::black_box(bfp_matmul_with_threads(&qa, &qb, nt).unwrap());
        });
        sink.push(&r, flops);
        // scalar-kernel partner at the resident-weight shape
        let r = bench(&opts, "bfp_matmul 8x256x256 (packed-panel warm, simd off)", flops, || {
            std::hint::black_box(bfp_matmul_with_simd(&qa, &qb, nt, Isa::Scalar).unwrap());
        });
        sink.push(&r, flops);
        qb.packed_panels(); // restore the active family's panels
        let r = bench(&opts, "bfp_matmul 8x256x256 (packed-panel, cold-pack)", flops, || {
            qb.clear_panel_cache();
            std::hint::black_box(bfp_matmul_with_threads(&qa, &qb, nt).unwrap());
        });
        sink.push(&r, flops);
    }

    header("wide weight storage: narrow_view (16 -> 8 bits, repacking)");
    let w = BfpTensor::from_f32(
        &randv(512 * 512, 5),
        512,
        512,
        16,
        TileSize::Edge(24),
        &mut Rounding::NearestEven,
    )
    .unwrap();
    let r = bench(&opts, "narrow_view 512x512 16->8", (512 * 512) as f64, || {
        std::hint::black_box(w.narrow_view(8, &mut Rounding::NearestEven).unwrap());
    });
    sink.push(&r, (512 * 512) as f64);

    sink.finish();
}
