//! Bench: software BFP library hot paths — quantization (the FP→BFP
//! converter) and the packed integer-MAC matmul vs the FP32 baseline.
//! These are the §Perf targets for the rust BFP substrate (see PERF.md).
//!
//! Everything runs through the context/plan API: one [`BfpContext`] per
//! policy variant (row-major layout, forced-scalar ISA, scoped-spawn
//! backend, single-thread) replaces the retired `_with_*` function zoo.
//! The matmul section prints the full before/after ladder on the same
//! operands: `naive` (j-innermost, the original kernel), `row-major 1T`
//! (cache-blocked, single thread), `row-major packed-parallel`,
//! `packed-panel` warm/cold (the k-tile-major B relayout, cached vs
//! repacked per call — the default path, running the active SIMD kernel
//! family), `packed-panel warm, simd off` (the same panel path forced
//! onto the scalar kernels — the SIMD margin), and `fused` (convert +
//! matmul in one pass). A dispatch section compares the persistent pool
//! against per-call scoped spawns at 128^3, and a skinny m=8 section
//! measures the resident-weight case where panel reuse pays every step —
//! including the new **plan-reuse** rungs: one prebuilt `MatmulPlan` +
//! caller buffer (`execute_into`, the training-step shape) paired
//! against the warm rung, which is the per-call `ctx.matmul` path
//! (policy re-resolved and output allocated every call).
//! The active family prints in the header (`HBFP_SIMD` to override).
//! A final section times one whole native training step (MLP fwd+bwd,
//! all six GEMMs through cached plans, plus the optimizer update) at m8
//! and fp32 — the end-to-end hybrid-split cost `examples/train_cifar.rs`
//! pays per step. Run with `--json` to write `BENCH_bfp_ops.json` at the
//! repo root.

mod common;

use common::{bench, header, BenchOpts, JsonSink};
use hbfp::bfp::{
    bfp_matmul_naive, fp32_matmul, BfpContext, Isa, MatmulKernel, Rounding, TileSize,
};
use hbfp::nn::{Mlp, Model, NnContext, Optimizer, Precision};
use hbfp::runtime::HostTensor;
use hbfp::util::pool::ParBackend;
use hbfp::util::rng::{SplitMix64, Xorshift32};

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

fn main() {
    let opts = BenchOpts::from_env();
    let mut sink = JsonSink::new("bfp_ops");
    let ctx = BfpContext::from_env(); // HBFP_THREADS / HBFP_SIMD resolved once
    let nt = ctx.threads();
    let isa = ctx.isa();
    println!(
        "SIMD kernel family: {} (panel width {}; HBFP_SIMD=off|sse|avx2|neon|auto to override)",
        isa.name(),
        isa.panel_nr()
    );

    header(&format!("BFP quantization (FP->BFP converter), {nt} threads"));
    for &(n, m, tile) in &[
        (256 * 256usize, 8u32, 24usize),
        (256 * 256, 12, 24),
        (256 * 256, 8, 64),
        (1024 * 1024, 8, 24),
    ] {
        let rows = (n as f64).sqrt() as usize;
        let data = randv(rows * rows, 1);
        let qctx = ctx.clone().with_tile(TileSize::Edge(tile));
        let r = bench(
            &opts,
            &format!("quantize {rows}x{rows} m={m} t={tile}"),
            (rows * rows) as f64,
            || {
                let t = qctx.quantize(&data, rows, rows, m, &mut Rounding::NearestEven).unwrap();
                std::hint::black_box(&t);
            },
        );
        sink.push(&r, (rows * rows) as f64);
    }
    // single-thread reference for the parallel-speedup row
    {
        let data = randv(1024 * 1024, 1);
        let ctx1 = ctx.clone().with_threads(1).with_tile(TileSize::Edge(24));
        let r = bench(&opts, "quantize 1024x1024 m=8 t=24 (1 thread)", (1024 * 1024) as f64, || {
            let t = ctx1.quantize(&data, 1024, 1024, 8, &mut Rounding::NearestEven).unwrap();
            std::hint::black_box(&t);
        });
        sink.push(&r, (1024 * 1024) as f64);
    }

    header("BFP quantization, stochastic rounding (hardware converter)");
    let data = randv(256 * 256, 2);
    let mut rng = Xorshift32::new(7);
    let sctx = ctx.clone().with_tile(TileSize::Edge(24));
    let r = bench(&opts, "quantize 256x256 m=8 t=24 stochastic", (256 * 256) as f64, || {
        let t = sctx.quantize(&data, 256, 256, 8, &mut Rounding::Stochastic(&mut rng)).unwrap();
        std::hint::black_box(&t);
    });
    sink.push(&r, (256 * 256) as f64);

    header(&format!("matmul 256x256x256: packed int MAC ladder, {nt} threads"));
    let (m, k, n) = (256usize, 256usize, 256usize);
    let a = randv(m * k, 3);
    let b = randv(k * n, 4);
    let flops = (2 * m * k * n) as f64;
    let r = bench(&opts, "fp32_matmul", flops, || {
        std::hint::black_box(fp32_matmul(&a, &b, m, k, n));
    });
    sink.push(&r, flops);
    for &(bits, tile) in &[(8u32, 24usize), (8, 64), (12, 24), (16, 24)] {
        let tctx = ctx.clone().with_tile(TileSize::Edge(tile));
        let qa = tctx.quantize(&a, m, k, bits, &mut Rounding::NearestEven).unwrap();
        let qb = tctx.quantize(&b, k, n, bits, &mut Rounding::NearestEven).unwrap();
        if bits == 8 && tile == 24 {
            // §Perf before/after ladder at the paper's hbfp8 config
            let r = bench(&opts, "bfp_matmul m=8 t=24 (naive, before)", flops, || {
                std::hint::black_box(bfp_matmul_naive(&qa, &qb).unwrap());
            });
            sink.push(&r, flops);
            let rm1 = tctx.clone().with_kernel(MatmulKernel::RowMajor).with_threads(1);
            let r = bench(&opts, "bfp_matmul m=8 t=24 (row-major, 1 thread)", flops, || {
                std::hint::black_box(rm1.matmul(&qa, &qb).unwrap());
            });
            sink.push(&r, flops);
            let rm = tctx.clone().with_kernel(MatmulKernel::RowMajor);
            let r =
                bench(&opts, "bfp_matmul m=8 t=24 (row-major packed-parallel)", flops, || {
                    std::hint::black_box(rm.matmul(&qa, &qb).unwrap());
                });
            sink.push(&r, flops);
        }
        qb.packed_panels(); // warm the panel cache outside the timed region
        let r = bench(
            &opts,
            &format!("bfp_matmul m={bits} t={tile} (packed-panel, warm)"),
            flops,
            || {
                std::hint::black_box(tctx.matmul(&qa, &qb).unwrap());
            },
        );
        sink.push(&r, flops);
        if bits == 8 && tile == 24 {
            // scalar-kernel partner of the warm rung: same panel path,
            // panels re-packed at the scalar width (8) — the margin over
            // this row is the SIMD win at 256^3
            let scalar = tctx.clone().with_isa(Isa::Scalar);
            let r = bench(&opts, "bfp_matmul m=8 t=24 (packed-panel warm, simd off)", flops, || {
                std::hint::black_box(scalar.matmul(&qa, &qb).unwrap());
            });
            sink.push(&r, flops);
            qb.packed_panels(); // restore the active family's panels
            let r = bench(&opts, "bfp_matmul m=8 t=24 (packed-panel, cold-pack)", flops, || {
                qb.clear_panel_cache();
                std::hint::black_box(tctx.matmul(&qa, &qb).unwrap());
            });
            sink.push(&r, flops);
            qb.packed_panels();
            let r = bench(&opts, "quantize_matmul m=8 t=24 (fused A-convert)", flops, || {
                std::hint::black_box(
                    tctx.quantize_matmul(&a, m, 8, &mut Rounding::NearestEven, &qb).unwrap(),
                );
            });
            sink.push(&r, flops);
        }
    }

    header(&format!("matmul dispatch: pooled vs per-call scoped spawns, {nt} threads"));
    {
        let (m, k, n) = (128usize, 128usize, 128usize);
        let a = randv(m * k, 6);
        let b = randv(k * n, 7);
        let flops = (2 * m * k * n) as f64;
        let tctx = ctx.clone().with_tile(TileSize::Edge(24));
        let qa = tctx.quantize(&a, m, k, 8, &mut Rounding::NearestEven).unwrap();
        let qb = tctx.quantize(&b, k, n, 8, &mut Rounding::NearestEven).unwrap();
        qb.packed_panels(); // both rungs warm: isolate dispatch cost
        let scoped = tctx.clone().with_backend(ParBackend::Scoped);
        let r = bench(&opts, "bfp_matmul 128^3 m=8 t=24 (scoped-spawn)", flops, || {
            std::hint::black_box(scoped.matmul(&qa, &qb).unwrap());
        });
        sink.push(&r, flops);
        let r = bench(&opts, "bfp_matmul 128^3 m=8 t=24 (pooled)", flops, || {
            std::hint::black_box(tctx.matmul(&qa, &qb).unwrap());
        });
        sink.push(&r, flops);
    }

    header("resident weights: skinny activation GEMM (8x256x256), panel + plan reuse per step");
    {
        let (m, k, n) = (8usize, 256usize, 256usize);
        let a = randv(m * k, 8);
        let b = randv(k * n, 9);
        let flops = (2 * m * k * n) as f64;
        let tctx = ctx.clone().with_tile(TileSize::Edge(24));
        let qa = tctx.quantize(&a, m, k, 8, &mut Rounding::NearestEven).unwrap();
        let qb = tctx.quantize(&b, k, n, 8, &mut Rounding::NearestEven).unwrap();
        let rm = tctx.clone().with_kernel(MatmulKernel::RowMajor);
        let r = bench(&opts, "bfp_matmul 8x256x256 (row-major)", flops, || {
            std::hint::black_box(rm.matmul(&qa, &qb).unwrap());
        });
        sink.push(&r, flops);
        qb.packed_panels();
        let r = bench(&opts, "bfp_matmul 8x256x256 (packed-panel, warm)", flops, || {
            std::hint::black_box(tctx.matmul(&qa, &qb).unwrap());
        });
        sink.push(&r, flops);
        // scalar-kernel partner at the resident-weight shape
        let scalar = tctx.clone().with_isa(Isa::Scalar);
        let r = bench(&opts, "bfp_matmul 8x256x256 (packed-panel warm, simd off)", flops, || {
            std::hint::black_box(scalar.matmul(&qa, &qb).unwrap());
        });
        sink.push(&r, flops);
        qb.packed_panels(); // restore the active family's panels
        let r = bench(&opts, "bfp_matmul 8x256x256 (packed-panel, cold-pack)", flops, || {
            qb.clear_panel_cache();
            std::hint::black_box(tctx.matmul(&qa, &qb).unwrap());
        });
        sink.push(&r, flops);
        qb.packed_panels();

        // The plan API's win, isolated: a prebuilt plan + caller buffer
        // (the per-layer training-step shape) vs the warm rung above,
        // which re-resolves policy and allocates output on every
        // ctx.matmul call. Same kernel, same bits.
        let plan = tctx.plan_matmul(m, k, n, (8, 8)).unwrap();
        let mut out = vec![0.0f32; plan.out_len()];
        let r = bench(&opts, "bfp_matmul 8x256x256 (plan-reuse, execute_into)", flops, || {
            plan.execute_into(&qa, &qb, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        sink.push(&r, flops);
        let r = bench(
            &opts,
            "quantize_matmul 8x256x256 (plan-reuse fused, into)",
            flops,
            || {
                plan.quantize_execute_into(&a, &mut Rounding::NearestEven, &qb, &mut out).unwrap();
                std::hint::black_box(&out);
            },
        );
        sink.push(&r, flops);
    }

    header(&format!("serving path: fair-share scheduler overhead, 1 vs 4 tenants, {nt} threads"));
    {
        use hbfp::serve::{InferenceServer, ManualClock, ServeConfig, Submission};
        use hbfp::util::fault::{self, FaultInjector};
        use std::sync::Arc;

        // Quiet injector + zero synthetic ticks: both rungs execute the
        // same 4x 8-row GEMMs per iteration, so the margin between them
        // is pure scheduler bookkeeping (per-tenant queues + DRR visits
        // vs the single-tenant head-of-line fast path).
        let _quiet = fault::install(FaultInjector::none());
        let (k, n) = (256usize, 256usize);
        let wts = randv(k * n, 10);
        let act = randv(k, 11);
        let rows_total = 32usize;
        let flops = (2 * rows_total * k * n) as f64;
        let mk_cfg = || ServeConfig {
            queue_capacity: 64,
            elevated_depth: 64,
            degrade_depth: 64,
            shed_depth: 64,
            max_batch_rows: 8,
            drr_quantum_rows: 8,
            est_ticks_per_row: 0,
            synthetic_ticks_per_row: 0,
            ..ServeConfig::default()
        };

        let sctx = ctx.clone().with_tile(TileSize::Edge(24));
        let mut srv1 = InferenceServer::new(mk_cfg(), sctx.clone(), Arc::new(ManualClock::new()));
        let t0 = srv1.register_model("bench-0", &wts, k, n).unwrap();
        let r = bench(&opts, "serve 32 rows 1-tenant (DRR floor, 4x 8-row GEMMs)", flops, || {
            for _ in 0..rows_total {
                let sub = srv1.submit(t0, act.clone(), None).unwrap();
                assert!(matches!(sub, Submission::Admitted { .. }));
            }
            srv1.run_until_idle().unwrap();
            std::hint::black_box(srv1.drain_completions());
        });
        sink.push(&r, flops);

        let mut srv4 = InferenceServer::new(mk_cfg(), sctx, Arc::new(ManualClock::new()));
        let tenants: Vec<usize> = (0..4)
            .map(|i| srv4.register_model(&format!("bench-{i}"), &wts, k, n).unwrap())
            .collect();
        let r = bench(
            &opts,
            "serve 32 rows 4-tenant (DRR interleave, 4x 8-row GEMMs)",
            flops,
            || {
                for t in &tenants {
                    for _ in 0..rows_total / 4 {
                        let sub = srv4.submit(*t, act.clone(), None).unwrap();
                        assert!(matches!(sub, Submission::Admitted { .. }));
                    }
                }
                srv4.run_until_idle().unwrap();
                std::hint::black_box(srv4.drain_completions());
            },
        );
        sink.push(&r, flops);
    }

    header("wide weight storage: narrow_view (16 -> 8 bits, repacking)");
    let wctx = ctx.clone().with_tile(TileSize::Edge(24));
    let w = wctx.quantize(&randv(512 * 512, 5), 512, 512, 16, &mut Rounding::NearestEven).unwrap();
    let r = bench(&opts, "narrow_view 512x512 16->8", (512 * 512) as f64, || {
        std::hint::black_box(w.narrow_view(8, &mut Rounding::NearestEven).unwrap());
    });
    sink.push(&r, (512 * 512) as f64);

    // Whole-training-step throughput on the native nn path: one MLP
    // fwd+bwd (six GEMMs: fwd/dW/dx per Linear) + optimizer update, the
    // shape `examples/train_cifar.rs` runs per step. m8 vs fp32 is the
    // end-to-end cost of the hybrid split (per-step weight
    // re-quantization included; plans are warm after the first call).
    header(&format!("nn training step: MLP fwd+bwd 32x432x[64]x10, {nt} threads"));
    let (batch, in_dim, hidden, classes) = (32usize, 432usize, 64usize, 10usize);
    let step_flops =
        3.0 * 2.0 * (batch * in_dim * hidden + batch * hidden * classes) as f64;
    let xdata = randv(batch * in_dim, 9);
    let labels: Vec<i32> = (0..batch).map(|i| (i % classes) as i32).collect();
    for (name, precision) in
        [("m8", Precision::Hbfp { bits: 8 }), ("fp32", Precision::Fp32)]
    {
        let mut nc = NnContext::new(ctx.clone().with_tile(TileSize::Edge(24)), precision);
        let mut mlp = Mlp::new(in_dim, &[hidden], classes, 77);
        let opt = Optimizer::Momentum { mu: 0.9 };
        let x = HostTensor::F32(xdata.clone(), vec![batch, in_dim]);
        let y = HostTensor::I32(labels.clone(), vec![batch]);
        let r = bench(
            &opts,
            &format!("mlp step fwd+bwd 32x432x64 ({name})"),
            step_flops,
            || {
                let (loss, _) = mlp.train_batch(&mut nc, &x, &y).unwrap();
                for p in mlp.params_mut() {
                    opt.update(p, 1e-4);
                }
                std::hint::black_box(loss);
            },
        );
        sink.push(&r, step_flops);
    }

    sink.finish();
}
