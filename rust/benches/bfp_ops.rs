//! Bench: software BFP library hot paths — quantization (the FP→BFP
//! converter) and the integer-MAC matmul vs the FP32 baseline. These are
//! the §Perf targets for the rust BFP substrate (EXPERIMENTS.md §Perf L3).

mod common;

use common::{bench, header, BenchOpts};
use hbfp::bfp::{bfp_matmul, fp32_matmul, BfpTensor, Rounding, TileSize};
use hbfp::util::rng::{SplitMix64, Xorshift32};

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

fn main() {
    let opts = BenchOpts::from_env();

    header("BFP quantization (FP->BFP converter)");
    for &(n, m, tile) in &[
        (256 * 256usize, 8u32, 24usize),
        (256 * 256, 12, 24),
        (256 * 256, 8, 64),
        (1024 * 1024, 8, 24),
    ] {
        let rows = (n as f64).sqrt() as usize;
        let data = randv(rows * rows, 1);
        bench(
            &opts,
            &format!("quantize {rows}x{rows} m={m} t={tile}"),
            (rows * rows) as f64,
            || {
                let t = BfpTensor::from_f32(
                    &data,
                    rows,
                    rows,
                    m,
                    TileSize::Edge(tile),
                    &mut Rounding::NearestEven,
                )
                .unwrap();
                std::hint::black_box(&t);
            },
        );
    }

    header("BFP quantization, stochastic rounding (hardware converter)");
    let data = randv(256 * 256, 2);
    let mut rng = Xorshift32::new(7);
    bench(&opts, "quantize 256x256 m=8 t=24 stochastic", (256 * 256) as f64, || {
        let t = BfpTensor::from_f32(
            &data,
            256,
            256,
            8,
            TileSize::Edge(24),
            &mut Rounding::Stochastic(&mut rng),
        )
        .unwrap();
        std::hint::black_box(&t);
    });

    header("matmul: integer-MAC BFP vs FP32 baseline (256x256x256)");
    let (m, k, n) = (256usize, 256usize, 256usize);
    let a = randv(m * k, 3);
    let b = randv(k * n, 4);
    let flops = (2 * m * k * n) as f64;
    bench(&opts, "fp32_matmul", flops, || {
        std::hint::black_box(fp32_matmul(&a, &b, m, k, n));
    });
    for &(bits, tile) in &[(8u32, 24usize), (8, 64), (12, 24), (16, 24)] {
        let qa =
            BfpTensor::from_f32(&a, m, k, bits, TileSize::Edge(tile), &mut Rounding::NearestEven)
                .unwrap();
        let qb =
            BfpTensor::from_f32(&b, k, n, bits, TileSize::Edge(tile), &mut Rounding::NearestEven)
                .unwrap();
        bench(&opts, &format!("bfp_matmul m={bits} t={tile} (blocked int MAC)"), flops, || {
            std::hint::black_box(bfp_matmul(&qa, &qb).unwrap());
        });
        if bits == 8 {
            // §Perf before/after: the pre-optimization j-innermost kernel
            bench(&opts, &format!("bfp_matmul m={bits} t={tile} (naive, before)"), flops, || {
                std::hint::black_box(hbfp::bfp::bfp_matmul_naive(&qa, &qb).unwrap());
            });
        }
    }

    header("wide weight storage: narrow_view (16 -> 8 bits)");
    let w = BfpTensor::from_f32(
        &randv(512 * 512, 5),
        512,
        512,
        16,
        TileSize::Edge(24),
        &mut Rounding::NearestEven,
    )
    .unwrap();
    bench(&opts, "narrow_view 512x512 16->8", (512 * 512) as f64, || {
        std::hint::black_box(w.narrow_view(8, &mut Rounding::NearestEven).unwrap());
    });
}
