//! Bench + table: the §6 hardware evaluation. Prints the area/throughput
//! table for every MAC format (the 8.5x BFP8-vs-FP16 row, the <10% / <1%
//! area fractions) and times the cycle-level simulator itself.

mod common;

use common::{bench, header, BenchOpts};
use hbfp::accel::{size_design, throughput_ratio, AccelConfig, Accelerator, MacFormat};
use hbfp::util::rng::SplitMix64;

fn main() {
    let opts = BenchOpts::from_env();

    // The paper table (regenerated, not timed).
    hbfp::coordinator::repro::throughput();
    let ratio = throughput_ratio(MacFormat::Bfp { mantissa_bits: 8 }, MacFormat::Fp { m: 11, e: 5 });
    assert!(ratio > 5.0, "throughput ratio collapsed: {ratio}");

    header("accelerator model micro-benchmarks");
    bench(&opts, "size_design (all 5 formats)", 5.0, || {
        for f in [
            MacFormat::Bfp { mantissa_bits: 8 },
            MacFormat::Bfp { mantissa_bits: 12 },
            MacFormat::Bfp { mantissa_bits: 16 },
            MacFormat::Fp { m: 11, e: 5 },
            MacFormat::Fp32,
        ] {
            std::hint::black_box(size_design(&AccelConfig::stratix_v_like(f)));
        }
    });

    let mut rng = SplitMix64::new(0);
    let (m, k, n) = (128usize, 256usize, 128usize);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
    let mut acc =
        Accelerator::new(AccelConfig::stratix_v_like(MacFormat::Bfp { mantissa_bits: 8 }));
    bench(
        &opts,
        &format!("cycle-sim gemm {m}x{k}x{n} (bfp8)"),
        (2 * m * k * n) as f64,
        || {
            std::hint::black_box(acc.gemm(&a, &b, m, k, n, 8).unwrap());
        },
    );
}
