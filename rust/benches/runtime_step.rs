//! Bench: the L3 hot path — train-step latency per numeric config through
//! the full PJRT runtime (compile once, then timed steps), plus the
//! literal<->host state round-trip overhead the tuple-root workaround
//! costs (see runtime/engine.rs module docs).
//!
//! Requires `make artifacts`; skips cleanly otherwise.

mod common;

use std::path::Path;
use std::sync::Arc;

use common::{bench, header, BenchOpts};
use hbfp::runtime::{Engine, HostTensor, Manifest, Role};

fn main() {
    let opts = BenchOpts::from_env();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = match Manifest::load(&dir) {
        Ok(m) => Arc::new(m),
        Err(e) => {
            eprintln!("SKIP runtime_step bench: {e:#} — run `make artifacts`");
            return;
        }
    };
    let engine = Engine::new().unwrap();

    header("train-step latency by numeric config (batch 32)");
    for combo in [
        "mlp-cifar10like-fp32",
        "mlp-cifar10like-hbfpp8_16_t24",
        "resnet_mini-cifar100like-fp32",
        "resnet_mini-cifar100like-hbfp8_16_t24",
        "lstm-ptblike-fp32",
        "lstm-ptblike-hbfp8_16_t24",
    ] {
        let (Ok(train_art), Ok(init_art)) =
            (manifest.artifact(combo, Role::Train), manifest.artifact(combo, Role::Init))
        else {
            eprintln!("  (skipping {combo}: not in manifest)");
            continue;
        };
        let train = engine.load(train_art).unwrap();
        let init = engine.load(init_art).unwrap();
        let mut state = init.run_host(&[HostTensor::scalar_i32(0)]).unwrap();
        let xspec = &train_art.inputs[train_art.state_len];
        let yspec = &train_art.inputs[train_art.state_len + 1];
        let xe: usize = xspec.shape.iter().product();
        let ye: usize = yspec.shape.iter().product();
        let x = match xspec.dtype {
            hbfp::runtime::DType::F32 => HostTensor::F32(vec![0.3; xe], xspec.shape.clone()),
            _ => HostTensor::I32(vec![1; xe], xspec.shape.clone()),
        };
        let y = HostTensor::I32(vec![1; ye], yspec.shape.clone());
        let xb = x.to_literal().unwrap();
        let yb = y.to_literal().unwrap();
        let lrb = HostTensor::scalar_f32(0.01).to_literal().unwrap();
        bench(&opts, combo, 32.0, || {
            let mut args: Vec<&xla::Literal> = state.iter().collect();
            args.push(&xb);
            args.push(&yb);
            args.push(&lrb);
            let mut out = train.run(&args).unwrap();
            out.pop();
            out.pop();
            state = out;
        });
    }

    header("state round-trip overhead (tuple-root workaround)");
    let art = manifest.artifact("resnet_mini-cifar100like-fp32", Role::Init).unwrap();
    let init = engine.load(art).unwrap();
    let state = init.run_host(&[HostTensor::scalar_i32(0)]).unwrap();
    let total_elems: usize = art.outputs.iter().map(|s| s.elems()).sum();
    bench(&opts, "fetch full state to host (f32)", total_elems as f64, || {
        for lit in &state {
            std::hint::black_box(lit.to_vec::<f32>().unwrap());
        }
    });
}
