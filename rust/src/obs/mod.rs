//! Unified observability layer: a shared metrics [`Registry`], lightweight
//! tracing [`trace`] spans, and HBFP numeric-health [`health`] probes —
//! one subsystem behind one sampling knob.
//!
//! The repo grew four disconnected counter surfaces
//! ([`GuardStats`](crate::bfp::GuardStats), the
//! [`PlanCache`](crate::bfp::PlanCache) hit/miss counters,
//! [`ServeMetrics`](crate::coordinator::metrics::ServeMetrics), the
//! [`DatasetCache`](crate::data::DatasetCache) hit/generated pair) and no
//! timing visibility inside a training step or a serve pump. This module
//! gives them one export path (`Registry::to_json`) and adds the numeric
//! telemetry the paper's central claim is debugged with: per-layer
//! block-exponent spreads, mantissa clamp/saturation rates, and
//! quantization SNR over training time (see PERF.md § Observability).
//!
//! ## The sampling knob
//!
//! `HBFP_OBS=off|counters|full` (default `off`), read once at first probe:
//!
//! - **off** — every probe site is a single relaxed atomic load and
//!   nothing else. This is the hard overhead contract on hot paths.
//! - **counters** — cheap monotonic counters only (quantize/GEMM call
//!   counts, pool dispatch counts). No clocks, no per-tensor analysis.
//! - **full** — everything: tracing spans, per-lane pool busy/idle
//!   timing, per-layer numeric-health probes with quantization SNR.
//!
//! **No mode perturbs results.** Probes only *read* tensors that the
//! datapath already produced (nearest-even weight quantizations), never
//! consume RNG draws, and never reorder parallel work — loss curves and
//! serve outputs are bit-identical across all three modes (enforced by
//! `tests/obs.rs` and the `obs-smoke` CI job).

pub mod health;
pub mod registry;
pub mod trace;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, MutexGuard};

pub use health::{ObsRecorder, TensorHealth};
pub use registry::Registry;
pub use trace::{span, SpanGuard};

/// Observability sampling mode (see module docs for what each enables).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ObsMode {
    Off,
    Counters,
    Full,
}

impl ObsMode {
    /// The spelling used in `HBFP_OBS`.
    pub fn name(self) -> &'static str {
        match self {
            ObsMode::Off => "off",
            ObsMode::Counters => "counters",
            ObsMode::Full => "full",
        }
    }

    fn parse(s: &str) -> Option<ObsMode> {
        match s.trim() {
            "off" => Some(ObsMode::Off),
            "counters" => Some(ObsMode::Counters),
            "full" => Some(ObsMode::Full),
            _ => None,
        }
    }
}

/// Encoded mode: 0/1/2 = off/counters/full, `MODE_UNINIT` = not yet read
/// from the environment. A sentinel (instead of a `OnceLock`) keeps the
/// armed-check on hot paths at exactly one relaxed load.
const MODE_UNINIT: u8 = 0xff;

static MODE: AtomicU8 = AtomicU8::new(MODE_UNINIT);

#[cold]
fn init_mode_from_env() -> ObsMode {
    let mode = match std::env::var("HBFP_OBS") {
        Ok(s) if !s.trim().is_empty() => match ObsMode::parse(&s) {
            Some(m) => m,
            None => {
                log::warn!("ignoring HBFP_OBS={s:?} (want off|counters|full)");
                ObsMode::Off
            }
        },
        _ => ObsMode::Off,
    };
    // A racing install() may have stored a real mode between our load and
    // here; never clobber it with the env default.
    let _ = MODE.compare_exchange(
        MODE_UNINIT,
        mode as u8,
        Ordering::Relaxed,
        Ordering::Relaxed,
    );
    decode(MODE.load(Ordering::Relaxed))
}

fn decode(v: u8) -> ObsMode {
    match v {
        1 => ObsMode::Counters,
        2 => ObsMode::Full,
        _ => ObsMode::Off,
    }
}

/// The active sampling mode. One relaxed atomic load after first use —
/// this IS the probe-site fast path, so callers gate all observability
/// work (clocks, locks, allocation) behind it.
#[inline]
pub fn mode() -> ObsMode {
    match MODE.load(Ordering::Relaxed) {
        0 => ObsMode::Off,
        1 => ObsMode::Counters,
        2 => ObsMode::Full,
        _ => init_mode_from_env(),
    }
}

/// Counters-or-better: the gate for cheap monotonic counter probes.
#[inline]
pub fn counting() -> bool {
    mode() != ObsMode::Off
}

/// Full mode: the gate for spans, timing, and numeric-health probes.
#[inline]
pub fn full() -> bool {
    mode() == ObsMode::Full
}

/// Force the mode from code (binaries like `examples/obs_demo.rs` that
/// want full telemetry without requiring the env var). Does not take the
/// install lock — tests use [`install`] instead.
pub fn set_mode(m: ObsMode) {
    MODE.store(m as u8, Ordering::Relaxed);
}

/// Serializes tests that override the mode (the knob is process-global).
static INSTALL_LOCK: Mutex<()> = Mutex::new(());

/// RAII guard from [`install`]: restores the env-derived mode (and holds
/// the install lock) until dropped.
pub struct ObsGuard {
    _lock: MutexGuard<'static, ()>,
}

impl Drop for ObsGuard {
    fn drop(&mut self) {
        MODE.store(MODE_UNINIT, Ordering::Relaxed);
        init_mode_from_env();
    }
}

/// Install a mode for the lifetime of the returned guard (test entry
/// point). Tests that override the mode serialize on an internal lock so
/// concurrently-running tests never see each other's settings.
pub fn install(m: ObsMode) -> ObsGuard {
    let lock = INSTALL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_mode(m);
    ObsGuard { _lock: lock }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parsing() {
        assert_eq!(ObsMode::parse("off"), Some(ObsMode::Off));
        assert_eq!(ObsMode::parse(" counters "), Some(ObsMode::Counters));
        assert_eq!(ObsMode::parse("full"), Some(ObsMode::Full));
        assert_eq!(ObsMode::parse("verbose"), None);
        assert_eq!(ObsMode::Full.name(), "full");
    }

    #[test]
    fn install_guard_swaps_and_restores() {
        let before = mode();
        {
            let _g = install(ObsMode::Full);
            assert_eq!(mode(), ObsMode::Full);
            assert!(full() && counting());
        }
        assert_eq!(mode(), before, "guard drop restores the env-derived mode");
    }
}
