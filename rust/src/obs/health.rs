//! HBFP numeric-health probes: what the quantizer actually did to a
//! tensor, aggregated per named layer over training time.
//!
//! The paper's central claim — HBFP-m8 tracks FP32 accuracy because dot
//! products see wide-enough dynamic range — is debugged with exactly
//! three signals, all computed here from tensors the datapath already
//! produced (never by re-quantizing or drawing randomness):
//!
//! - **block-exponent spread** (`exp_min`/`exp_max`/`exp_span`): how much
//!   dynamic range the shared exponents are absorbing;
//! - **clamp-rail and saturated-tile fractions**: how often the mantissa
//!   grid or the exponent range ran out of headroom;
//! - **quantization SNR** vs the f32 source: the end-to-end fidelity of
//!   the BFP representation for this tensor.
//!
//! [`ObsRecorder`] (owned by [`crate::nn::NnContext`]) collects one
//! [`TensorHealth`] per named layer per step into a bounded,
//! stride-decimated timeline, plus per-step stage timings
//! (quantize/fwd/bwd/opt), and exports both as the `"obs"` section of the
//! trainer's results JSON.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::bfp::{clamp_rail_frac, saturated_tile_frac, BfpTensor};
use crate::util::json::Json;

/// Timeline length cap per layer (and for the stage-timing rows). When a
/// timeline fills, every other sample is dropped and the sampling stride
/// doubles, so long runs keep full temporal coverage at bounded memory.
pub const TIMELINE_CAP: usize = 512;

/// SNR ceiling for JSON export: an exact quantization has infinite SNR,
/// which `Json` would render as `null`; 200 dB is far above anything a
/// real mantissa width produces.
pub const SNR_CAP_DB: f64 = 200.0;

/// Numeric health of one quantized tensor vs its f32 source.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TensorHealth {
    /// Smallest / largest shared block exponent in the tensor.
    pub exp_min: i32,
    pub exp_max: i32,
    /// `exp_max - exp_min`: the dynamic range the block exponents span.
    pub exp_span: i32,
    /// Fraction of mantissas at the two's-complement clamp rails.
    pub clamp_frac: f64,
    /// Fraction of tiles whose exponent sits at the `E_MAX` rail.
    pub sat_frac: f64,
    /// `10·log10(Σx² / Σ(x−x̂)²)`, capped at [`SNR_CAP_DB`].
    pub snr_db: f64,
}

impl TensorHealth {
    fn to_json(&self, step: usize) -> Json {
        Json::obj(vec![
            ("step", Json::num(step as f64)),
            ("exp_min", Json::num(self.exp_min as f64)),
            ("exp_max", Json::num(self.exp_max as f64)),
            ("exp_span", Json::num(self.exp_span as f64)),
            ("clamp_frac", Json::num(self.clamp_frac)),
            ("sat_frac", Json::num(self.sat_frac)),
            ("snr_db", Json::num(self.snr_db)),
        ])
    }
}

/// Measure an already-quantized tensor against its f32 source. Pure
/// read-only analysis: consumes no RNG, mutates nothing, and is only
/// invoked when the obs mode is `full`.
pub fn tensor_health(src: &[f32], q: &BfpTensor) -> TensorHealth {
    let (mut exp_min, mut exp_max) = (i32::MAX, i32::MIN);
    for &e in &q.exponents {
        exp_min = exp_min.min(e);
        exp_max = exp_max.max(e);
    }
    if q.exponents.is_empty() {
        exp_min = 0;
        exp_max = 0;
    }
    let deq = q.to_f32();
    let mut sig = 0.0f64;
    let mut noise = 0.0f64;
    for (&x, &y) in src.iter().zip(&deq) {
        sig += (x as f64) * (x as f64);
        let e = (x - y) as f64;
        noise += e * e;
    }
    let snr_db = if sig == 0.0 {
        0.0
    } else if noise > 0.0 {
        (10.0 * (sig / noise).log10()).min(SNR_CAP_DB)
    } else {
        SNR_CAP_DB
    };
    TensorHealth {
        exp_min,
        exp_max,
        exp_span: exp_max - exp_min,
        clamp_frac: clamp_rail_frac(q),
        sat_frac: saturated_tile_frac(q),
        snr_db,
    }
}

#[derive(Debug, Default)]
struct LayerTimeline {
    samples: Vec<(usize, TensorHealth)>,
    /// Only steps divisible by the stride are recorded (doubles on
    /// decimation).
    stride: usize,
}

/// Per-context collector for layer health timelines and per-step stage
/// timings. All mutating entry points are self-gating on the obs mode, so
/// callers on the training path don't need their own branches; in `off`
/// mode each call is one relaxed atomic load.
#[derive(Debug)]
pub struct ObsRecorder {
    step: usize,
    layers: BTreeMap<String, LayerTimeline>,
    /// Stage → accumulated µs for the *current* step.
    cur: BTreeMap<&'static str, u64>,
    /// Flushed per-step stage rows, stride-decimated like timelines.
    steps: Vec<(usize, BTreeMap<&'static str, u64>)>,
    step_stride: usize,
    /// Stage → total µs across the whole run.
    totals: BTreeMap<&'static str, u64>,
}

impl Default for ObsRecorder {
    fn default() -> ObsRecorder {
        ObsRecorder {
            step: 0,
            layers: BTreeMap::new(),
            cur: BTreeMap::new(),
            steps: Vec::new(),
            step_stride: 1,
            totals: BTreeMap::new(),
        }
    }
}

impl ObsRecorder {
    pub fn new() -> ObsRecorder {
        ObsRecorder::default()
    }

    /// True when nothing has been recorded (the `off`/`counters` case):
    /// the trainer omits the `"obs"` JSON key entirely, keeping off-mode
    /// output byte-identical to pre-observability builds.
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty() && self.cur.is_empty() && self.steps.is_empty()
    }

    /// Mark the start of a training step: flushes the previous step's
    /// stage timings into the timeline.
    pub fn begin_step(&mut self, step: usize) {
        if !crate::obs::full() {
            return;
        }
        self.flush_cur();
        self.step = step;
    }

    fn flush_cur(&mut self) {
        if self.cur.is_empty() {
            return;
        }
        let row = std::mem::take(&mut self.cur);
        if self.step % self.step_stride != 0 {
            return;
        }
        self.steps.push((self.step, row));
        if self.steps.len() >= TIMELINE_CAP {
            let mut keep = false;
            self.steps.retain(|_| {
                keep = !keep;
                keep
            });
            self.step_stride *= 2;
        }
    }

    /// Record one layer's tensor health at the current step. The first
    /// probe per (layer, step) wins — a backward pass re-quantizing the
    /// same weights doesn't duplicate the sample.
    pub fn record_layer(&mut self, layer: &str, health: TensorHealth) {
        if !crate::obs::full() {
            return;
        }
        let step = self.step;
        let tl = self.layers.entry(layer.to_string()).or_insert(LayerTimeline {
            samples: Vec::new(),
            stride: 1,
        });
        if step % tl.stride != 0 {
            return;
        }
        if tl.samples.last().is_some_and(|(s, _)| *s == step) {
            return;
        }
        tl.samples.push((step, health));
        if tl.samples.len() >= TIMELINE_CAP {
            let mut keep = false;
            tl.samples.retain(|_| {
                keep = !keep;
                keep
            });
            tl.stride *= 2;
        }
    }

    /// Start timing a stage. `None` (and zero further cost) below `full`.
    #[inline]
    pub fn stage_start(&self) -> Option<Instant> {
        if crate::obs::full() {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a stage opened by [`Self::stage_start`], accumulating its
    /// elapsed µs into the current step and the run totals.
    pub fn stage_end(&mut self, stage: &'static str, t0: Option<Instant>) {
        let Some(t0) = t0 else { return };
        let us = t0.elapsed().as_micros() as u64;
        *self.cur.entry(stage).or_insert(0) += us;
        *self.totals.entry(stage).or_insert(0) += us;
    }

    /// Export as the trainer's `"obs"` JSON section; `None` when nothing
    /// was recorded. Shape:
    ///
    /// ```json
    /// {"health": {"fc0.w": [{"step":0, "exp_min":-3, ..., "snr_db":41.2}, ...]},
    ///  "stage_totals_us": {"bwd":1, "fwd":2, "opt":3, "quantize":4},
    ///  "stage_us": [{"step":0, "bwd":1, ...}, ...]}
    /// ```
    ///
    /// Health timelines depend only on tensor *values* (thread-count
    /// invariant); the `stage_*` keys are wall-clock and must be stripped
    /// before any determinism comparison.
    pub fn to_json(&self) -> Option<Json> {
        if self.is_empty() {
            return None;
        }
        let mut health = BTreeMap::new();
        for (name, tl) in &self.layers {
            let rows = tl.samples.iter().map(|(s, h)| h.to_json(*s)).collect();
            health.insert(name.clone(), Json::Arr(rows));
        }
        let mut stage_rows: Vec<Json> = Vec::new();
        let emit_row = |step: usize, row: &BTreeMap<&'static str, u64>| {
            let mut obj = BTreeMap::new();
            obj.insert("step".to_string(), Json::num(step as f64));
            for (k, v) in row {
                obj.insert(k.to_string(), Json::num(*v as f64));
            }
            Json::Obj(obj)
        };
        for (step, row) in &self.steps {
            stage_rows.push(emit_row(*step, row));
        }
        if !self.cur.is_empty() {
            stage_rows.push(emit_row(self.step, &self.cur));
        }
        let mut totals = BTreeMap::new();
        for (k, v) in &self.totals {
            totals.insert(k.to_string(), Json::num(*v as f64));
        }
        Some(Json::obj(vec![
            ("health", Json::Obj(health)),
            ("stage_totals_us", Json::Obj(totals)),
            ("stage_us", Json::Arr(stage_rows)),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::{Rounding, TileSize};
    use crate::obs::{install, ObsMode};

    fn quantized(data: &[f32], rows: usize, cols: usize) -> BfpTensor {
        BfpTensor::from_f32(data, rows, cols, 8, TileSize::Edge(4), &mut Rounding::NearestEven)
            .unwrap()
    }

    #[test]
    fn health_of_simple_tensor() {
        let data: Vec<f32> = (0..16).map(|i| (i as f32 - 8.0) * 0.25).collect();
        let q = quantized(&data, 4, 4);
        let h = tensor_health(&data, &q);
        assert!(h.exp_max >= h.exp_min);
        assert_eq!(h.exp_span, h.exp_max - h.exp_min);
        assert!((0.0..=1.0).contains(&h.clamp_frac));
        assert!((0.0..=1.0).contains(&h.sat_frac));
        assert!(h.snr_db > 0.0 && h.snr_db <= SNR_CAP_DB);
    }

    #[test]
    fn health_of_zero_tensor_is_finite() {
        let data = vec![0.0f32; 16];
        let q = quantized(&data, 4, 4);
        let h = tensor_health(&data, &q);
        assert_eq!(h.snr_db, 0.0, "all-zero signal reports 0 dB, not NaN/inf");
        assert_eq!(h.clamp_frac, 0.0);
    }

    #[test]
    fn recorder_dedups_within_a_step_and_bounds_memory() {
        let _g = install(ObsMode::Full);
        let mut rec = ObsRecorder::new();
        let data: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let q = quantized(&data, 4, 4);
        let h = tensor_health(&data, &q);
        for step in 0..(2 * TIMELINE_CAP) {
            rec.begin_step(step);
            rec.record_layer("fc0", h);
            rec.record_layer("fc0", h); // backward re-probe: deduped
        }
        let j = rec.to_json().unwrap();
        let tl = j.get("health").unwrap().get("fc0").unwrap().as_arr().unwrap();
        assert!(tl.len() <= TIMELINE_CAP);
        assert!(tl.len() > TIMELINE_CAP / 4, "decimation keeps coverage");
        let steps: Vec<i64> = tl.iter().map(|r| r.get("step").unwrap().as_i64().unwrap()).collect();
        let mut sorted = steps.clone();
        sorted.dedup();
        assert_eq!(steps, sorted, "one sample per step, in order");
    }

    #[test]
    fn recorder_is_inert_below_full() {
        let _g = install(ObsMode::Counters);
        let mut rec = ObsRecorder::new();
        rec.begin_step(0);
        let data = vec![1.0f32; 16];
        let q = quantized(&data, 4, 4);
        rec.record_layer("fc0", tensor_health(&data, &q));
        assert!(rec.stage_start().is_none());
        rec.stage_end("fwd", None);
        assert!(rec.is_empty());
        assert!(rec.to_json().is_none());
    }

    #[test]
    fn stage_rows_flush_per_step() {
        let _g = install(ObsMode::Full);
        let mut rec = ObsRecorder::new();
        rec.begin_step(0);
        let t0 = rec.stage_start();
        assert!(t0.is_some());
        rec.stage_end("fwd", t0);
        rec.begin_step(1);
        rec.stage_end("opt", rec.stage_start());
        let j = rec.to_json().unwrap();
        let rows = j.get("stage_us").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows[0].get("fwd").is_some());
        assert_eq!(rows[1].get("step").unwrap().as_i64(), Some(1));
        assert!(j.get("stage_totals_us").unwrap().get("fwd").is_some());
    }
}
