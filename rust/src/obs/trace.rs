//! Lightweight tracing spans over an injectable monotonic clock.
//!
//! [`span`] returns a RAII guard; on drop it records a complete event
//! (name, start, duration, nesting depth) into a bounded per-thread ring
//! buffer. When the [`crate::obs`] mode is below `full`, `span` is one
//! relaxed atomic load and returns an inert guard — no clock read, no
//! lock, no allocation.
//!
//! Span names are static dot-paths following the subsystem.object.stage
//! convention documented in PERF.md § Observability: `nn.step`,
//! `nn.linear.fwd_gemm`, `serve.pump.gemm`, …
//!
//! The clock is monotonic microseconds since process start by default; a
//! test can swap in a manual clock ([`install_manual_clock`] +
//! [`advance_us`]) so recorded timestamps are exact and assertable.
//!
//! Exports: [`events_json`] (flat JSON for tests and the registry) and
//! [`chrome_trace_json`] / [`write_chrome_trace`] (the chrome://tracing
//! "trace event" format — open chrome://tracing or <https://ui.perfetto.dev>
//! and load the emitted `trace.json`).

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Per-thread ring capacity: old events are dropped (and counted) once a
/// thread has this many buffered.
pub const RING_CAP: usize = 4096;

/// One completed span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    pub name: &'static str,
    /// Start timestamp, microseconds on the active clock.
    pub start_us: u64,
    pub dur_us: u64,
    /// Nesting depth at entry (0 = top-level span on its thread).
    pub depth: u32,
    /// Stable per-thread id (ring registration order, not OS tid).
    pub tid: u64,
}

#[derive(Debug)]
struct Ring {
    tid: u64,
    events: VecDeque<SpanEvent>,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, ev: SpanEvent) {
        if self.events.len() == RING_CAP {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(ev);
    }
}

fn rings() -> &'static Mutex<Vec<Arc<Mutex<Ring>>>> {
    static RINGS: OnceLock<Mutex<Vec<Arc<Mutex<Ring>>>>> = OnceLock::new();
    RINGS.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static DEPTH: Cell<u32> = const { Cell::new(0) };
    static LOCAL_RING: RefCell<Option<Arc<Mutex<Ring>>>> = const { RefCell::new(None) };
}

fn local_ring() -> Arc<Mutex<Ring>> {
    LOCAL_RING.with(|slot| {
        let mut slot = slot.borrow_mut();
        if let Some(r) = slot.as_ref() {
            return Arc::clone(r);
        }
        let mut all = rings().lock().unwrap_or_else(|e| e.into_inner());
        let ring = Arc::new(Mutex::new(Ring {
            tid: all.len() as u64,
            events: VecDeque::new(),
            dropped: 0,
        }));
        all.push(Arc::clone(&ring));
        *slot = Some(Arc::clone(&ring));
        ring
    })
}

// ---------------------------------------------------------------- clock

static MANUAL_CLOCK: AtomicBool = AtomicBool::new(false);
static MANUAL_US: AtomicU64 = AtomicU64::new(0);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds on the active trace clock (monotonic since process start,
/// or the manual clock's current reading while one is installed).
pub fn now_us() -> u64 {
    if MANUAL_CLOCK.load(Ordering::Relaxed) {
        MANUAL_US.load(Ordering::Relaxed)
    } else {
        epoch().elapsed().as_micros() as u64
    }
}

/// Advance the manual clock (no-op unless one is installed).
pub fn advance_us(us: u64) {
    MANUAL_US.fetch_add(us, Ordering::Relaxed);
}

/// RAII guard from [`install_manual_clock`]; dropping restores the real
/// monotonic clock.
pub struct ManualClockGuard(());

impl Drop for ManualClockGuard {
    fn drop(&mut self) {
        MANUAL_CLOCK.store(false, Ordering::Relaxed);
    }
}

/// Swap the trace clock for a manual one starting at 0 µs. Tests drive it
/// with [`advance_us`] so span timestamps are exact. Callers serialize via
/// [`crate::obs::install`], which every mode-overriding test already holds.
pub fn install_manual_clock() -> ManualClockGuard {
    MANUAL_US.store(0, Ordering::Relaxed);
    MANUAL_CLOCK.store(true, Ordering::Relaxed);
    ManualClockGuard(())
}

// ---------------------------------------------------------------- spans

/// RAII span guard: records a [`SpanEvent`] when dropped. Inert (field
/// `armed == false`, nothing on drop) unless full mode was active at
/// creation.
#[must_use = "a span measures the scope it is bound to; dropping it immediately records a zero-length span"]
pub struct SpanGuard {
    name: &'static str,
    start_us: u64,
    depth: u32,
    armed: bool,
}

/// Open a span. One relaxed atomic load when observability is below
/// `full`; otherwise reads the clock and bumps this thread's depth.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !crate::obs::full() {
        return SpanGuard { name, start_us: 0, depth: 0, armed: false };
    }
    let depth = DEPTH.with(|d| {
        let v = d.get();
        d.set(v + 1);
        v
    });
    SpanGuard { name, start_us: now_us(), depth, armed: true }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let end = now_us();
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        let ring = local_ring();
        let mut ring = ring.lock().unwrap_or_else(|e| e.into_inner());
        let tid = ring.tid;
        ring.push(SpanEvent {
            name: self.name,
            start_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
            depth: self.depth,
            tid,
        });
    }
}

/// Open a span with a static name: `let _s = span!("nn.step");`.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::trace::span($name)
    };
}

// -------------------------------------------------------------- exports

/// Snapshot all buffered events, ordered by registration thread then
/// record order (stable and deterministic for single-threaded recording).
pub fn snapshot() -> (Vec<SpanEvent>, u64) {
    let all = rings().lock().unwrap_or_else(|e| e.into_inner());
    let mut events = Vec::new();
    let mut dropped = 0;
    for ring in all.iter() {
        let ring = ring.lock().unwrap_or_else(|e| e.into_inner());
        events.extend(ring.events.iter().copied());
        dropped += ring.dropped;
    }
    (events, dropped)
}

/// Flat JSON export: `{"dropped": n, "events": [{name, ts_us, dur_us,
/// depth, tid}, ...]}`.
pub fn events_json() -> Json {
    let (events, dropped) = snapshot();
    let rows = events
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::str(e.name)),
                ("ts_us", Json::num(e.start_us as f64)),
                ("dur_us", Json::num(e.dur_us as f64)),
                ("depth", Json::num(e.depth as f64)),
                ("tid", Json::num(e.tid as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("dropped", Json::num(dropped as f64)),
        ("events", Json::Arr(rows)),
    ])
}

/// chrome://tracing "trace event format" export: complete (`"ph":"X"`)
/// events under `{"traceEvents": [...]}`.
pub fn chrome_trace_json() -> Json {
    let (events, _) = snapshot();
    let rows = events
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::str(e.name)),
                ("ph", Json::str("X")),
                ("ts", Json::num(e.start_us as f64)),
                ("dur", Json::num(e.dur_us as f64)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(e.tid as f64)),
            ])
        })
        .collect();
    Json::obj(vec![("traceEvents", Json::Arr(rows))])
}

/// Write [`chrome_trace_json`] to `path` (open it in chrome://tracing or
/// <https://ui.perfetto.dev>).
pub fn write_chrome_trace(path: &Path) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, format!("{}\n", chrome_trace_json()))?;
    Ok(())
}

/// Discard all buffered events and drop counts (rings and their tid
/// assignments persist, so tids stay stable across clears).
pub fn clear() {
    let all = rings().lock().unwrap_or_else(|e| e.into_inner());
    for ring in all.iter() {
        let mut ring = ring.lock().unwrap_or_else(|e| e.into_inner());
        ring.events.clear();
        ring.dropped = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{install, ObsMode};

    #[test]
    fn spans_record_nesting_and_manual_time() {
        let _g = install(ObsMode::Full);
        let _c = install_manual_clock();
        clear();
        {
            let _outer = span("test.outer");
            advance_us(10);
            {
                let _inner = span("test.inner");
                advance_us(5);
            }
            advance_us(1);
        }
        let (events, dropped) = snapshot();
        assert_eq!(dropped, 0);
        let inner = events.iter().find(|e| e.name == "test.inner").unwrap();
        let outer = events.iter().find(|e| e.name == "test.outer").unwrap();
        assert_eq!(inner.start_us, 10);
        assert_eq!(inner.dur_us, 5);
        assert_eq!(inner.depth, 1);
        assert_eq!(outer.start_us, 0);
        assert_eq!(outer.dur_us, 16);
        assert_eq!(outer.depth, 0);
        // inner closes first, so it is recorded first
        let ipos = events.iter().position(|e| e.name == "test.inner").unwrap();
        let opos = events.iter().position(|e| e.name == "test.outer").unwrap();
        assert!(ipos < opos);
        clear();
    }

    #[test]
    fn off_mode_spans_are_inert() {
        let _g = install(ObsMode::Counters);
        clear();
        {
            let _s = span("test.should_not_record");
        }
        let (events, _) = snapshot();
        assert!(events.iter().all(|e| e.name != "test.should_not_record"));
    }

    #[test]
    fn chrome_export_shape() {
        let _g = install(ObsMode::Full);
        let _c = install_manual_clock();
        clear();
        {
            let _s = span("test.chrome");
            advance_us(3);
        }
        let j = chrome_trace_json();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        let ev = evs.iter().find(|e| {
            e.get("name").and_then(|n| n.as_str()) == Some("test.chrome")
        });
        let ev = ev.expect("span present in chrome export");
        assert_eq!(ev.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(ev.get("dur").unwrap().as_i64(), Some(3));
        assert!(ev.get("pid").is_some() && ev.get("tid").is_some());
        clear();
    }

    #[test]
    fn ring_bounds_and_counts_drops() {
        let _g = install(ObsMode::Full);
        let _c = install_manual_clock();
        clear();
        for _ in 0..(RING_CAP + 7) {
            let _s = span("test.flood");
        }
        let (events, dropped) = snapshot();
        let flood = events.iter().filter(|e| e.name == "test.flood").count();
        assert!(flood <= RING_CAP);
        assert!(dropped >= 7);
        clear();
    }
}
