//! Shared metrics registry: named counters, gauges, histograms, and
//! attached JSON sub-documents, exportable as one nested JSON tree.
//!
//! Names are dot-separated paths (`"plan_cache.hits"`,
//! `"pool.lane0.busy_us"`); [`Registry::to_json`] splits on `.` and emits
//! nested objects, so `guard.scans` and `guard.fp32_fallbacks` render as
//! one `"guard"` section. Keys sort lexicographically (the [`Json`]
//! object representation is a `BTreeMap`), which makes registry exports
//! byte-stable across runs — the property every determinism soak in this
//! repo asserts on.
//!
//! The pre-existing ad-hoc counter surfaces register themselves through
//! the `export_metrics` methods on
//! [`GuardStatsSnapshot`](crate::bfp::GuardStatsSnapshot),
//! [`PlanCache`](crate::bfp::PlanCache),
//! [`DatasetCache`](crate::data::DatasetCache), and
//! [`LatencyHistogram`](crate::coordinator::metrics::LatencyHistogram)
//! instead of hand-rolling their JSON blocks.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::coordinator::metrics::LatencyHistogram;
use crate::util::json::Json;

/// One named metric.
#[derive(Debug, Clone, PartialEq)]
pub enum Metric {
    /// Monotonic count (events, items).
    Counter(u64),
    /// Point-in-time value (depths, fractions, means).
    Gauge(f64),
    /// Short label (model names, mode strings).
    Text(String),
    /// Streaming log2-bucket histogram (see [`LatencyHistogram`]).
    Hist(LatencyHistogram),
    /// A pre-built JSON sub-document (arrays, externally-shaped blocks).
    Attached(Json),
}

impl Metric {
    fn to_json(&self) -> Json {
        match self {
            Metric::Counter(v) => Json::num(*v as f64),
            Metric::Gauge(v) => Json::num(*v),
            Metric::Text(s) => Json::str(s.clone()),
            Metric::Hist(h) => h.to_json(),
            Metric::Attached(j) => j.clone(),
        }
    }
}

/// Thread-safe map of named metrics. Cheap to create (subsystems build
/// one per export) and usable as a long-lived shared sink (the process
/// [`global`] registry the pool's lane timing records into).
#[derive(Debug, Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Set a counter to an absolute value (snapshot-style export).
    pub fn counter(&self, name: &str, value: u64) {
        self.insert(name, Metric::Counter(value));
    }

    /// Increment a counter by `delta` (creating it at 0 first).
    pub fn add(&self, name: &str, delta: u64) {
        let mut m = self.metrics.lock().unwrap_or_else(|e| e.into_inner());
        match m.get_mut(name) {
            Some(Metric::Counter(v)) => *v += delta,
            _ => {
                m.insert(name.to_string(), Metric::Counter(delta));
            }
        }
    }

    pub fn gauge(&self, name: &str, value: f64) {
        self.insert(name, Metric::Gauge(value));
    }

    pub fn text(&self, name: &str, value: &str) {
        self.insert(name, Metric::Text(value.to_string()));
    }

    /// Record one sample into the named histogram (created empty first).
    pub fn observe(&self, name: &str, value: u64) {
        let mut m = self.metrics.lock().unwrap_or_else(|e| e.into_inner());
        match m.get_mut(name) {
            Some(Metric::Hist(h)) => h.record(value),
            _ => {
                let mut h = LatencyHistogram::new();
                h.record(value);
                m.insert(name.to_string(), Metric::Hist(h));
            }
        }
    }

    /// Attach a pre-built JSON sub-document under `name`.
    pub fn attach(&self, name: &str, doc: Json) {
        self.insert(name, Metric::Attached(doc));
    }

    /// Register a whole histogram snapshot under `name` (exported through
    /// [`LatencyHistogram::to_json`]).
    pub fn histogram(&self, name: &str, h: LatencyHistogram) {
        self.insert(name, Metric::Hist(h));
    }

    fn insert(&self, name: &str, metric: Metric) {
        self.metrics
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(name.to_string(), metric);
    }

    /// Current value of a counter (None when absent or not a counter).
    pub fn get_counter(&self, name: &str) -> Option<u64> {
        match self.metrics.lock().unwrap_or_else(|e| e.into_inner()).get(name) {
            Some(Metric::Counter(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.metrics.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every metric (tests; demo resets between phases).
    pub fn clear(&self) {
        self.metrics.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }

    /// Export the registry as nested JSON: names split on `.`, each
    /// segment a nested object key. A name that collides with a
    /// parent path (`"a"` vs `"a.b"`) keeps the deeper entries and the
    /// scalar is emitted under the reserved `"_value"` key.
    pub fn to_json(&self) -> Json {
        let m = self.metrics.lock().unwrap_or_else(|e| e.into_inner());
        let mut root = BTreeMap::new();
        for (name, metric) in m.iter() {
            insert_path(&mut root, name.split('.'), metric.to_json());
        }
        Json::Obj(root)
    }
}

fn insert_path<'a>(
    node: &mut BTreeMap<String, Json>,
    mut path: impl Iterator<Item = &'a str>,
    value: Json,
) {
    let Some(seg) = path.next() else { return };
    let mut rest = path.peekable();
    if rest.peek().is_none() {
        match node.get_mut(seg) {
            // a subtree already lives here: keep it, nest the scalar
            Some(Json::Obj(sub)) => {
                sub.insert("_value".to_string(), value);
            }
            _ => {
                node.insert(seg.to_string(), value);
            }
        }
        return;
    }
    let entry = node
        .entry(seg.to_string())
        .or_insert_with(|| Json::Obj(BTreeMap::new()));
    if !matches!(entry, Json::Obj(_)) {
        // a scalar already lives here: demote it under "_value"
        let old = std::mem::replace(entry, Json::Obj(BTreeMap::new()));
        if let Json::Obj(sub) = entry {
            sub.insert("_value".to_string(), old);
        }
    }
    if let Json::Obj(sub) = entry {
        insert_path(sub, rest, value);
    }
}

/// The process-wide registry: the sink for probes that have no natural
/// owner object (pool lane timing, the `bfp` datapath call counters).
/// Snapshot it with [`Registry::to_json`]; tests `clear()` it first.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_and_hists_round_trip() {
        let r = Registry::new();
        r.counter("a.hits", 3);
        r.add("a.hits", 2);
        r.add("a.misses", 1);
        r.gauge("a.frac", 0.5);
        r.text("mode", "full");
        r.observe("lat", 100);
        r.observe("lat", 100);
        assert_eq!(r.get_counter("a.hits"), Some(5));
        assert_eq!(r.get_counter("a.frac"), None, "gauge is not a counter");
        let j = r.to_json();
        let a = j.get("a").unwrap();
        assert_eq!(a.get("hits").unwrap().as_i64(), Some(5));
        assert_eq!(a.get("misses").unwrap().as_i64(), Some(1));
        assert_eq!(a.get("frac").unwrap().as_f64(), Some(0.5));
        assert_eq!(j.get("mode").unwrap().as_str(), Some("full"));
        assert_eq!(j.get("lat").unwrap().get("count").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn nested_names_build_a_tree() {
        let r = Registry::new();
        r.counter("pool.lane0.busy_us", 10);
        r.counter("pool.lane0.idle_us", 20);
        r.counter("pool.lane1.busy_us", 30);
        r.counter("pool.dispatches", 2);
        let j = r.to_json();
        let pool = j.get("pool").unwrap();
        assert_eq!(pool.get("dispatches").unwrap().as_i64(), Some(2));
        assert_eq!(
            pool.get("lane0").unwrap().get("busy_us").unwrap().as_i64(),
            Some(10)
        );
        assert_eq!(
            pool.get("lane1").unwrap().get("busy_us").unwrap().as_i64(),
            Some(30)
        );
    }

    #[test]
    fn path_collisions_keep_both_values() {
        let r = Registry::new();
        r.counter("a", 1);
        r.counter("a.b", 2);
        let j = r.to_json();
        let a = j.get("a").unwrap();
        assert_eq!(a.get("_value").unwrap().as_i64(), Some(1));
        assert_eq!(a.get("b").unwrap().as_i64(), Some(2));
        // and in the opposite insertion order
        let r2 = Registry::new();
        r2.counter("x.y", 2);
        r2.counter("x", 1);
        let j2 = r2.to_json();
        assert_eq!(j2.get("x").unwrap().get("_value").unwrap().as_i64(), Some(1));
        assert_eq!(j2.get("x").unwrap().get("y").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn export_is_deterministic() {
        let mk = || {
            let r = Registry::new();
            r.counter("z.late", 1);
            r.counter("a.early", 2);
            r.gauge("m.mid", 0.25);
            r.to_json().to_string()
        };
        assert_eq!(mk(), mk(), "BTreeMap ordering makes exports byte-stable");
    }

    #[test]
    fn attach_and_clear() {
        let r = Registry::new();
        r.attach("models", Json::Arr(vec![Json::str("a")]));
        assert_eq!(r.len(), 1);
        assert_eq!(
            r.to_json().get("models").unwrap().as_arr().unwrap()[0].as_str(),
            Some("a")
        );
        r.clear();
        assert!(r.is_empty());
    }
}
