//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the checksum the
//! crash-safe checkpoint format appends over header + payload bytes.
//!
//! Implemented in-repo because the offline vendored crate set has no crc
//! crate (DESIGN.md §7). The byte-at-a-time table variant is plenty: the
//! checkpoint writer streams megabytes at worst, and integrity checking is
//! not on the training hot path.

/// 256-entry lookup table for the reflected IEEE polynomial, built at
/// compile time so the checksum has zero runtime setup cost.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ 0xEDB8_8320 } else { crc >> 1 };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// Streaming CRC-32 state. Feed bytes with [`update`](Crc32::update), read
/// the digest with [`finish`](Crc32::finish). `finish` does not consume the
/// state, so intermediate digests are fine.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut crc = self.state;
        for &b in bytes {
            crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
        }
        self.state = crc;
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // The canonical IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..1000u32).map(|i| (i * 7 + 3) as u8).collect();
        let whole = crc32(&data);
        let mut c = Crc32::new();
        for chunk in data.chunks(13) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), whole);
    }

    #[test]
    fn single_bit_flip_changes_digest() {
        let mut data = vec![0u8; 64];
        let base = crc32(&data);
        for i in 0..64 {
            data[i] ^= 1 << (i % 8);
            assert_ne!(crc32(&data), base, "flip at byte {i}");
            data[i] ^= 1 << (i % 8);
        }
    }
}
