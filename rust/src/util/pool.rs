//! Persistent worker pool: the spawn-amortized backend for the band-
//! parallel BFP kernels.
//!
//! The seed backend (`super::for_each_job`) pays a `std::thread::scope`
//! spawn + join for every quantize/matmul call — fine for one-shot
//! kernels, but a training run issues thousands of matmuls per second and
//! the OS-thread churn becomes a fixed tax on every small/medium GEMM.
//! This module keeps one process-wide set of workers alive (lazily
//! spawned on first dispatch, sized by `HBFP_THREADS` via
//! [`crate::util::worker_threads`]) and hands them contiguous job chunks
//! through a shared band queue.
//!
//! Design points:
//!
//! - **Scoped, borrow-safe API**: [`Pool::run`] blocks until every chunk
//!   has executed, so jobs may borrow caller data (`&mut` row bands of an
//!   output matrix) exactly like `for_each_job`. Internally the chunk
//!   closures are lifetime-erased before entering the queue; the
//!   completion latch restores soundness by never returning while a
//!   borrow is still live on a worker.
//! - **Work-stealing-lite**: one shared FIFO of chunk tasks. The caller
//!   enqueues, then help-drains the queue itself before waiting, so a
//!   dispatch never idles the submitting thread and concurrent callers
//!   (two trainer threads issuing matmuls) interleave without extra
//!   machinery.
//! - **Determinism**: chunking is by job order only — which worker runs a
//!   chunk never changes which jobs it contains or the per-job index the
//!   work function sees, so kernels that are bit-identical under
//!   `for_each_job` stay bit-identical under the pool, for any worker
//!   count and any interleaving.
//! - **Inline fast path**: `threads <= 1` (below the parallel floor, a
//!   1-core budget, or a single job) runs the same loop on the caller
//!   with zero queue traffic — callers route small problems through this
//!   path instead of keeping a duplicate scalar kernel body.
//!
//! Worker panics are caught and **contained**: the dispatch that
//! submitted the task fails with a [`PoolPanic`] error carrying the
//! panic message ([`Pool::try_run`]), or re-raises on the caller
//! ([`Pool::run`]) — never a process abort, and never a poisoned pool.
//! After a panicked dispatch the pool checks its worker set and respawns
//! any thread that died, so subsequent callers are unaffected. The
//! `worker-panic` / `slow-worker` sites of [`crate::util::fault`] inject
//! into queued chunks here, exercising the containment path in tests.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::util::fault::{self, FaultSite};

/// A contained worker panic: the dispatch whose task panicked fails with
/// this error. Other callers, the workers, and queued work from
/// concurrent dispatches are unaffected.
#[derive(Debug, Clone)]
pub struct PoolPanic {
    msg: String,
}

impl PoolPanic {
    /// The panic payload of the first chunk that panicked (when it was a
    /// string payload; a placeholder otherwise).
    pub fn message(&self) -> &str {
        &self.msg
    }
}

impl std::fmt::Display for PoolPanic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "worker task panicked during pool dispatch: {}", self.msg)
    }
}

impl std::error::Error for PoolPanic {}

/// Best-effort extraction of a panic payload's message.
fn payload_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Run `f` and convert any panic that crosses it into a typed
/// [`PoolPanic`]. [`Pool::try_run`] already contains *worker*-lane
/// panics; this closes the remaining gap for callers that must never
/// unwind — the inline (single-lane) dispatch path and [`Pool::run`]'s
/// re-raise both execute on the caller's thread, so a serve loop wraps
/// each GEMM in this to fail one request batch instead of the server.
pub fn catch_pool_panic<R>(f: impl FnOnce() -> R) -> Result<R, PoolPanic> {
    catch_unwind(AssertUnwindSafe(f)).map_err(|p| PoolPanic { msg: payload_msg(&*p) })
}

/// A lifetime-erased chunk of submitted work.
type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Task>>,
    work_ready: Condvar,
    /// Set by `Pool::drop`: workers finish the queue, then exit (the
    /// global pool lives for the process and never sets it).
    shutdown: AtomicBool,
}

/// Completion latch for one dispatch: counts outstanding chunks and
/// remembers the first panic message, if any chunk panicked.
struct Latch {
    state: Mutex<(usize, Option<String>)>,
    done: Condvar,
}

impl Latch {
    fn new(chunks: usize) -> Latch {
        Latch { state: Mutex::new((chunks, None)), done: Condvar::new() }
    }

    fn complete_one(&self, panicked: Option<String>) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        if st.1.is_none() {
            st.1 = panicked;
        }
        if st.0 == 0 {
            self.done.notify_all();
        }
    }

    /// Non-blocking: have all chunks completed?
    fn is_done(&self) -> bool {
        self.state.lock().unwrap().0 == 0
    }

    /// Block until every chunk completed; returns the first panic
    /// message if any chunk panicked.
    fn wait(&self) -> Option<String> {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.done.wait(st).unwrap();
        }
        st.1.take()
    }
}

/// A persistent pool of `workers` threads plus the calling thread.
/// Dropping a pool signals its workers to finish the queue and exit,
/// then joins them (the lazily-built [`global`] pool is never dropped).
pub struct Pool {
    shared: Arc<Shared>,
    workers: usize,
    /// Behind a mutex so [`heal`](Pool::heal) can replace dead handles
    /// from any dispatching thread.
    handles: Mutex<Vec<JoinHandle<()>>>,
    /// Monotonic worker-name counter (respawned workers get fresh names).
    next_id: AtomicUsize,
    /// Workers respawned after dying — observability for the containment
    /// tests (expected to stay 0: task panics are caught in the task).
    respawns: AtomicUsize,
}

fn worker_loop(shared: Arc<Shared>, lane: usize) {
    loop {
        // Per-lane busy/idle timing, full obs mode only: the gate is one
        // relaxed load, and the registry is touched once per chunk (never
        // per job), so the hot kernel loops are unaffected.
        let idle_t0 = if crate::obs::full() { Some(std::time::Instant::now()) } else { None };
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break Some(t);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.work_ready.wait(q).unwrap();
            }
        };
        if let Some(t0) = idle_t0 {
            crate::obs::registry::global()
                .add(&format!("pool.lane{lane}.idle_us"), t0.elapsed().as_micros() as u64);
        }
        match task {
            Some(t) => {
                let busy_t0 =
                    if crate::obs::full() { Some(std::time::Instant::now()) } else { None };
                t();
                if let Some(t0) = busy_t0 {
                    let reg = crate::obs::registry::global();
                    reg.add(&format!("pool.lane{lane}.busy_us"), t0.elapsed().as_micros() as u64);
                    reg.add(&format!("pool.lane{lane}.tasks"), 1);
                }
            }
            None => return,
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            // store under the queue lock: a worker is either inside its
            // locked check (will see the flag or the notification) or
            // already waiting — never between the two.
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Release);
        }
        self.shared.work_ready.notify_all();
        // No dispatch can be in flight (`run` borrows &self and blocks
        // until its chunks finish), so the queue is empty: workers wake,
        // observe shutdown, and exit promptly.
        let mut handles = self.handles.lock().unwrap_or_else(|e| e.into_inner());
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Pool {
    /// Spawn `workers` persistent threads (0 is valid: every dispatch then
    /// runs inline on the caller).
    pub fn new(workers: usize) -> Pool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work_ready: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("hbfp-pool-{i}"))
                    .spawn(move || worker_loop(s, i))
                    .expect("spawning pool worker")
            })
            .collect();
        Pool {
            shared,
            workers,
            handles: Mutex::new(handles),
            next_id: AtomicUsize::new(workers),
            respawns: AtomicUsize::new(0),
        }
    }

    /// Worker threads owned by the pool (the caller adds one more lane).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Workers respawned after dying. Stays 0 in normal operation — task
    /// panics are caught inside the task, so workers don't die — but the
    /// heal pass keeps the pool at full strength even if one somehow does.
    pub fn respawns(&self) -> usize {
        self.respawns.load(Ordering::Relaxed)
    }

    /// Rebuild the worker set: join and replace any thread that exited.
    /// Called after a panicked dispatch (belt and braces — the catch in
    /// the task normally keeps workers alive) so subsequent callers see a
    /// full-strength pool.
    fn heal(&self) {
        let mut handles = self.handles.lock().unwrap_or_else(|e| e.into_inner());
        let mut i = 0;
        while i < handles.len() {
            if handles[i].is_finished() && !self.shared.shutdown.load(Ordering::Acquire) {
                let _ = handles.remove(i).join();
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                let s = Arc::clone(&self.shared);
                if let Ok(h) = std::thread::Builder::new()
                    .name(format!("hbfp-pool-{id}"))
                    .spawn(move || worker_loop(s, id))
                {
                    handles.push(h);
                    self.respawns.fetch_add(1, Ordering::Relaxed);
                }
            } else {
                i += 1;
            }
        }
    }

    /// Run `(index, payload)` jobs across up to `max_threads` lanes
    /// (pool workers + the calling thread). Chunks are contiguous job
    /// runs, so callers handing out disjoint `&mut` slices parallelize
    /// without locking; results must not depend on which lane executes a
    /// chunk (the BFP kernels guarantee this). Blocks until every job has
    /// run; re-raises any worker panic on the caller (the panic message
    /// is preserved). Callers that want an error instead use
    /// [`try_run`](Pool::try_run).
    pub fn run<T, F>(&self, jobs: Vec<(usize, T)>, max_threads: usize, f: F)
    where
        T: Send,
        F: Fn(usize, T) + Sync,
    {
        if let Err(e) = self.try_run(jobs, max_threads, f) {
            panic!("{e}");
        }
    }

    /// [`run`](Pool::run) with contained panics: a task panic on any lane
    /// fails **this** dispatch with a [`PoolPanic`] (carrying the panic
    /// message) instead of unwinding the caller. The pool itself stays
    /// healthy — queued work from concurrent dispatches still runs, and
    /// the worker set is rebuilt if a thread died.
    ///
    /// The inline (single-lane) path executes on the caller's thread, so
    /// a panic there unwinds the caller directly as it always did — the
    /// containment contract is about *worker* lanes.
    pub fn try_run<T, F>(
        &self,
        jobs: Vec<(usize, T)>,
        max_threads: usize,
        f: F,
    ) -> Result<(), PoolPanic>
    where
        T: Send,
        F: Fn(usize, T) + Sync,
    {
        let n_jobs = jobs.len();
        if n_jobs == 0 {
            return Ok(());
        }
        let threads = max_threads.max(1).min(n_jobs).min(self.workers + 1);
        if threads == 1 {
            if crate::obs::counting() {
                crate::obs::registry::global().add("pool.inline_dispatches", 1);
            }
            // Inline fast path: the one kernel body, no queue traffic.
            for (i, job) in jobs {
                f(i, job);
            }
            return Ok(());
        }
        if crate::obs::counting() {
            let reg = crate::obs::registry::global();
            reg.add("pool.dispatches", 1);
            reg.add("pool.jobs", n_jobs as u64);
        }

        // One chunk per lane (same contiguous split as `for_each_job`):
        // at most `threads` lanes ever hold this dispatch's work, so the
        // cap bounds actual concurrency, not just the chunk count.
        let per = n_jobs.div_ceil(threads);
        let mut jobs = jobs;
        let mut chunks: Vec<Vec<(usize, T)>> = Vec::with_capacity(threads);
        while !jobs.is_empty() {
            let take = per.min(jobs.len());
            chunks.push(jobs.drain(..take).collect());
        }

        let latch = Arc::new(Latch::new(chunks.len()));
        let f_ref: &(dyn Fn(usize, T) + Sync) = &f;
        {
            let mut q = self.shared.queue.lock().unwrap();
            for chunk in chunks {
                let latch = Arc::clone(&latch);
                let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let result = catch_unwind(AssertUnwindSafe(|| {
                        // Fault-injection probes (no-ops unless HBFP_FAULT
                        // arms them; see util::fault). Inside the catch so
                        // an injected panic takes the real containment
                        // path.
                        if fault::fire(FaultSite::SlowWorker) {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        if fault::fire(FaultSite::WorkerPanic) {
                            panic!("injected worker panic (HBFP_FAULT worker-panic)");
                        }
                        for (i, job) in chunk {
                            f_ref(i, job);
                        }
                    }));
                    latch.complete_one(result.err().map(|p| payload_msg(&*p)));
                });
                // SAFETY: the erased closure borrows `f` and the job
                // payloads, which outlive this call: `run` does not
                // return until `latch.wait()` has observed every chunk
                // complete, and a chunk only completes after its closure
                // (and all its borrows) are finished. The transmute only
                // erases the lifetime bound; both types are boxed fat
                // pointers with identical layout.
                let task: Task = unsafe { std::mem::transmute(task) };
                q.push_back(task);
            }
        }
        self.shared.work_ready.notify_all();

        // Help-drain: the caller is a full lane, and may also pick up
        // chunks of concurrent dispatches while its own are in flight
        // (harmless: every chunk carries its own latch). Stop as soon as
        // this dispatch completes so a small call never burns its return
        // latency on another caller's backlog.
        while !latch.is_done() {
            let task = self.shared.queue.lock().unwrap().pop_front();
            match task {
                Some(t) => t(),
                None => break,
            }
        }
        match latch.wait() {
            None => Ok(()),
            Some(msg) => {
                // Contained failure: rebuild the worker set (normally a
                // no-op — the catch keeps workers alive) and report the
                // panic to this dispatch's caller only.
                self.heal();
                Err(PoolPanic { msg })
            }
        }
    }
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();

/// The process-wide pool, lazily spawned with `worker_threads() - 1`
/// workers (the dispatching thread is the final lane). `HBFP_THREADS` is
/// read once, at first use.
pub fn global() -> &'static Pool {
    GLOBAL.get_or_init(|| Pool::new(crate::util::worker_threads().saturating_sub(1)))
}

/// Dispatch jobs on the global pool — the drop-in replacement for
/// [`crate::util::for_each_job`] on hot paths. Single-lane dispatches
/// run inline without ever spawning the pool, so `HBFP_THREADS=1`
/// processes stay genuinely single-threaded.
pub fn dispatch_jobs<T, F>(jobs: Vec<(usize, T)>, max_threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    if max_threads <= 1 || jobs.len() <= 1 {
        for (i, job) in jobs {
            f(i, job);
        }
        return;
    }
    global().run(jobs, max_threads, f)
}

/// Lane count for a parallel section: 1 (the pool's inline path) below
/// the work floor, otherwise `max_threads` capped by the band count.
/// Centralizes the small-problem thresholds so every kernel routes
/// through the same inline/dispatch decision instead of keeping a
/// bypassing scalar copy.
pub fn par_threads(work: usize, par_floor: usize, max_threads: usize, bands: usize) -> usize {
    if work < par_floor {
        1
    } else {
        max_threads.min(bands).max(1)
    }
}

/// [`par_threads`] with the floor scaled by the active SIMD kernel
/// family's throughput class (`bfp::kernels::Isa::par_floor_scale`): a
/// wider vector unit finishes small problems faster, so the point where
/// dispatch overhead stops paying moves up proportionally. Purely a
/// speed knob — the lane count never changes results.
pub fn par_threads_simd(
    work: usize,
    par_floor: usize,
    floor_scale: usize,
    max_threads: usize,
    bands: usize,
) -> usize {
    par_threads(work, par_floor.saturating_mul(floor_scale.max(1)), max_threads, bands)
}

/// Which dispatch backend a kernel should use. The default everywhere is
/// [`ParBackend::Pooled`]; [`ParBackend::Scoped`] keeps the per-call
/// `std::thread::scope` baseline reachable for the bench ladder and the
/// pooled-vs-scoped differential tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParBackend {
    /// Per-call scoped spawn + join (the pre-pool seed backend).
    Scoped,
    /// Persistent global worker pool.
    Pooled,
}

/// Run jobs under the chosen backend. Both backends receive identical
/// `(index, payload)` chunks, so results are bit-identical across
/// backends for the kernels in this crate.
pub fn run_backend<T, F>(backend: ParBackend, jobs: Vec<(usize, T)>, max_threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    match backend {
        ParBackend::Scoped => crate::util::for_each_job(jobs, max_threads, f),
        ParBackend::Pooled => dispatch_jobs(jobs, max_threads, f),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_covers_all_disjoint_slices() {
        let pool = Pool::new(3);
        let mut data = vec![0u32; 103];
        for threads in [1, 2, 7] {
            data.fill(0);
            let jobs: Vec<(usize, &mut [u32])> = data.chunks_mut(10).enumerate().collect();
            pool.run(jobs, threads, |i, chunk| {
                for (j, x) in chunk.iter_mut().enumerate() {
                    *x = (i * 10 + j) as u32;
                }
            });
            for (i, &x) in data.iter().enumerate() {
                assert_eq!(x, i as u32, "threads={threads}");
            }
        }
    }

    #[test]
    fn empty_dispatch_is_noop() {
        let pool = Pool::new(2);
        pool.run(Vec::<(usize, ())>::new(), 4, |_, _| panic!("no jobs"));
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = Pool::new(0);
        let hits = AtomicUsize::new(0);
        let jobs: Vec<(usize, usize)> = (0..5).map(|i| (i, i * 2)).collect();
        pool.run(jobs, 8, |i, v| {
            assert_eq!(v, i * 2);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn pool_is_reusable_across_dispatches() {
        let pool = Pool::new(2);
        for round in 0..20 {
            let mut out = vec![0usize; 37];
            let jobs: Vec<(usize, &mut usize)> = out.iter_mut().enumerate().collect();
            pool.run(jobs, 3, |i, slot| *slot = i + round);
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i + round);
            }
        }
    }

    #[test]
    fn concurrent_callers_share_the_pool() {
        let pool = Pool::new(2);
        std::thread::scope(|scope| {
            for caller in 0..3 {
                let pool = &pool;
                scope.spawn(move || {
                    for _ in 0..10 {
                        let mut out = vec![0usize; 24];
                        let jobs: Vec<(usize, &mut usize)> =
                            out.iter_mut().enumerate().collect();
                        pool.run(jobs, 3, |i, slot| *slot = i * 3 + caller);
                        for (i, &v) in out.iter().enumerate() {
                            assert_eq!(v, i * 3 + caller);
                        }
                    }
                });
            }
        });
    }

    #[test]
    #[should_panic(expected = "worker task panicked")]
    fn worker_panic_propagates_to_caller() {
        let pool = Pool::new(2);
        let jobs: Vec<(usize, ())> = (0..8).map(|i| (i, ())).collect();
        pool.run(jobs, 4, |i, _| {
            if i == 3 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_a_panicked_dispatch() {
        let pool = Pool::new(2);
        let jobs: Vec<(usize, ())> = (0..8).map(|i| (i, ())).collect();
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(jobs, 4, |i, _| {
                if i == 0 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err());
        // the pool must still work after a task panicked
        let mut out = vec![0usize; 16];
        let jobs: Vec<(usize, &mut usize)> = out.iter_mut().enumerate().collect();
        pool.run(jobs, 4, |i, slot| *slot = i);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn try_run_contains_panic_as_error() {
        let pool = Pool::new(2);
        let jobs: Vec<(usize, ())> = (0..8).map(|i| (i, ())).collect();
        let err = pool
            .try_run(jobs, 4, |i, _| {
                if i == 3 {
                    panic!("kaboom at job {i}");
                }
            })
            .unwrap_err();
        assert!(err.message().contains("kaboom"), "payload preserved: {err}");
        assert!(err.to_string().contains("worker task panicked"), "{err}");
        // The very next dispatch on the same pool must succeed and be
        // bit-identical to a fresh pool's result.
        let mut out = vec![0u32; 64];
        let jobs: Vec<(usize, &mut [u32])> = out.chunks_mut(7).enumerate().collect();
        pool.try_run(jobs, 4, |i, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = (i * 100 + j) as u32;
            }
        })
        .unwrap();
        let fresh_pool = Pool::new(2);
        let mut fresh = vec![0u32; 64];
        let jobs: Vec<(usize, &mut [u32])> = fresh.chunks_mut(7).enumerate().collect();
        fresh_pool
            .try_run(jobs, 4, |i, chunk| {
                for (j, x) in chunk.iter_mut().enumerate() {
                    *x = (i * 100 + j) as u32;
                }
            })
            .unwrap();
        assert_eq!(out, fresh, "post-panic dispatch is bit-identical");
        assert_eq!(pool.respawns(), 0, "caught panics never kill workers");
    }

    #[test]
    fn catch_pool_panic_wraps_caller_side_panics() {
        assert_eq!(catch_pool_panic(|| 7).unwrap(), 7);
        let err = catch_pool_panic(|| -> u32 { panic!("inline boom") }).unwrap_err();
        assert!(err.message().contains("inline boom"), "{err}");
        // composes with `run`'s re-raise: the worker panic message that
        // unwinds the caller arrives intact in the typed error
        let pool = Pool::new(2);
        let jobs: Vec<(usize, ())> = (0..8).map(|i| (i, ())).collect();
        let err = catch_pool_panic(|| {
            pool.run(jobs, 4, |i, _| {
                if i == 3 {
                    panic!("boom");
                }
            })
        })
        .unwrap_err();
        assert!(err.message().contains("worker task panicked"), "{err}");
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        let pool = Pool::new(2);
        let mut out = vec![0usize; 8];
        let jobs: Vec<(usize, &mut usize)> = out.iter_mut().enumerate().collect();
        pool.run(jobs, 3, |i, slot| *slot = i);
        drop(pool); // must not hang: workers observe shutdown and exit
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn par_threads_threshold() {
        assert_eq!(par_threads(100, 1000, 8, 16), 1, "below floor -> inline");
        assert_eq!(par_threads(1000, 1000, 8, 16), 8, "at floor -> parallel");
        assert_eq!(par_threads(5000, 1000, 8, 3), 3, "capped by bands");
        assert_eq!(par_threads(5000, 1000, 0, 0), 1, "degenerate caps clamp to 1");
    }

    #[test]
    fn par_threads_simd_scales_the_floor() {
        // scale 1 == the plain threshold
        assert_eq!(par_threads_simd(1000, 1000, 1, 8, 16), 8);
        // a 4-wide family quadruples the inline region
        assert_eq!(par_threads_simd(1000, 1000, 4, 8, 16), 1, "below scaled floor");
        assert_eq!(par_threads_simd(4000, 1000, 4, 8, 16), 8, "at scaled floor");
        // degenerate scale clamps to 1 rather than zeroing the floor
        assert_eq!(par_threads_simd(1000, 1000, 0, 8, 16), 8);
    }

    #[test]
    fn backends_produce_identical_coverage() {
        let mut scoped = vec![0u32; 64];
        let mut pooled = vec![0u32; 64];
        for (backend, data) in
            [(ParBackend::Scoped, &mut scoped), (ParBackend::Pooled, &mut pooled)]
        {
            let jobs: Vec<(usize, &mut [u32])> = data.chunks_mut(7).enumerate().collect();
            run_backend(backend, jobs, 4, |i, chunk| {
                for (j, x) in chunk.iter_mut().enumerate() {
                    *x = (i * 100 + j) as u32;
                }
            });
        }
        assert_eq!(scoped, pooled);
    }
}
