//! Mini property-testing runner (proptest is not in the vendored crate set).
//!
//! A `Gen` wraps a seeded `SplitMix64`; properties are closures over a
//! `&mut Gen` returning `Result<(), String>`. `check` runs N seeded cases
//! and, on failure, retries the failing case with progressively "smaller"
//! size hints to report a reduced example. Deterministic: failures print
//! the seed, and `HBFP_PROP_SEED` reruns a single case.

use super::rng::SplitMix64;

pub struct Gen {
    pub rng: SplitMix64,
    /// Size hint in [0, 1]; generators scale their output magnitude by it,
    /// which is what makes the shrink pass produce smaller counterexamples.
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), size: 1.0 }
    }

    /// Integer in [lo, hi], scaled toward lo when shrinking.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        let span = ((hi - lo) as f64 * self.size).max(0.0) as usize;
        lo + if span == 0 { 0 } else { self.rng.below(span + 1) }
    }

    /// f32 in [-scale, scale], scale shrunk by the size hint.
    pub fn f32_sym(&mut self, scale: f32) -> f32 {
        let s = scale * self.size as f32;
        self.rng.range_f32(-s, s)
    }

    /// Standard-normal-ish value with a random scale spanning `decades`
    /// orders of magnitude — exercises the exponent-selection paths.
    pub fn f32_wide(&mut self, decades: i32) -> f32 {
        let d = (self.rng.next_f32() * 2.0 - 1.0) * decades as f32 * self.size as f32;
        self.rng.normal() * 10f32.powf(d)
    }

    pub fn vec_f32(&mut self, len: usize, decades: i32) -> Vec<f32> {
        (0..len).map(|_| self.f32_wide(decades)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `cases` seeded cases of `prop`. Panics with seed + message on the
/// first failure, after attempting a smaller repro via the size hint.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let forced: Option<u64> = std::env::var("HBFP_PROP_SEED").ok().and_then(|s| s.parse().ok());
    let seeds: Vec<u64> = match forced {
        Some(s) => vec![s],
        None => (0..cases).map(|i| 0x5eed_0000 + i).collect(),
    };
    for seed in seeds {
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            // Shrink pass: same seed, smaller size hints.
            let mut best = (1.0f64, msg.clone());
            for &size in &[0.5, 0.25, 0.1, 0.05] {
                let mut g2 = Gen::new(seed);
                g2.size = size;
                if let Err(m2) = prop(&mut g2) {
                    best = (size, m2);
                }
            }
            panic!(
                "property {name:?} failed (seed {seed}, rerun with HBFP_PROP_SEED={seed}):\n  \
                 at size {:.2}: {}",
                best.0, best.1
            );
        }
    }
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("sym range", 50, |g| {
            let x = g.f32_sym(10.0);
            if x.abs() <= 10.0 {
                Ok(())
            } else {
                Err(format!("|{x}| > 10"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn reports_failures() {
        check("always fails", 3, |g| {
            let v = g.vec_f32(4, 1);
            Err(format!("len {}", v.len()))
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = Gen::new(9);
        let mut b = Gen::new(9);
        assert_eq!(a.vec_f32(8, 3), b.vec_f32(8, 3));
    }
}
