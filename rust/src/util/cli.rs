//! Tiny argument parser for the launcher (no `clap` in the vendored set).
//!
//! Grammar: `hbfp <command> [positional...] [--flag] [--key value]...`.
//! Flags may also be written `--key=value`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut args = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn opt_f32(&self, key: &str, default: f32) -> anyhow::Result<f32> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects a float, got {v:?}")),
        }
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn basic() {
        let a = parse("train combo1 --steps 200 --lr 0.1 --verbose");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.positional, vec!["combo1"]);
        assert_eq!(a.opt("steps"), Some("200"));
        assert_eq!(a.opt_f32("lr", 0.0).unwrap(), 0.1);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn eq_form_and_defaults() {
        let a = parse("bench --steps=5");
        assert_eq!(a.opt_usize("steps", 0).unwrap(), 5);
        assert_eq!(a.opt_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_numeric() {
        let a = parse("x --steps nope");
        assert!(a.opt_usize("steps", 0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("repro table1 --force");
        assert!(a.has_flag("force"));
        assert_eq!(a.positional, vec!["table1"]);
    }
}
