//! Shared substrates: JSON, CLI parsing, RNGs, property-test runner,
//! timing helpers. These exist in-repo because the offline vendored crate
//! set lacks serde/clap/rand/proptest/criterion (DESIGN.md §7).

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;

use std::time::Instant;

/// Measure wall time of `f`, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Simple stats over a sample of seconds (used by the bench harnesses).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn from(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| xs[((xs.len() - 1) as f64 * p).round() as usize];
        Stats {
            n: xs.len(),
            mean: xs.iter().sum::<f64>() / xs.len() as f64,
            median: q(0.5),
            p10: q(0.1),
            p90: q(0.9),
            min: xs[0],
            max: xs[xs.len() - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_quantiles() {
        let s = Stats::from((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.median - 50.0).abs() <= 1.0);
        assert!((s.p90 - 90.0).abs() <= 1.0);
    }
}
