//! Shared substrates: JSON, CLI parsing, RNGs, property-test runner,
//! timing helpers. These exist in-repo because the offline vendored crate
//! set lacks serde/clap/rand/proptest/criterion (DESIGN.md §7).

pub mod cli;
pub mod crc;
pub mod fault;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;

use std::time::Instant;

/// Worker-thread budget for the parallel BFP kernels: the `HBFP_THREADS`
/// env var overrides, otherwise the machine's available parallelism.
pub fn worker_threads() -> usize {
    if let Ok(v) = std::env::var("HBFP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `(index, payload)` jobs across up to `max_threads` scoped threads.
///
/// Jobs are split into contiguous chunks, one chunk per thread, so callers
/// that hand out disjoint `&mut` slices (row bands of an output matrix)
/// parallelize without any locking. With `max_threads <= 1` everything
/// runs inline on the caller's thread — the work function must therefore
/// not depend on which thread it runs on (the BFP kernels guarantee this:
/// results are bit-identical for any thread count).
///
/// This is the per-call scoped-spawn baseline: it pays a thread spawn +
/// join on every invocation. The hot kernels now dispatch through the
/// persistent [`pool`] instead ([`pool::dispatch_jobs`]); this function
/// is kept as the `ParBackend::Scoped` reference for the bench ladder
/// and the pooled-vs-scoped differential tests.
pub fn for_each_job<T, F>(mut jobs: Vec<(usize, T)>, max_threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, T) + Sync,
{
    if jobs.is_empty() {
        return;
    }
    let threads = max_threads.max(1).min(jobs.len());
    if threads == 1 {
        for (i, job) in jobs {
            f(i, job);
        }
        return;
    }
    let per = jobs.len().div_ceil(threads);
    std::thread::scope(|scope| {
        while !jobs.is_empty() {
            let take = per.min(jobs.len());
            let chunk: Vec<(usize, T)> = jobs.drain(..take).collect();
            let f = &f;
            scope.spawn(move || {
                for (i, job) in chunk {
                    f(i, job);
                }
            });
        }
    });
}

/// Measure wall time of `f`, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Simple stats over a sample of seconds (used by the bench harnesses).
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn from(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| xs[((xs.len() - 1) as f64 * p).round() as usize];
        Stats {
            n: xs.len(),
            mean: xs.iter().sum::<f64>() / xs.len() as f64,
            median: q(0.5),
            p10: q(0.1),
            p90: q(0.9),
            min: xs[0],
            max: xs[xs.len() - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_each_job_covers_all_disjoint_slices() {
        let mut data = vec![0u32; 103];
        for threads in [1, 2, 7] {
            data.fill(0);
            let jobs: Vec<(usize, &mut [u32])> = data.chunks_mut(10).enumerate().collect();
            for_each_job(jobs, threads, |i, chunk| {
                for (j, x) in chunk.iter_mut().enumerate() {
                    *x = (i * 10 + j) as u32;
                }
            });
            for (i, &x) in data.iter().enumerate() {
                assert_eq!(x, i as u32, "threads={threads}");
            }
        }
    }

    #[test]
    fn for_each_job_empty_is_noop() {
        for_each_job(Vec::<(usize, ())>::new(), 4, |_, _| panic!("no jobs"));
    }

    #[test]
    fn worker_threads_at_least_one() {
        assert!(worker_threads() >= 1);
    }

    #[test]
    fn stats_quantiles() {
        let s = Stats::from((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.median - 50.0).abs() <= 1.0);
        assert!((s.p90 - 90.0).abs() <= 1.0);
    }
}
