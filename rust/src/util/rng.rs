//! Deterministic RNGs for data generation, stochastic rounding, and tests.
//!
//! `Xorshift32` is the generator the paper's FPGA prototype uses for
//! stochastic rounding (§5.3, citing Marsaglia'03): three shifts + three
//! xors, small enough to instantiate per converter lane in hardware. The
//! rust BFP library and the accelerator model use it so software results
//! are reproducible against a hardware implementation bit-for-bit.
//!
//! `SplitMix64` seeds streams and powers the data pipeline, where 32-bit
//! state would correlate across shards.

/// Marsaglia xorshift32 — the paper's stochastic-rounding RNG.
#[derive(Debug, Clone)]
pub struct Xorshift32 {
    state: u32,
}

impl Xorshift32 {
    /// Seed must be non-zero (xorshift has an all-zero fixed point); zero
    /// seeds are remapped to a fixed constant.
    pub fn new(seed: u32) -> Self {
        Self { state: if seed == 0 { 0x9e37_79b9 } else { seed } }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        // The (13, 17, 5) triple from Marsaglia's paper — the same one the
        // prototype synthesizes.
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 17;
        x ^= x << 5;
        self.state = x;
        x
    }

    /// Uniform in [0, 1) with 24 bits of resolution (matches the mantissa
    /// resolution relevant for stochastic rounding decisions).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Deterministic derived stream: mixes `(base, index)` through
    /// SplitMix64 into a fresh xorshift seed. The parallel quantizer gives
    /// every tile its own substream keyed by the tile's linear index, so
    /// stochastic rounding is reproducible for any thread count and any
    /// tile visit order.
    pub fn substream(base: u32, index: u64) -> Xorshift32 {
        let mut mixer = SplitMix64::new(((base as u64) << 32) ^ index);
        Xorshift32::new(mixer.next_u32())
    }
}

/// SplitMix64: fast, well-distributed 64-bit generator for seeding and data.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (pairs cached would complicate state;
    /// we just spend two uniforms per call — data generation is not hot).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-9 {
                let u2 = self.next_f32();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xorshift_nonzero_and_periodic_start() {
        let mut a = Xorshift32::new(1);
        let mut b = Xorshift32::new(1);
        let seq_a: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let seq_b: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_eq!(seq_a, seq_b);
        assert!(seq_a.iter().all(|&x| x != 0));
    }

    #[test]
    fn xorshift_zero_seed_remapped() {
        let mut r = Xorshift32::new(0);
        assert_ne!(r.next_u32(), 0);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = SplitMix64::new(42);
        for _ in 0..1000 {
            let f = r.next_f32();
            assert!((0.0..1.0).contains(&f));
        }
        let mut x = Xorshift32::new(7);
        for _ in 0..1000 {
            let f = x.next_f32();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(3);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn substreams_deterministic_and_distinct() {
        let mut a = Xorshift32::substream(42, 0);
        let mut b = Xorshift32::substream(42, 0);
        let mut c = Xorshift32::substream(42, 1);
        let mut d = Xorshift32::substream(43, 0);
        let seq = |r: &mut Xorshift32| (0..4).map(|_| r.next_u32()).collect::<Vec<_>>();
        let sa = seq(&mut a);
        assert_eq!(sa, seq(&mut b), "same (base, index) must repeat");
        assert_ne!(sa, seq(&mut c), "indices must decorrelate");
        assert_ne!(sa, seq(&mut d), "bases must decorrelate");
    }

    #[test]
    fn splitmix_streams_diverge() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(
            (0..4).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }
}
