//! Deterministic fault-injection harness.
//!
//! Every recovery path in the fault-tolerance subsystem (numeric guards,
//! crash-safe checkpoints, pool panic containment, the trainer watchdog)
//! is exercised by *injected* faults rather than trusted: this module
//! provides seeded injection sites that production code probes at the
//! exact point where the real fault would strike.
//!
//! Activation is via the `HBFP_FAULT` env var — a comma-separated list of
//! `<site>:<rate>:<seed>` specs, e.g.
//!
//! ```text
//! HBFP_FAULT=nan-activation:0.02:7,ckpt-truncate:1.0:3
//! ```
//!
//! or programmatically from tests via [`install`]. When no spec is armed
//! (the normal case) every probe is a single relaxed atomic load — the
//! harness costs nothing on production hot paths.
//!
//! Decisions are deterministic: the n-th probe of a site fires iff
//! `Xorshift32::substream(seed ^ site, n).next_f32() < rate`, so a run
//! with a fixed `HBFP_FAULT` string replays the same fault schedule
//! regardless of thread count or timing. Per-site probe/hit counters are
//! exposed so tests can assert a fault actually struck.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, RwLock};

use crate::util::rng::Xorshift32;

/// Where a fault can strike. Each variant corresponds to one probe point
/// in production code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Overwrite an activation value with NaN before quantization.
    NanActivation,
    /// Flip one mantissa bit in a quantized tensor.
    MantissaBitflip,
    /// Panic inside a pool worker's task chunk.
    WorkerPanic,
    /// Sleep inside a pool worker's task chunk (straggler simulation).
    SlowWorker,
    /// Truncate a checkpoint file mid-write (torn write).
    CkptTruncate,
    /// Flip bits in a checkpoint file after writing (media corruption).
    CkptGarble,
    /// Stall one inference request in the serving front-end (a slow or
    /// stuck client whose work must not hold up the batch behind it).
    SlowRequest,
    /// Corrupt the candidate weights of a hot `reload_model` between the
    /// caller's buffer and residency building (a bad artifact push). The
    /// reload validator must catch it and roll back.
    ReloadGarble,
    /// A tenant floods the serving front-end: traffic drivers (soaks,
    /// demos) probe this site to decide when to amplify one tenant's
    /// submission rate, so fairness is chaos-tested deterministically.
    TenantFlood,
}

/// All sites, in probe-table order.
pub const ALL_SITES: [FaultSite; 9] = [
    FaultSite::NanActivation,
    FaultSite::MantissaBitflip,
    FaultSite::WorkerPanic,
    FaultSite::SlowWorker,
    FaultSite::CkptTruncate,
    FaultSite::CkptGarble,
    FaultSite::SlowRequest,
    FaultSite::ReloadGarble,
    FaultSite::TenantFlood,
];

impl FaultSite {
    fn index(self) -> usize {
        match self {
            FaultSite::NanActivation => 0,
            FaultSite::MantissaBitflip => 1,
            FaultSite::WorkerPanic => 2,
            FaultSite::SlowWorker => 3,
            FaultSite::CkptTruncate => 4,
            FaultSite::CkptGarble => 5,
            FaultSite::SlowRequest => 6,
            FaultSite::ReloadGarble => 7,
            FaultSite::TenantFlood => 8,
        }
    }

    /// The spelling used in `HBFP_FAULT` specs.
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::NanActivation => "nan-activation",
            FaultSite::MantissaBitflip => "bitflip",
            FaultSite::WorkerPanic => "worker-panic",
            FaultSite::SlowWorker => "slow-worker",
            FaultSite::CkptTruncate => "ckpt-truncate",
            FaultSite::CkptGarble => "ckpt-garble",
            FaultSite::SlowRequest => "slow-request",
            FaultSite::ReloadGarble => "reload-garble",
            FaultSite::TenantFlood => "tenant-flood",
        }
    }

    fn from_name(name: &str) -> Option<FaultSite> {
        ALL_SITES.iter().copied().find(|s| s.name() == name)
    }
}

/// One armed injection site: fire with probability `rate` per probe,
/// deterministically derived from `seed`.
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    pub site: FaultSite,
    pub rate: f64,
    pub seed: u32,
}

#[derive(Debug, Default)]
struct SiteState {
    /// None when the site is not armed.
    spec: Option<(f64, u32)>,
    probes: AtomicU64,
    hits: AtomicU64,
}

/// A set of armed fault sites with deterministic per-probe decisions.
#[derive(Debug, Default)]
pub struct FaultInjector {
    sites: [SiteState; 9],
}

impl FaultInjector {
    /// An injector with no armed sites (every probe says "no fault").
    pub fn none() -> FaultInjector {
        FaultInjector::default()
    }

    /// Build from explicit specs (test entry point).
    pub fn from_specs(specs: &[FaultSpec]) -> FaultInjector {
        let mut inj = FaultInjector::none();
        for spec in specs {
            inj.sites[spec.site.index()].spec = Some((spec.rate, spec.seed));
        }
        inj
    }

    /// Parse an `HBFP_FAULT`-style spec string:
    /// comma-separated `<site>:<rate>:<seed>` entries.
    pub fn parse(s: &str) -> Result<FaultInjector, String> {
        let mut specs = Vec::new();
        for entry in s.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let mut parts = entry.split(':');
            let (name, rate, seed) = match (parts.next(), parts.next(), parts.next(), parts.next())
            {
                (Some(n), Some(r), Some(sd), None) => (n, r, sd),
                _ => return Err(format!("fault spec `{entry}`: want <site>:<rate>:<seed>")),
            };
            let site = FaultSite::from_name(name)
                .ok_or_else(|| format!("fault spec `{entry}`: unknown site `{name}`"))?;
            let rate: f64 = rate
                .parse()
                .map_err(|_| format!("fault spec `{entry}`: bad rate `{rate}`"))?;
            if !(0.0..=1.0).contains(&rate) {
                return Err(format!("fault spec `{entry}`: rate {rate} outside [0, 1]"));
            }
            let seed: u32 = seed
                .parse()
                .map_err(|_| format!("fault spec `{entry}`: bad seed `{seed}`"))?;
            specs.push(FaultSpec { site, rate, seed });
        }
        Ok(FaultInjector::from_specs(&specs))
    }

    /// Any site armed?
    pub fn armed(&self) -> bool {
        self.sites.iter().any(|s| s.spec.is_some())
    }

    /// Deterministic per-probe decision. Increments the site's probe
    /// counter; increments the hit counter too when it fires.
    pub fn should_fire(&self, site: FaultSite) -> bool {
        let st = &self.sites[site.index()];
        let Some((rate, seed)) = st.spec else { return false };
        let n = st.probes.fetch_add(1, Ordering::Relaxed);
        // Mix the site index into the substream base so two sites sharing
        // a seed still see independent schedules.
        let base = seed ^ ((site.index() as u32 + 1).wrapping_mul(0x9E37_79B9));
        let fire = rate >= 1.0 || (Xorshift32::substream(base, n).next_f32() as f64) < rate;
        if fire {
            st.hits.fetch_add(1, Ordering::Relaxed);
        }
        fire
    }

    /// How many times a site's probe has been evaluated.
    pub fn probes(&self, site: FaultSite) -> u64 {
        self.sites[site.index()].probes.load(Ordering::Relaxed)
    }

    /// How many times a site has actually fired.
    pub fn hits(&self, site: FaultSite) -> u64 {
        self.sites[site.index()].hits.load(Ordering::Relaxed)
    }
}

/// Process-wide armed flag: a single relaxed load on the probe fast path.
static ARMED: AtomicBool = AtomicBool::new(false);

fn state() -> &'static RwLock<Arc<FaultInjector>> {
    static STATE: OnceLock<RwLock<Arc<FaultInjector>>> = OnceLock::new();
    STATE.get_or_init(|| {
        let inj = injector_from_env();
        ARMED.store(inj.armed(), Ordering::Release);
        RwLock::new(inj)
    })
}

fn injector_from_env() -> Arc<FaultInjector> {
    match std::env::var("HBFP_FAULT") {
        Ok(spec) if !spec.trim().is_empty() => match FaultInjector::parse(&spec) {
            Ok(inj) => Arc::new(inj),
            Err(e) => {
                log::warn!("ignoring HBFP_FAULT: {e}");
                Arc::new(FaultInjector::none())
            }
        },
        _ => Arc::new(FaultInjector::none()),
    }
}

/// The active injector. Cheap when nothing is armed; callers on hot paths
/// should gate on [`enabled`] first.
pub fn active() -> Arc<FaultInjector> {
    Arc::clone(&state().read().unwrap_or_else(|e| e.into_inner()))
}

/// Fast probe gate: false unless some site is armed (one relaxed load).
#[inline]
pub fn enabled() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Probe a site: false (no fault) unless the harness is armed and the
/// site's deterministic schedule says this probe fires.
#[inline]
pub fn fire(site: FaultSite) -> bool {
    if !enabled() {
        return false;
    }
    active().should_fire(site)
}

/// Serializes tests that install injectors (the harness is process-global).
static INSTALL_LOCK: Mutex<()> = Mutex::new(());

/// RAII guard from [`install`]: restores the env-derived injector (and
/// holds the install lock) until dropped.
pub struct FaultGuard {
    _lock: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        let env_inj = injector_from_env();
        let mut w = state().write().unwrap_or_else(|e| e.into_inner());
        ARMED.store(env_inj.armed(), Ordering::Release);
        *w = env_inj;
    }
}

/// Install an injector for the lifetime of the returned guard (test entry
/// point). Tests that install injectors serialize on an internal lock so
/// concurrently-running tests never see each other's faults.
pub fn install(inj: FaultInjector) -> FaultGuard {
    let lock = INSTALL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let inj = Arc::new(inj);
    let mut w = state().write().unwrap_or_else(|e| e.into_inner());
    ARMED.store(inj.armed(), Ordering::Release);
    *w = inj;
    drop(w);
    FaultGuard { _lock: lock }
}

/// Exclusive guard over the install lock **without** replacing the active
/// injector. Tests that are fault-*sensitive* but meant to run under
/// whatever `HBFP_FAULT` the environment configured (the CI
/// fault-injection matrix) hold this so [`install`]-ing tests in the same
/// binary cannot swap the injector out from under them mid-run.
pub struct ExclusiveGuard {
    _lock: MutexGuard<'static, ()>,
}

/// See [`ExclusiveGuard`].
pub fn exclusive() -> ExclusiveGuard {
    ExclusiveGuard {
        _lock: INSTALL_LOCK.lock().unwrap_or_else(|e| e.into_inner()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_full_grammar() {
        let inj =
            FaultInjector::parse("nan-activation:0.5:7, ckpt-truncate:1.0:3,bitflip:0:1").unwrap();
        assert!(inj.armed());
        assert!(inj.should_fire(FaultSite::CkptTruncate), "rate 1.0 always fires");
        assert!(!inj.should_fire(FaultSite::MantissaBitflip), "rate 0 never fires");
        assert!(!inj.should_fire(FaultSite::WorkerPanic), "unarmed site never fires");
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(FaultInjector::parse("nan-activation:0.5").is_err(), "missing seed");
        assert!(FaultInjector::parse("warp-core:0.5:1").is_err(), "unknown site");
        assert!(FaultInjector::parse("bitflip:1.5:1").is_err(), "rate out of range");
        assert!(FaultInjector::parse("bitflip:x:1").is_err(), "non-numeric rate");
        assert!(FaultInjector::parse("bitflip:0.5:y").is_err(), "non-numeric seed");
        assert!(!FaultInjector::parse("").unwrap().armed(), "empty string: nothing armed");
    }

    #[test]
    fn schedule_is_deterministic() {
        let mk = || {
            FaultInjector::from_specs(&[FaultSpec {
                site: FaultSite::NanActivation,
                rate: 0.3,
                seed: 42,
            }])
        };
        let a = mk();
        let b = mk();
        let seq_a: Vec<bool> = (0..64).map(|_| a.should_fire(FaultSite::NanActivation)).collect();
        let seq_b: Vec<bool> = (0..64).map(|_| b.should_fire(FaultSite::NanActivation)).collect();
        assert_eq!(seq_a, seq_b, "same seed must replay the same schedule");
        assert!(seq_a.iter().any(|&f| f), "rate 0.3 over 64 probes should fire");
        assert!(seq_a.iter().any(|&f| !f), "rate 0.3 over 64 probes should also skip");
        assert_eq!(a.probes(FaultSite::NanActivation), 64);
        assert_eq!(
            a.hits(FaultSite::NanActivation),
            seq_a.iter().filter(|&&f| f).count() as u64
        );
    }

    #[test]
    fn seeds_decorrelate() {
        let fires = |seed: u32| {
            let inj = FaultInjector::from_specs(&[FaultSpec {
                site: FaultSite::WorkerPanic,
                rate: 0.5,
                seed,
            }]);
            (0..64).map(|_| inj.should_fire(FaultSite::WorkerPanic)).collect::<Vec<_>>()
        };
        assert_ne!(fires(1), fires(2));
    }

    #[test]
    fn lifecycle_sites_parse_and_fire() {
        let inj = FaultInjector::parse("reload-garble:1.0:3,tenant-flood:1.0:4").unwrap();
        assert!(inj.armed());
        assert!(inj.should_fire(FaultSite::ReloadGarble), "rate 1.0 always fires");
        assert!(inj.should_fire(FaultSite::TenantFlood), "rate 1.0 always fires");
        assert_eq!(inj.hits(FaultSite::ReloadGarble), 1);
        assert_eq!(inj.hits(FaultSite::TenantFlood), 1);
        assert_eq!(ALL_SITES.len(), 9, "every site must sit in the probe table");
    }

    #[test]
    fn slow_request_site_parses_and_fires() {
        let inj = FaultInjector::parse("slow-request:1.0:2").unwrap();
        assert!(inj.armed());
        assert!(inj.should_fire(FaultSite::SlowRequest), "rate 1.0 always fires");
        assert_eq!(inj.probes(FaultSite::SlowRequest), 1);
        assert_eq!(inj.hits(FaultSite::SlowRequest), 1);
    }

    #[test]
    fn install_guard_swaps_and_restores() {
        assert!(!fire(FaultSite::SlowWorker), "unarmed by default");
        {
            let _g = install(FaultInjector::from_specs(&[FaultSpec {
                site: FaultSite::SlowWorker,
                rate: 1.0,
                seed: 1,
            }]));
            assert!(enabled());
            assert!(fire(FaultSite::SlowWorker));
        }
        assert!(!fire(FaultSite::SlowWorker), "guard drop restores the env injector");
    }
}
