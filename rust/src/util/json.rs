//! Minimal JSON parser/emitter.
//!
//! The vendored crate set has no `serde` facade (only `serde_core`), so the
//! manifest, experiment configs and result files go through this module.
//! It supports the full JSON grammar minus surrogate-pair escapes, which
//! none of our producers emit.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use `BTreeMap` for deterministic ordering
/// (result files diff cleanly between runs).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----------------------------------------------------------- access

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key — manifest
    /// parsing wants actionable messages, not unwraps.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key {key:?} in json object"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ------------------------------------------------------ construction

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // ----------------------------------------------------------- parse

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte {:?}", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("eof in string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("eof in escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut cp = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
                            cp = cp * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-decode multi-byte UTF-8 sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = match c {
                            0xc0..=0xdf => 2,
                            0xe0..=0xef => 3,
                            _ => 4,
                        };
                        let end = (start + len).min(self.b.len());
                        let s = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// -------------------------------------------------------------- emission

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_indented(f, 0)
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn fmt_num(n: f64) -> String {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else if n.is_finite() {
        format!("{n}")
    } else {
        // JSON has no inf/nan; emit null like python's json with allow_nan off
        "null".to_string()
    }
}

impl Json {
    fn write_indented(&self, f: &mut fmt::Formatter<'_>, depth: usize) -> fmt::Result {
        let pad = " ".repeat(depth + 1);
        let pad0 = " ".repeat(depth);
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write!(f, "{}", fmt_num(*n)),
            Json::Str(s) => {
                let mut out = String::new();
                escape(s, &mut out);
                write!(f, "{out}")
            }
            Json::Arr(v) if v.is_empty() => write!(f, "[]"),
            Json::Arr(v) => {
                // Arrays of scalars stay on one line; nested ones wrap.
                let scalar = v.iter().all(|x| !matches!(x, Json::Arr(_) | Json::Obj(_)));
                if scalar {
                    write!(f, "[")?;
                    for (i, x) in v.iter().enumerate() {
                        if i > 0 {
                            write!(f, ", ")?;
                        }
                        x.write_indented(f, depth)?;
                    }
                    write!(f, "]")
                } else {
                    writeln!(f, "[")?;
                    for (i, x) in v.iter().enumerate() {
                        write!(f, "{pad}")?;
                        x.write_indented(f, depth + 1)?;
                        if i + 1 != v.len() {
                            write!(f, ",")?;
                        }
                        writeln!(f)?;
                    }
                    write!(f, "{pad0}]")
                }
            }
            Json::Obj(m) if m.is_empty() => write!(f, "{{}}"),
            Json::Obj(m) => {
                writeln!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    let mut key = String::new();
                    escape(k, &mut key);
                    write!(f, "{pad}{key}: ")?;
                    v.write_indented(f, depth + 1)?;
                    if i + 1 != m.len() {
                        write!(f, ",")?;
                    }
                    writeln!(f)?;
                }
                write!(f, "{pad0}}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert!(v.get("c").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr": [1.5, "two", null, false], "nested": {"x": [[1], [2]]}}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn int_formatting() {
        assert_eq!(Json::Num(32.0).to_string(), "32");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
