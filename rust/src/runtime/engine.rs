//! PJRT execution engine: load AOT HLO-text artifacts and run them from
//! the rust hot path.
//!
//! Python never runs here — `make artifacts` produced the HLO text once;
//! this module parses it with XLA's text parser (which reassigns the 64-bit
//! instruction ids jax >= 0.5 emits — see DESIGN.md §2), compiles on the
//! PJRT CPU client, and executes.
//!
//! ## Tuple note (affects the hot path)
//!
//! jax lowers multi-output functions to a tuple-rooted HLO module, and the
//! `xla` crate's execute does NOT set `untuple_result`, so every call
//! returns ONE tuple buffer. We therefore keep training state as host
//! `Literal`s: fetch the tuple literal, split it with `Literal::to_tuple`,
//! and feed the pieces back as parameters next step. On the CPU platform
//! PJRT buffers live in host memory, so this costs one memcpy per tensor
//! per step (measured in the §Perf pass; negligible against step compute).

use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::manifest::{Artifact, DType, TensorSpec};

/// Host-side tensor (row-major), the boundary type between the data
/// pipeline / metrics and the device.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32(vec![v], vec![])
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(..) => DType::F32,
            HostTensor::I32(..) => DType::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v, _) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// Check against a manifest spec (shape + dtype), with a useful message.
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.shape() != spec.shape.as_slice() || self.dtype() != spec.dtype {
            return Err(anyhow!(
                "tensor {:?}: expected {:?} {:?}, got {:?} {:?}",
                spec.name,
                spec.dtype,
                spec.shape,
                self.dtype(),
                self.shape()
            ));
        }
        Ok(())
    }

    /// Convert to an XLA literal (one memcpy).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(v, _) => xla::Literal::vec1(v),
            HostTensor::I32(v, _) => xla::Literal::vec1(v),
        };
        lit.reshape(&dims).context("reshaping literal")
    }
}

/// The PJRT client wrapper.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn new() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact. Compilation happens once per program;
    /// the executable is reusable across the whole training run.
    pub fn load(&self, art: &Artifact) -> Result<Program> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            art.file.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", art.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {}", art.name))?;
        let compile_secs = t0.elapsed().as_secs_f64();
        log::debug!("compiled {} in {compile_secs:.2}s", art.name);
        Ok(Program { exe, art: art.clone(), compile_secs })
    }
}

/// A compiled artifact ready to execute.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    pub art: Artifact,
    pub compile_secs: f64,
}

impl Program {
    /// Execute with literal arguments; returns one literal per manifest
    /// output (splitting the tuple root — see module docs).
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(&self, args: &[L]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.art.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.art.name,
                self.art.inputs.len(),
                args.len()
            ));
        }
        let mut out =
            self.exe.execute::<L>(args).with_context(|| format!("executing {}", self.art.name))?;
        let replica0 = out.drain(..).next().ok_or_else(|| anyhow!("no replica outputs"))?;
        let buf = replica0
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: empty output list", self.art.name))?;
        let lit = buf.to_literal_sync().context("fetching result")?;
        let parts = if self.art.outputs.len() == 1 {
            vec![lit]
        } else {
            lit.to_tuple().with_context(|| format!("untupling {} outputs", self.art.name))?
        };
        if parts.len() != self.art.outputs.len() {
            return Err(anyhow!(
                "{}: manifest promises {} outputs, runtime returned {}",
                self.art.name,
                self.art.outputs.len(),
                parts.len()
            ));
        }
        Ok(parts)
    }

    /// Execute with host tensors (validated against the manifest specs);
    /// convenience for init and tests.
    pub fn run_host(&self, args: &[HostTensor]) -> Result<Vec<xla::Literal>> {
        for (t, spec) in args.iter().zip(&self.art.inputs) {
            t.check(spec)?;
        }
        let lits = args.iter().map(|t| t.to_literal()).collect::<Result<Vec<_>>>()?;
        self.run(&lits)
    }
}

/// Fetch a literal as f32 data.
pub fn fetch_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("literal not f32")
}

/// Fetch a scalar f32 output (loss, accuracy, ...).
pub fn fetch_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().context("reading scalar literal")
}
