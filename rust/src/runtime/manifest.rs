//! Typed view of `artifacts/manifest.json` (written by `python -m compile.aot`).
//!
//! The manifest is the L2→L3 contract: for every artifact it pins the HLO
//! file, the flat input/output order (state leaves first), tensor shapes
//! and dtypes, and the dataset dimensions the data pipeline must generate.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Element type of a tensor edge. Mirrors aot.py's `_dtype_str`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            "u32" => Ok(DType::U32),
            _ => Err(anyhow!("unknown dtype {s:?}")),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// Shape + dtype + logical name of one tensor edge.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req("name")?.as_str().context("name not a string")?.to_string(),
            shape: j
                .req("shape")?
                .as_arr()
                .context("shape not an array")?
                .iter()
                .map(|d| d.as_usize().context("shape dim not a number"))
                .collect::<Result<_>>()?,
            dtype: DType::parse(j.req("dtype")?.as_str().context("dtype not a string")?)?,
        })
    }
}

/// The role of an artifact within a combo.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Init,
    Train,
    Eval,
}

impl Role {
    pub fn parse(s: &str) -> Result<Role> {
        match s {
            "init" => Ok(Role::Init),
            "train" => Ok(Role::Train),
            "eval" => Ok(Role::Eval),
            _ => Err(anyhow!("unknown role {s:?}")),
        }
    }

    pub fn suffix(self) -> &'static str {
        match self {
            Role::Init => "init",
            Role::Train => "train",
            Role::Eval => "eval",
        }
    }
}

/// One AOT-compiled HLO module and its I/O contract.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub role: Role,
    pub model: String,
    pub dataset: String,
    pub config: String,
    /// Number of leading inputs (and outputs, for train) that are training
    /// state fed back step-over-step.
    pub state_len: usize,
    pub batch: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Dataset dimensions (the rust generators consume these).
#[derive(Debug, Clone)]
pub enum DatasetSpec {
    Image { hw: usize, channels: usize, classes: usize },
    Text { vocab: usize, seq: usize },
}

/// The whole manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub datasets: BTreeMap<String, DatasetSpec>,
    pub artifacts: BTreeMap<String, Artifact>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let mut datasets = BTreeMap::new();
        for (name, d) in j.req("datasets")?.as_obj().context("datasets not an object")? {
            let kind = d.req("kind")?.as_str().context("kind")?;
            let spec = match kind {
                "image" => DatasetSpec::Image {
                    hw: d.req("hw")?.as_usize().context("hw")?,
                    channels: d.req("channels")?.as_usize().context("channels")?,
                    classes: d.req("classes")?.as_usize().context("classes")?,
                },
                "text" => DatasetSpec::Text {
                    vocab: d.req("vocab")?.as_usize().context("vocab")?,
                    seq: d.req("seq")?.as_usize().context("seq")?,
                },
                _ => return Err(anyhow!("unknown dataset kind {kind:?}")),
            };
            datasets.insert(name.clone(), spec);
        }

        let mut artifacts = BTreeMap::new();
        for (name, a) in j.req("artifacts")?.as_obj().context("artifacts not an object")? {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                a.req(key)?
                    .as_arr()
                    .with_context(|| format!("{key} not an array"))?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect()
            };
            let art = Artifact {
                name: name.clone(),
                file: dir.join(a.req("file")?.as_str().context("file")?),
                role: Role::parse(a.req("role")?.as_str().context("role")?)?,
                model: a.req("model")?.as_str().context("model")?.to_string(),
                dataset: a.req("dataset")?.as_str().context("dataset")?.to_string(),
                config: a.req("config")?.as_str().context("config")?.to_string(),
                state_len: a.req("state_len")?.as_usize().context("state_len")?,
                batch: a.req("batch")?.as_usize().context("batch")?,
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
            };
            artifacts.insert(name.clone(), art);
        }
        Ok(Manifest { dir: dir.to_path_buf(), datasets, artifacts })
    }

    /// Artifact for `"{model}-{dataset}-{config}"` and a role.
    pub fn artifact(&self, combo: &str, role: Role) -> Result<&Artifact> {
        let key = format!("{combo}__{}", role.suffix());
        self.artifacts
            .get(&key)
            .ok_or_else(|| anyhow!("artifact {key:?} not in manifest (available combos: run `hbfp list`)"))
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetSpec> {
        self.datasets.get(name).ok_or_else(|| anyhow!("unknown dataset {name:?}"))
    }

    /// All combo names (deduped from artifact keys).
    pub fn combos(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .artifacts
            .keys()
            .filter_map(|k| k.split_once("__").map(|(c, _)| c.to_string()))
            .collect();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> &'static str {
        r#"{
          "version": 1,
          "datasets": {
            "d1": {"kind": "image", "hw": 16, "channels": 3, "classes": 10},
            "t1": {"kind": "text", "vocab": 32, "seq": 48}
          },
          "artifacts": {
            "m-d1-fp32__train": {
              "file": "m-d1-fp32__train.hlo.txt", "role": "train",
              "model": "m", "dataset": "d1", "config": "fp32",
              "state_len": 2, "batch": 32,
              "inputs": [
                {"name": "state/p/w", "shape": [4, 4], "dtype": "f32"},
                {"name": "state/m/w", "shape": [4, 4], "dtype": "f32"},
                {"name": "x", "shape": [32, 16, 16, 3], "dtype": "f32"},
                {"name": "y", "shape": [32], "dtype": "i32"},
                {"name": "lr", "shape": [], "dtype": "f32"}
              ],
              "outputs": [
                {"name": "state/p/w", "shape": [4, 4], "dtype": "f32"},
                {"name": "state/m/w", "shape": [4, 4], "dtype": "f32"},
                {"name": "loss", "shape": [], "dtype": "f32"},
                {"name": "acc", "shape": [], "dtype": "f32"}
              ]
            }
          }
        }"#
    }

    #[test]
    fn parses_sample() {
        let dir = std::env::temp_dir().join("hbfp_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.combos(), vec!["m-d1-fp32"]);
        let a = m.artifact("m-d1-fp32", Role::Train).unwrap();
        assert_eq!(a.state_len, 2);
        assert_eq!(a.inputs.len(), 5);
        assert_eq!(a.inputs[2].shape, vec![32, 16, 16, 3]);
        assert_eq!(a.inputs[3].dtype, DType::I32);
        assert!(matches!(m.dataset("t1").unwrap(), DatasetSpec::Text { vocab: 32, seq: 48 }));
        assert!(m.artifact("m-d1-fp32", Role::Eval).is_err());
    }

    #[test]
    fn missing_key_is_actionable() {
        let dir = std::env::temp_dir().join("hbfp_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"datasets": {}}"#).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("artifacts"), "{err}");
    }
}
