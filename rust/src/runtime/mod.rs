//! Runtime layer: manifest parsing + PJRT engine.
//!
//! `Manifest` (what artifacts exist, their I/O contracts) + `Engine`
//! (compile & execute them with device-resident state). Everything above
//! this layer — the trainer, harnesses, examples — is backend-agnostic
//! rust; everything below is XLA.

pub mod engine;
pub mod manifest;

pub use engine::{fetch_f32, fetch_scalar_f32, Engine, HostTensor, Program};
pub use manifest::{Artifact, DType, DatasetSpec, Manifest, Role, TensorSpec};
