//! The native training driver: combo parsing, session assembly, and the
//! [`FaultTolerantModel`] adapter that puts the whole forward/backward
//! loop under the [`run_resilient`] watchdog.
//!
//! A combo string `"{model}-{dataset}-{config}"` (e.g.
//! `"mlp-cifar10like-hbfp8_t24"`) selects:
//!
//! - **model**: `mlp` (images) or `charlm` (text) — built from
//!   [`Xorshift32`](crate::util::rng::Xorshift32) substreams of the run
//!   seed, so two combos differing only in numeric config start from
//!   bit-identical FP32 weights (the paper's paired-curve methodology).
//! - **dataset**: a synthetic stand-in spec resolved through the shared
//!   [`DatasetCache`], so an FP32-vs-HBFP pair generates its dataset
//!   once and the second run is a cache hit.
//! - **config**: `fp32` or `hbfp{bits}`, with an optional `_t{edge}`
//!   tile suffix (default 24).
//!
//! The [`NnSession`] exposes the session as checkpoint leaves (`.w` +
//! `.v` per parameter, plus a `width_bits` scalar), so rollback restores
//! weights, momentum, *and* the mantissa width class together —
//! replayed batches are a pure function of `seed ^ step`, making
//! recovery deterministic.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::models::{CharLm, Mlp, Model};
use super::{NnContext, Optimizer, Precision};
use crate::bfp::{next_wider_class, BfpContext, GuardStatsSnapshot, TileSize};
use crate::coordinator::metrics::guard_stats_json;
use crate::coordinator::{run_resilient, FaultTolerantModel, History, RunConfig};
use crate::data::{Dataset, DatasetCache};
use crate::runtime::{DType, DatasetSpec, HostTensor, TensorSpec};
use crate::util::fault::{self, FaultSite};
use crate::util::json::Json;
use crate::util::rng::SplitMix64;

/// Default tile edge when the combo config carries no `_t{edge}` suffix.
const DEFAULT_TILE_EDGE: usize = 24;

/// Validation batches one eval pass consumes (the full split on small
/// datasets; a fixed deterministic prefix on large ones).
const EVAL_BATCH_CAP: usize = 8;

/// Parsed form of a `"{model}-{dataset}-{config}"` combo.
struct ComboSpec {
    arch: Arch,
    dataset: DatasetSpec,
    precision: Precision,
    tile: usize,
    batch: usize,
}

enum Arch {
    Mlp { hidden: Vec<usize> },
    CharLm { embed: usize, hidden: usize },
}

impl ComboSpec {
    fn parse(combo: &str) -> Result<ComboSpec> {
        let parts: Vec<&str> = combo.split('-').collect();
        let [model, dataset, config] = parts[..] else {
            return Err(anyhow!("combo {combo:?}: want \"model-dataset-config\""));
        };
        let (dataset, batch) = match dataset {
            "cifar10like" => (DatasetSpec::Image { hw: 12, channels: 3, classes: 10 }, 32),
            "tinyimg" => (DatasetSpec::Image { hw: 8, channels: 1, classes: 4 }, 16),
            "ptblike" => (DatasetSpec::Text { vocab: 32, seq: 24 }, 16),
            other => return Err(anyhow!("combo {combo:?}: unknown dataset {other:?}")),
        };
        let arch = match (model, &dataset) {
            ("mlp", DatasetSpec::Image { hw, channels, .. }) => {
                // One hidden layer sized to the input: enough capacity to
                // learn the synthetic classes, small enough for CI.
                let hidden = if hw * hw * channels >= 128 { vec![64] } else { vec![32] };
                Arch::Mlp { hidden }
            }
            ("charlm", DatasetSpec::Text { .. }) => Arch::CharLm { embed: 16, hidden: 32 },
            ("mlp", _) => return Err(anyhow!("combo {combo:?}: mlp needs an image dataset")),
            ("charlm", _) => return Err(anyhow!("combo {combo:?}: charlm needs a text dataset")),
            (other, _) => return Err(anyhow!("combo {combo:?}: unknown model {other:?}")),
        };
        let (prec_tok, tile) = match config.split_once("_t") {
            Some((p, t)) => {
                let tile: usize =
                    t.parse().map_err(|_| anyhow!("combo {combo:?}: bad tile suffix _t{t}"))?;
                if tile == 0 {
                    return Err(anyhow!("combo {combo:?}: tile edge must be > 0"));
                }
                (p, tile)
            }
            None => (config, DEFAULT_TILE_EDGE),
        };
        let precision = Precision::parse(prec_tok)?;
        Ok(ComboSpec { arch, dataset, precision, tile, batch })
    }

    fn build_model(&self, seed: u32) -> Box<dyn Model> {
        match (&self.arch, &self.dataset) {
            (Arch::Mlp { hidden }, DatasetSpec::Image { hw, channels, classes }) => {
                Box::new(Mlp::new(hw * hw * channels, hidden, *classes, seed))
            }
            (Arch::CharLm { embed, hidden }, DatasetSpec::Text { vocab, .. }) => {
                Box::new(CharLm::new(*vocab, *embed, *hidden, seed))
            }
            // parse() pairs arch and dataset; the other arms cannot be built.
            _ => unreachable!("ComboSpec::parse enforces model/dataset pairing"),
        }
    }

    fn optimizer(&self) -> Optimizer {
        match self.arch {
            Arch::Mlp { .. } => Optimizer::Momentum { mu: 0.9 },
            Arch::CharLm { .. } => Optimizer::Sgd,
        }
    }
}

/// One live training session: a model, its [`NnContext`] (BFP context +
/// plan cache + guard), an optimizer, and a shared dataset. Implements
/// [`FaultTolerantModel`] so [`run_resilient`] can checkpoint, roll
/// back, and widen it.
pub struct NnSession {
    model: Box<dyn Model>,
    pub nc: NnContext,
    opt: Optimizer,
    dataset: Arc<Dataset>,
    batch: usize,
    seed: u64,
    /// Validation batches per eval pass (deterministic prefix).
    pub eval_batch_cap: usize,
}

impl NnSession {
    /// Deterministic per-step batch RNG: the same `seed ^ f(step)`
    /// derivation the rest of the repo uses, so rollback replays the
    /// exact batch schedule.
    fn batch_rng(&self, step: usize) -> SplitMix64 {
        SplitMix64::new(self.seed ^ (step as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Forward-only pass over a deterministic prefix of the validation
    /// split; returns `(mean loss, mean error)`.
    pub fn evaluate(&mut self) -> Result<(f32, f32)> {
        let batches = self.dataset.val_batches(self.batch);
        if batches.is_empty() {
            return Err(anyhow!("validation split smaller than one batch"));
        }
        let take = self.eval_batch_cap.clamp(1, batches.len());
        let (mut loss, mut err) = (0.0f64, 0.0f64);
        for (x, y) in batches.iter().take(take) {
            let (l, e) = self.model.eval_batch(&mut self.nc, x, y)?;
            loss += l as f64;
            err += e as f64;
        }
        // An eval-side guard trip is not a training hazard: don't let it
        // leak into the next step's sticky flag.
        let _ = self.nc.take_tripped();
        Ok(((loss / take as f64) as f32, (err / take as f64) as f32))
    }
}

impl FaultTolerantModel for NnSession {
    fn specs(&self) -> Vec<TensorSpec> {
        let mut specs: Vec<TensorSpec> = Vec::new();
        for p in self.model.params() {
            specs.push(TensorSpec {
                name: format!("{}.w", p.name),
                shape: p.shape.clone(),
                dtype: DType::F32,
            });
            specs.push(TensorSpec {
                name: format!("{}.v", p.name),
                shape: p.shape.clone(),
                dtype: DType::F32,
            });
        }
        specs.push(TensorSpec { name: "width_bits".to_string(), shape: vec![], dtype: DType::I32 });
        specs
    }

    fn state(&self) -> Vec<HostTensor> {
        let mut leaves: Vec<HostTensor> = Vec::new();
        for p in self.model.params() {
            leaves.push(HostTensor::F32(p.w.clone(), p.shape.clone()));
            leaves.push(HostTensor::F32(p.v.clone(), p.shape.clone()));
        }
        leaves.push(HostTensor::scalar_i32(self.width() as i32));
        leaves
    }

    fn restore(&mut self, leaves: &[HostTensor]) -> Result<()> {
        let n_params = self.model.params().len();
        if leaves.len() != 2 * n_params + 1 {
            return Err(anyhow!("expected {} leaves, got {}", 2 * n_params + 1, leaves.len()));
        }
        self.nc.precision = match leaves.last() {
            Some(HostTensor::I32(v, _)) if v.len() == 1 => match v[0] {
                32 => Precision::Fp32,
                b if (2..=24).contains(&b) => Precision::Hbfp { bits: b as u32 },
                other => return Err(anyhow!("bad width leaf value {other}")),
            },
            other => return Err(anyhow!("bad width leaf {other:?}")),
        };
        for (i, p) in self.model.params_mut().into_iter().enumerate() {
            let w = leaves[2 * i].as_f32()?;
            let v = leaves[2 * i + 1].as_f32()?;
            if w.len() != p.len() || v.len() != p.len() {
                return Err(anyhow!("leaf size mismatch restoring {}", p.name));
            }
            p.w.copy_from_slice(w);
            p.v.copy_from_slice(v);
            p.zero_grad();
        }
        Ok(())
    }

    fn step(&mut self, step: usize, lr: f32) -> Result<(f32, f32)> {
        self.nc.obs.begin_step(step);
        let _span = crate::obs::trace::span("nn.step");
        let (mut x, y) = self.dataset.train_batch(self.batch, &mut self.batch_rng(step));
        // Narrow-class fault hook (same shape as the fault demo): hazards
        // born of aggressive quantization fire only at <= 8 bits, so the
        // watchdog's rollback-and-widen actually clears them.
        if self.width() <= 8 && fault::fire(FaultSite::NanActivation) {
            if let HostTensor::F32(v, _) = &mut x {
                if let Some(first) = v.first_mut() {
                    *first = f32::NAN;
                }
            }
        }
        let (loss, acc) = self.model.train_batch(&mut self.nc, &x, &y)?;
        // The guard is the hazard signal, not the loss: ReLU and softmax
        // can both absorb a NaN before it reaches the loss value, but the
        // input scan at the first GEMM boundary cannot be fooled.
        if self.nc.take_tripped() {
            for p in self.model.params_mut() {
                p.zero_grad();
            }
            return Err(anyhow!(
                "numeric guard tripped at step {step}: non-finite activations entered a GEMM"
            ));
        }
        if loss.is_finite() {
            let t_opt = self.nc.obs.stage_start();
            let _opt_span = crate::obs::trace::span("nn.opt");
            for p in self.model.params_mut() {
                self.opt.update(p, lr);
            }
            self.nc.obs.stage_end("opt", t_opt);
        } else {
            // Overflow-skip: poisoned gradients never reach the weights.
            for p in self.model.params_mut() {
                p.zero_grad();
            }
        }
        Ok((loss, acc))
    }

    fn width(&self) -> u32 {
        self.nc.precision.width_bits()
    }

    fn widen(&mut self) -> bool {
        match self.nc.precision {
            Precision::Fp32 => false,
            Precision::Hbfp { bits } => {
                self.nc.precision = match next_wider_class(bits) {
                    Some(w) => Precision::Hbfp { bits: w },
                    // Past the widest BFP class the remedy is the FP32
                    // baseline itself.
                    None => Precision::Fp32,
                };
                true
            }
        }
    }

    fn guard_stats(&self) -> Option<GuardStatsSnapshot> {
        Some(self.nc.guard.snapshot())
    }

    fn eval(&mut self) -> Option<Result<(f32, f32)>> {
        Some(self.evaluate())
    }
}

/// Everything one [`Trainer::run`] produced: the full [`History`] plus
/// the summary counters the acceptance harness asserts on.
pub struct NnRunReport {
    pub combo: String,
    pub config: Json,
    pub history: History,
    /// Mean training loss over the last 10 steps.
    pub final_loss: f32,
    /// Final validation `(loss, error)` when the run evaluated.
    pub final_eval_loss: Option<f32>,
    pub final_eval_error: Option<f32>,
    pub train_secs: f64,
    /// Plan-cache counters — the proof that every GEMM routed through
    /// cached [`MatmulPlan`](crate::bfp::MatmulPlan)s.
    pub plan_hits: u64,
    pub plan_misses: u64,
    pub plan_evictions: u64,
    pub plans_resident: usize,
    /// Did this run reuse a dataset another run already generated?
    pub dataset_cache_hit: bool,
    /// Mantissa width class at end of run (32 = FP32; differs from the
    /// combo's width only after a watchdog widening).
    pub final_width_bits: u32,
    /// For text runs: the corpus generator's per-token entropy (nats) —
    /// the loss floor a perfect model converges to.
    pub entropy_floor_nats: Option<f64>,
    /// Observability export (`HBFP_OBS=full` only): per-layer
    /// numeric-health timelines + per-step stage timings. `None` below
    /// full mode, and then the `"obs"` key is omitted entirely so
    /// off-mode metrics JSON is byte-identical to pre-obs builds.
    pub obs: Option<Json>,
}

impl NnRunReport {
    /// The run's metrics JSON (written next to the CSV curve by the
    /// examples; `plan_cache` counters are an acceptance criterion).
    pub fn summary_json(&self) -> Json {
        let mut fields = vec![
            ("combo", Json::str(self.combo.clone())),
            ("config", self.config.clone()),
            ("final_loss", Json::num(self.final_loss)),
            (
                "final_eval_loss",
                self.final_eval_loss.map(Json::num).unwrap_or(Json::Null),
            ),
            (
                "final_eval_error",
                self.final_eval_error.map(Json::num).unwrap_or(Json::Null),
            ),
            ("train_secs", Json::num(self.train_secs)),
            (
                "steps_per_sec",
                self.history.throughput().map(Json::num).unwrap_or(Json::Null),
            ),
            ("final_width_bits", Json::num(self.final_width_bits as f64)),
            ("recoveries", Json::num(self.history.recoveries.len() as f64)),
            ("diverged", Json::Bool(self.history.diverged())),
            ("dataset_cache_hit", Json::Bool(self.dataset_cache_hit)),
            (
                "plan_cache",
                Json::obj(vec![
                    ("hits", Json::num(self.plan_hits as f64)),
                    ("misses", Json::num(self.plan_misses as f64)),
                    ("evictions", Json::num(self.plan_evictions as f64)),
                    ("resident", Json::num(self.plans_resident as f64)),
                ]),
            ),
        ];
        if let Some(e) = self.entropy_floor_nats {
            fields.push(("entropy_floor_nats", Json::num(e)));
        }
        if let Some(g) = &self.history.guard {
            fields.push(("guard_stats", guard_stats_json(g)));
        }
        if let Some(o) = &self.obs {
            fields.push(("obs", o.clone()));
        }
        Json::obj(fields)
    }
}

/// The native trainer: one [`BfpContext`] (policy) + one [`DatasetCache`]
/// shared across runs, so paired FP32-vs-HBFP combos reuse generated
/// datasets. Stateless across runs otherwise — each [`Trainer::run`]
/// builds a fresh [`NnSession`].
pub struct Trainer {
    ctx: BfpContext,
    datasets: DatasetCache,
}

impl Trainer {
    /// Policy from the environment (`HBFP_THREADS`, `HBFP_SIMD`, …).
    pub fn new() -> Trainer {
        Trainer::with_context(BfpContext::from_env())
    }

    /// Explicit policy context (tests pin thread counts through this).
    pub fn with_context(ctx: BfpContext) -> Trainer {
        Trainer { ctx, datasets: DatasetCache::default() }
    }

    /// The shared dataset cache (counters are observable for tests).
    pub fn dataset_cache(&self) -> &DatasetCache {
        &self.datasets
    }

    /// Build the live session for `cfg` without running it (the watchdog
    /// test drives `run_resilient` directly).
    pub fn session(&self, cfg: &RunConfig) -> Result<NnSession> {
        let spec = ComboSpec::parse(&cfg.combo)?;
        let dataset = self.datasets.get_or_generate(&spec.dataset, cfg.seed ^ 0xda7a)?;
        let ctx = self.ctx.clone().with_tile(TileSize::Edge(spec.tile));
        // Weight-init substream off the run seed: combos differing only
        // in numeric config start from identical FP32 weights.
        let model = spec.build_model((cfg.seed as u32) ^ 0x5eed);
        Ok(NnSession {
            model,
            nc: NnContext::new(ctx, spec.precision),
            opt: spec.optimizer(),
            dataset,
            batch: spec.batch,
            seed: cfg.seed,
            eval_batch_cap: EVAL_BATCH_CAP,
        })
    }

    /// Train `cfg.combo` for `cfg.steps` under the resilient watchdog and
    /// report the curve plus the summary counters.
    pub fn run(&self, cfg: &RunConfig) -> Result<NnRunReport> {
        let hits_before = self.datasets.hits();
        let mut session = self.session(cfg)?;
        let entropy_floor_nats = match session.dataset.as_ref() {
            Dataset::Text(t) => Some(t.entropy_nats),
            Dataset::Image(_) => None,
        };
        let t0 = Instant::now();
        let history = run_resilient(&mut session, cfg)?;
        let train_secs = t0.elapsed().as_secs_f64();
        let final_eval = history.final_eval().copied();
        Ok(NnRunReport {
            combo: cfg.combo.clone(),
            config: cfg.to_json(),
            final_loss: history.tail_loss(10).unwrap_or(f32::NAN),
            final_eval_loss: final_eval.map(|e| e.loss),
            final_eval_error: final_eval.map(|e| e.error),
            train_secs,
            plan_hits: session.nc.plans.hits(),
            plan_misses: session.nc.plans.misses(),
            plan_evictions: session.nc.plans.evictions(),
            plans_resident: session.nc.plans.len(),
            dataset_cache_hit: self.datasets.hits() > hits_before,
            final_width_bits: session.width(),
            entropy_floor_nats,
            obs: session.nc.obs.to_json(),
            history,
        })
    }
}

impl Default for Trainer {
    fn default() -> Self {
        Trainer::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::LrSchedule;
    use crate::util::fault::FaultInjector;

    #[test]
    fn combo_parsing() {
        let c = ComboSpec::parse("mlp-cifar10like-hbfp8_t24").unwrap();
        assert_eq!(c.precision, Precision::Hbfp { bits: 8 });
        assert_eq!(c.tile, 24);
        assert_eq!(c.batch, 32);
        assert!(matches!(c.arch, Arch::Mlp { .. }));
        let c = ComboSpec::parse("charlm-ptblike-fp32").unwrap();
        assert_eq!(c.precision, Precision::Fp32);
        assert_eq!(c.tile, DEFAULT_TILE_EDGE, "no suffix: default tile");
        assert!(matches!(c.arch, Arch::CharLm { .. }));
        let c = ComboSpec::parse("mlp-tinyimg-hbfp16_t8").unwrap();
        assert_eq!((c.precision, c.tile), (Precision::Hbfp { bits: 16 }, 8));

        for bad in [
            "mlp-cifar10like",           // missing config
            "mlp-nosuch-fp32",           // unknown dataset
            "vgg-cifar10like-fp32",      // unknown model
            "mlp-ptblike-fp32",          // model/dataset mismatch
            "charlm-cifar10like-fp32",   // model/dataset mismatch
            "mlp-tinyimg-hbfp8_t0",      // zero tile
            "mlp-tinyimg-int8",          // unknown precision
        ] {
            assert!(ComboSpec::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn fp32_run_produces_curve_and_report() {
        let _guard = crate::util::fault::install(FaultInjector::none());
        let trainer = Trainer::with_context(BfpContext::from_env().with_threads(1));
        let cfg = RunConfig::new("mlp-tinyimg-fp32", 6)
            .with_seed(11)
            .with_lr(LrSchedule::Constant { lr: 0.05 })
            .with_eval_every(3);
        let r = trainer.run(&cfg).unwrap();
        assert_eq!(r.history.steps.len(), 6);
        assert!(r.final_loss.is_finite());
        assert!(!r.history.evals.is_empty(), "eval cadence must record");
        assert_eq!(r.final_width_bits, 32);
        assert_eq!(r.plan_hits + r.plan_misses, 0, "fp32 path never touches BFP plans");
        let j = r.summary_json();
        assert!(j.get("plan_cache").is_some());
        assert_eq!(j.get("diverged").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn hbfp_run_reuses_dataset_and_warms_plan_cache() {
        let _guard = crate::util::fault::install(FaultInjector::none());
        let trainer = Trainer::with_context(BfpContext::from_env().with_threads(1));
        let fp = RunConfig::new("mlp-tinyimg-fp32", 4).with_seed(7);
        let hb = RunConfig::new("mlp-tinyimg-hbfp8_t8", 4).with_seed(7);
        let r_fp = trainer.run(&fp).unwrap();
        assert!(!r_fp.dataset_cache_hit, "first run generates");
        let r_hb = trainer.run(&hb).unwrap();
        assert!(r_hb.dataset_cache_hit, "same (dataset, seed): second run reuses");
        assert!(r_hb.plan_misses > 0, "plans built");
        assert!(r_hb.plan_hits > 0, "plans reused across steps");
        // identical init + identical batches: step-0 loss matches exactly
        // at both precisions only in value distribution, but both must
        // start from the same uniform-logits ballpark.
        assert!((r_fp.history.steps[0].loss - r_hb.history.steps[0].loss).abs() < 0.5);
    }
}
