//! Softmax cross-entropy over row-major logits — FP32 end to end (the
//! paper keeps the loss, like all non-dot-product math, out of BFP).
//! Numerically stabilized by the usual row-max shift; NaN logits
//! propagate to the loss untouched (the watchdog's signal).

use anyhow::{anyhow, Result};

/// Caches the softmax probabilities and targets from `forward` so
/// `backward` can emit `(p - onehot) / rows` without recomputation.
#[derive(Default)]
pub struct SoftmaxCrossEntropy {
    probs: Vec<f32>,
    targets: Vec<usize>,
    rows: usize,
    classes: usize,
}

impl SoftmaxCrossEntropy {
    pub fn new() -> SoftmaxCrossEntropy {
        SoftmaxCrossEntropy::default()
    }

    /// Mean cross-entropy (nats) and top-1 accuracy over `rows`
    /// examples of `classes` logits each.
    pub fn forward(
        &mut self,
        logits: &[f32],
        targets: &[i32],
        rows: usize,
        classes: usize,
    ) -> Result<(f32, f32)> {
        if logits.len() != rows * classes || targets.len() != rows {
            return Err(anyhow!(
                "softmax: logits {} targets {} vs rows {rows} classes {classes}",
                logits.len(),
                targets.len()
            ));
        }
        self.probs.clear();
        self.probs.reserve(rows * classes);
        self.targets.clear();
        self.rows = rows;
        self.classes = classes;
        let mut loss = 0.0f32;
        let mut correct = 0usize;
        for r in 0..rows {
            let y = usize::try_from(targets[r]).map_err(|_| anyhow!("negative target"))?;
            if y >= classes {
                return Err(anyhow!("target {y} out of {classes} classes"));
            }
            self.targets.push(y);
            let row = &logits[r * classes..(r + 1) * classes];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            let base = self.probs.len();
            for &v in row {
                let e = (v - max).exp();
                self.probs.push(e);
                sum += e;
            }
            for p in &mut self.probs[base..] {
                *p /= sum;
            }
            let mut pred = 0usize;
            for c in 1..classes {
                if row[c] > row[pred] {
                    pred = c;
                }
            }
            if pred == y {
                correct += 1;
            }
            // NaN probabilities propagate to the loss (the watchdog's
            // signal); only the p == 0 underflow is clamped. Note
            // `f32::max` would *swallow* NaN here (`NaN.max(x) == x`),
            // so the clamp targets the -ln(0) = +inf case instead.
            let nll = -self.probs[base + y].ln();
            loss += if nll.is_infinite() { -(1e-12f32).ln() } else { nll };
        }
        Ok((loss / rows as f32, correct as f32 / rows as f32))
    }

    /// Gradient at the logits of the matching `forward`:
    /// `(p - onehot) / rows`.
    pub fn backward(&self) -> Vec<f32> {
        let mut grad = self.probs.clone();
        for (r, &y) in self.targets.iter().enumerate() {
            grad[r * self.classes + y] -= 1.0;
        }
        let inv = 1.0 / self.rows as f32;
        for g in &mut grad {
            *g *= inv;
        }
        grad
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_logits_give_log_classes() {
        let mut l = SoftmaxCrossEntropy::new();
        let (loss, _) = l.forward(&[0.0; 8], &[1, 3], 2, 4).unwrap();
        assert!((loss - (4.0f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn gradient_sums_to_zero_per_row() {
        let mut l = SoftmaxCrossEntropy::new();
        l.forward(&[1.0, 2.0, 3.0, -1.0, 0.0, 1.0], &[0, 2], 2, 3).unwrap();
        let g = l.backward();
        for r in 0..2 {
            let s: f32 = g[r * 3..(r + 1) * 3].iter().sum();
            assert!(s.abs() < 1e-6, "softmax grad rows sum to zero");
        }
        // target entry is negative (p - 1 < 0)
        assert!(g[0] < 0.0 && g[5] < 0.0);
    }

    #[test]
    fn nan_logits_poison_the_loss() {
        let mut l = SoftmaxCrossEntropy::new();
        let (loss, _) = l.forward(&[f32::NAN, 0.0, 0.0, 0.0], &[0], 1, 4).unwrap();
        assert!(!loss.is_finite(), "hazards must reach the watchdog through the loss");
    }

    #[test]
    fn bad_targets_rejected() {
        let mut l = SoftmaxCrossEntropy::new();
        assert!(l.forward(&[0.0; 4], &[4], 1, 4).is_err());
        assert!(l.forward(&[0.0; 4], &[-1], 1, 4).is_err());
    }
}
