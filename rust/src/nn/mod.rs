//! Native training subsystem: forward+backward layers on top of
//! [`BfpContext`]/[`MatmulPlan`](crate::bfp::MatmulPlan) with the paper's
//! exact hybrid split (§4) — **every GEMM** (forward, weight-gradient,
//! input-gradient) runs through a BFP plan, while activations, biases,
//! optimizer state, and the loss stay FP32.
//!
//! ```text
//!           FP32 domain                      BFP domain (MatmulPlan)
//!   x ──────────────┐
//!                   ├─► [quantize_execute] ──► y = x·W ─► +bias ─► act
//!   W (FP32 master) ┘        ▲ W quantized per step (weight storage
//!                              conversion); x streams through the
//!                              fused A-side converter
//!   δ, xᵀ, Wᵀ  ──────► same path for dW = xᵀ·δ and dx = δ·Wᵀ
//! ```
//!
//! Layout:
//!
//! - [`NnContext`] (here): one [`BfpContext`] + one shared
//!   [`PlanCache`] + the current [`Precision`] + guard counters. Every
//!   layer GEMM goes through [`NnContext::gemm`] /
//!   [`NnContext::gemm_guarded`], so "verifiably routed through
//!   `MatmulPlan`" is a grep: layers never call a matmul directly.
//! - [`layer`]: the [`Layer`] trait (cached-activation backprop),
//!   [`Param`] (FP32 master weights + grad + momentum), `ReLU`/`Tanh`.
//! - [`linear`]: fully connected layer — three plan-cached GEMMs per
//!   step (fwd, dW, dx).
//! - [`embedding`]: token-table gather (a gather, not a dot product, so
//!   FP32 per the hybrid split).
//! - [`rnn`]: Elman recurrent block (tanh) with truncated-BPTT-free full
//!   backprop through the sequence — the char-LM's recurrent core.
//! - [`loss`]: softmax cross-entropy (FP32).
//! - [`optim`]: SGD / momentum on FP32 master weights.
//! - [`models`]: the [`Model`] trait plus [`Mlp`] and [`CharLm`].
//! - [`trainer`]: [`Trainer`] — combo parsing
//!   (`"mlp-cifar10like-hbfp8_t24"`), dataset-cache reuse across
//!   FP32-vs-HBFP pairs, and [`NnSession`], the
//!   [`FaultTolerantModel`](crate::coordinator::FaultTolerantModel)
//!   adapter that puts the whole loop under the `run_resilient`
//!   watchdog (checkpoints, rollback, width widening).
//!
//! Determinism: batches are a pure function of `(seed, step)`, weight
//! init uses [`Xorshift32`](crate::util::rng::Xorshift32) substreams,
//! the BFP kernels are bit-identical for any `HBFP_THREADS`, and the
//! FP32 reference GEMM is single-threaded — so whole loss curves are
//! bitwise reproducible at 1 or N threads (tested in `tests/nn_train.rs`).

pub mod embedding;
pub mod layer;
pub mod linear;
pub mod loss;
pub mod models;
pub mod optim;
pub mod rnn;
pub mod trainer;

use anyhow::{anyhow, Result};

use crate::bfp::{
    fp32_matmul, BfpContext, GuardAction, GuardPolicy, GuardStats, PlanCache, Rounding,
};
use crate::obs::{self, health, ObsRecorder};

pub use embedding::Embedding;
pub use layer::{Layer, Param, Relu, Tanh};
pub use linear::Linear;
pub use loss::SoftmaxCrossEntropy;
pub use models::{CharLm, Mlp, Model};
pub use optim::Optimizer;
pub use rnn::Rnn;
pub use trainer::{NnRunReport, NnSession, Trainer};

/// Numeric mode of one training session. `Fp32` is the paper's baseline
/// (every GEMM through the deterministic single-threaded FP32 kernel);
/// `Hbfp` runs every GEMM through BFP plans at `bits`-wide mantissas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp32,
    Hbfp { bits: u32 },
}

impl Precision {
    /// Parse a combo config token: `"fp32"`, `"hbfp8"`, `"hbfp12"`, …
    /// (a `_t{edge}` tile suffix is the caller's to strip first).
    pub fn parse(s: &str) -> Result<Precision> {
        if s == "fp32" {
            return Ok(Precision::Fp32);
        }
        if let Some(bits) = s.strip_prefix("hbfp") {
            let bits: u32 =
                bits.parse().map_err(|_| anyhow!("bad precision token {s:?}"))?;
            crate::bfp::tensor::check_width(bits)?;
            return Ok(Precision::Hbfp { bits });
        }
        Err(anyhow!("unknown precision token {s:?} (want fp32 or hbfp<bits>)"))
    }

    /// Mantissa width class in bits (32 = IEEE FP32).
    pub fn width_bits(self) -> u32 {
        match self {
            Precision::Fp32 => 32,
            Precision::Hbfp { bits } => bits,
        }
    }
}

/// Execution state shared by every layer of one training session: the
/// BFP policy context, one plan cache covering all layer shapes (its
/// hit/miss counters are the routing proof surfaced into the run's
/// metrics JSON), the current precision, and the guard counters.
///
/// Not `Sync` by design: one session owns one `NnContext`; parallelism
/// lives *inside* the BFP kernels (the context's worker pool), which is
/// what keeps curves bit-identical for any `HBFP_THREADS`.
pub struct NnContext {
    pub ctx: BfpContext,
    pub plans: PlanCache,
    pub precision: Precision,
    /// Guard-layer counters (scans, non-finite detections, FP32
    /// fallbacks) accumulated by [`NnContext::gemm_guarded`].
    pub guard: GuardStats,
    /// Sticky per-step flag: a guarded GEMM detected non-finite input
    /// since the last [`NnContext::take_tripped`].
    tripped: bool,
    /// Numeric-health timelines + per-step stage timings (populated only
    /// in `HBFP_OBS=full`; empty otherwise, and the trainer omits it).
    pub obs: ObsRecorder,
    /// Layer name the next health probe is attributed to (set by layers
    /// via [`NnContext::set_layer`]; maintained only in full mode).
    layer: String,
}

impl NnContext {
    /// Wrap a context for training. The guard action is forced to
    /// `Fp32Fallback`: a poisoned activation degrades that one GEMM to
    /// the IEEE kernel (and trips the sticky flag) instead of aborting
    /// mid-backprop, so the step driver decides what to do.
    pub fn new(ctx: BfpContext, precision: Precision) -> NnContext {
        let ctx = ctx.with_guard(GuardPolicy {
            action: GuardAction::Fp32Fallback,
            ..GuardPolicy::default()
        });
        NnContext {
            ctx,
            plans: PlanCache::new(64),
            precision,
            guard: GuardStats::new(),
            tripped: false,
            obs: ObsRecorder::new(),
            layer: String::new(),
        }
    }

    /// Name the layer whose GEMMs follow (health probes are aggregated
    /// per layer under this name). One relaxed load and nothing else
    /// below `full` mode — no allocation, no copy.
    #[inline]
    pub fn set_layer(&mut self, name: &str) {
        if !obs::full() {
            return;
        }
        self.layer.clear();
        self.layer.push_str(name);
    }

    /// C = A·B for row-major f32 A (`m x k`) and B (`k x n`) at the
    /// session precision. HBFP: B is quantized to packed BFP (the
    /// per-step weight-storage conversion, nearest-even), A streams
    /// through the plan's fused converter — both on the context tile
    /// grid, bit-identical for any thread count. FP32: the
    /// single-threaded IEEE reference kernel.
    pub fn gemm(&mut self, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Result<Vec<f32>> {
        check_operands(a, b, m, k, n)?;
        match self.precision {
            Precision::Fp32 => Ok(fp32_matmul(a, b, m, k, n)),
            Precision::Hbfp { bits } => {
                let t0 = self.obs.stage_start();
                let qb = self.ctx.quantize(b, k, n, bits, &mut Rounding::NearestEven)?;
                self.obs.stage_end("quantize", t0);
                let plan = self.plans.get_or_plan(&self.ctx, m, k, n, (bits, bits))?;
                let t1 = self.obs.stage_start();
                let out = plan.quantize_execute(a, &mut Rounding::NearestEven, &qb);
                self.obs.stage_end("gemm", t1);
                out
            }
        }
    }

    /// [`NnContext::gemm`] behind the numeric guard: the f32 `a` operand
    /// (activations entering the datapath) is scanned; a non-finite hit
    /// falls back to the FP32 kernel for this one GEMM, records guard
    /// counters, and sets the sticky tripped flag. Used on every
    /// data-facing forward GEMM.
    pub fn gemm_guarded(
        &mut self,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Result<Vec<f32>> {
        check_operands(a, b, m, k, n)?;
        match self.precision {
            Precision::Fp32 => Ok(fp32_matmul(a, b, m, k, n)),
            Precision::Hbfp { bits } => {
                let t0 = self.obs.stage_start();
                let qb = self.ctx.quantize(b, k, n, bits, &mut Rounding::NearestEven)?;
                self.obs.stage_end("quantize", t0);
                if obs::full() {
                    // Probe the weight-side quantization the forward pass
                    // just produced: read-only vs the f32 source, so no
                    // RNG draw and no perturbation of the datapath.
                    let h = health::tensor_health(b, &qb);
                    let layer = if self.layer.is_empty() { "unnamed" } else { self.layer.as_str() };
                    self.obs.record_layer(layer, h);
                }
                let plan = self.plans.get_or_plan(&self.ctx, m, k, n, (bits, bits))?;
                let t1 = self.obs.stage_start();
                let mut out = vec![0.0f32; plan.out_len()];
                let outcome = plan.quantize_execute_guarded(
                    a,
                    &mut Rounding::NearestEven,
                    &qb,
                    &mut out,
                    Some(&self.guard),
                )?;
                self.obs.stage_end("gemm", t1);
                if outcome.tripped {
                    self.tripped = true;
                }
                Ok(out)
            }
        }
    }

    /// Read-and-clear the sticky guard flag (the step driver polls this
    /// once per step to turn a poisoned batch into a watchdog hazard).
    pub fn take_tripped(&mut self) -> bool {
        std::mem::take(&mut self.tripped)
    }
}

fn check_operands(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Result<()> {
    if a.len() != m * k {
        return Err(anyhow!("gemm: a len {} != {m}x{k}", a.len()));
    }
    if b.len() != k * n {
        return Err(anyhow!("gemm: b len {} != {k}x{n}", b.len()));
    }
    Ok(())
}

/// Row-major transpose (FP32 host op — exact, single-threaded, so it
/// never perturbs determinism). The dW and dx GEMMs consume these.
pub fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(src.len(), rows * cols);
    let mut out = vec![0.0f32; src.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = src[r * cols + c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_parsing() {
        assert_eq!(Precision::parse("fp32").unwrap(), Precision::Fp32);
        assert_eq!(Precision::parse("hbfp8").unwrap(), Precision::Hbfp { bits: 8 });
        assert_eq!(Precision::parse("hbfp12").unwrap(), Precision::Hbfp { bits: 12 });
        assert!(Precision::parse("hbfp99").is_err(), "width class out of range");
        assert!(Precision::parse("int8").is_err());
        assert_eq!(Precision::Fp32.width_bits(), 32);
        assert_eq!(Precision::Hbfp { bits: 8 }.width_bits(), 8);
    }

    #[test]
    fn transpose_round_trips() {
        let a: Vec<f32> = (0..6).map(|v| v as f32).collect();
        let t = transpose(&a, 2, 3);
        assert_eq!(t, vec![0.0, 3.0, 1.0, 4.0, 2.0, 5.0]);
        assert_eq!(transpose(&t, 3, 2), a);
    }

    #[test]
    fn gemm_shapes_validated() {
        let mut nc = NnContext::new(BfpContext::from_env(), Precision::Fp32);
        assert!(nc.gemm(&[1.0; 4], &[1.0; 4], 2, 2, 2).is_ok());
        assert!(nc.gemm(&[1.0; 3], &[1.0; 4], 2, 2, 2).is_err());
        assert!(nc.gemm(&[1.0; 4], &[1.0; 3], 2, 2, 2).is_err());
    }
}
