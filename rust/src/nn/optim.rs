//! Optimizers over FP32 master weights. Per the hybrid split the
//! optimizer never sees BFP: gradients arrive FP32 (dequantized GEMM
//! outputs), state (momentum) is FP32, and the updated master weights
//! are re-quantized at the next step's GEMMs.

use super::layer::Param;

#[derive(Debug, Clone, Copy)]
pub enum Optimizer {
    Sgd,
    /// Classical momentum: `v = mu·v + g; w -= lr·v`.
    Momentum { mu: f32 },
}

impl Optimizer {
    /// Apply one update to `p` and clear its gradient accumulator.
    pub fn update(&self, p: &mut Param, lr: f32) {
        match *self {
            Optimizer::Sgd => {
                for (w, g) in p.w.iter_mut().zip(&p.g) {
                    *w -= lr * g;
                }
            }
            Optimizer::Momentum { mu } => {
                for i in 0..p.w.len() {
                    p.v[i] = mu * p.v[i] + p.g[i];
                    p.w[i] -= lr * p.v[i];
                }
            }
        }
        p.zero_grad();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_steps_downhill_and_clears_grads() {
        let mut p = Param::new("p", vec![2], vec![1.0, -1.0]);
        p.g = vec![0.5, -0.5];
        Optimizer::Sgd.update(&mut p, 0.1);
        assert_eq!(p.w, vec![0.95, -0.95]);
        assert_eq!(p.g, vec![0.0, 0.0]);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut p = Param::new("p", vec![1], vec![0.0]);
        let opt = Optimizer::Momentum { mu: 0.5 };
        p.g = vec![1.0];
        opt.update(&mut p, 1.0);
        assert_eq!(p.v, vec![1.0]);
        assert_eq!(p.w, vec![-1.0]);
        p.g = vec![1.0];
        opt.update(&mut p, 1.0);
        assert_eq!(p.v, vec![1.5], "v = 0.5*1 + 1");
        assert_eq!(p.w, vec![-2.5]);
    }
}
