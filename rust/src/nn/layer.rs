//! The [`Layer`] trait (cached-activation backprop) plus the FP32
//! pointwise activations. A layer caches whatever its backward pass
//! needs during `forward` (inputs, activations, masks) — the standard
//! autodiff tape, flattened into the layer objects because the graphs
//! here are straight lines.

use anyhow::Result;

use super::NnContext;
use crate::util::rng::Xorshift32;

/// One trainable tensor: FP32 master weights `w`, gradient accumulator
/// `g`, and momentum buffer `v` — all FP32 per the hybrid split (only
/// dot products are BFP; the optimizer state never quantizes).
#[derive(Debug, Clone)]
pub struct Param {
    pub name: String,
    pub shape: Vec<usize>,
    pub w: Vec<f32>,
    pub g: Vec<f32>,
    pub v: Vec<f32>,
}

impl Param {
    pub fn new(name: &str, shape: Vec<usize>, w: Vec<f32>) -> Param {
        debug_assert_eq!(w.len(), shape.iter().product::<usize>());
        let n = w.len();
        Param { name: name.to_string(), shape, w, g: vec![0.0; n], v: vec![0.0; n] }
    }

    /// Uniform init in `±limit`, drawn from a deterministic
    /// [`Xorshift32`] substream so init is independent of construction
    /// order elsewhere.
    pub fn init_uniform(name: &str, shape: Vec<usize>, limit: f32, rng: &mut Xorshift32) -> Param {
        let n = shape.iter().product::<usize>();
        let w = (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * limit).collect();
        Param::new(name, shape, w)
    }

    pub fn zeros(name: &str, shape: Vec<usize>) -> Param {
        let n = shape.iter().product::<usize>();
        Param::new(name, shape, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        self.w.len()
    }

    pub fn is_empty(&self) -> bool {
        self.w.is_empty()
    }

    pub fn zero_grad(&mut self) {
        self.g.fill(0.0);
    }
}

/// A differentiable module over row-major `[rows, dim]` activations.
/// `backward` consumes the upstream gradient at this layer's output and
/// returns the gradient at its input, accumulating parameter gradients
/// into [`Param::g`] along the way. `backward` must follow the
/// `forward` whose activations it replays.
pub trait Layer {
    fn name(&self) -> &str;
    fn forward(&mut self, nc: &mut NnContext, x: &[f32], rows: usize) -> Result<Vec<f32>>;
    fn backward(&mut self, nc: &mut NnContext, dy: &[f32], rows: usize) -> Result<Vec<f32>>;
    /// Trainable tensors (read view, for checkpointing).
    fn params(&self) -> Vec<&Param> {
        Vec::new()
    }
    /// Trainable tensors (mutable, for the optimizer / checkpoint restore).
    fn params_mut(&mut self) -> Vec<&mut Param> {
        Vec::new()
    }
}

/// Rectifier. NaN inputs map to 0 like any non-positive value — which is
/// why hazard detection lives at the GEMM guard (the scan in
/// [`NnContext::gemm_guarded`]) and not on loss NaN-ness alone: a
/// poisoned activation does not survive a ReLU.
pub struct Relu {
    mask: Vec<bool>,
}

impl Relu {
    pub fn new() -> Relu {
        Relu { mask: Vec::new() }
    }
}

impl Default for Relu {
    fn default() -> Self {
        Self::new()
    }
}

impl Layer for Relu {
    fn name(&self) -> &str {
        "relu"
    }

    fn forward(&mut self, _nc: &mut NnContext, x: &[f32], _rows: usize) -> Result<Vec<f32>> {
        self.mask.clear();
        self.mask.extend(x.iter().map(|&v| v > 0.0));
        Ok(x.iter().map(|&v| if v > 0.0 { v } else { 0.0 }).collect())
    }

    fn backward(&mut self, _nc: &mut NnContext, dy: &[f32], _rows: usize) -> Result<Vec<f32>> {
        debug_assert_eq!(dy.len(), self.mask.len());
        Ok(dy.iter().zip(&self.mask).map(|(&d, &m)| if m { d } else { 0.0 }).collect())
    }
}

/// Hyperbolic tangent, caching the *output* (`d tanh = 1 - y²`).
pub struct Tanh {
    y: Vec<f32>,
}

impl Tanh {
    pub fn new() -> Tanh {
        Tanh { y: Vec::new() }
    }
}

impl Default for Tanh {
    fn default() -> Self {
        Self::new()
    }
}

impl Layer for Tanh {
    fn name(&self) -> &str {
        "tanh"
    }

    fn forward(&mut self, _nc: &mut NnContext, x: &[f32], _rows: usize) -> Result<Vec<f32>> {
        self.y = x.iter().map(|v| v.tanh()).collect();
        Ok(self.y.clone())
    }

    fn backward(&mut self, _nc: &mut NnContext, dy: &[f32], _rows: usize) -> Result<Vec<f32>> {
        debug_assert_eq!(dy.len(), self.y.len());
        Ok(dy.iter().zip(&self.y).map(|(&d, &y)| d * (1.0 - y * y)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::BfpContext;
    use crate::nn::Precision;

    #[test]
    fn relu_masks_and_routes_gradient() {
        let mut nc = NnContext::new(BfpContext::from_env(), Precision::Fp32);
        let mut r = Relu::new();
        let y = r.forward(&mut nc, &[-1.0, 0.0, 2.0], 1).unwrap();
        assert_eq!(y, vec![0.0, 0.0, 2.0]);
        let dx = r.backward(&mut nc, &[5.0, 5.0, 5.0], 1).unwrap();
        assert_eq!(dx, vec![0.0, 0.0, 5.0]);
    }

    #[test]
    fn tanh_gradient_uses_cached_output() {
        let mut nc = NnContext::new(BfpContext::from_env(), Precision::Fp32);
        let mut t = Tanh::new();
        let y = t.forward(&mut nc, &[0.5], 1).unwrap();
        let dx = t.backward(&mut nc, &[1.0], 1).unwrap();
        assert!((dx[0] - (1.0 - y[0] * y[0])).abs() < 1e-7);
    }
}
