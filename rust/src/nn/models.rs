//! Trainable model graphs: the [`Model`] trait the session drives, plus
//! the two workloads the paper's claim is demonstrated on — an [`Mlp`]
//! over the synthetic image datasets (Figure 3's loss-tracking shape)
//! and a [`CharLm`] (embedding → Elman RNN → tied-free linear head) over
//! the synthetic Markov corpus (the Table 3 workload class).

use anyhow::{anyhow, Result};

use super::embedding::Embedding;
use super::layer::{Layer, Param, Relu};
use super::linear::Linear;
use super::loss::SoftmaxCrossEntropy;
use super::rnn::Rnn;
use super::NnContext;
use crate::runtime::HostTensor;
use crate::util::rng::Xorshift32;

fn as_i32(t: &HostTensor) -> Result<&[i32]> {
    match t {
        HostTensor::I32(v, _) => Ok(v),
        other => Err(anyhow!("expected i32 tensor, got {:?}", other.shape())),
    }
}

/// One trainable workload: forward+backward on a batch (gradients
/// accumulate into params; the caller owns the optimizer step) and a
/// forward-only eval. Both take batches in the `data/` pipeline's
/// [`HostTensor`] layouts.
pub trait Model {
    /// Forward + backward; returns `(mean loss, accuracy)`. When the
    /// loss is non-finite the backward pass is skipped (the standard
    /// mixed-precision overflow-skip), leaving gradients untouched.
    fn train_batch(
        &mut self,
        nc: &mut NnContext,
        x: &HostTensor,
        y: &HostTensor,
    ) -> Result<(f32, f32)>;
    /// Forward only; returns `(mean loss, error in [0,1])`.
    fn eval_batch(
        &mut self,
        nc: &mut NnContext,
        x: &HostTensor,
        y: &HostTensor,
    ) -> Result<(f32, f32)>;
    fn params(&self) -> Vec<&Param>;
    fn params_mut(&mut self) -> Vec<&mut Param>;
}

/// Multi-layer perceptron over flattened image batches
/// (`[B, hw, hw, ch]` → `[B, in_dim]`): Linear → ReLU → … → Linear.
pub struct Mlp {
    layers: Vec<Box<dyn Layer>>,
    loss: SoftmaxCrossEntropy,
    pub in_dim: usize,
    pub classes: usize,
}

impl Mlp {
    pub fn new(in_dim: usize, hidden: &[usize], classes: usize, seed: u32) -> Mlp {
        let mut rng = Xorshift32::substream(seed, 0x6e6e);
        let mut layers: Vec<Box<dyn Layer>> = Vec::new();
        let mut prev = in_dim;
        for (i, &h) in hidden.iter().enumerate() {
            layers.push(Box::new(Linear::new(&format!("fc{i}"), prev, h, &mut rng)));
            layers.push(Box::new(Relu::new()));
            prev = h;
        }
        layers.push(Box::new(Linear::new(
            &format!("fc{}", hidden.len()),
            prev,
            classes,
            &mut rng,
        )));
        Mlp { layers, loss: SoftmaxCrossEntropy::new(), in_dim, classes }
    }

    fn logits(&mut self, nc: &mut NnContext, x: &HostTensor) -> Result<(Vec<f32>, usize)> {
        let xs = x.as_f32()?;
        let rows = *x.shape().first().ok_or_else(|| anyhow!("scalar batch"))?;
        if rows == 0 || xs.len() != rows * self.in_dim {
            return Err(anyhow!("mlp: batch {} x {} != input {}", rows, self.in_dim, xs.len()));
        }
        let mut act = xs.to_vec();
        for layer in &mut self.layers {
            act = layer.forward(nc, &act, rows)?;
        }
        Ok((act, rows))
    }
}

impl Model for Mlp {
    fn train_batch(
        &mut self,
        nc: &mut NnContext,
        x: &HostTensor,
        y: &HostTensor,
    ) -> Result<(f32, f32)> {
        let t_fwd = nc.obs.stage_start();
        let fwd = {
            let _span = crate::obs::trace::span("nn.mlp.fwd");
            self.logits(nc, x)
        };
        nc.obs.stage_end("fwd", t_fwd);
        let (logits, rows) = fwd?;
        let (loss, acc) = self.loss.forward(&logits, as_i32(y)?, rows, self.classes)?;
        if !loss.is_finite() {
            return Ok((loss, acc));
        }
        let t_bwd = nc.obs.stage_start();
        {
            let _span = crate::obs::trace::span("nn.mlp.bwd");
            let mut grad = self.loss.backward();
            for layer in self.layers.iter_mut().rev() {
                grad = layer.backward(nc, &grad, rows)?;
            }
        }
        nc.obs.stage_end("bwd", t_bwd);
        Ok((loss, acc))
    }

    fn eval_batch(
        &mut self,
        nc: &mut NnContext,
        x: &HostTensor,
        y: &HostTensor,
    ) -> Result<(f32, f32)> {
        let (logits, rows) = self.logits(nc, x)?;
        let (loss, acc) = self.loss.forward(&logits, as_i32(y)?, rows, self.classes)?;
        Ok((loss, 1.0 - acc))
    }

    fn params(&self) -> Vec<&Param> {
        self.layers.iter().flat_map(|l| l.params()).collect()
    }

    fn params_mut(&mut self) -> Vec<&mut Param> {
        self.layers.iter_mut().flat_map(|l| l.params_mut()).collect()
    }
}

/// Character language model: embedding gather (FP32) → Elman RNN →
/// linear vocab head, trained next-token over `[B, T]` token windows.
/// Activations run timestep-major internally so each timestep's GEMM
/// operand is contiguous.
pub struct CharLm {
    embed: Embedding,
    rnn: Rnn,
    head: Linear,
    loss: SoftmaxCrossEntropy,
    pub vocab: usize,
}

impl CharLm {
    pub fn new(vocab: usize, embed_dim: usize, hidden: usize, seed: u32) -> CharLm {
        let mut rng = Xorshift32::substream(seed, 0x1a6d);
        CharLm {
            embed: Embedding::new("embed", vocab, embed_dim, &mut rng),
            rnn: Rnn::new("rnn", embed_dim, hidden, &mut rng),
            head: Linear::new("head", hidden, vocab, &mut rng),
            loss: SoftmaxCrossEntropy::new(),
            vocab,
        }
    }

    /// Reorder a `[B, T]` batch-major token tensor to timestep-major
    /// (`out[t*B + b]`), the layout the recurrence consumes.
    fn timestep_major(tokens: &[i32], batch: usize, t_len: usize) -> Vec<i32> {
        let mut out = vec![0i32; tokens.len()];
        for b in 0..batch {
            for t in 0..t_len {
                out[t * batch + b] = tokens[b * t_len + t];
            }
        }
        out
    }

    fn logits(
        &mut self,
        nc: &mut NnContext,
        x: &HostTensor,
    ) -> Result<(Vec<f32>, usize, usize)> {
        let xs = as_i32(x)?;
        let shape = x.shape();
        if shape.len() != 2 {
            return Err(anyhow!("charlm: want [B, T] tokens, got {shape:?}"));
        }
        let (batch, t_len) = (shape[0], shape[1]);
        if batch == 0 || t_len == 0 {
            return Err(anyhow!("charlm: empty batch"));
        }
        let tokens_tm = Self::timestep_major(xs, batch, t_len);
        let emb = self.embed.forward(&tokens_tm)?;
        let h = self.rnn.forward(nc, &emb, batch, t_len)?;
        let logits = self.head.forward(nc, &h, t_len * batch)?;
        Ok((logits, batch, t_len))
    }
}

impl Model for CharLm {
    fn train_batch(
        &mut self,
        nc: &mut NnContext,
        x: &HostTensor,
        y: &HostTensor,
    ) -> Result<(f32, f32)> {
        let t_fwd = nc.obs.stage_start();
        let fwd = {
            let _span = crate::obs::trace::span("nn.charlm.fwd");
            self.logits(nc, x)
        };
        nc.obs.stage_end("fwd", t_fwd);
        let (logits, batch, t_len) = fwd?;
        let targets_tm = Self::timestep_major(as_i32(y)?, batch, t_len);
        let (loss, acc) = self.loss.forward(&logits, &targets_tm, t_len * batch, self.vocab)?;
        if !loss.is_finite() {
            return Ok((loss, acc));
        }
        let t_bwd = nc.obs.stage_start();
        {
            let _span = crate::obs::trace::span("nn.charlm.bwd");
            let grad = self.loss.backward();
            let grad = self.head.backward(nc, &grad, t_len * batch)?;
            let grad = self.rnn.backward(nc, &grad)?;
            self.embed.backward(&grad)?;
        }
        nc.obs.stage_end("bwd", t_bwd);
        Ok((loss, acc))
    }

    fn eval_batch(
        &mut self,
        nc: &mut NnContext,
        x: &HostTensor,
        y: &HostTensor,
    ) -> Result<(f32, f32)> {
        let (logits, batch, t_len) = self.logits(nc, x)?;
        let targets_tm = Self::timestep_major(as_i32(y)?, batch, t_len);
        let (loss, acc) = self.loss.forward(&logits, &targets_tm, t_len * batch, self.vocab)?;
        Ok((loss, 1.0 - acc))
    }

    fn params(&self) -> Vec<&Param> {
        let mut ps = vec![&self.embed.table];
        ps.extend(self.rnn.params());
        ps.extend(self.head.params());
        ps
    }

    fn params_mut(&mut self) -> Vec<&mut Param> {
        let mut ps = vec![&mut self.embed.table];
        ps.extend(self.rnn.params_mut());
        ps.extend(self.head.params_mut());
        ps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::BfpContext;
    use crate::nn::{Optimizer, Precision};

    #[test]
    fn mlp_learns_a_linearly_separable_toy() {
        let mut nc = NnContext::new(BfpContext::from_env(), Precision::Fp32);
        let mut m = Mlp::new(4, &[8], 2, 3);
        let opt = Optimizer::Momentum { mu: 0.9 };
        // class = sign of feature 0
        let x = HostTensor::F32(
            vec![
                1.0, 0.1, -0.2, 0.0, //
                -1.0, 0.2, 0.1, 0.3, //
                0.8, -0.3, 0.2, -0.1, //
                -0.9, 0.0, -0.1, 0.2,
            ],
            vec![4, 4],
        );
        let y = HostTensor::I32(vec![0, 1, 0, 1], vec![4]);
        let (first, _) = m.train_batch(&mut nc, &x, &y).unwrap();
        for p in m.params_mut() {
            opt.update(p, 0.1);
        }
        let mut last = first;
        for _ in 0..60 {
            let (l, _) = m.train_batch(&mut nc, &x, &y).unwrap();
            for p in m.params_mut() {
                opt.update(p, 0.1);
            }
            last = l;
        }
        assert!(last < first * 0.3, "loss {first} -> {last} should collapse on 4 points");
        let (_, err) = m.eval_batch(&mut nc, &x, &y).unwrap();
        assert_eq!(err, 0.0);
    }

    #[test]
    fn charlm_shapes_and_param_census() {
        let mut nc = NnContext::new(BfpContext::from_env(), Precision::Fp32);
        let mut m = CharLm::new(8, 4, 6, 3);
        assert_eq!(m.params().len(), 1 + 3 + 2, "embed + rnn(wx,wh,b) + head(w,b)");
        let x = HostTensor::I32(vec![1, 2, 3, 4, 5, 6], vec![2, 3]);
        let y = HostTensor::I32(vec![2, 3, 4, 5, 6, 7], vec![2, 3]);
        let (loss, _) = m.train_batch(&mut nc, &x, &y).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!(m.params().iter().any(|p| p.g.iter().any(|&g| g != 0.0)), "grads flowed");
    }

    #[test]
    fn timestep_major_reorders() {
        let tm = CharLm::timestep_major(&[1, 2, 3, 4, 5, 6], 2, 3);
        assert_eq!(tm, vec![1, 4, 2, 5, 3, 6]);
    }
}
