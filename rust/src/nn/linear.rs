//! Fully connected layer — the paper's workhorse. Three GEMMs per
//! training step, every one through a BFP plan (under HBFP):
//!
//! - forward:  `y[B,out]  = x[B,in] · W[in,out] (+ b)`
//! - weight-gradient: `dW[in,out] = xᵀ[in,B] · δ[B,out]`
//! - input-gradient:  `dx[B,in]  = δ[B,out] · Wᵀ[out,in]`
//!
//! All three shapes land in the session's shared
//! [`PlanCache`](crate::bfp::PlanCache), so after the first step every
//! GEMM is a cache hit; the per-step BFP work is the weight-storage
//! conversion (quantizing `W`/`Wᵀ` from the FP32 master) plus the fused
//! A-side converter inside the plan execution. Bias add, like all
//! non-dot-product math, stays FP32.

use anyhow::{anyhow, Result};

use super::layer::{Layer, Param};
use super::{transpose, NnContext};
use crate::util::rng::Xorshift32;

pub struct Linear {
    pub w: Param,
    pub b: Param,
    pub in_dim: usize,
    pub out_dim: usize,
    cached_x: Vec<f32>,
}

impl Linear {
    /// Glorot-uniform weight init, zero bias.
    pub fn new(name: &str, in_dim: usize, out_dim: usize, rng: &mut Xorshift32) -> Linear {
        let limit = (6.0 / (in_dim + out_dim) as f32).sqrt();
        Linear {
            w: Param::init_uniform(&format!("{name}.w"), vec![in_dim, out_dim], limit, rng),
            b: Param::zeros(&format!("{name}.b"), vec![out_dim]),
            in_dim,
            out_dim,
            cached_x: Vec::new(),
        }
    }
}

impl Layer for Linear {
    fn name(&self) -> &str {
        &self.w.name
    }

    fn forward(&mut self, nc: &mut NnContext, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        if x.len() != rows * self.in_dim {
            return Err(anyhow!(
                "{}: input len {} != {rows}x{}",
                self.w.name,
                x.len(),
                self.in_dim
            ));
        }
        // Data-facing GEMM: guarded, so a poisoned batch is detected at
        // the datapath boundary (see NnContext::gemm_guarded).
        nc.set_layer(&self.w.name);
        let _span = crate::obs::trace::span("nn.linear.fwd_gemm");
        let mut y = nc.gemm_guarded(x, &self.w.w, rows, self.in_dim, self.out_dim)?;
        for r in 0..rows {
            let row = &mut y[r * self.out_dim..(r + 1) * self.out_dim];
            for (yv, bv) in row.iter_mut().zip(&self.b.w) {
                *yv += bv;
            }
        }
        self.cached_x = x.to_vec();
        Ok(y)
    }

    fn backward(&mut self, nc: &mut NnContext, dy: &[f32], rows: usize) -> Result<Vec<f32>> {
        if dy.len() != rows * self.out_dim {
            return Err(anyhow!(
                "{}: grad len {} != {rows}x{}",
                self.w.name,
                dy.len(),
                self.out_dim
            ));
        }
        if self.cached_x.len() != rows * self.in_dim {
            return Err(anyhow!("{}: backward before forward", self.w.name));
        }
        // dW = xᵀ · δ  (BFP GEMM, k = batch: the skinny-k shape)
        nc.set_layer(&self.w.name);
        let _span = crate::obs::trace::span("nn.linear.bwd_gemms");
        let xt = transpose(&self.cached_x, rows, self.in_dim);
        let dw = nc.gemm(&xt, dy, self.in_dim, rows, self.out_dim)?;
        for (g, d) in self.w.g.iter_mut().zip(&dw) {
            *g += d;
        }
        // db = column-sum of δ (FP32 reduction)
        for r in 0..rows {
            let row = &dy[r * self.out_dim..(r + 1) * self.out_dim];
            for (g, d) in self.b.g.iter_mut().zip(row) {
                *g += d;
            }
        }
        // dx = δ · Wᵀ  (BFP GEMM)
        let wt = transpose(&self.w.w, self.in_dim, self.out_dim);
        nc.gemm(dy, &wt, rows, self.out_dim, self.in_dim)
    }

    fn params(&self) -> Vec<&Param> {
        vec![&self.w, &self.b]
    }

    fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.w, &mut self.b]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::{BfpContext, TileSize};
    use crate::nn::Precision;

    #[test]
    fn forward_matches_hand_computation_fp32() {
        let mut rng = Xorshift32::new(1);
        let mut l = Linear::new("fc", 2, 3, &mut rng);
        l.w.w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // [2,3]
        l.b.w = vec![0.5, -0.5, 0.0];
        let mut nc = NnContext::new(BfpContext::from_env(), Precision::Fp32);
        let y = l.forward(&mut nc, &[1.0, 1.0], 1).unwrap();
        assert_eq!(y, vec![5.5, 6.5, 9.0]);
    }

    #[test]
    fn hbfp_forward_populates_plan_cache() {
        let mut rng = Xorshift32::new(2);
        let mut l = Linear::new("fc", 6, 4, &mut rng);
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8));
        let mut nc = NnContext::new(ctx, Precision::Hbfp { bits: 8 });
        let x: Vec<f32> = (0..12).map(|v| v as f32 * 0.1).collect();
        l.forward(&mut nc, &x, 2).unwrap();
        let dy = vec![0.1f32; 8];
        l.backward(&mut nc, &dy, 2).unwrap();
        // fwd + dW + dx = three distinct shapes planned
        assert_eq!(nc.plans.misses(), 3);
        l.forward(&mut nc, &x, 2).unwrap();
        l.backward(&mut nc, &dy, 2).unwrap();
        assert_eq!(nc.plans.misses(), 3, "second step must be all hits");
        assert_eq!(nc.plans.hits(), 3);
    }

    #[test]
    fn shape_mismatches_error() {
        let mut rng = Xorshift32::new(3);
        let mut l = Linear::new("fc", 4, 2, &mut rng);
        let mut nc = NnContext::new(BfpContext::from_env(), Precision::Fp32);
        assert!(l.forward(&mut nc, &[0.0; 7], 2).is_err());
        assert!(l.backward(&mut nc, &[0.0; 4], 2).is_err(), "backward before forward");
    }
}
