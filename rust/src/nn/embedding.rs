//! Token embedding table. Lookup is a gather and its backward a
//! scatter-add — no dot products — so per the paper's hybrid split the
//! whole layer stays FP32. (The GEMMs downstream of the embedding are
//! where BFP engages.)

use anyhow::{anyhow, Result};

use super::layer::Param;
use crate::util::rng::Xorshift32;

pub struct Embedding {
    pub table: Param,
    pub vocab: usize,
    pub dim: usize,
    cached_tokens: Vec<usize>,
}

impl Embedding {
    pub fn new(name: &str, vocab: usize, dim: usize, rng: &mut Xorshift32) -> Embedding {
        Embedding {
            table: Param::init_uniform(&format!("{name}.table"), vec![vocab, dim], 0.1, rng),
            vocab,
            dim,
            cached_tokens: Vec::new(),
        }
    }

    /// Gather rows: `out[i] = table[tokens[i]]`, shape `[len, dim]`.
    pub fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(tokens.len() * self.dim);
        self.cached_tokens.clear();
        for &t in tokens {
            let t = usize::try_from(t).map_err(|_| anyhow!("negative token id {t}"))?;
            if t >= self.vocab {
                return Err(anyhow!("token id {t} out of vocab {}", self.vocab));
            }
            self.cached_tokens.push(t);
            out.extend_from_slice(&self.table.w[t * self.dim..(t + 1) * self.dim]);
        }
        Ok(out)
    }

    /// Scatter-add the upstream gradient back into the table rows that
    /// were gathered by the matching `forward`.
    pub fn backward(&mut self, dy: &[f32]) -> Result<()> {
        if dy.len() != self.cached_tokens.len() * self.dim {
            return Err(anyhow!(
                "embedding grad len {} != {}x{}",
                dy.len(),
                self.cached_tokens.len(),
                self.dim
            ));
        }
        for (i, &t) in self.cached_tokens.iter().enumerate() {
            let src = &dy[i * self.dim..(i + 1) * self.dim];
            let dst = &mut self.table.g[t * self.dim..(t + 1) * self.dim];
            for (g, d) in dst.iter_mut().zip(src) {
                *g += d;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_and_scatter_add() {
        let mut rng = Xorshift32::new(5);
        let mut e = Embedding::new("emb", 4, 2, &mut rng);
        e.table.w = vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        let out = e.forward(&[2, 0, 2]).unwrap();
        assert_eq!(out, vec![4.0, 5.0, 0.0, 1.0, 4.0, 5.0]);
        e.backward(&[1.0, 1.0, 0.5, 0.5, 1.0, 1.0]).unwrap();
        // token 2 gathered twice: grads accumulate
        assert_eq!(&e.table.g[4..6], &[2.0, 2.0]);
        assert_eq!(&e.table.g[0..2], &[0.5, 0.5]);
    }

    #[test]
    fn out_of_vocab_rejected() {
        let mut rng = Xorshift32::new(6);
        let mut e = Embedding::new("emb", 4, 2, &mut rng);
        assert!(e.forward(&[4]).is_err());
        assert!(e.forward(&[-1]).is_err());
    }
}
