//! Elman recurrent block for the char-LM: `h_t = tanh(x_t·Wx + h_{t-1}·Wh + b)`.
//!
//! Activations are timestep-major (`[T*B, dim]`, t outermost) so each
//! timestep's `[B, dim]` slab is contiguous for the GEMMs. Per step the
//! block runs `2T` forward GEMMs and `4T` backward GEMMs (dWx, dWh,
//! dh-carry, dx per timestep) — every one through the session's BFP
//! plan cache, where the four distinct shapes are warm after the first
//! timestep. Full backprop-through-time over the window (no
//! truncation: the window *is* the truncation, as in the paper's LSTM
//! training with fixed-length sequences); tanh, the bias, and all
//! gradient accumulation stay FP32.

use anyhow::{anyhow, Result};

use super::layer::Param;
use super::{transpose, NnContext};
use crate::util::rng::Xorshift32;

pub struct Rnn {
    pub wx: Param,
    pub wh: Param,
    pub b: Param,
    pub in_dim: usize,
    pub hidden: usize,
    cached_x: Vec<f32>,
    cached_h: Vec<f32>,
    batch: usize,
    t_len: usize,
}

impl Rnn {
    pub fn new(name: &str, in_dim: usize, hidden: usize, rng: &mut Xorshift32) -> Rnn {
        let lx = (6.0 / (in_dim + hidden) as f32).sqrt();
        let lh = (6.0 / (2 * hidden) as f32).sqrt();
        Rnn {
            wx: Param::init_uniform(&format!("{name}.wx"), vec![in_dim, hidden], lx, rng),
            wh: Param::init_uniform(&format!("{name}.wh"), vec![hidden, hidden], lh, rng),
            b: Param::zeros(&format!("{name}.b"), vec![hidden]),
            in_dim,
            hidden,
            cached_x: Vec::new(),
            cached_h: Vec::new(),
            batch: 0,
            t_len: 0,
        }
    }

    /// `x`: timestep-major `[T*B, in]`; returns all hidden states
    /// `[T*B, hidden]`, timestep-major. The initial hidden state is zero
    /// (stateless windows, matching the dataset's independent slices).
    pub fn forward(
        &mut self,
        nc: &mut NnContext,
        x: &[f32],
        batch: usize,
        t_len: usize,
    ) -> Result<Vec<f32>> {
        if x.len() != t_len * batch * self.in_dim {
            return Err(anyhow!(
                "{}: input len {} != {t_len}x{batch}x{}",
                self.wx.name,
                x.len(),
                self.in_dim
            ));
        }
        let _span = crate::obs::trace::span("nn.rnn.fwd");
        let (bsz, hid) = (batch, self.hidden);
        let mut all_h = Vec::with_capacity(t_len * bsz * hid);
        let mut h_prev = vec![0.0f32; bsz * hid];
        for t in 0..t_len {
            let xt = &x[t * bsz * self.in_dim..(t + 1) * bsz * self.in_dim];
            // Data-facing GEMM guarded; the recurrent GEMM consumes our
            // own (finite) hidden state.
            nc.set_layer(&self.wx.name);
            let mut pre = nc.gemm_guarded(xt, &self.wx.w, bsz, self.in_dim, hid)?;
            nc.set_layer(&self.wh.name);
            let rec = nc.gemm(&h_prev, &self.wh.w, bsz, hid, hid)?;
            for i in 0..pre.len() {
                pre[i] = (pre[i] + rec[i] + self.b.w[i % hid]).tanh();
            }
            all_h.extend_from_slice(&pre);
            h_prev = pre;
        }
        self.cached_x = x.to_vec();
        self.cached_h = all_h.clone();
        self.batch = batch;
        self.t_len = t_len;
        Ok(all_h)
    }

    /// BPTT: `dy` is the gradient at every hidden state (`[T*B, hidden]`,
    /// timestep-major); returns the gradient at the inputs.
    pub fn backward(&mut self, nc: &mut NnContext, dy: &[f32]) -> Result<Vec<f32>> {
        let (bsz, tl, ind, hid) = (self.batch, self.t_len, self.in_dim, self.hidden);
        if dy.len() != tl * bsz * hid || self.cached_h.len() != tl * bsz * hid {
            return Err(anyhow!("{}: backward before forward (or bad grad len)", self.wx.name));
        }
        let _span = crate::obs::trace::span("nn.rnn.bwd");
        nc.set_layer(&self.wx.name);
        // Hoisted transposed weights: one conversion per backward pass,
        // not per timestep.
        let wht = transpose(&self.wh.w, hid, hid);
        let wxt = transpose(&self.wx.w, ind, hid);
        let zeros = vec![0.0f32; bsz * hid];
        let mut dx = vec![0.0f32; tl * bsz * ind];
        let mut dh_carry = vec![0.0f32; bsz * hid];
        for t in (0..tl).rev() {
            let h_t = &self.cached_h[t * bsz * hid..(t + 1) * bsz * hid];
            // through tanh: dpre = (dy_t + carry) * (1 - h_t²)
            let mut dpre = vec![0.0f32; bsz * hid];
            for i in 0..dpre.len() {
                let total = dy[t * bsz * hid + i] + dh_carry[i];
                dpre[i] = total * (1.0 - h_t[i] * h_t[i]);
            }
            // dWx += x_tᵀ · dpre
            let xt = &self.cached_x[t * bsz * ind..(t + 1) * bsz * ind];
            let xtt = transpose(xt, bsz, ind);
            let dwx = nc.gemm(&xtt, &dpre, ind, bsz, hid)?;
            for (g, d) in self.wx.g.iter_mut().zip(&dwx) {
                *g += d;
            }
            // dWh += h_{t-1}ᵀ · dpre (h_{-1} = 0)
            let h_prev = if t == 0 {
                &zeros[..]
            } else {
                &self.cached_h[(t - 1) * bsz * hid..t * bsz * hid]
            };
            let hpt = transpose(h_prev, bsz, hid);
            let dwh = nc.gemm(&hpt, &dpre, hid, bsz, hid)?;
            for (g, d) in self.wh.g.iter_mut().zip(&dwh) {
                *g += d;
            }
            // db += column-sum(dpre)
            for r in 0..bsz {
                let row = &dpre[r * hid..(r + 1) * hid];
                for (g, d) in self.b.g.iter_mut().zip(row) {
                    *g += d;
                }
            }
            // carry into t-1 and input gradient at t
            dh_carry = nc.gemm(&dpre, &wht, bsz, hid, hid)?;
            let dxt = nc.gemm(&dpre, &wxt, bsz, hid, ind)?;
            dx[t * bsz * ind..(t + 1) * bsz * ind].copy_from_slice(&dxt);
        }
        Ok(dx)
    }

    pub fn params(&self) -> Vec<&Param> {
        vec![&self.wx, &self.wh, &self.b]
    }

    pub fn params_mut(&mut self) -> Vec<&mut Param> {
        vec![&mut self.wx, &mut self.wh, &mut self.b]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::BfpContext;
    use crate::nn::Precision;

    #[test]
    fn forward_shapes_and_tanh_range() {
        let mut rng = Xorshift32::new(7);
        let mut r = Rnn::new("rnn", 3, 5, &mut rng);
        let mut nc = NnContext::new(BfpContext::from_env(), Precision::Fp32);
        let x = vec![0.3f32; 4 * 2 * 3]; // T=4, B=2, in=3
        let h = r.forward(&mut nc, &x, 2, 4).unwrap();
        assert_eq!(h.len(), 4 * 2 * 5);
        assert!(h.iter().all(|v| v.abs() <= 1.0));
        let dx = r.backward(&mut nc, &vec![0.1f32; h.len()]).unwrap();
        assert_eq!(dx.len(), x.len());
    }

    #[test]
    fn recurrence_feeds_forward() {
        // With Wh = 0 every timestep is independent; with Wh != 0 a
        // change at t=0 must reach t=1.
        let mut rng = Xorshift32::new(8);
        let mut r = Rnn::new("rnn", 2, 2, &mut rng);
        let mut nc = NnContext::new(BfpContext::from_env(), Precision::Fp32);
        let mut x = vec![0.5f32; 2 * 1 * 2]; // T=2, B=1
        let h1 = r.forward(&mut nc, &x, 1, 2).unwrap();
        x[0] += 1.0; // perturb only t=0
        let h2 = r.forward(&mut nc, &x, 1, 2).unwrap();
        let late_delta: f32 = h1[2..].iter().zip(&h2[2..]).map(|(a, b)| (a - b).abs()).sum();
        assert!(late_delta > 1e-6, "t=1 hidden state must depend on t=0 input");
    }
}
