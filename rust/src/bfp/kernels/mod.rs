//! Runtime-dispatched SIMD kernel family for the packed-panel BFP
//! datapath.
//!
//! The paper's premise is that BFP dot products reduce to dense
//! fixed-point MACs; the packed `i8`/`i16` mantissa storage and the
//! k-tile-major B panels exist so a vector unit can stream them. This
//! module provides that vector unit in software: one kernel family per
//! ISA, selected once per process and dispatched at runtime.
//!
//! ## Kernels
//!
//! | kernel | contract |
//! |---|---|
//! | [`mac_panel`] | `acc[c] += Σ_dk arow[dk] * panel[dk*nr+c]` — the panel microkernel's inner loops (widening `i8×i8→i32`, `i16×i16→i32/i64`) |
//! | [`row_amax`] | max-magnitude reduction (shared-exponent selection) |
//! | [`quantize_row_rne`] | nearest-even mantissa scaling into packed storage |
//! | [`quantize_dequant_row_rne`] | in-place FP→BFP→FP row round-trip |
//!
//! ## ISAs and selection
//!
//! | [`Isa`] | panel width | availability |
//! |---|---|---|
//! | `Scalar` | 8 | always (the reference; `HBFP_SIMD=off`) |
//! | `Sse41`  | 16 | `x86_64` with SSE4.1 (CPUID-probed) |
//! | `Avx2`   | 32 | `x86_64` with AVX2 (CPUID-probed) |
//! | `Neon`   | 16 | `aarch64` (baseline) |
//!
//! `HBFP_SIMD=off|sse|avx2|neon|auto` overrides the default (`auto` =
//! widest available), read once at first use like `HBFP_THREADS`. A
//! request the CPU cannot honor degrades to the next-widest available
//! ISA. Every dispatcher also clamps its `Isa` argument to the detected
//! capabilities, so forcing an ISA (tests, the bench ladder) is always
//! memory-safe.
//!
//! ## Bit-identity contract
//!
//! Every vector path is bit-identical to the [`scalar`] reference for
//! finite and ±inf inputs. NaN is outside the quantizer contract —
//! scalar `max`/`clamp` and vector `maxps`/min-max differ on NaN, so
//! debug builds assert NaN-free converter input at the block-exponent
//! entry (`quant::block_exponent*`), and `frexp_exp` keeps its
//! finiteness assert:
//!
//! - integer MACs are exact and associative, and each vector lane is one
//!   output column (no cross-lane sums), so the per-element partials are
//!   the same integers in any lane width;
//! - the i32-accumulator overflow bound (`acc_fits_i32`) bounds every
//!   vector partial exactly as it bounds the scalar ones, so the same
//!   accumulator-width selection applies unchanged;
//! - mantissa scaling multiplies by the exact power-of-two reciprocal
//!   (IEEE-correctly-rounded, equal to the scalar division), rounds with
//!   the hardware round-ties-even, and clamps with min/max;
//! - the max reduction is associative/commutative over finite floats.
//!
//! **Stochastic rounding is deliberately not vectorized**: each tile's
//! Xorshift32 substream is consumed in element order, one draw per
//! element, so the draw sequence (and therefore every trained bit) is
//! identical whatever ISA is active. The stochastic row loops stay
//! scalar in `tensor.rs`/`matmul.rs`; only the RNE rows and the
//! exponent reduction vectorize.
//!
//! Differential tests live in this module (kernel level, every detected
//! ISA vs scalar) and in `tests/simd_kernels.rs` (whole-matmul level via
//! `BfpContext::with_isa`); CI runs the full suite under both
//! `HBFP_SIMD=off` and `HBFP_SIMD=auto`.

use std::sync::OnceLock;

use super::panels::{MAX_PANEL_NR, PANEL_NR};
use super::quant::{grid, TileRounding};
use super::tensor::MantissaElem;

pub mod scalar;

#[cfg(target_arch = "aarch64")]
mod neon;
#[cfg(target_arch = "x86_64")]
mod x86;

/// Integer accumulator for the tile MAC loops: `i32` when the overflow
/// bound allows (see `matmul::acc_fits_i32`), `i64` otherwise. Both sum
/// identical integer values.
pub trait Accum: Copy + Default + Send + 'static {
    fn mac<EA: MantissaElem, EB: MantissaElem>(&mut self, qa: EA, qb: EB);
    fn to_f32(self) -> f32;
    fn to_i64(self) -> i64;

    /// Downcast for the SIMD dispatcher (Some only on `i32`).
    fn as_i32s(acc: &mut [Self]) -> Option<&mut [i32]> {
        let _ = acc;
        None
    }

    /// Downcast for the SIMD dispatcher (Some only on `i64`).
    fn as_i64s(acc: &mut [Self]) -> Option<&mut [i64]> {
        let _ = acc;
        None
    }
}

impl Accum for i32 {
    #[inline(always)]
    fn mac<EA: MantissaElem, EB: MantissaElem>(&mut self, qa: EA, qb: EB) {
        *self += qa.to_i32() * qb.to_i32();
    }

    #[inline(always)]
    fn to_f32(self) -> f32 {
        self as f32
    }

    #[inline(always)]
    fn to_i64(self) -> i64 {
        self as i64
    }

    fn as_i32s(acc: &mut [i32]) -> Option<&mut [i32]> {
        Some(acc)
    }
}

impl Accum for i64 {
    #[inline(always)]
    fn mac<EA: MantissaElem, EB: MantissaElem>(&mut self, qa: EA, qb: EB) {
        *self += qa.to_i32() as i64 * qb.to_i32() as i64;
    }

    #[inline(always)]
    fn to_f32(self) -> f32 {
        self as f32
    }

    #[inline(always)]
    fn to_i64(self) -> i64 {
        self
    }

    fn as_i64s(acc: &mut [i64]) -> Option<&mut [i64]> {
        Some(acc)
    }
}

/// One kernel family. `Scalar` is the portable reference; the vector
/// variants are feature-gated per target and probed at runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    Scalar,
    Sse41,
    Avx2,
    Neon,
}

impl Isa {
    /// Stable display name (used by the bench header and PERF.md table).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse41 => "sse4.1",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Panel register width for this family: how many output columns one
    /// microkernel accumulator block holds, i.e. the `nr` the B-operand
    /// panels are packed at ([`crate::bfp::panels::pack_panels`]).
    pub fn panel_nr(self) -> usize {
        match self {
            Isa::Scalar => PANEL_NR,
            Isa::Sse41 => 16,
            Isa::Avx2 => 32,
            Isa::Neon => 16,
        }
    }

    /// Multiplier for the kernels' inline-vs-dispatch work floors
    /// (`pool::par_threads_simd`): wider families finish small problems
    /// faster, so the threshold below which dispatch overhead dominates
    /// scales with the family's throughput class. A heuristic — it only
    /// moves the speed knee, never the results.
    pub fn par_floor_scale(self) -> usize {
        match self {
            Isa::Scalar => 1,
            Isa::Sse41 => 2,
            Isa::Avx2 => 4,
            Isa::Neon => 2,
        }
    }

    /// This ISA if the running CPU supports it, else the widest
    /// available family of at most this panel width (so an `Avx2`
    /// request degrades to SSE4.1 or NEON, and a `Neon` request on
    /// x86 degrades to the same-width SSE4.1 — never silently to
    /// scalar while a vector unit exists). Makes any `Isa` value safe
    /// to pass to the dispatchers.
    pub fn clamped(self) -> Isa {
        if executable(self) {
            self
        } else {
            widest_within(CpuCaps::detect(), self.panel_nr())
        }
    }
}

/// Whether the running CPU can execute this family's kernels.
fn executable(isa: Isa) -> bool {
    let caps = CpuCaps::detect();
    match isa {
        Isa::Scalar => true,
        Isa::Sse41 => caps.sse41,
        Isa::Avx2 => caps.avx2,
        Isa::Neon => caps.neon,
    }
}

/// Widest available family whose panel width does not exceed `max_nr`
/// (explicit preferences act as width caps, so e.g. `HBFP_SIMD=sse`
/// selects NEON on aarch64 — the same 16-wide class).
fn widest_within(caps: CpuCaps, max_nr: usize) -> Isa {
    if caps.avx2 && max_nr >= Isa::Avx2.panel_nr() {
        Isa::Avx2
    } else if caps.sse41 && max_nr >= Isa::Sse41.panel_nr() {
        Isa::Sse41
    } else if caps.neon && max_nr >= Isa::Neon.panel_nr() {
        Isa::Neon
    } else {
        Isa::Scalar
    }
}

/// Runtime CPU capabilities relevant to the kernel families. A plain
/// value so [`select`] is a pure, exhaustively testable function.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CpuCaps {
    pub sse41: bool,
    pub avx2: bool,
    pub neon: bool,
}

fn probe_sse41() -> bool {
    #[cfg(target_arch = "x86_64")]
    return std::arch::is_x86_feature_detected!("sse4.1");
    #[cfg(not(target_arch = "x86_64"))]
    return false;
}

fn probe_avx2() -> bool {
    #[cfg(target_arch = "x86_64")]
    return std::arch::is_x86_feature_detected!("avx2");
    #[cfg(not(target_arch = "x86_64"))]
    return false;
}

fn probe_neon() -> bool {
    cfg!(target_arch = "aarch64")
}

impl CpuCaps {
    /// Probe the running CPU (cached after the first call).
    pub fn detect() -> CpuCaps {
        static CAPS: OnceLock<CpuCaps> = OnceLock::new();
        *CAPS.get_or_init(|| CpuCaps {
            sse41: probe_sse41(),
            avx2: probe_avx2(),
            neon: probe_neon(),
        })
    }

    /// No vector units at all (the `select` fallback row).
    pub fn none() -> CpuCaps {
        CpuCaps { sse41: false, avx2: false, neon: false }
    }
}

/// Parsed `HBFP_SIMD` preference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdPref {
    Off,
    Sse,
    Avx2,
    Neon,
    Auto,
}

impl SimdPref {
    /// Parse an `HBFP_SIMD` value; `None` for unrecognized input (the
    /// caller warns and falls back to auto).
    pub fn parse(s: &str) -> Option<SimdPref> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "scalar" | "none" => Some(SimdPref::Off),
            "sse" | "sse4" | "sse4.1" => Some(SimdPref::Sse),
            "avx2" | "avx" => Some(SimdPref::Avx2),
            "neon" => Some(SimdPref::Neon),
            "auto" | "" => Some(SimdPref::Auto),
            _ => None,
        }
    }
}

/// Pick the kernel family for a preference and a capability set: `Off`
/// forces the scalar reference, `Auto` (or no preference) takes the
/// widest available unit, and an explicit request acts as a panel-width
/// cap that degrades to the widest supported family within it rather
/// than failing — `sse`/`neon` mean "a 16-wide unit", `avx2` means "up
/// to 32-wide", whatever the architecture actually provides.
pub fn select(pref: Option<SimdPref>, caps: CpuCaps) -> Isa {
    match pref {
        None | Some(SimdPref::Auto) | Some(SimdPref::Avx2) => widest_within(caps, MAX_PANEL_NR),
        Some(SimdPref::Off) => Isa::Scalar,
        Some(SimdPref::Sse) | Some(SimdPref::Neon) => widest_within(caps, 16),
    }
}

/// The process-wide kernel family: `HBFP_SIMD` (read once, at first use)
/// applied to the detected CPU capabilities.
pub fn active() -> Isa {
    static ACTIVE: OnceLock<Isa> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let pref = match std::env::var("HBFP_SIMD") {
            Ok(v) => match SimdPref::parse(&v) {
                Some(p) => Some(p),
                None => {
                    eprintln!(
                        "HBFP_SIMD={v:?} not recognized (off|sse|avx2|neon|auto); using auto"
                    );
                    None
                }
            },
            Err(_) => None,
        };
        select(pref, CpuCaps::detect())
    })
}

/// Panel width of the active family — what `BfpTensor::packed_panels`
/// packs at.
pub fn active_panel_nr() -> usize {
    active().panel_nr()
}

/// Inline-floor multiplier for a converter pass: the stochastic inner
/// loop is deliberately scalar (ISA-independent RNG draws), so only
/// nearest-even scales the threshold with the family's width.
pub(crate) fn converter_floor_scale(isa: Isa, mode: TileRounding) -> usize {
    match mode {
        TileRounding::NearestEven => isa.par_floor_scale(),
        TileRounding::StochasticBase(_) => 1,
    }
}

/// Every family the running CPU can execute (always includes `Scalar`).
/// The differential tests iterate this.
pub fn detected() -> Vec<Isa> {
    let caps = CpuCaps::detect();
    let mut v = vec![Isa::Scalar];
    if caps.sse41 {
        v.push(Isa::Sse41);
    }
    if caps.avx2 {
        v.push(Isa::Avx2);
    }
    if caps.neon {
        v.push(Isa::Neon);
    }
    v
}

/// Panel MAC: `acc[c] += Σ_dk arow[dk] * panel[dk*nr + c]` for
/// `c in 0..nr`, under the chosen family (clamped to the CPU's
/// capabilities, so any `Isa` value is safe). Falls back to the scalar
/// reference for element/accumulator combinations without a vector
/// kernel (mixed-width operand pairs, `i8` with an `i64` accumulator) —
/// results are bit-identical either way.
#[inline]
pub fn mac_panel<EA: MantissaElem, EB: MantissaElem, A: Accum>(
    isa: Isa,
    arow: &[EA],
    panel: &[EB],
    nr: usize,
    acc: &mut [A],
) {
    mac_panel_preclamped(isa.clamped(), arow, panel, nr, acc)
}

/// [`mac_panel`] for an `isa` already known executable on this CPU
/// (`active()` or a `clamped()` result) — the per-row hot path skips
/// the re-clamp. Debug builds assert the contract.
#[inline]
pub(crate) fn mac_panel_preclamped<EA: MantissaElem, EB: MantissaElem, A: Accum>(
    isa: Isa,
    arow: &[EA],
    panel: &[EB],
    nr: usize,
    acc: &mut [A],
) {
    debug_assert!(executable(isa), "pass active() or a clamped() ISA");
    debug_assert!(acc.len() == nr);
    debug_assert!(panel.len() >= arow.len() * nr);
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Sse41 => {
            if x86::mac_panel_sse41(arow, panel, nr, &mut *acc) {
                return;
            }
        }
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => {
            if x86::mac_panel_avx2(arow, panel, nr, &mut *acc) {
                return;
            }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => {
            if neon::mac_panel_neon(arow, panel, nr, &mut *acc) {
                return;
            }
        }
        _ => {}
    }
    scalar::mac_panel(arow, panel, nr, acc);
}

/// Max |x| over a row (0.0 when empty) under the chosen family
/// (clamped, so any `Isa` value is safe).
#[inline]
pub fn row_amax(isa: Isa, xs: &[f32]) -> f32 {
    row_amax_preclamped(isa.clamped(), xs)
}

/// [`row_amax`] for an already-executable `isa` — the per-tile-row hot
/// path of the exponent selection.
#[inline]
pub(crate) fn row_amax_preclamped(isa: Isa, xs: &[f32]) -> f32 {
    debug_assert!(executable(isa), "pass active() or a clamped() ISA");
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Sse41 => x86::row_amax_sse41(xs),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::row_amax_avx2(xs),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::row_amax_neon(xs),
        _ => scalar::row_amax(xs),
    }
}

/// Nearest-even quantization of one row onto the grid of `(e, bits)`
/// into packed mantissa storage, under the chosen family (clamped, so
/// any `Isa` value is safe). (Stochastic rounding never routes here —
/// it stays scalar so the RNG draw order is ISA-independent.)
#[inline]
pub fn quantize_row_rne<E: MantissaElem>(
    isa: Isa,
    src: &[f32],
    dst: &mut [E],
    e: i32,
    mantissa_bits: u32,
) {
    quantize_row_rne_preclamped(isa.clamped(), src, dst, e, mantissa_bits)
}

/// [`quantize_row_rne`] for an already-executable `isa` — the per-row
/// hot path of the converters.
#[inline]
pub(crate) fn quantize_row_rne_preclamped<E: MantissaElem>(
    isa: Isa,
    src: &[f32],
    dst: &mut [E],
    e: i32,
    mantissa_bits: u32,
) {
    debug_assert!(executable(isa), "pass active() or a clamped() ISA");
    debug_assert_eq!(src.len(), dst.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Sse41 => {
            if x86::quantize_row_rne_sse41(src, &mut *dst, e, mantissa_bits) {
                return;
            }
        }
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => {
            if x86::quantize_row_rne_avx2(src, &mut *dst, e, mantissa_bits) {
                return;
            }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => {
            if neon::quantize_row_rne_neon(src, &mut *dst, e, mantissa_bits) {
                return;
            }
        }
        _ => {}
    }
    scalar::quantize_row_rne(src, dst, e, mantissa_bits);
}

/// In-place nearest-even quantize + dequantize of one row (the trainer's
/// host-side input converter), under the chosen family (clamped, so any
/// `Isa` value is safe).
#[inline]
pub fn quantize_dequant_row_rne(isa: Isa, row: &mut [f32], e: i32, mantissa_bits: u32) {
    quantize_dequant_row_rne_preclamped(isa.clamped(), row, e, mantissa_bits)
}

/// [`quantize_dequant_row_rne`] for an already-executable `isa`.
#[inline]
pub(crate) fn quantize_dequant_row_rne_preclamped(
    isa: Isa,
    row: &mut [f32],
    e: i32,
    mantissa_bits: u32,
) {
    debug_assert!(executable(isa), "pass active() or a clamped() ISA");
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Sse41 => x86::quantize_dequant_row_rne_sse41(row, e, mantissa_bits),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => x86::quantize_dequant_row_rne_avx2(row, e, mantissa_bits),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => neon::quantize_dequant_row_rne_neon(row, e, mantissa_bits),
        _ => scalar::quantize_dequant_row_rne(row, e, mantissa_bits),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Gen;

    const ALL_ISAS: [Isa; 4] = [Isa::Scalar, Isa::Sse41, Isa::Avx2, Isa::Neon];

    #[test]
    fn panel_widths_fit_the_layout_cap() {
        for isa in ALL_ISAS {
            assert!(isa.panel_nr() <= MAX_PANEL_NR, "{:?}", isa);
            assert!(isa.panel_nr() % PANEL_NR == 0, "{:?}", isa);
            assert!(isa.par_floor_scale() >= 1);
        }
    }

    #[test]
    fn pref_parsing() {
        assert_eq!(SimdPref::parse("off"), Some(SimdPref::Off));
        assert_eq!(SimdPref::parse("OFF"), Some(SimdPref::Off));
        assert_eq!(SimdPref::parse("scalar"), Some(SimdPref::Off));
        assert_eq!(SimdPref::parse("sse"), Some(SimdPref::Sse));
        assert_eq!(SimdPref::parse(" sse4.1 "), Some(SimdPref::Sse));
        assert_eq!(SimdPref::parse("avx2"), Some(SimdPref::Avx2));
        assert_eq!(SimdPref::parse("neon"), Some(SimdPref::Neon));
        assert_eq!(SimdPref::parse("auto"), Some(SimdPref::Auto));
        assert_eq!(SimdPref::parse("avx512"), None);
        assert_eq!(SimdPref::parse("1"), None);
    }

    #[test]
    fn selection_matrix() {
        let x86 = CpuCaps { sse41: true, avx2: true, neon: false };
        let old_x86 = CpuCaps { sse41: true, avx2: false, neon: false };
        let arm = CpuCaps { sse41: false, avx2: false, neon: true };
        let none = CpuCaps::none();

        // auto / no preference: widest available
        assert_eq!(select(None, x86), Isa::Avx2);
        assert_eq!(select(Some(SimdPref::Auto), x86), Isa::Avx2);
        assert_eq!(select(None, old_x86), Isa::Sse41);
        assert_eq!(select(None, arm), Isa::Neon);
        assert_eq!(select(None, none), Isa::Scalar);

        // off always wins
        for caps in [x86, old_x86, arm, none] {
            assert_eq!(select(Some(SimdPref::Off), caps), Isa::Scalar);
        }

        // explicit requests are width caps: they degrade to the widest
        // supported family within the cap, across architectures
        assert_eq!(select(Some(SimdPref::Avx2), x86), Isa::Avx2);
        assert_eq!(select(Some(SimdPref::Avx2), old_x86), Isa::Sse41);
        assert_eq!(select(Some(SimdPref::Avx2), arm), Isa::Neon);
        assert_eq!(select(Some(SimdPref::Avx2), none), Isa::Scalar);
        assert_eq!(select(Some(SimdPref::Sse), x86), Isa::Sse41);
        assert_eq!(select(Some(SimdPref::Sse), arm), Isa::Neon);
        assert_eq!(select(Some(SimdPref::Sse), none), Isa::Scalar);
        assert_eq!(select(Some(SimdPref::Neon), arm), Isa::Neon);
        assert_eq!(select(Some(SimdPref::Neon), x86), Isa::Sse41);
        assert_eq!(select(Some(SimdPref::Neon), none), Isa::Scalar);
        // clamping follows the same rule
        assert_eq!(Isa::Scalar.clamped(), Isa::Scalar);
    }

    #[test]
    fn clamped_is_always_executable() {
        // whatever Isa a caller passes, the clamped family must be in
        // the detected set
        let avail = detected();
        for isa in ALL_ISAS {
            assert!(avail.contains(&isa.clamped()), "{:?} clamps out of range", isa);
        }
        assert!(avail.contains(&active()));
    }

    #[test]
    fn grid_is_exact() {
        for (e, m) in [(-100i32, 24u32), (-100, 2), (0, 8), (127, 2), (127, 24), (5, 12)] {
            let (inv, step, lo, hi) = grid(e, m);
            assert_eq!(inv * step, 1.0, "e={e} m={m}");
            assert_eq!(lo, -((1i64 << (m - 1)) as f32));
            assert_eq!(hi, ((1i64 << (m - 1)) - 1) as f32);
        }
    }

    /// Random mantissa in the `bits`-wide two's-complement range,
    /// with extra mass on 0 and the extremes.
    fn rand_mant(g: &mut Gen, bits: u32) -> i32 {
        let lo = -(1i64 << (bits - 1));
        let hi = (1i64 << (bits - 1)) - 1;
        match g.int(0, 9) {
            0 => 0,
            1 => lo as i32,
            2 => hi as i32,
            _ => (lo + (g.rng.next_u64() % (hi - lo + 1) as u64) as i64) as i32,
        }
    }

    fn mac_case<EA, EB, A>(g: &mut Gen, isa: Isa, ea_bits: u32, eb_bits: u32, label: &str)
    where
        EA: MantissaElem,
        EB: MantissaElem,
        A: Accum + PartialEq + std::fmt::Debug,
    {
        let nr = *g.pick(&[8usize, 16, 32]);
        let klen = g.int(0, 60);
        let arow: Vec<EA> = (0..klen).map(|_| EA::from_i32(rand_mant(g, ea_bits))).collect();
        let panel: Vec<EB> =
            (0..klen * nr + g.int(0, 2) * nr) // may carry trailing padded rows
                .map(|_| EB::from_i32(rand_mant(g, eb_bits)))
                .collect();
        let mut want: Vec<A> = (0..nr).map(|_| A::default()).collect();
        // nonzero initial accumulators: the kernels must accumulate
        for (i, w) in want.iter_mut().enumerate() {
            w.mac(EA::from_i32(1), EB::from_i32((i % 3) as i32));
        }
        let mut got = want.clone();
        scalar::mac_panel(&arow, &panel, nr, &mut want);
        mac_panel(isa, &arow, &panel, nr, &mut got);
        assert!(
            got == want,
            "{label} isa={isa:?} nr={nr} klen={klen}: {got:?} != {want:?}"
        );
    }

    #[test]
    fn mac_panel_matches_scalar_on_every_detected_isa() {
        let mut g = Gen::new(0x51D3);
        for _ in 0..60 {
            for &isa in &detected() {
                // i8 x i8 -> i32: bound holds for any klen <= 60
                mac_case::<i8, i8, i32>(&mut g, isa, 8, 8, "i8*i8->i32");
                // i16 x i16 -> i32 at 12-bit values (bound: klen <= 511)
                mac_case::<i16, i16, i32>(&mut g, isa, 12, 12, "i16*i16->i32");
                // i16 x i16 -> i64 at full width
                mac_case::<i16, i16, i64>(&mut g, isa, 16, 16, "i16*i16->i64");
                // mixed storage classes: scalar fallback inside the dispatch
                mac_case::<i8, i16, i32>(&mut g, isa, 8, 12, "i8*i16->i32");
                mac_case::<i16, i8, i64>(&mut g, isa, 16, 8, "i16*i8->i64");
                mac_case::<i32, i32, i64>(&mut g, isa, 24, 24, "i32*i32->i64");
                // i8 with i64 accumulator: scalar fallback
                mac_case::<i8, i8, i64>(&mut g, isa, 8, 8, "i8*i8->i64");
            }
        }
    }

    #[test]
    fn mac_panel_extremes_at_the_i32_bound() {
        // all-extremal mantissas right at the accumulator bound: 12-bit
        // operands, 511 products is the largest i32-safe tile
        for &isa in &detected() {
            for nr in [8usize, 16, 32] {
                let klen = 511;
                let arow = vec![-(1i16 << 11); klen];
                let panel = vec![-(1i16 << 11); klen * nr];
                let mut want = vec![0i32; nr];
                let mut got = vec![0i32; nr];
                scalar::mac_panel(&arow, &panel, nr, &mut want);
                mac_panel(isa, &arow, &panel, nr, &mut got);
                assert_eq!(got, want, "isa={isa:?} nr={nr}");
                assert_eq!(want[0], 511 << 22); // 511 * 2^11 * 2^11, no wrap
            }
        }
    }

    #[test]
    fn row_amax_matches_scalar() {
        let mut g = Gen::new(0xA3A3);
        for _ in 0..120 {
            let len = g.int(0, 67);
            let mut xs = g.vec_f32(len, 6);
            if len > 0 && g.bool() {
                xs[g.int(0, len - 1)] = 0.0;
            }
            let want = scalar::row_amax(&xs);
            for &isa in &detected() {
                let got = row_amax(isa, &xs);
                assert!(
                    got.to_bits() == want.to_bits(),
                    "isa={isa:?} len={len}: {got} != {want}"
                );
            }
        }
    }

    fn q_row_case<E>(g: &mut Gen, isa: Isa, bits: u32)
    where
        E: MantissaElem + PartialEq + std::fmt::Debug,
    {
        let len = g.int(0, 67);
        let e = *g.pick(&[-100i32, -20, -1, 0, 1, 10, 127]);
        let (_, step, lo, hi) = grid(e, bits);
        let src: Vec<f32> = (0..len)
            .map(|_| match g.int(0, 5) {
                // exact grid ties: the round-ties-even hot spot
                0 => (g.int(0, 40) as f32 - 20.0 + 0.5) * step,
                1 => (g.int(0, 40) as f32 - 20.0) * step,
                // far outside the clamp range (finite-first product
                // order: never NaN, at worst ±inf, which still clamps
                // identically on every path)
                2 => g.f32_sym(4.0) * (hi - lo) * step,
                // tiny (possibly subnormal after scaling)
                3 => g.f32_sym(1.0) * f32::MIN_POSITIVE,
                _ => g.f32_sym(2.0) * step * 100.0,
            })
            .collect();
        let mut want: Vec<E> = (0..len).map(|_| E::from_i32(0)).collect();
        let mut got = want.clone();
        scalar::quantize_row_rne(&src, &mut want, e, bits);
        quantize_row_rne(isa, &src, &mut got, e, bits);
        assert!(got == want, "isa={isa:?} bits={bits} e={e} len={len}");

        // and the in-place round-trip
        let mut wantf = src.clone();
        let mut gotf = src.clone();
        scalar::quantize_dequant_row_rne(&mut wantf, e, bits);
        quantize_dequant_row_rne(isa, &mut gotf, e, bits);
        let same = wantf.iter().zip(&gotf).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "roundtrip isa={isa:?} bits={bits} e={e} len={len}");
    }

    #[test]
    fn quantize_rows_match_scalar_on_every_detected_isa() {
        let mut g = Gen::new(0x0BF9);
        for _ in 0..80 {
            for &isa in &detected() {
                for &bits in &[2u32, 4, 7, 8] {
                    q_row_case::<i8>(&mut g, isa, bits);
                }
                for &bits in &[9u32, 12, 16] {
                    q_row_case::<i16>(&mut g, isa, bits);
                }
                for &bits in &[17u32, 20, 24] {
                    q_row_case::<i32>(&mut g, isa, bits);
                }
            }
        }
    }
}
