//! x86_64 SSE4.1 / AVX2 kernels for the packed-panel MAC loops and the
//! FP→BFP converter.
//!
//! Every function here is bit-identical to its `super::scalar`
//! counterpart for finite inputs (the quantizer contract — `frexp_exp`
//! debug-asserts finiteness):
//!
//! - **Integer panel MACs** are exact: the per-lane sums are the same
//!   integers the scalar loop produces (addition of exact products is
//!   associative), and the overflow bound that licenses an `i32`
//!   accumulator bounds every vector partial the same way it bounds the
//!   scalar ones.
//! - **Mantissa scaling** multiplies by the exact reciprocal of the
//!   power-of-two step instead of dividing; IEEE-754 makes both the
//!   correctly-rounded result of the same exact quotient, so the bits
//!   agree. `roundps` with `_MM_FROUND_TO_NEAREST_INT` is exactly
//!   `f32::round_ties_even`, `min/max` reproduce `clamp` for finite
//!   operands, and `cvtps2dq` of an already-integral float is exact.
//! - **Max-magnitude reduction** is a tree of `maxps` — max is
//!   associative/commutative over finite floats, so the lane order does
//!   not change the result.
//!
//! The leaf kernels are `unsafe fn` + `#[target_feature]`; the safe
//! wrappers in this module downcast the generic element types and return
//! `false` when no vector kernel applies (mixed-width operand pairs, the
//! i8-with-i64-accumulator corner), which routes the caller back to the
//! scalar reference. Callers (the [`super`] dispatcher) must only pass
//! ISAs the running CPU supports.

#![allow(unsafe_op_in_unsafe_fn)]

use core::arch::x86_64::*;

use super::{grid, scalar, Accum};
use crate::bfp::tensor::MantissaElem;

// ---------------------------------------------------------------------------
// Panel MAC wrappers
// ---------------------------------------------------------------------------

/// SSE4.1 panel MAC: `acc[c] += Σ_dk arow[dk] * panel[dk*nr + c]`.
/// Returns false (untouched `acc`) when no vector kernel matches the
/// element/accumulator combination.
///
/// Caller contract: the running CPU supports SSE4.1.
pub fn mac_panel_sse41<EA: MantissaElem, EB: MantissaElem, A: Accum>(
    arow: &[EA],
    panel: &[EB],
    nr: usize,
    acc: &mut [A],
) -> bool {
    debug_assert!(acc.len() == nr && panel.len() >= arow.len() * nr);
    if nr % 4 != 0 {
        return false;
    }
    if let (Some(a), Some(p)) = (EA::as_i8s(arow), EB::as_i8s(panel)) {
        if let Some(acc32) = A::as_i32s(&mut *acc) {
            unsafe { mac_i8_i32_sse41(a, p, nr, acc32) };
            return true;
        }
        return false; // i8 x i8 with i64 acc: only at tile_k >= 2^17; scalar
    }
    if let (Some(a), Some(p)) = (EA::as_i16s(arow), EB::as_i16s(panel)) {
        if let Some(acc32) = A::as_i32s(&mut *acc) {
            unsafe { mac_i16_i32_sse41(a, p, nr, acc32) };
            return true;
        }
        if let Some(acc64) = A::as_i64s(&mut *acc) {
            unsafe { mac_i16_i64_sse41(a, p, nr, acc64) };
            return true;
        }
    }
    false
}

/// AVX2 panel MAC; same contract as [`mac_panel_sse41`].
///
/// Caller contract: the running CPU supports AVX2.
pub fn mac_panel_avx2<EA: MantissaElem, EB: MantissaElem, A: Accum>(
    arow: &[EA],
    panel: &[EB],
    nr: usize,
    acc: &mut [A],
) -> bool {
    debug_assert!(acc.len() == nr && panel.len() >= arow.len() * nr);
    if nr % 8 != 0 {
        return false;
    }
    if let (Some(a), Some(p)) = (EA::as_i8s(arow), EB::as_i8s(panel)) {
        if let Some(acc32) = A::as_i32s(&mut *acc) {
            unsafe { mac_i8_i32_avx2(a, p, nr, acc32) };
            return true;
        }
        return false;
    }
    if let (Some(a), Some(p)) = (EA::as_i16s(arow), EB::as_i16s(panel)) {
        if let Some(acc32) = A::as_i32s(&mut *acc) {
            unsafe { mac_i16_i32_avx2(a, p, nr, acc32) };
            return true;
        }
        if let Some(acc64) = A::as_i64s(&mut *acc) {
            unsafe { mac_i16_i64_avx2(a, p, nr, acc64) };
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Panel MAC leaves. Layout reminder: `panel[dk * nr + c]`, lanes = output
// columns, so the A element broadcasts and there is no cross-lane math.
// ---------------------------------------------------------------------------

/// SAFETY: requires SSE4.1; `nr % 4 == 0`, `acc.len() == nr`,
/// `panel.len() >= arow.len() * nr` (debug-asserted by the wrappers).
#[target_feature(enable = "sse4.1")]
unsafe fn mac_i8_i32_sse41(arow: &[i8], panel: &[i8], nr: usize, acc: &mut [i32]) {
    for c0 in (0..nr).step_by(4) {
        let mut accv = _mm_loadu_si128(acc.as_ptr().add(c0) as *const __m128i);
        for (dk, &qa) in arow.iter().enumerate() {
            if qa == 0 {
                continue;
            }
            let av = _mm_set1_epi32(qa as i32);
            // 4 i8 lanes: one unaligned 4-byte read into lane 0
            let w = (panel.as_ptr().add(dk * nr + c0) as *const i32).read_unaligned();
            let bv = _mm_cvtepi8_epi32(_mm_cvtsi32_si128(w));
            accv = _mm_add_epi32(accv, _mm_mullo_epi32(av, bv));
        }
        _mm_storeu_si128(acc.as_mut_ptr().add(c0) as *mut __m128i, accv);
    }
}

/// SAFETY: as [`mac_i8_i32_sse41`].
#[target_feature(enable = "sse4.1")]
unsafe fn mac_i16_i32_sse41(arow: &[i16], panel: &[i16], nr: usize, acc: &mut [i32]) {
    for c0 in (0..nr).step_by(4) {
        let mut accv = _mm_loadu_si128(acc.as_ptr().add(c0) as *const __m128i);
        for (dk, &qa) in arow.iter().enumerate() {
            if qa == 0 {
                continue;
            }
            let av = _mm_set1_epi32(qa as i32);
            let b4 = _mm_loadl_epi64(panel.as_ptr().add(dk * nr + c0) as *const __m128i);
            let bv = _mm_cvtepi16_epi32(b4);
            // i16 x i16 fits i32, so mullo is the exact product
            accv = _mm_add_epi32(accv, _mm_mullo_epi32(av, bv));
        }
        _mm_storeu_si128(acc.as_mut_ptr().add(c0) as *mut __m128i, accv);
    }
}

/// SAFETY: as [`mac_i8_i32_sse41`] (2 i64 lanes per step; `nr % 4 == 0`
/// implies `nr % 2 == 0`).
#[target_feature(enable = "sse4.1")]
unsafe fn mac_i16_i64_sse41(arow: &[i16], panel: &[i16], nr: usize, acc: &mut [i64]) {
    for c0 in (0..nr).step_by(2) {
        let mut accv = _mm_loadu_si128(acc.as_ptr().add(c0) as *const __m128i);
        for (dk, &qa) in arow.iter().enumerate() {
            if qa == 0 {
                continue;
            }
            let av = _mm_set1_epi32(qa as i32);
            // 2 i16 lanes: 4-byte read; upper lanes zero -> zero products
            let w = (panel.as_ptr().add(dk * nr + c0) as *const i32).read_unaligned();
            let bv = _mm_cvtepi16_epi32(_mm_cvtsi32_si128(w));
            let prod = _mm_mullo_epi32(av, bv); // exact: i16*i16 fits i32
            accv = _mm_add_epi64(accv, _mm_cvtepi32_epi64(prod));
        }
        _mm_storeu_si128(acc.as_mut_ptr().add(c0) as *mut __m128i, accv);
    }
}

/// SAFETY: requires AVX2; `nr % 8 == 0`, `acc.len() == nr`,
/// `panel.len() >= arow.len() * nr`.
#[target_feature(enable = "avx2")]
unsafe fn mac_i8_i32_avx2(arow: &[i8], panel: &[i8], nr: usize, acc: &mut [i32]) {
    for c0 in (0..nr).step_by(8) {
        let mut accv = _mm256_loadu_si256(acc.as_ptr().add(c0) as *const __m256i);
        for (dk, &qa) in arow.iter().enumerate() {
            if qa == 0 {
                continue;
            }
            let av = _mm256_set1_epi32(qa as i32);
            let b8 = _mm_loadl_epi64(panel.as_ptr().add(dk * nr + c0) as *const __m128i);
            let bv = _mm256_cvtepi8_epi32(b8);
            accv = _mm256_add_epi32(accv, _mm256_mullo_epi32(av, bv));
        }
        _mm256_storeu_si256(acc.as_mut_ptr().add(c0) as *mut __m256i, accv);
    }
}

/// SAFETY: as [`mac_i8_i32_avx2`].
#[target_feature(enable = "avx2")]
unsafe fn mac_i16_i32_avx2(arow: &[i16], panel: &[i16], nr: usize, acc: &mut [i32]) {
    for c0 in (0..nr).step_by(8) {
        let mut accv = _mm256_loadu_si256(acc.as_ptr().add(c0) as *const __m256i);
        for (dk, &qa) in arow.iter().enumerate() {
            if qa == 0 {
                continue;
            }
            let av = _mm256_set1_epi32(qa as i32);
            let b8 = _mm_loadu_si128(panel.as_ptr().add(dk * nr + c0) as *const __m128i);
            let bv = _mm256_cvtepi16_epi32(b8);
            accv = _mm256_add_epi32(accv, _mm256_mullo_epi32(av, bv));
        }
        _mm256_storeu_si256(acc.as_mut_ptr().add(c0) as *mut __m256i, accv);
    }
}

/// SAFETY: as [`mac_i8_i32_avx2`] (4 i64 lanes per step).
#[target_feature(enable = "avx2")]
unsafe fn mac_i16_i64_avx2(arow: &[i16], panel: &[i16], nr: usize, acc: &mut [i64]) {
    for c0 in (0..nr).step_by(4) {
        let mut accv = _mm256_loadu_si256(acc.as_ptr().add(c0) as *const __m256i);
        for (dk, &qa) in arow.iter().enumerate() {
            if qa == 0 {
                continue;
            }
            let av = _mm_set1_epi32(qa as i32);
            let b4 = _mm_loadl_epi64(panel.as_ptr().add(dk * nr + c0) as *const __m128i);
            let prod = _mm_mullo_epi32(av, _mm_cvtepi16_epi32(b4));
            accv = _mm256_add_epi64(accv, _mm256_cvtepi32_epi64(prod));
        }
        _mm256_storeu_si256(acc.as_mut_ptr().add(c0) as *mut __m256i, accv);
    }
}

// ---------------------------------------------------------------------------
// FP→BFP converter: max-magnitude reduction + nearest-even mantissa rows
// ---------------------------------------------------------------------------

/// SSE4.1 row max-magnitude. Caller contract: CPU supports SSE4.1.
pub fn row_amax_sse41(xs: &[f32]) -> f32 {
    unsafe { amax_sse41(xs) }
}

/// AVX2 row max-magnitude. Caller contract: CPU supports AVX2.
pub fn row_amax_avx2(xs: &[f32]) -> f32 {
    unsafe { amax_avx2(xs) }
}

/// SAFETY: requires SSE4.1.
#[target_feature(enable = "sse4.1")]
unsafe fn amax_sse41(xs: &[f32]) -> f32 {
    let absmask = _mm_castsi128_ps(_mm_set1_epi32(0x7fff_ffff));
    let mut m = _mm_setzero_ps();
    let mut i = 0;
    while i + 4 <= xs.len() {
        let x = _mm_and_ps(_mm_loadu_ps(xs.as_ptr().add(i)), absmask);
        m = _mm_max_ps(m, x);
        i += 4;
    }
    let m2 = _mm_max_ps(m, _mm_movehl_ps(m, m));
    let m1 = _mm_max_ss(m2, _mm_shuffle_ps::<0b01>(m2, m2));
    let mut amax = _mm_cvtss_f32(m1);
    for &x in &xs[i..] {
        amax = amax.max(x.abs());
    }
    amax
}

/// SAFETY: requires AVX2.
#[target_feature(enable = "avx2")]
unsafe fn amax_avx2(xs: &[f32]) -> f32 {
    let absmask = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
    let mut m = _mm256_setzero_ps();
    let mut i = 0;
    while i + 8 <= xs.len() {
        let x = _mm256_and_ps(_mm256_loadu_ps(xs.as_ptr().add(i)), absmask);
        m = _mm256_max_ps(m, x);
        i += 8;
    }
    let m4 = _mm_max_ps(_mm256_castps256_ps128(m), _mm256_extractf128_ps::<1>(m));
    let m2 = _mm_max_ps(m4, _mm_movehl_ps(m4, m4));
    let m1 = _mm_max_ss(m2, _mm_shuffle_ps::<0b01>(m2, m2));
    let mut amax = _mm_cvtss_f32(m1);
    for &x in &xs[i..] {
        amax = amax.max(x.abs());
    }
    amax
}

/// SSE4.1 nearest-even row quantization into packed mantissas. Returns
/// false when the storage class has no vector store path (never, today —
/// i8/i16/i32 are all covered — but the signature leaves room).
///
/// Caller contract: CPU supports SSE4.1.
pub fn quantize_row_rne_sse41<E: MantissaElem>(
    src: &[f32],
    dst: &mut [E],
    e: i32,
    mantissa_bits: u32,
) -> bool {
    debug_assert_eq!(src.len(), dst.len());
    let (inv, _, lo, hi) = grid(e, mantissa_bits);
    let done = if let Some(d) = E::as_i8s_mut(&mut *dst) {
        unsafe { q_row_i8_sse41(src, d, inv, lo, hi) }
    } else if let Some(d) = E::as_i16s_mut(&mut *dst) {
        unsafe { q_row_i16_sse41(src, d, inv, lo, hi) }
    } else if let Some(d) = E::as_i32s_mut(&mut *dst) {
        unsafe { q_row_i32_sse41(src, d, inv, lo, hi) }
    } else {
        return false;
    };
    scalar::quantize_row_rne(&src[done..], &mut dst[done..], e, mantissa_bits);
    true
}

/// AVX2 nearest-even row quantization; same contract as the SSE variant.
///
/// Caller contract: CPU supports AVX2.
pub fn quantize_row_rne_avx2<E: MantissaElem>(
    src: &[f32],
    dst: &mut [E],
    e: i32,
    mantissa_bits: u32,
) -> bool {
    debug_assert_eq!(src.len(), dst.len());
    let (inv, _, lo, hi) = grid(e, mantissa_bits);
    let done = if let Some(d) = E::as_i8s_mut(&mut *dst) {
        unsafe { q_row_i8_avx2(src, d, inv, lo, hi) }
    } else if let Some(d) = E::as_i16s_mut(&mut *dst) {
        unsafe { q_row_i16_avx2(src, d, inv, lo, hi) }
    } else if let Some(d) = E::as_i32s_mut(&mut *dst) {
        unsafe { q_row_i32_avx2(src, d, inv, lo, hi) }
    } else {
        return false;
    };
    scalar::quantize_row_rne(&src[done..], &mut dst[done..], e, mantissa_bits);
    true
}

/// SSE4.1 in-place nearest-even quantize + dequantize of one row.
/// Caller contract: CPU supports SSE4.1.
pub fn quantize_dequant_row_rne_sse41(row: &mut [f32], e: i32, mantissa_bits: u32) {
    let (inv, step, lo, hi) = grid(e, mantissa_bits);
    let done = unsafe { qd_row_sse41(row, inv, step, lo, hi) };
    scalar::quantize_dequant_row_rne(&mut row[done..], e, mantissa_bits);
}

/// AVX2 in-place nearest-even quantize + dequantize of one row.
/// Caller contract: CPU supports AVX2.
pub fn quantize_dequant_row_rne_avx2(row: &mut [f32], e: i32, mantissa_bits: u32) {
    let (inv, step, lo, hi) = grid(e, mantissa_bits);
    let done = unsafe { qd_row_avx2(row, inv, step, lo, hi) };
    scalar::quantize_dequant_row_rne(&mut row[done..], e, mantissa_bits);
}

const RNE: i32 = _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC;

/// Scale, round-to-nearest-even, clamp — 4 lanes. The float result is
/// integral and in `[lo, hi]`.
///
/// SAFETY: requires SSE4.1.
#[target_feature(enable = "sse4.1")]
unsafe fn q4(x: __m128, inv: __m128, lo: __m128, hi: __m128) -> __m128 {
    let r = _mm_round_ps::<RNE>(_mm_mul_ps(x, inv));
    _mm_min_ps(_mm_max_ps(r, lo), hi)
}

/// SAFETY: requires SSE4.1. Returns the number of elements written by
/// the vector loop (a multiple of 4; the caller finishes the tail).
#[target_feature(enable = "sse4.1")]
unsafe fn q_row_i8_sse41(src: &[f32], dst: &mut [i8], inv: f32, lo: f32, hi: f32) -> usize {
    let (vinv, vlo, vhi) = (_mm_set1_ps(inv), _mm_set1_ps(lo), _mm_set1_ps(hi));
    let mut i = 0;
    while i + 4 <= src.len() {
        let c = q4(_mm_loadu_ps(src.as_ptr().add(i)), vinv, vlo, vhi);
        let q = _mm_cvtps_epi32(c); // exact: c is integral, |c| <= 2^23
        let q8 = _mm_packs_epi16(_mm_packs_epi32(q, q), _mm_setzero_si128());
        // packs saturation is a no-op: values already clamped to the class
        (dst.as_mut_ptr().add(i) as *mut i32).write_unaligned(_mm_cvtsi128_si32(q8));
        i += 4;
    }
    i
}

/// SAFETY: requires SSE4.1.
#[target_feature(enable = "sse4.1")]
unsafe fn q_row_i16_sse41(src: &[f32], dst: &mut [i16], inv: f32, lo: f32, hi: f32) -> usize {
    let (vinv, vlo, vhi) = (_mm_set1_ps(inv), _mm_set1_ps(lo), _mm_set1_ps(hi));
    let mut i = 0;
    while i + 4 <= src.len() {
        let c = q4(_mm_loadu_ps(src.as_ptr().add(i)), vinv, vlo, vhi);
        let q16 = _mm_packs_epi32(_mm_cvtps_epi32(c), _mm_setzero_si128());
        _mm_storel_epi64(dst.as_mut_ptr().add(i) as *mut __m128i, q16);
        i += 4;
    }
    i
}

/// SAFETY: requires SSE4.1.
#[target_feature(enable = "sse4.1")]
unsafe fn q_row_i32_sse41(src: &[f32], dst: &mut [i32], inv: f32, lo: f32, hi: f32) -> usize {
    let (vinv, vlo, vhi) = (_mm_set1_ps(inv), _mm_set1_ps(lo), _mm_set1_ps(hi));
    let mut i = 0;
    while i + 4 <= src.len() {
        let c = q4(_mm_loadu_ps(src.as_ptr().add(i)), vinv, vlo, vhi);
        _mm_storeu_si128(dst.as_mut_ptr().add(i) as *mut __m128i, _mm_cvtps_epi32(c));
        i += 4;
    }
    i
}

/// SAFETY: requires SSE4.1.
#[target_feature(enable = "sse4.1")]
unsafe fn qd_row_sse41(row: &mut [f32], inv: f32, step: f32, lo: f32, hi: f32) -> usize {
    let (vinv, vlo, vhi) = (_mm_set1_ps(inv), _mm_set1_ps(lo), _mm_set1_ps(hi));
    let vstep = _mm_set1_ps(step);
    let mut i = 0;
    while i + 4 <= row.len() {
        let c = q4(_mm_loadu_ps(row.as_ptr().add(i)), vinv, vlo, vhi);
        _mm_storeu_ps(row.as_mut_ptr().add(i), _mm_mul_ps(c, vstep));
        i += 4;
    }
    i
}

/// Scale, round-to-nearest-even, clamp — 8 lanes.
///
/// SAFETY: requires AVX2.
#[target_feature(enable = "avx2")]
unsafe fn q8(x: __m256, inv: __m256, lo: __m256, hi: __m256) -> __m256 {
    let r = _mm256_round_ps::<RNE>(_mm256_mul_ps(x, inv));
    _mm256_min_ps(_mm256_max_ps(r, lo), hi)
}

/// SAFETY: requires AVX2. Returns the vector-loop element count
/// (multiple of 8).
#[target_feature(enable = "avx2")]
unsafe fn q_row_i8_avx2(src: &[f32], dst: &mut [i8], inv: f32, lo: f32, hi: f32) -> usize {
    let (vinv, vlo, vhi) = (_mm256_set1_ps(inv), _mm256_set1_ps(lo), _mm256_set1_ps(hi));
    let mut i = 0;
    while i + 8 <= src.len() {
        let c = q8(_mm256_loadu_ps(src.as_ptr().add(i)), vinv, vlo, vhi);
        let q = _mm256_cvtps_epi32(c);
        let q16 = _mm_packs_epi32(_mm256_castsi256_si128(q), _mm256_extracti128_si256::<1>(q));
        let q8v = _mm_packs_epi16(q16, q16);
        _mm_storel_epi64(dst.as_mut_ptr().add(i) as *mut __m128i, q8v);
        i += 8;
    }
    i
}

/// SAFETY: requires AVX2.
#[target_feature(enable = "avx2")]
unsafe fn q_row_i16_avx2(src: &[f32], dst: &mut [i16], inv: f32, lo: f32, hi: f32) -> usize {
    let (vinv, vlo, vhi) = (_mm256_set1_ps(inv), _mm256_set1_ps(lo), _mm256_set1_ps(hi));
    let mut i = 0;
    while i + 8 <= src.len() {
        let c = q8(_mm256_loadu_ps(src.as_ptr().add(i)), vinv, vlo, vhi);
        let q = _mm256_cvtps_epi32(c);
        let q16 = _mm_packs_epi32(_mm256_castsi256_si128(q), _mm256_extracti128_si256::<1>(q));
        _mm_storeu_si128(dst.as_mut_ptr().add(i) as *mut __m128i, q16);
        i += 8;
    }
    i
}

/// SAFETY: requires AVX2.
#[target_feature(enable = "avx2")]
unsafe fn q_row_i32_avx2(src: &[f32], dst: &mut [i32], inv: f32, lo: f32, hi: f32) -> usize {
    let (vinv, vlo, vhi) = (_mm256_set1_ps(inv), _mm256_set1_ps(lo), _mm256_set1_ps(hi));
    let mut i = 0;
    while i + 8 <= src.len() {
        let c = q8(_mm256_loadu_ps(src.as_ptr().add(i)), vinv, vlo, vhi);
        _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, _mm256_cvtps_epi32(c));
        i += 8;
    }
    i
}

/// SAFETY: requires AVX2.
#[target_feature(enable = "avx2")]
unsafe fn qd_row_avx2(row: &mut [f32], inv: f32, step: f32, lo: f32, hi: f32) -> usize {
    let (vinv, vlo, vhi) = (_mm256_set1_ps(inv), _mm256_set1_ps(lo), _mm256_set1_ps(hi));
    let vstep = _mm256_set1_ps(step);
    let mut i = 0;
    while i + 8 <= row.len() {
        let c = q8(_mm256_loadu_ps(row.as_ptr().add(i)), vinv, vlo, vhi);
        _mm256_storeu_ps(row.as_mut_ptr().add(i), _mm256_mul_ps(c, vstep));
        i += 8;
    }
    i
}
