//! Scalar reference kernels: the portable implementations every SIMD
//! variant must match bit-for-bit.
//!
//! These bodies are the *definition* of the kernel contract — they are
//! exactly the loops the pre-SIMD code ran, so `HBFP_SIMD=off` reproduces
//! the historical results and the differential tests in
//! [`super::tests`] compare every vector path against these.

use super::Accum;
use crate::bfp::quant::{exp2i, quantize_value, Rounding};
use crate::bfp::tensor::MantissaElem;

/// `acc[c] += Σ_dk arow[dk] * panel[dk*nr + c]` for `c in 0..nr`.
///
/// `panel` is one k-major packed panel (at least `arow.len() * nr`
/// elements; trailing padded rows are ignored because the loop is bounded
/// by `arow`). The `qa == 0` skip is a pure speed branch: skipped rows
/// contribute zero to every lane.
pub fn mac_panel<EA: MantissaElem, EB: MantissaElem, A: Accum>(
    arow: &[EA],
    panel: &[EB],
    nr: usize,
    acc: &mut [A],
) {
    debug_assert!(acc.len() == nr);
    debug_assert!(panel.len() >= arow.len() * nr);
    for (dk, &qa) in arow.iter().enumerate() {
        if qa.to_i32() == 0 {
            continue;
        }
        let prow = &panel[dk * nr..(dk + 1) * nr];
        for (aj, &qb) in acc.iter_mut().zip(prow) {
            aj.mac(qa, qb);
        }
    }
}

/// Max |x| over a row, 0.0 for an empty row — the inner reduction of
/// the shared-exponent selection.
pub fn row_amax(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
}

/// Round-to-nearest-even quantization of one row onto the grid
/// `step = 2^(e - (m-1))`, storing packed mantissas. Identical per
/// element to [`quantize_value`] with [`Rounding::NearestEven`].
pub fn quantize_row_rne<E: MantissaElem>(src: &[f32], dst: &mut [E], e: i32, mantissa_bits: u32) {
    debug_assert_eq!(src.len(), dst.len());
    let mut r = Rounding::NearestEven;
    for (d, &x) in dst.iter_mut().zip(src) {
        *d = E::from_i32(quantize_value(x, e, mantissa_bits, &mut r));
    }
}

/// In-place round-to-nearest-even quantize + dequantize of one row (the
/// FP→BFP→FP converter boundary used by the trainer's input conversion).
pub fn quantize_dequant_row_rne(row: &mut [f32], e: i32, mantissa_bits: u32) {
    let m = mantissa_bits as i32;
    let step = exp2i(e - (m - 1));
    let mut r = Rounding::NearestEven;
    for x in row.iter_mut() {
        let q = quantize_value(*x, e, mantissa_bits, &mut r);
        *x = q as f32 * step;
    }
}
