//! aarch64 NEON kernels — the vector counterparts of `super::scalar` for
//! ARM cores (NEON is baseline on aarch64, so there is no runtime probe;
//! `HBFP_SIMD=off` still forces the scalar reference).
//!
//! Bit-identity argument mirrors `super::x86`: integer widening MACs
//! (`vmlal`/`vaddw`) are exact; `vrndnq_f32` is round-ties-even;
//! multiplication by the exact power-of-two reciprocal equals the scalar
//! division; `vmaxq_f32` trees equal the scalar max fold for finite
//! inputs.

#![allow(unsafe_op_in_unsafe_fn)]

use core::arch::aarch64::*;

use super::{grid, scalar, Accum};
use crate::bfp::tensor::MantissaElem;

/// NEON panel MAC: `acc[c] += Σ_dk arow[dk] * panel[dk*nr + c]`.
/// Returns false (untouched `acc`) when no vector kernel matches.
pub fn mac_panel_neon<EA: MantissaElem, EB: MantissaElem, A: Accum>(
    arow: &[EA],
    panel: &[EB],
    nr: usize,
    acc: &mut [A],
) -> bool {
    debug_assert!(acc.len() == nr && panel.len() >= arow.len() * nr);
    if nr % 8 != 0 {
        return false;
    }
    if let (Some(a), Some(p)) = (EA::as_i8s(arow), EB::as_i8s(panel)) {
        if let Some(acc32) = A::as_i32s(&mut *acc) {
            unsafe { mac_i8_i32(a, p, nr, acc32) };
            return true;
        }
        return false; // i8 x i8 with i64 acc: only at tile_k >= 2^17; scalar
    }
    if let (Some(a), Some(p)) = (EA::as_i16s(arow), EB::as_i16s(panel)) {
        if let Some(acc32) = A::as_i32s(&mut *acc) {
            unsafe { mac_i16_i32(a, p, nr, acc32) };
            return true;
        }
        if let Some(acc64) = A::as_i64s(&mut *acc) {
            unsafe { mac_i16_i64(a, p, nr, acc64) };
            return true;
        }
    }
    false
}

/// SAFETY: `nr % 8 == 0`, `acc.len() == nr`,
/// `panel.len() >= arow.len() * nr`.
#[target_feature(enable = "neon")]
unsafe fn mac_i8_i32(arow: &[i8], panel: &[i8], nr: usize, acc: &mut [i32]) {
    for c0 in (0..nr).step_by(8) {
        let mut acc_lo = vld1q_s32(acc.as_ptr().add(c0));
        let mut acc_hi = vld1q_s32(acc.as_ptr().add(c0 + 4));
        for (dk, &qa) in arow.iter().enumerate() {
            if qa == 0 {
                continue;
            }
            let a4 = vdup_n_s16(qa as i16);
            let b16 = vmovl_s8(vld1_s8(panel.as_ptr().add(dk * nr + c0)));
            // widening i16*i16 -> i32 MAC (both operands fit i16 exactly)
            acc_lo = vmlal_s16(acc_lo, vget_low_s16(b16), a4);
            acc_hi = vmlal_s16(acc_hi, vget_high_s16(b16), a4);
        }
        vst1q_s32(acc.as_mut_ptr().add(c0), acc_lo);
        vst1q_s32(acc.as_mut_ptr().add(c0 + 4), acc_hi);
    }
}

/// SAFETY: as [`mac_i8_i32`].
#[target_feature(enable = "neon")]
unsafe fn mac_i16_i32(arow: &[i16], panel: &[i16], nr: usize, acc: &mut [i32]) {
    for c0 in (0..nr).step_by(8) {
        let mut acc_lo = vld1q_s32(acc.as_ptr().add(c0));
        let mut acc_hi = vld1q_s32(acc.as_ptr().add(c0 + 4));
        for (dk, &qa) in arow.iter().enumerate() {
            if qa == 0 {
                continue;
            }
            let a4 = vdup_n_s16(qa);
            let b16 = vld1q_s16(panel.as_ptr().add(dk * nr + c0));
            acc_lo = vmlal_s16(acc_lo, vget_low_s16(b16), a4);
            acc_hi = vmlal_s16(acc_hi, vget_high_s16(b16), a4);
        }
        vst1q_s32(acc.as_mut_ptr().add(c0), acc_lo);
        vst1q_s32(acc.as_mut_ptr().add(c0 + 4), acc_hi);
    }
}

/// SAFETY: as [`mac_i8_i32`] (4-lane steps; `nr % 8 == 0` implies
/// `nr % 4 == 0`).
#[target_feature(enable = "neon")]
unsafe fn mac_i16_i64(arow: &[i16], panel: &[i16], nr: usize, acc: &mut [i64]) {
    for c0 in (0..nr).step_by(4) {
        let mut acc_lo = vld1q_s64(acc.as_ptr().add(c0));
        let mut acc_hi = vld1q_s64(acc.as_ptr().add(c0 + 2));
        for (dk, &qa) in arow.iter().enumerate() {
            if qa == 0 {
                continue;
            }
            let b32 = vmovl_s16(vld1_s16(panel.as_ptr().add(dk * nr + c0)));
            let prod = vmulq_s32(b32, vdupq_n_s32(qa as i32)); // exact: i16*i16 fits i32
            acc_lo = vaddw_s32(acc_lo, vget_low_s32(prod));
            acc_hi = vaddw_s32(acc_hi, vget_high_s32(prod));
        }
        vst1q_s64(acc.as_mut_ptr().add(c0), acc_lo);
        vst1q_s64(acc.as_mut_ptr().add(c0 + 2), acc_hi);
    }
}

/// NEON row max-magnitude.
pub fn row_amax_neon(xs: &[f32]) -> f32 {
    unsafe { amax(xs) }
}

/// SAFETY: plain NEON (baseline on aarch64).
#[target_feature(enable = "neon")]
unsafe fn amax(xs: &[f32]) -> f32 {
    let mut m = vdupq_n_f32(0.0);
    let mut i = 0;
    while i + 4 <= xs.len() {
        m = vmaxq_f32(m, vabsq_f32(vld1q_f32(xs.as_ptr().add(i))));
        i += 4;
    }
    let mut amax = vmaxvq_f32(m);
    for &x in &xs[i..] {
        amax = amax.max(x.abs());
    }
    amax
}

/// NEON nearest-even row quantization into packed mantissas.
pub fn quantize_row_rne_neon<E: MantissaElem>(
    src: &[f32],
    dst: &mut [E],
    e: i32,
    mantissa_bits: u32,
) -> bool {
    debug_assert_eq!(src.len(), dst.len());
    let (inv, _, lo, hi) = grid(e, mantissa_bits);
    let done = if let Some(d) = E::as_i8s_mut(&mut *dst) {
        unsafe { q_row_i8(src, d, inv, lo, hi) }
    } else if let Some(d) = E::as_i16s_mut(&mut *dst) {
        unsafe { q_row_i16(src, d, inv, lo, hi) }
    } else if let Some(d) = E::as_i32s_mut(&mut *dst) {
        unsafe { q_row_i32(src, d, inv, lo, hi) }
    } else {
        return false;
    };
    scalar::quantize_row_rne(&src[done..], &mut dst[done..], e, mantissa_bits);
    true
}

/// NEON in-place nearest-even quantize + dequantize of one row.
pub fn quantize_dequant_row_rne_neon(row: &mut [f32], e: i32, mantissa_bits: u32) {
    let (inv, step, lo, hi) = grid(e, mantissa_bits);
    let done = unsafe { qd_row(row, inv, step, lo, hi) };
    scalar::quantize_dequant_row_rne(&mut row[done..], e, mantissa_bits);
}

/// Scale, round-ties-even, clamp — 4 lanes; result integral in [lo, hi].
///
/// SAFETY: plain NEON.
#[target_feature(enable = "neon")]
unsafe fn q4(x: float32x4_t, inv: float32x4_t, lo: float32x4_t, hi: float32x4_t) -> float32x4_t {
    vminq_f32(vmaxq_f32(vrndnq_f32(vmulq_f32(x, inv)), lo), hi)
}

/// SAFETY: plain NEON. Returns the vector-loop element count.
#[target_feature(enable = "neon")]
unsafe fn q_row_i8(src: &[f32], dst: &mut [i8], inv: f32, lo: f32, hi: f32) -> usize {
    let (vinv, vlo, vhi) = (vdupq_n_f32(inv), vdupq_n_f32(lo), vdupq_n_f32(hi));
    let mut i = 0;
    while i + 8 <= src.len() {
        let c0 = q4(vld1q_f32(src.as_ptr().add(i)), vinv, vlo, vhi);
        let c1 = q4(vld1q_f32(src.as_ptr().add(i + 4)), vinv, vlo, vhi);
        // cvt truncates, but the operand is integral after vrndn -> exact
        let q16 = vcombine_s16(vqmovn_s32(vcvtq_s32_f32(c0)), vqmovn_s32(vcvtq_s32_f32(c1)));
        vst1_s8(dst.as_mut_ptr().add(i), vqmovn_s16(q16));
        i += 8;
    }
    i
}

/// SAFETY: plain NEON.
#[target_feature(enable = "neon")]
unsafe fn q_row_i16(src: &[f32], dst: &mut [i16], inv: f32, lo: f32, hi: f32) -> usize {
    let (vinv, vlo, vhi) = (vdupq_n_f32(inv), vdupq_n_f32(lo), vdupq_n_f32(hi));
    let mut i = 0;
    while i + 8 <= src.len() {
        let c0 = q4(vld1q_f32(src.as_ptr().add(i)), vinv, vlo, vhi);
        let c1 = q4(vld1q_f32(src.as_ptr().add(i + 4)), vinv, vlo, vhi);
        let q16 = vcombine_s16(vqmovn_s32(vcvtq_s32_f32(c0)), vqmovn_s32(vcvtq_s32_f32(c1)));
        vst1q_s16(dst.as_mut_ptr().add(i), q16);
        i += 8;
    }
    i
}

/// SAFETY: plain NEON.
#[target_feature(enable = "neon")]
unsafe fn q_row_i32(src: &[f32], dst: &mut [i32], inv: f32, lo: f32, hi: f32) -> usize {
    let (vinv, vlo, vhi) = (vdupq_n_f32(inv), vdupq_n_f32(lo), vdupq_n_f32(hi));
    let mut i = 0;
    while i + 4 <= src.len() {
        let c = q4(vld1q_f32(src.as_ptr().add(i)), vinv, vlo, vhi);
        vst1q_s32(dst.as_mut_ptr().add(i), vcvtq_s32_f32(c));
        i += 4;
    }
    i
}

/// SAFETY: plain NEON.
#[target_feature(enable = "neon")]
unsafe fn qd_row(row: &mut [f32], inv: f32, step: f32, lo: f32, hi: f32) -> usize {
    let (vinv, vlo, vhi) = (vdupq_n_f32(inv), vdupq_n_f32(lo), vdupq_n_f32(hi));
    let vstep = vdupq_n_f32(step);
    let mut i = 0;
    while i + 4 <= row.len() {
        let c = q4(vld1q_f32(row.as_ptr().add(i)), vinv, vlo, vhi);
        vst1q_f32(row.as_mut_ptr().add(i), vmulq_f32(c, vstep));
        i += 4;
    }
    i
}
