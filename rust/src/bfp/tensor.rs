//! `BfpTensor`: a 2-D tensor stored as integer mantissas with one shared
//! exponent per (tile x tile) tile — the paper's storage format, including
//! the §4.2 optimizations (tiling, wide weight storage).
//!
//! Mantissas are stored as `i32` regardless of width (hardware would pack
//! them; the *numerics* only depend on the width, and the area model in
//! `crate::hw` accounts for the true packed cost).

use anyhow::{anyhow, Result};

use super::quant::{self, Rounding};

/// Tile granularity for exponent sharing: a whole-tensor exponent or
/// square tiles of the given edge length.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileSize {
    Whole,
    Edge(usize),
}

impl TileSize {
    pub fn edge_or(&self, rows: usize, cols: usize) -> (usize, usize) {
        match self {
            TileSize::Whole => (rows.max(1), cols.max(1)),
            TileSize::Edge(t) => (*t, *t),
        }
    }
}

/// A 2-D BFP tensor: row-major mantissas + per-tile exponents.
#[derive(Debug, Clone)]
pub struct BfpTensor {
    pub rows: usize,
    pub cols: usize,
    pub mantissa_bits: u32,
    pub tile: TileSize,
    /// Row-major mantissas, `rows * cols`.
    pub mantissas: Vec<i32>,
    /// Exponents, one per tile, row-major over the tile grid.
    pub exponents: Vec<i32>,
    tiles_per_row: usize,
    tile_rows: usize,
    tile_cols: usize,
}

impl BfpTensor {
    /// Quantize an f32 tensor into BFP storage.
    pub fn from_f32(
        data: &[f32],
        rows: usize,
        cols: usize,
        mantissa_bits: u32,
        tile: TileSize,
        rounding: &mut Rounding,
    ) -> Result<BfpTensor> {
        if data.len() != rows * cols {
            return Err(anyhow!("data len {} != {rows}x{cols}", data.len()));
        }
        if !(2..=24).contains(&mantissa_bits) {
            return Err(anyhow!("mantissa width {mantissa_bits} unsupported"));
        }
        let (th, tw) = tile.edge_or(rows, cols);
        let tiles_r = rows.div_ceil(th).max(1);
        let tiles_c = cols.div_ceil(tw).max(1);
        let mut mantissas = vec![0i32; rows * cols];
        let mut exponents = Vec::with_capacity(tiles_r * tiles_c);
        let mut block = Vec::with_capacity(th * tw);
        for tr in 0..tiles_r {
            for tc in 0..tiles_c {
                let r0 = tr * th;
                let c0 = tc * tw;
                let r1 = (r0 + th).min(rows);
                let c1 = (c0 + tw).min(cols);
                block.clear();
                for r in r0..r1 {
                    block.extend_from_slice(&data[r * cols + c0..r * cols + c1]);
                }
                let e = quant::block_exponent(&block);
                for r in r0..r1 {
                    for c in c0..c1 {
                        mantissas[r * cols + c] =
                            quant::quantize_value(data[r * cols + c], e, mantissa_bits, rounding);
                    }
                }
                exponents.push(e);
            }
        }
        Ok(BfpTensor {
            rows,
            cols,
            mantissa_bits,
            tile,
            mantissas,
            exponents,
            tiles_per_row: tiles_c,
            tile_rows: th,
            tile_cols: tw,
        })
    }

    /// Exponent of the tile containing element (r, c).
    #[inline]
    pub fn exponent_at(&self, r: usize, c: usize) -> i32 {
        let tr = r / self.tile_rows;
        let tc = c / self.tile_cols;
        self.exponents[tr * self.tiles_per_row + tc]
    }

    #[inline]
    pub fn mantissa_at(&self, r: usize, c: usize) -> i32 {
        self.mantissas[r * self.cols + c]
    }

    /// Dequantize back to f32 (the BFP→FP unit).
    pub fn to_f32(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[r * self.cols + c] = quant::dequantize_value(
                    self.mantissa_at(r, c),
                    self.exponent_at(r, c),
                    self.mantissa_bits,
                );
            }
        }
        out
    }

    /// Re-quantize to a narrower mantissa width *in place of* re-reading
    /// f32 data: this is the §4.2 wide-weight-storage read path, where the
    /// fwd/bwd passes consume only the `narrow` most significant bits of
    /// the stored wide mantissas.
    pub fn narrow_view(&self, narrow_bits: u32, rounding: &mut Rounding) -> Result<BfpTensor> {
        if narrow_bits > self.mantissa_bits {
            return Err(anyhow!(
                "narrow width {narrow_bits} exceeds storage width {}",
                self.mantissa_bits
            ));
        }
        let shift = self.mantissa_bits - narrow_bits;
        let mut out = self.clone();
        out.mantissa_bits = narrow_bits;
        if shift == 0 {
            return Ok(out);
        }
        let hi = (1i32 << (narrow_bits - 1)) - 1;
        let lo = -(1i32 << (narrow_bits - 1));
        for q in out.mantissas.iter_mut() {
            let v = *q as f32 / (1i64 << shift) as f32;
            let r = match rounding {
                Rounding::NearestEven => v.round_ties_even(),
                Rounding::Stochastic(rng) => (v + rng.next_f32()).floor(),
            };
            *q = (r as i32).clamp(lo, hi);
        }
        Ok(out)
    }

    /// Memory footprint in bits of the BFP representation (mantissas packed
    /// at their true width + one 8-bit exponent per tile) — the quantity
    /// behind the paper's "2x more compact models / up to 4x bandwidth"
    /// claims.
    pub fn storage_bits(&self) -> usize {
        self.mantissas.len() * self.mantissa_bits as usize + self.exponents.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{check, Gen};

    fn roundtrip(data: &[f32], rows: usize, cols: usize, m: u32, tile: TileSize) -> Vec<f32> {
        BfpTensor::from_f32(data, rows, cols, m, tile, &mut Rounding::NearestEven)
            .unwrap()
            .to_f32()
    }

    #[test]
    fn whole_tensor_single_exponent() {
        let t = BfpTensor::from_f32(
            &[1.0, 2.0, 3.0, 4.0],
            2,
            2,
            8,
            TileSize::Whole,
            &mut Rounding::NearestEven,
        )
        .unwrap();
        assert_eq!(t.exponents.len(), 1);
    }

    #[test]
    fn tiled_exponent_count() {
        let data = vec![1.0f32; 50 * 70];
        let t = BfpTensor::from_f32(&data, 50, 70, 8, TileSize::Edge(24), &mut Rounding::NearestEven)
            .unwrap();
        assert_eq!(t.exponents.len(), 3 * 3); // ceil(50/24) x ceil(70/24)
    }

    #[test]
    fn per_tile_exponents_capture_mixed_scales() {
        // top half tiny, bottom half large: tiled quantization must keep
        // the tiny half alive; whole-tensor must crush it.
        let rows = 32;
        let cols = 32;
        let mut data = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                data[r * cols + c] = if r < 16 { 1e-4 } else { 1.0 } * ((c + 1) as f32 / 8.0);
            }
        }
        let tiled = roundtrip(&data, rows, cols, 8, TileSize::Edge(16));
        let whole = roundtrip(&data, rows, cols, 8, TileSize::Whole);
        let err = |q: &[f32]| {
            data.iter().zip(q).map(|(a, b)| (a - b).abs()).sum::<f32>() / data.len() as f32
        };
        assert!(err(&tiled) < err(&whole) / 10.0, "{} vs {}", err(&tiled), err(&whole));
    }

    #[test]
    fn narrow_view_matches_direct_quantization_scale() {
        check("narrow view error bounded", 100, |g: &mut Gen| {
            let rows = g.int(1, 20);
            let cols = g.int(1, 20);
            let data = g.vec_f32(rows * cols, 2);
            let wide = BfpTensor::from_f32(
                &data,
                rows,
                cols,
                16,
                TileSize::Edge(8),
                &mut Rounding::NearestEven,
            )
            .unwrap();
            let narrow = wide.narrow_view(8, &mut Rounding::NearestEven).unwrap();
            let direct = BfpTensor::from_f32(
                &data,
                rows,
                cols,
                8,
                TileSize::Edge(8),
                &mut Rounding::NearestEven,
            )
            .unwrap();
            // narrow-from-wide may differ from direct by <= 1 ulp of the
            // narrow grid (double rounding), never more.
            for (a, b) in narrow.to_f32().iter().zip(direct.to_f32().iter()) {
                let ulp = (a - b).abs();
                let step = quant::exp2i(
                    quant::block_exponent(&data).max(quant::E_MIN) - 7,
                );
                prop_assert!(ulp <= step * 1.001, "narrow {a} vs direct {b}");
            }
            Ok(())
        });
    }

    #[test]
    fn narrow_view_rejects_widening() {
        let t = BfpTensor::from_f32(&[1.0], 1, 1, 8, TileSize::Whole, &mut Rounding::NearestEven)
            .unwrap();
        assert!(t.narrow_view(12, &mut Rounding::NearestEven).is_err());
    }

    #[test]
    fn storage_bits_compression() {
        // hbfp8 with t=24 on a 48x48 tensor: 8 bits/elem + 4 exponents.
        let data = vec![1.0f32; 48 * 48];
        let t = BfpTensor::from_f32(&data, 48, 48, 8, TileSize::Edge(24), &mut Rounding::NearestEven)
            .unwrap();
        assert_eq!(t.storage_bits(), 48 * 48 * 8 + 4 * 8);
        // 4x smaller than f32 minus exponent overhead (the paper's "up to 4x")
        let fp32_bits = 48 * 48 * 32;
        assert!((fp32_bits as f64 / t.storage_bits() as f64) > 3.9);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(BfpTensor::from_f32(&[1.0; 5], 2, 2, 8, TileSize::Whole, &mut Rounding::NearestEven)
            .is_err());
    }

    #[test]
    fn roundtrip_error_bound_property() {
        check("roundtrip bounded", 150, |g: &mut Gen| {
            let rows = g.int(1, 30);
            let cols = g.int(1, 30);
            let data = g.vec_f32(rows * cols, 4);
            let m = *g.pick(&[4u32, 8, 12]);
            let tile = *g.pick(&[TileSize::Whole, TileSize::Edge(8), TileSize::Edge(24)]);
            let t =
                BfpTensor::from_f32(&data, rows, cols, m, tile, &mut Rounding::NearestEven).unwrap();
            let back = t.to_f32();
            // every element's error is under one step of its own tile's grid
            for r in 0..rows {
                for c in 0..cols {
                    let x = data[r * cols + c];
                    let y = back[r * cols + c];
                    let step = quant::exp2i(t.exponent_at(r, c) - (m as i32 - 1));
                    prop_assert!((x - y).abs() <= step * 1.0001, "x={x} y={y} step={step}");
                }
            }
            Ok(())
        });
    }
}
