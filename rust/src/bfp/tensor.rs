//! `BfpTensor`: a 2-D tensor stored as integer mantissas with one shared
//! exponent per (tile x tile) tile — the paper's storage format, including
//! the §4.2 optimizations (tiling, wide weight storage).
//!
//! Mantissas are stored **packed at their true width class**: `i8` for
//! widths <= 8, `i16` for <= 16, `i32` above ([`Mantissas`]). That is the
//! representation the hardware streams, and in software it buys 2–4x less
//! memory traffic plus narrow integer inner loops for the MAC kernels
//! (`super::matmul`). Quantization is parallelized over tile rows with
//! per-tile RNG substreams, so stochastic rounding is reproducible for any
//! thread count.

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use super::kernels;
use super::panels::{self, matmul_tile_edge, PackedPanels};
use super::quant::{self, Rounding, TileRounding};
use crate::util::{pool, worker_threads};

/// Below this many elements the quantizers stay single-threaded (thread
/// spawn costs more than the work).
const PAR_MIN_ELEMS: usize = 1 << 14;

/// Tile granularity for exponent sharing: a whole-tensor exponent or
/// square tiles of the given edge length.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileSize {
    Whole,
    Edge(usize),
}

impl TileSize {
    pub fn edge_or(&self, rows: usize, cols: usize) -> (usize, usize) {
        match self {
            TileSize::Whole => (rows.max(1), cols.max(1)),
            TileSize::Edge(t) => (*t, *t),
        }
    }
}

/// One element of packed mantissa storage. The three implementations
/// (`i8`, `i16`, `i32`) are what [`Mantissas`] can hold; the matmul
/// kernels are generic over this trait so each width class gets its own
/// monomorphized (autovectorizable) inner loop.
pub trait MantissaElem: Copy + Send + Sync + 'static {
    /// Widest two's-complement mantissa (in bits) this element type holds.
    const MAX_BITS: u32;

    fn from_i32(v: i32) -> Self;
    fn to_i32(self) -> i32;

    /// Concrete-type downcasts for the SIMD kernel dispatch
    /// (`bfp::kernels`): each returns `Some` only on the matching
    /// element type, so a generic kernel caller can route `i8`/`i16`
    /// storage to the vector paths and everything else to scalar.
    fn as_i8s(s: &[Self]) -> Option<&[i8]> {
        let _ = s;
        None
    }

    fn as_i16s(s: &[Self]) -> Option<&[i16]> {
        let _ = s;
        None
    }

    fn as_i8s_mut(s: &mut [Self]) -> Option<&mut [i8]> {
        let _ = s;
        None
    }

    fn as_i16s_mut(s: &mut [Self]) -> Option<&mut [i16]> {
        let _ = s;
        None
    }

    fn as_i32s_mut(s: &mut [Self]) -> Option<&mut [i32]> {
        let _ = s;
        None
    }
}

impl MantissaElem for i8 {
    const MAX_BITS: u32 = 8;

    #[inline(always)]
    fn from_i32(v: i32) -> i8 {
        debug_assert!(i8::try_from(v).is_ok(), "mantissa {v} does not fit i8");
        v as i8
    }

    #[inline(always)]
    fn to_i32(self) -> i32 {
        self as i32
    }

    fn as_i8s(s: &[i8]) -> Option<&[i8]> {
        Some(s)
    }

    fn as_i8s_mut(s: &mut [i8]) -> Option<&mut [i8]> {
        Some(s)
    }
}

impl MantissaElem for i16 {
    const MAX_BITS: u32 = 16;

    #[inline(always)]
    fn from_i32(v: i32) -> i16 {
        debug_assert!(i16::try_from(v).is_ok(), "mantissa {v} does not fit i16");
        v as i16
    }

    #[inline(always)]
    fn to_i32(self) -> i32 {
        self as i32
    }

    fn as_i16s(s: &[i16]) -> Option<&[i16]> {
        Some(s)
    }

    fn as_i16s_mut(s: &mut [i16]) -> Option<&mut [i16]> {
        Some(s)
    }
}

impl MantissaElem for i32 {
    const MAX_BITS: u32 = 32;

    #[inline(always)]
    fn from_i32(v: i32) -> i32 {
        v
    }

    #[inline(always)]
    fn to_i32(self) -> i32 {
        self
    }

    fn as_i32s_mut(s: &mut [i32]) -> Option<&mut [i32]> {
        Some(s)
    }
}

/// Width-classed packed mantissa storage: the narrowest integer vector
/// that holds the tensor's mantissa width.
#[derive(Debug, Clone, PartialEq)]
pub enum Mantissas {
    I8(Vec<i8>),
    I16(Vec<i16>),
    I32(Vec<i32>),
}

impl Mantissas {
    /// Zero-filled storage of the right width class for `mantissa_bits`.
    pub fn for_width(mantissa_bits: u32, len: usize) -> Mantissas {
        if mantissa_bits <= 8 {
            Mantissas::I8(vec![0; len])
        } else if mantissa_bits <= 16 {
            Mantissas::I16(vec![0; len])
        } else {
            Mantissas::I32(vec![0; len])
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Mantissas::I8(v) => v.len(),
            Mantissas::I16(v) => v.len(),
            Mantissas::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Element at `i`, sign-extended.
    #[inline]
    pub fn get(&self, i: usize) -> i32 {
        match self {
            Mantissas::I8(v) => v[i] as i32,
            Mantissas::I16(v) => v[i] as i32,
            Mantissas::I32(v) => v[i],
        }
    }

    /// Store `q` at `i` (must fit the storage class; debug-asserted).
    #[inline]
    pub fn set(&mut self, i: usize, q: i32) {
        match self {
            Mantissas::I8(v) => v[i] = <i8 as MantissaElem>::from_i32(q),
            Mantissas::I16(v) => v[i] = <i16 as MantissaElem>::from_i32(q),
            Mantissas::I32(v) => v[i] = q,
        }
    }

    /// Bits per stored element (8 / 16 / 32).
    pub fn elem_bits(&self) -> usize {
        match self {
            Mantissas::I8(_) => 8,
            Mantissas::I16(_) => 16,
            Mantissas::I32(_) => 32,
        }
    }

    /// Actual heap bytes of the packed buffer.
    pub fn heap_bytes(&self) -> usize {
        self.len() * self.elem_bits() / 8
    }
}

/// A 2-D BFP tensor: row-major packed mantissas + per-tile exponents.
#[derive(Debug)]
pub struct BfpTensor {
    pub rows: usize,
    pub cols: usize,
    pub mantissa_bits: u32,
    pub tile: TileSize,
    /// Row-major mantissas, `rows * cols`, packed at the width class.
    pub mantissas: Mantissas,
    /// Exponents, one per tile, row-major over the tile grid.
    pub exponents: Vec<i32>,
    tiles_per_row: usize,
    tile_rows: usize,
    tile_cols: usize,
    /// Lazily-built packed B-panel layout (see [`PackedPanels`]): packed
    /// once on first use as a matmul B operand (at the active SIMD
    /// family's panel width), then reused by every subsequent GEMM — the
    /// resident-weight amortization. Cleared by
    /// [`BfpTensor::clear_panel_cache`]; constructors start empty, so
    /// derived tensors (`narrow_view`) never inherit stale panels.
    panels: Mutex<Option<PanelCache>>,
}

/// A cached panel layout plus, in debug builds, the content generation
/// it was packed from.
#[derive(Clone)]
struct PanelCache {
    panels: Arc<PackedPanels>,
    /// Debug-build stale-cache guard. The public `mantissas`/`exponents`
    /// fields make a true mutation counter impossible (field writes
    /// can't be intercepted), so the "generation" is a content
    /// fingerprint taken at pack time and re-derived on every cache hit:
    /// a mutation without [`BfpTensor::clear_panel_cache`] panics at the
    /// next matmul instead of silently serving stale panels. The rehash
    /// is full-coverage on purpose (a sampled hash would miss exactly
    /// the single-element mutations it guards against); at O(k·n) per
    /// hit it is bounded by 1/m of the matmul's own MAC work, and
    /// release builds skip it entirely.
    #[cfg(debug_assertions)]
    generation: u64,
}

impl PanelCache {
    fn new(panels: Arc<PackedPanels>, _tensor: &BfpTensor) -> PanelCache {
        #[cfg(debug_assertions)]
        return PanelCache { panels, generation: _tensor.content_generation() };
        #[cfg(not(debug_assertions))]
        return PanelCache { panels };
    }
}

impl Clone for BfpTensor {
    fn clone(&self) -> BfpTensor {
        BfpTensor {
            rows: self.rows,
            cols: self.cols,
            mantissa_bits: self.mantissa_bits,
            tile: self.tile,
            mantissas: self.mantissas.clone(),
            exponents: self.exponents.clone(),
            tiles_per_row: self.tiles_per_row,
            tile_rows: self.tile_rows,
            tile_cols: self.tile_cols,
            // panels describe the same mantissas, so the clone may share them
            panels: Mutex::new(self.panels.lock().unwrap().clone()),
        }
    }
}

/// Validated tile geometry shared by the constructors.
struct TileGrid {
    rows: usize,
    cols: usize,
    th: usize,
    tw: usize,
    tiles_r: usize,
    tiles_c: usize,
}

fn tile_grid(rows: usize, cols: usize, tile: TileSize) -> Result<TileGrid> {
    if let TileSize::Edge(0) = tile {
        return Err(anyhow!("tile edge must be nonzero"));
    }
    let (th, tw) = tile.edge_or(rows, cols);
    Ok(TileGrid {
        rows,
        cols,
        th,
        tw,
        tiles_r: rows.div_ceil(th).max(1),
        tiles_c: cols.div_ceil(tw).max(1),
    })
}

pub(crate) fn check_width(mantissa_bits: u32) -> Result<()> {
    if !(2..=24).contains(&mantissa_bits) {
        return Err(anyhow!("mantissa width {mantissa_bits} unsupported"));
    }
    Ok(())
}

/// The next wider mantissa *storage class* above `bits`: i8 (8), i16
/// (16), i32 (24). `None` at the top of the ladder. This is the step the
/// guard layer's graceful-degradation ladder climbs: when a width class
/// shows saturation or clamp-rail pressure (or the watchdog rolls a
/// diverged run back), training continues one class wider instead of
/// dying — the accuracy/density trade at the heart of the HBFP design.
pub fn next_wider_class(bits: u32) -> Option<u32> {
    match bits {
        0..=7 => Some(8),
        8..=15 => Some(16),
        16..=23 => Some(24),
        _ => None,
    }
}

impl BfpTensor {
    /// Quantize an f32 tensor into packed BFP storage, using the default
    /// worker-thread budget. For an explicit thread cap, tile default, or
    /// other policy, quantize through a
    /// [`crate::bfp::BfpContext`] (`ctx.quantize(...)`).
    ///
    /// **NaN/Inf contract**: non-finite input is rejected with a typed
    /// [`super::stats::NonFiniteError`] (full scan, before any tile is
    /// touched). A NaN or Inf would otherwise corrupt the *shared*
    /// exponent for its whole tile — every co-tiled value, not just the
    /// bad one — and the damage would differ by SIMD kernel family (see
    /// `bfp/quant.rs`). Callers that can tolerate scanning less than
    /// every element route through a `BfpContext` guard policy instead.
    pub fn from_f32(
        data: &[f32],
        rows: usize,
        cols: usize,
        mantissa_bits: u32,
        tile: TileSize,
        rounding: &mut Rounding,
    ) -> Result<BfpTensor> {
        if let Some(e) = super::stats::scan_nonfinite(data, 1).error(data) {
            return Err(anyhow::Error::new(e).context("BfpTensor::from_f32"));
        }
        let threads = worker_threads();
        Self::from_f32_impl(data, rows, cols, mantissa_bits, tile, rounding, threads)
    }

    /// Quantize with an explicit thread cap.
    #[deprecated(note = "use BfpContext::from_env().with_threads(n).quantize(...)")]
    pub fn from_f32_with_threads(
        data: &[f32],
        rows: usize,
        cols: usize,
        mantissa_bits: u32,
        tile: TileSize,
        rounding: &mut Rounding,
        max_threads: usize,
    ) -> Result<BfpTensor> {
        Self::from_f32_impl(data, rows, cols, mantissa_bits, tile, rounding, max_threads)
    }

    /// Shared converter body: quantize under an explicit thread cap.
    /// Results are bit-identical for any `max_threads` (stochastic
    /// rounding uses per-tile substreams). Public callers go through
    /// [`BfpTensor::from_f32`] or a `BfpContext`.
    pub(crate) fn from_f32_impl(
        data: &[f32],
        rows: usize,
        cols: usize,
        mantissa_bits: u32,
        tile: TileSize,
        rounding: &mut Rounding,
        max_threads: usize,
    ) -> Result<BfpTensor> {
        if data.len() != rows * cols {
            return Err(anyhow!("data len {} != {rows}x{cols}", data.len()));
        }
        check_width(mantissa_bits)?;
        let g = tile_grid(rows, cols, tile)?;
        let mut mantissas = Mantissas::for_width(mantissa_bits, rows * cols);
        let mut exponents = vec![quant::E_MIN; g.tiles_r * g.tiles_c];
        if rows * cols > 0 {
            let mode = TileRounding::capture(rounding);
            let threads = pool::par_threads_simd(
                rows * cols,
                PAR_MIN_ELEMS,
                kernels::converter_floor_scale(kernels::active(), mode),
                max_threads,
                g.tiles_r,
            );
            match &mut mantissas {
                Mantissas::I8(v) => {
                    quantize_bands::<i8>(data, v, &mut exponents, &g, mantissa_bits, mode, threads)
                }
                Mantissas::I16(v) => {
                    quantize_bands::<i16>(data, v, &mut exponents, &g, mantissa_bits, mode, threads)
                }
                Mantissas::I32(v) => {
                    quantize_bands::<i32>(data, v, &mut exponents, &g, mantissa_bits, mode, threads)
                }
            }
        }
        Ok(BfpTensor {
            rows,
            cols,
            mantissa_bits,
            tile,
            mantissas,
            exponents,
            tiles_per_row: g.tiles_c,
            tile_rows: g.th,
            tile_cols: g.tw,
            panels: Mutex::new(None),
        })
    }

    /// Assemble a tensor from raw parts (deserialization, adversarial
    /// tests). Validates lengths, exponent range, and that every mantissa
    /// is representable in `mantissa_bits` two's complement — the
    /// invariant the matmul overflow bound relies on.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        mantissa_bits: u32,
        tile: TileSize,
        mantissas: Mantissas,
        exponents: Vec<i32>,
    ) -> Result<BfpTensor> {
        check_width(mantissa_bits)?;
        let g = tile_grid(rows, cols, tile)?;
        if mantissas.len() != rows * cols {
            return Err(anyhow!("mantissa len {} != {rows}x{cols}", mantissas.len()));
        }
        if exponents.len() != g.tiles_r * g.tiles_c {
            return Err(anyhow!(
                "exponent len {} != {}x{} tiles",
                exponents.len(),
                g.tiles_r,
                g.tiles_c
            ));
        }
        let lo = -(1i32 << (mantissa_bits - 1));
        let hi = (1i32 << (mantissa_bits - 1)) - 1;
        for i in 0..mantissas.len() {
            let q = mantissas.get(i);
            if q < lo || q > hi {
                return Err(anyhow!("mantissa {q} at {i} outside {mantissa_bits}-bit range"));
            }
        }
        for &e in &exponents {
            if !(quant::E_MIN..=quant::E_MAX).contains(&e) {
                return Err(anyhow!("exponent {e} outside [{}, {}]", quant::E_MIN, quant::E_MAX));
            }
        }
        Ok(BfpTensor {
            rows,
            cols,
            mantissa_bits,
            tile,
            mantissas,
            exponents,
            tiles_per_row: g.tiles_c,
            tile_rows: g.th,
            tile_cols: g.tw,
            panels: Mutex::new(None),
        })
    }

    /// Packed B-panel layout for this tensor as a matmul B operand at
    /// the active SIMD family's panel width (see [`PackedPanels`]):
    /// built on first call, cached, and shared by every subsequent GEMM
    /// — the software analogue of weights held resident next to the MAC
    /// array. Callers that mutate `mantissas` or `exponents` through the
    /// public fields must call [`BfpTensor::clear_panel_cache`]
    /// afterwards (debug builds panic at the next use otherwise).
    pub fn packed_panels(&self) -> Arc<PackedPanels> {
        self.packed_panels_nr(kernels::active_panel_nr())
    }

    /// [`BfpTensor::packed_panels`] at an explicit panel width — the
    /// forced-ISA matmul path (`BfpContext::with_isa`) and the bench
    /// ladder's scalar rungs. The cache holds one layout: asking for a
    /// different width repacks and replaces it.
    pub fn packed_panels_nr(&self, nr: usize) -> Arc<PackedPanels> {
        let t = matmul_tile_edge(self.tile, self.rows);
        let mut guard = self.panels.lock().unwrap();
        if let Some(cache) = guard.as_ref() {
            if cache.panels.t == t && cache.panels.nr == nr {
                #[cfg(debug_assertions)]
                assert!(
                    cache.generation == self.content_generation(),
                    "stale panel cache: BfpTensor::mantissas/exponents were mutated through \
                     the public fields without clear_panel_cache()"
                );
                return Arc::clone(&cache.panels);
            }
        }
        let p = Arc::new(panels::pack_panels(self, t, nr));
        *guard = Some(PanelCache::new(Arc::clone(&p), self));
        p
    }

    /// Debug-build content fingerprint (FNV-1a over mantissa bytes and
    /// exponents) backing the stale-panel-cache guard.
    #[cfg(debug_assertions)]
    fn content_generation(&self) -> u64 {
        fn eat(h: u64, b: u64) -> u64 {
            (h ^ b).wrapping_mul(0x100_0000_01b3)
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        match &self.mantissas {
            Mantissas::I8(v) => {
                for &x in v {
                    h = eat(h, x as u8 as u64);
                }
            }
            Mantissas::I16(v) => {
                for &x in v {
                    h = eat(h, x as u16 as u64);
                }
            }
            Mantissas::I32(v) => {
                for &x in v {
                    h = eat(h, x as u32 as u64);
                }
            }
        }
        for &e in &self.exponents {
            h = eat(h, e as u32 as u64);
        }
        h
    }

    /// Drop the cached panel layout (next matmul repacks). Needed only
    /// after in-place mantissa/exponent mutation, and by the cold-pack
    /// bench rung.
    pub fn clear_panel_cache(&self) {
        *self.panels.lock().unwrap() = None;
    }

    /// Whether a packed panel layout is currently cached (test hook).
    pub fn has_packed_panels(&self) -> bool {
        self.panels.lock().unwrap().is_some()
    }

    /// Exponent of the tile containing element (r, c).
    #[inline]
    pub fn exponent_at(&self, r: usize, c: usize) -> i32 {
        let tr = r / self.tile_rows;
        let tc = c / self.tile_cols;
        self.exponents[tr * self.tiles_per_row + tc]
    }

    #[inline]
    pub fn mantissa_at(&self, r: usize, c: usize) -> i32 {
        self.mantissas.get(r * self.cols + c)
    }

    /// Dequantize back to f32 (the BFP→FP unit).
    pub fn to_f32(&self) -> Vec<f32> {
        match &self.mantissas {
            Mantissas::I8(v) => self.dequantize_slice(v),
            Mantissas::I16(v) => self.dequantize_slice(v),
            Mantissas::I32(v) => self.dequantize_slice(v),
        }
    }

    fn dequantize_slice<E: MantissaElem>(&self, q: &[E]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[r * self.cols + c] = quant::dequantize_value(
                    q[r * self.cols + c].to_i32(),
                    self.exponent_at(r, c),
                    self.mantissa_bits,
                );
            }
        }
        out
    }

    /// Re-quantize to a narrower mantissa width *in place of* re-reading
    /// f32 data: this is the §4.2 wide-weight-storage read path, where the
    /// fwd/bwd passes consume only the `narrow` most significant bits of
    /// the stored wide mantissas. The result is repacked into the narrow
    /// width class (a 16-bit master narrowed to 8 bits really is half the
    /// bytes).
    pub fn narrow_view(&self, narrow_bits: u32, rounding: &mut Rounding) -> Result<BfpTensor> {
        if narrow_bits > self.mantissa_bits {
            return Err(anyhow!(
                "narrow width {narrow_bits} exceeds storage width {}",
                self.mantissa_bits
            ));
        }
        let shift = self.mantissa_bits - narrow_bits;
        let mut out = Mantissas::for_width(narrow_bits, self.mantissas.len());
        if shift == 0 {
            for i in 0..self.mantissas.len() {
                out.set(i, self.mantissas.get(i));
            }
        } else {
            let hi = (1i32 << (narrow_bits - 1)) - 1;
            let lo = -(1i32 << (narrow_bits - 1));
            let down = (1i64 << shift) as f32;
            for i in 0..self.mantissas.len() {
                let v = self.mantissas.get(i) as f32 / down;
                let r = match rounding {
                    Rounding::NearestEven => v.round_ties_even(),
                    Rounding::Stochastic(rng) => (v + rng.next_f32()).floor(),
                };
                out.set(i, (r as i32).clamp(lo, hi));
            }
        }
        Ok(BfpTensor {
            rows: self.rows,
            cols: self.cols,
            mantissa_bits: narrow_bits,
            tile: self.tile,
            mantissas: out,
            exponents: self.exponents.clone(),
            tiles_per_row: self.tiles_per_row,
            tile_rows: self.tile_rows,
            tile_cols: self.tile_cols,
            // fresh cache: the narrow repack must never reuse the wide
            // tensor's panels (different values and width class)
            panels: Mutex::new(None),
        })
    }

    /// Memory footprint in bits of the BFP representation (mantissas packed
    /// at their true width + one 8-bit exponent per tile) — the quantity
    /// behind the paper's "2x more compact models / up to 4x bandwidth"
    /// claims.
    pub fn storage_bits(&self) -> usize {
        self.mantissas.len() * self.mantissa_bits as usize + self.exponents.len() * 8
    }

    /// Actual heap bytes of the software representation: packed mantissa
    /// vector + i32 exponents + the cached packed-panel copy when one is
    /// resident (a second, padded mantissa buffer — without it, memory
    /// reports undercount resident weights by the panel copy).
    pub fn heap_bytes(&self) -> usize {
        let panel_bytes = self.panels.lock().unwrap().as_ref().map_or(0, |c| c.panels.heap_bytes());
        self.mantissas.heap_bytes()
            + self.exponents.len() * std::mem::size_of::<i32>()
            + panel_bytes
    }
}

/// Quantize all tiles, band-parallel: band = one tile row (`th` data
/// rows), whose mantissa and exponent slices are disjoint across bands.
/// Nearest-even rows route through the SIMD kernel family; stochastic
/// rounding stays scalar in element order so each tile's RNG substream
/// is consumed identically whatever ISA is active.
fn quantize_bands<E: MantissaElem>(
    data: &[f32],
    out: &mut [E],
    exponents: &mut [i32],
    g: &TileGrid,
    mantissa_bits: u32,
    mode: TileRounding,
    threads: usize,
) {
    debug_assert!(mantissa_bits <= E::MAX_BITS);
    let isa = kernels::active();
    let band_elems = g.th * g.cols;
    let jobs: Vec<(usize, (&mut [E], &mut [i32]))> = out
        .chunks_mut(band_elems)
        .zip(exponents.chunks_mut(g.tiles_c))
        .enumerate()
        .collect();
    pool::dispatch_jobs(jobs, threads, |band, (band_out, band_exp)| {
        let r0 = band * g.th;
        let r1 = (r0 + g.th).min(g.rows);
        for tc in 0..g.tiles_c {
            let c0 = tc * g.tw;
            let c1 = (c0 + g.tw).min(g.cols);
            let e = quant::block_exponent_strided(data, g.cols, r0, r1, c0, c1);
            band_exp[tc] = e;
            match mode {
                TileRounding::NearestEven => {
                    for r in r0..r1 {
                        let src = &data[r * g.cols + c0..r * g.cols + c1];
                        let dst = &mut band_out[(r - r0) * g.cols + c0..(r - r0) * g.cols + c1];
                        kernels::quantize_row_rne_preclamped(isa, src, dst, e, mantissa_bits);
                    }
                }
                TileRounding::StochasticBase(_) => {
                    let mut owned = mode.for_tile((band * g.tiles_c + tc) as u64);
                    let mut rounding = owned.as_rounding();
                    for r in r0..r1 {
                        let src = &data[r * g.cols + c0..r * g.cols + c1];
                        let dst = &mut band_out[(r - r0) * g.cols + c0..(r - r0) * g.cols + c1];
                        for (d, &x) in dst.iter_mut().zip(src) {
                            *d = E::from_i32(quant::quantize_value(
                                x,
                                e,
                                mantissa_bits,
                                &mut rounding,
                            ));
                        }
                    }
                }
            }
        }
    });
}

/// In-place BFP round-trip (quantize + dequantize) of a row-major matrix —
/// the host-side FP→BFP→FP converter boundary, used by the trainer to
/// model input conversion without materializing mantissa storage.
/// Band-parallel with per-tile substreams (thread-count invariant). Uses
/// the default worker-thread budget; for an explicit cap or tile default
/// go through [`crate::bfp::BfpContext::quantize_inplace`].
pub fn quantize_inplace_2d(
    data: &mut [f32],
    rows: usize,
    cols: usize,
    mantissa_bits: u32,
    tile: TileSize,
    rounding: &mut Rounding,
) -> Result<()> {
    quantize_inplace_2d_impl(data, rows, cols, mantissa_bits, tile, rounding, worker_threads())
}

/// [`quantize_inplace_2d`] under an explicit thread cap (the
/// `BfpContext` body).
pub(crate) fn quantize_inplace_2d_impl(
    data: &mut [f32],
    rows: usize,
    cols: usize,
    mantissa_bits: u32,
    tile: TileSize,
    rounding: &mut Rounding,
    max_threads: usize,
) -> Result<()> {
    if data.len() != rows * cols {
        return Err(anyhow!("data len {} != {rows}x{cols}", data.len()));
    }
    check_width(mantissa_bits)?;
    let g = tile_grid(rows, cols, tile)?;
    if rows * cols == 0 {
        return Ok(());
    }
    let mode = TileRounding::capture(rounding);
    let isa = kernels::active();
    let threads = pool::par_threads_simd(
        rows * cols,
        PAR_MIN_ELEMS,
        kernels::converter_floor_scale(isa, mode),
        max_threads,
        g.tiles_r,
    );
    let jobs: Vec<(usize, &mut [f32])> = data.chunks_mut(g.th * g.cols).enumerate().collect();
    pool::dispatch_jobs(jobs, threads, |band, chunk| {
        let r0 = band * g.th;
        let r1 = (r0 + g.th).min(g.rows);
        for tc in 0..g.tiles_c {
            let c0 = tc * g.tw;
            let c1 = (c0 + g.tw).min(g.cols);
            let e = quant::block_exponent_strided(chunk, g.cols, 0, r1 - r0, c0, c1);
            match mode {
                TileRounding::NearestEven => {
                    for lr in 0..r1 - r0 {
                        let row = &mut chunk[lr * g.cols + c0..lr * g.cols + c1];
                        kernels::quantize_dequant_row_rne_preclamped(isa, row, e, mantissa_bits);
                    }
                }
                TileRounding::StochasticBase(_) => {
                    let mut owned = mode.for_tile((band * g.tiles_c + tc) as u64);
                    let mut r = owned.as_rounding();
                    for lr in 0..r1 - r0 {
                        for x in &mut chunk[lr * g.cols + c0..lr * g.cols + c1] {
                            let q = quant::quantize_value(*x, e, mantissa_bits, &mut r);
                            *x = quant::dequantize_value(q, e, mantissa_bits);
                        }
                    }
                }
            }
        }
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::Xorshift32;

    fn roundtrip(data: &[f32], rows: usize, cols: usize, m: u32, tile: TileSize) -> Vec<f32> {
        BfpTensor::from_f32(data, rows, cols, m, tile, &mut Rounding::NearestEven)
            .unwrap()
            .to_f32()
    }

    #[test]
    fn whole_tensor_single_exponent() {
        let t = BfpTensor::from_f32(
            &[1.0, 2.0, 3.0, 4.0],
            2,
            2,
            8,
            TileSize::Whole,
            &mut Rounding::NearestEven,
        )
        .unwrap();
        assert_eq!(t.exponents.len(), 1);
    }

    #[test]
    fn tiled_exponent_count() {
        let data = vec![1.0f32; 50 * 70];
        let t = BfpTensor::from_f32(&data, 50, 70, 8, TileSize::Edge(24), &mut Rounding::NearestEven)
            .unwrap();
        assert_eq!(t.exponents.len(), 3 * 3); // ceil(50/24) x ceil(70/24)
    }

    #[test]
    fn storage_width_matches_mantissa_class() {
        let data = vec![0.5f32; 16];
        let mk = |m: u32| {
            BfpTensor::from_f32(&data, 4, 4, m, TileSize::Edge(2), &mut Rounding::NearestEven)
                .unwrap()
        };
        assert!(matches!(mk(4).mantissas, Mantissas::I8(_)));
        assert!(matches!(mk(8).mantissas, Mantissas::I8(_)));
        assert!(matches!(mk(12).mantissas, Mantissas::I16(_)));
        assert!(matches!(mk(16).mantissas, Mantissas::I16(_)));
        assert!(matches!(mk(20).mantissas, Mantissas::I32(_)));
        // packed heap cost: 1 byte/elem at m=8 vs 4 at m=20 (+ exponents)
        assert_eq!(mk(8).heap_bytes(), 16 + 4 * 4);
        assert_eq!(mk(20).heap_bytes(), 64 + 4 * 4);
    }

    #[test]
    fn per_tile_exponents_capture_mixed_scales() {
        // top half tiny, bottom half large: tiled quantization must keep
        // the tiny half alive; whole-tensor must crush it.
        let rows = 32;
        let cols = 32;
        let mut data = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                data[r * cols + c] = if r < 16 { 1e-4 } else { 1.0 } * ((c + 1) as f32 / 8.0);
            }
        }
        let tiled = roundtrip(&data, rows, cols, 8, TileSize::Edge(16));
        let whole = roundtrip(&data, rows, cols, 8, TileSize::Whole);
        let err = |q: &[f32]| {
            data.iter().zip(q).map(|(a, b)| (a - b).abs()).sum::<f32>() / data.len() as f32
        };
        assert!(err(&tiled) < err(&whole) / 10.0, "{} vs {}", err(&tiled), err(&whole));
    }

    #[test]
    fn narrow_view_matches_direct_quantization_scale() {
        check("narrow view error bounded", 100, |g: &mut Gen| {
            let rows = g.int(1, 20);
            let cols = g.int(1, 20);
            let data = g.vec_f32(rows * cols, 2);
            let wide = BfpTensor::from_f32(
                &data,
                rows,
                cols,
                16,
                TileSize::Edge(8),
                &mut Rounding::NearestEven,
            )
            .unwrap();
            let narrow = wide.narrow_view(8, &mut Rounding::NearestEven).unwrap();
            let direct = BfpTensor::from_f32(
                &data,
                rows,
                cols,
                8,
                TileSize::Edge(8),
                &mut Rounding::NearestEven,
            )
            .unwrap();
            // narrow-from-wide may differ from direct by <= 1 ulp of the
            // narrow grid (double rounding), never more.
            for (a, b) in narrow.to_f32().iter().zip(direct.to_f32().iter()) {
                let ulp = (a - b).abs();
                let step = quant::exp2i(
                    quant::block_exponent(&data).max(quant::E_MIN) - 7,
                );
                prop_assert!(ulp <= step * 1.001, "narrow {a} vs direct {b}");
            }
            Ok(())
        });
    }

    #[test]
    fn narrow_view_repacks_storage() {
        let data: Vec<f32> = (0..64).map(|i| (i as f32 - 31.5) / 7.0).collect();
        let wide =
            BfpTensor::from_f32(&data, 8, 8, 16, TileSize::Edge(4), &mut Rounding::NearestEven)
                .unwrap();
        assert!(matches!(wide.mantissas, Mantissas::I16(_)));
        let narrow = wide.narrow_view(8, &mut Rounding::NearestEven).unwrap();
        assert!(matches!(narrow.mantissas, Mantissas::I8(_)));
        assert_eq!(narrow.heap_bytes(), wide.heap_bytes() / 2 + wide.exponents.len() * 2);
    }

    #[test]
    fn narrow_view_rejects_widening() {
        let t = BfpTensor::from_f32(&[1.0], 1, 1, 8, TileSize::Whole, &mut Rounding::NearestEven)
            .unwrap();
        assert!(t.narrow_view(12, &mut Rounding::NearestEven).is_err());
    }

    #[test]
    fn storage_bits_compression() {
        // hbfp8 with t=24 on a 48x48 tensor: 8 bits/elem + 4 exponents.
        let data = vec![1.0f32; 48 * 48];
        let t = BfpTensor::from_f32(&data, 48, 48, 8, TileSize::Edge(24), &mut Rounding::NearestEven)
            .unwrap();
        assert_eq!(t.storage_bits(), 48 * 48 * 8 + 4 * 8);
        // 4x smaller than f32 minus exponent overhead (the paper's "up to 4x")
        let fp32_bits = 48 * 48 * 32;
        assert!((fp32_bits as f64 / t.storage_bits() as f64) > 3.9);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(BfpTensor::from_f32(&[1.0; 5], 2, 2, 8, TileSize::Whole, &mut Rounding::NearestEven)
            .is_err());
        let zero_edge =
            BfpTensor::from_f32(&[1.0; 4], 2, 2, 8, TileSize::Edge(0), &mut Rounding::NearestEven);
        assert!(zero_edge.is_err());
    }

    #[test]
    fn from_parts_validates() {
        let ok = BfpTensor::from_parts(
            2,
            2,
            8,
            TileSize::Whole,
            Mantissas::I8(vec![-128, 127, 0, 1]),
            vec![3],
        );
        assert!(ok.is_ok());
        assert_eq!(ok.unwrap().mantissa_at(0, 1), 127);
        // mantissa outside the declared width
        assert!(BfpTensor::from_parts(
            2,
            2,
            4,
            TileSize::Whole,
            Mantissas::I8(vec![-128, 0, 0, 0]),
            vec![3],
        )
        .is_err());
        // wrong exponent count
        assert!(BfpTensor::from_parts(
            2,
            2,
            8,
            TileSize::Edge(1),
            Mantissas::I8(vec![0; 4]),
            vec![0; 3],
        )
        .is_err());
        // wrong mantissa count
        assert!(BfpTensor::from_parts(2, 2, 8, TileSize::Whole, Mantissas::I8(vec![0; 3]), vec![0])
            .is_err());
    }

    #[test]
    fn quantization_thread_count_invariant() {
        // Both rounding modes must give bit-identical tensors for 1 vs N
        // threads. Use a tensor big enough to clear the parallel floor.
        use crate::bfp::context::BfpContext;
        let rows = 160;
        let cols = 120;
        let mut g = Gen::new(0xBF9);
        let data = g.vec_f32(rows * cols, 4);
        let ctx1 = BfpContext::from_env().with_tile(TileSize::Edge(24)).with_threads(1);
        let ctx8 = BfpContext::from_env().with_tile(TileSize::Edge(24)).with_threads(8);
        for m in [8u32, 12] {
            let a = ctx1.quantize(&data, rows, cols, m, &mut Rounding::NearestEven).unwrap();
            let b = ctx8.quantize(&data, rows, cols, m, &mut Rounding::NearestEven).unwrap();
            assert!(a.mantissas == b.mantissas && a.exponents == b.exponents, "rne m={m}");

            let mut r1 = Xorshift32::new(77);
            let mut r8 = Xorshift32::new(77);
            let sa = ctx1.quantize(&data, rows, cols, m, &mut Rounding::Stochastic(&mut r1)).unwrap();
            let sb = ctx8.quantize(&data, rows, cols, m, &mut Rounding::Stochastic(&mut r8)).unwrap();
            assert!(sa.mantissas == sb.mantissas && sa.exponents == sb.exponents, "sr m={m}");
        }
    }

    #[test]
    fn quantize_inplace_matches_tensor_roundtrip() {
        let mut g = Gen::new(0x1A5);
        let rows = 48;
        let cols = 36;
        let data = g.vec_f32(rows * cols, 3);
        let want = roundtrip(&data, rows, cols, 8, TileSize::Edge(16));
        let mut got = data.clone();
        quantize_inplace_2d(&mut got, rows, cols, 8, TileSize::Edge(16), &mut Rounding::NearestEven)
            .unwrap();
        assert_eq!(got, want, "in-place converter must match the tensor path");
    }

    #[test]
    fn heap_bytes_includes_panel_cache() {
        let data: Vec<f32> = (0..48 * 40).map(|i| (i as f32 - 960.0) / 100.0).collect();
        let t =
            BfpTensor::from_f32(&data, 48, 40, 8, TileSize::Edge(16), &mut Rounding::NearestEven)
                .unwrap();
        let bare = t.heap_bytes();
        let pp = t.packed_panels();
        assert_eq!(
            t.heap_bytes(),
            bare + pp.heap_bytes(),
            "resident panel copy must be accounted"
        );
        t.clear_panel_cache();
        assert_eq!(t.heap_bytes(), bare);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "stale panel cache")]
    fn mutation_without_clear_panics_in_debug() {
        let data = vec![0.5f32; 64];
        let t = BfpTensor::from_f32(&data, 8, 8, 8, TileSize::Edge(4), &mut Rounding::NearestEven)
            .unwrap();
        let _ = t.packed_panels();
        // direct public-field mutation, no clear_panel_cache()
        let mut t = t;
        t.mantissas.set(3, 7);
        let _ = t.packed_panels(); // must panic instead of serving stale panels
    }

    #[test]
    fn mutation_with_clear_repacks() {
        let data = vec![0.5f32; 64];
        let mut t =
            BfpTensor::from_f32(&data, 8, 8, 8, TileSize::Edge(4), &mut Rounding::NearestEven)
                .unwrap();
        let _ = t.packed_panels();
        t.mantissas.set(3, 7);
        t.clear_panel_cache();
        assert!(!t.has_packed_panels());
        let pp = t.packed_panels(); // repacks from the mutated mantissas
        assert_eq!(pp.data.get(3), 7, "repacked panels must reflect the mutation");
    }

    #[test]
    fn roundtrip_error_bound_property() {
        check("roundtrip bounded", 150, |g: &mut Gen| {
            let rows = g.int(1, 30);
            let cols = g.int(1, 30);
            let data = g.vec_f32(rows * cols, 4);
            let m = *g.pick(&[4u32, 8, 12]);
            let tile = *g.pick(&[TileSize::Whole, TileSize::Edge(8), TileSize::Edge(24)]);
            let t =
                BfpTensor::from_f32(&data, rows, cols, m, tile, &mut Rounding::NearestEven).unwrap();
            let back = t.to_f32();
            // every element's error is under one step of its own tile's grid
            for r in 0..rows {
                for c in 0..cols {
                    let x = data[r * cols + c];
                    let y = back[r * cols + c];
                    let step = quant::exp2i(t.exponent_at(r, c) - (m as i32 - 1));
                    prop_assert!((x - y).abs() <= step * 1.0001, "x={x} y={y} step={step}");
                }
            }
            Ok(())
        });
    }
}
