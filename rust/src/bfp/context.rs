//! `BfpContext` + `MatmulPlan`: the execution-context API of the BFP
//! datapath.
//!
//! Three optimization passes (packed mantissas → worker pool + packed
//! panels → SIMD kernel families) each bolted a knob onto the call
//! surface until the paper's single conceptual operation — a BFP
//! dot-product engine with FP32 accumulation (§4, Eq. 2) — was reachable
//! through nine near-duplicate free functions plus `HBFP_THREADS` /
//! `HBFP_SIMD` env vars read at scattered points. This module replaces
//! that zoo with a two-level API:
//!
//! - [`BfpContext`] owns **all execution policy**: worker-thread budget,
//!   dispatch backend (pooled vs scoped spawns), SIMD kernel family,
//!   matmul kernel layout, exponent-tile size, accumulator policy, and a
//!   default rounding policy — resolved **once** from the environment
//!   ([`BfpContext::from_env`]) and adjusted with builder methods. A
//!   context is a plain value: clone it, tweak a knob, hand it to a
//!   subsystem.
//! - [`MatmulPlan`] ([`BfpContext::plan_matmul`]) pre-resolves every
//!   per-shape decision — matmul tile edge, panel register width,
//!   accumulator class ([`acc_fits_i32`]), inline-vs-pool lane counts
//!   for both the plain and the fused (convert + matmul) paths — so the
//!   hot loop does **zero per-call policy work**. Plans are `Copy`,
//!   cheap to build, validated against their operands, and reusable for
//!   any number of executions (the resident-weight training-step shape
//!   holds one plan per layer).
//!
//! Execution entry points:
//!
//! | call | use |
//! |---|---|
//! | [`MatmulPlan::execute`] | C = A·B over BFP tensors, fresh output |
//! | [`MatmulPlan::execute_into`] | same, into a caller buffer (allocation-free on the warm packed single-lane path) |
//! | [`MatmulPlan::quantize_execute`] | fused FP→BFP A-convert + matmul (activations streaming against resident weights) |
//! | [`MatmulPlan::quantize_execute_into`] | fused, into a caller buffer |
//! | [`BfpContext::matmul`] / [`BfpContext::quantize_matmul`] | one-shot conveniences that build the plan from the operands |
//! | [`BfpContext::quantize`] / [`BfpContext::quantize_inplace`] | the FP→BFP converter under the context's thread budget and tile |
//! | [`BfpContext::matmul_f32`] | quantize both f32 operands and multiply (demo/eval paths) |
//!
//! Every knob moves **speed, never bits**: all kernel layouts, ISA
//! families, backends, thread counts, and accumulator policies produce
//! results bit-identical to [`super::matmul::bfp_matmul_naive`],
//! enforced by `tests/context_api.rs`. The legacy free functions survive only as
//! `#[deprecated]` one-line shims over a default context (importable
//! from their defining modules; no longer re-exported at `bfp::`).

use anyhow::{anyhow, Result};

use super::kernels::Isa;
use super::matmul::{self, acc_fits_i32};
use super::panels::matmul_tile_edge;
use super::quant::{OwnedRounding, Rounding, TileRounding};
use super::tensor::{self, BfpTensor, TileSize};
use crate::util::pool::{self, ParBackend};
use crate::util::rng::Xorshift32;
use crate::util::worker_threads;

/// Which matmul kernel layout a context dispatches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatmulKernel {
    /// The packed-panel register-blocked microkernel streaming the
    /// B operand's cached k-tile-major layout — the default hot path.
    Packed,
    /// The pre-panel row-major walk (always scalar inner loops). Kept
    /// reachable as the bench ladder's layout partner and a
    /// differential-test reference; bit-identical to `Packed`. Applies
    /// to plain execution only — the fused convert+matmul paths
    /// ([`MatmulPlan::quantize_execute`]) always stream packed panels,
    /// whatever this knob says.
    RowMajor,
}

/// Integer-accumulator policy for the tile MAC loops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccPolicy {
    /// `i32` when the proven overflow bound ([`acc_fits_i32`]) allows,
    /// `i64` otherwise — the default, and what the hardware maps.
    Auto,
    /// Always accumulate in `i64`. Same integer partials, same bits —
    /// a diagnostic knob for isolating accumulator-width effects in
    /// benches and tests.
    ForceI64,
}

/// Default rounding for context conveniences that quantize on the
/// caller's behalf without an explicit [`Rounding`]
/// ([`BfpContext::matmul_f32`]). Paths that thread caller-owned RNG
/// state (the accelerator sim's persistent converter stream) keep
/// passing `&mut Rounding` explicitly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundingPolicy {
    NearestEven,
    /// Stochastic rounding from a fresh Xorshift32 seeded with this
    /// value at each convenience call (deterministic per call).
    StochasticSeed(u32),
}

impl RoundingPolicy {
    fn owned(self) -> OwnedRounding {
        match self {
            RoundingPolicy::NearestEven => OwnedRounding::NearestEven,
            RoundingPolicy::StochasticSeed(s) => OwnedRounding::Stochastic(Xorshift32::new(s)),
        }
    }
}

/// All execution policy for the BFP datapath, resolved once.
///
/// [`BfpContext::from_env`] (or `Default`) reads `HBFP_THREADS` and
/// `HBFP_SIMD` exactly as the legacy entry points did; builder methods
/// override individual knobs. The context carries the *policy*; the
/// per-shape resolution lives in [`MatmulPlan`].
///
/// The ISA knob steers the matmul microkernel and the panel width; the
/// converters always run the process-wide family (every family is
/// bit-identical, so this is invisible in the results).
#[derive(Debug, Clone)]
pub struct BfpContext {
    threads: usize,
    backend: ParBackend,
    isa: Isa,
    kernel: MatmulKernel,
    tile: TileSize,
    acc: AccPolicy,
    rounding: RoundingPolicy,
}

impl Default for BfpContext {
    fn default() -> BfpContext {
        BfpContext::from_env()
    }
}

impl BfpContext {
    /// Policy resolved from the environment: `HBFP_THREADS` (or all
    /// cores), the `HBFP_SIMD`-selected kernel family, pooled dispatch,
    /// the packed-panel kernel, the paper's t=24 exponent tiles,
    /// automatic accumulator selection, nearest-even rounding.
    pub fn from_env() -> BfpContext {
        BfpContext {
            threads: worker_threads(),
            backend: ParBackend::Pooled,
            isa: super::kernels::active(),
            kernel: MatmulKernel::Packed,
            tile: TileSize::Edge(24),
            acc: AccPolicy::Auto,
            rounding: RoundingPolicy::NearestEven,
        }
    }

    /// Cap the worker-lane budget (clamped to at least 1). Results are
    /// bit-identical for any thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Choose the dispatch backend (persistent pool vs per-call scoped
    /// spawns). Bit-identical either way; `Scoped` exists for the bench
    /// ladder's spawn-amortization rung.
    pub fn with_backend(mut self, backend: ParBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Force a SIMD kernel family. Clamped to what the CPU supports
    /// ([`Isa::clamped`]), so any value is safe; bit-identical across
    /// families.
    pub fn with_isa(mut self, isa: Isa) -> Self {
        self.isa = isa.clamped();
        self
    }

    /// Choose the matmul kernel layout (packed panels vs row-major).
    pub fn with_kernel(mut self, kernel: MatmulKernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Exponent-tile size used by [`BfpContext::plan_matmul`],
    /// [`BfpContext::quantize`], and [`BfpContext::quantize_inplace`].
    pub fn with_tile(mut self, tile: TileSize) -> Self {
        self.tile = tile;
        self
    }

    /// Accumulator policy override (see [`AccPolicy`]).
    pub fn with_acc(mut self, acc: AccPolicy) -> Self {
        self.acc = acc;
        self
    }

    /// Default rounding policy for the quantizing conveniences.
    pub fn with_rounding(mut self, rounding: RoundingPolicy) -> Self {
        self.rounding = rounding;
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn backend(&self) -> ParBackend {
        self.backend
    }

    pub fn isa(&self) -> Isa {
        self.isa
    }

    pub fn kernel(&self) -> MatmulKernel {
        self.kernel
    }

    pub fn tile(&self) -> TileSize {
        self.tile
    }

    pub fn acc(&self) -> AccPolicy {
        self.acc
    }

    pub fn rounding_policy(&self) -> RoundingPolicy {
        self.rounding
    }

    /// Pre-resolve a C = A·B execution for A: m x k and B: k x n at
    /// mantissa widths `(a_bits, b_bits)`, under this context's policy
    /// and tile size. The plan fixes the matmul tile edge, panel width,
    /// accumulator class, and lane counts once; executing it does no
    /// further policy work.
    pub fn plan_matmul(
        &self,
        m: usize,
        k: usize,
        n: usize,
        widths: (u32, u32),
    ) -> Result<MatmulPlan> {
        MatmulPlan::new(self, self.tile, m, k, n, widths.0, widths.1)
    }

    /// One-shot C = A·B: builds the plan from the operands (their tile
    /// configuration and widths — the context's tile default is not
    /// consulted, unlike [`BfpContext::plan_matmul`], which plans for
    /// `ctx.tile` and rejects operands quantized on a different grid).
    /// For repeated GEMMs of one shape, build the plan once with
    /// [`BfpContext::plan_matmul`].
    pub fn matmul(&self, a: &BfpTensor, b: &BfpTensor) -> Result<Vec<f32>> {
        self.plan_for_operands(a, b)?.execute(a, b)
    }

    /// [`BfpContext::matmul`] into a caller-provided buffer of exactly
    /// `a.rows * b.cols` elements.
    pub fn matmul_into(&self, a: &BfpTensor, b: &BfpTensor, out: &mut [f32]) -> Result<()> {
        self.plan_for_operands(a, b)?.execute_into(a, b, out)
    }

    /// One-shot fused FP→BFP convert + matmul: quantizes row-band tiles
    /// of `a` on the fly (per-band scratch, never a materialized A
    /// tensor) and MACs them against the resident `b`. The plan is
    /// built from **`b`'s tile configuration** (the context's tile
    /// default is not consulted — A must convert on B's tile grid), and
    /// the result is bit-identical to quantizing `a` at `b.tile` and
    /// multiplying, stochastic rounding included (same per-tile
    /// substreams).
    pub fn quantize_matmul(
        &self,
        a: &[f32],
        a_rows: usize,
        a_bits: u32,
        rounding: &mut Rounding,
        b: &BfpTensor,
    ) -> Result<Vec<f32>> {
        let plan = MatmulPlan::new(self, b.tile, a_rows, b.rows, b.cols, a_bits, b.mantissa_bits)?;
        plan.quantize_execute(a, rounding, b)
    }

    /// Quantize an f32 matrix into packed BFP storage under this
    /// context's tile size and thread budget. Bit-identical for any
    /// thread count (stochastic rounding uses per-tile substreams).
    pub fn quantize(
        &self,
        data: &[f32],
        rows: usize,
        cols: usize,
        mantissa_bits: u32,
        rounding: &mut Rounding,
    ) -> Result<BfpTensor> {
        BfpTensor::from_f32_impl(data, rows, cols, mantissa_bits, self.tile, rounding, self.threads)
    }

    /// In-place FP→BFP→FP round-trip of a row-major matrix (the
    /// host-side input-converter boundary) under this context's tile
    /// size and thread budget.
    pub fn quantize_inplace(
        &self,
        data: &mut [f32],
        rows: usize,
        cols: usize,
        mantissa_bits: u32,
        rounding: &mut Rounding,
    ) -> Result<()> {
        tensor::quantize_inplace_2d_impl(
            data,
            rows,
            cols,
            mantissa_bits,
            self.tile,
            rounding,
            self.threads,
        )
    }

    /// Convenience: quantize both f32 operands (B once as resident
    /// weights, A through the fused converter) and multiply in BFP,
    /// rounding per the context's [`RoundingPolicy`].
    pub fn matmul_f32(
        &self,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        mantissa_bits: u32,
    ) -> Result<Vec<f32>> {
        let mut owned = self.rounding.owned();
        let qb = {
            let mut r = owned.as_rounding();
            self.quantize(b, k, n, mantissa_bits, &mut r)?
        };
        let mut r = owned.as_rounding();
        self.quantize_matmul(a, m, mantissa_bits, &mut r, &qb)
    }

    fn plan_for_operands(&self, a: &BfpTensor, b: &BfpTensor) -> Result<MatmulPlan> {
        matmul::check_shapes(a, b)?;
        MatmulPlan::new(self, a.tile, a.rows, a.cols, b.cols, a.mantissa_bits, b.mantissa_bits)
    }
}

/// A pre-resolved C = A·B execution: one (m, k, n, widths, tile) shape
/// under one context's policy, with the tile edge, panel width,
/// accumulator class, and lane counts fixed at plan time.
///
/// Build with [`BfpContext::plan_matmul`]; execute any number of times.
/// Operands are validated against the planned shape/widths/tile on every
/// call (cheap field comparisons), so a plan can never silently run a
/// mismatched GEMM.
#[derive(Debug, Clone, Copy)]
pub struct MatmulPlan {
    m: usize,
    k: usize,
    n: usize,
    a_bits: u32,
    b_bits: u32,
    tile: TileSize,
    kernel: MatmulKernel,
    backend: ParBackend,
    isa: Isa,
    /// Matmul tile edge (`matmul_tile_edge(tile, k)`).
    t: usize,
    /// Panel register width the B operand packs at (the ISA family's).
    nr: usize,
    /// Accumulator class: `i32` iff the overflow bound holds (and the
    /// context did not force `i64`).
    use_i32: bool,
    /// Lane count for [`MatmulPlan::execute`] (inline when 1).
    threads: usize,
    /// Converter tile dims for the fused A path (`tile.edge_or(m, k)`).
    th: usize,
    tw: usize,
    /// Lane count for the fused path (its bands follow `th`, not `t`).
    threads_fused: usize,
}

impl MatmulPlan {
    fn new(
        ctx: &BfpContext,
        tile: TileSize,
        m: usize,
        k: usize,
        n: usize,
        a_bits: u32,
        b_bits: u32,
    ) -> Result<MatmulPlan> {
        tensor::check_width(a_bits)?;
        tensor::check_width(b_bits)?;
        if let TileSize::Edge(0) = tile {
            return Err(anyhow!("tile edge must be nonzero"));
        }
        let t = matmul_tile_edge(tile, k);
        let nr = ctx.isa.panel_nr();
        let tile_k = t.min(k).max(1);
        let use_i32 = match ctx.acc {
            AccPolicy::Auto => acc_fits_i32(tile_k, a_bits, b_bits),
            AccPolicy::ForceI64 => false,
        };
        let work = m * k * n;
        let bands = m.div_ceil(t).max(1);
        let threads = match ctx.kernel {
            MatmulKernel::Packed => pool::par_threads_simd(
                work,
                matmul::PAR_MIN_MACS,
                ctx.isa.par_floor_scale(),
                ctx.threads,
                bands,
            ),
            MatmulKernel::RowMajor => {
                pool::par_threads(work, matmul::PAR_MIN_MACS, ctx.threads, bands)
            }
        };
        let (th, tw) = tile.edge_or(m, k);
        let fused_bands = m.div_ceil(th).max(1);
        let threads_fused = pool::par_threads_simd(
            work,
            matmul::PAR_MIN_MACS,
            ctx.isa.par_floor_scale(),
            ctx.threads,
            fused_bands,
        );
        Ok(MatmulPlan {
            m,
            k,
            n,
            a_bits,
            b_bits,
            tile,
            kernel: ctx.kernel,
            backend: ctx.backend,
            isa: ctx.isa,
            t,
            nr,
            use_i32,
            threads,
            th,
            tw,
            threads_fused,
        })
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Output length (`m * n`) an [`MatmulPlan::execute_into`] buffer
    /// must have.
    pub fn out_len(&self) -> usize {
        self.m * self.n
    }

    /// Planned panel register width (the ISA family's).
    pub fn panel_nr(&self) -> usize {
        self.nr
    }

    /// Whether the plan accumulates k-tile partials in `i32` (the
    /// proven-bound fast class) rather than `i64`.
    pub fn uses_i32_acc(&self) -> bool {
        self.use_i32
    }

    /// Planned lane count for [`MatmulPlan::execute`].
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// C = A·B into a fresh row-major f32 vector.
    pub fn execute(&self, a: &BfpTensor, b: &BfpTensor) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.m * self.n];
        self.execute_into(a, b, &mut out)?;
        Ok(out)
    }

    /// C = A·B into a caller buffer of exactly [`MatmulPlan::out_len`]
    /// elements (zeroed and filled here). On the default packed-panel
    /// kernel with a warm panel cache, the single-lane path performs no
    /// heap allocation; multi-lane dispatch allocates only the per-band
    /// job list. (A cold panel cache packs the B layout once, and the
    /// row-major kernel keeps per-band accumulator scratch — those paths
    /// allocate regardless.) A length mismatch panics in debug builds
    /// and returns an error in release.
    pub fn execute_into(&self, a: &BfpTensor, b: &BfpTensor, out: &mut [f32]) -> Result<()> {
        self.check_a(a)?;
        self.check_b(b)?;
        self.check_out(out.len())?;
        out.fill(0.0);
        if self.m == 0 || self.k == 0 || self.n == 0 {
            return Ok(());
        }
        match self.kernel {
            MatmulKernel::Packed => matmul::packed_matmul_into(
                a,
                b,
                out,
                self.t,
                self.nr,
                self.threads,
                self.backend,
                self.isa,
                self.use_i32,
            ),
            MatmulKernel::RowMajor => matmul::rowmajor_matmul_into(
                a,
                b,
                out,
                self.t,
                self.threads,
                self.backend,
                self.use_i32,
            ),
        }
        Ok(())
    }

    /// Fused FP→BFP convert + matmul into a fresh vector: `a` (row-major
    /// f32, `m x k`) streams through the converter band by band and MACs
    /// against the resident `b`. Bit-identical to quantizing `a` first
    /// and calling [`MatmulPlan::execute`], stochastic rounding included.
    /// The fused path always runs the packed-panel kernel (packing `b`'s
    /// panels on first use) — a `MatmulKernel::RowMajor` context affects
    /// only plain execution.
    pub fn quantize_execute(
        &self,
        a: &[f32],
        rounding: &mut Rounding,
        b: &BfpTensor,
    ) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.m * self.n];
        self.quantize_execute_into(a, rounding, b, &mut out)?;
        Ok(out)
    }

    /// [`MatmulPlan::quantize_execute`] into a caller buffer of exactly
    /// [`MatmulPlan::out_len`] elements. The per-band converter scratch
    /// is inherent to the fused path; the output itself is not
    /// reallocated. Length mismatch: debug panic, release error.
    pub fn quantize_execute_into(
        &self,
        a: &[f32],
        rounding: &mut Rounding,
        b: &BfpTensor,
        out: &mut [f32],
    ) -> Result<()> {
        if a.len() != self.m * self.k {
            return Err(anyhow!("a len {} != {}x{}", a.len(), self.m, self.k));
        }
        self.check_b(b)?;
        self.check_out(out.len())?;
        out.fill(0.0);
        if self.m * self.k == 0 {
            return Ok(());
        }
        // Capture before the n == 0 early return: the caller's RNG
        // advances exactly once per fused call, matching the legacy
        // entry point draw for draw.
        let mode = TileRounding::capture(rounding);
        if self.n == 0 {
            return Ok(());
        }
        matmul::fused_matmul_into(
            a,
            b,
            out,
            self.m,
            self.a_bits,
            mode,
            self.t,
            self.nr,
            self.th,
            self.tw,
            self.threads_fused,
            self.backend,
            self.isa,
            self.use_i32,
        );
        Ok(())
    }

    fn check_a(&self, a: &BfpTensor) -> Result<()> {
        if a.rows != self.m || a.cols != self.k {
            return Err(anyhow!(
                "A is {}x{}, plan expects {}x{}",
                a.rows,
                a.cols,
                self.m,
                self.k
            ));
        }
        if a.mantissa_bits != self.a_bits {
            return Err(anyhow!(
                "A mantissa width {} != planned {}",
                a.mantissa_bits,
                self.a_bits
            ));
        }
        if a.tile != self.tile {
            return Err(anyhow!("A tile {:?} != planned {:?}", a.tile, self.tile));
        }
        Ok(())
    }

    fn check_b(&self, b: &BfpTensor) -> Result<()> {
        if b.rows != self.k || b.cols != self.n {
            return Err(anyhow!(
                "B is {}x{}, plan expects {}x{}",
                b.rows,
                b.cols,
                self.k,
                self.n
            ));
        }
        if b.mantissa_bits != self.b_bits {
            return Err(anyhow!(
                "B mantissa width {} != planned {}",
                b.mantissa_bits,
                self.b_bits
            ));
        }
        if b.tile != self.tile {
            return Err(anyhow!("B tile {:?} != planned {:?}", b.tile, self.tile));
        }
        Ok(())
    }

    fn check_out(&self, len: usize) -> Result<()> {
        if len != self.m * self.n {
            let msg = format!(
                "plan output buffer holds {len} elements, needs {} ({}x{})",
                self.m * self.n,
                self.m,
                self.n
            );
            // Loud in development, recoverable in production: a sized
            // output buffer is the caller's contract, but a release
            // binary must not take down a serving process over it.
            if cfg!(debug_assertions) {
                panic!("{msg}");
            }
            return Err(anyhow!(msg));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn rand_mat(rng: &mut SplitMix64, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal() * scale).collect()
    }

    fn quantize(ctx: &BfpContext, data: &[f32], rows: usize, cols: usize, bits: u32) -> BfpTensor {
        ctx.quantize(data, rows, cols, bits, &mut Rounding::NearestEven).unwrap()
    }

    #[test]
    fn env_context_defaults() {
        let ctx = BfpContext::from_env();
        assert!(ctx.threads() >= 1);
        assert_eq!(ctx.backend(), ParBackend::Pooled);
        assert_eq!(ctx.kernel(), MatmulKernel::Packed);
        assert_eq!(ctx.acc(), AccPolicy::Auto);
        assert_eq!(ctx.rounding_policy(), RoundingPolicy::NearestEven);
        assert_eq!(ctx.isa(), crate::bfp::kernels::active());
    }

    #[test]
    fn builder_clamps() {
        let ctx = BfpContext::from_env().with_threads(0);
        assert_eq!(ctx.threads(), 1);
        // any Isa value is safe: the builder clamps to the CPU
        for isa in [Isa::Scalar, Isa::Sse41, Isa::Avx2, Isa::Neon] {
            let c = BfpContext::from_env().with_isa(isa);
            assert!(crate::bfp::kernels::detected().contains(&c.isa()));
        }
    }

    #[test]
    fn plan_precomputes_policy() {
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(24));
        let plan = ctx.plan_matmul(8, 256, 256, (8, 8)).unwrap();
        assert_eq!((plan.m(), plan.k(), plan.n()), (8, 256, 256));
        assert_eq!(plan.out_len(), 8 * 256);
        assert_eq!(plan.panel_nr(), ctx.isa().panel_nr());
        // tile_k = 24: 24 * 2^14 fits i32
        assert!(plan.uses_i32_acc());
        // at 16x16-bit widths a 2-deep tile already overflows i32
        let wide = ctx.plan_matmul(8, 256, 256, (16, 16)).unwrap();
        assert!(!wide.uses_i32_acc());
        // the override forces the wide class even when i32 would fit
        let forced = ctx
            .clone()
            .with_acc(AccPolicy::ForceI64)
            .plan_matmul(8, 256, 256, (8, 8))
            .unwrap();
        assert!(!forced.uses_i32_acc());
    }

    #[test]
    fn plan_rejects_bad_config() {
        let ctx = BfpContext::from_env();
        assert!(ctx.plan_matmul(4, 4, 4, (1, 8)).is_err(), "width below range");
        assert!(ctx.plan_matmul(4, 4, 4, (8, 25)).is_err(), "width above range");
        let z = BfpContext::from_env().with_tile(TileSize::Edge(0));
        assert!(z.plan_matmul(4, 4, 4, (8, 8)).is_err(), "zero tile edge");
    }

    #[test]
    fn plan_validates_operands() {
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8));
        let mut rng = SplitMix64::new(1);
        let a = rand_mat(&mut rng, 6 * 10, 1.0);
        let b = rand_mat(&mut rng, 10 * 4, 1.0);
        let qa = quantize(&ctx, &a, 6, 10, 8);
        let qb = quantize(&ctx, &b, 10, 4, 8);
        let plan = ctx.plan_matmul(6, 10, 4, (8, 8)).unwrap();
        assert!(plan.execute(&qa, &qb).is_ok());
        // wrong shapes / widths / tiles are rejected, never misread
        assert!(plan.execute(&qb, &qa).is_err(), "swapped operands");
        let q12 = quantize(&ctx, &a, 6, 10, 12);
        assert!(plan.execute(&q12, &qb).is_err(), "width mismatch");
        let wt = BfpContext::from_env().with_tile(TileSize::Whole);
        let qa_whole = quantize(&wt, &a, 6, 10, 8);
        assert!(plan.execute(&qa_whole, &qb).is_err(), "tile mismatch");
    }

    // The full policy-knob cross-product (kernel x backend x acc x
    // threads, bit-equal to the naive reference) lives in
    // tests/context_api.rs::policy_knobs_never_change_bits — one copy.

    #[test]
    fn execute_into_reuses_buffer() {
        let mut rng = SplitMix64::new(7);
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8));
        let (m, k, n) = (9, 12, 7);
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        let qa = quantize(&ctx, &a, m, k, 8);
        let qb = quantize(&ctx, &b, k, n, 8);
        let plan = ctx.plan_matmul(m, k, n, (8, 8)).unwrap();
        let want = plan.execute(&qa, &qb).unwrap();
        let mut out = vec![f32::NAN; m * n]; // stale contents must be overwritten
        plan.execute_into(&qa, &qb, &mut out).unwrap();
        assert!(out == want);
        plan.execute_into(&qa, &qb, &mut out).unwrap();
        assert!(out == want, "reused buffer must reproduce the result");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "plan output buffer")]
    fn execute_into_length_mismatch_panics_in_debug() {
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(4));
        let qa = quantize(&ctx, &[1.0; 16], 4, 4, 8);
        let qb = quantize(&ctx, &[1.0; 16], 4, 4, 8);
        let plan = ctx.plan_matmul(4, 4, 4, (8, 8)).unwrap();
        let mut out = vec![0.0f32; 15];
        let _ = plan.execute_into(&qa, &qb, &mut out);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn execute_into_length_mismatch_errors_in_release() {
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(4));
        let qa = quantize(&ctx, &[1.0; 16], 4, 4, 8);
        let qb = quantize(&ctx, &[1.0; 16], 4, 4, 8);
        let plan = ctx.plan_matmul(4, 4, 4, (8, 8)).unwrap();
        let mut out = vec![0.0f32; 15];
        assert!(plan.execute_into(&qa, &qb, &mut out).is_err());
        let mut out = vec![0.0f32; 17];
        assert!(plan.quantize_execute_into(&[1.0; 16], &mut Rounding::NearestEven, &qb, &mut out)
            .is_err());
    }

    #[test]
    fn fused_equals_materialized_through_the_plan() {
        let mut rng = SplitMix64::new(0xFAB);
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8));
        let (m, k, n) = (14, 22, 18);
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        let qb = quantize(&ctx, &b, k, n, 8);
        let plan = ctx.plan_matmul(m, k, n, (8, 8)).unwrap();

        // nearest-even
        let qa = quantize(&ctx, &a, m, k, 8);
        let want = plan.execute(&qa, &qb).unwrap();
        let got = plan.quantize_execute(&a, &mut Rounding::NearestEven, &qb).unwrap();
        assert!(got == want);

        // stochastic: same seed, same per-tile substreams
        let mut r1 = Xorshift32::new(0xA5);
        let mut r2 = Xorshift32::new(0xA5);
        let qa_s = ctx.quantize(&a, m, k, 8, &mut Rounding::Stochastic(&mut r1)).unwrap();
        let want_s = plan.execute(&qa_s, &qb).unwrap();
        let got_s = plan.quantize_execute(&a, &mut Rounding::Stochastic(&mut r2), &qb).unwrap();
        assert!(got_s == want_s);
    }

    #[test]
    fn zero_dim_plans_execute_cleanly() {
        let ctx = BfpContext::from_env().with_tile(TileSize::Whole);
        let qa = quantize(&ctx, &[], 0, 3, 8);
        let qb = quantize(&ctx, &[1.0; 6], 3, 2, 8);
        let plan = ctx.plan_matmul(0, 3, 2, (8, 8)).unwrap();
        assert_eq!(plan.execute(&qa, &qb).unwrap().len(), 0);
        // fused with n == 0 still advances the caller RNG exactly once
        let qe = quantize(&ctx, &[], 3, 0, 8);
        let plan0 = ctx.plan_matmul(2, 3, 0, (8, 8)).unwrap();
        let mut r = Xorshift32::new(9);
        let mut replay = Xorshift32::new(9);
        let out = plan0
            .quantize_execute(&[1.0; 6], &mut Rounding::Stochastic(&mut r), &qe)
            .unwrap();
        assert!(out.is_empty());
        let _ = replay.next_u32(); // the capture draw
        assert_eq!(r.next_u32(), replay.next_u32());
    }

    #[test]
    fn matmul_f32_policy_rounding() {
        let mut rng = SplitMix64::new(0x33);
        let (m, k, n) = (10, 12, 8);
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8));
        let rne = ctx.matmul_f32(&a, &b, m, k, n, 8).unwrap();
        // explicit composition must match the convenience
        let qb = quantize(&ctx, &b, k, n, 8);
        let want = ctx.quantize_matmul(&a, m, 8, &mut Rounding::NearestEven, &qb).unwrap();
        assert!(rne == want);
        // a stochastic policy is deterministic per call
        let sctx = ctx.clone().with_rounding(RoundingPolicy::StochasticSeed(42));
        let s1 = sctx.matmul_f32(&a, &b, m, k, n, 8).unwrap();
        let s2 = sctx.matmul_f32(&a, &b, m, k, n, 8).unwrap();
        assert!(s1 == s2);
    }
}
