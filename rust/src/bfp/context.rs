//! `BfpContext` + `MatmulPlan`: the execution-context API of the BFP
//! datapath.
//!
//! Three optimization passes (packed mantissas → worker pool + packed
//! panels → SIMD kernel families) each bolted a knob onto the call
//! surface until the paper's single conceptual operation — a BFP
//! dot-product engine with FP32 accumulation (§4, Eq. 2) — was reachable
//! through nine near-duplicate free functions plus `HBFP_THREADS` /
//! `HBFP_SIMD` env vars read at scattered points. This module replaces
//! that zoo with a two-level API:
//!
//! - [`BfpContext`] owns **all execution policy**: worker-thread budget,
//!   dispatch backend (pooled vs scoped spawns), SIMD kernel family,
//!   matmul kernel layout, exponent-tile size, accumulator policy, and a
//!   default rounding policy — resolved **once** from the environment
//!   ([`BfpContext::from_env`]) and adjusted with builder methods. A
//!   context is a plain value: clone it, tweak a knob, hand it to a
//!   subsystem.
//! - [`MatmulPlan`] ([`BfpContext::plan_matmul`]) pre-resolves every
//!   per-shape decision — matmul tile edge, panel register width,
//!   accumulator class ([`acc_fits_i32`]), inline-vs-pool lane counts
//!   for both the plain and the fused (convert + matmul) paths — so the
//!   hot loop does **zero per-call policy work**. Plans are `Copy`,
//!   cheap to build, validated against their operands, and reusable for
//!   any number of executions (the resident-weight training-step shape
//!   holds one plan per layer).
//!
//! Execution entry points:
//!
//! | call | use |
//! |---|---|
//! | [`MatmulPlan::execute`] | C = A·B over BFP tensors, fresh output |
//! | [`MatmulPlan::execute_into`] | same, into a caller buffer (allocation-free on the warm packed single-lane path) |
//! | [`MatmulPlan::quantize_execute`] | fused FP→BFP A-convert + matmul (activations streaming against resident weights) |
//! | [`MatmulPlan::quantize_execute_into`] | fused, into a caller buffer |
//! | [`BfpContext::matmul`] / [`BfpContext::quantize_matmul`] | one-shot conveniences that build the plan from the operands |
//! | [`BfpContext::quantize`] / [`BfpContext::quantize_inplace`] | the FP→BFP converter under the context's thread budget and tile |
//! | [`BfpContext::matmul_f32`] | quantize both f32 operands and multiply (demo/eval paths) |
//!
//! Every knob moves **speed, never bits**: all kernel layouts, ISA
//! families, backends, thread counts, and accumulator policies produce
//! results bit-identical to [`super::matmul::bfp_matmul_naive`],
//! enforced by `tests/context_api.rs`. The legacy free functions survive only as
//! `#[deprecated]` one-line shims over a default context (importable
//! from their defining modules; no longer re-exported at `bfp::`).

use std::sync::atomic::AtomicU64;

use anyhow::{anyhow, Result};

use super::kernels::Isa;
use super::matmul::{self, acc_fits_i32};
use super::panels::matmul_tile_edge;
use super::quant::{obs_count, OwnedRounding, Rounding, TileRounding};
use super::stats::{self, GuardStats};
use super::tensor::{self, next_wider_class, BfpTensor, TileSize};
use crate::util::pool::{self, ParBackend};
use crate::util::rng::Xorshift32;
use crate::util::worker_threads;

/// Datapath probe: whole tensors quantized through a [`BfpContext`].
/// Counters mode and above (one relaxed load when off); exported by
/// [`stats::export_datapath_counters`](super::stats::export_datapath_counters).
pub static OBS_TENSORS_QUANTIZED: AtomicU64 = AtomicU64::new(0);

/// Datapath probe: BFP matmul plan executions (fused and pre-quantized).
pub static OBS_GEMMS_EXECUTED: AtomicU64 = AtomicU64::new(0);

// ---------------------------------------------------------------- guards

/// How a guard scans f32 inputs for NaN/Inf before quantization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputScan {
    /// No scanning — the caller promises finite inputs. The quantizer's
    /// debug-build assert still backstops this in debug builds (and in
    /// the `release-dbg` CI profile).
    Off,
    /// Inspect every n-th element (clamped to at least 1). A fraction of
    /// a full pass, and still catches the blanket non-finite patterns a
    /// diverged run produces.
    Sampled(usize),
    /// Inspect every element — the default: one cheap `is_finite` pass
    /// against a GEMM's worth of MACs.
    Full,
}

/// What a guard does when it detects numeric trouble.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GuardAction {
    /// Fail the call with a typed [`NumericGuardError`] naming the op
    /// and the offending index — the caller decides what dies.
    Abort,
    /// Degrade the offending GEMM to FP32 (IEEE semantics: a NaN flows
    /// to the loss, where the watchdog sees it) instead of letting a
    /// non-finite value corrupt shared-exponent tiles. Quantize-side
    /// hazards (saturation/clamp) are report-only under this action.
    Fp32Fallback,
    /// Like [`GuardAction::Fp32Fallback`] for GEMMs, and additionally
    /// auto-widen the mantissa width class on quantize-side hazards
    /// ([`BfpContext::quantize_guarded`] climbs `next_wider_class`), with
    /// `widen_hint` set so training loops can widen their own width knob.
    Widen,
}

/// Numeric-guard policy carried by [`BfpContext`] and baked into every
/// [`MatmulPlan`]. The default detects loudly (full scan, abort) but
/// never flags healthy saturation/clamp levels (thresholds at 1.0).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GuardPolicy {
    pub scan: InputScan,
    pub action: GuardAction,
    /// Flag a quantized tensor when more than this fraction of tiles sit
    /// at the `E_MAX` exponent rail (1.0 = never).
    pub max_saturated_tile_frac: f64,
    /// Flag when more than this fraction of mantissas sit on the clamp
    /// rails `±(2^(m-1)-1)` (1.0 = never). Widening the mantissa class
    /// thins the rails (finer grid, fewer half-ulp round-ups).
    pub max_clamp_frac: f64,
}

impl Default for GuardPolicy {
    fn default() -> GuardPolicy {
        GuardPolicy {
            scan: InputScan::Full,
            action: GuardAction::Abort,
            max_saturated_tile_frac: 1.0,
            max_clamp_frac: 1.0,
        }
    }
}

/// What a guard detected.
#[derive(Debug, Clone, Copy)]
pub enum GuardEvent {
    /// NaN/Inf in data headed for the quantizer.
    NonFiniteInput { index: usize, value: f32 },
    /// Fraction of tiles at the shared-exponent `E_MAX` rail.
    ExponentSaturation { frac: f64 },
    /// Fraction of mantissas at the clamp rails.
    MantissaClampRate { frac: f64 },
}

impl std::fmt::Display for GuardEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GuardEvent::NonFiniteInput { index, value } => {
                write!(f, "non-finite input {value} at flat index {index}")
            }
            GuardEvent::ExponentSaturation { frac } => {
                write!(f, "{:.1}% of tiles at the E_MAX exponent rail", frac * 100.0)
            }
            GuardEvent::MantissaClampRate { frac } => {
                write!(f, "{:.1}% of mantissas at the clamp rails", frac * 100.0)
            }
        }
    }
}

/// Typed error for [`GuardAction::Abort`]: names the operation and the
/// detection, so a trainer can report "layer X, step N" by adding its
/// own context on top.
#[derive(Debug, Clone)]
pub struct NumericGuardError {
    /// The guarded operation, e.g. `quantize_execute(32x256 · 256x64)`.
    pub op: String,
    pub event: GuardEvent,
}

impl std::fmt::Display for NumericGuardError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "numeric guard tripped in {}: {}", self.op, self.event)
    }
}

impl std::error::Error for NumericGuardError {}

/// What a non-aborting guarded call did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GuardOutcome {
    /// A hazard was detected (false = clean run).
    pub tripped: bool,
    /// The GEMM ran in FP32 instead of BFP.
    pub fell_back_fp32: bool,
    /// The caller should widen its mantissa width class (and, for
    /// [`BfpContext::quantize_guarded`] under [`GuardAction::Widen`],
    /// the returned tensor already is wider than requested).
    pub widen_hint: bool,
}

/// Which matmul kernel layout a context dispatches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatmulKernel {
    /// The packed-panel register-blocked microkernel streaming the
    /// B operand's cached k-tile-major layout — the default hot path.
    Packed,
    /// The pre-panel row-major walk (always scalar inner loops). Kept
    /// reachable as the bench ladder's layout partner and a
    /// differential-test reference; bit-identical to `Packed`. Applies
    /// to plain execution only — the fused convert+matmul paths
    /// ([`MatmulPlan::quantize_execute`]) always stream packed panels,
    /// whatever this knob says.
    RowMajor,
}

/// Integer-accumulator policy for the tile MAC loops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccPolicy {
    /// `i32` when the proven overflow bound ([`acc_fits_i32`]) allows,
    /// `i64` otherwise — the default, and what the hardware maps.
    Auto,
    /// Always accumulate in `i64`. Same integer partials, same bits —
    /// a diagnostic knob for isolating accumulator-width effects in
    /// benches and tests.
    ForceI64,
}

/// Default rounding for context conveniences that quantize on the
/// caller's behalf without an explicit [`Rounding`]
/// ([`BfpContext::matmul_f32`]). Paths that thread caller-owned RNG
/// state (the accelerator sim's persistent converter stream) keep
/// passing `&mut Rounding` explicitly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundingPolicy {
    NearestEven,
    /// Stochastic rounding from a fresh Xorshift32 seeded with this
    /// value at each convenience call (deterministic per call).
    StochasticSeed(u32),
}

impl RoundingPolicy {
    fn owned(self) -> OwnedRounding {
        match self {
            RoundingPolicy::NearestEven => OwnedRounding::NearestEven,
            RoundingPolicy::StochasticSeed(s) => OwnedRounding::Stochastic(Xorshift32::new(s)),
        }
    }
}

/// All execution policy for the BFP datapath, resolved once.
///
/// [`BfpContext::from_env`] (or `Default`) reads `HBFP_THREADS` and
/// `HBFP_SIMD` exactly as the legacy entry points did; builder methods
/// override individual knobs. The context carries the *policy*; the
/// per-shape resolution lives in [`MatmulPlan`].
///
/// The ISA knob steers the matmul microkernel and the panel width; the
/// converters always run the process-wide family (every family is
/// bit-identical, so this is invisible in the results).
#[derive(Debug, Clone)]
pub struct BfpContext {
    threads: usize,
    backend: ParBackend,
    isa: Isa,
    kernel: MatmulKernel,
    tile: TileSize,
    acc: AccPolicy,
    rounding: RoundingPolicy,
    guard: GuardPolicy,
}

impl Default for BfpContext {
    fn default() -> BfpContext {
        BfpContext::from_env()
    }
}

impl BfpContext {
    /// Policy resolved from the environment: `HBFP_THREADS` (or all
    /// cores), the `HBFP_SIMD`-selected kernel family, pooled dispatch,
    /// the packed-panel kernel, the paper's t=24 exponent tiles,
    /// automatic accumulator selection, nearest-even rounding.
    pub fn from_env() -> BfpContext {
        BfpContext {
            threads: worker_threads(),
            backend: ParBackend::Pooled,
            isa: super::kernels::active(),
            kernel: MatmulKernel::Packed,
            tile: TileSize::Edge(24),
            acc: AccPolicy::Auto,
            rounding: RoundingPolicy::NearestEven,
            guard: GuardPolicy::default(),
        }
    }

    /// Cap the worker-lane budget (clamped to at least 1). Results are
    /// bit-identical for any thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Choose the dispatch backend (persistent pool vs per-call scoped
    /// spawns). Bit-identical either way; `Scoped` exists for the bench
    /// ladder's spawn-amortization rung.
    pub fn with_backend(mut self, backend: ParBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Force a SIMD kernel family. Clamped to what the CPU supports
    /// ([`Isa::clamped`]), so any value is safe; bit-identical across
    /// families.
    pub fn with_isa(mut self, isa: Isa) -> Self {
        self.isa = isa.clamped();
        self
    }

    /// Choose the matmul kernel layout (packed panels vs row-major).
    pub fn with_kernel(mut self, kernel: MatmulKernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Exponent-tile size used by [`BfpContext::plan_matmul`],
    /// [`BfpContext::quantize`], and [`BfpContext::quantize_inplace`].
    pub fn with_tile(mut self, tile: TileSize) -> Self {
        self.tile = tile;
        self
    }

    /// Accumulator policy override (see [`AccPolicy`]).
    pub fn with_acc(mut self, acc: AccPolicy) -> Self {
        self.acc = acc;
        self
    }

    /// Default rounding policy for the quantizing conveniences.
    pub fn with_rounding(mut self, rounding: RoundingPolicy) -> Self {
        self.rounding = rounding;
        self
    }

    /// Numeric-guard policy for the guarded entry points
    /// ([`MatmulPlan::quantize_execute_guarded`],
    /// [`BfpContext::quantize_guarded`]). The unguarded entry points are
    /// unaffected — guards are opt-in per call site, policy per context.
    pub fn with_guard(mut self, guard: GuardPolicy) -> Self {
        self.guard = guard;
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn backend(&self) -> ParBackend {
        self.backend
    }

    pub fn isa(&self) -> Isa {
        self.isa
    }

    pub fn kernel(&self) -> MatmulKernel {
        self.kernel
    }

    pub fn tile(&self) -> TileSize {
        self.tile
    }

    pub fn acc(&self) -> AccPolicy {
        self.acc
    }

    pub fn rounding_policy(&self) -> RoundingPolicy {
        self.rounding
    }

    pub fn guard(&self) -> GuardPolicy {
        self.guard
    }

    /// Pre-resolve a C = A·B execution for A: m x k and B: k x n at
    /// mantissa widths `(a_bits, b_bits)`, under this context's policy
    /// and tile size. The plan fixes the matmul tile edge, panel width,
    /// accumulator class, and lane counts once; executing it does no
    /// further policy work.
    pub fn plan_matmul(
        &self,
        m: usize,
        k: usize,
        n: usize,
        widths: (u32, u32),
    ) -> Result<MatmulPlan> {
        MatmulPlan::new(self, self.tile, m, k, n, widths.0, widths.1)
    }

    /// One-shot C = A·B: builds the plan from the operands (their tile
    /// configuration and widths — the context's tile default is not
    /// consulted, unlike [`BfpContext::plan_matmul`], which plans for
    /// `ctx.tile` and rejects operands quantized on a different grid).
    /// For repeated GEMMs of one shape, build the plan once with
    /// [`BfpContext::plan_matmul`].
    pub fn matmul(&self, a: &BfpTensor, b: &BfpTensor) -> Result<Vec<f32>> {
        self.plan_for_operands(a, b)?.execute(a, b)
    }

    /// [`BfpContext::matmul`] into a caller-provided buffer of exactly
    /// `a.rows * b.cols` elements.
    pub fn matmul_into(&self, a: &BfpTensor, b: &BfpTensor, out: &mut [f32]) -> Result<()> {
        self.plan_for_operands(a, b)?.execute_into(a, b, out)
    }

    /// One-shot fused FP→BFP convert + matmul: quantizes row-band tiles
    /// of `a` on the fly (per-band scratch, never a materialized A
    /// tensor) and MACs them against the resident `b`. The plan is
    /// built from **`b`'s tile configuration** (the context's tile
    /// default is not consulted — A must convert on B's tile grid), and
    /// the result is bit-identical to quantizing `a` at `b.tile` and
    /// multiplying, stochastic rounding included (same per-tile
    /// substreams).
    pub fn quantize_matmul(
        &self,
        a: &[f32],
        a_rows: usize,
        a_bits: u32,
        rounding: &mut Rounding,
        b: &BfpTensor,
    ) -> Result<Vec<f32>> {
        let plan = MatmulPlan::new(self, b.tile, a_rows, b.rows, b.cols, a_bits, b.mantissa_bits)?;
        plan.quantize_execute(a, rounding, b)
    }

    /// Quantize an f32 matrix into packed BFP storage under this
    /// context's tile size and thread budget. Bit-identical for any
    /// thread count (stochastic rounding uses per-tile substreams).
    pub fn quantize(
        &self,
        data: &[f32],
        rows: usize,
        cols: usize,
        mantissa_bits: u32,
        rounding: &mut Rounding,
    ) -> Result<BfpTensor> {
        obs_count(&OBS_TENSORS_QUANTIZED);
        BfpTensor::from_f32_impl(data, rows, cols, mantissa_bits, self.tile, rounding, self.threads)
    }

    /// In-place FP→BFP→FP round-trip of a row-major matrix (the
    /// host-side input-converter boundary) under this context's tile
    /// size and thread budget.
    pub fn quantize_inplace(
        &self,
        data: &mut [f32],
        rows: usize,
        cols: usize,
        mantissa_bits: u32,
        rounding: &mut Rounding,
    ) -> Result<()> {
        tensor::quantize_inplace_2d_impl(
            data,
            rows,
            cols,
            mantissa_bits,
            self.tile,
            rounding,
            self.threads,
        )
    }

    /// [`BfpContext::quantize`] behind this context's [`GuardPolicy`]:
    /// scan for non-finite input per policy, quantize, then check the
    /// exponent-saturation and mantissa-clamp fractions against the
    /// policy thresholds.
    ///
    /// Non-finite input **always** errors here regardless of
    /// [`GuardAction`] — there is no BFP representation of NaN/Inf, and
    /// the FP32-fallback escape hatch only exists on the GEMM path
    /// ([`MatmulPlan::quantize_execute_guarded`]).
    ///
    /// Saturation/clamp hazards follow the action: `Abort` fails with a
    /// typed [`NumericGuardError`]; `Fp32Fallback` reports (counters +
    /// `tripped`) and returns the tensor as-is; `Widen` climbs
    /// [`next_wider_class`] until the fractions fall inside the
    /// thresholds or the widest class (24 bits) is reached, setting
    /// `widen_hint` so the caller can persist the wider width.
    pub fn quantize_guarded(
        &self,
        data: &[f32],
        rows: usize,
        cols: usize,
        mantissa_bits: u32,
        rounding: &mut Rounding,
        stats: Option<&GuardStats>,
    ) -> Result<(BfpTensor, GuardOutcome)> {
        let stride = match self.guard.scan {
            InputScan::Off => None,
            InputScan::Sampled(s) => Some(s.max(1)),
            InputScan::Full => Some(1),
        };
        let mut outcome = GuardOutcome::default();
        if let Some(stride) = stride {
            if let Some(st) = stats {
                st.record_scan();
            }
            if let Some(err) = stats::scan_nonfinite(data, stride).error(data) {
                if let Some(st) = stats {
                    st.record_nonfinite();
                }
                return Err(anyhow::Error::new(NumericGuardError {
                    op: format!("quantize({rows}x{cols}, {mantissa_bits}b)"),
                    event: GuardEvent::NonFiniteInput {
                        index: err.index,
                        value: err.value,
                    },
                }));
            }
        }
        let mut bits = mantissa_bits;
        loop {
            let t = BfpTensor::from_f32_impl(data, rows, cols, bits, self.tile, rounding, self.threads)?;
            let sat = stats::saturated_tile_frac(&t);
            let clamp = stats::clamp_rail_frac(&t);
            let event = if sat > self.guard.max_saturated_tile_frac {
                Some(GuardEvent::ExponentSaturation { frac: sat })
            } else if clamp > self.guard.max_clamp_frac {
                Some(GuardEvent::MantissaClampRate { frac: clamp })
            } else {
                None
            };
            let Some(event) = event else {
                return Ok((t, outcome));
            };
            outcome.tripped = true;
            match event {
                GuardEvent::ExponentSaturation { .. } => {
                    if let Some(st) = stats {
                        st.record_saturation();
                    }
                }
                GuardEvent::MantissaClampRate { .. } => {
                    if let Some(st) = stats {
                        st.record_clamp();
                    }
                }
                GuardEvent::NonFiniteInput { .. } => unreachable!(),
            }
            match self.guard.action {
                GuardAction::Abort => {
                    return Err(anyhow::Error::new(NumericGuardError {
                        op: format!("quantize({rows}x{cols}, {bits}b)"),
                        event,
                    }))
                }
                GuardAction::Fp32Fallback => return Ok((t, outcome)),
                GuardAction::Widen => match next_wider_class(bits) {
                    // Note: widening thins the clamp rails but cannot
                    // relieve exponent saturation; a saturated tensor at
                    // 24 bits exits through the None arm below.
                    Some(w) => {
                        bits = w;
                        outcome.widen_hint = true;
                        if let Some(st) = stats {
                            st.record_widening();
                        }
                    }
                    None => return Ok((t, outcome)),
                },
            }
        }
    }

    /// Convenience: quantize both f32 operands (B once as resident
    /// weights, A through the fused converter) and multiply in BFP,
    /// rounding per the context's [`RoundingPolicy`].
    pub fn matmul_f32(
        &self,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        mantissa_bits: u32,
    ) -> Result<Vec<f32>> {
        let mut owned = self.rounding.owned();
        let qb = {
            let mut r = owned.as_rounding();
            self.quantize(b, k, n, mantissa_bits, &mut r)?
        };
        let mut r = owned.as_rounding();
        self.quantize_matmul(a, m, mantissa_bits, &mut r, &qb)
    }

    fn plan_for_operands(&self, a: &BfpTensor, b: &BfpTensor) -> Result<MatmulPlan> {
        matmul::check_shapes(a, b)?;
        MatmulPlan::new(self, a.tile, a.rows, a.cols, b.cols, a.mantissa_bits, b.mantissa_bits)
    }
}

/// A pre-resolved C = A·B execution: one (m, k, n, widths, tile) shape
/// under one context's policy, with the tile edge, panel width,
/// accumulator class, and lane counts fixed at plan time.
///
/// Build with [`BfpContext::plan_matmul`]; execute any number of times.
/// Operands are validated against the planned shape/widths/tile on every
/// call (cheap field comparisons), so a plan can never silently run a
/// mismatched GEMM.
#[derive(Debug, Clone, Copy)]
pub struct MatmulPlan {
    m: usize,
    k: usize,
    n: usize,
    a_bits: u32,
    b_bits: u32,
    tile: TileSize,
    kernel: MatmulKernel,
    backend: ParBackend,
    isa: Isa,
    /// Matmul tile edge (`matmul_tile_edge(tile, k)`).
    t: usize,
    /// Panel register width the B operand packs at (the ISA family's).
    nr: usize,
    /// Accumulator class: `i32` iff the overflow bound holds (and the
    /// context did not force `i64`).
    use_i32: bool,
    /// Lane count for [`MatmulPlan::execute`] (inline when 1).
    threads: usize,
    /// Converter tile dims for the fused A path (`tile.edge_or(m, k)`).
    th: usize,
    tw: usize,
    /// Lane count for the fused path (its bands follow `th`, not `t`).
    threads_fused: usize,
    /// Guard policy inherited from the planning context.
    guard: GuardPolicy,
}

impl MatmulPlan {
    fn new(
        ctx: &BfpContext,
        tile: TileSize,
        m: usize,
        k: usize,
        n: usize,
        a_bits: u32,
        b_bits: u32,
    ) -> Result<MatmulPlan> {
        tensor::check_width(a_bits)?;
        tensor::check_width(b_bits)?;
        if let TileSize::Edge(0) = tile {
            return Err(anyhow!("tile edge must be nonzero"));
        }
        let t = matmul_tile_edge(tile, k);
        let nr = ctx.isa.panel_nr();
        let tile_k = t.min(k).max(1);
        let use_i32 = match ctx.acc {
            AccPolicy::Auto => acc_fits_i32(tile_k, a_bits, b_bits),
            AccPolicy::ForceI64 => false,
        };
        let work = m * k * n;
        let bands = m.div_ceil(t).max(1);
        let threads = match ctx.kernel {
            MatmulKernel::Packed => pool::par_threads_simd(
                work,
                matmul::PAR_MIN_MACS,
                ctx.isa.par_floor_scale(),
                ctx.threads,
                bands,
            ),
            MatmulKernel::RowMajor => {
                pool::par_threads(work, matmul::PAR_MIN_MACS, ctx.threads, bands)
            }
        };
        let (th, tw) = tile.edge_or(m, k);
        let fused_bands = m.div_ceil(th).max(1);
        let threads_fused = pool::par_threads_simd(
            work,
            matmul::PAR_MIN_MACS,
            ctx.isa.par_floor_scale(),
            ctx.threads,
            fused_bands,
        );
        Ok(MatmulPlan {
            m,
            k,
            n,
            a_bits,
            b_bits,
            tile,
            kernel: ctx.kernel,
            backend: ctx.backend,
            isa: ctx.isa,
            t,
            nr,
            use_i32,
            threads,
            th,
            tw,
            threads_fused,
            guard: ctx.guard,
        })
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Output length (`m * n`) an [`MatmulPlan::execute_into`] buffer
    /// must have.
    pub fn out_len(&self) -> usize {
        self.m * self.n
    }

    /// Planned panel register width (the ISA family's).
    pub fn panel_nr(&self) -> usize {
        self.nr
    }

    /// Whether the plan accumulates k-tile partials in `i32` (the
    /// proven-bound fast class) rather than `i64`.
    pub fn uses_i32_acc(&self) -> bool {
        self.use_i32
    }

    /// Planned lane count for [`MatmulPlan::execute`].
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// C = A·B into a fresh row-major f32 vector.
    pub fn execute(&self, a: &BfpTensor, b: &BfpTensor) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.m * self.n];
        self.execute_into(a, b, &mut out)?;
        Ok(out)
    }

    /// C = A·B into a caller buffer of exactly [`MatmulPlan::out_len`]
    /// elements (zeroed and filled here). On the default packed-panel
    /// kernel with a warm panel cache, the single-lane path performs no
    /// heap allocation; multi-lane dispatch allocates only the per-band
    /// job list. (A cold panel cache packs the B layout once, and the
    /// row-major kernel keeps per-band accumulator scratch — those paths
    /// allocate regardless.) A length mismatch panics in debug builds
    /// and returns an error in release.
    pub fn execute_into(&self, a: &BfpTensor, b: &BfpTensor, out: &mut [f32]) -> Result<()> {
        obs_count(&OBS_GEMMS_EXECUTED);
        self.check_a(a)?;
        self.check_b(b)?;
        self.check_out(out.len())?;
        out.fill(0.0);
        if self.m == 0 || self.k == 0 || self.n == 0 {
            return Ok(());
        }
        match self.kernel {
            MatmulKernel::Packed => matmul::packed_matmul_into(
                a,
                b,
                out,
                self.t,
                self.nr,
                self.threads,
                self.backend,
                self.isa,
                self.use_i32,
            ),
            MatmulKernel::RowMajor => matmul::rowmajor_matmul_into(
                a,
                b,
                out,
                self.t,
                self.threads,
                self.backend,
                self.use_i32,
            ),
        }
        Ok(())
    }

    /// Fused FP→BFP convert + matmul into a fresh vector: `a` (row-major
    /// f32, `m x k`) streams through the converter band by band and MACs
    /// against the resident `b`. Bit-identical to quantizing `a` first
    /// and calling [`MatmulPlan::execute`], stochastic rounding included.
    /// The fused path always runs the packed-panel kernel (packing `b`'s
    /// panels on first use) — a `MatmulKernel::RowMajor` context affects
    /// only plain execution.
    pub fn quantize_execute(
        &self,
        a: &[f32],
        rounding: &mut Rounding,
        b: &BfpTensor,
    ) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.m * self.n];
        self.quantize_execute_into(a, rounding, b, &mut out)?;
        Ok(out)
    }

    /// [`MatmulPlan::quantize_execute`] into a caller buffer of exactly
    /// [`MatmulPlan::out_len`] elements. The per-band converter scratch
    /// is inherent to the fused path; the output itself is not
    /// reallocated. Length mismatch: debug panic, release error.
    pub fn quantize_execute_into(
        &self,
        a: &[f32],
        rounding: &mut Rounding,
        b: &BfpTensor,
        out: &mut [f32],
    ) -> Result<()> {
        obs_count(&OBS_GEMMS_EXECUTED);
        if a.len() != self.m * self.k {
            return Err(anyhow!("a len {} != {}x{}", a.len(), self.m, self.k));
        }
        self.check_b(b)?;
        self.check_out(out.len())?;
        out.fill(0.0);
        if self.m * self.k == 0 {
            return Ok(());
        }
        // Capture before the n == 0 early return: the caller's RNG
        // advances exactly once per fused call, matching the legacy
        // entry point draw for draw.
        let mode = TileRounding::capture(rounding);
        if self.n == 0 {
            return Ok(());
        }
        matmul::fused_matmul_into(
            a,
            b,
            out,
            self.m,
            self.a_bits,
            mode,
            self.t,
            self.nr,
            self.th,
            self.tw,
            self.threads_fused,
            self.backend,
            self.isa,
            self.use_i32,
        );
        Ok(())
    }

    /// [`MatmulPlan::quantize_execute_into`] behind this plan's
    /// [`GuardPolicy`]: scan the f32 `a` operand per policy, and on a
    /// non-finite detection either abort with a typed
    /// [`NumericGuardError`] or degrade this one GEMM to FP32 (keeping
    /// IEEE semantics so the NaN reaches the loss instead of corrupting
    /// shared-exponent tiles). A clean scan runs the normal fused path
    /// bit-identically to the unguarded call.
    ///
    /// The caller's RNG advances exactly once per call on every path
    /// (including the FP32 fallback), so a recovered run replays the
    /// same rounding stream as a clean one.
    ///
    /// `stats` (optional) receives scan/detection/degradation counters.
    pub fn quantize_execute_guarded(
        &self,
        a: &[f32],
        rounding: &mut Rounding,
        b: &BfpTensor,
        out: &mut [f32],
        stats: Option<&GuardStats>,
    ) -> Result<GuardOutcome> {
        let stride = match self.guard.scan {
            InputScan::Off => None,
            InputScan::Sampled(s) => Some(s.max(1)),
            InputScan::Full => Some(1),
        };
        let mut outcome = GuardOutcome::default();
        if let Some(stride) = stride {
            if let Some(st) = stats {
                st.record_scan();
            }
            if let Some(err) = stats::scan_nonfinite(a, stride).error(a) {
                if let Some(st) = stats {
                    st.record_nonfinite();
                }
                outcome.tripped = true;
                let op = format!(
                    "quantize_execute({}x{} · {}x{})",
                    self.m, self.k, self.k, self.n
                );
                match self.guard.action {
                    GuardAction::Abort => {
                        return Err(anyhow::Error::new(NumericGuardError {
                            op,
                            event: GuardEvent::NonFiniteInput {
                                index: err.index,
                                value: err.value,
                            },
                        }))
                    }
                    GuardAction::Fp32Fallback | GuardAction::Widen => {
                        if a.len() != self.m * self.k {
                            return Err(anyhow!("a len {} != {}x{}", a.len(), self.m, self.k));
                        }
                        self.check_b(b)?;
                        self.check_out(out.len())?;
                        // RNG draw parity with the fused path.
                        let _ = TileRounding::capture(rounding);
                        let bf = b.to_f32();
                        let full = matmul::fp32_matmul(a, &bf, self.m, self.k, self.n);
                        out.copy_from_slice(&full);
                        outcome.fell_back_fp32 = true;
                        outcome.widen_hint = self.guard.action == GuardAction::Widen;
                        if let Some(st) = stats {
                            st.record_fp32_fallback();
                        }
                        return Ok(outcome);
                    }
                }
            }
        }
        self.quantize_execute_into(a, rounding, b, out)?;
        Ok(outcome)
    }

    fn check_a(&self, a: &BfpTensor) -> Result<()> {
        if a.rows != self.m || a.cols != self.k {
            return Err(anyhow!(
                "A is {}x{}, plan expects {}x{}",
                a.rows,
                a.cols,
                self.m,
                self.k
            ));
        }
        if a.mantissa_bits != self.a_bits {
            return Err(anyhow!(
                "A mantissa width {} != planned {}",
                a.mantissa_bits,
                self.a_bits
            ));
        }
        if a.tile != self.tile {
            return Err(anyhow!("A tile {:?} != planned {:?}", a.tile, self.tile));
        }
        Ok(())
    }

    fn check_b(&self, b: &BfpTensor) -> Result<()> {
        if b.rows != self.k || b.cols != self.n {
            return Err(anyhow!(
                "B is {}x{}, plan expects {}x{}",
                b.rows,
                b.cols,
                self.k,
                self.n
            ));
        }
        if b.mantissa_bits != self.b_bits {
            return Err(anyhow!(
                "B mantissa width {} != planned {}",
                b.mantissa_bits,
                self.b_bits
            ));
        }
        if b.tile != self.tile {
            return Err(anyhow!("B tile {:?} != planned {:?}", b.tile, self.tile));
        }
        Ok(())
    }

    fn check_out(&self, len: usize) -> Result<()> {
        if len != self.m * self.n {
            let msg = format!(
                "plan output buffer holds {len} elements, needs {} ({}x{})",
                self.m * self.n,
                self.m,
                self.n
            );
            // Loud in development, recoverable in production: a sized
            // output buffer is the caller's contract, but a release
            // binary must not take down a serving process over it.
            if cfg!(debug_assertions) {
                panic!("{msg}");
            }
            return Err(anyhow!(msg));
        }
        Ok(())
    }
}

// ------------------------------------------------------------ plan cache

/// The shape/width tuple a [`PlanCache`] entry is keyed on. The cache
/// belongs to exactly one context (plans bake in the owning context's
/// tile, ISA, and thread policy), so the context's knobs are *not* part
/// of the key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanKey {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub a_bits: u32,
    pub b_bits: u32,
}

/// A bounded, shape-keyed cache of [`MatmulPlan`]s with deterministic
/// LRU eviction — the serving front-end's answer to micro-batches whose
/// row count varies with queue depth: each distinct (m, k, n, widths)
/// plans once, then executes with zero policy work.
///
/// Determinism contract: entries are held most-recently-used-first in a
/// plain vector; a hit moves its entry to the front, an insert beyond
/// capacity evicts the back. For a fixed request sequence the hit /
/// miss / eviction counters — and the surviving key set — are exact
/// functions of that sequence, which the overload-soak determinism test
/// compares across runs.
#[derive(Debug)]
pub struct PlanCache {
    capacity: usize,
    /// MRU-first.
    entries: Vec<(PlanKey, MatmulPlan)>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl PlanCache {
    /// A cache holding at most `capacity` plans (clamped to ≥ 1).
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache {
            capacity: capacity.max(1),
            entries: Vec::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// The plan for (m x k) · (k x n) at `widths`, planning through
    /// `ctx` on a miss. Always use the same context for one cache: the
    /// key does not cover the context's policy knobs.
    pub fn get_or_plan(
        &mut self,
        ctx: &BfpContext,
        m: usize,
        k: usize,
        n: usize,
        widths: (u32, u32),
    ) -> Result<MatmulPlan> {
        let key = PlanKey { m, k, n, a_bits: widths.0, b_bits: widths.1 };
        if let Some(pos) = self.entries.iter().position(|(k2, _)| *k2 == key) {
            self.hits += 1;
            let entry = self.entries.remove(pos);
            self.entries.insert(0, entry);
            return Ok(self.entries[0].1);
        }
        self.misses += 1;
        let plan = ctx.plan_matmul(m, k, n, widths)?;
        self.entries.insert(0, (key, plan));
        if self.entries.len() > self.capacity {
            self.entries.pop();
            self.evictions += 1;
        }
        Ok(plan)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Register the cache counters into `reg` under `prefix` (dot-joined
    /// when non-empty): `len`, `hits`, `misses`, `evictions` — the same
    /// key set the serve metrics JSON has always used.
    pub fn export_metrics(&self, reg: &crate::obs::Registry, prefix: &str) {
        let name = |k: &str| {
            if prefix.is_empty() {
                k.to_string()
            } else {
                format!("{prefix}.{k}")
            }
        };
        reg.counter(&name("len"), self.len() as u64);
        reg.counter(&name("hits"), self.hits);
        reg.counter(&name("misses"), self.misses);
        reg.counter(&name("evictions"), self.evictions);
    }

    /// Resident keys, most-recently-used first (test observability).
    pub fn keys(&self) -> Vec<PlanKey> {
        self.entries.iter().map(|(k, _)| *k).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn rand_mat(rng: &mut SplitMix64, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal() * scale).collect()
    }

    fn quantize(ctx: &BfpContext, data: &[f32], rows: usize, cols: usize, bits: u32) -> BfpTensor {
        ctx.quantize(data, rows, cols, bits, &mut Rounding::NearestEven).unwrap()
    }

    #[test]
    fn env_context_defaults() {
        let ctx = BfpContext::from_env();
        assert!(ctx.threads() >= 1);
        assert_eq!(ctx.backend(), ParBackend::Pooled);
        assert_eq!(ctx.kernel(), MatmulKernel::Packed);
        assert_eq!(ctx.acc(), AccPolicy::Auto);
        assert_eq!(ctx.rounding_policy(), RoundingPolicy::NearestEven);
        assert_eq!(ctx.isa(), crate::bfp::kernels::active());
    }

    #[test]
    fn builder_clamps() {
        let ctx = BfpContext::from_env().with_threads(0);
        assert_eq!(ctx.threads(), 1);
        // any Isa value is safe: the builder clamps to the CPU
        for isa in [Isa::Scalar, Isa::Sse41, Isa::Avx2, Isa::Neon] {
            let c = BfpContext::from_env().with_isa(isa);
            assert!(crate::bfp::kernels::detected().contains(&c.isa()));
        }
    }

    #[test]
    fn plan_precomputes_policy() {
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(24));
        let plan = ctx.plan_matmul(8, 256, 256, (8, 8)).unwrap();
        assert_eq!((plan.m(), plan.k(), plan.n()), (8, 256, 256));
        assert_eq!(plan.out_len(), 8 * 256);
        assert_eq!(plan.panel_nr(), ctx.isa().panel_nr());
        // tile_k = 24: 24 * 2^14 fits i32
        assert!(plan.uses_i32_acc());
        // at 16x16-bit widths a 2-deep tile already overflows i32
        let wide = ctx.plan_matmul(8, 256, 256, (16, 16)).unwrap();
        assert!(!wide.uses_i32_acc());
        // the override forces the wide class even when i32 would fit
        let forced = ctx
            .clone()
            .with_acc(AccPolicy::ForceI64)
            .plan_matmul(8, 256, 256, (8, 8))
            .unwrap();
        assert!(!forced.uses_i32_acc());
    }

    #[test]
    fn plan_rejects_bad_config() {
        let ctx = BfpContext::from_env();
        assert!(ctx.plan_matmul(4, 4, 4, (1, 8)).is_err(), "width below range");
        assert!(ctx.plan_matmul(4, 4, 4, (8, 25)).is_err(), "width above range");
        let z = BfpContext::from_env().with_tile(TileSize::Edge(0));
        assert!(z.plan_matmul(4, 4, 4, (8, 8)).is_err(), "zero tile edge");
    }

    #[test]
    fn plan_validates_operands() {
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8));
        let mut rng = SplitMix64::new(1);
        let a = rand_mat(&mut rng, 6 * 10, 1.0);
        let b = rand_mat(&mut rng, 10 * 4, 1.0);
        let qa = quantize(&ctx, &a, 6, 10, 8);
        let qb = quantize(&ctx, &b, 10, 4, 8);
        let plan = ctx.plan_matmul(6, 10, 4, (8, 8)).unwrap();
        assert!(plan.execute(&qa, &qb).is_ok());
        // wrong shapes / widths / tiles are rejected, never misread
        assert!(plan.execute(&qb, &qa).is_err(), "swapped operands");
        let q12 = quantize(&ctx, &a, 6, 10, 12);
        assert!(plan.execute(&q12, &qb).is_err(), "width mismatch");
        let wt = BfpContext::from_env().with_tile(TileSize::Whole);
        let qa_whole = quantize(&wt, &a, 6, 10, 8);
        assert!(plan.execute(&qa_whole, &qb).is_err(), "tile mismatch");
    }

    // The full policy-knob cross-product (kernel x backend x acc x
    // threads, bit-equal to the naive reference) lives in
    // tests/context_api.rs::policy_knobs_never_change_bits — one copy.

    #[test]
    fn execute_into_reuses_buffer() {
        let mut rng = SplitMix64::new(7);
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8));
        let (m, k, n) = (9, 12, 7);
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        let qa = quantize(&ctx, &a, m, k, 8);
        let qb = quantize(&ctx, &b, k, n, 8);
        let plan = ctx.plan_matmul(m, k, n, (8, 8)).unwrap();
        let want = plan.execute(&qa, &qb).unwrap();
        let mut out = vec![f32::NAN; m * n]; // stale contents must be overwritten
        plan.execute_into(&qa, &qb, &mut out).unwrap();
        assert!(out == want);
        plan.execute_into(&qa, &qb, &mut out).unwrap();
        assert!(out == want, "reused buffer must reproduce the result");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "plan output buffer")]
    fn execute_into_length_mismatch_panics_in_debug() {
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(4));
        let qa = quantize(&ctx, &[1.0; 16], 4, 4, 8);
        let qb = quantize(&ctx, &[1.0; 16], 4, 4, 8);
        let plan = ctx.plan_matmul(4, 4, 4, (8, 8)).unwrap();
        let mut out = vec![0.0f32; 15];
        let _ = plan.execute_into(&qa, &qb, &mut out);
    }

    #[test]
    #[cfg(not(debug_assertions))]
    fn execute_into_length_mismatch_errors_in_release() {
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(4));
        let qa = quantize(&ctx, &[1.0; 16], 4, 4, 8);
        let qb = quantize(&ctx, &[1.0; 16], 4, 4, 8);
        let plan = ctx.plan_matmul(4, 4, 4, (8, 8)).unwrap();
        let mut out = vec![0.0f32; 15];
        assert!(plan.execute_into(&qa, &qb, &mut out).is_err());
        let mut out = vec![0.0f32; 17];
        assert!(plan.quantize_execute_into(&[1.0; 16], &mut Rounding::NearestEven, &qb, &mut out)
            .is_err());
    }

    #[test]
    fn fused_equals_materialized_through_the_plan() {
        let mut rng = SplitMix64::new(0xFAB);
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8));
        let (m, k, n) = (14, 22, 18);
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        let qb = quantize(&ctx, &b, k, n, 8);
        let plan = ctx.plan_matmul(m, k, n, (8, 8)).unwrap();

        // nearest-even
        let qa = quantize(&ctx, &a, m, k, 8);
        let want = plan.execute(&qa, &qb).unwrap();
        let got = plan.quantize_execute(&a, &mut Rounding::NearestEven, &qb).unwrap();
        assert!(got == want);

        // stochastic: same seed, same per-tile substreams
        let mut r1 = Xorshift32::new(0xA5);
        let mut r2 = Xorshift32::new(0xA5);
        let qa_s = ctx.quantize(&a, m, k, 8, &mut Rounding::Stochastic(&mut r1)).unwrap();
        let want_s = plan.execute(&qa_s, &qb).unwrap();
        let got_s = plan.quantize_execute(&a, &mut Rounding::Stochastic(&mut r2), &qb).unwrap();
        assert!(got_s == want_s);
    }

    #[test]
    fn zero_dim_plans_execute_cleanly() {
        let ctx = BfpContext::from_env().with_tile(TileSize::Whole);
        let qa = quantize(&ctx, &[], 0, 3, 8);
        let qb = quantize(&ctx, &[1.0; 6], 3, 2, 8);
        let plan = ctx.plan_matmul(0, 3, 2, (8, 8)).unwrap();
        assert_eq!(plan.execute(&qa, &qb).unwrap().len(), 0);
        // fused with n == 0 still advances the caller RNG exactly once
        let qe = quantize(&ctx, &[], 3, 0, 8);
        let plan0 = ctx.plan_matmul(2, 3, 0, (8, 8)).unwrap();
        let mut r = Xorshift32::new(9);
        let mut replay = Xorshift32::new(9);
        let out = plan0
            .quantize_execute(&[1.0; 6], &mut Rounding::Stochastic(&mut r), &qe)
            .unwrap();
        assert!(out.is_empty());
        let _ = replay.next_u32(); // the capture draw
        assert_eq!(r.next_u32(), replay.next_u32());
    }

    #[test]
    fn matmul_f32_policy_rounding() {
        let mut rng = SplitMix64::new(0x33);
        let (m, k, n) = (10, 12, 8);
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8));
        let rne = ctx.matmul_f32(&a, &b, m, k, n, 8).unwrap();
        // explicit composition must match the convenience
        let qb = quantize(&ctx, &b, k, n, 8);
        let want = ctx.quantize_matmul(&a, m, 8, &mut Rounding::NearestEven, &qb).unwrap();
        assert!(rne == want);
        // a stochastic policy is deterministic per call
        let sctx = ctx.clone().with_rounding(RoundingPolicy::StochasticSeed(42));
        let s1 = sctx.matmul_f32(&a, &b, m, k, n, 8).unwrap();
        let s2 = sctx.matmul_f32(&a, &b, m, k, n, 8).unwrap();
        assert!(s1 == s2);
    }

    #[test]
    fn guarded_clean_run_is_bit_identical_and_untripped() {
        let mut rng = SplitMix64::new(0x60A);
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8));
        let (m, k, n) = (7, 16, 9);
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        let qb = quantize(&ctx, &b, k, n, 8);
        let plan = ctx.plan_matmul(m, k, n, (8, 8)).unwrap();
        let want = plan.quantize_execute(&a, &mut Rounding::NearestEven, &qb).unwrap();
        let stats = GuardStats::new();
        let mut out = vec![0.0f32; m * n];
        let outcome = plan
            .quantize_execute_guarded(&a, &mut Rounding::NearestEven, &qb, &mut out, Some(&stats))
            .unwrap();
        assert_eq!(outcome, GuardOutcome::default());
        assert!(out == want, "guard must not change bits on a clean run");
        assert_eq!(stats.scans(), 1);
        assert_eq!(stats.nonfinite_inputs(), 0);
        // stochastic path: guarded call consumes the same RNG stream
        let mut r1 = Xorshift32::new(0xBEE);
        let mut r2 = Xorshift32::new(0xBEE);
        let want_s = plan.quantize_execute(&a, &mut Rounding::Stochastic(&mut r1), &qb).unwrap();
        let outcome_s = plan
            .quantize_execute_guarded(&a, &mut Rounding::Stochastic(&mut r2), &qb, &mut out, None)
            .unwrap();
        assert!(!outcome_s.tripped);
        assert!(out == want_s);
        assert_eq!(r1.next_u32(), r2.next_u32(), "RNG streams must stay in lockstep");
    }

    #[test]
    fn guarded_nan_aborts_with_typed_error() {
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(4));
        let (m, k, n) = (4, 8, 4);
        let mut a = vec![1.0f32; m * k];
        a[13] = f32::NAN;
        let qb = quantize(&ctx, &vec![1.0f32; k * n], k, n, 8);
        let plan = ctx.plan_matmul(m, k, n, (8, 8)).unwrap();
        let mut out = vec![0.0f32; m * n];
        let err = plan
            .quantize_execute_guarded(&a, &mut Rounding::NearestEven, &qb, &mut out, None)
            .unwrap_err();
        let guard = err.downcast_ref::<NumericGuardError>().expect("typed guard error");
        match guard.event {
            GuardEvent::NonFiniteInput { index, .. } => assert_eq!(index, 13),
            ref other => panic!("wrong event: {other}"),
        }
        // InputScan::Off skips detection; the NaN reaches the output
        // (via the quantizer, which tolerates it only in release builds —
        // keep this leg debug-safe by scanning but never matching).
        let off = ctx.clone().with_guard(GuardPolicy {
            scan: InputScan::Sampled(1000),
            ..GuardPolicy::default()
        });
        // index 13 is not a multiple of 1000, so the sampled scan misses
        // it and the sampled policy demonstrates its blind spot — but a
        // stride that lands on it still catches it.
        let plan_off = off.plan_matmul(m, k, n, (8, 8)).unwrap();
        assert_eq!(plan_off.guard.scan, InputScan::Sampled(1000));
        let on = ctx.clone().with_guard(GuardPolicy {
            scan: InputScan::Sampled(13),
            ..GuardPolicy::default()
        });
        let plan_on = on.plan_matmul(m, k, n, (8, 8)).unwrap();
        assert!(plan_on
            .quantize_execute_guarded(&a, &mut Rounding::NearestEven, &qb, &mut out, None)
            .is_err());
    }

    #[test]
    fn guarded_nan_fp32_fallback_matches_ieee_and_keeps_rng_parity() {
        let mut rng = SplitMix64::new(0xF01);
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8)).with_guard(GuardPolicy {
            action: GuardAction::Fp32Fallback,
            ..GuardPolicy::default()
        });
        let (m, k, n) = (5, 12, 6);
        let mut a = rand_mat(&mut rng, m * k, 1.0);
        a[20] = f32::INFINITY;
        let b = rand_mat(&mut rng, k * n, 1.0);
        let qb = quantize(&ctx, &b, k, n, 8);
        let plan = ctx.plan_matmul(m, k, n, (8, 8)).unwrap();
        let stats = GuardStats::new();
        let mut out = vec![0.0f32; m * n];
        let mut r = Xorshift32::new(0x51);
        let outcome = plan
            .quantize_execute_guarded(&a, &mut Rounding::Stochastic(&mut r), &qb, &mut out, Some(&stats))
            .unwrap();
        assert!(outcome.tripped && outcome.fell_back_fp32);
        assert!(!outcome.widen_hint, "Fp32Fallback does not ask for widening");
        assert_eq!(stats.fp32_fallbacks(), 1);
        let want = matmul::fp32_matmul(&a, &qb.to_f32(), m, k, n);
        assert!(out == want, "fallback must be the IEEE product of a and dequantized b");
        // the fallback consumed exactly the capture draw, like the fused path
        let mut replay = Xorshift32::new(0x51);
        let _ = replay.next_u32();
        assert_eq!(r.next_u32(), replay.next_u32());
        // Widen action also falls back, and additionally hints
        let wctx = ctx.clone().with_guard(GuardPolicy {
            action: GuardAction::Widen,
            ..GuardPolicy::default()
        });
        let wplan = wctx.plan_matmul(m, k, n, (8, 8)).unwrap();
        let w = wplan
            .quantize_execute_guarded(&a, &mut Rounding::NearestEven, &qb, &mut out, None)
            .unwrap();
        assert!(w.tripped && w.fell_back_fp32 && w.widen_hint);
    }

    #[test]
    fn quantize_guarded_rejects_nonfinite_under_every_action() {
        let mut data = vec![1.0f32; 16];
        data[5] = f32::NEG_INFINITY;
        for action in [GuardAction::Abort, GuardAction::Fp32Fallback, GuardAction::Widen] {
            let ctx = BfpContext::from_env().with_tile(TileSize::Edge(4)).with_guard(GuardPolicy {
                action,
                ..GuardPolicy::default()
            });
            let err = ctx
                .quantize_guarded(&data, 4, 4, 8, &mut Rounding::NearestEven, None)
                .unwrap_err();
            assert!(err.downcast_ref::<NumericGuardError>().is_some(), "{action:?}");
        }
    }

    #[test]
    fn quantize_guarded_widen_ladder_terminates_at_widest_class() {
        // a threshold below zero trips on any clamp fraction, so the
        // ladder must climb 8 -> 16 -> 24 and then stop at the widest
        // class instead of looping.
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(4)).with_guard(GuardPolicy {
            action: GuardAction::Widen,
            max_clamp_frac: -1.0,
            ..GuardPolicy::default()
        });
        let data = vec![1.0f32; 16];
        let stats = GuardStats::new();
        let (t, outcome) = ctx
            .quantize_guarded(&data, 4, 4, 8, &mut Rounding::NearestEven, Some(&stats))
            .unwrap();
        assert_eq!(t.mantissa_bits, 24);
        assert!(outcome.tripped && outcome.widen_hint);
        assert_eq!(stats.widenings(), 2, "8 -> 16 -> 24");
        // saturation on f32::MAX data: Abort names the event...
        let sat = BfpContext::from_env().with_tile(TileSize::Edge(4)).with_guard(GuardPolicy {
            max_saturated_tile_frac: 0.5,
            ..GuardPolicy::default()
        });
        let big = vec![f32::MAX; 16];
        let err = sat
            .quantize_guarded(&big, 4, 4, 8, &mut Rounding::NearestEven, None)
            .unwrap_err();
        let g = err.downcast_ref::<NumericGuardError>().unwrap();
        assert!(matches!(g.event, GuardEvent::ExponentSaturation { .. }));
        // ...Fp32Fallback reports without widening...
        let rep = sat.clone().with_guard(GuardPolicy {
            action: GuardAction::Fp32Fallback,
            max_saturated_tile_frac: 0.5,
            ..GuardPolicy::default()
        });
        let (t8, o) = rep
            .quantize_guarded(&big, 4, 4, 8, &mut Rounding::NearestEven, None)
            .unwrap();
        assert_eq!(t8.mantissa_bits, 8);
        assert!(o.tripped && !o.widen_hint);
        // ...and Widen cannot fix saturation but still terminates.
        let wsat = sat.clone().with_guard(GuardPolicy {
            action: GuardAction::Widen,
            max_saturated_tile_frac: 0.5,
            ..GuardPolicy::default()
        });
        let (t24, o24) = wsat
            .quantize_guarded(&big, 4, 4, 8, &mut Rounding::NearestEven, None)
            .unwrap();
        assert_eq!(t24.mantissa_bits, 24);
        assert!(o24.tripped && o24.widen_hint);
    }

    #[test]
    fn plan_cache_hits_misses_and_lru_eviction() {
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8));
        let mut cache = PlanCache::new(2);
        let shapes = [(1usize, 16usize, 8usize), (4, 16, 8), (8, 16, 8)];
        // miss, miss, hit, hit — nothing evicted yet
        cache.get_or_plan(&ctx, shapes[0].0, shapes[0].1, shapes[0].2, (8, 8)).unwrap();
        cache.get_or_plan(&ctx, shapes[1].0, shapes[1].1, shapes[1].2, (8, 8)).unwrap();
        cache.get_or_plan(&ctx, shapes[0].0, shapes[0].1, shapes[0].2, (8, 8)).unwrap();
        cache.get_or_plan(&ctx, shapes[0].0, shapes[0].1, shapes[0].2, (8, 8)).unwrap();
        assert_eq!((cache.hits(), cache.misses(), cache.evictions()), (2, 2, 0));
        // third shape evicts the least-recently-used (m=4)
        cache.get_or_plan(&ctx, shapes[2].0, shapes[2].1, shapes[2].2, (8, 8)).unwrap();
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.len(), 2);
        let ms: Vec<usize> = cache.keys().iter().map(|k| k.m).collect();
        assert_eq!(ms, vec![8, 1], "MRU first; m=4 evicted");
        // the evicted shape misses again; widths are part of the key
        cache.get_or_plan(&ctx, shapes[1].0, shapes[1].1, shapes[1].2, (8, 8)).unwrap();
        assert_eq!(cache.misses(), 4);
        cache.get_or_plan(&ctx, shapes[1].0, shapes[1].1, shapes[1].2, (8, 16)).unwrap();
        assert_eq!(cache.misses(), 5, "different widths = different plan");
        // cached plans execute like fresh ones
        let plan = cache.get_or_plan(&ctx, 2, 16, 8, (8, 8)).unwrap();
        let mut rng = SplitMix64::new(3);
        let a = quantize(&ctx, &rand_mat(&mut rng, 2 * 16, 1.0), 2, 16, 8);
        let b = quantize(&ctx, &rand_mat(&mut rng, 16 * 8, 1.0), 16, 8, 8);
        assert_eq!(plan.execute(&a, &b).unwrap(), ctx.matmul(&a, &b).unwrap());
    }

    #[test]
    fn plan_cache_replay_is_deterministic() {
        // same request sequence -> same counters and same resident keys
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8));
        let run = || {
            let mut cache = PlanCache::new(3);
            // deterministic pseudo-random m sequence over a few rungs
            let mut x = 9u64;
            for _ in 0..64 {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let m = 1 + (x >> 33) as usize % 8;
                cache.get_or_plan(&ctx, m, 32, 16, (8, 8)).unwrap();
            }
            (cache.hits(), cache.misses(), cache.evictions(), cache.keys())
        };
        assert_eq!(run(), run());
    }
}
