//! BFP matrix multiplication with integer MACs + FP32 tile accumulation —
//! the software model of the paper's MatMul unit (Figure 2).
//!
//! Per (t x t) tile pair: the mantissa dot products run entirely in integer
//! arithmetic (`i64` accumulators — the "wide accumulators present in the
//! MatMul unit"); each tile-partial is scaled by `2^(e_a + e_b)` once and
//! added to the FP32 output accumulator. That is exactly Equation (2) plus
//! the §4.2 tiling rule: "tile multiplications are performed in fixed
//! point, and their results are accumulated in floating point arithmetic".

use anyhow::{anyhow, Result};

use super::quant::exp2i;
use super::tensor::{BfpTensor, TileSize};

/// C = A · B over BFP tensors; returns row-major f32 (the BFP→FP unit
/// output). Requires matching tile configurations so tile boundaries align
/// on the contraction dimension.
pub fn bfp_matmul(a: &BfpTensor, b: &BfpTensor) -> Result<Vec<f32>> {
    if a.cols != b.rows {
        return Err(anyhow!("contraction mismatch: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols));
    }
    if a.tile != b.tile {
        return Err(anyhow!("tile mismatch: {:?} vs {:?}", a.tile, b.tile));
    }
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let ma = a.mantissa_bits as i32;
    let mb = b.mantissa_bits as i32;
    let t = match a.tile {
        TileSize::Whole => k.max(1),
        TileSize::Edge(t) => t,
    };
    let mut out = vec![0.0f32; m * n];
    // Tile loops: (i-tile, j-tile, k-tile); integer MAC inside. The inner
    // kernel accumulates a row of i64 partials while walking B row-major
    // (contiguous loads) — §Perf L3: ~4x over the naive j-innermost walk
    // (see `cargo bench bfp_ops` naive-vs-blocked rows).
    let mut scratch = vec![0i64; t.min(n) * t.min(m).max(1)];
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + t).min(m);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + t).min(n);
            let tj = j1 - j0;
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + t).min(k);
                // Shared exponents are constant across the tile pair.
                let ea = a.exponent_at(i0, k0);
                let eb = b.exponent_at(k0, j0);
                // scale = 2^(ea - (ma-1)) * 2^(eb - (mb-1)), applied once
                // per tile-partial (the FP realignment the paper amortizes
                // over 2N fixed-point ops).
                let scale = exp2i(ea - (ma - 1)) * exp2i(eb - (mb - 1));
                let ti = i1 - i0;
                let acc = &mut scratch[..ti * tj];
                acc.fill(0);
                for i in i0..i1 {
                    let arow = &a.mantissas[i * k + k0..i * k + k1];
                    let accrow = &mut acc[(i - i0) * tj..(i - i0 + 1) * tj];
                    for (dk, &qa) in arow.iter().enumerate() {
                        if qa == 0 {
                            continue;
                        }
                        let qa64 = qa as i64;
                        let brow = &b.mantissas[(k0 + dk) * n + j0..(k0 + dk) * n + j1];
                        for (aj, &qb) in accrow.iter_mut().zip(brow) {
                            *aj += qa64 * qb as i64;
                        }
                    }
                }
                for i in i0..i1 {
                    let accrow = &acc[(i - i0) * tj..(i - i0 + 1) * tj];
                    let orow = &mut out[i * n + j0..i * n + j1];
                    for (o, &v) in orow.iter_mut().zip(accrow) {
                        *o += v as f32 * scale;
                    }
                }
                k0 = k1;
            }
            j0 = j1;
        }
        i0 = i1;
    }
    Ok(out)
}

/// The pre-optimization j-innermost kernel, kept for the §Perf
/// before/after bench and as a differential-testing partner (must agree
/// with `bfp_matmul` bit-for-bit — both sum the same i64 partials).
pub fn bfp_matmul_naive(a: &BfpTensor, b: &BfpTensor) -> Result<Vec<f32>> {
    if a.cols != b.rows {
        return Err(anyhow!("contraction mismatch: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols));
    }
    if a.tile != b.tile {
        return Err(anyhow!("tile mismatch: {:?} vs {:?}", a.tile, b.tile));
    }
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let ma = a.mantissa_bits as i32;
    let mb = b.mantissa_bits as i32;
    let t = match a.tile {
        TileSize::Whole => k.max(1),
        TileSize::Edge(t) => t,
    };
    let mut out = vec![0.0f32; m * n];
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + t).min(m);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + t).min(n);
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + t).min(k);
                let ea = a.exponent_at(i0, k0);
                let eb = b.exponent_at(k0, j0);
                let scale = exp2i(ea - (ma - 1)) * exp2i(eb - (mb - 1));
                for i in i0..i1 {
                    let arow = &a.mantissas[i * k + k0..i * k + k1];
                    for j in j0..j1 {
                        let mut acc: i64 = 0;
                        for (dk, &qa) in arow.iter().enumerate() {
                            let qb = b.mantissas[(k0 + dk) * n + j];
                            acc += qa as i64 * qb as i64;
                        }
                        out[i * n + j] += acc as f32 * scale;
                    }
                }
                k0 = k1;
            }
            j0 = j1;
        }
        i0 = i1;
    }
    Ok(out)
}

/// Reference FP32 matmul (the baseline the harnesses compare against).
pub fn fp32_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..kk * n + n];
            let orow = &mut out[i * n..i * n + n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// Convenience: quantize f32 operands and multiply in BFP.
pub fn hbfp_matmul_f32(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    mantissa_bits: u32,
    tile: TileSize,
) -> Result<Vec<f32>> {
    use super::quant::Rounding;
    let qa = BfpTensor::from_f32(a, m, k, mantissa_bits, tile, &mut Rounding::NearestEven)?;
    let qb = BfpTensor::from_f32(b, k, n, mantissa_bits, tile, &mut Rounding::NearestEven)?;
    bfp_matmul(&qa, &qb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::SplitMix64;

    fn rand_mat(rng: &mut SplitMix64, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal() * scale).collect()
    }

    #[test]
    fn integer_mac_equals_dequantized_fp_product() {
        // The integer-MAC path must equal multiplying the dequantized
        // tensors in f64 then rounding — i.e. the mantissa math is exact.
        check("mac exactness", 60, |g: &mut Gen| {
            let (m, k, n) = (g.int(1, 20), g.int(1, 24), g.int(1, 20));
            let a = g.vec_f32(m * k, 2);
            let b = g.vec_f32(k * n, 2);
            let tile = *g.pick(&[TileSize::Whole, TileSize::Edge(8)]);
            let mb = *g.pick(&[4u32, 8]);
            use super::super::quant::Rounding;
            let qa = BfpTensor::from_f32(&a, m, k, mb, tile, &mut Rounding::NearestEven).unwrap();
            let qb = BfpTensor::from_f32(&b, k, n, mb, tile, &mut Rounding::NearestEven).unwrap();
            let got = bfp_matmul(&qa, &qb).unwrap();
            let da = qa.to_f32();
            let db = qb.to_f32();
            // f64 product of dequantized values (exact for these widths)
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f64;
                    for kk in 0..k {
                        acc += da[i * k + kk] as f64 * db[kk * n + j] as f64;
                    }
                    let gotv = got[i * n + j] as f64;
                    let tol = acc.abs().max(1.0) * 1e-5;
                    prop_assert!((gotv - acc).abs() <= tol, "({i},{j}): {gotv} vs {acc}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn error_decays_with_mantissa_width() {
        let mut rng = SplitMix64::new(7);
        let (m, k, n) = (32, 48, 32);
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        let exact = fp32_matmul(&a, &b, m, k, n);
        let amax = exact.iter().fold(0.0f32, |s, &x| s.max(x.abs()));
        let mut last = f32::INFINITY;
        for &bits in &[4u32, 8, 12, 16] {
            let got = hbfp_matmul_f32(&a, &b, m, k, n, bits, TileSize::Edge(16)).unwrap();
            let err = got
                .iter()
                .zip(&exact)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max)
                / amax;
            assert!(err < last, "error should decay: {err} !< {last} at m={bits}");
            last = err;
        }
        assert!(last < 1e-3, "16-bit error too large: {last}");
    }

    #[test]
    fn tiling_beats_whole_tensor_on_mixed_scales() {
        let mut rng = SplitMix64::new(3);
        let (m, k, n) = (32, 32, 32);
        let mut a = rand_mat(&mut rng, m * k, 1.0);
        for r in 0..16 {
            for c in 0..k {
                a[r * k + c] *= 1e-3; // two exponent regimes
            }
        }
        let b = rand_mat(&mut rng, k * n, 1.0);
        let exact = fp32_matmul(&a, &b, m, k, n);
        let err = |got: &[f32]| {
            got.iter().zip(&exact).map(|(x, y)| (x - y).abs()).sum::<f32>() / exact.len() as f32
        };
        let tiled = hbfp_matmul_f32(&a, &b, m, k, n, 8, TileSize::Edge(16)).unwrap();
        let whole = hbfp_matmul_f32(&a, &b, m, k, n, 8, TileSize::Whole).unwrap();
        assert!(err(&tiled) < err(&whole), "{} !< {}", err(&tiled), err(&whole));
    }

    #[test]
    fn mismatched_shapes_rejected() {
        use super::super::quant::Rounding;
        let a = BfpTensor::from_f32(&[1.0; 6], 2, 3, 8, TileSize::Whole, &mut Rounding::NearestEven)
            .unwrap();
        let b = BfpTensor::from_f32(&[1.0; 8], 2, 4, 8, TileSize::Whole, &mut Rounding::NearestEven)
            .unwrap();
        assert!(bfp_matmul(&a, &b).is_err());
    }

    #[test]
    fn mismatched_tiles_rejected() {
        use super::super::quant::Rounding;
        let a = BfpTensor::from_f32(&[1.0; 4], 2, 2, 8, TileSize::Whole, &mut Rounding::NearestEven)
            .unwrap();
        let b =
            BfpTensor::from_f32(&[1.0; 4], 2, 2, 8, TileSize::Edge(2), &mut Rounding::NearestEven)
                .unwrap();
        assert!(bfp_matmul(&a, &b).is_err());
    }

    #[test]
    fn blocked_equals_naive_bitwise() {
        // Both kernels sum identical i64 partials in identical k order, so
        // results must be bit-for-bit equal.
        check("blocked == naive", 60, |g: &mut Gen| {
            let (m, k, n) = (g.int(1, 40), g.int(1, 40), g.int(1, 40));
            let a = g.vec_f32(m * k, 3);
            let b = g.vec_f32(k * n, 3);
            let tile = *g.pick(&[TileSize::Whole, TileSize::Edge(8), TileSize::Edge(24)]);
            use super::super::quant::Rounding;
            let qa = BfpTensor::from_f32(&a, m, k, 8, tile, &mut Rounding::NearestEven).unwrap();
            let qb = BfpTensor::from_f32(&b, k, n, 8, tile, &mut Rounding::NearestEven).unwrap();
            let fast = bfp_matmul(&qa, &qb).unwrap();
            let slow = bfp_matmul_naive(&qa, &qb).unwrap();
            prop_assert!(fast == slow, "blocked and naive kernels disagree");
            Ok(())
        });
    }

    #[test]
    fn zero_matrices() {
        let z = hbfp_matmul_f32(&[0.0; 16], &[0.0; 16], 4, 4, 4, 8, TileSize::Edge(2)).unwrap();
        assert!(z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn identity_with_powers_of_two_exact() {
        // diag(2) quantizes exactly; product must equal 2*Q(b) exactly.
        use super::super::quant::Rounding;
        let n = 8;
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 2.0;
        }
        let mut rng = SplitMix64::new(11);
        let b = rand_mat(&mut rng, n * n, 1.0);
        let qb =
            BfpTensor::from_f32(&b, n, n, 8, TileSize::Edge(4), &mut Rounding::NearestEven).unwrap();
        let got = hbfp_matmul_f32(&a, &b, n, n, n, 8, TileSize::Edge(4)).unwrap();
        for (g, q) in got.iter().zip(qb.to_f32().iter()) {
            assert_eq!(*g, 2.0 * q);
        }
    }
}
