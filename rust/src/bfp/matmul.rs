//! BFP matrix multiplication with integer MACs + FP32 tile accumulation —
//! the software model of the paper's MatMul unit (Figure 2).
//!
//! Per (t x t) tile pair: the mantissa dot products run entirely in
//! integer arithmetic; each tile-partial is scaled by `2^(e_a + e_b)` once
//! and added to the FP32 output accumulator. That is exactly Equation (2)
//! plus the §4.2 tiling rule: "tile multiplications are performed in fixed
//! point, and their results are accumulated in floating point arithmetic".
//!
//! ## Entry points
//!
//! The public execution API lives in [`super::context`]: a
//! [`super::context::BfpContext`] resolves all execution policy once and
//! a [`super::context::MatmulPlan`] pre-resolves the per-shape decisions.
//! This module keeps:
//!
//! - the kernel bodies ([`packed_matmul_into`], [`rowmajor_matmul_into`],
//!   [`fused_matmul_into`] — crate-internal, driven by plans),
//! - the always-i64 j-innermost reference [`bfp_matmul_naive`] and the
//!   FP32 baseline [`fp32_matmul`],
//! - the accumulator overflow bound ([`acc_fits_i32`],
//!   [`max_tile_partial`]),
//! - the legacy free-function zoo as `#[deprecated]` one-line shims over
//!   a default context (no longer re-exported at `bfp::`; import from
//!   this module if a transition really needs them).
//!
//! ## Packed, parallel kernels
//!
//! The kernels are generic over the packed storage ([`MantissaElem`]:
//! `i8`/`i16`/`i32`), so hbfp8 streams 1-byte mantissas and the inner
//! loops autovectorize as widening integer MACs. The accumulator width is
//! chosen per plan by a proven bound (see [`acc_fits_i32`]): a k-tile
//! partial of `tile_k` products each at most `2^(ma-1) * 2^(mb-1)` in
//! magnitude sums to at most `tile_k * 2^(ma+mb-2)`; when that fits `i32`
//! the kernel accumulates in `i32` (the dense fixed-point logic the paper
//! maps onto), otherwise it falls back to `i64`. Both paths produce
//! identical partials, so results are bit-for-bit equal to the
//! [`bfp_matmul_naive`] reference.
//!
//! Output row-bands are distributed over the persistent worker pool
//! (`util::pool`); every output element accumulates its k-tiles in the
//! same order on exactly one lane, so results are bit-identical for any
//! thread count and either dispatch backend. Single-lane executions run
//! inline on the caller with no job-list allocation at all.
//!
//! ## Packed-panel default path, SIMD kernel family
//!
//! The default kernels stream the B operand from its [`PackedPanels`]
//! layout (reordered once per tensor, cached on the `BfpTensor`): per
//! k-tile, mantissas sit k-major in panels as wide as the plan's SIMD
//! family's register block ([`Isa::panel_nr`]: 8 scalar, 16 SSE4.1/NEON,
//! 32 AVX2), so the microkernel keeps one `[acc; nr]` block per output
//! row and reads B strictly contiguously. The pre-panel row-major walk is
//! retained behind `MatmulKernel::RowMajor` (bench rung +
//! differential-test partner, always scalar inner loops). All paths —
//! every ISA, layout, backend, and accumulator policy — are bit-for-bit
//! equal to [`bfp_matmul_naive`].

use anyhow::{anyhow, Result};

use super::context::{BfpContext, MatmulKernel};
use super::kernels::{self, Accum, Isa};
use super::panels::{PackedPanels, MAX_PANEL_NR};
use super::quant::{exp2i, Rounding, TileRounding};
use super::tensor::{BfpTensor, MantissaElem, Mantissas, TileSize};
use crate::util::pool::{self, ParBackend};

/// Below this many MACs (m*k*n) the matmuls stay single-threaded (scaled
/// by the active kernel family's throughput class — see
/// [`pool::par_threads_simd`]). Plan creation reads this; the hot loops
/// never re-derive it.
pub(crate) const PAR_MIN_MACS: usize = 1 << 17;

/// Largest possible |sum| of `tile_k` mantissa products at widths
/// `(ma, mb)`: every product is at most `2^(ma-1) * 2^(mb-1)` in
/// magnitude (attained only at the two most-negative mantissas).
pub fn max_tile_partial(tile_k: usize, ma: u32, mb: u32) -> u128 {
    (tile_k as u128) << (ma + mb).saturating_sub(2)
}

/// True iff a k-tile partial provably fits an `i32` accumulator, i.e.
/// `tile_k * 2^(ma-1) * 2^(mb-1) <= i32::MAX`. Every intermediate partial
/// sum is bounded by the final bound (magnitudes only accumulate), so no
/// intermediate overflow is possible either.
pub fn acc_fits_i32(tile_k: usize, ma: u32, mb: u32) -> bool {
    max_tile_partial(tile_k.max(1), ma, mb) <= i32::MAX as u128
}

/// Operand compatibility for C = A·B: matching contraction dims and tile
/// configurations. Shared by [`bfp_matmul_naive`] and the context API's
/// plan construction.
pub(crate) fn check_shapes(a: &BfpTensor, b: &BfpTensor) -> Result<()> {
    if a.cols != b.rows {
        return Err(anyhow!("contraction mismatch: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols));
    }
    if a.tile != b.tile {
        return Err(anyhow!("tile mismatch: {:?} vs {:?}", a.tile, b.tile));
    }
    Ok(())
}

/// Run one band-parallel section: `f(band, band_out)` over `out` split
/// into `band_elems`-sized row bands. The single-lane path iterates
/// inline with **no allocation**; multi-lane dispatch builds the job
/// list once and hands it to the chosen backend.
fn run_bands<F>(out: &mut [f32], band_elems: usize, threads: usize, backend: ParBackend, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if threads <= 1 {
        for (band, chunk) in out.chunks_mut(band_elems).enumerate() {
            f(band, chunk);
        }
        return;
    }
    let jobs: Vec<(usize, &mut [f32])> = out.chunks_mut(band_elems).enumerate().collect();
    pool::run_backend(backend, jobs, threads, f);
}

/// Packed-panel matmul body. Preconditions (the plan's job): shapes
/// validated, `out` zeroed with `len == a.rows * b.cols`, no zero dims,
/// `isa` executable on this CPU, and `use_i32` implied by the overflow
/// bound (debug-asserted downstream).
#[allow(clippy::too_many_arguments)]
pub(crate) fn packed_matmul_into(
    a: &BfpTensor,
    b: &BfpTensor,
    out: &mut [f32],
    t: usize,
    nr: usize,
    threads: usize,
    backend: ParBackend,
    isa: Isa,
    use_i32: bool,
) {
    let pp = b.packed_panels_nr(nr);
    match &a.mantissas {
        Mantissas::I8(av) => {
            packed_dispatch_b::<i8>(av, a, b, &pp, out, t, threads, backend, isa, use_i32)
        }
        Mantissas::I16(av) => {
            packed_dispatch_b::<i16>(av, a, b, &pp, out, t, threads, backend, isa, use_i32)
        }
        Mantissas::I32(av) => {
            packed_dispatch_b::<i32>(av, a, b, &pp, out, t, threads, backend, isa, use_i32)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn packed_dispatch_b<EA: MantissaElem>(
    av: &[EA],
    a: &BfpTensor,
    b: &BfpTensor,
    pp: &PackedPanels,
    out: &mut [f32],
    t: usize,
    threads: usize,
    backend: ParBackend,
    isa: Isa,
    use_i32: bool,
) {
    match &pp.data {
        Mantissas::I8(pv) => {
            packed_bands(av, pv, a, b, pp, out, t, threads, backend, isa, use_i32)
        }
        Mantissas::I16(pv) => {
            packed_bands(av, pv, a, b, pp, out, t, threads, backend, isa, use_i32)
        }
        Mantissas::I32(pv) => {
            packed_bands(av, pv, a, b, pp, out, t, threads, backend, isa, use_i32)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn packed_bands<EA: MantissaElem, EB: MantissaElem>(
    av: &[EA],
    pv: &[EB],
    a: &BfpTensor,
    b: &BfpTensor,
    pp: &PackedPanels,
    out: &mut [f32],
    t: usize,
    threads: usize,
    backend: ParBackend,
    isa: Isa,
    use_i32: bool,
) {
    let n = b.cols;
    run_bands(out, t * n, threads, backend, |band, band_out| {
        let i0 = band * t;
        let i1 = (i0 + t).min(a.rows);
        let a_exp = |r: usize, c: usize| a.exponent_at(r, c);
        band_matmul_packed(
            av,
            0,
            &a_exp,
            a.mantissa_bits,
            pv,
            pp,
            b,
            band_out,
            i0,
            i1,
            t,
            isa,
            use_i32,
        );
    });
}

/// Row-major matmul body (the pre-panel walk). Same preconditions as
/// [`packed_matmul_into`]; always scalar inner loops.
pub(crate) fn rowmajor_matmul_into(
    a: &BfpTensor,
    b: &BfpTensor,
    out: &mut [f32],
    t: usize,
    threads: usize,
    backend: ParBackend,
    use_i32: bool,
) {
    match &a.mantissas {
        Mantissas::I8(av) => rowmajor_dispatch_b::<i8>(av, a, b, out, t, threads, backend, use_i32),
        Mantissas::I16(av) => {
            rowmajor_dispatch_b::<i16>(av, a, b, out, t, threads, backend, use_i32)
        }
        Mantissas::I32(av) => {
            rowmajor_dispatch_b::<i32>(av, a, b, out, t, threads, backend, use_i32)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn rowmajor_dispatch_b<EA: MantissaElem>(
    av: &[EA],
    a: &BfpTensor,
    b: &BfpTensor,
    out: &mut [f32],
    t: usize,
    threads: usize,
    backend: ParBackend,
    use_i32: bool,
) {
    match &b.mantissas {
        Mantissas::I8(bv) => rowmajor_bands(av, bv, a, b, out, t, threads, backend, use_i32),
        Mantissas::I16(bv) => rowmajor_bands(av, bv, a, b, out, t, threads, backend, use_i32),
        Mantissas::I32(bv) => rowmajor_bands(av, bv, a, b, out, t, threads, backend, use_i32),
    }
}

#[allow(clippy::too_many_arguments)]
fn rowmajor_bands<EA: MantissaElem, EB: MantissaElem>(
    av: &[EA],
    bv: &[EB],
    a: &BfpTensor,
    b: &BfpTensor,
    out: &mut [f32],
    t: usize,
    threads: usize,
    backend: ParBackend,
    use_i32: bool,
) {
    let n = b.cols;
    run_bands(out, t * n, threads, backend, |band, band_out| {
        let i0 = band * t;
        let i1 = (i0 + t).min(a.rows);
        let a_exp = |r: usize, c: usize| a.exponent_at(r, c);
        band_matmul(av, 0, &a_exp, a.mantissa_bits, bv, b, band_out, i0, i1, t, use_i32);
    });
}

/// Compute output rows `i0..i1` into `band_out` (local row 0 = global row
/// `i0`, row stride `n`). `av` holds A's mantissas starting at global row
/// `a_row0` (0 for a full tensor, `i0` for a fused per-band scratch);
/// `a_exp(r, c)` is A's shared exponent at a global coordinate. The
/// accumulator class is the caller's pre-resolved decision (`use_i32`
/// must satisfy the overflow bound — debug-asserted).
#[allow(clippy::too_many_arguments)]
fn band_matmul<EA: MantissaElem, EB: MantissaElem, FA: Fn(usize, usize) -> i32>(
    av: &[EA],
    a_row0: usize,
    a_exp: &FA,
    ma_bits: u32,
    bv: &[EB],
    b: &BfpTensor,
    band_out: &mut [f32],
    i0: usize,
    i1: usize,
    t: usize,
    use_i32: bool,
) {
    let k = b.rows;
    let n = b.cols;
    let ma = ma_bits as i32;
    let mb = b.mantissa_bits as i32;
    let ti = i1 - i0;
    if ti == 0 {
        return;
    }
    let tj_cap = t.min(n);
    let tile_k = t.min(k).max(1);
    debug_assert!(
        !use_i32 || acc_fits_i32(tile_k, ma_bits, b.mantissa_bits),
        "i32 accumulation requested outside the proven bound"
    );
    let mut acc32 = vec![0i32; if use_i32 { ti * tj_cap } else { 0 }];
    let mut acc64 = vec![0i64; if use_i32 { 0 } else { ti * tj_cap }];
    let arow0 = i0 - a_row0;
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + t).min(n);
        let tj = j1 - j0;
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + t).min(k);
            // Shared exponents are constant across the tile pair; the
            // scale 2^(ea-(ma-1)) * 2^(eb-(mb-1)) is applied once per
            // tile-partial (the FP realignment the paper amortizes over
            // 2N fixed-point ops).
            let ea = a_exp(i0, k0);
            let eb = b.exponent_at(k0, j0);
            let scale = exp2i(ea - (ma - 1)) * exp2i(eb - (mb - 1));
            if use_i32 {
                let acc = &mut acc32[..ti * tj];
                acc.fill(0);
                tile_mac(av, bv, acc, arow0, ti, j0, j1, k0, k1, k, n);
                debug_assert_tile_bound(acc, tile_k, ma_bits, b.mantissa_bits);
                flush_tile(acc, band_out, scale, n, j0, tj, ti);
            } else {
                let acc = &mut acc64[..ti * tj];
                acc.fill(0);
                tile_mac(av, bv, acc, arow0, ti, j0, j1, k0, k1, k, n);
                flush_tile(acc, band_out, scale, n, j0, tj, ti);
            }
            k0 = k1;
        }
        j0 = j1;
    }
}

/// Integer MAC over one tile pair: walks B row-major (contiguous loads)
/// accumulating a row of partials — §Perf L3: ~4x over the naive
/// j-innermost walk, and the loop the narrow storage classes vectorize.
#[allow(clippy::too_many_arguments)]
#[inline]
fn tile_mac<EA: MantissaElem, EB: MantissaElem, A: Accum>(
    av: &[EA],
    bv: &[EB],
    acc: &mut [A],
    arow0: usize,
    ti: usize,
    j0: usize,
    j1: usize,
    k0: usize,
    k1: usize,
    k: usize,
    n: usize,
) {
    let tj = j1 - j0;
    for li in 0..ti {
        let ar = arow0 + li;
        let arow = &av[ar * k + k0..ar * k + k1];
        let accrow = &mut acc[li * tj..(li + 1) * tj];
        for (dk, &qa) in arow.iter().enumerate() {
            if qa.to_i32() == 0 {
                continue;
            }
            let brow = &bv[(k0 + dk) * n + j0..(k0 + dk) * n + j1];
            for (aj, &qb) in accrow.iter_mut().zip(brow) {
                aj.mac(qa, qb);
            }
        }
    }
}

/// Scale a tile's integer partials into the f32 band accumulator.
#[inline]
fn flush_tile<A: Accum>(
    acc: &[A],
    band_out: &mut [f32],
    scale: f32,
    n: usize,
    j0: usize,
    tj: usize,
    ti: usize,
) {
    for li in 0..ti {
        let accrow = &acc[li * tj..(li + 1) * tj];
        let orow = &mut band_out[li * n + j0..li * n + j0 + tj];
        for (o, &v) in orow.iter_mut().zip(accrow) {
            *o += v.to_f32() * scale;
        }
    }
}

/// Debug-build check of the overflow proof's premise: no tile partial may
/// exceed `tile_k * 2^(ma+mb-2)` (possible only if a tensor carries
/// mantissas wider than its declared width).
fn debug_assert_tile_bound<A: Accum>(acc: &[A], tile_k: usize, ma: u32, mb: u32) {
    if cfg!(debug_assertions) {
        let bound = max_tile_partial(tile_k, ma, mb);
        for v in acc {
            debug_assert!(
                (v.to_i64().unsigned_abs() as u128) <= bound,
                "tile partial {} exceeds proven bound {bound} (tile_k={tile_k}, ma={ma}, mb={mb})",
                v.to_i64()
            );
        }
    }
}

/// Compute output rows `i0..i1` against the packed B panels. Same
/// contract as [`band_matmul`] (same k order, same per-tile flush order,
/// hence bit-identical results), but B streams contiguously panel by
/// panel and each output row keeps a `[acc; nr]` register block, with
/// the inner MAC loop dispatched to the `isa` kernel family.
#[allow(clippy::too_many_arguments)]
fn band_matmul_packed<EA: MantissaElem, EB: MantissaElem, FA: Fn(usize, usize) -> i32>(
    av: &[EA],
    a_row0: usize,
    a_exp: &FA,
    ma_bits: u32,
    pv: &[EB],
    pp: &PackedPanels,
    b: &BfpTensor,
    band_out: &mut [f32],
    i0: usize,
    i1: usize,
    t: usize,
    isa: Isa,
    use_i32: bool,
) {
    debug_assert_eq!(pp.t, t, "panel layout built for a different tile edge");
    debug_assert_eq!(pp.data.len(), pv.len());
    let nr = pp.nr;
    debug_assert!(nr <= MAX_PANEL_NR);
    let k = b.rows;
    let n = b.cols;
    let ma = ma_bits as i32;
    let mb = b.mantissa_bits as i32;
    let ti = i1 - i0;
    if ti == 0 {
        return;
    }
    let tile_k = t.min(k).max(1);
    debug_assert!(
        !use_i32 || acc_fits_i32(tile_k, ma_bits, b.mantissa_bits),
        "i32 accumulation requested outside the proven bound"
    );
    let arow0 = i0 - a_row0;
    let panel_elems = pp.tk * nr;
    for jt in 0..pp.tiles_j {
        let j0 = jt * t;
        let j1 = (j0 + t).min(n);
        for kt in 0..pp.tiles_k {
            let k0 = kt * t;
            let k1 = (k0 + t).min(k);
            let ea = a_exp(i0, k0);
            let eb = b.exponent_at(k0, j0);
            let scale = exp2i(ea - (ma - 1)) * exp2i(eb - (mb - 1));
            let tile_base = pp.tile_base(jt, kt);
            let mut p = 0;
            let mut c0 = j0;
            while c0 < j1 {
                let c1 = (c0 + nr).min(j1);
                let panel = &pv[tile_base + p * panel_elems..tile_base + (p + 1) * panel_elems];
                if use_i32 {
                    panel_mac_rows::<EA, EB, i32>(
                        av, panel, arow0, ti, k, k0, k1, band_out, n, c0, c1, scale, tile_k,
                        ma_bits, b.mantissa_bits, nr, isa,
                    );
                } else {
                    panel_mac_rows::<EA, EB, i64>(
                        av, panel, arow0, ti, k, k0, k1, band_out, n, c0, c1, scale, tile_k,
                        ma_bits, b.mantissa_bits, nr, isa,
                    );
                }
                c0 = c1;
                p += 1;
            }
        }
    }
}

/// Register-blocked microkernel: for each of `ti` output rows, stream one
/// packed panel (k-major, `nr` wide) through an `nr`-lane accumulator
/// block via the `isa` family's MAC kernel ([`kernels::mac_panel`]),
/// then scale the block into the f32 band accumulator. Padding columns
/// hold zero mantissas (every product 0), so only the `c0..c1` lanes are
/// flushed and the integer partials equal the row-major walk's exactly —
/// the flush stays scalar and in element order on every ISA.
#[allow(clippy::too_many_arguments)]
#[inline]
fn panel_mac_rows<EA: MantissaElem, EB: MantissaElem, A: Accum>(
    av: &[EA],
    panel: &[EB],
    arow0: usize,
    ti: usize,
    k: usize,
    k0: usize,
    k1: usize,
    band_out: &mut [f32],
    n: usize,
    c0: usize,
    c1: usize,
    scale: f32,
    tile_k: usize,
    ma_bits: u32,
    mb_bits: u32,
    nr: usize,
    isa: Isa,
) {
    let tj = c1 - c0;
    // one fixed-capacity block, re-zeroed per row over only the `nr`
    // lanes actually in use (the scalar family pays for 8, not 32)
    let mut acc = [A::default(); MAX_PANEL_NR];
    for li in 0..ti {
        let ar = arow0 + li;
        let arow = &av[ar * k + k0..ar * k + k1];
        let lanes = &mut acc[..nr];
        lanes.fill(A::default());
        kernels::mac_panel_preclamped(isa, arow, panel, nr, lanes);
        debug_assert_tile_bound(&acc[..tj], tile_k, ma_bits, mb_bits);
        let orow = &mut band_out[li * n + c0..li * n + c1];
        for (o, aj) in orow.iter_mut().zip(&acc[..tj]) {
            *o += aj.to_f32() * scale;
        }
    }
}

/// The pre-optimization j-innermost kernel, kept for the §Perf
/// before/after bench and as the differential-testing reference (every
/// context/plan configuration must agree with it bit-for-bit — all paths
/// sum the same integer partials, always in `i64` here).
pub fn bfp_matmul_naive(a: &BfpTensor, b: &BfpTensor) -> Result<Vec<f32>> {
    check_shapes(a, b)?;
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = vec![0.0f32; m * n];
    if m == 0 || k == 0 || n == 0 {
        return Ok(out);
    }
    let t = super::panels::matmul_tile_edge(a.tile, k);
    match &a.mantissas {
        Mantissas::I8(av) => naive_dispatch_b::<i8>(av, a, b, &mut out, t),
        Mantissas::I16(av) => naive_dispatch_b::<i16>(av, a, b, &mut out, t),
        Mantissas::I32(av) => naive_dispatch_b::<i32>(av, a, b, &mut out, t),
    }
    Ok(out)
}

fn naive_dispatch_b<EA: MantissaElem>(
    av: &[EA],
    a: &BfpTensor,
    b: &BfpTensor,
    out: &mut [f32],
    t: usize,
) {
    match &b.mantissas {
        Mantissas::I8(bv) => naive_kernel(av, bv, a, b, out, t),
        Mantissas::I16(bv) => naive_kernel(av, bv, a, b, out, t),
        Mantissas::I32(bv) => naive_kernel(av, bv, a, b, out, t),
    }
}

fn naive_kernel<EA: MantissaElem, EB: MantissaElem>(
    av: &[EA],
    bv: &[EB],
    a: &BfpTensor,
    b: &BfpTensor,
    out: &mut [f32],
    t: usize,
) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let ma = a.mantissa_bits as i32;
    let mb = b.mantissa_bits as i32;
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + t).min(m);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + t).min(n);
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + t).min(k);
                let ea = a.exponent_at(i0, k0);
                let eb = b.exponent_at(k0, j0);
                let scale = exp2i(ea - (ma - 1)) * exp2i(eb - (mb - 1));
                for i in i0..i1 {
                    let arow = &av[i * k + k0..i * k + k1];
                    for j in j0..j1 {
                        let mut acc: i64 = 0;
                        for (dk, &qa) in arow.iter().enumerate() {
                            let qb = bv[(k0 + dk) * n + j];
                            acc += qa.to_i32() as i64 * qb.to_i32() as i64;
                        }
                        out[i * n + j] += acc as f32 * scale;
                    }
                }
                k0 = k1;
            }
            j0 = j1;
        }
        i0 = i1;
    }
}

/// Reference FP32 matmul (the baseline the harnesses compare against).
pub fn fp32_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..kk * n + n];
            let orow = &mut out[i * n..i * n + n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// Fused FP→BFP convert + matmul body: quantizes row-band tiles of `a`
/// on the fly (per-band scratch, never a full materialized tensor) and
/// MACs them against the already-quantized, resident `b` — the paper's
/// datapath, where activations stream through the converter into the
/// array while weights sit in BFP. Preconditions (the plan's job):
/// `a.len() == m * b.rows`, `out` zeroed at `m * b.cols`, `m`, `k`, `n`
/// all nonzero, rounding mode already captured. Bit-for-bit identical to
/// materializing A and running [`packed_matmul_into`], including
/// stochastic rounding (same per-tile substreams). `th`/`tw` are the
/// converter tile dims (`tile.edge_or(m, k)`), `t` the matmul tile edge.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fused_matmul_into(
    a: &[f32],
    b: &BfpTensor,
    out: &mut [f32],
    m: usize,
    a_bits: u32,
    mode: TileRounding,
    t: usize,
    nr: usize,
    th: usize,
    tw: usize,
    threads: usize,
    backend: ParBackend,
    isa: Isa,
    use_i32: bool,
) {
    let pp = b.packed_panels_nr(nr);
    match Mantissas::for_width(a_bits, 0) {
        Mantissas::I8(_) => fused_bands::<i8>(
            a, b, &pp, out, m, a_bits, mode, t, th, tw, threads, backend, isa, use_i32,
        ),
        Mantissas::I16(_) => fused_bands::<i16>(
            a, b, &pp, out, m, a_bits, mode, t, th, tw, threads, backend, isa, use_i32,
        ),
        Mantissas::I32(_) => fused_bands::<i32>(
            a, b, &pp, out, m, a_bits, mode, t, th, tw, threads, backend, isa, use_i32,
        ),
    }
}

#[allow(clippy::too_many_arguments)]
fn fused_bands<EA: MantissaElem>(
    a: &[f32],
    b: &BfpTensor,
    pp: &PackedPanels,
    out: &mut [f32],
    m: usize,
    a_bits: u32,
    mode: TileRounding,
    t: usize,
    th: usize,
    tw: usize,
    threads: usize,
    backend: ParBackend,
    isa: Isa,
    use_i32: bool,
) {
    match &pp.data {
        Mantissas::I8(pv) => fused_bands_b::<EA, i8>(
            a, pv, pp, b, out, m, a_bits, mode, t, th, tw, threads, backend, isa, use_i32,
        ),
        Mantissas::I16(pv) => fused_bands_b::<EA, i16>(
            a, pv, pp, b, out, m, a_bits, mode, t, th, tw, threads, backend, isa, use_i32,
        ),
        Mantissas::I32(pv) => fused_bands_b::<EA, i32>(
            a, pv, pp, b, out, m, a_bits, mode, t, th, tw, threads, backend, isa, use_i32,
        ),
    }
}

#[allow(clippy::too_many_arguments)]
fn fused_bands_b<EA: MantissaElem, EB: MantissaElem>(
    a: &[f32],
    pv: &[EB],
    pp: &PackedPanels,
    b: &BfpTensor,
    out: &mut [f32],
    m: usize,
    a_bits: u32,
    mode: TileRounding,
    t: usize,
    th: usize,
    tw: usize,
    threads: usize,
    backend: ParBackend,
    isa: Isa,
    use_i32: bool,
) {
    let k = b.rows;
    let n = b.cols;
    let tiles_c = k.div_ceil(tw).max(1);
    run_bands(out, th * n, threads, backend, |band, band_out| {
        let i0 = band * th;
        let i1 = (i0 + th).min(m);
        let band_rows = i1 - i0;
        // Per-band converter: quantize this band's A tiles into packed
        // scratch (the only A-mantissa storage that ever exists). RNE
        // rows vectorize; stochastic rows stay scalar in element order
        // so the per-tile RNG draws are ISA-independent.
        let mut scratch: Vec<EA> = vec![EA::from_i32(0); band_rows * k];
        let mut band_exps = vec![0i32; tiles_c];
        let conv_isa = kernels::active();
        for tc in 0..tiles_c {
            let c0 = tc * tw;
            let c1 = (c0 + tw).min(k);
            let e = super::quant::block_exponent_strided(a, k, i0, i1, c0, c1);
            band_exps[tc] = e;
            match mode {
                TileRounding::NearestEven => {
                    for r in i0..i1 {
                        let src = &a[r * k + c0..r * k + c1];
                        let dst = &mut scratch[(r - i0) * k + c0..(r - i0) * k + c1];
                        kernels::quantize_row_rne_preclamped(conv_isa, src, dst, e, a_bits);
                    }
                }
                TileRounding::StochasticBase(_) => {
                    let mut owned = mode.for_tile((band * tiles_c + tc) as u64);
                    let mut rounding = owned.as_rounding();
                    for r in i0..i1 {
                        let src = &a[r * k + c0..r * k + c1];
                        let dst = &mut scratch[(r - i0) * k + c0..(r - i0) * k + c1];
                        for (d, &x) in dst.iter_mut().zip(src) {
                            *d = EA::from_i32(super::quant::quantize_value(
                                x,
                                e,
                                a_bits,
                                &mut rounding,
                            ));
                        }
                    }
                }
            }
        }
        band_matmul_packed(
            &scratch,
            i0,
            &|_r, c| band_exps[c / tw],
            a_bits,
            pv,
            pp,
            b,
            band_out,
            i0,
            i1,
            t,
            isa,
            use_i32,
        );
    });
}

// ---------------------------------------------------------------------------
// Deprecated legacy surface: one-line shims over a default context.
//
// These are kept only so downstream code migrates on its own schedule;
// nothing in this repository calls them outside the shim-equivalence
// test. They are no longer re-exported at `bfp::` — import from this
// module explicitly if a transition really needs them.
// ---------------------------------------------------------------------------

/// C = A · B over BFP tensors with the environment's default policy.
#[deprecated(note = "use BfpContext::from_env().matmul(a, b), or plan_matmul for reuse")]
pub fn bfp_matmul(a: &BfpTensor, b: &BfpTensor) -> Result<Vec<f32>> {
    BfpContext::from_env().matmul(a, b)
}

/// [`bfp_matmul`] with an explicit thread cap.
#[deprecated(note = "use BfpContext::from_env().with_threads(n).matmul(a, b)")]
pub fn bfp_matmul_with_threads(
    a: &BfpTensor,
    b: &BfpTensor,
    max_threads: usize,
) -> Result<Vec<f32>> {
    BfpContext::from_env().with_threads(max_threads).matmul(a, b)
}

/// [`bfp_matmul`] with an explicit dispatch backend.
#[deprecated(note = "use BfpContext::from_env().with_backend(backend).matmul(a, b)")]
pub fn bfp_matmul_with_backend(
    a: &BfpTensor,
    b: &BfpTensor,
    max_threads: usize,
    backend: ParBackend,
) -> Result<Vec<f32>> {
    BfpContext::from_env().with_threads(max_threads).with_backend(backend).matmul(a, b)
}

/// [`bfp_matmul`] with an explicitly forced SIMD kernel family.
#[deprecated(note = "use BfpContext::from_env().with_isa(isa).matmul(a, b)")]
pub fn bfp_matmul_with_simd(
    a: &BfpTensor,
    b: &BfpTensor,
    max_threads: usize,
    isa: Isa,
) -> Result<Vec<f32>> {
    BfpContext::from_env().with_threads(max_threads).with_isa(isa).matmul(a, b)
}

/// The pre-panel row-major B walk.
#[deprecated(note = "use BfpContext::from_env().with_kernel(MatmulKernel::RowMajor).matmul(a, b)")]
pub fn bfp_matmul_rowmajor(a: &BfpTensor, b: &BfpTensor) -> Result<Vec<f32>> {
    BfpContext::from_env().with_kernel(MatmulKernel::RowMajor).matmul(a, b)
}

/// [`bfp_matmul_rowmajor`] with an explicit thread cap.
#[deprecated(
    note = "use BfpContext::from_env().with_kernel(MatmulKernel::RowMajor).with_threads(n)"
)]
pub fn bfp_matmul_rowmajor_with_threads(
    a: &BfpTensor,
    b: &BfpTensor,
    max_threads: usize,
) -> Result<Vec<f32>> {
    BfpContext::from_env()
        .with_kernel(MatmulKernel::RowMajor)
        .with_threads(max_threads)
        .matmul(a, b)
}

/// Fused FP→BFP convert + matmul with the environment's default policy.
#[deprecated(note = "use BfpContext::quantize_matmul, or MatmulPlan::quantize_execute for reuse")]
pub fn quantize_matmul(
    a: &[f32],
    a_rows: usize,
    a_bits: u32,
    rounding: &mut Rounding,
    b: &BfpTensor,
) -> Result<Vec<f32>> {
    BfpContext::from_env().quantize_matmul(a, a_rows, a_bits, rounding, b)
}

/// [`quantize_matmul`] with an explicit thread cap.
#[deprecated(note = "use BfpContext::from_env().with_threads(n).quantize_matmul(...)")]
pub fn quantize_matmul_with_threads(
    a: &[f32],
    a_rows: usize,
    a_bits: u32,
    rounding: &mut Rounding,
    b: &BfpTensor,
    max_threads: usize,
) -> Result<Vec<f32>> {
    BfpContext::from_env().with_threads(max_threads).quantize_matmul(a, a_rows, a_bits, rounding, b)
}

/// Convenience: quantize f32 operands and multiply in BFP.
#[deprecated(note = "use BfpContext::from_env().with_tile(tile).matmul_f32(...)")]
pub fn hbfp_matmul_f32(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    mantissa_bits: u32,
    tile: TileSize,
) -> Result<Vec<f32>> {
    BfpContext::from_env().with_tile(tile).matmul_f32(a, b, m, k, n, mantissa_bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::{SplitMix64, Xorshift32};

    fn ctx() -> BfpContext {
        BfpContext::from_env()
    }

    fn rand_mat(rng: &mut SplitMix64, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal() * scale).collect()
    }

    fn from_f32(data: &[f32], rows: usize, cols: usize, m: u32, tile: TileSize) -> BfpTensor {
        BfpTensor::from_f32(data, rows, cols, m, tile, &mut Rounding::NearestEven).unwrap()
    }

    #[test]
    fn integer_mac_equals_dequantized_fp_product() {
        // The integer-MAC path must equal multiplying the dequantized
        // tensors in f64 then rounding — i.e. the mantissa math is exact.
        check("mac exactness", 60, |g: &mut Gen| {
            let (m, k, n) = (g.int(1, 20), g.int(1, 24), g.int(1, 20));
            let a = g.vec_f32(m * k, 2);
            let b = g.vec_f32(k * n, 2);
            let tile = *g.pick(&[TileSize::Whole, TileSize::Edge(8)]);
            let mb = *g.pick(&[4u32, 8]);
            let qa = from_f32(&a, m, k, mb, tile);
            let qb = from_f32(&b, k, n, mb, tile);
            let got = ctx().matmul(&qa, &qb).unwrap();
            let da = qa.to_f32();
            let db = qb.to_f32();
            // f64 product of dequantized values (exact for these widths)
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f64;
                    for kk in 0..k {
                        acc += da[i * k + kk] as f64 * db[kk * n + j] as f64;
                    }
                    let gotv = got[i * n + j] as f64;
                    let tol = acc.abs().max(1.0) * 1e-5;
                    prop_assert!((gotv - acc).abs() <= tol, "({i},{j}): {gotv} vs {acc}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn error_decays_with_mantissa_width() {
        let mut rng = SplitMix64::new(7);
        let (m, k, n) = (32, 48, 32);
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        let exact = fp32_matmul(&a, &b, m, k, n);
        let amax = exact.iter().fold(0.0f32, |s, &x| s.max(x.abs()));
        let c = ctx().with_tile(TileSize::Edge(16));
        let mut last = f32::INFINITY;
        for &bits in &[4u32, 8, 12, 16] {
            let got = c.matmul_f32(&a, &b, m, k, n, bits).unwrap();
            let err = got
                .iter()
                .zip(&exact)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max)
                / amax;
            assert!(err < last, "error should decay: {err} !< {last} at m={bits}");
            last = err;
        }
        assert!(last < 1e-3, "16-bit error too large: {last}");
    }

    #[test]
    fn tiling_beats_whole_tensor_on_mixed_scales() {
        let mut rng = SplitMix64::new(3);
        let (m, k, n) = (32, 32, 32);
        let mut a = rand_mat(&mut rng, m * k, 1.0);
        for r in 0..16 {
            for c in 0..k {
                a[r * k + c] *= 1e-3; // two exponent regimes
            }
        }
        let b = rand_mat(&mut rng, k * n, 1.0);
        let exact = fp32_matmul(&a, &b, m, k, n);
        let err = |got: &[f32]| {
            got.iter().zip(&exact).map(|(x, y)| (x - y).abs()).sum::<f32>() / exact.len() as f32
        };
        let tiled =
            ctx().with_tile(TileSize::Edge(16)).matmul_f32(&a, &b, m, k, n, 8).unwrap();
        let whole = ctx().with_tile(TileSize::Whole).matmul_f32(&a, &b, m, k, n, 8).unwrap();
        assert!(err(&tiled) < err(&whole), "{} !< {}", err(&tiled), err(&whole));
    }

    #[test]
    fn mismatched_shapes_rejected() {
        let a = from_f32(&[1.0; 6], 2, 3, 8, TileSize::Whole);
        let b = from_f32(&[1.0; 8], 2, 4, 8, TileSize::Whole);
        assert!(ctx().matmul(&a, &b).is_err());
    }

    #[test]
    fn mismatched_tiles_rejected() {
        let a = from_f32(&[1.0; 4], 2, 2, 8, TileSize::Whole);
        let b = from_f32(&[1.0; 4], 2, 2, 8, TileSize::Edge(2));
        assert!(ctx().matmul(&a, &b).is_err());
    }

    #[test]
    fn blocked_equals_naive_bitwise() {
        // The context path sums identical integer partials in identical k
        // order, so results must be bit-for-bit equal — across storage
        // classes (i8/i16/i32) and mixed-width operand pairs.
        check("blocked == naive", 60, |g: &mut Gen| {
            let (m, k, n) = (g.int(1, 40), g.int(1, 40), g.int(1, 40));
            let a = g.vec_f32(m * k, 3);
            let b = g.vec_f32(k * n, 3);
            let tile = *g.pick(&[TileSize::Whole, TileSize::Edge(8), TileSize::Edge(24)]);
            let ma = *g.pick(&[4u32, 8, 12, 16, 20, 24]);
            let mb = *g.pick(&[4u32, 8, 12, 16, 20, 24]);
            let qa = from_f32(&a, m, k, ma, tile);
            let qb = from_f32(&b, k, n, mb, tile);
            let fast = ctx().matmul(&qa, &qb).unwrap();
            let slow = bfp_matmul_naive(&qa, &qb).unwrap();
            prop_assert!(fast == slow, "blocked and naive kernels disagree (ma={ma}, mb={mb})");
            Ok(())
        });
    }

    #[test]
    fn thread_count_invariant() {
        let mut rng = SplitMix64::new(21);
        let (m, k, n) = (96, 80, 72); // above the parallel floor
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        let qa = from_f32(&a, m, k, 8, TileSize::Edge(16));
        let qb = from_f32(&b, k, n, 8, TileSize::Edge(16));
        let one = ctx().with_threads(1).matmul(&qa, &qb).unwrap();
        let many = ctx().with_threads(8).matmul(&qa, &qb).unwrap();
        assert!(one == many, "thread count must not change results");
    }

    #[test]
    fn fused_equals_materialized_bitwise() {
        check("fused == materialized", 40, |g: &mut Gen| {
            let (m, k, n) = (g.int(1, 30), g.int(1, 30), g.int(1, 30));
            let a = g.vec_f32(m * k, 3);
            let b = g.vec_f32(k * n, 3);
            let tile = *g.pick(&[TileSize::Whole, TileSize::Edge(8), TileSize::Edge(24)]);
            let bits = *g.pick(&[4u32, 8, 12]);
            let qb = from_f32(&b, k, n, bits, tile);

            // nearest-even
            let qa = from_f32(&a, m, k, bits, tile);
            let want = ctx().matmul(&qa, &qb).unwrap();
            let got = ctx().quantize_matmul(&a, m, bits, &mut Rounding::NearestEven, &qb).unwrap();
            prop_assert!(got == want, "fused != materialized (rne, bits={bits})");

            // stochastic: same seed => same per-tile substreams
            let seed = g.rng.next_u32();
            let mut r1 = Xorshift32::new(seed);
            let mut r2 = Xorshift32::new(seed);
            let qa_s =
                BfpTensor::from_f32(&a, m, k, bits, tile, &mut Rounding::Stochastic(&mut r1))
                    .unwrap();
            let want_s = ctx().matmul(&qa_s, &qb).unwrap();
            let got_s = ctx()
                .quantize_matmul(&a, m, bits, &mut Rounding::Stochastic(&mut r2), &qb)
                .unwrap();
            prop_assert!(got_s == want_s, "fused != materialized (stochastic, bits={bits})");
            Ok(())
        });
    }

    #[test]
    fn fused_rejects_bad_len() {
        let qb = from_f32(&[1.0; 4], 2, 2, 8, TileSize::Whole);
        assert!(ctx().quantize_matmul(&[1.0; 5], 2, 8, &mut Rounding::NearestEven, &qb).is_err());
        assert!(ctx().quantize_matmul(&[1.0; 4], 2, 1, &mut Rounding::NearestEven, &qb).is_err());
    }

    #[test]
    fn acc_bound_arithmetic() {
        // m=8 x m=8: 2^14 per product; i32 holds 2^17 - 1 of them.
        assert!(acc_fits_i32((1 << 17) - 1, 8, 8));
        assert!(!acc_fits_i32(1 << 17, 8, 8));
        // m=12 x m=12: 2^22 per product; 512 products hit 2^31 exactly — too big.
        assert!(acc_fits_i32(511, 12, 12));
        assert!(!acc_fits_i32(512, 12, 12));
        // m=16 x m=16: 2^30 per product; only one fits.
        assert!(acc_fits_i32(1, 16, 16));
        assert!(!acc_fits_i32(2, 16, 16));
        // widest supported: must fall back to i64 for any real tile
        assert!(!acc_fits_i32(24, 24, 24));
        assert_eq!(max_tile_partial(3, 8, 8), 3 << 14);
    }

    #[test]
    fn zero_matrices() {
        let z = ctx()
            .with_tile(TileSize::Edge(2))
            .matmul_f32(&[0.0; 16], &[0.0; 16], 4, 4, 4, 8)
            .unwrap();
        assert!(z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn identity_with_powers_of_two_exact() {
        // diag(2) quantizes exactly; product must equal 2*Q(b) exactly.
        let n = 8;
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 2.0;
        }
        let mut rng = SplitMix64::new(11);
        let b = rand_mat(&mut rng, n * n, 1.0);
        let qb = from_f32(&b, n, n, 8, TileSize::Edge(4));
        let got = ctx().with_tile(TileSize::Edge(4)).matmul_f32(&a, &b, n, n, n, 8).unwrap();
        for (g, q) in got.iter().zip(qb.to_f32().iter()) {
            assert_eq!(*g, 2.0 * q);
        }
    }
}
