//! BFP matrix multiplication with integer MACs + FP32 tile accumulation —
//! the software model of the paper's MatMul unit (Figure 2).
//!
//! Per (t x t) tile pair: the mantissa dot products run entirely in
//! integer arithmetic; each tile-partial is scaled by `2^(e_a + e_b)` once
//! and added to the FP32 output accumulator. That is exactly Equation (2)
//! plus the §4.2 tiling rule: "tile multiplications are performed in fixed
//! point, and their results are accumulated in floating point arithmetic".
//!
//! ## Packed, parallel kernels
//!
//! The kernels are generic over the packed storage ([`MantissaElem`]:
//! `i8`/`i16`/`i32`), so hbfp8 streams 1-byte mantissas and the inner
//! loops autovectorize as widening integer MACs. The accumulator width is
//! chosen per tile shape by a proven bound (see [`acc_fits_i32`]): a
//! k-tile partial of `tile_k` products each at most `2^(ma-1) * 2^(mb-1)`
//! in magnitude sums to at most `tile_k * 2^(ma+mb-2)`; when that fits
//! `i32` the kernel accumulates in `i32` (the dense fixed-point logic the
//! paper maps onto), otherwise it falls back to `i64`. Both paths produce
//! identical partials, so results are bit-for-bit equal to the
//! [`bfp_matmul_naive`] reference.
//!
//! Output row-bands are distributed over the persistent worker pool
//! (`util::pool`); every output element accumulates its k-tiles in the
//! same order on exactly one lane, so results are bit-identical for any
//! thread count and either dispatch backend.
//!
//! ## Packed-panel default path, SIMD kernel family
//!
//! The default kernels stream the B operand from its [`PackedPanels`]
//! layout (reordered once per tensor, cached on the `BfpTensor`): per
//! k-tile, mantissas sit k-major in panels as wide as the active SIMD
//! family's register block ([`Isa::panel_nr`]: 8 scalar, 16 SSE4.1/NEON,
//! 32 AVX2), so the microkernel keeps one `[acc; nr]` block per output
//! row and reads B strictly contiguously. The inner MAC loop dispatches
//! to the runtime-selected kernel family (`bfp::kernels`, `HBFP_SIMD`
//! override); [`bfp_matmul_with_simd`] forces a family explicitly (the
//! bench ladder's `simd off` rungs and the cross-ISA differential
//! tests). The pre-panel row-major walk is retained as
//! [`bfp_matmul_rowmajor`] (bench rung + differential-test partner,
//! always scalar), and [`bfp_matmul_with_backend`] exposes the
//! scoped-spawn dispatch baseline for the pooled-vs-scoped rung. All
//! paths — every ISA included — are bit-for-bit equal to
//! [`bfp_matmul_naive`].

use anyhow::{anyhow, Result};

use super::kernels::{self, Accum, Isa};
use super::panels::{matmul_tile_edge, PackedPanels, MAX_PANEL_NR};
use super::quant::{self, exp2i, Rounding, TileRounding};
use super::tensor::{BfpTensor, MantissaElem, Mantissas, TileSize};
use crate::util::pool::{self, ParBackend};
use crate::util::worker_threads;

/// Below this many MACs (m*k*n) the matmuls stay single-threaded (scaled
/// by the active kernel family's throughput class — see
/// [`pool::par_threads_simd`]).
const PAR_MIN_MACS: usize = 1 << 17;

/// Largest possible |sum| of `tile_k` mantissa products at widths
/// `(ma, mb)`: every product is at most `2^(ma-1) * 2^(mb-1)` in
/// magnitude (attained only at the two most-negative mantissas).
pub fn max_tile_partial(tile_k: usize, ma: u32, mb: u32) -> u128 {
    (tile_k as u128) << (ma + mb).saturating_sub(2)
}

/// True iff a k-tile partial provably fits an `i32` accumulator, i.e.
/// `tile_k * 2^(ma-1) * 2^(mb-1) <= i32::MAX`. Every intermediate partial
/// sum is bounded by the final bound (magnitudes only accumulate), so no
/// intermediate overflow is possible either.
pub fn acc_fits_i32(tile_k: usize, ma: u32, mb: u32) -> bool {
    max_tile_partial(tile_k.max(1), ma, mb) <= i32::MAX as u128
}

fn check_shapes(a: &BfpTensor, b: &BfpTensor) -> Result<()> {
    if a.cols != b.rows {
        return Err(anyhow!("contraction mismatch: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols));
    }
    if a.tile != b.tile {
        return Err(anyhow!("tile mismatch: {:?} vs {:?}", a.tile, b.tile));
    }
    Ok(())
}

/// C = A · B over BFP tensors; returns row-major f32 (the BFP→FP unit
/// output). Requires matching tile configurations so tile boundaries
/// align on the contraction dimension. Streams B from its cached packed
/// panels, parallel over output row-bands on the persistent pool with
/// the default worker-thread budget.
pub fn bfp_matmul(a: &BfpTensor, b: &BfpTensor) -> Result<Vec<f32>> {
    bfp_matmul_with_threads(a, b, worker_threads())
}

/// [`bfp_matmul`] with an explicit thread cap. Bit-identical results for
/// any `max_threads`.
pub fn bfp_matmul_with_threads(
    a: &BfpTensor,
    b: &BfpTensor,
    max_threads: usize,
) -> Result<Vec<f32>> {
    bfp_matmul_with_backend(a, b, max_threads, ParBackend::Pooled)
}

/// [`bfp_matmul`] with an explicit dispatch backend (pooled vs per-call
/// scoped spawns) — the packed-panel kernel either way, bit-identical
/// across backends; `Scoped` exists for the bench ladder's
/// spawn-amortization rung.
pub fn bfp_matmul_with_backend(
    a: &BfpTensor,
    b: &BfpTensor,
    max_threads: usize,
    backend: ParBackend,
) -> Result<Vec<f32>> {
    bfp_matmul_full(a, b, max_threads, backend, kernels::active())
}

/// [`bfp_matmul`] with an explicitly forced SIMD kernel family: packs
/// (or re-packs) B's panels at that family's width and runs its MAC
/// kernels. Bit-identical to every other family — this exists for the
/// bench ladder's `simd off` rungs and the cross-ISA differential tests.
/// The request is clamped to what the CPU supports
/// ([`Isa::clamped`]), so any `Isa` value is safe.
pub fn bfp_matmul_with_simd(
    a: &BfpTensor,
    b: &BfpTensor,
    max_threads: usize,
    isa: Isa,
) -> Result<Vec<f32>> {
    bfp_matmul_full(a, b, max_threads, ParBackend::Pooled, isa.clamped())
}

/// Shared matmul body. `isa` must already be executable on this CPU
/// (`kernels::active()` or an `Isa::clamped()` result) — the microkernel
/// uses the preclamped dispatch.
fn bfp_matmul_full(
    a: &BfpTensor,
    b: &BfpTensor,
    max_threads: usize,
    backend: ParBackend,
    isa: Isa,
) -> Result<Vec<f32>> {
    check_shapes(a, b)?;
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = vec![0.0f32; m * n];
    if m == 0 || k == 0 || n == 0 {
        return Ok(out);
    }
    let t = matmul_tile_edge(a.tile, k);
    let bands = m.div_ceil(t);
    let threads =
        pool::par_threads_simd(m * k * n, PAR_MIN_MACS, isa.par_floor_scale(), max_threads, bands);
    let pp = b.packed_panels_nr(isa.panel_nr());
    match &a.mantissas {
        Mantissas::I8(av) => {
            packed_dispatch_b::<i8>(av, a, b, &pp, &mut out, t, threads, backend, isa)
        }
        Mantissas::I16(av) => {
            packed_dispatch_b::<i16>(av, a, b, &pp, &mut out, t, threads, backend, isa)
        }
        Mantissas::I32(av) => {
            packed_dispatch_b::<i32>(av, a, b, &pp, &mut out, t, threads, backend, isa)
        }
    }
    Ok(out)
}

#[allow(clippy::too_many_arguments)]
fn packed_dispatch_b<EA: MantissaElem>(
    av: &[EA],
    a: &BfpTensor,
    b: &BfpTensor,
    pp: &PackedPanels,
    out: &mut [f32],
    t: usize,
    threads: usize,
    backend: ParBackend,
    isa: Isa,
) {
    match &pp.data {
        Mantissas::I8(pv) => packed_bands(av, pv, a, b, pp, out, t, threads, backend, isa),
        Mantissas::I16(pv) => packed_bands(av, pv, a, b, pp, out, t, threads, backend, isa),
        Mantissas::I32(pv) => packed_bands(av, pv, a, b, pp, out, t, threads, backend, isa),
    }
}

#[allow(clippy::too_many_arguments)]
fn packed_bands<EA: MantissaElem, EB: MantissaElem>(
    av: &[EA],
    pv: &[EB],
    a: &BfpTensor,
    b: &BfpTensor,
    pp: &PackedPanels,
    out: &mut [f32],
    t: usize,
    threads: usize,
    backend: ParBackend,
    isa: Isa,
) {
    let n = b.cols;
    let jobs: Vec<(usize, &mut [f32])> = out.chunks_mut(t * n).enumerate().collect();
    pool::run_backend(backend, jobs, threads, |band, band_out| {
        let i0 = band * t;
        let i1 = (i0 + t).min(a.rows);
        let a_exp = |r: usize, c: usize| a.exponent_at(r, c);
        band_matmul_packed(av, 0, &a_exp, a.mantissa_bits, pv, pp, b, band_out, i0, i1, t, isa);
    });
}

/// The pre-panel row-major B walk, kept as the packed-panel rung's bench
/// partner and differential-test reference. Pooled dispatch, default
/// thread budget.
pub fn bfp_matmul_rowmajor(a: &BfpTensor, b: &BfpTensor) -> Result<Vec<f32>> {
    bfp_matmul_rowmajor_with_threads(a, b, worker_threads())
}

/// [`bfp_matmul_rowmajor`] with an explicit thread cap.
pub fn bfp_matmul_rowmajor_with_threads(
    a: &BfpTensor,
    b: &BfpTensor,
    max_threads: usize,
) -> Result<Vec<f32>> {
    check_shapes(a, b)?;
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = vec![0.0f32; m * n];
    if m == 0 || k == 0 || n == 0 {
        return Ok(out);
    }
    let t = matmul_tile_edge(a.tile, k);
    let bands = m.div_ceil(t);
    let threads = pool::par_threads(m * k * n, PAR_MIN_MACS, max_threads, bands);
    match &a.mantissas {
        Mantissas::I8(av) => rowmajor_dispatch_b::<i8>(av, a, b, &mut out, t, threads),
        Mantissas::I16(av) => rowmajor_dispatch_b::<i16>(av, a, b, &mut out, t, threads),
        Mantissas::I32(av) => rowmajor_dispatch_b::<i32>(av, a, b, &mut out, t, threads),
    }
    Ok(out)
}

fn rowmajor_dispatch_b<EA: MantissaElem>(
    av: &[EA],
    a: &BfpTensor,
    b: &BfpTensor,
    out: &mut [f32],
    t: usize,
    threads: usize,
) {
    match &b.mantissas {
        Mantissas::I8(bv) => rowmajor_bands(av, bv, a, b, out, t, threads),
        Mantissas::I16(bv) => rowmajor_bands(av, bv, a, b, out, t, threads),
        Mantissas::I32(bv) => rowmajor_bands(av, bv, a, b, out, t, threads),
    }
}

fn rowmajor_bands<EA: MantissaElem, EB: MantissaElem>(
    av: &[EA],
    bv: &[EB],
    a: &BfpTensor,
    b: &BfpTensor,
    out: &mut [f32],
    t: usize,
    threads: usize,
) {
    let n = b.cols;
    let jobs: Vec<(usize, &mut [f32])> = out.chunks_mut(t * n).enumerate().collect();
    pool::dispatch_jobs(jobs, threads, |band, band_out| {
        let i0 = band * t;
        let i1 = (i0 + t).min(a.rows);
        let a_exp = |r: usize, c: usize| a.exponent_at(r, c);
        band_matmul(av, 0, &a_exp, a.mantissa_bits, bv, b, band_out, i0, i1, t);
    });
}

/// Compute output rows `i0..i1` into `band_out` (local row 0 = global row
/// `i0`, row stride `n`). `av` holds A's mantissas starting at global row
/// `a_row0` (0 for a full tensor, `i0` for a fused per-band scratch);
/// `a_exp(r, c)` is A's shared exponent at a global coordinate.
#[allow(clippy::too_many_arguments)]
fn band_matmul<EA: MantissaElem, EB: MantissaElem, FA: Fn(usize, usize) -> i32>(
    av: &[EA],
    a_row0: usize,
    a_exp: &FA,
    ma_bits: u32,
    bv: &[EB],
    b: &BfpTensor,
    band_out: &mut [f32],
    i0: usize,
    i1: usize,
    t: usize,
) {
    let k = b.rows;
    let n = b.cols;
    let ma = ma_bits as i32;
    let mb = b.mantissa_bits as i32;
    let ti = i1 - i0;
    if ti == 0 {
        return;
    }
    let tj_cap = t.min(n);
    let tile_k = t.min(k).max(1);
    let use_i32 = acc_fits_i32(tile_k, ma_bits, b.mantissa_bits);
    let mut acc32 = vec![0i32; if use_i32 { ti * tj_cap } else { 0 }];
    let mut acc64 = vec![0i64; if use_i32 { 0 } else { ti * tj_cap }];
    let arow0 = i0 - a_row0;
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + t).min(n);
        let tj = j1 - j0;
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + t).min(k);
            // Shared exponents are constant across the tile pair; the
            // scale 2^(ea-(ma-1)) * 2^(eb-(mb-1)) is applied once per
            // tile-partial (the FP realignment the paper amortizes over
            // 2N fixed-point ops).
            let ea = a_exp(i0, k0);
            let eb = b.exponent_at(k0, j0);
            let scale = exp2i(ea - (ma - 1)) * exp2i(eb - (mb - 1));
            if use_i32 {
                let acc = &mut acc32[..ti * tj];
                acc.fill(0);
                tile_mac(av, bv, acc, arow0, ti, j0, j1, k0, k1, k, n);
                debug_assert_tile_bound(acc, tile_k, ma_bits, b.mantissa_bits);
                flush_tile(acc, band_out, scale, n, j0, tj, ti);
            } else {
                let acc = &mut acc64[..ti * tj];
                acc.fill(0);
                tile_mac(av, bv, acc, arow0, ti, j0, j1, k0, k1, k, n);
                flush_tile(acc, band_out, scale, n, j0, tj, ti);
            }
            k0 = k1;
        }
        j0 = j1;
    }
}

/// Integer MAC over one tile pair: walks B row-major (contiguous loads)
/// accumulating a row of partials — §Perf L3: ~4x over the naive
/// j-innermost walk, and the loop the narrow storage classes vectorize.
#[allow(clippy::too_many_arguments)]
#[inline]
fn tile_mac<EA: MantissaElem, EB: MantissaElem, A: Accum>(
    av: &[EA],
    bv: &[EB],
    acc: &mut [A],
    arow0: usize,
    ti: usize,
    j0: usize,
    j1: usize,
    k0: usize,
    k1: usize,
    k: usize,
    n: usize,
) {
    let tj = j1 - j0;
    for li in 0..ti {
        let ar = arow0 + li;
        let arow = &av[ar * k + k0..ar * k + k1];
        let accrow = &mut acc[li * tj..(li + 1) * tj];
        for (dk, &qa) in arow.iter().enumerate() {
            if qa.to_i32() == 0 {
                continue;
            }
            let brow = &bv[(k0 + dk) * n + j0..(k0 + dk) * n + j1];
            for (aj, &qb) in accrow.iter_mut().zip(brow) {
                aj.mac(qa, qb);
            }
        }
    }
}

/// Scale a tile's integer partials into the f32 band accumulator.
#[inline]
fn flush_tile<A: Accum>(
    acc: &[A],
    band_out: &mut [f32],
    scale: f32,
    n: usize,
    j0: usize,
    tj: usize,
    ti: usize,
) {
    for li in 0..ti {
        let accrow = &acc[li * tj..(li + 1) * tj];
        let orow = &mut band_out[li * n + j0..li * n + j0 + tj];
        for (o, &v) in orow.iter_mut().zip(accrow) {
            *o += v.to_f32() * scale;
        }
    }
}

/// Debug-build check of the overflow proof's premise: no tile partial may
/// exceed `tile_k * 2^(ma+mb-2)` (possible only if a tensor carries
/// mantissas wider than its declared width).
fn debug_assert_tile_bound<A: Accum>(acc: &[A], tile_k: usize, ma: u32, mb: u32) {
    if cfg!(debug_assertions) {
        let bound = max_tile_partial(tile_k, ma, mb);
        for v in acc {
            debug_assert!(
                (v.to_i64().unsigned_abs() as u128) <= bound,
                "tile partial {} exceeds proven bound {bound} (tile_k={tile_k}, ma={ma}, mb={mb})",
                v.to_i64()
            );
        }
    }
}

/// Compute output rows `i0..i1` against the packed B panels. Same
/// contract as [`band_matmul`] (same k order, same per-tile flush order,
/// hence bit-identical results), but B streams contiguously panel by
/// panel and each output row keeps a `[acc; nr]` register block, with
/// the inner MAC loop dispatched to the `isa` kernel family.
#[allow(clippy::too_many_arguments)]
fn band_matmul_packed<EA: MantissaElem, EB: MantissaElem, FA: Fn(usize, usize) -> i32>(
    av: &[EA],
    a_row0: usize,
    a_exp: &FA,
    ma_bits: u32,
    pv: &[EB],
    pp: &PackedPanels,
    b: &BfpTensor,
    band_out: &mut [f32],
    i0: usize,
    i1: usize,
    t: usize,
    isa: Isa,
) {
    debug_assert_eq!(pp.t, t, "panel layout built for a different tile edge");
    debug_assert_eq!(pp.data.len(), pv.len());
    let nr = pp.nr;
    debug_assert!(nr <= MAX_PANEL_NR);
    let k = b.rows;
    let n = b.cols;
    let ma = ma_bits as i32;
    let mb = b.mantissa_bits as i32;
    let ti = i1 - i0;
    if ti == 0 {
        return;
    }
    let tile_k = t.min(k).max(1);
    let use_i32 = acc_fits_i32(tile_k, ma_bits, b.mantissa_bits);
    let arow0 = i0 - a_row0;
    let panel_elems = pp.tk * nr;
    for jt in 0..pp.tiles_j {
        let j0 = jt * t;
        let j1 = (j0 + t).min(n);
        for kt in 0..pp.tiles_k {
            let k0 = kt * t;
            let k1 = (k0 + t).min(k);
            let ea = a_exp(i0, k0);
            let eb = b.exponent_at(k0, j0);
            let scale = exp2i(ea - (ma - 1)) * exp2i(eb - (mb - 1));
            let tile_base = pp.tile_base(jt, kt);
            let mut p = 0;
            let mut c0 = j0;
            while c0 < j1 {
                let c1 = (c0 + nr).min(j1);
                let panel = &pv[tile_base + p * panel_elems..tile_base + (p + 1) * panel_elems];
                if use_i32 {
                    panel_mac_rows::<EA, EB, i32>(
                        av, panel, arow0, ti, k, k0, k1, band_out, n, c0, c1, scale, tile_k,
                        ma_bits, b.mantissa_bits, nr, isa,
                    );
                } else {
                    panel_mac_rows::<EA, EB, i64>(
                        av, panel, arow0, ti, k, k0, k1, band_out, n, c0, c1, scale, tile_k,
                        ma_bits, b.mantissa_bits, nr, isa,
                    );
                }
                c0 = c1;
                p += 1;
            }
        }
    }
}

/// Register-blocked microkernel: for each of `ti` output rows, stream one
/// packed panel (k-major, `nr` wide) through an `nr`-lane accumulator
/// block via the `isa` family's MAC kernel ([`kernels::mac_panel`]),
/// then scale the block into the f32 band accumulator. Padding columns
/// hold zero mantissas (every product 0), so only the `c0..c1` lanes are
/// flushed and the integer partials equal the row-major walk's exactly —
/// the flush stays scalar and in element order on every ISA.
#[allow(clippy::too_many_arguments)]
#[inline]
fn panel_mac_rows<EA: MantissaElem, EB: MantissaElem, A: Accum>(
    av: &[EA],
    panel: &[EB],
    arow0: usize,
    ti: usize,
    k: usize,
    k0: usize,
    k1: usize,
    band_out: &mut [f32],
    n: usize,
    c0: usize,
    c1: usize,
    scale: f32,
    tile_k: usize,
    ma_bits: u32,
    mb_bits: u32,
    nr: usize,
    isa: Isa,
) {
    let tj = c1 - c0;
    // one fixed-capacity block, re-zeroed per row over only the `nr`
    // lanes actually in use (the scalar family pays for 8, not 32)
    let mut acc = [A::default(); MAX_PANEL_NR];
    for li in 0..ti {
        let ar = arow0 + li;
        let arow = &av[ar * k + k0..ar * k + k1];
        let lanes = &mut acc[..nr];
        lanes.fill(A::default());
        kernels::mac_panel_preclamped(isa, arow, panel, nr, lanes);
        debug_assert_tile_bound(&acc[..tj], tile_k, ma_bits, mb_bits);
        let orow = &mut band_out[li * n + c0..li * n + c1];
        for (o, aj) in orow.iter_mut().zip(&acc[..tj]) {
            *o += aj.to_f32() * scale;
        }
    }
}

/// The pre-optimization j-innermost kernel, kept for the §Perf
/// before/after bench and as a differential-testing partner (must agree
/// with `bfp_matmul` bit-for-bit — both sum the same integer partials,
/// always in `i64` here).
pub fn bfp_matmul_naive(a: &BfpTensor, b: &BfpTensor) -> Result<Vec<f32>> {
    check_shapes(a, b)?;
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = vec![0.0f32; m * n];
    if m == 0 || k == 0 || n == 0 {
        return Ok(out);
    }
    let t = matmul_tile_edge(a.tile, k);
    match &a.mantissas {
        Mantissas::I8(av) => naive_dispatch_b::<i8>(av, a, b, &mut out, t),
        Mantissas::I16(av) => naive_dispatch_b::<i16>(av, a, b, &mut out, t),
        Mantissas::I32(av) => naive_dispatch_b::<i32>(av, a, b, &mut out, t),
    }
    Ok(out)
}

fn naive_dispatch_b<EA: MantissaElem>(
    av: &[EA],
    a: &BfpTensor,
    b: &BfpTensor,
    out: &mut [f32],
    t: usize,
) {
    match &b.mantissas {
        Mantissas::I8(bv) => naive_kernel(av, bv, a, b, out, t),
        Mantissas::I16(bv) => naive_kernel(av, bv, a, b, out, t),
        Mantissas::I32(bv) => naive_kernel(av, bv, a, b, out, t),
    }
}

fn naive_kernel<EA: MantissaElem, EB: MantissaElem>(
    av: &[EA],
    bv: &[EB],
    a: &BfpTensor,
    b: &BfpTensor,
    out: &mut [f32],
    t: usize,
) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let ma = a.mantissa_bits as i32;
    let mb = b.mantissa_bits as i32;
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + t).min(m);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + t).min(n);
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + t).min(k);
                let ea = a.exponent_at(i0, k0);
                let eb = b.exponent_at(k0, j0);
                let scale = exp2i(ea - (ma - 1)) * exp2i(eb - (mb - 1));
                for i in i0..i1 {
                    let arow = &av[i * k + k0..i * k + k1];
                    for j in j0..j1 {
                        let mut acc: i64 = 0;
                        for (dk, &qa) in arow.iter().enumerate() {
                            let qb = bv[(k0 + dk) * n + j];
                            acc += qa.to_i32() as i64 * qb.to_i32() as i64;
                        }
                        out[i * n + j] += acc as f32 * scale;
                    }
                }
                k0 = k1;
            }
            j0 = j1;
        }
        i0 = i1;
    }
}

/// Reference FP32 matmul (the baseline the harnesses compare against).
pub fn fp32_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..kk * n + n];
            let orow = &mut out[i * n..i * n + n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// Fused FP→BFP convert + matmul: quantizes row-band tiles of `a` on the
/// fly (per-band scratch, never a full materialized tensor) and MACs them
/// against the already-quantized, resident `b` — the paper's datapath,
/// where activations stream through the converter into the array while
/// weights sit in BFP. Bit-for-bit identical to
/// `BfpTensor::from_f32(a, ..., b.tile, ...)` followed by [`bfp_matmul`],
/// including stochastic rounding (same per-tile substreams).
pub fn quantize_matmul(
    a: &[f32],
    a_rows: usize,
    a_bits: u32,
    rounding: &mut Rounding,
    b: &BfpTensor,
) -> Result<Vec<f32>> {
    quantize_matmul_with_threads(a, a_rows, a_bits, rounding, b, worker_threads())
}

/// [`quantize_matmul`] with an explicit thread cap.
pub fn quantize_matmul_with_threads(
    a: &[f32],
    a_rows: usize,
    a_bits: u32,
    rounding: &mut Rounding,
    b: &BfpTensor,
    max_threads: usize,
) -> Result<Vec<f32>> {
    let (m, k, n) = (a_rows, b.rows, b.cols);
    if a.len() != m * k {
        return Err(anyhow!("a len {} != {m}x{k}", a.len()));
    }
    super::tensor::check_width(a_bits)?;
    let mut out = vec![0.0f32; m * n];
    if m * k == 0 {
        return Ok(out);
    }
    let mode = TileRounding::capture(rounding);
    if n == 0 {
        return Ok(out);
    }
    let (th, _) = b.tile.edge_or(m, k);
    let bands = m.div_ceil(th).max(1);
    let isa = kernels::active();
    let threads =
        pool::par_threads_simd(m * k * n, PAR_MIN_MACS, isa.par_floor_scale(), max_threads, bands);
    let pp = b.packed_panels_nr(isa.panel_nr());
    match Mantissas::for_width(a_bits, 0) {
        Mantissas::I8(_) => {
            fused_dispatch_b::<i8>(a, b, &pp, &mut out, m, a_bits, mode, threads, isa)
        }
        Mantissas::I16(_) => {
            fused_dispatch_b::<i16>(a, b, &pp, &mut out, m, a_bits, mode, threads, isa)
        }
        Mantissas::I32(_) => {
            fused_dispatch_b::<i32>(a, b, &pp, &mut out, m, a_bits, mode, threads, isa)
        }
    }
    Ok(out)
}

#[allow(clippy::too_many_arguments)]
fn fused_dispatch_b<EA: MantissaElem>(
    a: &[f32],
    b: &BfpTensor,
    pp: &PackedPanels,
    out: &mut [f32],
    m: usize,
    a_bits: u32,
    mode: TileRounding,
    threads: usize,
    isa: Isa,
) {
    match &pp.data {
        Mantissas::I8(pv) => {
            fused_bands::<EA, i8>(a, pv, pp, b, out, m, a_bits, mode, threads, isa)
        }
        Mantissas::I16(pv) => {
            fused_bands::<EA, i16>(a, pv, pp, b, out, m, a_bits, mode, threads, isa)
        }
        Mantissas::I32(pv) => {
            fused_bands::<EA, i32>(a, pv, pp, b, out, m, a_bits, mode, threads, isa)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn fused_bands<EA: MantissaElem, EB: MantissaElem>(
    a: &[f32],
    pv: &[EB],
    pp: &PackedPanels,
    b: &BfpTensor,
    out: &mut [f32],
    m: usize,
    a_bits: u32,
    mode: TileRounding,
    threads: usize,
    isa: Isa,
) {
    let k = b.rows;
    let n = b.cols;
    let (th, tw) = b.tile.edge_or(m, k);
    let tiles_c = k.div_ceil(tw).max(1);
    let t_mm = matmul_tile_edge(b.tile, k);
    let jobs: Vec<(usize, &mut [f32])> = out.chunks_mut(th * n).enumerate().collect();
    pool::dispatch_jobs(jobs, threads, |band, band_out| {
        let i0 = band * th;
        let i1 = (i0 + th).min(m);
        let band_rows = i1 - i0;
        // Per-band converter: quantize this band's A tiles into packed
        // scratch (the only A-mantissa storage that ever exists). RNE
        // rows vectorize; stochastic rows stay scalar in element order
        // so the per-tile RNG draws are ISA-independent.
        let mut scratch: Vec<EA> = vec![EA::from_i32(0); band_rows * k];
        let mut band_exps = vec![0i32; tiles_c];
        for tc in 0..tiles_c {
            let c0 = tc * tw;
            let c1 = (c0 + tw).min(k);
            let e = quant::block_exponent_strided(a, k, i0, i1, c0, c1);
            band_exps[tc] = e;
            match mode {
                TileRounding::NearestEven => {
                    for r in i0..i1 {
                        let src = &a[r * k + c0..r * k + c1];
                        let dst = &mut scratch[(r - i0) * k + c0..(r - i0) * k + c1];
                        kernels::quantize_row_rne_preclamped(isa, src, dst, e, a_bits);
                    }
                }
                TileRounding::StochasticBase(_) => {
                    let mut owned = mode.for_tile((band * tiles_c + tc) as u64);
                    let mut rounding = owned.as_rounding();
                    for r in i0..i1 {
                        let src = &a[r * k + c0..r * k + c1];
                        let dst = &mut scratch[(r - i0) * k + c0..(r - i0) * k + c1];
                        for (d, &x) in dst.iter_mut().zip(src) {
                            *d = EA::from_i32(quant::quantize_value(x, e, a_bits, &mut rounding));
                        }
                    }
                }
            }
        }
        band_matmul_packed(
            &scratch,
            i0,
            &|_r, c| band_exps[c / tw],
            a_bits,
            pv,
            pp,
            b,
            band_out,
            i0,
            i1,
            t_mm,
            isa,
        );
    });
}

/// Convenience: quantize f32 operands and multiply in BFP. Uses the fused
/// path for the A operand (B is quantized once, as resident weights).
pub fn hbfp_matmul_f32(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    mantissa_bits: u32,
    tile: TileSize,
) -> Result<Vec<f32>> {
    let qb = BfpTensor::from_f32(b, k, n, mantissa_bits, tile, &mut Rounding::NearestEven)?;
    quantize_matmul(a, m, mantissa_bits, &mut Rounding::NearestEven, &qb)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{check, Gen};
    use crate::util::rng::{SplitMix64, Xorshift32};

    fn rand_mat(rng: &mut SplitMix64, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal() * scale).collect()
    }

    #[test]
    fn integer_mac_equals_dequantized_fp_product() {
        // The integer-MAC path must equal multiplying the dequantized
        // tensors in f64 then rounding — i.e. the mantissa math is exact.
        check("mac exactness", 60, |g: &mut Gen| {
            let (m, k, n) = (g.int(1, 20), g.int(1, 24), g.int(1, 20));
            let a = g.vec_f32(m * k, 2);
            let b = g.vec_f32(k * n, 2);
            let tile = *g.pick(&[TileSize::Whole, TileSize::Edge(8)]);
            let mb = *g.pick(&[4u32, 8]);
            let qa = BfpTensor::from_f32(&a, m, k, mb, tile, &mut Rounding::NearestEven).unwrap();
            let qb = BfpTensor::from_f32(&b, k, n, mb, tile, &mut Rounding::NearestEven).unwrap();
            let got = bfp_matmul(&qa, &qb).unwrap();
            let da = qa.to_f32();
            let db = qb.to_f32();
            // f64 product of dequantized values (exact for these widths)
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0.0f64;
                    for kk in 0..k {
                        acc += da[i * k + kk] as f64 * db[kk * n + j] as f64;
                    }
                    let gotv = got[i * n + j] as f64;
                    let tol = acc.abs().max(1.0) * 1e-5;
                    prop_assert!((gotv - acc).abs() <= tol, "({i},{j}): {gotv} vs {acc}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn error_decays_with_mantissa_width() {
        let mut rng = SplitMix64::new(7);
        let (m, k, n) = (32, 48, 32);
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        let exact = fp32_matmul(&a, &b, m, k, n);
        let amax = exact.iter().fold(0.0f32, |s, &x| s.max(x.abs()));
        let mut last = f32::INFINITY;
        for &bits in &[4u32, 8, 12, 16] {
            let got = hbfp_matmul_f32(&a, &b, m, k, n, bits, TileSize::Edge(16)).unwrap();
            let err = got
                .iter()
                .zip(&exact)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max)
                / amax;
            assert!(err < last, "error should decay: {err} !< {last} at m={bits}");
            last = err;
        }
        assert!(last < 1e-3, "16-bit error too large: {last}");
    }

    #[test]
    fn tiling_beats_whole_tensor_on_mixed_scales() {
        let mut rng = SplitMix64::new(3);
        let (m, k, n) = (32, 32, 32);
        let mut a = rand_mat(&mut rng, m * k, 1.0);
        for r in 0..16 {
            for c in 0..k {
                a[r * k + c] *= 1e-3; // two exponent regimes
            }
        }
        let b = rand_mat(&mut rng, k * n, 1.0);
        let exact = fp32_matmul(&a, &b, m, k, n);
        let err = |got: &[f32]| {
            got.iter().zip(&exact).map(|(x, y)| (x - y).abs()).sum::<f32>() / exact.len() as f32
        };
        let tiled = hbfp_matmul_f32(&a, &b, m, k, n, 8, TileSize::Edge(16)).unwrap();
        let whole = hbfp_matmul_f32(&a, &b, m, k, n, 8, TileSize::Whole).unwrap();
        assert!(err(&tiled) < err(&whole), "{} !< {}", err(&tiled), err(&whole));
    }

    #[test]
    fn mismatched_shapes_rejected() {
        let a = BfpTensor::from_f32(&[1.0; 6], 2, 3, 8, TileSize::Whole, &mut Rounding::NearestEven)
            .unwrap();
        let b = BfpTensor::from_f32(&[1.0; 8], 2, 4, 8, TileSize::Whole, &mut Rounding::NearestEven)
            .unwrap();
        assert!(bfp_matmul(&a, &b).is_err());
    }

    #[test]
    fn mismatched_tiles_rejected() {
        let a = BfpTensor::from_f32(&[1.0; 4], 2, 2, 8, TileSize::Whole, &mut Rounding::NearestEven)
            .unwrap();
        let b =
            BfpTensor::from_f32(&[1.0; 4], 2, 2, 8, TileSize::Edge(2), &mut Rounding::NearestEven)
                .unwrap();
        assert!(bfp_matmul(&a, &b).is_err());
    }

    #[test]
    fn blocked_equals_naive_bitwise() {
        // Both kernels sum identical integer partials in identical k
        // order, so results must be bit-for-bit equal — across storage
        // classes (i8/i16/i32) and mixed-width operand pairs.
        check("blocked == naive", 60, |g: &mut Gen| {
            let (m, k, n) = (g.int(1, 40), g.int(1, 40), g.int(1, 40));
            let a = g.vec_f32(m * k, 3);
            let b = g.vec_f32(k * n, 3);
            let tile = *g.pick(&[TileSize::Whole, TileSize::Edge(8), TileSize::Edge(24)]);
            let ma = *g.pick(&[4u32, 8, 12, 16, 20, 24]);
            let mb = *g.pick(&[4u32, 8, 12, 16, 20, 24]);
            let qa = BfpTensor::from_f32(&a, m, k, ma, tile, &mut Rounding::NearestEven).unwrap();
            let qb = BfpTensor::from_f32(&b, k, n, mb, tile, &mut Rounding::NearestEven).unwrap();
            let fast = bfp_matmul(&qa, &qb).unwrap();
            let slow = bfp_matmul_naive(&qa, &qb).unwrap();
            prop_assert!(fast == slow, "blocked and naive kernels disagree (ma={ma}, mb={mb})");
            Ok(())
        });
    }

    #[test]
    fn thread_count_invariant() {
        let mut rng = SplitMix64::new(21);
        let (m, k, n) = (96, 80, 72); // above the parallel floor
        let a = rand_mat(&mut rng, m * k, 1.0);
        let b = rand_mat(&mut rng, k * n, 1.0);
        let qa = BfpTensor::from_f32(&a, m, k, 8, TileSize::Edge(16), &mut Rounding::NearestEven)
            .unwrap();
        let qb = BfpTensor::from_f32(&b, k, n, 8, TileSize::Edge(16), &mut Rounding::NearestEven)
            .unwrap();
        let one = bfp_matmul_with_threads(&qa, &qb, 1).unwrap();
        let many = bfp_matmul_with_threads(&qa, &qb, 8).unwrap();
        assert!(one == many, "thread count must not change results");
    }

    #[test]
    fn fused_equals_materialized_bitwise() {
        check("fused == materialized", 40, |g: &mut Gen| {
            let (m, k, n) = (g.int(1, 30), g.int(1, 30), g.int(1, 30));
            let a = g.vec_f32(m * k, 3);
            let b = g.vec_f32(k * n, 3);
            let tile = *g.pick(&[TileSize::Whole, TileSize::Edge(8), TileSize::Edge(24)]);
            let bits = *g.pick(&[4u32, 8, 12]);
            let qb = BfpTensor::from_f32(&b, k, n, bits, tile, &mut Rounding::NearestEven).unwrap();

            // nearest-even
            let qa = BfpTensor::from_f32(&a, m, k, bits, tile, &mut Rounding::NearestEven).unwrap();
            let want = bfp_matmul(&qa, &qb).unwrap();
            let got = quantize_matmul(&a, m, bits, &mut Rounding::NearestEven, &qb).unwrap();
            prop_assert!(got == want, "fused != materialized (rne, bits={bits})");

            // stochastic: same seed => same per-tile substreams
            let seed = g.rng.next_u32();
            let mut r1 = Xorshift32::new(seed);
            let mut r2 = Xorshift32::new(seed);
            let qa_s =
                BfpTensor::from_f32(&a, m, k, bits, tile, &mut Rounding::Stochastic(&mut r1))
                    .unwrap();
            let want_s = bfp_matmul(&qa_s, &qb).unwrap();
            let got_s =
                quantize_matmul(&a, m, bits, &mut Rounding::Stochastic(&mut r2), &qb).unwrap();
            prop_assert!(got_s == want_s, "fused != materialized (stochastic, bits={bits})");
            Ok(())
        });
    }

    #[test]
    fn fused_rejects_bad_len() {
        let qb = BfpTensor::from_f32(&[1.0; 4], 2, 2, 8, TileSize::Whole, &mut Rounding::NearestEven)
            .unwrap();
        assert!(quantize_matmul(&[1.0; 5], 2, 8, &mut Rounding::NearestEven, &qb).is_err());
        assert!(quantize_matmul(&[1.0; 4], 2, 1, &mut Rounding::NearestEven, &qb).is_err());
    }

    #[test]
    fn acc_bound_arithmetic() {
        // m=8 x m=8: 2^14 per product; i32 holds 2^17 - 1 of them.
        assert!(acc_fits_i32((1 << 17) - 1, 8, 8));
        assert!(!acc_fits_i32(1 << 17, 8, 8));
        // m=12 x m=12: 2^22 per product; 512 products hit 2^31 exactly — too big.
        assert!(acc_fits_i32(511, 12, 12));
        assert!(!acc_fits_i32(512, 12, 12));
        // m=16 x m=16: 2^30 per product; only one fits.
        assert!(acc_fits_i32(1, 16, 16));
        assert!(!acc_fits_i32(2, 16, 16));
        // widest supported: must fall back to i64 for any real tile
        assert!(!acc_fits_i32(24, 24, 24));
        assert_eq!(max_tile_partial(3, 8, 8), 3 << 14);
    }

    #[test]
    fn zero_matrices() {
        let z = hbfp_matmul_f32(&[0.0; 16], &[0.0; 16], 4, 4, 4, 8, TileSize::Edge(2)).unwrap();
        assert!(z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn identity_with_powers_of_two_exact() {
        // diag(2) quantizes exactly; product must equal 2*Q(b) exactly.
        let n = 8;
        let mut a = vec![0.0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 2.0;
        }
        let mut rng = SplitMix64::new(11);
        let b = rand_mat(&mut rng, n * n, 1.0);
        let qb =
            BfpTensor::from_f32(&b, n, n, 8, TileSize::Edge(4), &mut Rounding::NearestEven).unwrap();
        let got = hbfp_matmul_f32(&a, &b, n, n, n, 8, TileSize::Edge(4)).unwrap();
        for (g, q) in got.iter().zip(qb.to_f32().iter()) {
            assert_eq!(*g, 2.0 * q);
        }
    }
}
