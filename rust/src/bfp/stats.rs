//! Quantization analysis tooling — the §4/§4.2 motivation numbers.
//!
//! The paper's argument for tiling and for the hybrid split rests on value
//! distributions: tensors whose values span more binades than the mantissa
//! can absorb lose their small values ("if the tensors' value
//! distributions are too wide to be captured by its mantissa bits").
//! This module quantifies that: per-block exponent spread, quantization
//! SNR, and the fraction of values flushed to zero — the evidence behind
//! `examples/quantization_study.rs`.

use std::sync::atomic::{AtomicU64, Ordering};

use super::quant::{block_exponent, frexp_exp, E_MAX};
use super::tensor::{BfpTensor, TileSize};
use super::Rounding;

// ---------------------------------------------------------------- guards
//
// The numeric-guard layer (`GuardPolicy` on `BfpContext` / `MatmulPlan`)
// surfaces its detections through the helpers below: non-finite input
// scans, shared-exponent saturation, and mantissa clamp-rail rates — the
// three ways HBFP training goes numerically wrong before the loss ever
// shows it.

/// A non-finite value in data that is about to be quantized. The shared
/// tile exponent makes this worse than in FP32: one NaN/Inf corrupts the
/// exponent for its whole tile, so the quantizer contract rejects
/// non-finite input outright (see `bfp/quant.rs`).
#[derive(Debug, Clone, Copy)]
pub struct NonFiniteError {
    /// Index of the first non-finite element found.
    pub index: usize,
    /// The offending value (NaN or ±Inf).
    pub value: f32,
}

impl std::fmt::Display for NonFiniteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "non-finite value {} at flat index {}", self.value, self.index)
    }
}

impl std::error::Error for NonFiniteError {}

/// Result of a non-finite scan over f32 data.
#[derive(Debug, Clone, Copy, Default)]
pub struct ScanReport {
    /// Elements actually inspected (`len.div_ceil(stride)`).
    pub checked: usize,
    /// Non-finite elements among those inspected.
    pub nonfinite: usize,
    /// Flat index of the first non-finite element found, if any.
    pub first: Option<usize>,
}

impl ScanReport {
    pub fn clean(&self) -> bool {
        self.nonfinite == 0
    }

    /// The scan's finding as a typed error (None when clean).
    pub fn error(&self, data: &[f32]) -> Option<NonFiniteError> {
        self.first.map(|index| NonFiniteError { index, value: data[index] })
    }
}

/// Scan for NaN/Inf, inspecting every `stride`-th element (stride 1 =
/// every element; clamped to at least 1). A strided scan costs a fraction
/// of a full pass and still catches the blanket non-finite patterns a
/// diverging run produces (one NaN in a GEMM output infects the whole
/// row within a step).
pub fn scan_nonfinite(data: &[f32], stride: usize) -> ScanReport {
    let stride = stride.max(1);
    let mut report = ScanReport::default();
    let mut i = 0;
    while i < data.len() {
        report.checked += 1;
        if !data[i].is_finite() {
            report.nonfinite += 1;
            if report.first.is_none() {
                report.first = Some(i);
            }
        }
        i += stride;
    }
    report
}

/// Fraction of tiles whose shared exponent sits at the `E_MAX` rail —
/// the quantizer's saturation indicator (values too large for the
/// exponent range; the next overflow wraps into garbage on hardware).
pub fn saturated_tile_frac(t: &BfpTensor) -> f64 {
    if t.exponents.is_empty() {
        return 0.0;
    }
    let sat = t.exponents.iter().filter(|&&e| e >= E_MAX).count();
    sat as f64 / t.exponents.len() as f64
}

/// Fraction of mantissas at the two's-complement clamp rails
/// (`±(2^(bits-1) - 1)`). A high rail rate means the mantissa grid is too
/// coarse for the tile's value spread — the width class should widen.
pub fn clamp_rail_frac(t: &BfpTensor) -> f64 {
    let n = t.rows * t.cols;
    if n == 0 {
        return 0.0;
    }
    let hi = (1i32 << (t.mantissa_bits - 1)) - 1;
    let lo = -hi;
    let mut railed = 0usize;
    for i in 0..n {
        let q = t.mantissas.get(i);
        if q >= hi || q <= lo {
            railed += 1;
        }
    }
    railed as f64 / n as f64
}

/// Shared counters for the guard layer: how often guards scanned, what
/// they caught, and which degradations they triggered. Atomic so one
/// stats block can be shared across threads and recorded from inside
/// pool-dispatched work.
#[derive(Debug, Default)]
pub struct GuardStats {
    scans: AtomicU64,
    nonfinite_inputs: AtomicU64,
    saturated_tensors: AtomicU64,
    clamp_flagged: AtomicU64,
    fp32_fallbacks: AtomicU64,
    widenings: AtomicU64,
}

impl GuardStats {
    pub fn new() -> GuardStats {
        GuardStats::default()
    }

    pub fn record_scan(&self) {
        self.scans.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_nonfinite(&self) {
        self.nonfinite_inputs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_saturation(&self) {
        self.saturated_tensors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_clamp(&self) {
        self.clamp_flagged.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_fp32_fallback(&self) {
        self.fp32_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_widening(&self) {
        self.widenings.fetch_add(1, Ordering::Relaxed);
    }

    pub fn scans(&self) -> u64 {
        self.scans.load(Ordering::Relaxed)
    }

    pub fn nonfinite_inputs(&self) -> u64 {
        self.nonfinite_inputs.load(Ordering::Relaxed)
    }

    pub fn saturated_tensors(&self) -> u64 {
        self.saturated_tensors.load(Ordering::Relaxed)
    }

    pub fn clamp_flagged(&self) -> u64 {
        self.clamp_flagged.load(Ordering::Relaxed)
    }

    pub fn fp32_fallbacks(&self) -> u64 {
        self.fp32_fallbacks.load(Ordering::Relaxed)
    }

    pub fn widenings(&self) -> u64 {
        self.widenings.load(Ordering::Relaxed)
    }

    /// A plain-value copy of the counters, for embedding in metrics
    /// artifacts (see `coordinator::metrics`) and comparing runs.
    pub fn snapshot(&self) -> GuardStatsSnapshot {
        GuardStatsSnapshot {
            scans: self.scans(),
            nonfinite_inputs: self.nonfinite_inputs(),
            saturated_tensors: self.saturated_tensors(),
            clamp_flagged: self.clamp_flagged(),
            fp32_fallbacks: self.fp32_fallbacks(),
            widenings: self.widenings(),
        }
    }
}

/// Point-in-time copy of [`GuardStats`] — `Copy + Eq` so metrics
/// artifacts can carry it and determinism tests can compare whole runs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GuardStatsSnapshot {
    pub scans: u64,
    pub nonfinite_inputs: u64,
    pub saturated_tensors: u64,
    pub clamp_flagged: u64,
    pub fp32_fallbacks: u64,
    pub widenings: u64,
}

impl GuardStatsSnapshot {
    /// Did any guard observe anything at all?
    pub fn any_activity(&self) -> bool {
        self.scans != 0
            || self.nonfinite_inputs != 0
            || self.saturated_tensors != 0
            || self.clamp_flagged != 0
            || self.fp32_fallbacks != 0
            || self.widenings != 0
    }

    /// Register the six counters into `reg` under `prefix` (dot-joined
    /// when non-empty). `coordinator::metrics::guard_stats_json` routes
    /// through this, so one key list serves both export surfaces.
    pub fn export_metrics(&self, reg: &crate::obs::Registry, prefix: &str) {
        let name = |k: &str| {
            if prefix.is_empty() {
                k.to_string()
            } else {
                format!("{prefix}.{k}")
            }
        };
        reg.counter(&name("scans"), self.scans);
        reg.counter(&name("nonfinite_inputs"), self.nonfinite_inputs);
        reg.counter(&name("saturated_tensors"), self.saturated_tensors);
        reg.counter(&name("clamp_flagged"), self.clamp_flagged);
        reg.counter(&name("fp32_fallbacks"), self.fp32_fallbacks);
        reg.counter(&name("widenings"), self.widenings);
    }
}

/// Register the process-wide BFP datapath probe counters
/// ([`super::quant::OBS_BLOCKS_QUANTIZED`],
/// [`super::context::OBS_TENSORS_QUANTIZED`],
/// [`super::context::OBS_GEMMS_EXECUTED`]) into `reg` under `bfp.*`.
/// They count only while the obs mode is `counters` or `full`.
pub fn export_datapath_counters(reg: &crate::obs::Registry) {
    use std::sync::atomic::Ordering::Relaxed;
    reg.counter(
        "bfp.blocks_quantized",
        super::quant::OBS_BLOCKS_QUANTIZED.load(Relaxed),
    );
    reg.counter(
        "bfp.tensors_quantized",
        super::context::OBS_TENSORS_QUANTIZED.load(Relaxed),
    );
    reg.counter(
        "bfp.gemms_executed",
        super::context::OBS_GEMMS_EXECUTED.load(Relaxed),
    );
}

/// Distribution statistics of one tensor's element exponents.
#[derive(Debug, Clone)]
pub struct ExponentStats {
    /// Histogram over element frexp exponents (key = exponent).
    pub histogram: Vec<(i32, usize)>,
    pub min: i32,
    pub max: i32,
    /// Fraction of exact zeros (excluded from the histogram).
    pub zero_frac: f64,
}

impl ExponentStats {
    pub fn of(xs: &[f32]) -> ExponentStats {
        let mut map = std::collections::BTreeMap::new();
        let mut zeros = 0usize;
        for &x in xs {
            if x == 0.0 {
                zeros += 1;
            } else {
                *map.entry(frexp_exp(x.abs())).or_insert(0usize) += 1;
            }
        }
        let (min, max) = match (map.keys().next(), map.keys().next_back()) {
            (Some(&a), Some(&b)) => (a, b),
            _ => (0, 0),
        };
        ExponentStats {
            histogram: map.into_iter().collect(),
            min,
            max,
            zero_frac: zeros as f64 / xs.len().max(1) as f64,
        }
    }

    /// Binade span: how many mantissa bits a single shared exponent would
    /// need to represent every nonzero value at full precision.
    pub fn span(&self) -> i32 {
        self.max - self.min
    }
}

/// Quantization quality of a BFP configuration on given data.
#[derive(Debug, Clone, Copy)]
pub struct QuantReport {
    /// Signal-to-noise ratio in dB: 10 log10(E[x^2] / E[(x - Q(x))^2]).
    pub snr_db: f64,
    /// Fraction of nonzero inputs that quantized to exactly zero (the
    /// "small values are lost" failure mode).
    pub underflow_frac: f64,
    /// Max |x - Q(x)| over max |x| (worst-case relative distortion).
    pub max_rel_err: f64,
}

/// Quantize `data` (rows x cols) at the given mantissa width / tiling and
/// measure the damage.
pub fn quant_report(
    data: &[f32],
    rows: usize,
    cols: usize,
    mantissa_bits: u32,
    tile: TileSize,
) -> anyhow::Result<QuantReport> {
    let t = BfpTensor::from_f32(data, rows, cols, mantissa_bits, tile, &mut Rounding::NearestEven)?;
    let q = t.to_f32();
    let mut sig = 0.0f64;
    let mut noise = 0.0f64;
    let mut lost = 0usize;
    let mut nonzero = 0usize;
    let mut max_err = 0.0f64;
    let mut max_abs = 0.0f64;
    for (&x, &y) in data.iter().zip(&q) {
        sig += (x as f64) * (x as f64);
        let e = (x - y) as f64;
        noise += e * e;
        max_err = max_err.max(e.abs());
        max_abs = max_abs.max(x.abs() as f64);
        if x != 0.0 {
            nonzero += 1;
            if y == 0.0 {
                lost += 1;
            }
        }
    }
    Ok(QuantReport {
        snr_db: if noise > 0.0 { 10.0 * (sig / noise).log10() } else { f64::INFINITY },
        underflow_frac: lost as f64 / nonzero.max(1) as f64,
        max_rel_err: if max_abs > 0.0 { max_err / max_abs } else { 0.0 },
    })
}

/// Per-tile exponent spread of a 2-D tensor: for each tile, the span of
/// element exponents that one shared exponent must cover. Tiling helps
/// exactly when whole-tensor span >> per-tile spans.
pub fn tile_spans(data: &[f32], rows: usize, cols: usize, tile: usize) -> Vec<i32> {
    let mut spans = Vec::new();
    let mut block = Vec::new();
    let mut r0 = 0;
    while r0 < rows {
        let r1 = (r0 + tile).min(rows);
        let mut c0 = 0;
        while c0 < cols {
            let c1 = (c0 + tile).min(cols);
            block.clear();
            for r in r0..r1 {
                block.extend_from_slice(&data[r * cols + c0..r * cols + c1]);
            }
            let nonzero: Vec<f32> = block.iter().copied().filter(|&x| x != 0.0).collect();
            if nonzero.is_empty() {
                spans.push(0);
            } else {
                let e = block_exponent(&nonzero);
                let emin =
                    nonzero.iter().map(|&x| frexp_exp(x.abs())).min().unwrap_or(e);
                spans.push(e - emin);
            }
            c0 = c1;
        }
        r0 = r1;
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn mixed_scale_matrix(rows: usize, cols: usize) -> Vec<f32> {
        // top half ~1e-4, bottom half ~1: a >13-binade whole-tensor span
        let mut rng = SplitMix64::new(1);
        let mut v = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                let s = if r < rows / 2 { 1e-4 } else { 1.0 };
                v[r * cols + c] = rng.normal() * s;
            }
        }
        v
    }

    #[test]
    fn exponent_stats_basics() {
        let st = ExponentStats::of(&[0.0, 1.0, 2.0, 0.25]);
        assert_eq!(st.zero_frac, 0.25);
        assert_eq!(st.min, -1); // 0.25 -> frexp exp -1
        assert_eq!(st.max, 2); // 2.0 -> frexp exp 2
        assert_eq!(st.span(), 3);
    }

    #[test]
    fn snr_improves_with_mantissa_width() {
        let data = mixed_scale_matrix(32, 32);
        let mut last = -1.0;
        for m in [4u32, 8, 12, 16] {
            let r = quant_report(&data, 32, 32, m, TileSize::Edge(8)).unwrap();
            assert!(r.snr_db > last, "m={m}: {} !> {last}", r.snr_db);
            last = r.snr_db;
        }
        // ~6 dB per mantissa bit is the theoretical slope; 16-bit on
        // narrow-span tiles should be extremely clean
        assert!(last > 60.0, "16-bit SNR {last}");
    }

    #[test]
    fn tiling_rescues_mixed_scales() {
        let data = mixed_scale_matrix(32, 32);
        let whole = quant_report(&data, 32, 32, 8, TileSize::Whole).unwrap();
        let tiled = quant_report(&data, 32, 32, 8, TileSize::Edge(16)).unwrap();
        // whole-tensor exponent flushes the 1e-4 half to zero
        assert!(whole.underflow_frac > 0.3, "whole underflow {}", whole.underflow_frac);
        // within-tile gaussian tails still flush a little; the failure mode
        // under test is the order-of-magnitude difference
        assert!(tiled.underflow_frac < 0.05, "tiled underflow {}", tiled.underflow_frac);
        // global SNR is energy-weighted (dominated by the large half), so
        // it barely moves — the flushed-values fraction above is the
        // discriminating statistic, SNR just must not regress.
        assert!(tiled.snr_db >= whole.snr_db - 0.1);
    }

    #[test]
    fn tile_spans_reflect_structure() {
        let data = mixed_scale_matrix(32, 32);
        let spans16 = tile_spans(&data, 32, 32, 16);
        let spans_whole = tile_spans(&data, 32, 32, 32);
        let max16 = *spans16.iter().max().unwrap();
        let max_whole = *spans_whole.iter().max().unwrap();
        assert!(max_whole > max16, "{max_whole} !> {max16}");
        assert!(max_whole >= 12, "mixed scales should span >= 12 binades");
    }

    #[test]
    fn scan_finds_nonfinite_at_any_stride() {
        let mut v = vec![1.0f32; 100];
        v[37] = f32::NAN;
        let full = scan_nonfinite(&v, 1);
        assert_eq!(full.checked, 100);
        assert_eq!(full.nonfinite, 1);
        assert_eq!(full.first, Some(37));
        let e = full.error(&v).unwrap();
        assert_eq!(e.index, 37);
        assert!(e.value.is_nan());
        // clean data scans clean at every stride
        let clean = vec![2.5f32; 64];
        for stride in [1, 3, 16] {
            assert!(scan_nonfinite(&clean, stride).clean());
        }
        // stride 0 is clamped to 1, not an infinite loop
        assert_eq!(scan_nonfinite(&v, 0).checked, 100);
        // a blanket-NaN tensor is caught even by a sparse sample
        let all_bad = vec![f32::INFINITY; 64];
        assert!(!scan_nonfinite(&all_bad, 16).clean());
    }

    #[test]
    fn saturation_and_clamp_fracs() {
        // moderate data: nothing saturates, few rails
        let data = mixed_scale_matrix(16, 16);
        let t = BfpTensor::from_f32(&data, 16, 16, 8, TileSize::Edge(8), &mut Rounding::NearestEven)
            .unwrap();
        assert_eq!(saturated_tile_frac(&t), 0.0);
        assert!(clamp_rail_frac(&t) < 0.2, "rails {}", clamp_rail_frac(&t));
        // huge values pin the shared exponent at the E_MAX rail
        let big = vec![f32::MAX; 64];
        let tb =
            BfpTensor::from_f32(&big, 8, 8, 8, TileSize::Whole, &mut Rounding::NearestEven).unwrap();
        assert_eq!(saturated_tile_frac(&tb), 1.0);
    }

    #[test]
    fn guard_stats_count() {
        let g = GuardStats::new();
        g.record_scan();
        g.record_scan();
        g.record_nonfinite();
        g.record_fp32_fallback();
        g.record_widening();
        assert_eq!(g.scans(), 2);
        assert_eq!(g.nonfinite_inputs(), 1);
        assert_eq!(g.fp32_fallbacks(), 1);
        assert_eq!(g.widenings(), 1);
        assert_eq!(g.saturated_tensors(), 0);
        let snap = g.snapshot();
        assert_eq!(
            snap,
            GuardStatsSnapshot {
                scans: 2,
                nonfinite_inputs: 1,
                saturated_tensors: 0,
                clamp_flagged: 0,
                fp32_fallbacks: 1,
                widenings: 1,
            }
        );
        assert!(snap.any_activity());
        assert!(!GuardStatsSnapshot::default().any_activity());
    }

    #[test]
    fn uniform_tensor_has_tiny_span() {
        let v = vec![1.5f32; 64];
        let st = ExponentStats::of(&v);
        assert_eq!(st.span(), 0);
        let r = quant_report(&v, 8, 8, 8, TileSize::Whole).unwrap();
        assert!(r.underflow_frac == 0.0 && r.max_rel_err < 0.01);
    }
}
