//! Quantization analysis tooling — the §4/§4.2 motivation numbers.
//!
//! The paper's argument for tiling and for the hybrid split rests on value
//! distributions: tensors whose values span more binades than the mantissa
//! can absorb lose their small values ("if the tensors' value
//! distributions are too wide to be captured by its mantissa bits").
//! This module quantifies that: per-block exponent spread, quantization
//! SNR, and the fraction of values flushed to zero — the evidence behind
//! `examples/quantization_study.rs`.

use super::quant::{block_exponent, frexp_exp};
use super::tensor::{BfpTensor, TileSize};
use super::Rounding;

/// Distribution statistics of one tensor's element exponents.
#[derive(Debug, Clone)]
pub struct ExponentStats {
    /// Histogram over element frexp exponents (key = exponent).
    pub histogram: Vec<(i32, usize)>,
    pub min: i32,
    pub max: i32,
    /// Fraction of exact zeros (excluded from the histogram).
    pub zero_frac: f64,
}

impl ExponentStats {
    pub fn of(xs: &[f32]) -> ExponentStats {
        let mut map = std::collections::BTreeMap::new();
        let mut zeros = 0usize;
        for &x in xs {
            if x == 0.0 {
                zeros += 1;
            } else {
                *map.entry(frexp_exp(x.abs())).or_insert(0usize) += 1;
            }
        }
        let (min, max) = match (map.keys().next(), map.keys().next_back()) {
            (Some(&a), Some(&b)) => (a, b),
            _ => (0, 0),
        };
        ExponentStats {
            histogram: map.into_iter().collect(),
            min,
            max,
            zero_frac: zeros as f64 / xs.len().max(1) as f64,
        }
    }

    /// Binade span: how many mantissa bits a single shared exponent would
    /// need to represent every nonzero value at full precision.
    pub fn span(&self) -> i32 {
        self.max - self.min
    }
}

/// Quantization quality of a BFP configuration on given data.
#[derive(Debug, Clone, Copy)]
pub struct QuantReport {
    /// Signal-to-noise ratio in dB: 10 log10(E[x^2] / E[(x - Q(x))^2]).
    pub snr_db: f64,
    /// Fraction of nonzero inputs that quantized to exactly zero (the
    /// "small values are lost" failure mode).
    pub underflow_frac: f64,
    /// Max |x - Q(x)| over max |x| (worst-case relative distortion).
    pub max_rel_err: f64,
}

/// Quantize `data` (rows x cols) at the given mantissa width / tiling and
/// measure the damage.
pub fn quant_report(
    data: &[f32],
    rows: usize,
    cols: usize,
    mantissa_bits: u32,
    tile: TileSize,
) -> anyhow::Result<QuantReport> {
    let t = BfpTensor::from_f32(data, rows, cols, mantissa_bits, tile, &mut Rounding::NearestEven)?;
    let q = t.to_f32();
    let mut sig = 0.0f64;
    let mut noise = 0.0f64;
    let mut lost = 0usize;
    let mut nonzero = 0usize;
    let mut max_err = 0.0f64;
    let mut max_abs = 0.0f64;
    for (&x, &y) in data.iter().zip(&q) {
        sig += (x as f64) * (x as f64);
        let e = (x - y) as f64;
        noise += e * e;
        max_err = max_err.max(e.abs());
        max_abs = max_abs.max(x.abs() as f64);
        if x != 0.0 {
            nonzero += 1;
            if y == 0.0 {
                lost += 1;
            }
        }
    }
    Ok(QuantReport {
        snr_db: if noise > 0.0 { 10.0 * (sig / noise).log10() } else { f64::INFINITY },
        underflow_frac: lost as f64 / nonzero.max(1) as f64,
        max_rel_err: if max_abs > 0.0 { max_err / max_abs } else { 0.0 },
    })
}

/// Per-tile exponent spread of a 2-D tensor: for each tile, the span of
/// element exponents that one shared exponent must cover. Tiling helps
/// exactly when whole-tensor span >> per-tile spans.
pub fn tile_spans(data: &[f32], rows: usize, cols: usize, tile: usize) -> Vec<i32> {
    let mut spans = Vec::new();
    let mut block = Vec::new();
    let mut r0 = 0;
    while r0 < rows {
        let r1 = (r0 + tile).min(rows);
        let mut c0 = 0;
        while c0 < cols {
            let c1 = (c0 + tile).min(cols);
            block.clear();
            for r in r0..r1 {
                block.extend_from_slice(&data[r * cols + c0..r * cols + c1]);
            }
            let nonzero: Vec<f32> = block.iter().copied().filter(|&x| x != 0.0).collect();
            if nonzero.is_empty() {
                spans.push(0);
            } else {
                let e = block_exponent(&nonzero);
                let emin =
                    nonzero.iter().map(|&x| frexp_exp(x.abs())).min().unwrap_or(e);
                spans.push(e - emin);
            }
            c0 = c1;
        }
        r0 = r1;
    }
    spans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn mixed_scale_matrix(rows: usize, cols: usize) -> Vec<f32> {
        // top half ~1e-4, bottom half ~1: a >13-binade whole-tensor span
        let mut rng = SplitMix64::new(1);
        let mut v = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                let s = if r < rows / 2 { 1e-4 } else { 1.0 };
                v[r * cols + c] = rng.normal() * s;
            }
        }
        v
    }

    #[test]
    fn exponent_stats_basics() {
        let st = ExponentStats::of(&[0.0, 1.0, 2.0, 0.25]);
        assert_eq!(st.zero_frac, 0.25);
        assert_eq!(st.min, -1); // 0.25 -> frexp exp -1
        assert_eq!(st.max, 2); // 2.0 -> frexp exp 2
        assert_eq!(st.span(), 3);
    }

    #[test]
    fn snr_improves_with_mantissa_width() {
        let data = mixed_scale_matrix(32, 32);
        let mut last = -1.0;
        for m in [4u32, 8, 12, 16] {
            let r = quant_report(&data, 32, 32, m, TileSize::Edge(8)).unwrap();
            assert!(r.snr_db > last, "m={m}: {} !> {last}", r.snr_db);
            last = r.snr_db;
        }
        // ~6 dB per mantissa bit is the theoretical slope; 16-bit on
        // narrow-span tiles should be extremely clean
        assert!(last > 60.0, "16-bit SNR {last}");
    }

    #[test]
    fn tiling_rescues_mixed_scales() {
        let data = mixed_scale_matrix(32, 32);
        let whole = quant_report(&data, 32, 32, 8, TileSize::Whole).unwrap();
        let tiled = quant_report(&data, 32, 32, 8, TileSize::Edge(16)).unwrap();
        // whole-tensor exponent flushes the 1e-4 half to zero
        assert!(whole.underflow_frac > 0.3, "whole underflow {}", whole.underflow_frac);
        // within-tile gaussian tails still flush a little; the failure mode
        // under test is the order-of-magnitude difference
        assert!(tiled.underflow_frac < 0.05, "tiled underflow {}", tiled.underflow_frac);
        // global SNR is energy-weighted (dominated by the large half), so
        // it barely moves — the flushed-values fraction above is the
        // discriminating statistic, SNR just must not regress.
        assert!(tiled.snr_db >= whole.snr_db - 0.1);
    }

    #[test]
    fn tile_spans_reflect_structure() {
        let data = mixed_scale_matrix(32, 32);
        let spans16 = tile_spans(&data, 32, 32, 16);
        let spans_whole = tile_spans(&data, 32, 32, 32);
        let max16 = *spans16.iter().max().unwrap();
        let max_whole = *spans_whole.iter().max().unwrap();
        assert!(max_whole > max16, "{max_whole} !> {max16}");
        assert!(max_whole >= 12, "mixed scales should span >= 12 binades");
    }

    #[test]
    fn uniform_tensor_has_tiny_span() {
        let v = vec![1.5f32; 64];
        let st = ExponentStats::of(&v);
        assert_eq!(st.span(), 0);
        let r = quant_report(&v, 8, 8, 8, TileSize::Whole).unwrap();
        assert!(r.underflow_frac == 0.0 && r.max_rel_err < 0.01);
    }
}
