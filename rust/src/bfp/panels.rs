//! Packed B-operand panels: the once-per-tensor weight relayout the MAC
//! kernels stream.
//!
//! The row-major layout makes the matmul's inner loop read the B operand
//! in `n`-strided row segments of each (t x t) tile — every k step jumps
//! a full matrix row, and the tile walk re-derives slice bounds per
//! access. The hardware analogue keeps weights resident next to the MAC
//! array in exactly the order the array consumes them; this module is the
//! software equivalent: reorder B's mantissas **once** into k-tile-major,
//! register-block-width panels, then let every training step's GEMM
//! stream them contiguously.
//!
//! Layout (matching the matmul loop order `jt` outer, `kt` inner), for a
//! panel width `nr`:
//!
//! ```text
//! for each j-tile jt, k-tile kt:          # one shared exponent pair
//!   for each panel p (nr columns wide):   # one accumulator block
//!     for dk in 0..tk:                    # contraction, contiguous
//!       nr mantissas of row k0+dk, cols c0..c0+nr   (zero-padded)
//! ```
//!
//! The panel width is the **register-block width of the kernel family
//! that streams it** ([`crate::bfp::kernels::Isa::panel_nr`]): 8 for the
//! scalar reference ([`PANEL_NR`]), 16 for 128-bit units (SSE4.1/NEON),
//! 32 for AVX2 — chosen at pack time and recorded in
//! [`PackedPanels::nr`], never larger than [`MAX_PANEL_NR`]. Tiles and
//! panels are padded to uniform size (`tk` x `panels_per_tile * nr`) so
//! offsets are pure arithmetic; padding is zero mantissas, which
//! contribute nothing to any integer partial, so the packed kernel is
//! bit-identical to the row-major walk at **any** panel width. The width
//! class of the source storage (`i8`/`i16`/`i32`) is preserved — packing
//! never widens the bytes the MAC loop streams.

use super::tensor::{BfpTensor, MantissaElem, Mantissas, TileSize};

/// Scalar panel width: columns per accumulator block of the scalar
/// reference microkernel (the pre-SIMD layout, and the `HBFP_SIMD=off`
/// layout). Vector families pack wider — see
/// [`crate::bfp::kernels::Isa::panel_nr`].
pub const PANEL_NR: usize = 8;

/// Upper bound on any family's panel width: the microkernel's
/// accumulator block is a fixed `[acc; MAX_PANEL_NR]` array sliced to
/// the actual width.
pub const MAX_PANEL_NR: usize = 32;

/// Tile edge the matmul's band/tile loops use when this tensor is the B
/// operand (`TileSize::Whole` ⇒ one tile spanning the contraction dim).
pub fn matmul_tile_edge(tile: TileSize, k: usize) -> usize {
    match tile {
        TileSize::Whole => k.max(1),
        TileSize::Edge(t) => t,
    }
}

/// B mantissas reordered into k-tile-major, `nr`-wide panels. Built once
/// per (tensor, panel width) — cached on [`BfpTensor`] — and reused by
/// every matmul that streams the tensor as its resident operand.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedPanels {
    /// Matmul tile edge the layout was built for.
    pub t: usize,
    /// Columns per panel (the packing family's register-block width).
    pub nr: usize,
    /// Padded k-extent of every k-tile (`min(t, k)`).
    pub tk: usize,
    /// Panels per j-tile (`ceil(min(t, n) / nr)`, uniform via padding).
    pub panels_per_tile: usize,
    /// K-tiles (`ceil(k / t)`).
    pub tiles_k: usize,
    /// J-tiles (`ceil(n / t)`).
    pub tiles_j: usize,
    /// Source dims (B is k x n).
    pub k: usize,
    pub n: usize,
    /// Reordered mantissas, same width class as the source tensor.
    pub data: Mantissas,
}

impl PackedPanels {
    /// Elements spanned by one (jt, kt) tile in `data`.
    #[inline]
    pub fn tile_stride(&self) -> usize {
        self.tk * self.panels_per_tile * self.nr
    }

    /// Start of tile (jt, kt) in `data`.
    #[inline]
    pub fn tile_base(&self, jt: usize, kt: usize) -> usize {
        (jt * self.tiles_k + kt) * self.tile_stride()
    }

    /// Actual heap bytes of the packed buffer (padding included).
    pub fn heap_bytes(&self) -> usize {
        self.data.heap_bytes()
    }
}

/// Reorder `b`'s mantissas for matmul tile edge `t` at panel width `nr`
/// (a kernel family's register-block width, `<=` [`MAX_PANEL_NR`]).
/// Requires a non-empty tensor (the matmuls early-return before packing
/// empty operands).
pub fn pack_panels(b: &BfpTensor, t: usize, nr: usize) -> PackedPanels {
    let (k, n) = (b.rows, b.cols);
    debug_assert!(k > 0 && n > 0 && t > 0, "pack_panels on degenerate operand {k}x{n} t={t}");
    debug_assert!(
        nr > 0 && nr <= MAX_PANEL_NR,
        "panel width {nr} outside (0, {MAX_PANEL_NR}]"
    );
    let tk = t.min(k).max(1);
    let panels_per_tile = t.min(n).max(1).div_ceil(nr);
    let tiles_k = k.div_ceil(t).max(1);
    let tiles_j = n.div_ceil(t).max(1);
    let total = tiles_j * tiles_k * tk * panels_per_tile * nr;
    let mut data = match &b.mantissas {
        Mantissas::I8(_) => Mantissas::I8(vec![0; total]),
        Mantissas::I16(_) => Mantissas::I16(vec![0; total]),
        Mantissas::I32(_) => Mantissas::I32(vec![0; total]),
    };
    let geom = Geom { t, nr, tk, panels_per_tile, tiles_k, tiles_j, k, n };
    match (&b.mantissas, &mut data) {
        (Mantissas::I8(src), Mantissas::I8(dst)) => fill_panels(src, dst, &geom),
        (Mantissas::I16(src), Mantissas::I16(dst)) => fill_panels(src, dst, &geom),
        (Mantissas::I32(src), Mantissas::I32(dst)) => fill_panels(src, dst, &geom),
        _ => unreachable!("packed storage class always matches the source class"),
    }
    PackedPanels { t, nr, tk, panels_per_tile, tiles_k, tiles_j, k, n, data }
}

struct Geom {
    t: usize,
    nr: usize,
    tk: usize,
    panels_per_tile: usize,
    tiles_k: usize,
    tiles_j: usize,
    k: usize,
    n: usize,
}

fn fill_panels<E: MantissaElem>(src: &[E], dst: &mut [E], g: &Geom) {
    let tile_stride = g.tk * g.panels_per_tile * g.nr;
    for jt in 0..g.tiles_j {
        let j0 = jt * g.t;
        let j1 = (j0 + g.t).min(g.n);
        for kt in 0..g.tiles_k {
            let k0 = kt * g.t;
            let k1 = (k0 + g.t).min(g.k);
            let tile_base = (jt * g.tiles_k + kt) * tile_stride;
            for p in 0..g.panels_per_tile {
                let c0 = j0 + p * g.nr;
                if c0 >= j1 {
                    break; // trailing padded panels of a ragged j-tile stay zero
                }
                let c1 = (c0 + g.nr).min(j1);
                let panel_base = tile_base + p * g.tk * g.nr;
                for dk in 0..k1 - k0 {
                    let srow = &src[(k0 + dk) * g.n + c0..(k0 + dk) * g.n + c1];
                    let drow = &mut dst[panel_base + dk * g.nr..panel_base + dk * g.nr + (c1 - c0)];
                    drow.copy_from_slice(srow);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tensor whose mantissa at (r, c) is `r * cols + c` (mod the 8-bit
    /// range), so packed positions are checkable by value.
    fn indexed_tensor(rows: usize, cols: usize, tile: TileSize) -> BfpTensor {
        let mut m = Mantissas::for_width(8, rows * cols);
        for i in 0..rows * cols {
            m.set(i, (i % 127) as i32);
        }
        let (th, tw) = tile.edge_or(rows, cols);
        let exps = vec![0i32; rows.div_ceil(th).max(1) * cols.div_ceil(tw).max(1)];
        BfpTensor::from_parts(rows, cols, 8, tile, m, exps).unwrap()
    }

    #[test]
    fn every_element_lands_at_its_panel_slot() {
        for &nr in &[PANEL_NR, 16, MAX_PANEL_NR] {
            for &(k, n, t) in &[(10usize, 13usize, 4usize), (24, 24, 8), (7, 30, 24), (16, 5, 8)] {
                let b = indexed_tensor(k, n, TileSize::Edge(t));
                let pp = pack_panels(&b, matmul_tile_edge(b.tile, k), nr);
                assert_eq!(pp.nr, nr);
                for kk in 0..k {
                    for j in 0..n {
                        let jt = j / t;
                        let kt = kk / t;
                        let jin = j - jt * t; // column within the j-tile
                        let p = jin / nr;
                        let c = jin % nr;
                        let dk = kk - kt * t;
                        let idx = pp.tile_base(jt, kt) + p * pp.tk * nr + dk * nr + c;
                        assert_eq!(
                            pp.data.get(idx),
                            b.mantissa_at(kk, j),
                            "({kk},{j}) misplaced at k={k} n={n} t={t} nr={nr}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn padding_is_zero() {
        // ragged j-tile: n=13, t=8 -> second j-tile is 5 wide, its first
        // panel has 3 padded columns and its second panel is all padding
        let b = indexed_tensor(8, 13, TileSize::Edge(8));
        let pp = pack_panels(&b, 8, PANEL_NR);
        assert_eq!(pp.panels_per_tile, 1); // min(t, n) = 8 -> 1 panel per tile
        let b2 = indexed_tensor(8, 13, TileSize::Edge(16));
        let pp2 = pack_panels(&b2, 16, PANEL_NR);
        assert_eq!(pp2.panels_per_tile, 2);
        // columns 13..16 of the single j-tile are padding
        let base = pp2.tile_base(0, 0) + pp2.tk * PANEL_NR; // second panel (cols 8..16)
        for dk in 0..8 {
            for c in 5..8 {
                assert_eq!(pp2.data.get(base + dk * PANEL_NR + c), 0, "padding at ({dk},{c})");
            }
        }
        // at a 32-wide vector panel the same tile is one panel with 19
        // padded trailing columns
        let pp3 = pack_panels(&b2, 16, 32);
        assert_eq!(pp3.panels_per_tile, 1);
        let base3 = pp3.tile_base(0, 0);
        for dk in 0..8 {
            for c in 13..32 {
                assert_eq!(pp3.data.get(base3 + dk * 32 + c), 0, "vector padding at ({dk},{c})");
            }
        }
    }

    #[test]
    fn width_class_preserved() {
        for bits in [8u32, 12, 20] {
            let data: Vec<f32> = (0..64).map(|i| (i as f32 - 31.5) / 4.0).collect();
            let b = BfpTensor::from_f32(
                &data,
                8,
                8,
                bits,
                TileSize::Edge(4),
                &mut super::super::quant::Rounding::NearestEven,
            )
            .unwrap();
            for nr in [PANEL_NR, 16] {
                let pp = pack_panels(&b, 4, nr);
                assert_eq!(
                    pp.data.elem_bits(),
                    b.mantissas.elem_bits(),
                    "packing must not change the streamed width class (bits={bits}, nr={nr})"
                );
            }
        }
    }

    #[test]
    fn whole_tile_single_tile_geometry() {
        let b = indexed_tensor(6, 20, TileSize::Whole);
        let pp = pack_panels(&b, matmul_tile_edge(b.tile, 6), PANEL_NR);
        assert_eq!((pp.tiles_k, pp.tiles_j), (1, 4)); // t = k = 6; ceil(20/6) = 4
        assert_eq!(pp.tk, 6);
    }
}
