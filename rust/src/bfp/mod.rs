//! Software BFP arithmetic library — the rust-side implementation of the
//! paper's numeric format (§4), used by the accelerator model, the
//! benchmark harnesses, and as the cross-language contract with the
//! python oracle (fixtures in `tests/bfp_cross.rs`).
//!
//! ## The context/plan execution model
//!
//! All execution goes through two types ([`context`]):
//!
//! - [`BfpContext`] — every piece of execution policy (worker-thread
//!   budget, dispatch backend, SIMD kernel family, matmul kernel layout,
//!   exponent-tile size, accumulator policy, default rounding) resolved
//!   **once** from the environment (`HBFP_THREADS`, `HBFP_SIMD`) plus
//!   builder overrides. Subsystems hold one context instead of picking a
//!   `_with_*` variant per call.
//! - [`MatmulPlan`] — [`BfpContext::plan_matmul`] pre-resolves the
//!   per-shape decisions (tile edge, panel width, accumulator class,
//!   lane counts) so the hot loop does zero per-call policy work.
//!   `execute` / `execute_into` run C = A·B over BFP tensors;
//!   `quantize_execute{,_into}` fuse the A-side FP→BFP conversion into
//!   the band loop (activations streaming against resident weights).
//!
//! ```no_run
//! use hbfp::bfp::{BfpContext, Rounding, TileSize};
//!
//! let ctx = BfpContext::from_env().with_tile(TileSize::Edge(24));
//! let w = ctx.quantize(&vec![0.5; 256 * 256], 256, 256, 8,
//!                      &mut Rounding::NearestEven)?;
//! // per layer, once:
//! let plan = ctx.plan_matmul(8, 256, 256, (8, 8))?;
//! // per step, zero policy work, reusable output buffer:
//! let mut out = vec![0.0; plan.out_len()];
//! plan.quantize_execute_into(&vec![0.1; 8 * 256], &mut Rounding::NearestEven,
//!                            &w, &mut out)?;
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! Every policy knob moves speed, never bits: each configuration is
//! bit-identical to [`bfp_matmul_naive`] (enforced by
//! `tests/context_api.rs`). The pre-context free functions
//! (`bfp_matmul`, `quantize_matmul`, the `_with_threads/_with_simd/...`
//! variants) survive only as `#[deprecated]` shims in [`matmul`] /
//! [`tensor`], importable from their defining modules; see PERF.md for
//! the old-call → new-call migration table.
//!
//! ## Layers
//!
//! - [`context`]: the execution-context API described above.
//! - [`quant`]: shared-exponent selection, RNE + stochastic rounding
//!   (Xorshift32, §5.3), value-level quantize/dequantize, per-tile
//!   substream derivation for the parallel converters.
//! - [`kernels`]: the runtime-dispatched SIMD kernel family (scalar /
//!   SSE4.1 / AVX2 / NEON, `HBFP_SIMD` override) behind the panel MACs
//!   and the FP→BFP converter — every family bit-identical to scalar.
//! - [`tensor`]: tiled BFP tensor storage with width-packed mantissas
//!   (`i8`/`i16`/`i32` by mantissa class), wide weight storage (§4.2),
//!   and the cached packed-panel weight layout.
//! - [`panels`]: the once-per-tensor B-operand relayout (k-tile-major,
//!   panels at the kernel family's register width) the GEMM microkernel
//!   streams.
//! - [`matmul`]: the packed, pool-parallel integer-MAC kernel bodies with
//!   FP32 tile accumulation (Eq. 2) the plans drive, the accumulator
//!   overflow bound, and the naive/FP32 references.

pub mod context;
pub mod kernels;
pub mod matmul;
pub mod panels;
pub mod quant;
pub mod stats;
pub mod tensor;

pub use context::{
    AccPolicy, BfpContext, GuardAction, GuardEvent, GuardOutcome, GuardPolicy, InputScan,
    MatmulKernel, MatmulPlan, NumericGuardError, PlanCache, PlanKey, RoundingPolicy,
};
pub use kernels::Isa;
pub use matmul::{acc_fits_i32, bfp_matmul_naive, fp32_matmul, max_tile_partial};
pub use panels::{pack_panels, PackedPanels, MAX_PANEL_NR, PANEL_NR};
pub use quant::{
    block_exponent, dequantize_value, exp2i, quantize_value, Rounding, TileRounding, E_MAX, E_MIN,
};
pub use stats::{
    clamp_rail_frac, export_datapath_counters, quant_report, saturated_tile_frac, scan_nonfinite,
    tile_spans, ExponentStats, GuardStats, GuardStatsSnapshot, NonFiniteError, QuantReport,
    ScanReport,
};
pub use tensor::{
    next_wider_class, quantize_inplace_2d, BfpTensor, MantissaElem, Mantissas, TileSize,
};
