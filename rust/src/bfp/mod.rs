//! Software BFP arithmetic library — the rust-side implementation of the
//! paper's numeric format (§4), used by the accelerator model, the
//! benchmark harnesses, and as the cross-language contract with the
//! python oracle (fixtures in `tests/bfp_cross.rs`).
//!
//! - [`quant`]: shared-exponent selection, RNE + stochastic rounding
//!   (Xorshift32, §5.3), value-level quantize/dequantize, per-tile
//!   substream derivation for the parallel converters.
//! - [`kernels`]: the runtime-dispatched SIMD kernel family (scalar /
//!   SSE4.1 / AVX2 / NEON, `HBFP_SIMD` override) behind the panel MACs
//!   and the FP→BFP converter — every family bit-identical to scalar.
//! - [`tensor`]: tiled BFP tensor storage with width-packed mantissas
//!   (`i8`/`i16`/`i32` by mantissa class), wide weight storage (§4.2),
//!   and the cached packed-panel weight layout.
//! - [`panels`]: the once-per-tensor B-operand relayout (k-tile-major,
//!   panels at the kernel family's register width) the GEMM microkernel
//!   streams.
//! - [`matmul`]: packed, pool-parallel integer-MAC matmul with FP32 tile
//!   accumulation (Eq. 2), accumulator width chosen by a proven overflow
//!   bound, a register-blocked packed-panel microkernel, plus the fused
//!   FP→BFP-convert + matmul hot path.

pub mod kernels;
pub mod matmul;
pub mod panels;
pub mod quant;
pub mod stats;
pub mod tensor;

pub use kernels::Isa;
pub use matmul::{
    acc_fits_i32, bfp_matmul, bfp_matmul_naive, bfp_matmul_rowmajor,
    bfp_matmul_rowmajor_with_threads, bfp_matmul_with_backend, bfp_matmul_with_simd,
    bfp_matmul_with_threads, fp32_matmul, hbfp_matmul_f32, max_tile_partial, quantize_matmul,
    quantize_matmul_with_threads,
};
pub use panels::{pack_panels, PackedPanels, MAX_PANEL_NR, PANEL_NR};
pub use quant::{
    block_exponent, dequantize_value, exp2i, quantize_value, Rounding, TileRounding, E_MAX, E_MIN,
};
pub use stats::{quant_report, tile_spans, ExponentStats, QuantReport};
pub use tensor::{quantize_inplace_2d, BfpTensor, MantissaElem, Mantissas, TileSize};
