//! Software BFP arithmetic library — the rust-side implementation of the
//! paper's numeric format (§4), used by the accelerator model, the
//! benchmark harnesses, and as the cross-language contract with the
//! python oracle (fixtures in `tests/bfp_cross.rs`).
//!
//! - [`quant`]: shared-exponent selection, RNE + stochastic rounding
//!   (Xorshift32, §5.3), value-level quantize/dequantize.
//! - [`tensor`]: tiled BFP tensor storage, wide weight storage (§4.2).
//! - [`matmul`]: integer-MAC matmul with FP32 tile accumulation (Eq. 2).

pub mod matmul;
pub mod quant;
pub mod stats;
pub mod tensor;

pub use matmul::{bfp_matmul, bfp_matmul_naive, fp32_matmul, hbfp_matmul_f32};
pub use quant::{block_exponent, dequantize_value, exp2i, quantize_value, Rounding, E_MAX, E_MIN};
pub use stats::{quant_report, tile_spans, ExponentStats, QuantReport};
pub use tensor::{BfpTensor, TileSize};
