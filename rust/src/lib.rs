//! # HBFP — Training DNNs with Hybrid Block Floating Point
//!
//! Full-stack reproduction of Drumond et al., NIPS 2018: all dot products
//! in block floating point (shared-exponent fixed-point mantissas), all
//! other ops in FP32.
//!
//! Three layers (DESIGN.md):
//!
//! - **L1** (`python/compile/kernels/`): Pallas BFP matmul/quantize kernels.
//! - **L2** (`python/compile/`): JAX models + HBFP training step, AOT-lowered
//!   to HLO text under `artifacts/`.
//! - **L3** (this crate): the training framework — data pipeline, trainer,
//!   experiment harnesses — plus the paper's substrates: a software BFP
//!   arithmetic library (`bfp`), the Figure-2 accelerator area/throughput
//!   model (`accel`, `hw`), the PJRT runtime (`runtime`), and a native
//!   forward/backward training subsystem (`nn`) that runs the paper's
//!   hybrid split end to end in pure rust — every GEMM through BFP
//!   plans, everything else FP32 — with no Python or artifacts needed.
//!
//! Python never runs at training time; the `hbfp` binary is self-contained
//! once `make artifacts` has produced the HLO modules, and the `nn`
//! training path needs no artifacts at all.
//!
//! The workspace builds offline: `rust/vendor/xla` is an API-compatible
//! stand-in for the PJRT binding (artifact execution reports itself
//! unavailable until the real binding is swapped in via Cargo.toml), and
//! the BFP substrate (`bfp`) — packed mantissa storage, parallel
//! converters, the fused integer-MAC matmul — is pure rust with no
//! external runtime (see PERF.md).

pub mod accel;
pub mod bfp;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod nn;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod util;
