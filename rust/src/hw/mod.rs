//! Arithmetic-unit cost tables and scaling models.
//!
//! The paper's density argument rests on published unit costs (its ref [3],
//! Dally's NIPS'15 tutorial, 45nm): an 8-bit fixed-point multiplier is
//! 5.8x smaller and 5.5x less energy than FP16; FP32 is 4.7x larger than
//! FP16. This module encodes those exact numbers plus standard asymptotic
//! scaling (multiplier area/energy quadratic in width, adder linear) so the
//! accelerator model can price arbitrary mantissa widths.
//!
//! All areas in um^2 (45nm), energies in pJ.

/// Cost of one arithmetic unit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UnitCost {
    pub area_um2: f64,
    pub energy_pj: f64,
}

/// Anchor points from Dally NIPS'15 (45nm). These reproduce the ratios the
/// paper quotes: fp16_mult/int8_mult area = 5.8x, energy = 5.5x;
/// fp32_mult/fp16_mult area = 4.7x.
pub mod anchors {
    use super::UnitCost;

    pub const INT8_ADD: UnitCost = UnitCost { area_um2: 36.0, energy_pj: 0.03 };
    pub const INT16_ADD: UnitCost = UnitCost { area_um2: 67.0, energy_pj: 0.05 };
    pub const INT32_ADD: UnitCost = UnitCost { area_um2: 137.0, energy_pj: 0.1 };
    pub const FP16_ADD: UnitCost = UnitCost { area_um2: 1360.0, energy_pj: 0.4 };
    pub const FP32_ADD: UnitCost = UnitCost { area_um2: 4184.0, energy_pj: 0.9 };

    pub const INT8_MULT: UnitCost = UnitCost { area_um2: 282.0, energy_pj: 0.2 };
    pub const INT32_MULT: UnitCost = UnitCost { area_um2: 3495.0, energy_pj: 3.1 };
    pub const FP16_MULT: UnitCost = UnitCost { area_um2: 1640.0, energy_pj: 1.1 };
    pub const FP32_MULT: UnitCost = UnitCost { area_um2: 7700.0, energy_pj: 3.7 };
}

/// Fixed-point multiplier cost at arbitrary width: quadratic scaling
/// anchored at the published 8-bit point (array multipliers are O(m^2) in
/// both area and switched capacitance).
pub fn int_mult(bits: u32) -> UnitCost {
    let s = (bits as f64 / 8.0).powi(2);
    UnitCost {
        area_um2: anchors::INT8_MULT.area_um2 * s,
        energy_pj: anchors::INT8_MULT.energy_pj * s,
    }
}

/// Fixed-point adder cost: linear scaling anchored at the 32-bit point.
pub fn int_add(bits: u32) -> UnitCost {
    let s = bits as f64 / 32.0;
    UnitCost {
        area_um2: anchors::INT32_ADD.area_um2 * s,
        energy_pj: anchors::INT32_ADD.energy_pj * s,
    }
}

/// Floating-point multiplier with `m` significand bits (incl. implicit bit)
/// and `e` exponent bits: significand multiplier (quadratic) + exponent
/// adder (linear) + normalization overhead, calibrated so (11,5) = FP16 and
/// (24,8) = FP32 anchors hold to within a few percent.
pub fn fp_mult(m: u32, e: u32) -> UnitCost {
    // FP16 mult = 1640 at (11,5): significand part ~ int11 mult =
    // 282*(11/8)^2 = 533, leaving 1107 of normalization/rounding/exponent
    // logic at w = m+e = 16; fitting the FP32 anchor (7700 at w = 32) gives
    // that overhead a w^2.22 growth (shifters + rounding are superlinear).
    let w = (m + e) as f64;
    let sig = anchors::INT8_MULT.area_um2 * (m as f64 / 8.0).powi(2);
    let norm = 1107.0 * (w / 16.0).powf(2.22);
    let area = sig + norm;
    // energy: same decomposition, anchored at fp16 = 1.1 pJ, fp32 = 3.7 pJ
    let sig_e = anchors::INT8_MULT.energy_pj * (m as f64 / 8.0).powi(2);
    let norm_e = 0.722 * (w / 16.0).powf(1.4);
    UnitCost { area_um2: area, energy_pj: sig_e + norm_e }
}

/// Floating-point adder: dominated by alignment/normalization shifters,
/// ~linear in significand width; calibrated at the FP16/FP32 anchors.
pub fn fp_add(m: u32, e: u32) -> UnitCost {
    let w = (m + e) as f64;
    // fp16: w=16 -> 1360, fp32: w=32 -> 4184. Fit a*w^1.62.
    let area = 1360.0 * (w / 16.0).powf(1.62);
    let energy = 0.4 * (w / 16.0).powf(1.17);
    UnitCost { area_um2: area, energy_pj: energy }
}

/// One BFP MAC lane: int multiplier at the mantissa width + a fixed-point
/// accumulator wide enough for 2m + log2(N) bits of dot-product growth.
pub fn bfp_mac(mantissa_bits: u32, acc_bits: u32) -> UnitCost {
    let m = int_mult(mantissa_bits);
    let a = int_add(acc_bits);
    UnitCost { area_um2: m.area_um2 + a.area_um2, energy_pj: m.energy_pj + a.energy_pj }
}

/// One FP MAC lane (the paper's FP16 comparison point accumulates in FP16
/// on the FPGA variant; pass (11,5) twice for that, or an FP32 adder for a
/// mixed-precision tensor-core-style unit).
pub fn fp_mac(mult_m: u32, mult_e: u32, add_m: u32, add_e: u32) -> UnitCost {
    let m = fp_mult(mult_m, mult_e);
    let a = fp_add(add_m, add_e);
    UnitCost { area_um2: m.area_um2 + a.area_um2, energy_pj: m.energy_pj + a.energy_pj }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ratio_fp16_over_int8_mult() {
        // "8-bit fixed-point multipliers occupy 5.8x less area and consume
        // 5.5x less energy than their FP16 counterpart"
        let area_ratio = anchors::FP16_MULT.area_um2 / anchors::INT8_MULT.area_um2;
        let energy_ratio = anchors::FP16_MULT.energy_pj / anchors::INT8_MULT.energy_pj;
        assert!((area_ratio - 5.8).abs() < 0.05, "area ratio {area_ratio}");
        assert!((energy_ratio - 5.5).abs() < 0.05, "energy ratio {energy_ratio}");
    }

    #[test]
    fn paper_ratio_fp32_over_fp16_mult() {
        // "the area of an FP16 multiplier is 4.7x smaller than ... FP32"
        let r = anchors::FP32_MULT.area_um2 / anchors::FP16_MULT.area_um2;
        assert!((r - 4.7).abs() < 0.05, "ratio {r}");
    }

    #[test]
    fn fp_mult_model_hits_anchors() {
        let fp16 = fp_mult(11, 5);
        let fp32 = fp_mult(24, 8);
        assert!(
            (fp16.area_um2 - anchors::FP16_MULT.area_um2).abs() / anchors::FP16_MULT.area_um2 < 0.05,
            "fp16 model {} vs anchor {}",
            fp16.area_um2,
            anchors::FP16_MULT.area_um2
        );
        assert!(
            (fp32.area_um2 - anchors::FP32_MULT.area_um2).abs() / anchors::FP32_MULT.area_um2 < 0.1,
            "fp32 model {} vs anchor {}",
            fp32.area_um2,
            anchors::FP32_MULT.area_um2
        );
    }

    #[test]
    fn fp_add_model_hits_anchors() {
        let fp16 = fp_add(11, 5);
        let fp32 = fp_add(24, 8);
        assert!((fp16.area_um2 - 1360.0).abs() < 1.0);
        assert!((fp32.area_um2 - 4184.0).abs() / 4184.0 < 0.02, "{}", fp32.area_um2);
    }

    #[test]
    fn int_scaling_monotone() {
        assert!(int_mult(12).area_um2 > int_mult(8).area_um2);
        assert!(int_mult(16).area_um2 > int_mult(12).area_um2);
        assert!(int_add(24).area_um2 > int_add(16).area_um2);
        // quadratic: 16-bit mult = 4x the 8-bit one
        assert!((int_mult(16).area_um2 / int_mult(8).area_um2 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn bfp_mac_vs_fp16_mac_density() {
        // The core density claim: a BFP8 MAC (int8 mult + 24-bit acc) is
        // several times smaller than an FP16 MAC.
        let bfp = bfp_mac(8, 24);
        let fp16 = fp_mac(11, 5, 11, 5);
        let ratio = fp16.area_um2 / bfp.area_um2;
        assert!(ratio > 5.0, "ratio {ratio} too small");
    }
}
