//! Synthetic character-level corpus (PTB stand-in): an order-2 Markov
//! chain with a sparse, peaked transition structure, so an LSTM that
//! learns the bigram context achieves substantially lower perplexity than
//! any unigram model — giving the quantized-vs-fp32 comparison (Table 3)
//! real headroom.
//!
//! Deterministic in (vocab, seed).

use crate::runtime::HostTensor;
use crate::util::rng::SplitMix64;

pub struct TextDataset {
    pub vocab: usize,
    pub seq: usize,
    pub train: Vec<i32>,
    pub val: Vec<i32>,
    /// The chain's true conditional entropy in nats (the perplexity floor
    /// exp(H) a perfect model would reach) — reported by the harness so
    /// results are interpretable.
    pub entropy_nats: f64,
}

impl TextDataset {
    pub fn generate(vocab: usize, seq: usize, seed: u64, train_len: usize, val_len: usize) -> Self {
        let mut rng = SplitMix64::new(seed ^ 0x7e97);
        // Transition logits: sparse + peaked. Each (a, b) context prefers
        // ~4 successors strongly.
        let v2 = vocab * vocab;
        let mut probs = vec![0.0f64; v2 * vocab];
        for ctx in 0..v2 {
            let row = &mut probs[ctx * vocab..(ctx + 1) * vocab];
            for p in row.iter_mut() {
                *p = 0.05; // smoothing floor
            }
            for _ in 0..4 {
                row[rng.below(vocab)] += rng.range_f32(1.0, 6.0) as f64;
            }
            let sum: f64 = row.iter().sum();
            for p in row.iter_mut() {
                *p /= sum;
            }
        }
        // True conditional entropy under the stationary-ish distribution:
        // estimate by averaging over contexts (uniform context weights are
        // fine for reporting purposes).
        let entropy_nats = (0..v2)
            .map(|ctx| {
                probs[ctx * vocab..(ctx + 1) * vocab]
                    .iter()
                    .map(|&p| if p > 0.0 { -p * p.ln() } else { 0.0 })
                    .sum::<f64>()
            })
            .sum::<f64>()
            / v2 as f64;

        let sample_stream = |len: usize, r: &mut SplitMix64| -> Vec<i32> {
            let mut out = Vec::with_capacity(len);
            let (mut a, mut b) = (r.below(vocab), r.below(vocab));
            out.push(a as i32);
            out.push(b as i32);
            while out.len() < len {
                let row = &probs[(a * vocab + b) * vocab..(a * vocab + b + 1) * vocab];
                let u = r.next_f32() as f64;
                let mut acc = 0.0;
                let mut next = vocab - 1;
                for (i, &p) in row.iter().enumerate() {
                    acc += p;
                    if u < acc {
                        next = i;
                        break;
                    }
                }
                out.push(next as i32);
                a = b;
                b = next;
            }
            out
        };
        let mut r1 = SplitMix64::new(seed.wrapping_add(1));
        let mut r2 = SplitMix64::new(seed.wrapping_add(2));
        TextDataset {
            vocab,
            seq,
            train: sample_stream(train_len, &mut r1),
            val: sample_stream(val_len, &mut r2),
            entropy_nats,
        }
    }

    /// Random training windows: x = stream[i..i+T], y = stream[i+1..i+T+1].
    pub fn train_batch(&self, batch: usize, rng: &mut SplitMix64) -> (HostTensor, HostTensor) {
        let t = self.seq;
        let mut xs = Vec::with_capacity(batch * t);
        let mut ys = Vec::with_capacity(batch * t);
        let max_start = self.train.len() - t - 1;
        for _ in 0..batch {
            let i = rng.below(max_start);
            xs.extend_from_slice(&self.train[i..i + t]);
            ys.extend_from_slice(&self.train[i + 1..i + t + 1]);
        }
        (HostTensor::I32(xs, vec![batch, t]), HostTensor::I32(ys, vec![batch, t]))
    }

    /// Sequential validation windows (deterministic, non-overlapping).
    pub fn val_batches(&self, batch: usize) -> Vec<(HostTensor, HostTensor)> {
        let t = self.seq;
        let windows = (self.val.len() - 1) / t;
        let n_batches = windows / batch;
        (0..n_batches)
            .map(|b| {
                let mut xs = Vec::with_capacity(batch * t);
                let mut ys = Vec::with_capacity(batch * t);
                for w in 0..batch {
                    let i = (b * batch + w) * t;
                    xs.extend_from_slice(&self.val[i..i + t]);
                    ys.extend_from_slice(&self.val[i + 1..i + t + 1]);
                }
                (HostTensor::I32(xs, vec![batch, t]), HostTensor::I32(ys, vec![batch, t]))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> TextDataset {
        TextDataset::generate(16, 12, 3, 4000, 1000)
    }

    #[test]
    fn deterministic_and_in_vocab() {
        let a = tiny();
        let b = tiny();
        assert_eq!(a.train, b.train);
        assert!(a.train.iter().all(|&c| (0..16).contains(&c)));
        assert_eq!(a.train.len(), 4000);
    }

    #[test]
    fn entropy_below_uniform() {
        let d = tiny();
        // peaked transitions => entropy well below ln(16)
        assert!(d.entropy_nats < (16f64).ln() * 0.9, "H = {}", d.entropy_nats);
        assert!(d.entropy_nats > 0.3, "H = {}", d.entropy_nats);
    }

    #[test]
    fn batch_targets_are_shifted_inputs() {
        let d = tiny();
        let (x, y) = d.train_batch(4, &mut SplitMix64::new(0));
        let (xv, yv) = match (&x, &y) {
            (HostTensor::I32(a, _), HostTensor::I32(b, _)) => (a, b),
            _ => panic!("wrong dtype"),
        };
        // y[t] should equal x[t+1] within each window
        for w in 0..4 {
            for t in 0..11 {
                assert_eq!(yv[w * 12 + t], xv[w * 12 + t + 1]);
            }
        }
    }

    #[test]
    fn val_batches_nonoverlapping() {
        let d = tiny();
        let vb = d.val_batches(4);
        assert!(!vb.is_empty());
        for (x, _) in &vb {
            assert_eq!(x.shape(), &[4, 12]);
        }
    }

    #[test]
    fn bigram_structure_learnable() {
        // Empirical check: the chain's next-char distribution given context
        // is far from uniform (max prob > 2/vocab on average).
        let d = tiny();
        let v = d.vocab;
        let mut counts = vec![0u32; v * v * v];
        let s = &d.train;
        for w in s.windows(3) {
            counts[(w[0] as usize * v + w[1] as usize) * v + w[2] as usize] += 1;
        }
        let mut peaked = 0;
        let mut contexts = 0;
        for ctx in 0..v * v {
            let row = &counts[ctx * v..(ctx + 1) * v];
            let total: u32 = row.iter().sum();
            if total >= 10 {
                contexts += 1;
                let max = *row.iter().max().unwrap();
                if max as f64 / total as f64 > 2.0 / v as f64 {
                    peaked += 1;
                }
            }
        }
        assert!(contexts > 0 && peaked * 10 >= contexts * 9, "{peaked}/{contexts}");
    }
}
