//! Synthetic image classification datasets (CIFAR/SVHN/ImageNet stand-ins).
//!
//! The paper's claims are numeric-format properties measured *relative to an
//! FP32 baseline on the same task*; what the task must provide is (a) a
//! learnable signal through conv stacks, (b) a real generalization gap so
//! "validation error" is meaningful, and (c) enough per-class variation
//! that gradient scales span multiple binades (exercising exponent
//! selection). See DESIGN.md §5.
//!
//! Each class gets a smooth template (sum of random 2-D sinusoids per
//! channel). A sample is `contrast * shift(template) + noise`, where the
//! nuisances (contrast scaling across one binade, ±2px cyclic shifts,
//! horizontal flips, heavy Gaussian noise) create the train/val gap.
//! Generation is deterministic in (dataset dims, seed).

use crate::runtime::HostTensor;
use crate::util::rng::SplitMix64;

/// In-memory synthetic dataset, already split train/val.
pub struct ImageDataset {
    pub hw: usize,
    pub channels: usize,
    pub classes: usize,
    pub train_x: Vec<f32>,
    pub train_y: Vec<i32>,
    pub val_x: Vec<f32>,
    pub val_y: Vec<i32>,
}

/// Generation knobs; defaults tuned so resnet_mini/fp32 lands at a few
/// percent validation error after a few hundred steps (a regime where
/// format-induced degradation is visible but convergence is attainable).
#[derive(Debug, Clone, Copy)]
pub struct ImageGenConfig {
    pub n_train: usize,
    pub n_val: usize,
    pub signal: f32,
    pub noise: f32,
    pub waves: usize,
}

impl Default for ImageGenConfig {
    fn default() -> Self {
        Self { n_train: 4096, n_val: 1024, signal: 0.6, noise: 1.0, waves: 4 }
    }
}

impl ImageDataset {
    pub fn generate(
        hw: usize,
        channels: usize,
        classes: usize,
        seed: u64,
        cfg: ImageGenConfig,
    ) -> ImageDataset {
        let mut rng = SplitMix64::new(seed ^ 0x1111_a9e5);
        let templates = make_templates(&mut rng, hw, channels, classes, cfg.waves);
        let gen_split = |n: usize, stream: u64| {
            let mut r = SplitMix64::new(seed.wrapping_add(stream));
            let mut xs = Vec::with_capacity(n * hw * hw * channels);
            let mut ys = Vec::with_capacity(n);
            for _ in 0..n {
                let y = r.below(classes);
                ys.push(y as i32);
                sample_into(&mut xs, &templates[y], hw, channels, &mut r, &cfg);
            }
            (xs, ys)
        };
        let (train_x, train_y) = gen_split(cfg.n_train, 0x7121);
        let (val_x, val_y) = gen_split(cfg.n_val, 0x0a11);
        ImageDataset { hw, channels, classes, train_x, train_y, val_x, val_y }
    }

    pub fn sample_elems(&self) -> usize {
        self.hw * self.hw * self.channels
    }

    /// One training batch (with-replacement shuffled sampling + flips —
    /// the augmentation happens at batch assembly, like a real loader).
    pub fn train_batch(&self, batch: usize, rng: &mut SplitMix64) -> (HostTensor, HostTensor) {
        let k = self.sample_elems();
        let mut x = Vec::with_capacity(batch * k);
        let mut y = Vec::with_capacity(batch);
        let n = self.train_y.len();
        for _ in 0..batch {
            let i = rng.below(n);
            let src = &self.train_x[i * k..(i + 1) * k];
            if rng.next_u64() & 1 == 0 {
                x.extend_from_slice(src);
            } else {
                push_hflip(&mut x, src, self.hw, self.channels);
            }
            y.push(self.train_y[i]);
        }
        (
            HostTensor::F32(x, vec![batch, self.hw, self.hw, self.channels]),
            HostTensor::I32(y, vec![batch]),
        )
    }

    /// Deterministic validation batches (no augmentation, sequential).
    pub fn val_batches(&self, batch: usize) -> Vec<(HostTensor, HostTensor)> {
        let k = self.sample_elems();
        let n = self.val_y.len() / batch; // drop ragged tail
        (0..n)
            .map(|b| {
                let xs = self.val_x[b * batch * k..(b + 1) * batch * k].to_vec();
                let ys = self.val_y[b * batch..(b + 1) * batch].to_vec();
                (
                    HostTensor::F32(xs, vec![batch, self.hw, self.hw, self.channels]),
                    HostTensor::I32(ys, vec![batch]),
                )
            })
            .collect()
    }
}

fn make_templates(
    rng: &mut SplitMix64,
    hw: usize,
    channels: usize,
    classes: usize,
    waves: usize,
) -> Vec<Vec<f32>> {
    (0..classes)
        .map(|_| {
            let mut t = vec![0.0f32; hw * hw * channels];
            for ch in 0..channels {
                for _ in 0..waves {
                    let fx = rng.range_f32(0.5, 3.0);
                    let fy = rng.range_f32(0.5, 3.0);
                    let phase = rng.range_f32(0.0, std::f32::consts::TAU);
                    let amp = rng.range_f32(0.4, 1.0);
                    for r in 0..hw {
                        for c in 0..hw {
                            let v = amp
                                * (std::f32::consts::TAU * (fx * r as f32 + fy * c as f32)
                                    / hw as f32
                                    + phase)
                                    .sin();
                            t[(r * hw + c) * channels + ch] += v;
                        }
                    }
                }
            }
            t
        })
        .collect()
}

fn sample_into(
    out: &mut Vec<f32>,
    template: &[f32],
    hw: usize,
    channels: usize,
    rng: &mut SplitMix64,
    cfg: &ImageGenConfig,
) {
    // nuisances: contrast over one binade, cyclic shift, additive noise
    let contrast = cfg.signal * rng.range_f32(0.7, 1.4);
    let dr = rng.below(5) as isize - 2;
    let dc = rng.below(5) as isize - 2;
    for r in 0..hw as isize {
        for c in 0..hw as isize {
            let sr = (r + dr).rem_euclid(hw as isize) as usize;
            let sc = (c + dc).rem_euclid(hw as isize) as usize;
            for ch in 0..channels {
                let v = contrast * template[(sr * hw + sc) * channels + ch]
                    + cfg.noise * rng.normal();
                out.push(v);
            }
        }
    }
}

/// Random-crop augmentation: pad by `pad` (zeros) and crop back at a random
/// offset — the standard CIFAR recipe ([22, 23] in the paper). Appends the
/// cropped image to `out`.
pub fn push_random_crop(
    out: &mut Vec<f32>,
    src: &[f32],
    hw: usize,
    channels: usize,
    pad: usize,
    rng: &mut SplitMix64,
) {
    let dr = rng.below(2 * pad + 1) as isize - pad as isize;
    let dc = rng.below(2 * pad + 1) as isize - pad as isize;
    for r in 0..hw as isize {
        for c in 0..hw as isize {
            let (sr, sc) = (r + dr, c + dc);
            if sr < 0 || sc < 0 || sr >= hw as isize || sc >= hw as isize {
                out.extend(std::iter::repeat(0.0).take(channels));
            } else {
                let base = (sr as usize * hw + sc as usize) * channels;
                out.extend_from_slice(&src[base..base + channels]);
            }
        }
    }
}

/// Cutout augmentation: zero a random (sz x sz) square in place.
pub fn cutout_inplace(img: &mut [f32], hw: usize, channels: usize, sz: usize, rng: &mut SplitMix64) {
    if sz == 0 || sz > hw {
        return;
    }
    let r0 = rng.below(hw - sz + 1);
    let c0 = rng.below(hw - sz + 1);
    for r in r0..r0 + sz {
        for c in c0..c0 + sz {
            for ch in 0..channels {
                img[(r * hw + c) * channels + ch] = 0.0;
            }
        }
    }
}

fn push_hflip(out: &mut Vec<f32>, src: &[f32], hw: usize, channels: usize) {
    for r in 0..hw {
        for c in 0..hw {
            let sc = hw - 1 - c;
            let base = (r * hw + sc) * channels;
            out.extend_from_slice(&src[base..base + channels]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ImageDataset {
        ImageDataset::generate(
            8,
            3,
            4,
            42,
            ImageGenConfig { n_train: 64, n_val: 32, ..Default::default() },
        )
    }

    #[test]
    fn deterministic() {
        let a = tiny();
        let b = tiny();
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.val_y, b.val_y);
    }

    #[test]
    fn shapes_and_labels() {
        let d = tiny();
        assert_eq!(d.train_x.len(), 64 * 8 * 8 * 3);
        assert!(d.train_y.iter().all(|&y| (0..4).contains(&y)));
        let (x, y) = d.train_batch(16, &mut SplitMix64::new(1));
        assert_eq!(x.shape(), &[16, 8, 8, 3]);
        assert_eq!(y.shape(), &[16]);
    }

    #[test]
    fn val_batches_cover_without_ragged() {
        let d = tiny();
        let vb = d.val_batches(10);
        assert_eq!(vb.len(), 3); // 32 / 10 -> 3 full batches
    }

    #[test]
    fn classes_are_distinguishable_by_template() {
        // linear probe sanity: mean intra-class distance << inter-class
        let d = ImageDataset::generate(
            8,
            1,
            3,
            7,
            ImageGenConfig { n_train: 300, n_val: 30, noise: 0.3, ..Default::default() },
        );
        let k = d.sample_elems();
        let mut means = vec![vec![0.0f64; k]; 3];
        let mut counts = [0usize; 3];
        for (i, &y) in d.train_y.iter().enumerate() {
            counts[y as usize] += 1;
            for j in 0..k {
                means[y as usize][j] += d.train_x[i * k + j] as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f64;
            }
        }
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
        };
        let d01 = dist(&means[0], &means[1]);
        let d02 = dist(&means[0], &means[2]);
        assert!(d01 > 1.0 && d02 > 1.0, "class means too close: {d01} {d02}");
    }

    #[test]
    fn random_crop_preserves_size_and_content_origin() {
        let d = tiny();
        let k = d.sample_elems();
        let src = &d.train_x[..k];
        let mut rng = SplitMix64::new(0);
        let mut out = Vec::new();
        push_random_crop(&mut out, src, 8, 3, 2, &mut rng);
        assert_eq!(out.len(), k);
        // every nonzero output value must exist in the source
        let src_set: std::collections::HashSet<u32> =
            src.iter().map(|f| f.to_bits()).collect();
        for &v in &out {
            assert!(v == 0.0 || src_set.contains(&v.to_bits()));
        }
    }

    #[test]
    fn random_crop_zero_pad_is_identity_at_zero_offset() {
        // pad = 0 forces offset 0 -> identity
        let d = tiny();
        let k = d.sample_elems();
        let src = &d.train_x[..k];
        let mut rng = SplitMix64::new(1);
        let mut out = Vec::new();
        push_random_crop(&mut out, src, 8, 3, 0, &mut rng);
        assert_eq!(&out[..], src);
    }

    #[test]
    fn cutout_zeroes_exactly_one_square() {
        let mut img = vec![1.0f32; 8 * 8 * 3];
        let mut rng = SplitMix64::new(2);
        cutout_inplace(&mut img, 8, 3, 3, &mut rng);
        let zeros = img.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 3 * 3 * 3);
    }

    #[test]
    fn cutout_degenerate_sizes_noop() {
        let mut img = vec![1.0f32; 4 * 4];
        let mut rng = SplitMix64::new(3);
        cutout_inplace(&mut img, 4, 1, 0, &mut rng);
        cutout_inplace(&mut img, 4, 1, 9, &mut rng);
        assert!(img.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn hflip_is_involution() {
        let d = tiny();
        let k = d.sample_elems();
        let src = &d.train_x[..k];
        let mut once = Vec::new();
        push_hflip(&mut once, src, 8, 3);
        let mut twice = Vec::new();
        push_hflip(&mut twice, &once, 8, 3);
        assert_eq!(src, &twice[..]);
    }
}
