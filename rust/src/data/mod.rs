//! Data pipeline: deterministic synthetic dataset generators + batchers.
//!
//! The paper evaluates on CIFAR-100/SVHN/ImageNet/PTB; this repo has no
//! network access or dataset files, so `images` and `text` generate
//! deterministic stand-ins sized to the manifest's dataset dims
//! (DESIGN.md §5 explains why the substitution preserves the claims under
//! test). `prefetch` overlaps batch assembly with device execution.

pub mod images;
pub mod prefetch;
pub mod text;

use anyhow::Result;

use crate::runtime::{DatasetSpec, HostTensor};
use crate::util::rng::SplitMix64;

pub use images::{ImageDataset, ImageGenConfig};
pub use text::TextDataset;

/// Unified handle over the two dataset kinds.
pub enum Dataset {
    Image(ImageDataset),
    Text(TextDataset),
}

impl Dataset {
    /// Instantiate the generator matching a manifest dataset spec.
    pub fn from_spec(spec: &DatasetSpec, seed: u64) -> Result<Dataset> {
        Ok(match spec {
            DatasetSpec::Image { hw, channels, classes } => Dataset::Image(ImageDataset::generate(
                *hw,
                *channels,
                *classes,
                seed,
                ImageGenConfig::default(),
            )),
            DatasetSpec::Text { vocab, seq } => {
                Dataset::Text(TextDataset::generate(*vocab, *seq, seed, 60_000, 12_000))
            }
        })
    }

    pub fn train_batch(&self, batch: usize, rng: &mut SplitMix64) -> (HostTensor, HostTensor) {
        match self {
            Dataset::Image(d) => d.train_batch(batch, rng),
            Dataset::Text(d) => d.train_batch(batch, rng),
        }
    }

    pub fn val_batches(&self, batch: usize) -> Vec<(HostTensor, HostTensor)> {
        match self {
            Dataset::Image(d) => d.val_batches(batch),
            Dataset::Text(d) => d.val_batches(batch),
        }
    }

    /// Number of examples one eval batch contributes to metric denominators
    /// (images: batch; text: batch sequences, each already averaged over T).
    pub fn eval_denominator(&self, batch: usize) -> f64 {
        batch as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_spec_dispatch() {
        let d = Dataset::from_spec(
            &DatasetSpec::Image { hw: 8, channels: 3, classes: 4 },
            1,
        )
        .unwrap();
        assert!(matches!(d, Dataset::Image(_)));
        let (x, _) = d.train_batch(4, &mut SplitMix64::new(0));
        assert_eq!(x.shape(), &[4, 8, 8, 3]);
    }
}
