//! Data pipeline: deterministic synthetic dataset generators + batchers.
//!
//! The paper evaluates on CIFAR-100/SVHN/ImageNet/PTB; this repo has no
//! network access or dataset files, so `images` and `text` generate
//! deterministic stand-ins sized to the manifest's dataset dims
//! (DESIGN.md §5 explains why the substitution preserves the claims under
//! test). `prefetch` overlaps batch assembly with device execution.

pub mod images;
pub mod prefetch;
pub mod text;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::runtime::{DatasetSpec, HostTensor};
use crate::util::rng::SplitMix64;

pub use images::{ImageDataset, ImageGenConfig};
pub use text::TextDataset;

/// Unified handle over the two dataset kinds.
pub enum Dataset {
    Image(ImageDataset),
    Text(TextDataset),
}

impl Dataset {
    /// Instantiate the generator matching a manifest dataset spec.
    pub fn from_spec(spec: &DatasetSpec, seed: u64) -> Result<Dataset> {
        Ok(match spec {
            DatasetSpec::Image { hw, channels, classes } => Dataset::Image(ImageDataset::generate(
                *hw,
                *channels,
                *classes,
                seed,
                ImageGenConfig::default(),
            )),
            DatasetSpec::Text { vocab, seq } => {
                Dataset::Text(TextDataset::generate(*vocab, *seq, seed, 60_000, 12_000))
            }
        })
    }

    pub fn train_batch(&self, batch: usize, rng: &mut SplitMix64) -> (HostTensor, HostTensor) {
        match self {
            Dataset::Image(d) => d.train_batch(batch, rng),
            Dataset::Text(d) => d.train_batch(batch, rng),
        }
    }

    pub fn val_batches(&self, batch: usize) -> Vec<(HostTensor, HostTensor)> {
        match self {
            Dataset::Image(d) => d.val_batches(batch),
            Dataset::Text(d) => d.val_batches(batch),
        }
    }

    /// Number of examples one eval batch contributes to metric denominators
    /// (images: batch; text: batch sequences, each already averaged over T).
    pub fn eval_denominator(&self, batch: usize) -> f64 {
        batch as f64
    }
}

/// Generated datasets a cache holds at most: sweeps touch one or two
/// (spec, seed) pairs at a time, and evicting the oldest bounds a
/// long-lived trainer's memory at a handful of synthetic datasets.
const DATASET_CACHE_CAP: usize = 4;

/// Cache of generated datasets keyed by (spec, seed). Generation is
/// deterministic in both, so a sweep running many numeric configs over
/// the same dataset reuses one generated copy instead of regenerating
/// (and re-allocating) it per combo. Insertion-order eviction above
/// [`DATASET_CACHE_CAP`] keeps many-seed sweeps from accumulating every
/// dataset they ever generated.
#[derive(Default)]
pub struct DatasetCache {
    entries: Mutex<Vec<(String, Arc<Dataset>)>>,
    /// Lookups served from cache / generated fresh — observable so
    /// sweeps and tests can assert that paired combos (FP32 vs HBFP over
    /// the same dataset) actually shared one generated copy.
    hits: AtomicU64,
    generated: AtomicU64,
}

impl DatasetCache {
    /// Fetch the dataset for `(spec, seed)`, generating it on first use.
    pub fn get_or_generate(&self, spec: &DatasetSpec, seed: u64) -> Result<Arc<Dataset>> {
        let key = format!("{spec:?}#{seed}");
        let mut entries = self.entries.lock().unwrap();
        if let Some((_, d)) = entries.iter().find(|(k, _)| *k == key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(d));
        }
        self.generated.fetch_add(1, Ordering::Relaxed);
        let d = Arc::new(Dataset::from_spec(spec, seed)?);
        entries.push((key, Arc::clone(&d)));
        if entries.len() > DATASET_CACHE_CAP {
            entries.remove(0); // oldest first; live Arcs keep their data alive
        }
        Ok(d)
    }

    /// Distinct datasets currently cached.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from cache since construction.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Datasets generated (cache misses) since construction.
    pub fn generated(&self) -> u64 {
        self.generated.load(Ordering::Relaxed)
    }

    /// Register the cache's counters into `reg` under `prefix`
    /// (dot-joined when non-empty) — the registry-side view of the same
    /// hits/generated/len surface the accessors expose.
    pub fn export_metrics(&self, reg: &crate::obs::Registry, prefix: &str) {
        let name = |k: &str| {
            if prefix.is_empty() {
                k.to_string()
            } else {
                format!("{prefix}.{k}")
            }
        };
        reg.counter(&name("hits"), self.hits());
        reg.counter(&name("generated"), self.generated());
        reg.counter(&name("len"), self.len() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_spec_dispatch() {
        let d = Dataset::from_spec(
            &DatasetSpec::Image { hw: 8, channels: 3, classes: 4 },
            1,
        )
        .unwrap();
        assert!(matches!(d, Dataset::Image(_)));
        let (x, _) = d.train_batch(4, &mut SplitMix64::new(0));
        assert_eq!(x.shape(), &[4, 8, 8, 3]);
    }

    #[test]
    fn dataset_cache_reuses_by_spec_and_seed() {
        let cache = DatasetCache::default();
        let spec = DatasetSpec::Image { hw: 8, channels: 1, classes: 2 };
        let a = cache.get_or_generate(&spec, 7).unwrap();
        let b = cache.get_or_generate(&spec, 7).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same (spec, seed) must share one dataset");
        assert_eq!(cache.len(), 1);
        assert_eq!((cache.hits(), cache.generated()), (1, 1));
        // different seed or spec generates a distinct entry
        let c = cache.get_or_generate(&spec, 8).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        cache
            .get_or_generate(&DatasetSpec::Image { hw: 8, channels: 3, classes: 2 }, 7)
            .unwrap();
        assert_eq!(cache.len(), 3);
        assert_eq!((cache.hits(), cache.generated()), (1, 3));
    }

    #[test]
    fn dataset_cache_evicts_oldest_beyond_cap() {
        let cache = DatasetCache::default();
        let spec = DatasetSpec::Image { hw: 8, channels: 1, classes: 2 };
        let first = cache.get_or_generate(&spec, 0).unwrap();
        for seed in 1..=DATASET_CACHE_CAP as u64 {
            cache.get_or_generate(&spec, seed).unwrap();
        }
        assert_eq!(cache.len(), DATASET_CACHE_CAP, "cache must stay bounded");
        // seed 0 was evicted: fetching it again generates a fresh Arc
        let again = cache.get_or_generate(&spec, 0).unwrap();
        assert!(!Arc::ptr_eq(&first, &again), "oldest entry should have been evicted");
        // the most recent seed is still cached
        let last = cache.get_or_generate(&spec, DATASET_CACHE_CAP as u64).unwrap();
        let last2 = cache.get_or_generate(&spec, DATASET_CACHE_CAP as u64).unwrap();
        assert!(Arc::ptr_eq(&last, &last2));
    }
}
