//! Background batch prefetcher: overlaps host-side batch assembly (template
//! sampling, augmentation, RNG) with device execution of the previous step.
//!
//! std threads + sync_channel (tokio is not in the vendored set; a bounded
//! channel of depth N is exactly the backpressure semantics we want anyway:
//! the producer runs at most N batches ahead and blocks when the trainer
//! stalls).

use std::sync::mpsc::{sync_channel, Receiver};
use std::thread::JoinHandle;

use crate::runtime::HostTensor;

pub struct Prefetcher {
    rx: Receiver<(HostTensor, HostTensor)>,
    /// Kept so the producer thread has an owner; dropping the Prefetcher
    /// drops `rx`, the producer's next `send` errors, and the (detached)
    /// thread exits.
    _handle: JoinHandle<()>,
}

impl Prefetcher {
    /// Spawn a producer thread calling `make_batch` repeatedly, keeping at
    /// most `depth` batches in flight.
    pub fn spawn<F>(depth: usize, mut make_batch: F) -> Prefetcher
    where
        F: FnMut() -> (HostTensor, HostTensor) + Send + 'static,
    {
        let (tx, rx) = sync_channel(depth);
        let handle = std::thread::Builder::new()
            .name("hbfp-prefetch".into())
            .spawn(move || {
                // Stop when the receiver hangs up.
                while tx.send(make_batch()).is_ok() {}
            })
            .expect("spawning prefetch thread");
        Prefetcher { rx, _handle: handle }
    }

    /// Next batch (blocks if the producer is behind — that only happens if
    /// batch generation is slower than a training step).
    pub fn next(&self) -> (HostTensor, HostTensor) {
        self.rx.recv().expect("prefetch thread died")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    fn batch(i: i32) -> (HostTensor, HostTensor) {
        (HostTensor::scalar_i32(i), HostTensor::scalar_i32(i))
    }

    #[test]
    fn produces_in_order() {
        let mut i = 0;
        let p = Prefetcher::spawn(2, move || {
            i += 1;
            batch(i)
        });
        for want in 1..=10 {
            let (x, _) = p.next();
            assert_eq!(x, HostTensor::scalar_i32(want));
        }
    }

    #[test]
    fn drop_terminates_producer() {
        let p = Prefetcher::spawn(1, move || {
            std::thread::sleep(std::time::Duration::from_millis(1));
            batch(0)
        });
        let _ = p.next();
        drop(p); // must not hang
    }

    #[test]
    fn works_with_real_generator() {
        let d = crate::data::ImageDataset::generate(
            8,
            1,
            2,
            1,
            crate::data::ImageGenConfig { n_train: 32, n_val: 8, ..Default::default() },
        );
        let p = {
            let mut rng = SplitMix64::new(0);
            Prefetcher::spawn(2, move || d.train_batch(4, &mut rng))
        };
        for _ in 0..5 {
            let (x, y) = p.next();
            assert_eq!(x.shape(), &[4, 8, 8, 1]);
            assert_eq!(y.shape(), &[4]);
        }
    }
}
