//! `hbfp` — the launcher.
//!
//! ```text
//! hbfp list                               # combos available in artifacts/
//! hbfp train <combo> [--steps N] [--lr S] [--seed K] [--eval-every N]
//!            [--input-bfp MxT]   # host-side BFP input converter, e.g. 8x24
//!            [--prefetch-depth N] # batches kept in flight (default 2)
//! hbfp repro <table1|table2|table3|fig3|mantissa|tiles|attention|throughput|all>
//!            [--steps N] [--seed K]
//! hbfp accel-report                       # area/throughput model table
//! ```
//!
//! Artifacts are read from `--artifacts DIR` (default `artifacts/`),
//! results written under `--results DIR` (default `results/`).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use hbfp::coordinator::{parse_schedule, repro, RunConfig, Sweep, Trainer};
use hbfp::runtime::Manifest;
use hbfp::util::cli::Args;

fn init_logging(verbose: bool) {
    struct Logger {
        verbose: bool,
    }
    impl log::Log for Logger {
        fn enabled(&self, metadata: &log::Metadata) -> bool {
            metadata.level() <= if self.verbose { log::Level::Debug } else { log::Level::Info }
        }
        fn log(&self, record: &log::Record) {
            if self.enabled(record.metadata()) {
                eprintln!("[{}] {}", record.level().as_str().to_lowercase(), record.args());
            }
        }
        fn flush(&self) {}
    }
    let logger = Box::leak(Box::new(Logger { verbose }));
    let _ = log::set_logger(logger);
    log::set_max_level(if verbose { log::LevelFilter::Debug } else { log::LevelFilter::Info });
}

/// Parse `--input-bfp 8x24` into (mantissa_bits, tile_edge).
fn parse_input_bfp(spec: &str) -> Result<(u32, usize)> {
    let parsed = spec
        .split_once('x')
        .and_then(|(m, t)| Some((m.parse::<u32>().ok()?, t.parse::<usize>().ok()?)));
    parsed.ok_or_else(|| anyhow!("--input-bfp expects <mantissa>x<tile>, e.g. 8x24; got {spec:?}"))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    init_logging(args.has_flag("verbose"));
    let artifacts = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let results = PathBuf::from(args.opt_or("results", "results"));

    match args.command.as_deref() {
        Some("list") => {
            let manifest = Manifest::load(&artifacts)?;
            for combo in manifest.combos() {
                println!("{combo}");
            }
            Ok(())
        }
        Some("train") => {
            let combo = args
                .positional
                .first()
                .ok_or_else(|| {
                    anyhow!(
                        "usage: hbfp train <combo> [--steps N] [--input-bfp MxT] \
                         [--prefetch-depth N]"
                    )
                })?;
            let steps = args.opt_usize("steps", 200)?;
            let manifest = Arc::new(Manifest::load(&artifacts)?);
            let mut cfg = RunConfig::new(combo, steps)
                .with_seed(args.opt_u64("seed", 0)?)
                .with_eval_every(args.opt_usize("eval-every", 0)?)
                .with_prefetch_depth(args.opt_usize(
                    "prefetch-depth",
                    hbfp::coordinator::DEFAULT_PREFETCH_DEPTH,
                )?);
            if let Some(spec) = args.opt("input-bfp") {
                let (m, t) = parse_input_bfp(spec)?;
                cfg = cfg.with_input_bfp(m, t);
            }
            let model = cfg.model().to_string();
            let base = hbfp::coordinator::default_base_lr(&model);
            cfg = cfg.with_lr(parse_schedule(
                &args.opt_or("lr", &format!("{base}")),
                steps,
            )?);
            if args.has_flag("checkpoint") {
                cfg.checkpoint_dir = Some(results.join("checkpoints"));
            }
            let trainer = Trainer::new(manifest)?;
            let r = trainer.run(&cfg)?;
            std::fs::create_dir_all(&results)?;
            let out = results.join(format!("{combo}_train.json"));
            std::fs::write(&out, r.summary_json().to_string())
                .with_context(|| format!("writing {out:?}"))?;
            println!(
                "{combo}: final val err {:.2}%  loss {:.4}  ({:.1} steps/s, result -> {out:?})",
                r.final_error * 100.0,
                r.final_loss,
                r.history.throughput().unwrap_or(0.0)
            );
            Ok(())
        }
        Some("repro") => {
            let what = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
            if what == "throughput" {
                repro::throughput();
                return Ok(());
            }
            let steps = args.opt_usize("steps", 300)?;
            let seed = args.opt_u64("seed", 0)?;
            let manifest = Arc::new(Manifest::load(&artifacts)?);
            let sweep = Sweep::new(manifest, &results)?;
            match what {
                "table1" => {
                    repro::table1(&sweep, steps, seed)?;
                }
                "table2" => {
                    repro::table2(&sweep, steps, seed)?;
                }
                "table3" => {
                    repro::table3(&sweep, steps, seed)?;
                }
                "fig3" => {
                    repro::fig3(&sweep, steps, seed)?;
                }
                "mantissa" => {
                    repro::mantissa_sweep(&sweep, steps, seed)?;
                }
                "tiles" => {
                    repro::tile_sweep(&sweep, steps, seed)?;
                }
                "attention" => {
                    repro::attention(&sweep, steps, seed)?;
                }
                "all" => {
                    repro::table1(&sweep, steps, seed)?;
                    repro::table2(&sweep, steps, seed)?;
                    repro::table3(&sweep, steps, seed)?;
                    repro::fig3(&sweep, steps, seed)?;
                    repro::mantissa_sweep(&sweep, steps, seed)?;
                    repro::tile_sweep(&sweep, steps, seed)?;
                    repro::attention(&sweep, steps, seed)?;
                    repro::throughput();
                }
                other => return Err(anyhow!("unknown repro target {other:?}")),
            }
            Ok(())
        }
        Some("report") => {
            let rows = hbfp::coordinator::report::load_results(&results)?;
            println!("{}", hbfp::coordinator::report::render_markdown(&rows));
            Ok(())
        }
        Some("accel-report") => {
            repro::throughput();
            Ok(())
        }
        other => {
            eprintln!(
                "hbfp — HBFP training framework (NIPS'18 reproduction)\n\
                 commands: list | train <combo> | repro <target> | report | accel-report\n\
                 (got {other:?})"
            );
            Err(anyhow!("unknown command"))
        }
    }
}
