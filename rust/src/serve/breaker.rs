//! Per-tenant circuit breakers: quarantine a misbehaving resident model
//! instead of burning pool dispatches on it.
//!
//! Each resident model owns one [`CircuitBreaker`], a
//! Closed → Open → HalfProbe state machine driven entirely by the
//! injectable [`super::clock::ServeClock`], so trips, cooldowns, and
//! half-open probes replay bit-identically under `ManualClock`:
//!
//! - **Closed**: requests flow. Guard errors, contained worker panics,
//!   and expiry bursts attributable to the model count as failures;
//!   any served row resets the consecutive-failure counter.
//! - **Open**: `failure_threshold` consecutive failures trip the
//!   breaker. Admission refuses the tenant with
//!   [`super::admission::Rejected::Quarantined`] and dispatch skips its
//!   queue until `cooldown_ticks` have elapsed.
//! - **HalfProbe**: after the cooldown, up to `half_open_probes`
//!   requests are admitted as probes. `half_open_probes` consecutive
//!   probe successes close the breaker; any probe failure re-opens it
//!   for a fresh cooldown.
//!
//! The breaker deliberately does *not* distinguish why a model fails —
//! poisoned weights, a deterministic panic in its panel, NaN-dense
//! inputs from one client — because from the scheduler's seat they all
//! read the same: dispatches to this tenant keep dying. What it must
//! never do is trip on somebody else's failures, which is why every
//! settlement call is keyed by model index in the server.

/// Thresholds for one tenant's breaker. `Default` matches the serve
/// soak configuration documented in PERF.md.
#[derive(Debug, Clone, Copy)]
pub struct BreakerConfig {
    /// Consecutive failures (guard errors, contained panics, expiry
    /// bursts) that trip Closed -> Open.
    pub failure_threshold: u32,
    /// Ticks to hold Open before probing (same unit as `ServeClock`).
    pub cooldown_ticks: u64,
    /// Probe successes required to close from HalfProbe; also the cap
    /// on concurrently admitted probes.
    pub half_open_probes: u32,
    /// A single pump that expires at least this many of the tenant's
    /// requests counts as one failure (expiry burst), even though no
    /// individual request "failed".
    pub expiry_burst: usize,
}

impl Default for BreakerConfig {
    fn default() -> BreakerConfig {
        BreakerConfig {
            failure_threshold: 8,
            cooldown_ticks: 10_000,
            half_open_probes: 2,
            expiry_burst: 4,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    /// Quarantined until the clock reaches `until`.
    Open { until: u64 },
    /// Cooled down; admitting up to the probe cap.
    HalfProbe { in_flight: u32, successes: u32 },
}

impl BreakerState {
    pub fn name(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open { .. } => "open",
            BreakerState::HalfProbe { .. } => "half-open",
        }
    }
}

/// One resident model's breaker.
#[derive(Debug)]
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    state: BreakerState,
    consecutive_failures: u32,
    trips: u64,
    recoveries: u64,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker {
            cfg: BreakerConfig {
                failure_threshold: cfg.failure_threshold.max(1),
                cooldown_ticks: cfg.cooldown_ticks.max(1),
                half_open_probes: cfg.half_open_probes.max(1),
                expiry_burst: cfg.expiry_burst.max(1),
            },
            state: BreakerState::Closed,
            consecutive_failures: 0,
            trips: 0,
            recoveries: 0,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    pub fn trips(&self) -> u64 {
        self.trips
    }

    pub fn recoveries(&self) -> u64 {
        self.recoveries
    }

    pub fn config(&self) -> BreakerConfig {
        self.cfg
    }

    /// Admission-side gate: may a new request for this tenant enter the
    /// queue at `now`? Transitions Open -> HalfProbe when the cooldown
    /// has elapsed; in HalfProbe, admits only up to the probe cap and
    /// reserves a probe slot for each admitted request. Call this *last*
    /// in the admission chain so rejected submissions never leak a slot.
    pub fn admit(&mut self, now: u64) -> bool {
        match self.state {
            BreakerState::Closed => true,
            BreakerState::Open { until } => {
                if now < until {
                    false
                } else {
                    self.state = BreakerState::HalfProbe { in_flight: 1, successes: 0 };
                    true
                }
            }
            BreakerState::HalfProbe { in_flight, successes } => {
                if in_flight >= self.cfg.half_open_probes {
                    false
                } else {
                    self.state = BreakerState::HalfProbe { in_flight: in_flight + 1, successes };
                    true
                }
            }
        }
    }

    /// Dispatch-side gate: should the scheduler skip this tenant's queue
    /// at `now`? Open (and still cooling) means yes. HalfProbe work that
    /// was admitted must be allowed to run, so it does not block.
    pub fn blocks_dispatch(&self, now: u64) -> bool {
        matches!(self.state, BreakerState::Open { until } if now < until)
    }

    /// A request for this tenant was served. Returns `true` when this
    /// closes the breaker (a recovery).
    pub fn record_success(&mut self) -> bool {
        self.consecutive_failures = 0;
        if let BreakerState::HalfProbe { in_flight, successes } = self.state {
            let successes = successes + 1;
            if successes >= self.cfg.half_open_probes {
                self.state = BreakerState::Closed;
                self.recoveries += 1;
                return true;
            }
            self.state = BreakerState::HalfProbe { in_flight: in_flight.saturating_sub(1), successes };
        }
        false
    }

    /// A request for this tenant failed (guard error or contained
    /// panic), or an expiry burst was charged. Returns `true` when this
    /// trips the breaker Closed/HalfProbe -> Open.
    pub fn record_failure(&mut self, now: u64) -> bool {
        match self.state {
            BreakerState::Open { .. } => false,
            BreakerState::HalfProbe { .. } => {
                // One failed probe is enough: back to quarantine.
                self.trip(now);
                true
            }
            BreakerState::Closed => {
                self.consecutive_failures += 1;
                if self.consecutive_failures >= self.cfg.failure_threshold {
                    self.trip(now);
                    true
                } else {
                    false
                }
            }
        }
    }

    /// An admitted probe expired in queue (neither success nor model
    /// failure): release its slot without judging the model.
    pub fn probe_expired(&mut self) {
        if let BreakerState::HalfProbe { in_flight, successes } = self.state {
            self.state = BreakerState::HalfProbe { in_flight: in_flight.saturating_sub(1), successes };
        }
    }

    /// Does a pump that expired `count` of this tenant's queued requests
    /// constitute an expiry burst (chargeable as one failure)?
    pub fn is_expiry_burst(&self, count: usize) -> bool {
        count >= self.cfg.expiry_burst
    }

    fn trip(&mut self, now: u64) {
        self.state = BreakerState::Open { until: now.saturating_add(self.cfg.cooldown_ticks) };
        self.consecutive_failures = 0;
        self.trips += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BreakerConfig {
        BreakerConfig {
            failure_threshold: 3,
            cooldown_ticks: 100,
            half_open_probes: 2,
            expiry_burst: 4,
        }
    }

    #[test]
    fn trips_after_consecutive_failures_only() {
        let mut b = CircuitBreaker::new(cfg());
        assert!(!b.record_failure(0));
        assert!(!b.record_failure(0));
        // a success resets the streak
        b.record_success();
        assert!(!b.record_failure(10));
        assert!(!b.record_failure(10));
        assert!(b.record_failure(10), "third consecutive failure trips");
        assert_eq!(b.state().name(), "open");
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn open_refuses_admission_and_blocks_dispatch_until_cooldown() {
        let mut b = CircuitBreaker::new(cfg());
        for _ in 0..3 {
            b.record_failure(50);
        }
        assert_eq!(b.state(), BreakerState::Open { until: 150 });
        assert!(!b.admit(149));
        assert!(b.blocks_dispatch(149));
        // cooldown elapsed: first admission becomes a probe
        assert!(b.admit(150));
        assert_eq!(b.state(), BreakerState::HalfProbe { in_flight: 1, successes: 0 });
        assert!(!b.blocks_dispatch(150), "admitted probes must be dispatchable");
    }

    #[test]
    fn half_open_caps_probes_and_recovers_on_successes() {
        let mut b = CircuitBreaker::new(cfg());
        for _ in 0..3 {
            b.record_failure(0);
        }
        assert!(b.admit(100));
        assert!(b.admit(100));
        assert!(!b.admit(100), "probe cap of 2 reached");
        assert!(!b.record_success(), "first probe success is not yet recovery");
        assert!(b.admit(100), "slot released by the settled probe");
        assert!(b.record_success(), "second success closes the breaker");
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.recoveries(), 1);
    }

    #[test]
    fn failed_probe_reopens_with_fresh_cooldown() {
        let mut b = CircuitBreaker::new(cfg());
        for _ in 0..3 {
            b.record_failure(0);
        }
        assert!(b.admit(100));
        assert!(b.record_failure(120), "one failed probe re-trips");
        assert_eq!(b.state(), BreakerState::Open { until: 220 });
        assert_eq!(b.trips(), 2);
    }

    #[test]
    fn expired_probe_releases_slot_without_judging() {
        let mut b = CircuitBreaker::new(cfg());
        for _ in 0..3 {
            b.record_failure(0);
        }
        assert!(b.admit(100));
        assert!(b.admit(100));
        assert!(!b.admit(100));
        b.probe_expired();
        assert_eq!(b.state(), BreakerState::HalfProbe { in_flight: 1, successes: 0 });
        assert!(b.admit(100), "expired probe freed a slot");
        assert_eq!(b.trips(), 1, "expiry did not re-trip");
    }

    #[test]
    fn expiry_burst_threshold_is_config_driven() {
        let b = CircuitBreaker::new(cfg());
        assert!(!b.is_expiry_burst(3));
        assert!(b.is_expiry_burst(4));
    }
}
