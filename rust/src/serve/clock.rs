//! Serving-time clock abstraction.
//!
//! The server never reads wall time directly: every timestamp — admission,
//! deadline arithmetic, latency accounting, fault-injected stalls — goes
//! through a [`ServeClock`]. Production uses [`SystemClock`] (microseconds
//! since server start); the overload soak tests use [`ManualClock`] so the
//! exact same request trace produces the exact same expiry/shed decisions
//! on every run, independent of host load.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// A monotonic tick source the serving front-end schedules against.
///
/// Ticks are an abstract unit; [`SystemClock`] makes them microseconds,
/// [`ManualClock`] makes them whatever the test advances by.
pub trait ServeClock: Send + Sync {
    /// Current tick count. Monotonically non-decreasing.
    fn now(&self) -> u64;

    /// Spend `ticks` of time. Real clocks sleep; manual clocks jump.
    /// Used by the `slow-request` fault site and the synthetic per-row
    /// service-time model.
    fn advance(&self, ticks: u64);
}

/// Wall-clock ticks: microseconds elapsed since the clock was created.
#[derive(Debug)]
pub struct SystemClock {
    start: Instant,
}

impl SystemClock {
    pub fn new() -> SystemClock {
        SystemClock { start: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> SystemClock {
        SystemClock::new()
    }
}

impl ServeClock for SystemClock {
    fn now(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    fn advance(&self, ticks: u64) {
        std::thread::sleep(Duration::from_micros(ticks));
    }
}

/// Deterministic test clock: time moves only when something advances it.
#[derive(Debug, Default)]
pub struct ManualClock {
    ticks: AtomicU64,
}

impl ManualClock {
    pub fn new() -> ManualClock {
        ManualClock::default()
    }
}

impl ServeClock for ManualClock {
    fn now(&self) -> u64 {
        self.ticks.load(Ordering::SeqCst)
    }

    fn advance(&self, ticks: u64) {
        self.ticks.fetch_add(ticks, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_advances_exactly() {
        let c = ManualClock::new();
        assert_eq!(c.now(), 0);
        c.advance(7);
        c.advance(0);
        assert_eq!(c.now(), 7);
    }

    #[test]
    fn system_clock_is_monotonic_and_advances() {
        let c = SystemClock::new();
        let a = c.now();
        c.advance(1_000); // 1ms sleep
        let b = c.now();
        assert!(b >= a + 500, "1ms sleep moved the clock {a} -> {b}");
    }
}
