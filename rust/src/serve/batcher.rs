//! Micro-batching: coalesce single-row requests into skinny GEMMs.
//!
//! Inference requests arrive one activation row at a time, but the packed
//! datapath amortizes its panel relayout and pool dispatch over rows — an
//! 8×256×256 skinny GEMM is far cheaper than eight 1×256×256 calls. The
//! batcher takes the head-of-line request's model and greedily coalesces
//! up to `max_rows` FIFO requests for that same model into one
//! [`MicroBatch`]; a single shape-keyed plan (from the
//! [`crate::bfp::PlanCache`]) then serves every batch of that shape.

use super::queue::{BoundedQueue, QueuedRequest};

/// A group of same-model requests that will execute as one GEMM with
/// `requests.len()` rows.
#[derive(Debug)]
pub struct MicroBatch {
    pub model: usize,
    pub requests: Vec<QueuedRequest>,
}

impl MicroBatch {
    pub fn rows(&self) -> usize {
        self.requests.len()
    }
}

/// Form the next batch: head-of-line model, up to `max_rows` rows.
/// Returns `None` when the queue is empty.
pub fn next_batch(queue: &mut BoundedQueue, max_rows: usize) -> Option<MicroBatch> {
    let model = queue.front_model()?;
    let requests = queue.take_for_model(model, max_rows.max(1));
    Some(MicroBatch { model, requests })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: usize) -> QueuedRequest {
        QueuedRequest { id, model, input: vec![0.0; 4], deadline: u64::MAX, submitted_at: 0 }
    }

    #[test]
    fn batches_follow_head_of_line_model() {
        let mut q = BoundedQueue::new(16);
        for (id, model) in [(1, 0), (2, 0), (3, 1), (4, 0), (5, 1)] {
            q.push(req(id, model)).unwrap();
        }
        let b = next_batch(&mut q, 8).unwrap();
        assert_eq!(b.model, 0);
        assert_eq!(b.rows(), 3);
        assert_eq!(b.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 4]);

        let b = next_batch(&mut q, 1).unwrap();
        assert_eq!(b.model, 1);
        assert_eq!(b.requests[0].id, 3);

        let b = next_batch(&mut q, 8).unwrap();
        assert_eq!(b.requests[0].id, 5);
        assert!(next_batch(&mut q, 8).is_none());
    }
}
