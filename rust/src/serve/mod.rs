//! Resilient inference front-end over the BFP datapath.
//!
//! A synchronous-core serving layer: callers [`InferenceServer::submit`]
//! single activation rows against models whose weights live resident in
//! quantized + packed form ([`session`]); a drive loop calls
//! [`InferenceServer::pump`], which coalesces requests into skinny
//! micro-batch GEMMs ([`batcher`]) executed through the shape-keyed
//! [`crate::bfp::PlanCache`] on the worker pool.
//!
//! The robustness contract:
//!
//! - **Admission control & backpressure** ([`admission`]): a bounded
//!   queue ([`queue`]) behind a watermark ladder — callers get a typed
//!   [`Rejected`] reason or a [`Pressure`] signal, never an unbounded
//!   buffer.
//! - **Deadlines**: enforced at dequeue (dead work never costs a GEMM)
//!   and at completion (late answers are reported expired, not served).
//! - **Graceful precision degradation**: the ladder's last rung before
//!   refusal serves at the narrow mantissa width (§4.2 narrow read path,
//!   pre-built at model load), and every degraded response says so.
//! - **Fault isolation**: a poisoned input or a contained worker panic
//!   fails only its own request; batch-mates are redispatched or split
//!   into per-row GEMMs.
//!
//! Time is abstracted behind [`ServeClock`] ([`clock`]) so the overload
//! soak tests replay deterministically on a [`ManualClock`].

pub mod admission;
pub mod batcher;
pub mod clock;
pub mod queue;
pub mod server;
pub mod session;

pub use admission::{AdmissionPolicy, Pressure, Rejected};
pub use batcher::{next_batch, MicroBatch};
pub use clock::{ManualClock, ServeClock, SystemClock};
pub use queue::{BoundedQueue, QueuedRequest};
pub use server::{
    BatchReport, Completion, ExpiredAt, InferenceServer, Outcome, PumpReport, Response,
    ServeConfig, Submission,
};
pub use session::ResidentModel;
