//! Resilient multi-tenant inference front-end over the BFP datapath.
//!
//! A synchronous-core serving layer: callers [`InferenceServer::submit`]
//! single activation rows against models whose weights live resident in
//! quantized + packed form ([`session`]); a drive loop calls
//! [`InferenceServer::pump`], which takes one fair-share scheduler turn
//! ([`scheduler`]) and executes that tenant's micro-batch as a skinny
//! GEMM through the shape-keyed [`crate::bfp::PlanCache`] on the worker
//! pool.
//!
//! The robustness contract:
//!
//! - **Admission control & backpressure** ([`admission`]): per-tenant
//!   bounded queues ([`queue`]) behind a watermark ladder — callers get
//!   a typed [`Rejected`] reason or a [`Pressure`] signal, never an
//!   unbounded buffer.
//! - **Fair share** ([`scheduler`]): deficit round robin weighted by
//!   registered share bounds how long any backlogged tenant can wait —
//!   a flooding tenant cannot push its neighbours past their deadlines.
//! - **Deadlines**: enforced at dequeue (dead work never costs a GEMM)
//!   and at completion (late answers are reported expired, not served).
//! - **Graceful precision degradation**: the ladder's last rung before
//!   refusal serves at the narrow mantissa width (§4.2 narrow read path,
//!   pre-built at model load), and every degraded response says so.
//! - **Fault isolation**: a poisoned input or a contained worker panic
//!   fails only its own request; batch-mates are redispatched or split
//!   into per-row GEMMs. Failures that keep hitting one resident model
//!   trip its circuit breaker ([`breaker`]) and quarantine it behind
//!   [`Rejected::Quarantined`] until half-open probes clear it.
//! - **Lifecycle** ([`server`]): hot weight reload swaps validated
//!   generations without dropping in-flight work, and
//!   `Running -> Draining -> Stopped` shuts the server down with every
//!   admitted request accounted exactly once.
//!
//! Time is abstracted behind [`ServeClock`] ([`clock`]) so the overload
//! and lifecycle soak tests replay deterministically on a
//! [`ManualClock`].

pub mod admission;
pub mod batcher;
pub mod breaker;
pub mod clock;
pub mod queue;
pub mod scheduler;
pub mod server;
pub mod session;

pub use admission::{AdmissionPolicy, Pressure, Rejected};
pub use batcher::{next_batch, MicroBatch};
pub use breaker::{BreakerConfig, BreakerState, CircuitBreaker};
pub use clock::{ManualClock, ServeClock, SystemClock};
pub use queue::{BoundedQueue, QueuedRequest};
pub use scheduler::FairScheduler;
pub use server::{
    BatchReport, Completion, DrainReport, ExpiredAt, InferenceServer, Lifecycle, Outcome,
    PumpReport, ReloadError, ReloadReport, Response, ServeConfig, Submission,
};
pub use session::ResidentModel;
