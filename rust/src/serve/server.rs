//! The synchronous-core inference server.
//!
//! `submit` runs admission control and enqueues; `pump` forms one
//! micro-batch, enforces deadlines at dequeue and again at completion,
//! executes the skinny GEMM against resident packed weights through the
//! shape-keyed plan cache, and contains every per-request hazard:
//!
//! - a non-finite activation row (including the `nan-activation` fault
//!   site) fails *that request only* — the row is scanned and dropped
//!   before batch assembly;
//! - a contained worker panic ([`crate::util::pool::PoolPanic`], e.g. the
//!   `worker-panic` fault site) triggers whole-batch redispatch up to
//!   `max_gemm_retries`, then a per-row split fallback so one poisoned
//!   dispatch cannot take down its batch-mates;
//! - the `slow-request` fault site stalls a single request's assembly,
//!   exercising the completion-time deadline check.
//!
//! Everything the server does is observable in [`ServeMetrics`]
//! (latency histogram, queue depth high-water, shed/reject/degrade/retry
//! counters) plus the numeric [`GuardStats`], both surfaced by
//! [`InferenceServer::metrics_json`].

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::bfp::stats::scan_nonfinite;
use crate::bfp::{BfpContext, GuardStats, GuardStatsSnapshot, PlanCache, Rounding};
use crate::coordinator::metrics::{guard_stats_json, ServeMetrics};
use crate::util::fault::{self, FaultSite};
use crate::util::json::Json;
use crate::util::pool::catch_pool_panic;

use super::admission::{AdmissionPolicy, Pressure, Rejected};
use super::batcher;
use super::clock::ServeClock;
use super::queue::{BoundedQueue, QueuedRequest};
use super::session::ResidentModel;

/// Serving knobs. Depth watermarks are normalized at server construction
/// to `elevated <= degrade <= shed <= capacity`.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Hard bound on queued requests.
    pub queue_capacity: usize,
    /// Depth at which admitted callers are told [`Pressure::Elevated`].
    pub elevated_depth: usize,
    /// Depth at which service drops to the degraded width.
    pub degrade_depth: usize,
    /// Depth at which new requests are refused ([`Rejected::Shedding`]).
    pub shed_depth: usize,
    /// Micro-batch row cap (the skinny-GEMM m).
    pub max_batch_rows: usize,
    /// Mantissa width for nominal service.
    pub full_bits: u32,
    /// Mantissa width for degraded service (last rung before refusal).
    pub degraded_bits: u32,
    /// Relative deadline applied when `submit` gets `None`
    /// (`u64::MAX` = no deadline).
    pub default_deadline_ticks: u64,
    /// Per-row service-time estimate for the admission feasibility
    /// screen; 0 disables [`Rejected::Overloaded`].
    pub est_ticks_per_row: u64,
    /// Ticks charged per served row on the serve clock (deterministic
    /// service-time model for manual-clock tests; 0 = off).
    pub synthetic_ticks_per_row: u64,
    /// Stall charged when the `slow-request` fault site fires.
    pub slow_request_penalty_ticks: u64,
    /// Whole-batch redispatches after a contained panic before the
    /// per-row split fallback kicks in.
    pub max_gemm_retries: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            queue_capacity: 64,
            elevated_depth: 16,
            degrade_depth: 32,
            shed_depth: 48,
            max_batch_rows: 8,
            full_bits: 16,
            degraded_bits: 8,
            default_deadline_ticks: u64::MAX,
            est_ticks_per_row: 0,
            synthetic_ticks_per_row: 0,
            slow_request_penalty_ticks: 2_000,
            max_gemm_retries: 2,
        }
    }
}

impl ServeConfig {
    fn normalized(mut self) -> ServeConfig {
        self.queue_capacity = self.queue_capacity.max(1);
        self.max_batch_rows = self.max_batch_rows.max(1);
        self.shed_depth = self.shed_depth.min(self.queue_capacity);
        self.degrade_depth = self.degrade_depth.min(self.shed_depth);
        self.elevated_depth = self.elevated_depth.min(self.degrade_depth);
        self
    }
}

/// Outcome of `submit`: either queued (with the pressure signal the
/// caller should throttle on) or refused with a typed reason.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Submission {
    Admitted { id: u64, pressure: Pressure },
    Rejected(Rejected),
}

impl Submission {
    pub fn is_admitted(&self) -> bool {
        matches!(self, Submission::Admitted { .. })
    }

    pub fn id(&self) -> Option<u64> {
        match self {
            Submission::Admitted { id, .. } => Some(*id),
            Submission::Rejected(_) => None,
        }
    }
}

/// Where a request's deadline was found to have passed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpiredAt {
    /// Dead before service: dropped at dequeue, no GEMM spent.
    Dequeue,
    /// Served, but the result arrived after the deadline.
    Completion,
}

/// A successful inference result.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub output: Vec<f32>,
    /// Mantissa width actually served.
    pub served_bits: u32,
    /// True when the load-shed ladder narrowed this request's precision.
    pub degraded: bool,
    pub latency_ticks: u64,
}

/// Terminal state of one admitted request.
#[derive(Debug, Clone, PartialEq)]
pub enum Outcome {
    Served(Response),
    Expired(ExpiredAt),
    /// This request failed (bad input or unrecoverable dispatch); its
    /// batch-mates were unaffected.
    Failed(String),
}

/// Request id + terminal outcome, delivered via `drain_completions`.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub id: u64,
    pub model: usize,
    pub outcome: Outcome,
}

/// What one `pump` call did to the batch it formed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchReport {
    pub model: usize,
    /// Ids of the rows that reached GEMM assembly, in batch-row order.
    pub ids: Vec<u64>,
    /// Width this batch was served at.
    pub bits: u32,
    pub degraded: bool,
    /// Whole-batch redispatches after contained panics.
    pub retries: usize,
    /// True when the batch fell back to per-row GEMMs (outputs are then
    /// quantized per row, not per batch).
    pub split_fallback: bool,
}

#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct PumpReport {
    pub batch: Option<BatchReport>,
    pub expired_at_dequeue: usize,
    /// Rows that terminated as [`Outcome::Failed`] this pump.
    pub failed_rows: usize,
}

/// The serving front-end. Single-threaded control loop over the
/// pool-parallel BFP datapath: callers `submit`, something drives `pump`,
/// results come back through `drain_completions`.
pub struct InferenceServer {
    cfg: ServeConfig,
    ctx: BfpContext,
    clock: Arc<dyn ServeClock>,
    policy: AdmissionPolicy,
    models: Vec<ResidentModel>,
    queue: BoundedQueue,
    plans: PlanCache,
    metrics: ServeMetrics,
    guard: GuardStats,
    next_id: u64,
    completions: Vec<Completion>,
    scratch_a: Vec<f32>,
    scratch_out: Vec<f32>,
}

impl InferenceServer {
    pub fn new(cfg: ServeConfig, ctx: BfpContext, clock: Arc<dyn ServeClock>) -> InferenceServer {
        let cfg = cfg.normalized();
        let policy = AdmissionPolicy {
            capacity: cfg.queue_capacity,
            elevated_depth: cfg.elevated_depth,
            degrade_depth: cfg.degrade_depth,
            shed_depth: cfg.shed_depth,
            est_ticks_per_row: cfg.est_ticks_per_row,
        };
        InferenceServer {
            policy,
            queue: BoundedQueue::new(cfg.queue_capacity),
            plans: PlanCache::new(16),
            metrics: ServeMetrics::default(),
            guard: GuardStats::default(),
            next_id: 0,
            completions: Vec::new(),
            scratch_a: Vec::new(),
            scratch_out: Vec::new(),
            models: Vec::new(),
            cfg,
            ctx,
            clock,
        }
    }

    /// Quantize + pack `weights` (row-major `k x n`) resident at both
    /// serving widths; returns the model handle used by `submit`.
    pub fn register_model(
        &mut self,
        name: &str,
        weights: &[f32],
        k: usize,
        n: usize,
    ) -> Result<usize> {
        let model = ResidentModel::load(
            &self.ctx,
            name,
            weights,
            k,
            n,
            self.cfg.full_bits,
            self.cfg.degraded_bits,
        )?;
        self.models.push(model);
        Ok(self.models.len() - 1)
    }

    pub fn model(&self, idx: usize) -> Option<&ResidentModel> {
        self.models.get(idx)
    }

    pub fn context(&self) -> &BfpContext {
        &self.ctx
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    pub fn guard_snapshot(&self) -> GuardStatsSnapshot {
        self.guard.snapshot()
    }

    pub fn plan_cache(&self) -> &PlanCache {
        &self.plans
    }

    /// Admission control + enqueue. `deadline_in` is relative ticks from
    /// now (falls back to the config default). An `Err` is a caller bug
    /// (unknown model, wrong input length); refusal under load is the
    /// `Ok(Submission::Rejected(_))` backpressure path.
    pub fn submit(
        &mut self,
        model: usize,
        input: Vec<f32>,
        deadline_in: Option<u64>,
    ) -> Result<Submission> {
        let k = self
            .models
            .get(model)
            .ok_or_else(|| anyhow!("no model #{model} registered"))?
            .k();
        if input.len() != k {
            return Err(anyhow!(
                "model #{model} takes {k} input features, got {}",
                input.len()
            ));
        }
        let now = self.clock.now();
        let rel = deadline_in.unwrap_or(self.cfg.default_deadline_ticks);
        let deadline = now.saturating_add(rel);
        match self.policy.decide(self.queue.depth(), now, deadline) {
            Err(rej) => {
                match rej {
                    Rejected::QueueFull => self.metrics.rejected_queue_full += 1,
                    Rejected::Overloaded => self.metrics.rejected_overloaded += 1,
                    Rejected::Shedding => self.metrics.rejected_shedding += 1,
                }
                Ok(Submission::Rejected(rej))
            }
            Ok(pressure) => {
                let id = self.next_id;
                self.next_id += 1;
                let req = QueuedRequest { id, model, input, deadline, submitted_at: now };
                self.queue
                    .push(req)
                    .map_err(|_| anyhow!("admission passed a full queue (policy bug)"))?;
                self.metrics.admitted += 1;
                self.metrics.note_depth(self.queue.depth());
                Ok(Submission::Admitted { id, pressure })
            }
        }
    }

    /// Terminal outcomes accumulated since the last drain, in completion
    /// order.
    pub fn drain_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Pump until the queue is empty, collecting per-batch reports.
    pub fn run_until_idle(&mut self) -> Result<Vec<PumpReport>> {
        let mut reports = Vec::new();
        while !self.queue.is_empty() {
            reports.push(self.pump()?);
        }
        Ok(reports)
    }

    /// One scheduler turn: expire dead work at dequeue, form one
    /// micro-batch, execute it, and settle every member's outcome.
    pub fn pump(&mut self) -> Result<PumpReport> {
        let now = self.clock.now();
        // Deadline enforcement point 1: already-dead requests are dropped
        // before they cost a GEMM.
        let dead = self.queue.drain_expired(now);
        let expired_at_dequeue = dead.len();
        for r in dead {
            self.metrics.expired_at_dequeue += 1;
            self.completions.push(Completion {
                id: r.id,
                model: r.model,
                outcome: Outcome::Expired(ExpiredAt::Dequeue),
            });
        }

        // Degrade decision reads post-expiry depth: the ladder's last
        // rung before refusal is serving at the narrow width.
        let depth = self.queue.depth();
        let degraded =
            depth >= self.cfg.degrade_depth && self.cfg.degraded_bits < self.cfg.full_bits;

        let Some(batch) = batcher::next_batch(&mut self.queue, self.cfg.max_batch_rows) else {
            return Ok(PumpReport { batch: None, expired_at_dequeue, failed_rows: 0 });
        };
        let model_idx = batch.model;
        let bits = if degraded {
            self.models[model_idx].degraded_bits()
        } else {
            self.models[model_idx].full_bits()
        };

        // Per-row hazard handling: fault probes, then a non-finite scan,
        // so one poisoned request fails alone instead of sinking the
        // batch at quantization time.
        let mut rows: Vec<QueuedRequest> = Vec::with_capacity(batch.requests.len());
        let mut failed_rows = 0usize;
        for mut r in batch.requests {
            if fault::fire(FaultSite::SlowRequest) {
                self.metrics.slow_requests += 1;
                self.clock.advance(self.cfg.slow_request_penalty_ticks);
            }
            if fault::fire(FaultSite::NanActivation) {
                if let Some(x) = r.input.first_mut() {
                    *x = f32::NAN;
                }
            }
            self.guard.record_scan();
            if let Some(err) = scan_nonfinite(&r.input, 1).error(&r.input) {
                self.guard.record_nonfinite();
                self.metrics.failed += 1;
                failed_rows += 1;
                self.completions.push(Completion {
                    id: r.id,
                    model: r.model,
                    outcome: Outcome::Failed(format!("rejected input: {err}")),
                });
                continue;
            }
            rows.push(r);
        }

        let (k, n) = (self.models[model_idx].k(), self.models[model_idx].n());
        let m = rows.len();
        let report = BatchReport {
            model: model_idx,
            ids: rows.iter().map(|r| r.id).collect(),
            bits,
            degraded,
            retries: 0,
            split_fallback: false,
        };
        if m == 0 {
            self.metrics.batches += 1;
            return Ok(PumpReport { batch: Some(report), expired_at_dequeue, failed_rows });
        }

        self.scratch_a.resize(m * k, 0.0);
        for (i, r) in rows.iter().enumerate() {
            self.scratch_a[i * k..(i + 1) * k].copy_from_slice(&r.input);
        }
        self.scratch_out.resize(m * n, 0.0);

        let plan = self.plans.get_or_plan(&self.ctx, m, k, n, (bits, bits))?;
        let weights = self.models[model_idx].weights_at(bits);
        let a = &self.scratch_a[..m * k];
        let out = &mut self.scratch_out[..m * n];

        // Attempt 1..=retries: the whole batch in one pool-parallel GEMM,
        // each contained panic redispatched bit-identically.
        let mut retries = 0usize;
        let mut whole_failed = None;
        loop {
            let attempt = catch_pool_panic(|| {
                plan.quantize_execute_into(a, &mut Rounding::NearestEven, weights, &mut *out)
            });
            match attempt {
                Ok(inner) => {
                    inner?;
                    break;
                }
                Err(p) => {
                    self.metrics.panics_contained += 1;
                    if retries >= self.cfg.max_gemm_retries {
                        whole_failed = Some(p);
                        break;
                    }
                    retries += 1;
                    self.metrics.gemm_retries += 1;
                }
            }
        }

        // Split fallback: per-row GEMMs isolate the damage to single
        // requests. (A 1-row dispatch runs inline — below the pool's
        // parallel floor — so injected worker faults cannot reach it.)
        let mut row_failed: Vec<Option<String>> = vec![None; m];
        let split_fallback = whole_failed.is_some();
        if let Some(panic) = whole_failed {
            self.metrics.split_fallbacks += 1;
            let row_plan = self.plans.get_or_plan(&self.ctx, 1, k, n, (bits, bits))?;
            for i in 0..m {
                let row_a = &a[i * k..(i + 1) * k];
                let row_out = &mut out[i * n..(i + 1) * n];
                let mut last = panic.message().to_string();
                let mut ok = false;
                for _ in 0..=self.cfg.max_gemm_retries {
                    let attempt = catch_pool_panic(|| {
                        row_plan.quantize_execute_into(
                            row_a,
                            &mut Rounding::NearestEven,
                            weights,
                            &mut *row_out,
                        )
                    });
                    match attempt {
                        Ok(inner) => {
                            inner?;
                            ok = true;
                            break;
                        }
                        Err(p) => {
                            self.metrics.panics_contained += 1;
                            last = p.message().to_string();
                        }
                    }
                }
                if !ok {
                    row_failed[i] = Some(last);
                }
            }
        }

        // Deterministic service-time model (manual-clock soaks) — the
        // batch costs ticks proportional to its rows.
        if self.cfg.synthetic_ticks_per_row > 0 {
            self.clock.advance(self.cfg.synthetic_ticks_per_row * m as u64);
        }

        // Deadline enforcement point 2: a result that arrives after its
        // deadline is reported expired, not served.
        let done = self.clock.now();
        for (i, r) in rows.iter().enumerate() {
            if let Some(msg) = row_failed[i].take() {
                self.metrics.failed += 1;
                failed_rows += 1;
                self.completions.push(Completion {
                    id: r.id,
                    model: r.model,
                    outcome: Outcome::Failed(format!("gemm dispatch failed: {msg}")),
                });
                continue;
            }
            if r.expired(done) {
                self.metrics.expired_at_completion += 1;
                self.completions.push(Completion {
                    id: r.id,
                    model: r.model,
                    outcome: Outcome::Expired(ExpiredAt::Completion),
                });
                continue;
            }
            let latency = done.saturating_sub(r.submitted_at);
            self.metrics.latency.record(latency);
            self.metrics.completed += 1;
            if degraded {
                self.metrics.degraded_served += 1;
            }
            self.completions.push(Completion {
                id: r.id,
                model: r.model,
                outcome: Outcome::Served(Response {
                    output: self.scratch_out[i * n..(i + 1) * n].to_vec(),
                    served_bits: bits,
                    degraded,
                    latency_ticks: latency,
                }),
            });
        }

        self.metrics.batches += 1;
        self.metrics.batched_rows += m as u64;
        let report = BatchReport { retries, split_fallback, ..report };
        Ok(PumpReport { batch: Some(report), expired_at_dequeue, failed_rows })
    }

    /// Full observability dump: serving counters + latency percentiles,
    /// numeric guard totals, and plan-cache effectiveness.
    pub fn metrics_json(&self) -> Json {
        Json::obj(vec![
            ("serve", self.metrics.to_json()),
            ("guard_stats", guard_stats_json(&self.guard.snapshot())),
            (
                "plan_cache",
                Json::obj(vec![
                    ("len", Json::num(self.plans.len() as f64)),
                    ("hits", Json::num(self.plans.hits() as f64)),
                    ("misses", Json::num(self.plans.misses() as f64)),
                    ("evictions", Json::num(self.plans.evictions() as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::{bfp_matmul_naive, TileSize};
    use crate::serve::clock::ManualClock;

    fn ramp(len: usize, phase: f32) -> Vec<f32> {
        (0..len).map(|i| ((i as f32) * 0.11 + phase).sin()).collect()
    }

    fn server(cfg: ServeConfig) -> (InferenceServer, Arc<ManualClock>) {
        let clock = Arc::new(ManualClock::new());
        let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
        (InferenceServer::new(cfg, ctx, clock.clone()), clock)
    }

    #[test]
    fn served_batch_is_bit_identical_to_naive() {
        let (mut srv, _clock) = server(ServeConfig::default());
        let k = 8;
        let n = 8;
        let w = ramp(k * n, 0.3);
        let model = srv.register_model("toy", &w, k, n).unwrap();

        let inputs: Vec<Vec<f32>> = (0..3).map(|i| ramp(k, i as f32)).collect();
        for input in &inputs {
            let sub = srv.submit(model, input.clone(), None).unwrap();
            assert!(sub.is_admitted());
        }
        let report = srv.pump().unwrap();
        let batch = report.batch.unwrap();
        assert_eq!(batch.ids.len(), 3);
        assert!(!batch.degraded);
        assert_eq!(batch.bits, 16);

        // naive reference over the same batch grouping and width
        let ctx = srv.context();
        let mut flat = Vec::new();
        for input in &inputs {
            flat.extend_from_slice(input);
        }
        let qa = ctx.quantize(&flat, 3, k, 16, &mut Rounding::NearestEven).unwrap();
        let want = bfp_matmul_naive(&qa, srv.model(model).unwrap().weights_at(16)).unwrap();

        let done = srv.drain_completions();
        assert_eq!(done.len(), 3);
        for (i, c) in done.iter().enumerate() {
            match &c.outcome {
                Outcome::Served(resp) => {
                    assert_eq!(resp.served_bits, 16);
                    assert!(!resp.degraded);
                    assert_eq!(resp.output, want[i * n..(i + 1) * n].to_vec());
                }
                other => panic!("request {i} not served: {other:?}"),
            }
        }
        assert_eq!(srv.metrics().completed, 3);
        assert_eq!(srv.metrics().batches, 1);
        assert_eq!(srv.metrics().batched_rows, 3);
        assert_eq!(srv.plan_cache().misses(), 1);
    }

    #[test]
    fn ladder_degrades_then_sheds_then_fills() {
        let cfg = ServeConfig {
            queue_capacity: 8,
            elevated_depth: 2,
            degrade_depth: 3,
            shed_depth: 6,
            max_batch_rows: 4,
            ..ServeConfig::default()
        };
        let (mut srv, _clock) = server(cfg);
        let k = 4;
        let model = srv.register_model("toy", &ramp(k * 4, 0.0), k, 4).unwrap();

        let mut pressures = Vec::new();
        let mut rejections = Vec::new();
        for i in 0..8 {
            match srv.submit(model, ramp(k, i as f32), None).unwrap() {
                Submission::Admitted { pressure, .. } => pressures.push(pressure),
                Submission::Rejected(r) => rejections.push(r),
            }
        }
        assert_eq!(
            pressures,
            vec![
                Pressure::Nominal,
                Pressure::Nominal,
                Pressure::Elevated,
                Pressure::Degraded,
                Pressure::Degraded,
                Pressure::Degraded,
            ]
        );
        assert_eq!(rejections, vec![Rejected::Shedding, Rejected::Shedding]);
        assert_eq!(srv.metrics().rejected_shedding, 2);
        assert_eq!(srv.metrics().max_queue_depth, 6);

        // depth 6 >= degrade_depth -> first batch served narrow + flagged
        let report = srv.pump().unwrap();
        let batch = report.batch.unwrap();
        assert!(batch.degraded);
        assert_eq!(batch.bits, 8);
        let served: Vec<_> = srv
            .drain_completions()
            .into_iter()
            .filter_map(|c| match c.outcome {
                Outcome::Served(r) => Some(r),
                _ => None,
            })
            .collect();
        assert_eq!(served.len(), 4);
        assert!(served.iter().all(|r| r.degraded && r.served_bits == 8));
        assert_eq!(srv.metrics().degraded_served, 4);

        // backlog drained below the watermark -> service recovers
        let report = srv.pump().unwrap();
        assert!(!report.batch.unwrap().degraded);
    }

    #[test]
    fn overloaded_deadline_is_refused_at_admission() {
        let cfg = ServeConfig { est_ticks_per_row: 100, ..ServeConfig::default() };
        let (mut srv, _clock) = server(cfg);
        let model = srv.register_model("toy", &ramp(16, 0.0), 4, 4).unwrap();
        srv.submit(model, ramp(4, 0.0), None).unwrap();
        // backlog estimate (1+1)*100 = 200 > 150
        let sub = srv.submit(model, ramp(4, 1.0), Some(150)).unwrap();
        assert_eq!(sub, Submission::Rejected(Rejected::Overloaded));
        assert_eq!(srv.metrics().rejected_overloaded, 1);
        // a feasible deadline on the same queue is admitted
        assert!(srv.submit(model, ramp(4, 2.0), Some(250)).unwrap().is_admitted());
    }

    #[test]
    fn deadlines_expire_at_dequeue_and_completion() {
        let cfg = ServeConfig { synthetic_ticks_per_row: 100, ..ServeConfig::default() };
        let (mut srv, clock) = server(cfg);
        let model = srv.register_model("toy", &ramp(16, 0.0), 4, 4).unwrap();

        // expires in the queue: deadline 50, clock jumps to 60
        let a = srv.submit(model, ramp(4, 0.0), Some(50)).unwrap().id().unwrap();
        // expires at completion: deadline 150, batch costs 2*100 ticks
        let b = srv.submit(model, ramp(4, 1.0), Some(150)).unwrap().id().unwrap();
        // survives: no deadline
        let c = srv.submit(model, ramp(4, 2.0), None).unwrap().id().unwrap();
        clock.advance(60);
        let report = srv.pump().unwrap();
        assert_eq!(report.expired_at_dequeue, 1);
        assert_eq!(report.batch.as_ref().unwrap().ids, vec![b, c]);

        let done = srv.drain_completions();
        let outcome = |id: u64| done.iter().find(|x| x.id == id).unwrap().outcome.clone();
        assert_eq!(outcome(a), Outcome::Expired(ExpiredAt::Dequeue));
        assert_eq!(outcome(b), Outcome::Expired(ExpiredAt::Completion));
        assert!(matches!(outcome(c), Outcome::Served(_)));
        assert_eq!(srv.metrics().expired_at_dequeue, 1);
        assert_eq!(srv.metrics().expired_at_completion, 1);
        assert_eq!(srv.metrics().latency.count(), 1);
        assert_eq!(srv.metrics().latency.max(), 260); // 60 wait + 200 service
    }

    #[test]
    fn nonfinite_input_fails_only_its_own_request() {
        let (mut srv, _clock) = server(ServeConfig::default());
        let k = 4;
        let n = 4;
        let model = srv.register_model("toy", &ramp(k * n, 0.0), k, n).unwrap();
        let good = ramp(k, 1.0);
        srv.submit(model, good.clone(), None).unwrap();
        let mut bad = ramp(k, 2.0);
        bad[2] = f32::INFINITY;
        let bad_id = srv.submit(model, bad, None).unwrap().id().unwrap();
        srv.submit(model, good.clone(), None).unwrap();

        let report = srv.pump().unwrap();
        assert_eq!(report.failed_rows, 1);
        assert_eq!(report.batch.as_ref().unwrap().ids.len(), 2);

        let done = srv.drain_completions();
        assert_eq!(done.len(), 3);
        let failed: Vec<u64> = done
            .iter()
            .filter(|x| matches!(x.outcome, Outcome::Failed(_)))
            .map(|x| x.id)
            .collect();
        assert_eq!(failed, vec![bad_id]);
        assert_eq!(srv.metrics().failed, 1);
        assert_eq!(srv.metrics().completed, 2);
        let snap = srv.guard_snapshot();
        assert_eq!(snap.scans, 3);
        assert_eq!(snap.nonfinite_inputs, 1);
    }

    #[test]
    fn submit_rejects_caller_bugs_as_errors() {
        let (mut srv, _clock) = server(ServeConfig::default());
        assert!(srv.submit(0, vec![1.0], None).is_err());
        let model = srv.register_model("toy", &ramp(16, 0.0), 4, 4).unwrap();
        assert!(srv.submit(model, vec![1.0; 3], None).is_err());
    }

    #[test]
    fn metrics_json_has_all_three_sections() {
        let (mut srv, _clock) = server(ServeConfig::default());
        let model = srv.register_model("toy", &ramp(16, 0.0), 4, 4).unwrap();
        srv.submit(model, ramp(4, 0.0), None).unwrap();
        srv.run_until_idle().unwrap();
        let j = srv.metrics_json();
        assert!(j.get("serve").is_some());
        assert!(j.get("guard_stats").is_some());
        let pc = j.get("plan_cache").unwrap();
        assert_eq!(pc.get("misses").and_then(|v| v.as_i64()), Some(1));
    }
}
