//! The synchronous-core multi-tenant inference server.
//!
//! `submit` runs admission control and enqueues into the target tenant's
//! own bounded queue; `pump` takes one deficit-round-robin scheduler turn
//! ([`super::scheduler`]), enforces deadlines at dequeue and again at
//! completion, executes the skinny GEMM against resident packed weights
//! through the shape-keyed plan cache, and contains every per-request
//! hazard:
//!
//! - a non-finite activation row (including the `nan-activation` fault
//!   site) fails *that request only* — the row is scanned and dropped
//!   before batch assembly;
//! - a contained worker panic ([`crate::util::pool::PoolPanic`], e.g. the
//!   `worker-panic` fault site) triggers whole-batch redispatch up to
//!   `max_gemm_retries`, then a per-row split fallback so one poisoned
//!   dispatch cannot take down its batch-mates;
//! - the `slow-request` fault site stalls a single request's assembly,
//!   exercising the completion-time deadline check;
//! - repeated failures attributable to one resident model trip its
//!   circuit breaker ([`super::breaker`]): its pending queue is flushed,
//!   new submissions get [`Rejected::Quarantined`], and dispatch skips
//!   it until deterministic half-open probes prove it healthy again.
//!
//! Two lifecycle operations run *off* the serving path:
//!
//! - [`InferenceServer::reload_model`] quantizes + panel-packs a weight
//!   candidate, validates it (finite scan + golden-row bit-check against
//!   [`crate::bfp::bfp_matmul_naive`] at both serving widths — which is
//!   what catches the `reload-garble` fault site), and only then
//!   atomically swaps the model generation; a failed validation rolls
//!   back to the serving generation with a typed [`ReloadError`].
//! - [`InferenceServer::begin_drain`] moves `Running -> Draining`:
//!   admission closes with [`Rejected::Draining`], admitted work keeps
//!   pumping, and whatever remains at the drain deadline is
//!   force-expired; [`InferenceServer::run_until_stopped`] then lands in
//!   `Stopped` with a conservation-checked [`DrainReport`].
//!
//! Everything the server does is observable in [`ServeMetrics`]
//! (global + per-tenant counters and latency percentiles, breaker and
//! reload events) plus the numeric [`GuardStats`], all surfaced by
//! [`InferenceServer::metrics_json`].

use std::fmt;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::bfp::stats::scan_nonfinite;
use crate::bfp::{bfp_matmul_naive, BfpContext, GuardStats, GuardStatsSnapshot, PlanCache, Rounding};
use crate::coordinator::metrics::{guard_stats_json, ModelMetrics, ServeMetrics};
use crate::util::fault::{self, FaultSite};
use crate::util::json::Json;
use crate::util::pool::catch_pool_panic;

use super::admission::{AdmissionPolicy, Pressure, Rejected};
use super::breaker::{BreakerConfig, BreakerState, CircuitBreaker};
use super::clock::ServeClock;
use super::queue::QueuedRequest;
use super::scheduler::FairScheduler;
use super::session::ResidentModel;

/// Serving knobs. Depth watermarks are normalized at server construction
/// to `elevated <= degrade <= shed <= capacity`; under multi-tenancy the
/// ladder applies to each tenant's *own* queue depth.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Hard bound on queued requests, per tenant queue.
    pub queue_capacity: usize,
    /// Depth at which admitted callers are told [`Pressure::Elevated`].
    pub elevated_depth: usize,
    /// Depth at which service drops to the degraded width.
    pub degrade_depth: usize,
    /// Depth at which new requests are refused ([`Rejected::Shedding`]).
    pub shed_depth: usize,
    /// Micro-batch row cap (the skinny-GEMM m).
    pub max_batch_rows: usize,
    /// DRR credit granted per unit of share on each scheduler visit.
    /// With a single tenant and `drr_quantum_rows >= max_batch_rows`
    /// batching is identical to plain head-of-line coalescing.
    pub drr_quantum_rows: usize,
    /// Mantissa width for nominal service.
    pub full_bits: u32,
    /// Mantissa width for degraded service (last rung before refusal).
    pub degraded_bits: u32,
    /// Relative deadline applied when `submit` gets `None`
    /// (`u64::MAX` = no deadline).
    pub default_deadline_ticks: u64,
    /// Per-row service-time estimate for the admission feasibility
    /// screen; 0 disables [`Rejected::Overloaded`].
    pub est_ticks_per_row: u64,
    /// Ticks charged per served row on the serve clock (deterministic
    /// service-time model for manual-clock tests; 0 = off).
    pub synthetic_ticks_per_row: u64,
    /// Stall charged when the `slow-request` fault site fires.
    pub slow_request_penalty_ticks: u64,
    /// Whole-batch redispatches after a contained panic before the
    /// per-row split fallback kicks in.
    pub max_gemm_retries: usize,
    /// Per-tenant circuit-breaker thresholds.
    pub breaker: BreakerConfig,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            queue_capacity: 64,
            elevated_depth: 16,
            degrade_depth: 32,
            shed_depth: 48,
            max_batch_rows: 8,
            drr_quantum_rows: 8,
            full_bits: 16,
            degraded_bits: 8,
            default_deadline_ticks: u64::MAX,
            est_ticks_per_row: 0,
            synthetic_ticks_per_row: 0,
            slow_request_penalty_ticks: 2_000,
            max_gemm_retries: 2,
            breaker: BreakerConfig::default(),
        }
    }
}

impl ServeConfig {
    fn normalized(mut self) -> ServeConfig {
        self.queue_capacity = self.queue_capacity.max(1);
        self.max_batch_rows = self.max_batch_rows.max(1);
        self.drr_quantum_rows = self.drr_quantum_rows.max(1);
        self.shed_depth = self.shed_depth.min(self.queue_capacity);
        self.degrade_depth = self.degrade_depth.min(self.shed_depth);
        self.elevated_depth = self.elevated_depth.min(self.degrade_depth);
        self
    }
}

/// Server lifecycle: `Running` (admitting) → `Draining` (admission
/// closed, pumping admitted work toward a deadline) → `Stopped` (queues
/// empty, nothing will ever run again).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lifecycle {
    Running,
    Draining { deadline: u64 },
    Stopped,
}

impl Lifecycle {
    pub fn name(&self) -> &'static str {
        match self {
            Lifecycle::Running => "running",
            Lifecycle::Draining { .. } => "draining",
            Lifecycle::Stopped => "stopped",
        }
    }
}

/// Outcome of `submit`: either queued (with the pressure signal the
/// caller should throttle on) or refused with a typed reason.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Submission {
    Admitted { id: u64, pressure: Pressure },
    Rejected(Rejected),
}

impl Submission {
    pub fn is_admitted(&self) -> bool {
        matches!(self, Submission::Admitted { .. })
    }

    pub fn id(&self) -> Option<u64> {
        match self {
            Submission::Admitted { id, .. } => Some(*id),
            Submission::Rejected(_) => None,
        }
    }
}

/// Where a request's deadline was found to have passed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpiredAt {
    /// Dead before service: dropped at dequeue, no GEMM spent.
    Dequeue,
    /// Served, but the result arrived after the deadline.
    Completion,
    /// Force-expired: still queued when the drain deadline landed.
    DrainDeadline,
}

/// A successful inference result.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub output: Vec<f32>,
    /// Mantissa width actually served.
    pub served_bits: u32,
    /// True when the load-shed ladder narrowed this request's precision.
    pub degraded: bool,
    /// Weight generation that produced this output (bumped by reloads).
    pub generation: u64,
    pub latency_ticks: u64,
}

/// Terminal state of one admitted request.
#[derive(Debug, Clone, PartialEq)]
pub enum Outcome {
    Served(Response),
    Expired(ExpiredAt),
    /// This request failed (bad input, unrecoverable dispatch, or its
    /// model was quarantined); its batch-mates were unaffected.
    Failed(String),
}

/// Request id + terminal outcome, delivered via `drain_completions`.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub id: u64,
    pub model: usize,
    pub outcome: Outcome,
}

/// What one `pump` call did to the batch it formed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchReport {
    pub model: usize,
    /// Ids of the rows that reached GEMM assembly, in batch-row order.
    pub ids: Vec<u64>,
    /// Width this batch was served at.
    pub bits: u32,
    pub degraded: bool,
    /// Weight generation this batch executed against.
    pub generation: u64,
    /// Whole-batch redispatches after contained panics.
    pub retries: usize,
    /// True when the batch fell back to per-row GEMMs (outputs are then
    /// quantized per row, not per batch).
    pub split_fallback: bool,
}

#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct PumpReport {
    pub batch: Option<BatchReport>,
    pub expired_at_dequeue: usize,
    /// Rows that terminated as [`Outcome::Failed`] this pump.
    pub failed_rows: usize,
    /// Requests force-expired because the drain deadline landed.
    pub force_expired: usize,
}

impl PumpReport {
    /// Did this pump settle or serve anything at all?
    pub fn made_progress(&self) -> bool {
        self.batch.is_some()
            || self.expired_at_dequeue > 0
            || self.failed_rows > 0
            || self.force_expired > 0
    }
}

/// Typed failure of [`InferenceServer::reload_model`]. On any variant the
/// previous generation keeps serving untouched — a failed reload rolls
/// back, it never degrades the running model or trips its breaker.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReloadError {
    UnknownModel(usize),
    ShapeMismatch { expected: usize, got: usize },
    /// The candidate failed validation (non-finite weights, or the
    /// golden-row bit-check against the naive reference diverged at one
    /// of the serving widths).
    Validation(String),
}

impl fmt::Display for ReloadError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReloadError::UnknownModel(m) => write!(f, "no model #{m} registered"),
            ReloadError::ShapeMismatch { expected, got } => {
                write!(f, "weight shape mismatch: expected {expected} values, got {got}")
            }
            ReloadError::Validation(msg) => write!(f, "candidate failed validation: {msg}"),
        }
    }
}

impl std::error::Error for ReloadError {}

/// A successful hot reload: the generation swap that happened.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReloadReport {
    pub model: usize,
    pub old_generation: u64,
    pub new_generation: u64,
    /// Widths the golden-row bit-check validated (full, degraded).
    pub validated_widths: (u32, u32),
}

/// Final accounting from [`InferenceServer::run_until_stopped`]: every
/// admitted request must be accounted exactly once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DrainReport {
    /// Pumps executed between `begin_drain` taking effect and `Stopped`.
    pub pumps: u64,
    pub admitted: u64,
    pub served: u64,
    /// Deadline expiries (dequeue + completion).
    pub expired: u64,
    /// Force-expired at the drain deadline.
    pub force_expired: u64,
    pub failed: u64,
    /// `admitted == served + expired + force_expired + failed` and every
    /// queue is empty.
    pub conserved: bool,
}

/// Breaker settlement event, applied in row order after a batch.
enum Settle {
    Success,
    Failure,
    ProbeExpired,
}

/// The serving front-end. Single-threaded control loop over the
/// pool-parallel BFP datapath: callers `submit`, something drives `pump`,
/// results come back through `drain_completions`.
pub struct InferenceServer {
    cfg: ServeConfig,
    ctx: BfpContext,
    clock: Arc<dyn ServeClock>,
    policy: AdmissionPolicy,
    lifecycle: Lifecycle,
    models: Vec<ResidentModel>,
    breakers: Vec<CircuitBreaker>,
    sched: FairScheduler,
    plans: PlanCache,
    metrics: ServeMetrics,
    guard: GuardStats,
    next_id: u64,
    completions: Vec<Completion>,
    scratch_a: Vec<f32>,
    scratch_out: Vec<f32>,
}

impl InferenceServer {
    pub fn new(cfg: ServeConfig, ctx: BfpContext, clock: Arc<dyn ServeClock>) -> InferenceServer {
        let cfg = cfg.normalized();
        let policy = AdmissionPolicy {
            capacity: cfg.queue_capacity,
            elevated_depth: cfg.elevated_depth,
            degrade_depth: cfg.degrade_depth,
            shed_depth: cfg.shed_depth,
            est_ticks_per_row: cfg.est_ticks_per_row,
        };
        InferenceServer {
            policy,
            lifecycle: Lifecycle::Running,
            sched: FairScheduler::new(cfg.queue_capacity, cfg.drr_quantum_rows),
            plans: PlanCache::new(16),
            metrics: ServeMetrics::default(),
            guard: GuardStats::default(),
            next_id: 0,
            completions: Vec::new(),
            scratch_a: Vec::new(),
            scratch_out: Vec::new(),
            models: Vec::new(),
            breakers: Vec::new(),
            cfg,
            ctx,
            clock,
        }
    }

    /// Quantize + pack `weights` (row-major `k x n`) resident at both
    /// serving widths with DRR share 1; returns the model handle used by
    /// `submit`.
    pub fn register_model(
        &mut self,
        name: &str,
        weights: &[f32],
        k: usize,
        n: usize,
    ) -> Result<usize> {
        self.register_model_with_share(name, weights, k, n, 1)
    }

    /// `register_model` with an explicit fair-share weight: a tenant with
    /// share `s` is granted `s * drr_quantum_rows` rows of credit per
    /// scheduler round.
    pub fn register_model_with_share(
        &mut self,
        name: &str,
        weights: &[f32],
        k: usize,
        n: usize,
        share: u32,
    ) -> Result<usize> {
        let model = ResidentModel::load(
            &self.ctx,
            name,
            weights,
            k,
            n,
            self.cfg.full_bits,
            self.cfg.degraded_bits,
        )?;
        self.models.push(model);
        self.breakers.push(CircuitBreaker::new(self.cfg.breaker));
        let idx = self.sched.add_tenant(share);
        debug_assert_eq!(idx, self.models.len() - 1);
        self.metrics.models.push(ModelMetrics {
            name: name.to_string(),
            share: share.max(1),
            ..ModelMetrics::default()
        });
        Ok(idx)
    }

    pub fn model(&self, idx: usize) -> Option<&ResidentModel> {
        self.models.get(idx)
    }

    pub fn breaker_state(&self, idx: usize) -> Option<BreakerState> {
        self.breakers.get(idx).map(|b| b.state())
    }

    pub fn lifecycle(&self) -> Lifecycle {
        self.lifecycle
    }

    /// Readiness: admitting new work (the health-check bit).
    pub fn is_ready(&self) -> bool {
        matches!(self.lifecycle, Lifecycle::Running)
    }

    pub fn context(&self) -> &BfpContext {
        &self.ctx
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Total queued rows across every tenant.
    pub fn queue_depth(&self) -> usize {
        self.sched.total_depth()
    }

    /// One tenant's queued rows.
    pub fn model_queue_depth(&self, idx: usize) -> usize {
        self.sched.depth(idx)
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    pub fn guard_snapshot(&self) -> GuardStatsSnapshot {
        self.guard.snapshot()
    }

    pub fn plan_cache(&self) -> &PlanCache {
        &self.plans
    }

    /// Admission control + enqueue. `deadline_in` is relative ticks from
    /// now (falls back to the config default). An `Err` is a caller bug
    /// (unknown model, wrong input length); refusal under load or
    /// quarantine is the `Ok(Submission::Rejected(_))` backpressure path.
    pub fn submit(
        &mut self,
        model: usize,
        input: Vec<f32>,
        deadline_in: Option<u64>,
    ) -> Result<Submission> {
        let k = self
            .models
            .get(model)
            .ok_or_else(|| anyhow!("no model #{model} registered"))?
            .k();
        if input.len() != k {
            return Err(anyhow!(
                "model #{model} takes {k} input features, got {}",
                input.len()
            ));
        }
        if !matches!(self.lifecycle, Lifecycle::Running) {
            self.metrics.rejected_draining += 1;
            return Ok(Submission::Rejected(Rejected::Draining));
        }
        let now = self.clock.now();
        let rel = deadline_in.unwrap_or(self.cfg.default_deadline_ticks);
        let deadline = now.saturating_add(rel);
        // The watermark ladder reads the *target tenant's* depth: one
        // tenant's backlog never sheds another tenant's requests.
        match self.policy.decide(self.sched.depth(model), now, deadline) {
            Err(rej) => {
                match rej {
                    Rejected::QueueFull => self.metrics.rejected_queue_full += 1,
                    Rejected::Overloaded => self.metrics.rejected_overloaded += 1,
                    Rejected::Shedding => self.metrics.rejected_shedding += 1,
                    Rejected::Quarantined | Rejected::Draining => unreachable!("policy ladder"),
                }
                Ok(Submission::Rejected(rej))
            }
            Ok(pressure) => {
                // Breaker gate last, so a request the ladder would have
                // refused anyway never consumes a half-open probe slot.
                if !self.breakers[model].admit(now) {
                    self.metrics.rejected_quarantined += 1;
                    self.metrics.models[model].quarantined += 1;
                    return Ok(Submission::Rejected(Rejected::Quarantined));
                }
                let id = self.next_id;
                self.next_id += 1;
                let req = QueuedRequest { id, model, input, deadline, submitted_at: now };
                self.sched
                    .push(req)
                    .map_err(|_| anyhow!("admission passed a full queue (policy bug)"))?;
                self.metrics.admitted += 1;
                self.metrics.models[model].admitted += 1;
                self.metrics.note_depth(self.sched.total_depth());
                Ok(Submission::Admitted { id, pressure })
            }
        }
    }

    /// Terminal outcomes accumulated since the last drain, in completion
    /// order.
    pub fn drain_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Pump until every queue is empty, collecting per-batch reports.
    /// When all remaining work belongs to quarantined (cooling) tenants,
    /// the clock is advanced to the earliest breaker re-probe point so
    /// the loop provably terminates.
    pub fn run_until_idle(&mut self) -> Result<Vec<PumpReport>> {
        let mut reports = Vec::new();
        while !self.sched.is_empty() {
            let report = self.pump()?;
            let stalled = !report.made_progress();
            reports.push(report);
            if stalled && !self.sched.is_empty() {
                match self.earliest_unblock() {
                    Some(at) => {
                        let now = self.clock.now();
                        self.clock.advance(at.saturating_sub(now).max(1));
                    }
                    None => break, // defensive: nothing dispatchable, nothing cooling
                }
            }
        }
        Ok(reports)
    }

    /// Close admission and set the drain deadline (relative ticks from
    /// now). Idempotent while draining; an error once stopped.
    pub fn begin_drain(&mut self, deadline_in: u64) -> Result<u64> {
        match self.lifecycle {
            Lifecycle::Stopped => Err(anyhow!("server already stopped")),
            Lifecycle::Draining { deadline } => Ok(deadline),
            Lifecycle::Running => {
                let deadline = self.clock.now().saturating_add(deadline_in);
                self.lifecycle = Lifecycle::Draining { deadline };
                Ok(deadline)
            }
        }
    }

    /// Pump admitted work to completion or expiry, force-expire whatever
    /// is still queued when the drain deadline lands, and stop. Requires
    /// `begin_drain` first. Returns the conservation-checked accounting.
    pub fn run_until_stopped(&mut self) -> Result<DrainReport> {
        let Lifecycle::Draining { deadline } = self.lifecycle else {
            return Err(anyhow!(
                "run_until_stopped requires begin_drain (lifecycle is {})",
                self.lifecycle.name()
            ));
        };
        let mut pumps = 0u64;
        while !self.sched.is_empty() {
            let report = self.pump()?;
            pumps += 1;
            if !report.made_progress() && !self.sched.is_empty() {
                // Every non-empty tenant is quarantined: march the clock
                // to the earlier of its re-probe point and the drain
                // deadline (where force-expiry clears the rest).
                let now = self.clock.now();
                let target = self.earliest_unblock().unwrap_or(deadline).min(deadline);
                self.clock.advance(target.saturating_sub(now).max(1));
            }
        }
        self.lifecycle = Lifecycle::Stopped;
        let m = &self.metrics;
        let served = m.completed;
        let expired = m.expired_at_dequeue + m.expired_at_completion;
        let force_expired = m.expired_at_drain;
        let failed = m.failed;
        Ok(DrainReport {
            pumps,
            admitted: m.admitted,
            served,
            expired,
            force_expired,
            failed,
            conserved: m.admitted == served + expired + force_expired + failed
                && self.sched.is_empty(),
        })
    }

    /// Hot weight reload: build + validate a candidate **off the serving
    /// path**, then atomically swap generations. In-flight work is
    /// untouched (the swap happens between pumps, and already-formed
    /// batches hold the old tensors); queued requests simply serve on the
    /// new generation. A failed validation leaves the old generation
    /// serving and trips nothing.
    pub fn reload_model(
        &mut self,
        model: usize,
        weights: &[f32],
    ) -> std::result::Result<ReloadReport, ReloadError> {
        let old = self.models.get(model).ok_or(ReloadError::UnknownModel(model))?;
        let (k, n) = (old.k(), old.n());
        if weights.len() != k * n {
            return Err(ReloadError::ShapeMismatch { expected: k * n, got: weights.len() });
        }
        let name = old.name().to_string();
        let old_generation = old.generation();

        // The build copy is the unit the `reload-garble` fault corrupts —
        // standing in for a torn read or bad deserialization on the
        // reload path. The corruption is finite on purpose: it must be
        // the golden-row bit-check that catches it, not the NaN guard.
        let mut build = weights.to_vec();
        if fault::fire(FaultSite::ReloadGarble) {
            for x in build.iter_mut().step_by(7) {
                *x = *x * -1.75 + 0.125;
            }
        }

        // Caller-input sanity: non-finite weights are a validation
        // failure, not a panic inside quantization.
        self.guard.record_scan();
        if let Some(err) = scan_nonfinite(weights, k).error(weights) {
            self.guard.record_nonfinite();
            self.metrics.reload_rollbacks += 1;
            return Err(ReloadError::Validation(format!("non-finite weights: {err}")));
        }

        // Candidate build and validation both dispatch on the worker
        // pool (quantize, panel packing, the golden-row GEMM), whose
        // single-lane and re-raise paths unwind the *caller*. A reload
        // must never crash a serving process, so both are contained: an
        // injected or real panic here is a validation failure that rolls
        // back, exactly like a garbled build.
        let built = catch_pool_panic(|| {
            ResidentModel::load(
                &self.ctx,
                &name,
                &build,
                k,
                n,
                self.cfg.full_bits,
                self.cfg.degraded_bits,
            )
        });
        let candidate = match built {
            Ok(Ok(c)) => c,
            Ok(Err(e)) => {
                self.metrics.reload_rollbacks += 1;
                return Err(ReloadError::Validation(e.to_string()));
            }
            Err(p) => {
                self.metrics.reload_rollbacks += 1;
                return Err(ReloadError::Validation(format!(
                    "panic contained during candidate build: {p}"
                )));
            }
        };

        match catch_pool_panic(|| self.validate_candidate(&candidate, weights, k, n)) {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => {
                self.metrics.reload_rollbacks += 1;
                return Err(ReloadError::Validation(msg));
            }
            Err(p) => {
                self.metrics.reload_rollbacks += 1;
                return Err(ReloadError::Validation(format!(
                    "panic contained during candidate validation: {p}"
                )));
            }
        }

        let mut candidate = candidate;
        candidate.set_generation(old_generation + 1);
        self.models[model] = candidate;
        self.metrics.reloads += 1;
        Ok(ReloadReport {
            model,
            old_generation,
            new_generation: old_generation + 1,
            validated_widths: (self.cfg.full_bits, self.cfg.degraded_bits),
        })
    }

    /// Golden-row validation: quantize the *pristine* caller weights
    /// through the same path the candidate took, run one probe row
    /// through the planned datapath against the candidate and through
    /// `bfp_matmul_naive` against the reference, and demand bitwise
    /// equality at both serving widths. Any corruption of the candidate's
    /// build (the `reload-garble` site) diverges the mantissas and fails
    /// here.
    fn validate_candidate(
        &self,
        candidate: &ResidentModel,
        pristine: &[f32],
        k: usize,
        n: usize,
    ) -> std::result::Result<(), String> {
        let golden: Vec<f32> = (0..k).map(|i| ((i % 11) as f32 - 5.0) * 0.3 + 0.05).collect();
        let reference_full = self
            .ctx
            .quantize(pristine, k, n, self.cfg.full_bits, &mut Rounding::NearestEven)
            .map_err(|e| format!("reference quantization: {e}"))?;
        let mut widths = vec![(self.cfg.full_bits, None)];
        if self.cfg.degraded_bits < self.cfg.full_bits {
            let narrow = reference_full
                .narrow_view(self.cfg.degraded_bits, &mut Rounding::NearestEven)
                .map_err(|e| format!("reference narrow view: {e}"))?;
            widths.push((self.cfg.degraded_bits, Some(narrow)));
        }
        for (bits, narrow_ref) in &widths {
            let bits = *bits;
            let qa = self
                .ctx
                .quantize(&golden, 1, k, bits, &mut Rounding::NearestEven)
                .map_err(|e| format!("golden-row quantization at {bits}b: {e}"))?;
            let plan = self
                .ctx
                .plan_matmul(1, k, n, (bits, bits))
                .map_err(|e| format!("golden-row plan at {bits}b: {e}"))?;
            let got = plan
                .execute(&qa, candidate.weights_at(bits))
                .map_err(|e| format!("golden-row execute at {bits}b: {e}"))?;
            let reference = narrow_ref.as_ref().unwrap_or(&reference_full);
            let want = bfp_matmul_naive(&qa, reference)
                .map_err(|e| format!("golden-row reference at {bits}b: {e}"))?;
            let diverged = got.len() != want.len()
                || got.iter().zip(&want).any(|(g, w)| g.to_bits() != w.to_bits());
            if diverged {
                return Err(format!(
                    "golden-row bit-check diverged at {bits}b (candidate does not match \
                     the naive reference built from the submitted weights)"
                ));
            }
        }
        Ok(())
    }

    /// Apply one breaker settlement for `model`, handling trip/recovery
    /// bookkeeping. A trip flushes the tenant's pending queue: its
    /// requests fail immediately (typed, accounted) instead of rotting
    /// until their deadlines while dispatch skips the tenant.
    fn settle_breaker(&mut self, model: usize, event: Settle, now: u64) {
        match event {
            Settle::Success => {
                if self.breakers[model].record_success() {
                    self.metrics.breaker_recoveries += 1;
                }
            }
            Settle::ProbeExpired => self.breakers[model].probe_expired(),
            Settle::Failure => {
                if self.breakers[model].record_failure(now) {
                    self.metrics.breaker_trips += 1;
                    for r in self.sched.drain_tenant(model) {
                        self.metrics.failed += 1;
                        self.metrics.models[model].failed += 1;
                        self.completions.push(Completion {
                            id: r.id,
                            model,
                            outcome: Outcome::Failed(
                                "model quarantined (circuit breaker open)".into(),
                            ),
                        });
                    }
                }
            }
        }
    }

    /// Earliest tick at which some quarantined tenant with pending work
    /// becomes dispatchable again; `None` when no such tenant exists.
    fn earliest_unblock(&self) -> Option<u64> {
        (0..self.models.len())
            .filter(|&i| self.sched.depth(i) > 0)
            .filter_map(|i| match self.breakers[i].state() {
                BreakerState::Open { until } => Some(until),
                _ => None,
            })
            .min()
    }

    /// One scheduler turn: expire dead work at dequeue, take one DRR
    /// micro-batch, execute it, and settle every member's outcome (and
    /// its tenant's breaker).
    pub fn pump(&mut self) -> Result<PumpReport> {
        let _span = crate::obs::trace::span("serve.pump");
        if matches!(self.lifecycle, Lifecycle::Stopped) {
            return Ok(PumpReport::default());
        }
        let now = self.clock.now();
        // Deadline enforcement point 1: already-dead requests are dropped
        // before they cost a GEMM. An expiry *burst* attributable to one
        // tenant counts against its breaker.
        let dead = self.sched.drain_expired(now);
        let expired_at_dequeue = dead.len();
        if !dead.is_empty() {
            // (guarded so the idle pump path stays allocation-free)
            let mut dead_per_model = vec![0usize; self.models.len()];
            for r in dead {
                dead_per_model[r.model] += 1;
                self.metrics.expired_at_dequeue += 1;
                self.metrics.models[r.model].expired += 1;
                self.settle_breaker(r.model, Settle::ProbeExpired, now);
                self.completions.push(Completion {
                    id: r.id,
                    model: r.model,
                    outcome: Outcome::Expired(ExpiredAt::Dequeue),
                });
            }
            for (m, &count) in dead_per_model.iter().enumerate() {
                if count > 0 && self.breakers[m].is_expiry_burst(count) {
                    self.settle_breaker(m, Settle::Failure, now);
                }
            }
        }

        // Drain deadline landed: force-expire everything still queued.
        let mut force_expired = 0usize;
        if let Lifecycle::Draining { deadline } = self.lifecycle {
            if now >= deadline {
                for r in self.sched.drain_all() {
                    force_expired += 1;
                    self.metrics.expired_at_drain += 1;
                    self.metrics.models[r.model].expired += 1;
                    self.settle_breaker(r.model, Settle::ProbeExpired, now);
                    self.completions.push(Completion {
                        id: r.id,
                        model: r.model,
                        outcome: Outcome::Expired(ExpiredAt::DrainDeadline),
                    });
                }
            }
        }

        let breakers = &self.breakers;
        let Some(batch) = self
            .sched
            .next_batch(self.cfg.max_batch_rows, |m| breakers[m].blocks_dispatch(now))
        else {
            return Ok(PumpReport {
                batch: None,
                expired_at_dequeue,
                failed_rows: 0,
                force_expired,
            });
        };
        let model_idx = batch.model;
        let generation = self.models[model_idx].generation();

        // Degrade decision reads the *tenant's* post-expiry depth (batch
        // rows included): the ladder's last rung before refusal is
        // serving that tenant at the narrow width.
        let depth = batch.rows() + self.sched.depth(model_idx);
        let degraded =
            depth >= self.cfg.degrade_depth && self.cfg.degraded_bits < self.cfg.full_bits;
        let bits = if degraded {
            self.models[model_idx].degraded_bits()
        } else {
            self.models[model_idx].full_bits()
        };

        // Per-row hazard handling: fault probes, then a non-finite scan,
        // so one poisoned request fails alone instead of sinking the
        // batch at quantization time.
        let mut rows: Vec<QueuedRequest> = Vec::with_capacity(batch.requests.len());
        let mut failed_rows = 0usize;
        let mut settlements: Vec<Settle> = Vec::with_capacity(batch.requests.len());
        for mut r in batch.requests {
            if fault::fire(FaultSite::SlowRequest) {
                self.metrics.slow_requests += 1;
                self.clock.advance(self.cfg.slow_request_penalty_ticks);
            }
            if fault::fire(FaultSite::NanActivation) {
                if let Some(x) = r.input.first_mut() {
                    *x = f32::NAN;
                }
            }
            self.guard.record_scan();
            if let Some(err) = scan_nonfinite(&r.input, 1).error(&r.input) {
                self.guard.record_nonfinite();
                self.metrics.failed += 1;
                self.metrics.models[r.model].failed += 1;
                failed_rows += 1;
                settlements.push(Settle::Failure);
                self.completions.push(Completion {
                    id: r.id,
                    model: r.model,
                    outcome: Outcome::Failed(format!("rejected input: {err}")),
                });
                continue;
            }
            rows.push(r);
        }

        let (k, n) = (self.models[model_idx].k(), self.models[model_idx].n());
        let m = rows.len();
        let report = BatchReport {
            model: model_idx,
            ids: rows.iter().map(|r| r.id).collect(),
            bits,
            degraded,
            generation,
            retries: 0,
            split_fallback: false,
        };
        if m == 0 {
            self.metrics.batches += 1;
            for s in settlements {
                self.settle_breaker(model_idx, s, now);
            }
            return Ok(PumpReport {
                batch: Some(report),
                expired_at_dequeue,
                failed_rows,
                force_expired,
            });
        }

        self.scratch_a.resize(m * k, 0.0);
        for (i, r) in rows.iter().enumerate() {
            self.scratch_a[i * k..(i + 1) * k].copy_from_slice(&r.input);
        }
        self.scratch_out.resize(m * n, 0.0);

        let plan = self.plans.get_or_plan(&self.ctx, m, k, n, (bits, bits))?;
        let weights = self.models[model_idx].weights_at(bits);
        let a = &self.scratch_a[..m * k];
        let out = &mut self.scratch_out[..m * n];

        // Attempt 1..=retries: the whole batch in one pool-parallel GEMM,
        // each contained panic redispatched bit-identically.
        let mut retries = 0usize;
        let mut whole_failed = None;
        let gemm_span = crate::obs::trace::span("serve.pump.gemm");
        loop {
            let attempt = catch_pool_panic(|| {
                plan.quantize_execute_into(a, &mut Rounding::NearestEven, weights, &mut *out)
            });
            match attempt {
                Ok(inner) => {
                    inner?;
                    break;
                }
                Err(p) => {
                    self.metrics.panics_contained += 1;
                    if retries >= self.cfg.max_gemm_retries {
                        whole_failed = Some(p);
                        break;
                    }
                    retries += 1;
                    self.metrics.gemm_retries += 1;
                }
            }
        }

        // Split fallback: per-row GEMMs isolate the damage to single
        // requests. (A 1-row dispatch runs inline — below the pool's
        // parallel floor — so injected worker faults cannot reach it.)
        let mut row_failed: Vec<Option<String>> = vec![None; m];
        let split_fallback = whole_failed.is_some();
        if let Some(panic) = whole_failed {
            self.metrics.split_fallbacks += 1;
            let row_plan = self.plans.get_or_plan(&self.ctx, 1, k, n, (bits, bits))?;
            for i in 0..m {
                let row_a = &a[i * k..(i + 1) * k];
                let row_out = &mut out[i * n..(i + 1) * n];
                let mut last = panic.message().to_string();
                let mut ok = false;
                for _ in 0..=self.cfg.max_gemm_retries {
                    let attempt = catch_pool_panic(|| {
                        row_plan.quantize_execute_into(
                            row_a,
                            &mut Rounding::NearestEven,
                            weights,
                            &mut *row_out,
                        )
                    });
                    match attempt {
                        Ok(inner) => {
                            inner?;
                            ok = true;
                            break;
                        }
                        Err(p) => {
                            self.metrics.panics_contained += 1;
                            last = p.message().to_string();
                        }
                    }
                }
                if !ok {
                    row_failed[i] = Some(last);
                }
            }
        }
        drop(gemm_span);

        // Deterministic service-time model (manual-clock soaks) — the
        // batch costs ticks proportional to its rows.
        if self.cfg.synthetic_ticks_per_row > 0 {
            self.clock.advance(self.cfg.synthetic_ticks_per_row * m as u64);
        }

        // Deadline enforcement point 2: a result that arrives after its
        // deadline is reported expired, not served.
        let _settle_span = crate::obs::trace::span("serve.pump.settle");
        let done = self.clock.now();
        for (i, r) in rows.iter().enumerate() {
            if let Some(msg) = row_failed[i].take() {
                self.metrics.failed += 1;
                self.metrics.models[r.model].failed += 1;
                failed_rows += 1;
                settlements.push(Settle::Failure);
                self.completions.push(Completion {
                    id: r.id,
                    model: r.model,
                    outcome: Outcome::Failed(format!("gemm dispatch failed: {msg}")),
                });
                continue;
            }
            if r.expired(done) {
                self.metrics.expired_at_completion += 1;
                self.metrics.models[r.model].expired += 1;
                settlements.push(Settle::ProbeExpired);
                self.completions.push(Completion {
                    id: r.id,
                    model: r.model,
                    outcome: Outcome::Expired(ExpiredAt::Completion),
                });
                continue;
            }
            let latency = done.saturating_sub(r.submitted_at);
            self.metrics.latency.record(latency);
            self.metrics.completed += 1;
            self.metrics.models[r.model].served += 1;
            self.metrics.models[r.model].latency.record(latency);
            if degraded {
                self.metrics.degraded_served += 1;
                self.metrics.models[r.model].degraded += 1;
            }
            settlements.push(Settle::Success);
            self.completions.push(Completion {
                id: r.id,
                model: r.model,
                outcome: Outcome::Served(Response {
                    output: self.scratch_out[i * n..(i + 1) * n].to_vec(),
                    served_bits: bits,
                    degraded,
                    generation,
                    latency_ticks: latency,
                }),
            });
        }

        // Breaker settlement in row order (streaks are order-sensitive).
        for s in settlements {
            self.settle_breaker(model_idx, s, done);
        }

        self.metrics.batches += 1;
        self.metrics.batched_rows += m as u64;
        let report = BatchReport { retries, split_fallback, ..report };
        Ok(PumpReport { batch: Some(report), expired_at_dequeue, failed_rows, force_expired })
    }

    /// Full observability dump: serving counters + latency percentiles
    /// (global and per-tenant), lifecycle/readiness, per-tenant breaker
    /// states, numeric guard totals, and plan-cache effectiveness.
    pub fn metrics_json(&self) -> Json {
        let drain_deadline = match self.lifecycle {
            Lifecycle::Draining { deadline } => Json::num(deadline as f64),
            _ => Json::Null,
        };
        let breakers = self
            .breakers
            .iter()
            .enumerate()
            .map(|(i, b)| {
                Json::obj(vec![
                    ("model", Json::num(i as f64)),
                    ("name", Json::str(self.models[i].name())),
                    ("state", Json::str(b.state().name())),
                    ("trips", Json::num(b.trips() as f64)),
                    ("recoveries", Json::num(b.recoveries() as f64)),
                ])
            })
            .collect();
        let generations = self
            .models
            .iter()
            .map(|m| Json::num(m.generation() as f64))
            .collect();
        Json::obj(vec![
            ("serve", self.metrics.to_json()),
            (
                "lifecycle",
                Json::obj(vec![
                    ("state", Json::str(self.lifecycle.name())),
                    ("ready", Json::Bool(self.is_ready())),
                    ("drain_deadline", drain_deadline),
                    ("queue_depth", Json::num(self.sched.total_depth() as f64)),
                    ("models_resident", Json::num(self.models.len() as f64)),
                    ("generations", Json::Arr(generations)),
                ]),
            ),
            ("breakers", Json::Arr(breakers)),
            ("guard_stats", guard_stats_json(&self.guard.snapshot())),
            ("plan_cache", {
                // routed through the shared registry; key set (and hence
                // byte layout — both sides are BTreeMap-sorted) unchanged
                let reg = crate::obs::Registry::new();
                self.plans.export_metrics(&reg, "");
                reg.to_json()
            }),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::TileSize;
    use crate::serve::clock::ManualClock;
    use crate::util::fault::FaultInjector;

    fn ramp(len: usize, phase: f32) -> Vec<f32> {
        (0..len).map(|i| ((i as f32) * 0.11 + phase).sin()).collect()
    }

    fn server(cfg: ServeConfig) -> (InferenceServer, Arc<ManualClock>) {
        let clock = Arc::new(ManualClock::new());
        let ctx = BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4));
        (InferenceServer::new(cfg, ctx, clock.clone()), clock)
    }

    #[test]
    fn served_batch_is_bit_identical_to_naive() {
        let (mut srv, _clock) = server(ServeConfig::default());
        let k = 8;
        let n = 8;
        let w = ramp(k * n, 0.3);
        let model = srv.register_model("toy", &w, k, n).unwrap();

        let inputs: Vec<Vec<f32>> = (0..3).map(|i| ramp(k, i as f32)).collect();
        for input in &inputs {
            let sub = srv.submit(model, input.clone(), None).unwrap();
            assert!(sub.is_admitted());
        }
        let report = srv.pump().unwrap();
        let batch = report.batch.unwrap();
        assert_eq!(batch.ids.len(), 3);
        assert!(!batch.degraded);
        assert_eq!(batch.bits, 16);
        assert_eq!(batch.generation, 0);

        // naive reference over the same batch grouping and width
        let ctx = srv.context();
        let mut flat = Vec::new();
        for input in &inputs {
            flat.extend_from_slice(input);
        }
        let qa = ctx.quantize(&flat, 3, k, 16, &mut Rounding::NearestEven).unwrap();
        let want = bfp_matmul_naive(&qa, srv.model(model).unwrap().weights_at(16)).unwrap();

        let done = srv.drain_completions();
        assert_eq!(done.len(), 3);
        for (i, c) in done.iter().enumerate() {
            match &c.outcome {
                Outcome::Served(resp) => {
                    assert_eq!(resp.served_bits, 16);
                    assert!(!resp.degraded);
                    assert_eq!(resp.generation, 0);
                    assert_eq!(resp.output, want[i * n..(i + 1) * n].to_vec());
                }
                other => panic!("request {i} not served: {other:?}"),
            }
        }
        assert_eq!(srv.metrics().completed, 3);
        assert_eq!(srv.metrics().batches, 1);
        assert_eq!(srv.metrics().batched_rows, 3);
        assert_eq!(srv.metrics().models[model].served, 3);
        assert_eq!(srv.plan_cache().misses(), 1);
    }

    #[test]
    fn ladder_degrades_then_sheds_then_fills() {
        let cfg = ServeConfig {
            queue_capacity: 8,
            elevated_depth: 2,
            degrade_depth: 3,
            shed_depth: 6,
            max_batch_rows: 4,
            ..ServeConfig::default()
        };
        let (mut srv, _clock) = server(cfg);
        let k = 4;
        let model = srv.register_model("toy", &ramp(k * 4, 0.0), k, 4).unwrap();

        let mut pressures = Vec::new();
        let mut rejections = Vec::new();
        for i in 0..8 {
            match srv.submit(model, ramp(k, i as f32), None).unwrap() {
                Submission::Admitted { pressure, .. } => pressures.push(pressure),
                Submission::Rejected(r) => rejections.push(r),
            }
        }
        assert_eq!(
            pressures,
            vec![
                Pressure::Nominal,
                Pressure::Nominal,
                Pressure::Elevated,
                Pressure::Degraded,
                Pressure::Degraded,
                Pressure::Degraded,
            ]
        );
        assert_eq!(rejections, vec![Rejected::Shedding, Rejected::Shedding]);
        assert_eq!(srv.metrics().rejected_shedding, 2);
        assert_eq!(srv.metrics().max_queue_depth, 6);

        // depth 6 >= degrade_depth -> first batch served narrow + flagged
        let report = srv.pump().unwrap();
        let batch = report.batch.unwrap();
        assert!(batch.degraded);
        assert_eq!(batch.bits, 8);
        let served: Vec<_> = srv
            .drain_completions()
            .into_iter()
            .filter_map(|c| match c.outcome {
                Outcome::Served(r) => Some(r),
                _ => None,
            })
            .collect();
        assert_eq!(served.len(), 4);
        assert!(served.iter().all(|r| r.degraded && r.served_bits == 8));
        assert_eq!(srv.metrics().degraded_served, 4);
        assert_eq!(srv.metrics().models[model].degraded, 4);

        // backlog drained below the watermark -> service recovers
        let report = srv.pump().unwrap();
        assert!(!report.batch.unwrap().degraded);
    }

    #[test]
    fn overloaded_deadline_is_refused_at_admission() {
        let cfg = ServeConfig { est_ticks_per_row: 100, ..ServeConfig::default() };
        let (mut srv, _clock) = server(cfg);
        let model = srv.register_model("toy", &ramp(16, 0.0), 4, 4).unwrap();
        srv.submit(model, ramp(4, 0.0), None).unwrap();
        // backlog estimate (1+1)*100 = 200 > 150
        let sub = srv.submit(model, ramp(4, 1.0), Some(150)).unwrap();
        assert_eq!(sub, Submission::Rejected(Rejected::Overloaded));
        assert_eq!(srv.metrics().rejected_overloaded, 1);
        // a feasible deadline on the same queue is admitted
        assert!(srv.submit(model, ramp(4, 2.0), Some(250)).unwrap().is_admitted());
    }

    #[test]
    fn deadlines_expire_at_dequeue_and_completion() {
        let cfg = ServeConfig { synthetic_ticks_per_row: 100, ..ServeConfig::default() };
        let (mut srv, clock) = server(cfg);
        let model = srv.register_model("toy", &ramp(16, 0.0), 4, 4).unwrap();

        // expires in the queue: deadline 50, clock jumps to 60
        let a = srv.submit(model, ramp(4, 0.0), Some(50)).unwrap().id().unwrap();
        // expires at completion: deadline 150, batch costs 2*100 ticks
        let b = srv.submit(model, ramp(4, 1.0), Some(150)).unwrap().id().unwrap();
        // survives: no deadline
        let c = srv.submit(model, ramp(4, 2.0), None).unwrap().id().unwrap();
        clock.advance(60);
        let report = srv.pump().unwrap();
        assert_eq!(report.expired_at_dequeue, 1);
        assert_eq!(report.batch.as_ref().unwrap().ids, vec![b, c]);

        let done = srv.drain_completions();
        let outcome = |id: u64| done.iter().find(|x| x.id == id).unwrap().outcome.clone();
        assert_eq!(outcome(a), Outcome::Expired(ExpiredAt::Dequeue));
        assert_eq!(outcome(b), Outcome::Expired(ExpiredAt::Completion));
        assert!(matches!(outcome(c), Outcome::Served(_)));
        assert_eq!(srv.metrics().expired_at_dequeue, 1);
        assert_eq!(srv.metrics().expired_at_completion, 1);
        assert_eq!(srv.metrics().models[model].expired, 2);
        assert_eq!(srv.metrics().latency.count(), 1);
        assert_eq!(srv.metrics().latency.max(), 260); // 60 wait + 200 service
    }

    #[test]
    fn nonfinite_input_fails_only_its_own_request() {
        let (mut srv, _clock) = server(ServeConfig::default());
        let k = 4;
        let n = 4;
        let model = srv.register_model("toy", &ramp(k * n, 0.0), k, n).unwrap();
        let good = ramp(k, 1.0);
        srv.submit(model, good.clone(), None).unwrap();
        let mut bad = ramp(k, 2.0);
        bad[2] = f32::INFINITY;
        let bad_id = srv.submit(model, bad, None).unwrap().id().unwrap();
        srv.submit(model, good.clone(), None).unwrap();

        let report = srv.pump().unwrap();
        assert_eq!(report.failed_rows, 1);
        assert_eq!(report.batch.as_ref().unwrap().ids.len(), 2);

        let done = srv.drain_completions();
        assert_eq!(done.len(), 3);
        let failed: Vec<u64> = done
            .iter()
            .filter(|x| matches!(x.outcome, Outcome::Failed(_)))
            .map(|x| x.id)
            .collect();
        assert_eq!(failed, vec![bad_id]);
        assert_eq!(srv.metrics().failed, 1);
        assert_eq!(srv.metrics().completed, 2);
        let snap = srv.guard_snapshot();
        assert_eq!(snap.scans, 3);
        assert_eq!(snap.nonfinite_inputs, 1);
    }

    #[test]
    fn submit_rejects_caller_bugs_as_errors() {
        let (mut srv, _clock) = server(ServeConfig::default());
        assert!(srv.submit(0, vec![1.0], None).is_err());
        let model = srv.register_model("toy", &ramp(16, 0.0), 4, 4).unwrap();
        assert!(srv.submit(model, vec![1.0; 3], None).is_err());
    }

    #[test]
    fn metrics_json_has_all_sections() {
        let (mut srv, _clock) = server(ServeConfig::default());
        let model = srv.register_model("toy", &ramp(16, 0.0), 4, 4).unwrap();
        srv.submit(model, ramp(4, 0.0), None).unwrap();
        srv.run_until_idle().unwrap();
        let j = srv.metrics_json();
        assert!(j.get("serve").is_some());
        assert!(j.get("guard_stats").is_some());
        let pc = j.get("plan_cache").unwrap();
        assert_eq!(pc.get("misses").and_then(|v| v.as_i64()), Some(1));
        let life = j.get("lifecycle").unwrap();
        assert_eq!(life.get("state").unwrap().as_str(), Some("running"));
        assert_eq!(life.get("ready").unwrap().as_bool(), Some(true));
        let breakers = j.get("breakers").unwrap().as_arr().unwrap();
        assert_eq!(breakers.len(), 1);
        assert_eq!(breakers[0].get("state").unwrap().as_str(), Some("closed"));
        let models = j.get("serve").unwrap().get("models").unwrap().as_arr().unwrap();
        assert_eq!(models[0].get("served").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn fair_share_serves_both_tenants_round_robin() {
        let cfg = ServeConfig { max_batch_rows: 4, drr_quantum_rows: 4, ..ServeConfig::default() };
        let (mut srv, _clock) = server(cfg);
        let a = srv.register_model("tenant-a", &ramp(16, 0.1), 4, 4).unwrap();
        let b = srv.register_model("tenant-b", &ramp(16, 0.7), 4, 4).unwrap();
        // A floods 12 rows, B submits 2
        for i in 0..12 {
            srv.submit(a, ramp(4, i as f32), None).unwrap();
        }
        for i in 0..2 {
            srv.submit(b, ramp(4, 20.0 + i as f32), None).unwrap();
        }
        let reports = srv.run_until_idle().unwrap();
        let order: Vec<usize> = reports.iter().filter_map(|r| r.batch.as_ref()).map(|x| x.model).collect();
        // B is served on the second turn despite A's 12-row backlog
        assert_eq!(order, vec![a, b, a, a]);
        assert_eq!(srv.metrics().models[a].served, 12);
        assert_eq!(srv.metrics().models[b].served, 2);
    }

    #[test]
    fn clean_reload_swaps_generation_and_serves_bit_identical() {
        let (mut srv, _clock) = server(ServeConfig::default());
        let k = 8;
        let n = 8;
        let model = srv.register_model("toy", &ramp(k * n, 0.3), k, n).unwrap();
        let new_w = ramp(k * n, 1.9);
        let rep = srv.reload_model(model, &new_w).unwrap();
        assert_eq!((rep.old_generation, rep.new_generation), (0, 1));
        assert_eq!(srv.model(model).unwrap().generation(), 1);
        assert_eq!(srv.metrics().reloads, 1);

        // service after the swap is bit-identical to naive on the NEW weights
        let input = ramp(k, 0.5);
        srv.submit(model, input.clone(), None).unwrap();
        let report = srv.pump().unwrap();
        assert_eq!(report.batch.as_ref().unwrap().generation, 1);
        let ctx = srv.context();
        let qa = ctx.quantize(&input, 1, k, 16, &mut Rounding::NearestEven).unwrap();
        let qw = ctx.quantize(&new_w, k, n, 16, &mut Rounding::NearestEven).unwrap();
        let want = bfp_matmul_naive(&qa, &qw).unwrap();
        let done = srv.drain_completions();
        match &done[0].outcome {
            Outcome::Served(r) => {
                assert_eq!(r.generation, 1);
                assert_eq!(r.output, want);
            }
            other => panic!("not served: {other:?}"),
        }
    }

    #[test]
    fn garbled_reload_rolls_back_and_old_generation_keeps_serving() {
        let (mut srv, _clock) = server(ServeConfig::default());
        let k = 8;
        let n = 8;
        let w0 = ramp(k * n, 0.3);
        let model = srv.register_model("toy", &w0, k, n).unwrap();

        let _guard = fault::install(FaultInjector::parse("reload-garble:1.0:7").unwrap());
        let err = srv.reload_model(model, &ramp(k * n, 1.9)).unwrap_err();
        assert!(matches!(err, ReloadError::Validation(_)), "{err}");
        drop(_guard);

        assert_eq!(srv.model(model).unwrap().generation(), 0, "rollback keeps gen 0");
        assert_eq!(srv.metrics().reload_rollbacks, 1);
        assert_eq!(srv.metrics().reloads, 0);
        assert_eq!(srv.metrics().breaker_trips, 0, "failed reload trips nothing");

        // old weights still serve, bit-identical to naive on w0
        let input = ramp(k, 0.5);
        srv.submit(model, input.clone(), None).unwrap();
        srv.pump().unwrap();
        let ctx = srv.context();
        let qa = ctx.quantize(&input, 1, k, 16, &mut Rounding::NearestEven).unwrap();
        let qw = ctx.quantize(&w0, k, n, 16, &mut Rounding::NearestEven).unwrap();
        let want = bfp_matmul_naive(&qa, &qw).unwrap();
        match &srv.drain_completions()[0].outcome {
            Outcome::Served(r) => {
                assert_eq!(r.generation, 0);
                assert_eq!(r.output, want);
            }
            other => panic!("not served: {other:?}"),
        }
    }

    #[test]
    fn reload_rejects_shape_and_nonfinite_candidates() {
        let (mut srv, _clock) = server(ServeConfig::default());
        let model = srv.register_model("toy", &ramp(16, 0.0), 4, 4).unwrap();
        assert!(matches!(
            srv.reload_model(99, &ramp(16, 0.0)),
            Err(ReloadError::UnknownModel(99))
        ));
        assert!(matches!(
            srv.reload_model(model, &ramp(15, 0.0)),
            Err(ReloadError::ShapeMismatch { expected: 16, got: 15 })
        ));
        let mut bad = ramp(16, 0.0);
        bad[5] = f32::NAN;
        assert!(matches!(srv.reload_model(model, &bad), Err(ReloadError::Validation(_))));
        assert_eq!(srv.metrics().reload_rollbacks, 1, "shape bugs are not rollbacks");
    }

    #[test]
    fn drain_refuses_new_work_and_reaches_stopped_conserved() {
        let cfg = ServeConfig { synthetic_ticks_per_row: 10, ..ServeConfig::default() };
        let (mut srv, _clock) = server(cfg);
        let model = srv.register_model("toy", &ramp(16, 0.0), 4, 4).unwrap();
        for i in 0..20 {
            // deadline 150: at 10 ticks/row and batches of 8, rows 16..
            // cannot finish in time and are force-expired by the drain
            srv.submit(model, ramp(4, i as f32), Some(150)).unwrap();
        }
        srv.begin_drain(150).unwrap();
        assert_eq!(
            srv.submit(model, ramp(4, 99.0), None).unwrap(),
            Submission::Rejected(Rejected::Draining)
        );
        let rep = srv.run_until_stopped().unwrap();
        assert_eq!(srv.lifecycle(), Lifecycle::Stopped);
        assert!(!srv.is_ready());
        assert!(rep.conserved, "{rep:?}");
        assert_eq!(rep.admitted, 20);
        assert_eq!(rep.served + rep.expired + rep.force_expired + rep.failed, 20);
        assert!(rep.force_expired > 0 || rep.expired > 0, "deadline pressure was real");
        assert_eq!(srv.queue_depth(), 0);
        // stopped server: pump is a no-op, admission stays closed
        assert!(!srv.pump().unwrap().made_progress());
        assert!(srv.begin_drain(10).is_err());
        // every admitted request has exactly one completion
        let done = srv.drain_completions();
        assert_eq!(done.len(), 20);
        let mut ids: Vec<u64> = done.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 20, "no duplicate outcomes");
    }

    #[test]
    fn breaker_trips_quarantines_and_recovers_via_probes() {
        let cfg = ServeConfig {
            // batch cap 2: the two poisoned rows ride one batch, the
            // victim behind them is still queued when the trip lands
            max_batch_rows: 2,
            breaker: BreakerConfig {
                failure_threshold: 2,
                cooldown_ticks: 100,
                half_open_probes: 1,
                expiry_burst: 64,
            },
            ..ServeConfig::default()
        };
        let (mut srv, clock) = server(cfg);
        let sick = srv.register_model("sick", &ramp(16, 0.0), 4, 4).unwrap();
        let healthy = srv.register_model("healthy", &ramp(16, 0.5), 4, 4).unwrap();

        // two poisoned inputs in a row trip the sick model's breaker
        for i in 0..2 {
            let mut bad = ramp(4, i as f32);
            bad[0] = f32::NAN;
            srv.submit(sick, bad, None).unwrap();
        }
        // a queued-behind victim gets flushed by the quarantine
        srv.submit(sick, ramp(4, 9.0), None).unwrap();
        srv.run_until_idle().unwrap();
        assert_eq!(srv.metrics().breaker_trips, 1);
        assert!(matches!(srv.breaker_state(sick), Some(BreakerState::Open { .. })));
        let done = srv.drain_completions();
        assert_eq!(done.len(), 3, "victim was flushed, not stranded");

        // quarantine: sick refused, healthy unaffected
        assert_eq!(
            srv.submit(sick, ramp(4, 1.0), None).unwrap(),
            Submission::Rejected(Rejected::Quarantined)
        );
        assert!(srv.submit(healthy, ramp(4, 2.0), None).unwrap().is_admitted());
        srv.run_until_idle().unwrap();
        assert_eq!(srv.metrics().models[healthy].served, 1);
        assert_eq!(srv.metrics().models[sick].quarantined, 1);

        // cooldown elapses: one clean probe closes the breaker
        clock.advance(200);
        assert!(srv.submit(sick, ramp(4, 3.0), None).unwrap().is_admitted());
        srv.run_until_idle().unwrap();
        assert_eq!(srv.breaker_state(sick), Some(BreakerState::Closed));
        assert_eq!(srv.metrics().breaker_recoveries, 1);
        assert!(srv.submit(sick, ramp(4, 4.0), None).unwrap().is_admitted());
    }
}
