//! Resident models: weights quantized, packed, and pinned once at load.
//!
//! This generalizes the accelerator model's resident-weight path
//! ([`crate::accel::sim`]) for serving: the B operand is quantized to the
//! full serving width at load time, its packed-panel layout is warmed
//! eagerly, and — because the load-shed ladder's last rung is precision
//! degradation — a narrow copy at the degraded width is *also* built at
//! load time via the §4.2 narrow read path
//! ([`crate::bfp::BfpTensor::narrow_view`]). Degrading under overload is
//! then a pointer swap, not a re-quantization.

use anyhow::{anyhow, Result};

use crate::bfp::{BfpContext, BfpTensor, Rounding};

/// One served model: a `k x n` weight matrix resident at the full width
/// plus (when the widths differ) a pre-narrowed degraded copy.
///
/// The `generation` counter supports hot reload
/// ([`crate::serve::InferenceServer::reload_model`]): a freshly loaded
/// model is generation 0; each validated reload builds a *new*
/// `ResidentModel` off the serving path and swaps it in with the
/// generation bumped, so every [`crate::serve::Response`] can say which
/// weight generation produced it.
#[derive(Debug)]
pub struct ResidentModel {
    name: String,
    k: usize,
    n: usize,
    full_bits: u32,
    degraded_bits: u32,
    generation: u64,
    full: BfpTensor,
    /// `None` when `degraded_bits == full_bits` (no separate copy).
    degraded: Option<BfpTensor>,
}

impl ResidentModel {
    /// Quantize `weights` (row-major `k x n`) at the context's tile size,
    /// build the degraded narrow copy, and warm both packed-panel caches.
    pub fn load(
        ctx: &BfpContext,
        name: &str,
        weights: &[f32],
        k: usize,
        n: usize,
        full_bits: u32,
        degraded_bits: u32,
    ) -> Result<ResidentModel> {
        if k == 0 || n == 0 {
            return Err(anyhow!("model {name}: degenerate shape {k}x{n}"));
        }
        if weights.len() != k * n {
            return Err(anyhow!(
                "model {name}: weights len {} != {k}x{n}",
                weights.len()
            ));
        }
        if degraded_bits > full_bits {
            return Err(anyhow!(
                "model {name}: degraded width {degraded_bits} exceeds full width {full_bits}"
            ));
        }
        // Weights are quantized RNE: serving must be reproducible across
        // restarts, so no stochastic state is allowed into residency.
        let full = ctx.quantize(weights, k, n, full_bits, &mut Rounding::NearestEven)?;
        let nr = ctx.isa().panel_nr();
        full.packed_panels_nr(nr);
        let degraded = if degraded_bits < full_bits {
            let narrow = full.narrow_view(degraded_bits, &mut Rounding::NearestEven)?;
            narrow.packed_panels_nr(nr);
            Some(narrow)
        } else {
            None
        };
        Ok(ResidentModel {
            name: name.to_string(),
            k,
            n,
            full_bits,
            degraded_bits,
            generation: 0,
            full,
            degraded,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Weight generation serving right now (0 = initial load; bumped by
    /// each validated hot reload).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Stamp the generation on a candidate built for hot reload (the
    /// server calls this before the atomic swap).
    pub fn set_generation(&mut self, generation: u64) {
        self.generation = generation;
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn full_bits(&self) -> u32 {
        self.full_bits
    }

    pub fn degraded_bits(&self) -> u32 {
        self.degraded_bits
    }

    /// The resident tensor serving width `bits`. Any width other than the
    /// configured degraded width gets the full-width tensor.
    pub fn weights_at(&self, bits: u32) -> &BfpTensor {
        match &self.degraded {
            Some(d) if bits == self.degraded_bits => d,
            _ => &self.full,
        }
    }

    /// Resident bytes across both width copies (mantissas + exponents +
    /// cached panels).
    pub fn heap_bytes(&self) -> usize {
        self.full.heap_bytes() + self.degraded.as_ref().map_or(0, |d| d.heap_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::{bfp_matmul_naive, TileSize};

    fn ctx() -> BfpContext {
        BfpContext::from_env().with_threads(1).with_tile(TileSize::Edge(4))
    }

    fn ramp(len: usize) -> Vec<f32> {
        (0..len).map(|i| (i as f32 * 0.37).sin()).collect()
    }

    #[test]
    fn load_builds_both_width_copies() {
        let ctx = ctx();
        let w = ramp(8 * 6);
        let m = ResidentModel::load(&ctx, "toy", &w, 8, 6, 16, 8).unwrap();
        assert_eq!((m.k(), m.n()), (8, 6));
        assert_eq!(m.weights_at(16).mantissa_bits, 16);
        assert_eq!(m.weights_at(8).mantissa_bits, 8);
        // unknown width falls back to the full copy
        assert_eq!(m.weights_at(12).mantissa_bits, 16);
        assert!(m.heap_bytes() > 0);
    }

    #[test]
    fn equal_widths_skip_the_degraded_copy() {
        let ctx = ctx();
        let w = ramp(4 * 4);
        let m = ResidentModel::load(&ctx, "flat", &w, 4, 4, 8, 8).unwrap();
        assert!(std::ptr::eq(m.weights_at(8), m.weights_at(16)));
    }

    #[test]
    fn degraded_copy_matches_naive_at_narrow_width() {
        let ctx = ctx();
        let w = ramp(8 * 8);
        let m = ResidentModel::load(&ctx, "toy", &w, 8, 8, 16, 8).unwrap();
        let a = ctx
            .quantize(&ramp(2 * 8), 2, 8, 8, &mut Rounding::NearestEven)
            .unwrap();
        let plan = ctx.plan_matmul(2, 8, 8, (8, 8)).unwrap();
        let got = plan.execute(&a, m.weights_at(8)).unwrap();
        let want = bfp_matmul_naive(&a, m.weights_at(8)).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn generation_starts_at_zero_and_is_stampable() {
        let ctx = ctx();
        let mut m = ResidentModel::load(&ctx, "toy", &ramp(16), 4, 4, 16, 8).unwrap();
        assert_eq!(m.generation(), 0);
        m.set_generation(3);
        assert_eq!(m.generation(), 3);
    }

    #[test]
    fn load_rejects_bad_shapes_and_widths() {
        let ctx = ctx();
        assert!(ResidentModel::load(&ctx, "m", &[0.0; 12], 3, 5, 16, 8).is_err());
        assert!(ResidentModel::load(&ctx, "m", &[0.0; 15], 3, 5, 8, 16).is_err());
        assert!(ResidentModel::load(&ctx, "m", &[], 0, 5, 16, 8).is_err());
    }
}
