//! The bounded FIFO request queue behind admission control.
//!
//! Capacity is a hard bound — `push` on a full queue hands the request
//! back instead of growing, which is what makes the backpressure in
//! [`super::admission`] honest. Deadline expiry is enforced here at
//! dequeue time: [`BoundedQueue::drain_expired`] removes work that is
//! already dead so it never costs a GEMM.
//!
//! The queue tracks the earliest deadline it holds, so the idle pump
//! path (`drain_expired` with nothing expired — by far the common case)
//! is one comparison and **zero allocations** instead of a full
//! drain-and-rebuild. The tracked bound is maintained exactly on push
//! and on the expiry rebuild, and conservatively (it may go stale *low*,
//! never high) on dequeue, so an expiry can never be missed.

use std::collections::VecDeque;

/// One admitted inference request waiting for a batch slot.
#[derive(Debug, Clone)]
pub struct QueuedRequest {
    pub id: u64,
    /// Index of the resident model this request targets.
    pub model: usize,
    /// Row-major activation row, length = model's `k`.
    pub input: Vec<f32>,
    /// Absolute deadline in clock ticks; `u64::MAX` means none.
    pub deadline: u64,
    pub submitted_at: u64,
}

impl QueuedRequest {
    pub fn expired(&self, now: u64) -> bool {
        now > self.deadline
    }
}

/// Fixed-capacity FIFO of admitted requests.
#[derive(Debug)]
pub struct BoundedQueue {
    items: VecDeque<QueuedRequest>,
    capacity: usize,
    /// Lower bound on the minimum deadline held; `u64::MAX` when empty.
    earliest_deadline: u64,
}

impl BoundedQueue {
    pub fn new(capacity: usize) -> BoundedQueue {
        let capacity = capacity.max(1);
        BoundedQueue {
            items: VecDeque::with_capacity(capacity),
            capacity,
            earliest_deadline: u64::MAX,
        }
    }

    pub fn depth(&self) -> usize {
        self.items.len()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.capacity
    }

    /// Enqueue, or hand the request back if the queue is at capacity.
    pub fn push(&mut self, r: QueuedRequest) -> Result<(), QueuedRequest> {
        if self.is_full() {
            return Err(r);
        }
        self.earliest_deadline = self.earliest_deadline.min(r.deadline);
        self.items.push_back(r);
        Ok(())
    }

    /// Model id at the head of the line, if any.
    pub fn front_model(&self) -> Option<usize> {
        self.items.front().map(|r| r.model)
    }

    /// Remove and return every request whose deadline has already passed,
    /// wherever it sits in the queue, preserving FIFO order among both
    /// the removed and the survivors. When the tracked earliest deadline
    /// says nothing can have expired, this returns an empty vec without
    /// touching (or allocating) anything.
    pub fn drain_expired(&mut self, now: u64) -> Vec<QueuedRequest> {
        if now <= self.earliest_deadline {
            // Nothing held can be expired: `expired` is `now > deadline`
            // and every deadline is >= the tracked bound.
            return Vec::new();
        }
        let mut expired = Vec::new();
        let mut keep = VecDeque::with_capacity(self.items.len());
        let mut earliest = u64::MAX;
        for r in self.items.drain(..) {
            if r.expired(now) {
                expired.push(r);
            } else {
                earliest = earliest.min(r.deadline);
                keep.push_back(r);
            }
        }
        self.items = keep;
        self.earliest_deadline = earliest;
        expired
    }

    /// Dequeue up to `max_rows` requests from the front, preserving FIFO
    /// order (the per-model queue case: every resident is the same model).
    pub fn take_front(&mut self, max_rows: usize) -> Vec<QueuedRequest> {
        let take = max_rows.min(self.items.len());
        let taken: Vec<QueuedRequest> = self.items.drain(..take).collect();
        if self.items.is_empty() {
            self.earliest_deadline = u64::MAX;
        }
        // Otherwise the tracked bound may now be stale *low* — safe: a
        // too-low bound only costs one unnecessary scan, never a missed
        // expiry.
        taken
    }

    /// Remove every remaining request (drain/quarantine flush paths).
    pub fn drain_all(&mut self) -> Vec<QueuedRequest> {
        self.earliest_deadline = u64::MAX;
        self.items.drain(..).collect()
    }

    /// Dequeue up to `max_rows` requests for `model`, preserving FIFO
    /// order; requests for other models keep their relative order.
    pub fn take_for_model(&mut self, model: usize, max_rows: usize) -> Vec<QueuedRequest> {
        let mut taken = Vec::new();
        let mut keep = VecDeque::with_capacity(self.items.len());
        for r in self.items.drain(..) {
            if r.model == model && taken.len() < max_rows {
                taken.push(r);
            } else {
                keep.push_back(r);
            }
        }
        self.items = keep;
        if self.items.is_empty() {
            self.earliest_deadline = u64::MAX;
        }
        taken
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: usize, deadline: u64) -> QueuedRequest {
        QueuedRequest { id, model, input: vec![0.0; 4], deadline, submitted_at: 0 }
    }

    #[test]
    fn push_bounces_at_capacity() {
        let mut q = BoundedQueue::new(2);
        assert!(q.push(req(1, 0, u64::MAX)).is_ok());
        assert!(q.push(req(2, 0, u64::MAX)).is_ok());
        assert!(q.is_full());
        let bounced = q.push(req(3, 0, u64::MAX)).unwrap_err();
        assert_eq!(bounced.id, 3);
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn drain_expired_keeps_fifo_order() {
        let mut q = BoundedQueue::new(8);
        for (id, dl) in [(1, 10), (2, 5), (3, u64::MAX), (4, 5), (5, 20)] {
            q.push(req(id, 0, dl)).unwrap();
        }
        let dead: Vec<u64> = q.drain_expired(7).iter().map(|r| r.id).collect();
        assert_eq!(dead, vec![2, 4]);
        assert_eq!(q.depth(), 3);
        assert_eq!(q.front_model(), Some(0));
        let rest: Vec<u64> = q.take_for_model(0, 8).iter().map(|r| r.id).collect();
        assert_eq!(rest, vec![1, 3, 5]);
    }

    #[test]
    fn drain_expired_short_circuits_when_nothing_can_be_dead() {
        let mut q = BoundedQueue::new(8);
        q.push(req(1, 0, 100)).unwrap();
        q.push(req(2, 0, u64::MAX)).unwrap();
        // now == earliest deadline: `expired` is strict, so nothing dead
        assert!(q.drain_expired(100).is_empty());
        assert_eq!(q.depth(), 2);
        // past the bound: the real scan runs and finds the dead request
        let dead: Vec<u64> = q.drain_expired(101).iter().map(|r| r.id).collect();
        assert_eq!(dead, vec![1]);
        // the bound was recomputed by the rebuild: now u64::MAX, so any
        // finite clock short-circuits
        assert!(q.drain_expired(u64::MAX - 1).is_empty());
        assert_eq!(q.depth(), 1);
    }

    #[test]
    fn deadline_bound_survives_dequeue_staleness() {
        let mut q = BoundedQueue::new(8);
        q.push(req(1, 0, 10)).unwrap();
        q.push(req(2, 0, 500)).unwrap();
        // taking the earliest-deadline holder leaves the bound stale low —
        // which must still *detect* the remaining expiry, just via a scan
        let t = q.take_front(1);
        assert_eq!(t[0].id, 1);
        let dead: Vec<u64> = q.drain_expired(501).iter().map(|r| r.id).collect();
        assert_eq!(dead, vec![2]);
        assert!(q.is_empty());
        // empty queue resets the bound: pushes re-establish it exactly
        q.push(req(3, 0, 42)).unwrap();
        assert!(q.drain_expired(42).is_empty());
        assert_eq!(q.drain_expired(43).len(), 1);
    }

    #[test]
    fn take_front_is_fifo_and_capped() {
        let mut q = BoundedQueue::new(8);
        for id in 1..=5u64 {
            q.push(req(id, 3, u64::MAX)).unwrap();
        }
        let a: Vec<u64> = q.take_front(2).iter().map(|r| r.id).collect();
        assert_eq!(a, vec![1, 2]);
        let b: Vec<u64> = q.take_front(10).iter().map(|r| r.id).collect();
        assert_eq!(b, vec![3, 4, 5]);
        assert!(q.take_front(1).is_empty());
    }

    #[test]
    fn take_for_model_coalesces_fifo_and_skips_other_models() {
        let mut q = BoundedQueue::new(8);
        for (id, model) in [(1, 0), (2, 1), (3, 0), (4, 0), (5, 1)] {
            q.push(req(id, model, u64::MAX)).unwrap();
        }
        let batch: Vec<u64> = q.take_for_model(0, 2).iter().map(|r| r.id).collect();
        assert_eq!(batch, vec![1, 3]); // capped at 2 rows, id 4 stays
        let left: Vec<u64> = q.take_for_model(1, 8).iter().map(|r| r.id).collect();
        assert_eq!(left, vec![2, 5]);
        assert_eq!(q.depth(), 1);
        assert_eq!(q.front_model(), Some(0));
    }
}
