//! The bounded FIFO request queue behind admission control.
//!
//! Capacity is a hard bound — `push` on a full queue hands the request
//! back instead of growing, which is what makes the backpressure in
//! [`super::admission`] honest. Deadline expiry is enforced here at
//! dequeue time: [`BoundedQueue::drain_expired`] removes work that is
//! already dead so it never costs a GEMM.

use std::collections::VecDeque;

/// One admitted inference request waiting for a batch slot.
#[derive(Debug, Clone)]
pub struct QueuedRequest {
    pub id: u64,
    /// Index of the resident model this request targets.
    pub model: usize,
    /// Row-major activation row, length = model's `k`.
    pub input: Vec<f32>,
    /// Absolute deadline in clock ticks; `u64::MAX` means none.
    pub deadline: u64,
    pub submitted_at: u64,
}

impl QueuedRequest {
    pub fn expired(&self, now: u64) -> bool {
        now > self.deadline
    }
}

/// Fixed-capacity FIFO of admitted requests.
#[derive(Debug)]
pub struct BoundedQueue {
    items: VecDeque<QueuedRequest>,
    capacity: usize,
}

impl BoundedQueue {
    pub fn new(capacity: usize) -> BoundedQueue {
        let capacity = capacity.max(1);
        BoundedQueue { items: VecDeque::with_capacity(capacity), capacity }
    }

    pub fn depth(&self) -> usize {
        self.items.len()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.capacity
    }

    /// Enqueue, or hand the request back if the queue is at capacity.
    pub fn push(&mut self, r: QueuedRequest) -> Result<(), QueuedRequest> {
        if self.is_full() {
            return Err(r);
        }
        self.items.push_back(r);
        Ok(())
    }

    /// Model id at the head of the line, if any.
    pub fn front_model(&self) -> Option<usize> {
        self.items.front().map(|r| r.model)
    }

    /// Remove and return every request whose deadline has already passed,
    /// wherever it sits in the queue, preserving FIFO order among both
    /// the removed and the survivors.
    pub fn drain_expired(&mut self, now: u64) -> Vec<QueuedRequest> {
        let mut expired = Vec::new();
        let mut keep = VecDeque::with_capacity(self.items.len());
        for r in self.items.drain(..) {
            if r.expired(now) {
                expired.push(r);
            } else {
                keep.push_back(r);
            }
        }
        self.items = keep;
        expired
    }

    /// Dequeue up to `max_rows` requests for `model`, preserving FIFO
    /// order; requests for other models keep their relative order.
    pub fn take_for_model(&mut self, model: usize, max_rows: usize) -> Vec<QueuedRequest> {
        let mut taken = Vec::new();
        let mut keep = VecDeque::with_capacity(self.items.len());
        for r in self.items.drain(..) {
            if r.model == model && taken.len() < max_rows {
                taken.push(r);
            } else {
                keep.push_back(r);
            }
        }
        self.items = keep;
        taken
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: usize, deadline: u64) -> QueuedRequest {
        QueuedRequest { id, model, input: vec![0.0; 4], deadline, submitted_at: 0 }
    }

    #[test]
    fn push_bounces_at_capacity() {
        let mut q = BoundedQueue::new(2);
        assert!(q.push(req(1, 0, u64::MAX)).is_ok());
        assert!(q.push(req(2, 0, u64::MAX)).is_ok());
        assert!(q.is_full());
        let bounced = q.push(req(3, 0, u64::MAX)).unwrap_err();
        assert_eq!(bounced.id, 3);
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn drain_expired_keeps_fifo_order() {
        let mut q = BoundedQueue::new(8);
        for (id, dl) in [(1, 10), (2, 5), (3, u64::MAX), (4, 5), (5, 20)] {
            q.push(req(id, 0, dl)).unwrap();
        }
        let dead: Vec<u64> = q.drain_expired(7).iter().map(|r| r.id).collect();
        assert_eq!(dead, vec![2, 4]);
        assert_eq!(q.depth(), 3);
        assert_eq!(q.front_model(), Some(0));
        let rest: Vec<u64> = q.take_for_model(0, 8).iter().map(|r| r.id).collect();
        assert_eq!(rest, vec![1, 3, 5]);
    }

    #[test]
    fn take_for_model_coalesces_fifo_and_skips_other_models() {
        let mut q = BoundedQueue::new(8);
        for (id, model) in [(1, 0), (2, 1), (3, 0), (4, 0), (5, 1)] {
            q.push(req(id, model, u64::MAX)).unwrap();
        }
        let batch: Vec<u64> = q.take_for_model(0, 2).iter().map(|r| r.id).collect();
        assert_eq!(batch, vec![1, 3]); // capped at 2 rows, id 4 stays
        let left: Vec<u64> = q.take_for_model(1, 8).iter().map(|r| r.id).collect();
        assert_eq!(left, vec![2, 5]);
        assert_eq!(q.depth(), 1);
        assert_eq!(q.front_model(), Some(0));
    }
}
