//! Fair-share scheduling: per-tenant queues drained by deficit round
//! robin (DRR).
//!
//! The PR-7 server had one FIFO: whichever model sat at the head of the
//! line owned the next batch, so a flooding tenant's backlog pushed
//! every other tenant's requests toward their deadlines. Here each
//! resident model owns its own [`BoundedQueue`] and the batcher visits
//! tenants in a DRR ring:
//!
//! - On each visit a backlogged tenant's **deficit** grows by
//!   `quantum_rows * share`; it then serves one micro-batch of up to
//!   `min(deficit, max_batch_rows)` rows and pays for exactly the rows
//!   served.
//! - A tenant whose queue empties forfeits its deficit (standard DRR:
//!   no banking credit while idle), and a visit never charges a blocked
//!   tenant (quarantined models keep their place without burning turns).
//!
//! **Starvation bound** (the provable part): between two consecutive
//! batches of a backlogged, unblocked tenant `i`, every other tenant is
//! visited at most once, so at most `T - 1` batches (each capped at
//! `max_batch_rows` rows) are served in between, and tenant `i`'s own
//! batch carries at least `min(quantum_rows * share_i, max_batch_rows)`
//! rows. Deficits are capped at `quantum_rows * share + max_batch_rows`
//! so no tenant can bank unbounded credit when its quantum exceeds the
//! batch cap. Micro-batching still coalesces within one tenant's queue
//! only — batches stay single-model, single-shape GEMMs.

use super::batcher::MicroBatch;
use super::queue::{BoundedQueue, QueuedRequest};

/// One tenant's scheduling state: its share, its DRR deficit, and its
/// private bounded queue. Tenant index == model index on the server.
#[derive(Debug)]
struct Tenant {
    share: u32,
    deficit: u64,
    queue: BoundedQueue,
}

/// Deficit-round-robin scheduler over per-tenant bounded queues.
#[derive(Debug)]
pub struct FairScheduler {
    tenants: Vec<Tenant>,
    /// Next ring position to visit.
    cursor: usize,
    /// Rows of credit granted per unit of share on each visit.
    quantum_rows: u64,
    /// Capacity of each tenant's queue.
    queue_capacity: usize,
}

impl FairScheduler {
    pub fn new(queue_capacity: usize, quantum_rows: usize) -> FairScheduler {
        FairScheduler {
            tenants: Vec::new(),
            cursor: 0,
            quantum_rows: quantum_rows.max(1) as u64,
            queue_capacity: queue_capacity.max(1),
        }
    }

    /// Register a tenant with the given share weight (clamped to >= 1).
    /// Returns its index, which the server keeps equal to the model index.
    pub fn add_tenant(&mut self, share: u32) -> usize {
        self.tenants.push(Tenant {
            share: share.max(1),
            deficit: 0,
            queue: BoundedQueue::new(self.queue_capacity),
        });
        self.tenants.len() - 1
    }

    pub fn tenants(&self) -> usize {
        self.tenants.len()
    }

    pub fn share(&self, model: usize) -> u32 {
        self.tenants[model].share
    }

    /// Queue depth of one tenant (admission reads this, not the total, so
    /// tenants cannot shed each other).
    pub fn depth(&self, model: usize) -> usize {
        self.tenants[model].queue.depth()
    }

    /// Total queued rows across every tenant.
    pub fn total_depth(&self) -> usize {
        self.tenants.iter().map(|t| t.queue.depth()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.iter().all(|t| t.queue.is_empty())
    }

    /// Enqueue into the request's own tenant queue.
    pub fn push(&mut self, r: QueuedRequest) -> Result<(), QueuedRequest> {
        self.tenants[r.model].queue.push(r)
    }

    /// Remove every expired request across all tenant queues, in tenant
    /// order then FIFO order. Idle tenants cost one comparison each (the
    /// queue's earliest-deadline short-circuit).
    pub fn drain_expired(&mut self, now: u64) -> Vec<QueuedRequest> {
        let mut dead = Vec::new();
        for t in &mut self.tenants {
            dead.append(&mut t.queue.drain_expired(now));
        }
        dead
    }

    /// Remove everything still queued (drain-deadline force-expiry).
    pub fn drain_all(&mut self) -> Vec<QueuedRequest> {
        let mut all = Vec::new();
        for t in &mut self.tenants {
            all.append(&mut t.queue.drain_all());
            t.deficit = 0;
        }
        all
    }

    /// Remove one tenant's entire backlog (quarantine flush on a breaker
    /// trip) and forfeit its deficit.
    pub fn drain_tenant(&mut self, model: usize) -> Vec<QueuedRequest> {
        let t = &mut self.tenants[model];
        t.deficit = 0;
        t.queue.drain_all()
    }

    /// One DRR turn: visit tenants starting at the ring cursor, grant the
    /// first backlogged unblocked tenant its quantum, and take one
    /// micro-batch from its queue. Returns `None` when every queue is
    /// empty or blocked. `blocked(model)` gates dispatch (open circuit
    /// breakers) without consuming the tenant's turn.
    pub fn next_batch(
        &mut self,
        max_rows: usize,
        mut blocked: impl FnMut(usize) -> bool,
    ) -> Option<MicroBatch> {
        let n = self.tenants.len();
        if n == 0 {
            return None;
        }
        let max_rows = max_rows.max(1) as u64;
        for step in 0..n {
            let i = (self.cursor + step) % n;
            if self.tenants[i].queue.is_empty() {
                // Standard DRR: an idle tenant banks nothing.
                self.tenants[i].deficit = 0;
                continue;
            }
            if blocked(i) {
                continue;
            }
            let t = &mut self.tenants[i];
            let quantum = self.quantum_rows * u64::from(t.share);
            // Cap banked credit so a tenant whose quantum exceeds the
            // batch cap cannot accumulate unbounded arrears.
            t.deficit = (t.deficit + quantum).min(quantum + max_rows);
            let take = t.deficit.min(max_rows).min(t.queue.depth() as u64) as usize;
            let requests = t.queue.take_front(take);
            t.deficit -= requests.len() as u64;
            if t.queue.is_empty() {
                t.deficit = 0;
            }
            self.cursor = (i + 1) % n;
            return Some(MicroBatch { model: i, requests });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, model: usize) -> QueuedRequest {
        QueuedRequest { id, model, input: vec![0.0; 2], deadline: u64::MAX, submitted_at: 0 }
    }

    fn sched(shares: &[u32]) -> FairScheduler {
        let mut s = FairScheduler::new(64, 4);
        for &w in shares {
            s.add_tenant(w);
        }
        s
    }

    #[test]
    fn round_robin_alternates_between_backlogged_tenants() {
        let mut s = sched(&[1, 1]);
        for i in 0..12u64 {
            s.push(req(i, (i % 2) as usize)).unwrap();
        }
        let mut order = Vec::new();
        while let Some(b) = s.next_batch(4, |_| false) {
            order.push((b.model, b.rows()));
        }
        // quantum 4 per visit, 6 rows queued per tenant: 4+2 each,
        // strictly alternating
        assert_eq!(order, vec![(0, 4), (1, 4), (0, 2), (1, 2)]);
        assert!(s.is_empty());
    }

    #[test]
    fn flooding_tenant_cannot_starve_the_other() {
        let mut s = sched(&[1, 1]);
        // tenant 0 floods 40 rows; tenant 1 has 2
        for i in 0..40u64 {
            s.push(req(i, 0)).unwrap();
        }
        s.push(req(100, 1)).unwrap();
        s.push(req(101, 1)).unwrap();
        let b = s.next_batch(4, |_| false).unwrap();
        assert_eq!(b.model, 0);
        // the very next turn belongs to tenant 1 no matter how deep
        // tenant 0's backlog is
        let b = s.next_batch(4, |_| false).unwrap();
        assert_eq!(b.model, 1);
        assert_eq!(b.rows(), 2);
    }

    #[test]
    fn shares_weight_rows_per_round() {
        let mut s = FairScheduler::new(256, 2);
        s.add_tenant(3); // 6 rows per visit
        s.add_tenant(1); // 2 rows per visit
        for i in 0..60u64 {
            s.push(req(i, (i % 2) as usize)).unwrap();
        }
        let mut rows = [0usize; 2];
        for _ in 0..4 {
            let b = s.next_batch(16, |_| false).unwrap();
            rows[b.model] += b.rows();
        }
        // two full rounds: shares 3:1 over quantum 2 -> 12 vs 4 rows
        assert_eq!(rows, [12, 4]);
    }

    #[test]
    fn blocked_tenant_is_skipped_without_losing_its_queue() {
        let mut s = sched(&[1, 1]);
        for i in 0..4u64 {
            s.push(req(i, 0)).unwrap();
        }
        s.push(req(10, 1)).unwrap();
        // tenant 0 quarantined: every batch comes from tenant 1
        let b = s.next_batch(4, |m| m == 0).unwrap();
        assert_eq!(b.model, 1);
        assert!(s.next_batch(4, |m| m == 0).is_none(), "only blocked work left");
        assert_eq!(s.depth(0), 4, "blocked backlog is preserved");
        // unblocked again: the backlog serves
        let b = s.next_batch(4, |_| false).unwrap();
        assert_eq!((b.model, b.rows()), (0, 4));
    }

    #[test]
    fn deficit_does_not_bank_across_idle_periods() {
        let mut s = sched(&[1, 1]);
        for i in 0..2u64 {
            s.push(req(i, 0)).unwrap();
        }
        // tenant 0 drains fully (deficit would be 4-2=2, forfeited on empty)
        let b = s.next_batch(8, |_| false).unwrap();
        assert_eq!((b.model, b.rows()), (0, 2));
        // refill: a fresh burst starts from zero credit, one quantum only
        for i in 10..30u64 {
            s.push(req(i, 0)).unwrap();
        }
        let b = s.next_batch(8, |_| false).unwrap();
        assert_eq!(b.rows(), 4, "one quantum (4), not quantum + banked credit");
    }

    #[test]
    fn expiry_drain_crosses_all_tenants() {
        let mut s = sched(&[1, 1, 1]);
        for (id, model, dl) in [(1u64, 0usize, 10u64), (2, 1, u64::MAX), (3, 2, 5)] {
            s.push(QueuedRequest {
                id,
                model,
                input: vec![0.0; 2],
                deadline: dl,
                submitted_at: 0,
            })
            .unwrap();
        }
        let dead: Vec<u64> = s.drain_expired(20).iter().map(|r| r.id).collect();
        assert_eq!(dead, vec![1, 3]);
        assert_eq!(s.total_depth(), 1);
        assert_eq!(s.drain_all().len(), 1);
        assert!(s.is_empty());
    }
}
