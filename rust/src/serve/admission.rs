//! Admission control: the decision made *before* a request costs anything.
//!
//! The policy is a watermark ladder over queue depth. Below
//! `elevated_depth` the server is nominal; past it, callers are told to
//! back off ([`Pressure::Elevated`]); past `degrade_depth` new work is
//! admitted but will be served at the narrow mantissa width
//! ([`Pressure::Degraded`] — the last rung before refusal, §4.2 narrow
//! read path); past `shed_depth` requests are refused outright, and at
//! `capacity` the queue itself is full. A request whose deadline cannot
//! plausibly be met given the backlog is refused as
//! [`Rejected::Overloaded`] instead of being admitted to expire later.

use std::fmt;

/// Typed refusal: why a request was not admitted. Returned to the caller
/// as backpressure — every variant means "not queued, try later or never".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejected {
    /// The tenant's bounded queue is at hard capacity.
    QueueFull,
    /// Backlog estimate says the deadline would expire before service.
    Overloaded,
    /// Load-shed watermark reached; request refused to protect the rest.
    Shedding,
    /// The target model's circuit breaker is open (repeated failures
    /// attributed to it); it is quarantined until its half-open probes
    /// prove it healthy again ([`super::breaker`]).
    Quarantined,
    /// The server is draining (or stopped): admission is closed for good,
    /// only already-admitted work is still being pumped.
    Draining,
}

impl Rejected {
    pub fn name(self) -> &'static str {
        match self {
            Rejected::QueueFull => "queue-full",
            Rejected::Overloaded => "overloaded",
            Rejected::Shedding => "shedding",
            Rejected::Quarantined => "quarantined",
            Rejected::Draining => "draining",
        }
    }
}

impl fmt::Display for Rejected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Service pressure reported back to an *admitted* caller, so clients can
/// throttle before the server has to refuse them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Pressure {
    Nominal,
    /// Above the soft watermark: caller should slow down.
    Elevated,
    /// Above the degrade watermark: request will be served at the
    /// narrow mantissa width and flagged as degraded.
    Degraded,
}

impl Pressure {
    pub fn name(self) -> &'static str {
        match self {
            Pressure::Nominal => "nominal",
            Pressure::Elevated => "elevated",
            Pressure::Degraded => "degraded",
        }
    }
}

/// The watermark ladder, resolved once from the server config. Under
/// multi-tenancy the ladder is applied to the *target tenant's* queue
/// depth — one tenant's backlog never sheds another tenant's requests.
/// Invariant (enforced by config normalization):
/// `elevated_depth <= degrade_depth <= shed_depth <= capacity`.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionPolicy {
    pub capacity: usize,
    pub elevated_depth: usize,
    pub degrade_depth: usize,
    pub shed_depth: usize,
    /// Backlog service-time model for the Overloaded check; 0 disables
    /// deadline feasibility screening.
    pub est_ticks_per_row: u64,
}

impl AdmissionPolicy {
    /// Decide a request's fate given current queue depth, the current
    /// clock, and the request's absolute deadline (`u64::MAX` = none).
    pub fn decide(&self, depth: usize, now: u64, deadline: u64) -> Result<Pressure, Rejected> {
        if depth >= self.capacity {
            return Err(Rejected::QueueFull);
        }
        if depth >= self.shed_depth {
            return Err(Rejected::Shedding);
        }
        if self.est_ticks_per_row > 0 && deadline != u64::MAX {
            // Everything ahead of us plus ourselves, one row each.
            let backlog = (depth as u64 + 1).saturating_mul(self.est_ticks_per_row);
            if now.saturating_add(backlog) > deadline {
                return Err(Rejected::Overloaded);
            }
        }
        Ok(if depth >= self.degrade_depth {
            Pressure::Degraded
        } else if depth >= self.elevated_depth {
            Pressure::Elevated
        } else {
            Pressure::Nominal
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> AdmissionPolicy {
        AdmissionPolicy {
            capacity: 8,
            elevated_depth: 2,
            degrade_depth: 4,
            shed_depth: 6,
            est_ticks_per_row: 100,
        }
    }

    #[test]
    fn ladder_escalates_with_depth() {
        let p = policy();
        assert_eq!(p.decide(0, 0, u64::MAX), Ok(Pressure::Nominal));
        assert_eq!(p.decide(1, 0, u64::MAX), Ok(Pressure::Nominal));
        assert_eq!(p.decide(2, 0, u64::MAX), Ok(Pressure::Elevated));
        assert_eq!(p.decide(4, 0, u64::MAX), Ok(Pressure::Degraded));
        assert_eq!(p.decide(5, 0, u64::MAX), Ok(Pressure::Degraded));
        assert_eq!(p.decide(6, 0, u64::MAX), Err(Rejected::Shedding));
        assert_eq!(p.decide(8, 0, u64::MAX), Err(Rejected::QueueFull));
        assert_eq!(p.decide(9, 0, u64::MAX), Err(Rejected::QueueFull));
    }

    #[test]
    fn infeasible_deadline_is_refused_as_overloaded() {
        let p = policy();
        // depth 3 -> estimate (3+1)*100 = 400 ticks of backlog.
        assert_eq!(p.decide(3, 1_000, 1_399), Err(Rejected::Overloaded));
        assert_eq!(p.decide(3, 1_000, 1_400), Ok(Pressure::Elevated));
        // no deadline -> no feasibility screen
        assert_eq!(p.decide(3, 1_000, u64::MAX), Ok(Pressure::Elevated));
    }

    #[test]
    fn zero_estimate_disables_feasibility_screen() {
        let mut p = policy();
        p.est_ticks_per_row = 0;
        assert_eq!(p.decide(3, 1_000, 1_001), Ok(Pressure::Elevated));
    }

    #[test]
    fn rejection_names_are_stable() {
        assert_eq!(Rejected::QueueFull.name(), "queue-full");
        assert_eq!(Rejected::Overloaded.to_string(), "overloaded");
        assert_eq!(Rejected::Shedding.name(), "shedding");
        assert_eq!(Rejected::Quarantined.name(), "quarantined");
        assert_eq!(Rejected::Draining.to_string(), "draining");
        assert_eq!(Pressure::Degraded.name(), "degraded");
    }
}
