//! Memory-traffic model — the §6 bandwidth discussion, quantified.
//!
//! The paper claims: (a) 8-bit mantissas cut fwd/bwd memory bandwidth "by
//! up to 4x" vs FP32, because only the most significant bits of the wide
//! weight storage are read (§4.2); (b) weight traffic dwarfs activation
//! traffic in fully connected layers; (c) in conv layers the
//! compute-to-communication ratio is high enough that activation traffic
//! doesn't bound throughput. This module computes per-layer traffic and
//! arithmetic intensity under each numeric format so the harnesses can
//! print those three claims with numbers.

/// One dot-product layer's shape, as the accelerator sees it.
#[derive(Debug, Clone, Copy)]
pub enum LayerShape {
    /// Fully connected: (batch, in, out).
    Dense { batch: usize, d_in: usize, d_out: usize },
    /// Conv as im2col: batch x out-positions rows, cin*kh*kw contraction.
    Conv { batch: usize, h_out: usize, w_out: usize, k: usize, cin: usize, cout: usize },
}

impl LayerShape {
    /// MACs in one forward pass.
    pub fn macs(&self) -> u64 {
        match *self {
            LayerShape::Dense { batch, d_in, d_out } => (batch * d_in * d_out) as u64,
            LayerShape::Conv { batch, h_out, w_out, k, cin, cout } => {
                (batch * h_out * w_out * k * k * cin * cout) as u64
            }
        }
    }

    pub fn weight_elems(&self) -> u64 {
        match *self {
            LayerShape::Dense { d_in, d_out, .. } => (d_in * d_out) as u64,
            LayerShape::Conv { k, cin, cout, .. } => (k * k * cin * cout) as u64,
        }
    }

    pub fn activation_elems(&self) -> u64 {
        match *self {
            LayerShape::Dense { batch, d_in, d_out } => (batch * (d_in + d_out)) as u64,
            LayerShape::Conv { batch, h_out, w_out, cin, cout, .. } => {
                // input read (~= output size of the previous layer) + output write
                (batch * h_out * w_out * (cin + cout)) as u64
            }
        }
    }
}

/// Storage widths (bits per element) of one numeric configuration.
#[derive(Debug, Clone, Copy)]
pub struct FormatBits {
    /// Weight bits *read by fwd/bwd* (the narrow view of wide storage).
    pub weight_read: u32,
    /// Weight bits touched per update (wide storage write).
    pub weight_update: u32,
    /// Activation bits (HBFP keeps FP activations; the paper notes narrow
    /// FP or summarized formats are fine — parameterized here).
    pub activation: u32,
    /// Exponent overhead per tile (8 bits / tile^2 elements), in
    /// milli-bits per element for a t=24 tiling; small enough to fold in.
    pub exponent_overhead_milli: u32,
}

impl FormatBits {
    pub fn fp32() -> FormatBits {
        FormatBits { weight_read: 32, weight_update: 32, activation: 32, exponent_overhead_milli: 0 }
    }

    /// hbfpM_S with tile t: fwd/bwd read M bits/weight + 8/t^2 exponent.
    pub fn hbfp(mantissa: u32, storage: u32, tile: u32) -> FormatBits {
        FormatBits {
            weight_read: mantissa,
            weight_update: storage,
            activation: 16, // narrow-FP activations (paper §6)
            exponent_overhead_milli: 8000 / (tile * tile),
        }
    }
}

/// Traffic in bits for one training step over a layer (fwd + bwd + update).
#[derive(Debug, Clone, Copy)]
pub struct TrafficReport {
    pub weight_bits: u64,
    pub activation_bits: u64,
    pub total_bits: u64,
    /// MACs per bit moved — arithmetic intensity; high = compute-bound.
    pub macs_per_bit: f64,
}

pub fn step_traffic(shape: &LayerShape, fmt: &FormatBits) -> TrafficReport {
    let w = shape.weight_elems();
    let a = shape.activation_elems();
    let we = fmt.weight_read as u64 + fmt.exponent_overhead_milli as u64 / 1000;
    // fwd reads W once; bwd reads W once (dgrad) + writes the update
    // (wide); wgrad re-reads activations. 3 MAC passes total (fwd, dgrad,
    // wgrad) is the standard accounting.
    let weight_bits = 2 * w * we + w * fmt.weight_update as u64;
    let activation_bits = 3 * a * fmt.activation as u64;
    let total = weight_bits + activation_bits;
    TrafficReport {
        weight_bits,
        activation_bits,
        total_bits: total,
        macs_per_bit: (3 * shape.macs()) as f64 / total as f64,
    }
}

/// Bandwidth-reduction ratio of `fmt` vs FP32 on the same layer.
pub fn bandwidth_ratio(shape: &LayerShape, fmt: &FormatBits) -> f64 {
    let base = step_traffic(shape, &FormatBits::fp32());
    let ours = step_traffic(shape, fmt);
    base.total_bits as f64 / ours.total_bits as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fc() -> LayerShape {
        // large FC layer: weights dominate (batch small relative to dims)
        LayerShape::Dense { batch: 32, d_in: 4096, d_out: 4096 }
    }

    fn conv() -> LayerShape {
        LayerShape::Conv { batch: 32, h_out: 16, w_out: 16, k: 3, cin: 128, cout: 128 }
    }

    #[test]
    fn weights_dominate_fc_traffic() {
        // paper: "activation traffic is dwarfed by weight traffic in fully
        // connected layers"
        let t = step_traffic(&fc(), &FormatBits::fp32());
        assert!(t.weight_bits > 10 * t.activation_bits, "{t:?}");
    }

    #[test]
    fn conv_is_compute_bound() {
        // paper: "in convolutional layers the computation-to-communication
        // ratio is so high that ... activations not a significant factor"
        let t = step_traffic(&conv(), &FormatBits::hbfp(8, 16, 24));
        assert!(t.macs_per_bit > 10.0, "arithmetic intensity {}", t.macs_per_bit);
        let dense_small = LayerShape::Dense { batch: 1, d_in: 4096, d_out: 4096 };
        let td = step_traffic(&dense_small, &FormatBits::hbfp(8, 16, 24));
        assert!(t.macs_per_bit > 5.0 * td.macs_per_bit);
    }

    #[test]
    fn hbfp8_cuts_fc_bandwidth_towards_4x() {
        // paper: "reduces the memory bandwidth requirements of the forward
        // and backward passes by up to 4x compared to FP32". The update
        // pass writes wide (16-bit) storage, so the whole-step ratio lands
        // between 2x and 4x; the fwd/bwd-only ratio hits 4x.
        let fmt = FormatBits::hbfp(8, 16, 24);
        let ratio = bandwidth_ratio(&fc(), &fmt);
        assert!(ratio > 2.0 && ratio < 4.2, "whole-step ratio {ratio}");
        // fwd/bwd-only view: weight-read bits 32 -> 8 (+ tiny exponent)
        let fwd_fp32 = 2 * fc().weight_elems() * 32;
        let fwd_hbfp = 2 * fc().weight_elems() * 8;
        assert_eq!(fwd_fp32 / fwd_hbfp, 4);
    }

    #[test]
    fn wider_mantissa_costs_bandwidth() {
        let r8 = bandwidth_ratio(&fc(), &FormatBits::hbfp(8, 16, 24));
        let r12 = bandwidth_ratio(&fc(), &FormatBits::hbfp(12, 16, 24));
        let r16 = bandwidth_ratio(&fc(), &FormatBits::hbfp(16, 16, 24));
        assert!(r8 > r12 && r12 > r16, "{r8} {r12} {r16}");
    }

    #[test]
    fn exponent_overhead_negligible_at_t24() {
        let fmt = FormatBits::hbfp(8, 16, 24);
        // 8 bits per 576 elements ~ 0.014 bits/elem
        assert!(fmt.exponent_overhead_milli < 20, "{}", fmt.exponent_overhead_milli);
    }

    #[test]
    fn macs_count_sanity() {
        assert_eq!(
            LayerShape::Dense { batch: 2, d_in: 3, d_out: 5 }.macs(),
            30
        );
        let c = LayerShape::Conv { batch: 1, h_out: 2, w_out: 2, k: 3, cin: 4, cout: 8 };
        assert_eq!(c.macs(), (2 * 2 * 9 * 4 * 8) as u64);
    }
}
