//! Accelerator area/throughput estimator — the §5.3/§6 hardware evaluation.
//!
//! Models the Figure-2 accelerator: a square systolic MatMul array of MAC
//! lanes in the chosen dot-product format, an activation/loss unit in
//! narrow FP (the paper uses 8-bit mantissa + 8-bit exponent floats), and
//! the FP→BFP / BFP→FP converter units. Given a silicon budget, it sizes
//! the array to fill the budget and reports throughput + area fractions —
//! reproducing the paper's numbers: 1 TOp/s at 8-bit on a Stratix-V-class
//! budget @ 200MHz, activation unit < 10%, converters < 1%, and BFP8
//! ~8.5x the throughput of the FP16 variant.

use crate::hw::{self, UnitCost};

/// Dot-product arithmetic of the MatMul array.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MacFormat {
    /// BFP: int multipliers at the mantissa width + fixed accumulators.
    Bfp { mantissa_bits: u32 },
    /// FP MACs (e.g. FP16 mult + FP16 add) — the paper's comparison point.
    Fp { m: u32, e: u32 },
    /// FP32 — the software baseline's hardware equivalent.
    Fp32,
}

impl MacFormat {
    /// Cost of one MAC lane.
    pub fn mac_cost(&self, acc_bits: u32) -> UnitCost {
        match *self {
            MacFormat::Bfp { mantissa_bits } => hw::bfp_mac(mantissa_bits, acc_bits),
            MacFormat::Fp { m, e } => hw::fp_mac(m, e, m, e),
            MacFormat::Fp32 => hw::fp_mac(24, 8, 24, 8),
        }
    }

    pub fn name(&self) -> String {
        match *self {
            MacFormat::Bfp { mantissa_bits } => format!("bfp{mantissa_bits}"),
            MacFormat::Fp { m, e } => format!("fp{}(m{m}e{e})", m + e),
            MacFormat::Fp32 => "fp32".to_string(),
        }
    }
}

/// Design parameters of the Figure-2 accelerator.
#[derive(Debug, Clone, Copy)]
pub struct AccelConfig {
    pub format: MacFormat,
    /// Silicon budget in um^2 (45nm equivalents). The default budget is
    /// calibrated so a BFP8 design hits the paper's 1 TOp/s at 200MHz.
    pub budget_um2: f64,
    pub clock_hz: f64,
    /// Accumulator width for BFP arrays (2m + log2 of max dot length).
    pub acc_bits: u32,
    /// Activation-unit throughput match: one activation lane per MatMul
    /// output column (the paper sizes them to avoid backpressure).
    pub act_mantissa: u32,
    pub act_exponent: u32,
}

impl AccelConfig {
    /// Budget calibrated to the paper's prototype scale: BFP8 @ 200 MHz
    /// => ~1 TOp/s (2500 MACs -> 50x50 array).
    pub fn stratix_v_like(format: MacFormat) -> AccelConfig {
        AccelConfig {
            format,
            budget_um2: 1.25e6,
            clock_hz: 200e6,
            acc_bits: 24,
            act_mantissa: 8,
            act_exponent: 8,
        }
    }
}

/// Sized design + its reported metrics.
#[derive(Debug, Clone)]
pub struct AreaReport {
    pub config_name: String,
    /// Systolic array edge (array is edge x edge MAC lanes).
    pub array_edge: usize,
    pub n_macs: usize,
    pub mac_area_um2: f64,
    pub act_area_um2: f64,
    pub conv_area_um2: f64,
    pub total_area_um2: f64,
    /// Fraction of total area per component.
    pub mac_frac: f64,
    pub act_frac: f64,
    pub conv_frac: f64,
    /// Peak throughput in ops/s (1 MAC = 2 ops, the convention the paper's
    /// "1 TOp/s" uses).
    pub peak_ops: f64,
    pub energy_per_mac_pj: f64,
}

/// Size the array for the budget and report area/throughput.
///
/// Component model (Figure 2):
/// - MatMul unit: edge^2 MAC lanes.
/// - Activation/loss unit: `edge` lanes of narrow-FP mult+add (sized to the
///   MatMul output width so there is no backpressure) plus weight-update
///   datapath, also in FP.
/// - Converters: FP→BFP needs a comparator tree + shifter per input lane
///   (2*edge lanes), BFP→FP a normalizer per output lane; both are priced
///   as an int adder + small shifter per lane — they amortize over the
///   whole array, which is why they land under 1%.
pub fn size_design(cfg: &AccelConfig) -> AreaReport {
    let mac = cfg.format.mac_cost(cfg.acc_bits);
    // activation lane: narrow-FP multiply + add + nonlinearity LUT (~priced
    // as one more add)
    let act_lane = {
        let m = hw::fp_mult(cfg.act_mantissa, cfg.act_exponent);
        let a = hw::fp_add(cfg.act_mantissa, cfg.act_exponent);
        UnitCost { area_um2: m.area_um2 + 2.0 * a.area_um2, energy_pj: m.energy_pj + 2.0 * a.energy_pj }
    };
    // converter lane: an 8-bit max-exponent comparator + an 8-bit barrel
    // shifter's worth of logic (priced as two 8-bit adders) — the mantissa
    // realignment hardware Eq. 2 amortizes over the reduction
    let conv_lane = {
        let b = hw::int_add(8);
        UnitCost { area_um2: 2.0 * b.area_um2, energy_pj: 2.0 * b.energy_pj }
    };

    // Output-stationary arrays drain edge^2 results every ~K cycles, so the
    // activation unit needs ~edge/2 lanes to match the MatMul output width
    // (the paper sizes them "to avoid backpressure"); the converters need
    // 2*edge input lanes + edge/2 output lanes ~= 3*edge lanes.
    // Solve for the largest edge fitting the budget:
    //   edge^2 * mac + (edge/2) * act + 3*edge * conv <= budget
    let mut edge = 1usize;
    loop {
        let e = (edge + 1) as f64;
        let total =
            e * e * mac.area_um2 + e / 2.0 * act_lane.area_um2 + 3.0 * e * conv_lane.area_um2;
        if total > cfg.budget_um2 {
            break;
        }
        edge += 1;
    }
    let e = edge as f64;
    let mac_area = e * e * mac.area_um2;
    let act_area = e / 2.0 * act_lane.area_um2;
    let conv_area = 3.0 * e * conv_lane.area_um2;
    let total = mac_area + act_area + conv_area;
    AreaReport {
        config_name: cfg.format.name(),
        array_edge: edge,
        n_macs: edge * edge,
        mac_area_um2: mac_area,
        act_area_um2: act_area,
        conv_area_um2: conv_area,
        total_area_um2: total,
        mac_frac: mac_area / total,
        act_frac: act_area / total,
        conv_frac: conv_area / total,
        peak_ops: 2.0 * (edge * edge) as f64 * cfg.clock_hz,
        energy_per_mac_pj: mac.energy_pj,
    }
}

/// The paper's headline hardware comparison: throughput of `a` relative to
/// `b` on the same budget.
pub fn throughput_ratio(a: MacFormat, b: MacFormat) -> f64 {
    let ra = size_design(&AccelConfig::stratix_v_like(a));
    let rb = size_design(&AccelConfig::stratix_v_like(b));
    ra.peak_ops / rb.peak_ops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bfp8_hits_paper_scale() {
        // ~1 TOp/s at 200MHz on the calibrated budget (§6: "maximum
        // throughput of 1 TOp/s using 8-bit ... at 200 MHz").
        let r = size_design(&AccelConfig::stratix_v_like(MacFormat::Bfp { mantissa_bits: 8 }));
        assert!(
            r.peak_ops > 0.8e12 && r.peak_ops < 1.3e12,
            "peak {:.2} TOp/s",
            r.peak_ops / 1e12
        );
    }

    #[test]
    fn activation_unit_under_10_percent() {
        let r = size_design(&AccelConfig::stratix_v_like(MacFormat::Bfp { mantissa_bits: 8 }));
        assert!(r.act_frac < 0.10, "act frac {}", r.act_frac);
        assert!(r.act_frac > 0.001);
    }

    #[test]
    fn converters_under_1_percent() {
        let r = size_design(&AccelConfig::stratix_v_like(MacFormat::Bfp { mantissa_bits: 8 }));
        assert!(r.conv_frac < 0.01, "conv frac {}", r.conv_frac);
    }

    #[test]
    fn bfp8_vs_fp16_throughput_ratio_near_8_5() {
        let ratio =
            throughput_ratio(MacFormat::Bfp { mantissa_bits: 8 }, MacFormat::Fp { m: 11, e: 5 });
        assert!(
            (6.5..11.0).contains(&ratio),
            "throughput ratio {ratio} out of the paper's ballpark (8.5x)"
        );
    }

    #[test]
    fn wider_mantissas_cost_throughput() {
        let t8 = size_design(&AccelConfig::stratix_v_like(MacFormat::Bfp { mantissa_bits: 8 }))
            .peak_ops;
        let t12 = size_design(&AccelConfig::stratix_v_like(MacFormat::Bfp { mantissa_bits: 12 }))
            .peak_ops;
        let t16 = size_design(&AccelConfig::stratix_v_like(MacFormat::Bfp { mantissa_bits: 16 }))
            .peak_ops;
        assert!(t8 > t12 && t12 > t16);
    }

    #[test]
    fn fp32_is_the_slowest() {
        let t_fp32 = size_design(&AccelConfig::stratix_v_like(MacFormat::Fp32)).peak_ops;
        let t_fp16 =
            size_design(&AccelConfig::stratix_v_like(MacFormat::Fp { m: 11, e: 5 })).peak_ops;
        assert!(t_fp16 > 2.0 * t_fp32);
    }

    #[test]
    fn area_fractions_sum_to_one() {
        let r = size_design(&AccelConfig::stratix_v_like(MacFormat::Bfp { mantissa_bits: 8 }));
        assert!((r.mac_frac + r.act_frac + r.conv_frac - 1.0).abs() < 1e-9);
        assert!(r.total_area_um2 <= 1.25e6 * 1.001);
    }
}
