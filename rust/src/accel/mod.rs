//! Accelerator model (the paper's §5.3 FPGA prototype, Figure 2):
//! area/throughput estimation (`area`) + a cycle-level functional
//! simulator (`sim`) built on the software BFP library.
//!
//! Reproduces the hardware numbers the paper reports: 1 TOp/s for BFP8 at
//! 200 MHz on a Stratix-V-class budget, activation units < 10% of area,
//! converters < 1%, and ~8.5x the throughput of the FP16 variant.

pub mod area;
pub mod sim;
pub mod traffic;

pub use area::{size_design, throughput_ratio, AccelConfig, AreaReport, MacFormat};
pub use sim::{Accelerator, GemmStats};
pub use traffic::{bandwidth_ratio, step_traffic, FormatBits, LayerShape, TrafficReport};
