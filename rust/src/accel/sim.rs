//! Cycle-level functional simulator of the Figure-2 accelerator.
//!
//! Executes a GEMM the way the hardware would — FP→BFP conversion at the
//! array boundary (stochastic rounding via Xorshift32, §5.3), integer MACs
//! with wide accumulators, BFP→FP normalization on the way out, activation
//! unit in narrow FP — while counting cycles of an output-stationary
//! systolic schedule. Produces both the *numbers* (bit-accurate against
//! `crate::bfp`) and the *performance* (cycles, utilization, effective
//! throughput), so the repro harness can report TOp/s per format.
//!
//! Numeric execution goes through one [`BfpContext`] resolved at
//! construction (tile = the array edge) and, for resident weights, a
//! cached [`MatmulPlan`] per loaded layer: every training-step GEMM
//! re-executes the plan with zero per-call policy work, and
//! [`Accelerator::gemm_resident_into`] streams into a caller-held output
//! buffer so the step loop allocates nothing per step.

use anyhow::{anyhow, Result};

use crate::bfp::{BfpContext, BfpTensor, MatmulPlan, PlanCache, Rounding, TileSize};
use crate::util::rng::Xorshift32;

use super::area::{size_design, AccelConfig};

/// Cycle accounting of one GEMM on the systolic array.
#[derive(Debug, Clone, Copy)]
pub struct GemmStats {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub array_edge: usize,
    pub cycles: u64,
    pub macs_used: u64,
    /// MAC-slot utilization in [0, 1].
    pub utilization: f64,
    /// Effective throughput at the config's clock, in ops/s.
    pub effective_ops: f64,
    /// Conversion work overlapped with compute (cycles the converters were
    /// busy; pipelined so they never stall the array — §6 "no performance
    /// overhead").
    pub conv_cycles: u64,
}

/// Weights quantized once and held next to the array (packed-panel
/// layout cached on the tensor) — the paper's resident operand, reused
/// by every training-step GEMM without reconversion or relayout. Also
/// carries a small shape-keyed [`PlanCache`], so alternating activation
/// batch heights (train batch vs eval batch vs ragged tail) each plan
/// once instead of thrashing a single cached plan.
struct ResidentWeights {
    qb: BfpTensor,
    mantissa_bits: u32,
    plans: PlanCache,
}

/// The simulated accelerator.
pub struct Accelerator {
    pub cfg: AccelConfig,
    pub edge: usize,
    ctx: BfpContext,
    rng: Xorshift32,
    resident: Option<ResidentWeights>,
}

impl Accelerator {
    pub fn new(cfg: AccelConfig) -> Accelerator {
        let report = size_design(&cfg);
        let edge = report.array_edge;
        Accelerator {
            cfg,
            edge,
            // exponent tiles == systolic tiles; everything else (threads,
            // SIMD family, backend) resolves from the environment once
            ctx: BfpContext::from_env().with_tile(TileSize::Edge(edge)),
            rng: Xorshift32::new(0xACCE1),
            resident: None,
        }
    }

    /// Execute C = A (MxK) · B (KxN) through the modeled datapath.
    ///
    /// Numeric path: B (the resident operand) is quantized per
    /// (edge x edge) tile with stochastic rounding into packed BFP; A
    /// streams through the fused converter + integer-MAC path
    /// ([`MatmulPlan::quantize_execute_into`]), exactly like activations
    /// crossing the array boundary in Figure 2 — no intermediate
    /// quantized-A tensor is ever materialized. Schedule:
    /// output-stationary; each (edge x edge) output tile streams K values
    /// through the array with a fill+drain of 2*edge cycles.
    pub fn gemm(
        &mut self,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        mantissa_bits: u32,
    ) -> Result<(Vec<f32>, GemmStats)> {
        // one-shot path: quantize into a local operand (never clobbers
        // weights loaded via `load_weights`); its converter cycles count
        // toward this GEMM
        let rw = self.quantize_weights(b, k, n, mantissa_bits)?;
        let plan = self.ctx.plan_matmul(m, k, n, (mantissa_bits, mantissa_bits))?;
        let mut out = Vec::new();
        let Accelerator { cfg, edge, rng, .. } = self;
        let stats = gemm_against(cfg, *edge, rng, &rw, &plan, a, m, true, &mut out)?;
        Ok((out, stats))
    }

    /// Quantize + panel-pack `b` once as the array's resident operand.
    /// Subsequent [`Accelerator::gemm_resident`] calls stream activations
    /// against it without touching the weights again — the amortization a
    /// training run gets from weights staying on the array across steps.
    pub fn load_weights(
        &mut self,
        b: &[f32],
        k: usize,
        n: usize,
        mantissa_bits: u32,
    ) -> Result<()> {
        let rw = self.quantize_weights(b, k, n, mantissa_bits)?;
        self.resident = Some(rw);
        Ok(())
    }

    fn quantize_weights(
        &mut self,
        b: &[f32],
        k: usize,
        n: usize,
        mantissa_bits: u32,
    ) -> Result<ResidentWeights> {
        let qb = {
            let mut rounding = Rounding::Stochastic(&mut self.rng);
            self.ctx.quantize(b, k, n, mantissa_bits, &mut rounding)?
        };
        if k > 0 && n > 0 {
            // pack now, at the context's kernel-family panel width;
            // every GEMM reuses the layout
            qb.packed_panels_nr(self.ctx.isa().panel_nr());
        }
        Ok(ResidentWeights { qb, mantissa_bits, plans: PlanCache::new(4) })
    }

    /// GEMM of streamed activations against the resident weights (must be
    /// loaded first). Only the A-side converter runs; weights were
    /// converted and packed at load time. Allocates a fresh output — the
    /// step loop should prefer [`Accelerator::gemm_resident_into`].
    pub fn gemm_resident(&mut self, a: &[f32], m: usize) -> Result<(Vec<f32>, GemmStats)> {
        let mut out = Vec::new();
        let stats = self.gemm_resident_into(a, m, &mut out)?;
        Ok((out, stats))
    }

    /// [`Accelerator::gemm_resident`] into a caller-held buffer: resized
    /// to `m * n` on first use, then reused allocation-free across steps.
    /// The layer's [`MatmulPlan`]s are cached alongside the weights,
    /// keyed by activation batch height.
    pub fn gemm_resident_into(
        &mut self,
        a: &[f32],
        m: usize,
        out: &mut Vec<f32>,
    ) -> Result<GemmStats> {
        let Accelerator { cfg, edge, ctx, rng, resident } = self;
        let rw = resident
            .as_mut()
            .ok_or_else(|| anyhow!("no resident weights: call load_weights first"))?;
        let plan = rw.plans.get_or_plan(
            ctx,
            m,
            rw.qb.rows,
            rw.qb.cols,
            (rw.mantissa_bits, rw.mantissa_bits),
        )?;
        gemm_against(cfg, *edge, rng, rw, &plan, a, m, false, out)
    }

    /// Activation-unit pass (ReLU in narrow FP): counted at one element per
    /// lane per cycle, `edge` lanes — sized to the MatMul output rate so it
    /// adds pipeline latency, not throughput loss.
    pub fn relu(&mut self, x: &mut [f32]) -> u64 {
        for v in x.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        (x.len() as u64).div_ceil(self.edge as u64)
    }
}

/// Numeric path + cycle accounting of one GEMM against quantized,
/// panel-packed weights, executed through the layer's plan into the
/// caller's buffer. `count_weight_conv` adds the weight-side converter
/// traffic (one-shot GEMMs convert weights in-call; resident weights
/// were converted at load).
#[allow(clippy::too_many_arguments)]
fn gemm_against(
    cfg: &AccelConfig,
    edge: usize,
    rng: &mut Xorshift32,
    rw: &ResidentWeights,
    plan: &MatmulPlan,
    a: &[f32],
    m: usize,
    count_weight_conv: bool,
    out: &mut Vec<f32>,
) -> Result<GemmStats> {
    let (k, n) = (rw.qb.rows, rw.qb.cols);
    out.resize(plan.out_len(), 0.0);
    plan.quantize_execute_into(a, &mut Rounding::Stochastic(rng), &rw.qb, out)?;

    let e = edge as u64;
    let tiles_m = m.div_ceil(edge) as u64;
    let tiles_n = n.div_ceil(edge) as u64;
    // per output tile: K MAC cycles + fill/drain
    let per_tile = k as u64 + 2 * e;
    let cycles = tiles_m * tiles_n * per_tile;
    let macs_used = (m as u64) * (k as u64) * (n as u64);
    let mac_slots = cycles * e * e;
    let utilization = macs_used as f64 / mac_slots as f64;
    // converters process 2*edge inputs per cycle, pipelined with compute
    let conv_inputs = (m * k + if count_weight_conv { k * n } else { 0 }) as u64;
    let conv_cycles = conv_inputs / (2 * e).max(1);
    let secs = cycles as f64 / cfg.clock_hz;
    let effective_ops = 2.0 * macs_used as f64 / secs;
    Ok(GemmStats {
        m,
        k,
        n,
        array_edge: edge,
        cycles,
        macs_used,
        utilization,
        effective_ops,
        conv_cycles,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::area::MacFormat;
    use crate::bfp::fp32_matmul;
    use crate::util::rng::SplitMix64;

    fn accel() -> Accelerator {
        Accelerator::new(AccelConfig::stratix_v_like(MacFormat::Bfp { mantissa_bits: 8 }))
    }

    #[test]
    fn gemm_numerics_close_to_fp32() {
        let mut rng = SplitMix64::new(1);
        let (m, k, n) = (64, 96, 48);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let exact = fp32_matmul(&a, &b, m, k, n);
        let (got, _) = accel().gemm(&a, &b, m, k, n, 8).unwrap();
        let amax = exact.iter().fold(0.0f32, |s, &x| s.max(x.abs()));
        let err = got.iter().zip(&exact).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max) / amax;
        assert!(err < 0.05, "rel err {err}");
    }

    #[test]
    fn large_gemm_high_utilization() {
        let mut acc = accel();
        let e = acc.edge;
        let (m, k, n) = (4 * e, 8 * e, 4 * e);
        let a = vec![0.5f32; m * k];
        let b = vec![0.5f32; k * n];
        let (_, stats) = acc.gemm(&a, &b, m, k, n, 8).unwrap();
        assert!(stats.utilization > 0.7, "utilization {}", stats.utilization);
        assert!(stats.effective_ops > 0.5e12, "{} ops/s", stats.effective_ops);
    }

    #[test]
    fn small_gemm_low_utilization() {
        let mut acc = accel();
        let (_, stats) = acc.gemm(&[1.0; 64], &[1.0; 64], 8, 8, 8, 8).unwrap();
        assert!(stats.utilization < 0.1);
    }

    #[test]
    fn converters_never_dominate() {
        let mut acc = accel();
        let e = acc.edge;
        let (m, k, n) = (2 * e, 4 * e, 2 * e);
        let a = vec![0.1f32; m * k];
        let b = vec![0.1f32; k * n];
        let (_, stats) = acc.gemm(&a, &b, m, k, n, 8).unwrap();
        // pipelined conversion stays under the compute cycle count
        assert!(stats.conv_cycles < stats.cycles, "{} vs {}", stats.conv_cycles, stats.cycles);
    }

    #[test]
    fn resident_weights_reused_across_steps() {
        // Two accelerators with identical seeds: one loads weights once
        // and streams two batches; the other must match it by doing the
        // same draws — the resident path changes cost accounting, never
        // numerics.
        let mut rng = SplitMix64::new(9);
        let e = accel().edge;
        let (m, k, n) = (2 * e, 4 * e, 2 * e); // edge-relative: conv counts stay nonzero
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let a1: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let a2: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();

        let mut acc = accel();
        acc.load_weights(&b, k, n, 8).unwrap();
        let (o1, s1) = acc.gemm_resident(&a1, m).unwrap();
        let (o2, s2) = acc.gemm_resident(&a2, m).unwrap();
        assert_ne!(o1, o2);
        assert_eq!(s1.cycles, s2.cycles);
        // resident steps convert only activations; a one-shot gemm also
        // converts the weights
        let mut one_shot = accel();
        let (_, s3) = one_shot.gemm(&a1, &b, m, k, n, 8).unwrap();
        assert!(s1.conv_cycles < s3.conv_cycles, "{} !< {}", s1.conv_cycles, s3.conv_cycles);
        // and the one-shot path equals load+resident with the same RNG
        let mut split = accel();
        split.load_weights(&b, k, n, 8).unwrap();
        let (o3, _) = split.gemm_resident(&a1, m).unwrap();
        let mut fused = accel();
        let (o4, _) = fused.gemm(&a1, &b, m, k, n, 8).unwrap();
        assert_eq!(o3, o4, "gemm must equal load_weights + gemm_resident");
    }

    #[test]
    fn gemm_resident_into_reuses_the_buffer_and_plan() {
        // The step-loop shape: one caller-held output buffer across
        // steps, the layer plan cached on the resident weights, results
        // identical to the allocating wrapper with the same RNG stream.
        let mut rng = SplitMix64::new(0x1C);
        let e = accel().edge;
        let (m, k, n) = (e, 2 * e, e);
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let a1: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let a2: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();

        let mut want = accel();
        want.load_weights(&b, k, n, 8).unwrap();
        let (w1, _) = want.gemm_resident(&a1, m).unwrap();
        let (w2, _) = want.gemm_resident(&a2, m).unwrap();

        let mut acc = accel();
        acc.load_weights(&b, k, n, 8).unwrap();
        let mut out = Vec::new();
        let s1 = acc.gemm_resident_into(&a1, m, &mut out).unwrap();
        assert_eq!(out, w1);
        assert_eq!((s1.m, s1.k, s1.n), (m, k, n));
        let cap = out.capacity();
        acc.gemm_resident_into(&a2, m, &mut out).unwrap();
        assert_eq!(out, w2);
        assert_eq!(out.capacity(), cap, "steady-state steps must not reallocate");
        let plans = &acc.resident.as_ref().unwrap().plans;
        assert_eq!(plans.len(), 1, "one batch height, one cached plan");
        assert_eq!(plans.hits(), 1, "the second step reused it");
        let key = plans.keys()[0];
        assert_eq!((key.m, key.k, key.n), (m, k, n));
    }

    #[test]
    fn gemm_resident_requires_loaded_weights() {
        let mut acc = accel();
        assert!(acc.gemm_resident(&[1.0; 8], 1).is_err());
    }

    #[test]
    fn resident_weights_pack_at_the_active_simd_width() {
        // load_weights pre-packs the panel layout; it must be the layout
        // the context's kernel family streams, or the first gemm_resident
        // would silently repack (paying the relayout per step).
        let mut rng = SplitMix64::new(12);
        let mut acc = accel();
        let e = acc.edge;
        let (k, n) = (2 * e, e);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        acc.load_weights(&w, k, n, 8).unwrap();
        let rw = acc.resident.as_ref().unwrap();
        assert!(rw.qb.has_packed_panels(), "load_weights must pre-pack");
        let pp = rw.qb.packed_panels();
        assert_eq!(
            pp.nr,
            crate::bfp::kernels::active_panel_nr(),
            "resident panels must match the active kernel family's width"
        );
    }

    #[test]
    fn one_shot_gemm_does_not_clobber_resident_weights() {
        let mut rng = SplitMix64::new(4);
        let mut acc = accel();
        let e = acc.edge;
        let (m, k, n) = (e, 2 * e, e);
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        acc.load_weights(&w, k, n, 8).unwrap();
        // an unrelated one-shot multiply must not replace the loaded weights
        let other: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let _ = acc.gemm(&[1.0; 16], &other, 4, 4, 4, 8).unwrap();
        let (_, stats) = acc.gemm_resident(&a, m).unwrap();
        assert_eq!((stats.k, stats.n), (k, n), "resident dims must survive one-shot gemm");
    }

    #[test]
    fn relu_cycles_and_semantics() {
        let mut acc = accel();
        let mut x = vec![-1.0f32, 2.0, -3.0, 4.0];
        let cycles = acc.relu(&mut x);
        assert_eq!(x, vec![0.0, 2.0, 0.0, 4.0]);
        assert!(cycles >= 1);
    }
}
