//! Cycle-level functional simulator of the Figure-2 accelerator.
//!
//! Executes a GEMM the way the hardware would — FP→BFP conversion at the
//! array boundary (stochastic rounding via Xorshift32, §5.3), integer MACs
//! with wide accumulators, BFP→FP normalization on the way out, activation
//! unit in narrow FP — while counting cycles of an output-stationary
//! systolic schedule. Produces both the *numbers* (bit-accurate against
//! `crate::bfp`) and the *performance* (cycles, utilization, effective
//! throughput), so the repro harness can report TOp/s per format.

use anyhow::Result;

use crate::bfp::{BfpTensor, Rounding, TileSize};
use crate::util::rng::Xorshift32;

use super::area::{size_design, AccelConfig};

/// Cycle accounting of one GEMM on the systolic array.
#[derive(Debug, Clone, Copy)]
pub struct GemmStats {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub array_edge: usize,
    pub cycles: u64,
    pub macs_used: u64,
    /// MAC-slot utilization in [0, 1].
    pub utilization: f64,
    /// Effective throughput at the config's clock, in ops/s.
    pub effective_ops: f64,
    /// Conversion work overlapped with compute (cycles the converters were
    /// busy; pipelined so they never stall the array — §6 "no performance
    /// overhead").
    pub conv_cycles: u64,
}

/// The simulated accelerator.
pub struct Accelerator {
    pub cfg: AccelConfig,
    pub edge: usize,
    rng: Xorshift32,
}

impl Accelerator {
    pub fn new(cfg: AccelConfig) -> Accelerator {
        let report = size_design(&cfg);
        Accelerator { cfg, edge: report.array_edge, rng: Xorshift32::new(0xACCE1) }
    }

    /// Execute C = A (MxK) · B (KxN) through the modeled datapath.
    ///
    /// Numeric path: B (the resident operand) is quantized per
    /// (edge x edge) tile with stochastic rounding into packed BFP; A
    /// streams through the fused converter + integer-MAC path
    /// (`quantize_matmul`), exactly like activations crossing the array
    /// boundary in Figure 2 — no intermediate quantized-A tensor is ever
    /// materialized. Schedule: output-stationary; each (edge x edge)
    /// output tile streams K values through the array with a fill+drain
    /// of 2*edge cycles.
    pub fn gemm(
        &mut self,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
        mantissa_bits: u32,
    ) -> Result<(Vec<f32>, GemmStats)> {
        let tile = TileSize::Edge(self.edge);
        let qb = {
            let rounding = &mut Rounding::Stochastic(&mut self.rng);
            BfpTensor::from_f32(b, k, n, mantissa_bits, tile, rounding)?
        };
        let out = crate::bfp::quantize_matmul(
            a,
            m,
            mantissa_bits,
            &mut Rounding::Stochastic(&mut self.rng),
            &qb,
        )?;

        let e = self.edge as u64;
        let tiles_m = m.div_ceil(self.edge) as u64;
        let tiles_n = n.div_ceil(self.edge) as u64;
        // per output tile: K MAC cycles + fill/drain
        let per_tile = k as u64 + 2 * e;
        let cycles = tiles_m * tiles_n * per_tile;
        let macs_used = (m as u64) * (k as u64) * (n as u64);
        let mac_slots = cycles * e * e;
        let utilization = macs_used as f64 / mac_slots as f64;
        // converters process 2*edge inputs per cycle, pipelined with compute
        let conv_inputs = (m * k + k * n) as u64;
        let conv_cycles = conv_inputs / (2 * e).max(1);
        let secs = cycles as f64 / self.cfg.clock_hz;
        let effective_ops = 2.0 * macs_used as f64 / secs;
        Ok((
            out,
            GemmStats {
                m,
                k,
                n,
                array_edge: self.edge,
                cycles,
                macs_used,
                utilization,
                effective_ops,
                conv_cycles,
            },
        ))
    }

    /// Activation-unit pass (ReLU in narrow FP): counted at one element per
    /// lane per cycle, `edge` lanes — sized to the MatMul output rate so it
    /// adds pipeline latency, not throughput loss.
    pub fn relu(&mut self, x: &mut [f32]) -> u64 {
        for v in x.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        (x.len() as u64).div_ceil(self.edge as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::area::MacFormat;
    use crate::bfp::fp32_matmul;
    use crate::util::rng::SplitMix64;

    fn accel() -> Accelerator {
        Accelerator::new(AccelConfig::stratix_v_like(MacFormat::Bfp { mantissa_bits: 8 }))
    }

    #[test]
    fn gemm_numerics_close_to_fp32() {
        let mut rng = SplitMix64::new(1);
        let (m, k, n) = (64, 96, 48);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let exact = fp32_matmul(&a, &b, m, k, n);
        let (got, _) = accel().gemm(&a, &b, m, k, n, 8).unwrap();
        let amax = exact.iter().fold(0.0f32, |s, &x| s.max(x.abs()));
        let err = got.iter().zip(&exact).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max) / amax;
        assert!(err < 0.05, "rel err {err}");
    }

    #[test]
    fn large_gemm_high_utilization() {
        let mut acc = accel();
        let e = acc.edge;
        let (m, k, n) = (4 * e, 8 * e, 4 * e);
        let a = vec![0.5f32; m * k];
        let b = vec![0.5f32; k * n];
        let (_, stats) = acc.gemm(&a, &b, m, k, n, 8).unwrap();
        assert!(stats.utilization > 0.7, "utilization {}", stats.utilization);
        assert!(stats.effective_ops > 0.5e12, "{} ops/s", stats.effective_ops);
    }

    #[test]
    fn small_gemm_low_utilization() {
        let mut acc = accel();
        let (_, stats) = acc.gemm(&[1.0; 64], &[1.0; 64], 8, 8, 8, 8).unwrap();
        assert!(stats.utilization < 0.1);
    }

    #[test]
    fn converters_never_dominate() {
        let mut acc = accel();
        let e = acc.edge;
        let (m, k, n) = (2 * e, 4 * e, 2 * e);
        let a = vec![0.1f32; m * k];
        let b = vec![0.1f32; k * n];
        let (_, stats) = acc.gemm(&a, &b, m, k, n, 8).unwrap();
        // pipelined conversion stays under the compute cycle count
        assert!(stats.conv_cycles < stats.cycles, "{} vs {}", stats.conv_cycles, stats.cycles);
    }

    #[test]
    fn relu_cycles_and_semantics() {
        let mut acc = accel();
        let mut x = vec![-1.0f32, 2.0, -3.0, 4.0];
        let cycles = acc.relu(&mut x);
        assert_eq!(x, vec![0.0, 2.0, 0.0, 4.0]);
        assert!(cycles >= 1);
    }
}
