//! Training metric collection: per-step records, eval records, CSV export,
//! and the summary statistics the repro harnesses report.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};
// (Path used in write_csv signature)

use crate::util::json::Json;

#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
    pub step_secs: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct EvalRecord {
    pub step: usize,
    /// Mean per-example loss over the validation set.
    pub loss: f32,
    /// Top-1 error in [0, 1] (images) / per-char error (text).
    pub error: f32,
}

impl EvalRecord {
    /// Perplexity view for LM runs: exp(mean loss).
    pub fn perplexity(&self) -> f32 {
        self.loss.exp()
    }
}

/// One fault-tolerance intervention during a run: what tripped the
/// watchdog ([`RecoveryKind`]) and what the loop did about it
/// ([`RecoveryAction`]). Appended to [`History::recoveries`] so recovery
/// behaviour is visible in the same CSV/JSON artifacts as the loss curve.
#[derive(Debug, Clone)]
pub struct RecoveryEvent {
    /// Training step at which the hazard was detected.
    pub step: usize,
    pub kind: RecoveryKind,
    pub action: RecoveryAction,
    /// Human-readable diagnostic (offending value, error text, …).
    pub detail: String,
}

/// What tripped the watchdog.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryKind {
    /// NaN/Inf loss (or a numeric-guard abort surfaced as a step error).
    NonFiniteLoss,
    /// Finite but exploding loss (above the divergence threshold).
    ExplodingLoss,
    /// The step itself failed (worker panic, guard abort, checkpoint IO).
    StepError,
    /// A checkpoint failed validation during restore and was skipped.
    CorruptCheckpoint,
}

impl RecoveryKind {
    pub fn name(self) -> &'static str {
        match self {
            RecoveryKind::NonFiniteLoss => "non-finite-loss",
            RecoveryKind::ExplodingLoss => "exploding-loss",
            RecoveryKind::StepError => "step-error",
            RecoveryKind::CorruptCheckpoint => "corrupt-checkpoint",
        }
    }
}

/// What the loop did in response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryAction {
    /// Rolled state back to the newest valid checkpoint.
    Rollback,
    /// Rolled back and widened the mantissa width class.
    RollbackWiden,
    /// Restarted from step 0 (no valid checkpoint existed).
    Restart,
    /// Gave up: the recovery budget was exhausted.
    Abort,
}

impl RecoveryAction {
    pub fn name(self) -> &'static str {
        match self {
            RecoveryAction::Rollback => "rollback",
            RecoveryAction::RollbackWiden => "rollback-widen",
            RecoveryAction::Restart => "restart",
            RecoveryAction::Abort => "abort",
        }
    }
}

/// Full history of one run.
#[derive(Debug, Default, Clone)]
pub struct History {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    /// Fault-tolerance interventions, in detection order (empty for a
    /// clean run — and absent from the CSV/JSON output in that case).
    pub recoveries: Vec<RecoveryEvent>,
}

impl History {
    pub fn final_eval(&self) -> Option<&EvalRecord> {
        self.evals.last()
    }

    pub fn best_error(&self) -> Option<f32> {
        self.evals.iter().map(|e| e.error).min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Mean training loss over the last `n` recorded steps (convergence
    /// signal robust to per-batch noise).
    pub fn tail_loss(&self, n: usize) -> Option<f32> {
        if self.steps.is_empty() {
            return None;
        }
        let k = self.steps.len().saturating_sub(n);
        let tail = &self.steps[k..];
        Some(tail.iter().map(|s| s.loss).sum::<f32>() / tail.len() as f32)
    }

    /// Steps/second over the whole run (excludes eval time by construction:
    /// step_secs measures only the train step).
    pub fn throughput(&self) -> Option<f64> {
        if self.steps.is_empty() {
            return None;
        }
        let total: f64 = self.steps.iter().map(|s| s.step_secs).sum();
        Some(self.steps.len() as f64 / total)
    }

    /// Did the run diverge (NaN/inf loss or loss explosion)?
    pub fn diverged(&self) -> bool {
        self.steps
            .iter()
            .any(|s| !s.loss.is_finite() || s.loss > 50.0)
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
        writeln!(f, "kind,step,loss,metric,lr,secs")?;
        for s in &self.steps {
            writeln!(f, "train,{},{},{},{},{:.6}", s.step, s.loss, s.acc, s.lr, s.step_secs)?;
        }
        for e in &self.evals {
            writeln!(f, "eval,{},{},{},,", e.step, e.loss, e.error)?;
        }
        for r in &self.recoveries {
            // detail is free text: keep the row parseable
            let detail = r.detail.replace([',', '\n'], ";");
            writeln!(f, "recovery,{},,{},{},{}", r.step, r.kind.name(), r.action.name(), detail)?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            (
                "train",
                Json::Arr(
                    self.steps
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("step", Json::num(s.step as f64)),
                                ("loss", Json::num(s.loss)),
                                ("acc", Json::num(s.acc)),
                                ("lr", Json::num(s.lr)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "eval",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("step", Json::num(e.step as f64)),
                                ("loss", Json::num(e.loss)),
                                ("error", Json::num(e.error)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if !self.recoveries.is_empty() {
            fields.push((
                "recoveries",
                Json::Arr(
                    self.recoveries
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("step", Json::num(r.step as f64)),
                                ("kind", Json::Str(r.kind.name().to_string())),
                                ("action", Json::Str(r.action.name().to_string())),
                                ("detail", Json::Str(r.detail.clone())),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist() -> History {
        History {
            steps: (0..10)
                .map(|i| StepRecord {
                    step: i,
                    loss: 2.0 - i as f32 * 0.1,
                    acc: i as f32 * 0.05,
                    lr: 0.1,
                    step_secs: 0.01,
                })
                .collect(),
            evals: vec![
                EvalRecord { step: 5, loss: 1.0, error: 0.4 },
                EvalRecord { step: 10, loss: 0.8, error: 0.3 },
            ],
        }
    }

    #[test]
    fn summaries() {
        let h = hist();
        assert_eq!(h.final_eval().unwrap().error, 0.3);
        assert_eq!(h.best_error().unwrap(), 0.3);
        assert!((h.throughput().unwrap() - 100.0).abs() < 1.0);
        assert!(!h.diverged());
        assert!(h.tail_loss(3).unwrap() < 1.3);
    }

    #[test]
    fn divergence_detection() {
        let mut h = hist();
        h.steps.push(StepRecord { step: 11, loss: f32::NAN, acc: 0.0, lr: 0.1, step_secs: 0.01 });
        assert!(h.diverged());
    }

    #[test]
    fn perplexity() {
        let e = EvalRecord { step: 0, loss: 2.0, error: 0.5 };
        assert!((e.perplexity() - 2.0f32.exp()).abs() < 1e-4);
    }

    #[test]
    fn csv_writes() {
        let p = std::env::temp_dir().join("hbfp_metrics_test.csv");
        hist().write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.lines().count() == 1 + 10 + 2);
        assert!(s.starts_with("kind,step"));
    }

    #[test]
    fn recoveries_surface_in_csv_and_json_only_when_present() {
        assert!(hist().to_json().get("recoveries").is_none(), "clean run stays clean");
        let mut h = hist();
        h.recoveries.push(RecoveryEvent {
            step: 7,
            kind: RecoveryKind::NonFiniteLoss,
            action: RecoveryAction::RollbackWiden,
            detail: "loss=NaN, widened 8->16".into(),
        });
        let p = std::env::temp_dir().join("hbfp_metrics_recovery_test.csv");
        h.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s.lines().count(), 1 + 10 + 2 + 1);
        let row = s.lines().last().unwrap();
        assert!(row.starts_with("recovery,7,,non-finite-loss,rollback-widen,"));
        assert!(row.contains("loss=NaN; widened"), "detail commas sanitized: {row}");
        let rec = h.to_json();
        let rec = rec.get("recoveries").unwrap().as_arr().unwrap();
        assert_eq!(rec.len(), 1);
        assert_eq!(rec[0].get("kind").unwrap().as_str().unwrap(), "non-finite-loss");
    }
}
