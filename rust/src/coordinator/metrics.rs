//! Training metric collection: per-step records, eval records, CSV export,
//! and the summary statistics the repro harnesses report.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};
// (Path used in write_csv signature)

use crate::bfp::stats::GuardStatsSnapshot;
use crate::util::json::Json;

#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
    pub step_secs: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct EvalRecord {
    pub step: usize,
    /// Mean per-example loss over the validation set.
    pub loss: f32,
    /// Top-1 error in [0, 1] (images) / per-char error (text).
    pub error: f32,
}

impl EvalRecord {
    /// Perplexity view for LM runs: exp(mean loss).
    pub fn perplexity(&self) -> f32 {
        self.loss.exp()
    }
}

/// One fault-tolerance intervention during a run: what tripped the
/// watchdog ([`RecoveryKind`]) and what the loop did about it
/// ([`RecoveryAction`]). Appended to [`History::recoveries`] so recovery
/// behaviour is visible in the same CSV/JSON artifacts as the loss curve.
#[derive(Debug, Clone)]
pub struct RecoveryEvent {
    /// Training step at which the hazard was detected.
    pub step: usize,
    pub kind: RecoveryKind,
    pub action: RecoveryAction,
    /// Human-readable diagnostic (offending value, error text, …).
    pub detail: String,
}

/// What tripped the watchdog.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryKind {
    /// NaN/Inf loss (or a numeric-guard abort surfaced as a step error).
    NonFiniteLoss,
    /// Finite but exploding loss (above the divergence threshold).
    ExplodingLoss,
    /// The step itself failed (worker panic, guard abort, checkpoint IO).
    StepError,
    /// A checkpoint failed validation during restore and was skipped.
    CorruptCheckpoint,
}

impl RecoveryKind {
    pub fn name(self) -> &'static str {
        match self {
            RecoveryKind::NonFiniteLoss => "non-finite-loss",
            RecoveryKind::ExplodingLoss => "exploding-loss",
            RecoveryKind::StepError => "step-error",
            RecoveryKind::CorruptCheckpoint => "corrupt-checkpoint",
        }
    }
}

/// What the loop did in response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryAction {
    /// Rolled state back to the newest valid checkpoint.
    Rollback,
    /// Rolled back and widened the mantissa width class.
    RollbackWiden,
    /// Restarted from step 0 (no valid checkpoint existed).
    Restart,
    /// Gave up: the recovery budget was exhausted.
    Abort,
}

impl RecoveryAction {
    pub fn name(self) -> &'static str {
        match self {
            RecoveryAction::Rollback => "rollback",
            RecoveryAction::RollbackWiden => "rollback-widen",
            RecoveryAction::Restart => "restart",
            RecoveryAction::Abort => "abort",
        }
    }
}

/// Streaming latency histogram: fixed log2 buckets, one `u64` counter
/// each — recording a sample is a handful of integer ops with **no
/// per-sample allocation**, so the serving hot path can record every
/// request. Bucket `i` holds values whose bit length is `i` (bucket 0:
/// the value 0; bucket 63 additionally absorbs everything ≥ 2^62), which
/// keeps relative resolution constant (~1 bucket per doubling) across
/// the microsecond-to-minute range percentile extraction cares about.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LatencyHistogram {
    buckets: [u64; 64],
    count: u64,
    sum: u64,
    max: u64,
}

impl Default for LatencyHistogram {
    fn default() -> LatencyHistogram {
        LatencyHistogram { buckets: [0; 64], count: 0, sum: 0, max: 0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    /// Record one sample (any unit; callers pick one and stick to it).
    pub fn record(&mut self, value: u64) {
        let idx = (u64::BITS - value.leading_zeros()) as usize;
        self.buckets[idx.min(63)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(value);
        self.max = self.max.max(value);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Mean of all recorded samples. **Empty histogram: returns `0.0`**
    /// (never divides by zero, never NaN) — an unused latency section
    /// renders as zeros, not as nulls.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper bound of the bucket holding the `p`-quantile sample
    /// (`p` in [0, 1]), clamped to the observed maximum — an upper
    /// bound on the true percentile that is exact to within one
    /// doubling, which is what a deadline assertion needs.
    ///
    /// Edge behavior (locked in by `latency_histogram_edge_cases`):
    ///
    /// - **empty histogram**: returns `0` for every `p`;
    /// - **`p <= 0.0`**: rank clamps to 1 — the upper bound of the
    ///   *smallest* sample's bucket (a min estimate, same doubling
    ///   resolution);
    /// - **`p >= 1.0`**: rank clamps to `count` and the result clamps to
    ///   the exact observed [`max`](Self::max);
    /// - **non-finite `p`** (NaN): treated like `p = 0`.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= rank {
                let upper = match i {
                    0 => 0,
                    63 => u64::MAX,
                    _ => (1u64 << i) - 1,
                };
                return upper.min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }

    /// Register this histogram into `reg` under `name` — the registry
    /// renders it through [`LatencyHistogram::to_json`], so a
    /// registry-routed latency section is byte-identical to a hand-rolled
    /// one.
    pub fn export_metrics(&self, reg: &crate::obs::Registry, name: &str) {
        reg.histogram(name, self.clone());
    }

    /// Summary + the nonzero buckets (as `[bit_length, count]` pairs, so
    /// two runs' histograms compare equal iff every sample landed in the
    /// same bucket).
    pub fn to_json(&self) -> Json {
        let buckets = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &b)| b != 0)
            .map(|(i, &b)| Json::Arr(vec![Json::num(i as f64), Json::num(b as f64)]))
            .collect();
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean", Json::num(self.mean())),
            ("max", Json::num(self.max as f64)),
            ("p50", Json::num(self.p50() as f64)),
            ("p95", Json::num(self.p95() as f64)),
            ("p99", Json::num(self.p99() as f64)),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// Per-tenant (per resident model) serving counters: the fairness view.
/// The scheduler *enforces* fair share; this makes it observable — one
/// section per model in `metrics_json()`, each with its own latency
/// percentiles, so a flooding tenant's queueing shows up in *its* p99,
/// not its neighbours'.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ModelMetrics {
    pub name: String,
    /// Registered DRR share weight.
    pub share: u32,
    pub admitted: u64,
    pub served: u64,
    /// Served at the narrow width (subset of `served`).
    pub degraded: u64,
    /// All expiry kinds (dequeue + completion + drain force-expiry).
    pub expired: u64,
    pub failed: u64,
    /// Submissions refused because this model's breaker was open.
    pub quarantined: u64,
    /// End-to-end latency of this tenant's served requests.
    pub latency: LatencyHistogram,
}

impl ModelMetrics {
    /// Register this tenant's counters into `reg` under `prefix`
    /// (dot-joined when non-empty). The one key list behind both
    /// [`ModelMetrics::to_json`] and registry snapshots.
    pub fn export_metrics(&self, reg: &crate::obs::Registry, prefix: &str) {
        let name = |k: &str| {
            if prefix.is_empty() {
                k.to_string()
            } else {
                format!("{prefix}.{k}")
            }
        };
        reg.text(&name("name"), &self.name);
        reg.counter(&name("share"), self.share as u64);
        reg.counter(&name("admitted"), self.admitted);
        reg.counter(&name("served"), self.served);
        reg.counter(&name("degraded"), self.degraded);
        reg.counter(&name("expired"), self.expired);
        reg.counter(&name("failed"), self.failed);
        reg.counter(&name("quarantined"), self.quarantined);
        reg.histogram(&name("latency"), self.latency.clone());
    }

    pub fn to_json(&self) -> Json {
        let reg = crate::obs::Registry::new();
        self.export_metrics(&reg, "");
        reg.to_json()
    }
}

/// Counters of the serving front-end (`crate::serve`), aggregated per
/// server. Everything is a plain integer or a [`LatencyHistogram`], so a
/// whole-run metrics comparison (the overload-soak determinism check) is
/// a single `==` / JSON string equality.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ServeMetrics {
    /// Requests accepted into the queue.
    pub admitted: u64,
    /// Rejections by cause (the typed `Rejected` ladder).
    pub rejected_queue_full: u64,
    pub rejected_overloaded: u64,
    pub rejected_shedding: u64,
    /// Submissions refused by an open per-tenant circuit breaker.
    pub rejected_quarantined: u64,
    /// Submissions refused because the server is draining or stopped.
    pub rejected_draining: u64,
    /// Deadline expiries: caught before the GEMM vs after it.
    pub expired_at_dequeue: u64,
    pub expired_at_completion: u64,
    /// Admitted work force-expired at the drain deadline.
    pub expired_at_drain: u64,
    /// Requests answered (including degraded ones).
    pub completed: u64,
    /// Completed responses served at the degraded width class.
    pub degraded_served: u64,
    /// Requests failed individually (poisoned input, unrecoverable GEMM).
    pub failed: u64,
    /// Micro-batches executed / rows across them.
    pub batches: u64,
    pub batched_rows: u64,
    /// `slow-request` fault-site hits observed.
    pub slow_requests: u64,
    /// Contained `PoolPanic`s (each failed one attempt, never the loop).
    pub panics_contained: u64,
    /// Whole-batch GEMM retries after a contained panic.
    pub gemm_retries: u64,
    /// Batches that fell back to per-row execution.
    pub split_fallbacks: u64,
    /// High-water mark of the request queue (sum across tenants).
    pub max_queue_depth: u64,
    /// Circuit-breaker lifecycle events across all tenants.
    pub breaker_trips: u64,
    pub breaker_recoveries: u64,
    /// Hot weight reloads: generations swapped vs rolled back.
    pub reloads: u64,
    pub reload_rollbacks: u64,
    /// End-to-end latency of completed requests (submit → response).
    pub latency: LatencyHistogram,
    /// Per-tenant sections, indexed by model id.
    pub models: Vec<ModelMetrics>,
}

impl ServeMetrics {
    /// Track the queue-depth high-water mark.
    pub fn note_depth(&mut self, depth: usize) {
        self.max_queue_depth = self.max_queue_depth.max(depth as u64);
    }

    /// All rejections regardless of cause.
    pub fn rejected_total(&self) -> u64 {
        self.rejected_queue_full
            + self.rejected_overloaded
            + self.rejected_shedding
            + self.rejected_quarantined
            + self.rejected_draining
    }

    /// Register the whole serving surface into `reg` under `prefix`
    /// (dot-joined when non-empty): every counter, the end-to-end latency
    /// histogram, and the per-tenant sections (attached as the `models`
    /// array so its shape matches the historical JSON exactly).
    pub fn export_metrics(&self, reg: &crate::obs::Registry, prefix: &str) {
        let name = |k: &str| {
            if prefix.is_empty() {
                k.to_string()
            } else {
                format!("{prefix}.{k}")
            }
        };
        for (k, v) in [
            ("admitted", self.admitted),
            ("rejected_queue_full", self.rejected_queue_full),
            ("rejected_overloaded", self.rejected_overloaded),
            ("rejected_shedding", self.rejected_shedding),
            ("rejected_quarantined", self.rejected_quarantined),
            ("rejected_draining", self.rejected_draining),
            ("expired_at_dequeue", self.expired_at_dequeue),
            ("expired_at_completion", self.expired_at_completion),
            ("expired_at_drain", self.expired_at_drain),
            ("completed", self.completed),
            ("degraded_served", self.degraded_served),
            ("failed", self.failed),
            ("batches", self.batches),
            ("batched_rows", self.batched_rows),
            ("slow_requests", self.slow_requests),
            ("panics_contained", self.panics_contained),
            ("gemm_retries", self.gemm_retries),
            ("split_fallbacks", self.split_fallbacks),
            ("max_queue_depth", self.max_queue_depth),
            ("breaker_trips", self.breaker_trips),
            ("breaker_recoveries", self.breaker_recoveries),
            ("reloads", self.reloads),
            ("reload_rollbacks", self.reload_rollbacks),
        ] {
            reg.counter(&name(k), v);
        }
        self.latency.export_metrics(reg, &name("latency"));
        reg.attach(
            &name("models"),
            Json::Arr(self.models.iter().map(|m| m.to_json()).collect()),
        );
    }

    pub fn to_json(&self) -> Json {
        let reg = crate::obs::Registry::new();
        self.export_metrics(&reg, "");
        reg.to_json()
    }

    /// `name,value` rows (latency summarized as percentiles), mirroring
    /// the JSON artifact for spreadsheet consumers.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
        writeln!(f, "counter,value")?;
        for (name, v) in [
            ("admitted", self.admitted),
            ("rejected_queue_full", self.rejected_queue_full),
            ("rejected_overloaded", self.rejected_overloaded),
            ("rejected_shedding", self.rejected_shedding),
            ("rejected_quarantined", self.rejected_quarantined),
            ("rejected_draining", self.rejected_draining),
            ("expired_at_dequeue", self.expired_at_dequeue),
            ("expired_at_completion", self.expired_at_completion),
            ("expired_at_drain", self.expired_at_drain),
            ("completed", self.completed),
            ("degraded_served", self.degraded_served),
            ("failed", self.failed),
            ("batches", self.batches),
            ("batched_rows", self.batched_rows),
            ("slow_requests", self.slow_requests),
            ("panics_contained", self.panics_contained),
            ("gemm_retries", self.gemm_retries),
            ("split_fallbacks", self.split_fallbacks),
            ("max_queue_depth", self.max_queue_depth),
            ("breaker_trips", self.breaker_trips),
            ("breaker_recoveries", self.breaker_recoveries),
            ("reloads", self.reloads),
            ("reload_rollbacks", self.reload_rollbacks),
            ("latency_count", self.latency.count()),
            ("latency_p50", self.latency.p50()),
            ("latency_p95", self.latency.p95()),
            ("latency_p99", self.latency.p99()),
            ("latency_max", self.latency.max()),
        ] {
            writeln!(f, "{name},{v}")?;
        }
        for m in &self.models {
            for (suffix, v) in [
                ("admitted", m.admitted),
                ("served", m.served),
                ("degraded", m.degraded),
                ("expired", m.expired),
                ("failed", m.failed),
                ("quarantined", m.quarantined),
                ("latency_p99", m.latency.p99()),
            ] {
                writeln!(f, "model.{}.{suffix},{v}", m.name)?;
            }
        }
        Ok(())
    }
}

/// JSON view of the guard-layer counters, routed through the shared
/// [`Registry`](crate::obs::Registry) (`GuardStatsSnapshot::export_metrics`
/// owns the key list). Byte-identical to the old hand-rolled object:
/// registry exports and `Json::obj` both sort keys via `BTreeMap`.
pub fn guard_stats_json(g: &GuardStatsSnapshot) -> Json {
    let reg = crate::obs::Registry::new();
    g.export_metrics(&reg, "");
    reg.to_json()
}

/// Full history of one run.
#[derive(Debug, Default, Clone)]
pub struct History {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    /// Fault-tolerance interventions, in detection order (empty for a
    /// clean run — and absent from the CSV/JSON output in that case).
    pub recoveries: Vec<RecoveryEvent>,
    /// Guard-layer counters at end of run (`None` when the model keeps
    /// no guard stats; absent from CSV/JSON in that case).
    pub guard: Option<GuardStatsSnapshot>,
}

impl History {
    pub fn final_eval(&self) -> Option<&EvalRecord> {
        self.evals.last()
    }

    pub fn best_error(&self) -> Option<f32> {
        self.evals.iter().map(|e| e.error).min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Mean training loss over the last `n` recorded steps (convergence
    /// signal robust to per-batch noise).
    pub fn tail_loss(&self, n: usize) -> Option<f32> {
        if self.steps.is_empty() {
            return None;
        }
        let k = self.steps.len().saturating_sub(n);
        let tail = &self.steps[k..];
        Some(tail.iter().map(|s| s.loss).sum::<f32>() / tail.len() as f32)
    }

    /// Steps/second over the whole run (excludes eval time by construction:
    /// step_secs measures only the train step).
    pub fn throughput(&self) -> Option<f64> {
        if self.steps.is_empty() {
            return None;
        }
        let total: f64 = self.steps.iter().map(|s| s.step_secs).sum();
        Some(self.steps.len() as f64 / total)
    }

    /// Did the run diverge (NaN/inf loss or loss explosion)?
    pub fn diverged(&self) -> bool {
        self.steps
            .iter()
            .any(|s| !s.loss.is_finite() || s.loss > 50.0)
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
        writeln!(f, "kind,step,loss,metric,lr,secs")?;
        for s in &self.steps {
            writeln!(f, "train,{},{},{},{},{:.6}", s.step, s.loss, s.acc, s.lr, s.step_secs)?;
        }
        for e in &self.evals {
            writeln!(f, "eval,{},{},{},,", e.step, e.loss, e.error)?;
        }
        for r in &self.recoveries {
            // detail is free text: keep the row parseable
            let detail = r.detail.replace([',', '\n'], ";");
            writeln!(f, "recovery,{},,{},{},{}", r.step, r.kind.name(), r.action.name(), detail)?;
        }
        if let Some(g) = &self.guard {
            writeln!(
                f,
                "guard,,,,,scans={};nonfinite={};saturated={};clamp={};fp32={};widen={}",
                g.scans,
                g.nonfinite_inputs,
                g.saturated_tensors,
                g.clamp_flagged,
                g.fp32_fallbacks,
                g.widenings
            )?;
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            (
                "train",
                Json::Arr(
                    self.steps
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("step", Json::num(s.step as f64)),
                                ("loss", Json::num(s.loss)),
                                ("acc", Json::num(s.acc)),
                                ("lr", Json::num(s.lr)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "eval",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("step", Json::num(e.step as f64)),
                                ("loss", Json::num(e.loss)),
                                ("error", Json::num(e.error)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if !self.recoveries.is_empty() {
            fields.push((
                "recoveries",
                Json::Arr(
                    self.recoveries
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("step", Json::num(r.step as f64)),
                                ("kind", Json::Str(r.kind.name().to_string())),
                                ("action", Json::Str(r.action.name().to_string())),
                                ("detail", Json::Str(r.detail.clone())),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if let Some(g) = &self.guard {
            fields.push(("guard_stats", guard_stats_json(g)));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hist() -> History {
        History {
            steps: (0..10)
                .map(|i| StepRecord {
                    step: i,
                    loss: 2.0 - i as f32 * 0.1,
                    acc: i as f32 * 0.05,
                    lr: 0.1,
                    step_secs: 0.01,
                })
                .collect(),
            evals: vec![
                EvalRecord { step: 5, loss: 1.0, error: 0.4 },
                EvalRecord { step: 10, loss: 0.8, error: 0.3 },
            ],
            ..History::default()
        }
    }

    #[test]
    fn summaries() {
        let h = hist();
        assert_eq!(h.final_eval().unwrap().error, 0.3);
        assert_eq!(h.best_error().unwrap(), 0.3);
        assert!((h.throughput().unwrap() - 100.0).abs() < 1.0);
        assert!(!h.diverged());
        assert!(h.tail_loss(3).unwrap() < 1.3);
    }

    #[test]
    fn divergence_detection() {
        let mut h = hist();
        h.steps.push(StepRecord { step: 11, loss: f32::NAN, acc: 0.0, lr: 0.1, step_secs: 0.01 });
        assert!(h.diverged());
    }

    #[test]
    fn perplexity() {
        let e = EvalRecord { step: 0, loss: 2.0, error: 0.5 };
        assert!((e.perplexity() - 2.0f32.exp()).abs() < 1e-4);
    }

    #[test]
    fn csv_writes() {
        let p = std::env::temp_dir().join("hbfp_metrics_test.csv");
        hist().write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.lines().count() == 1 + 10 + 2);
        assert!(s.starts_with("kind,step"));
    }

    #[test]
    fn recoveries_surface_in_csv_and_json_only_when_present() {
        assert!(hist().to_json().get("recoveries").is_none(), "clean run stays clean");
        let mut h = hist();
        h.recoveries.push(RecoveryEvent {
            step: 7,
            kind: RecoveryKind::NonFiniteLoss,
            action: RecoveryAction::RollbackWiden,
            detail: "loss=NaN, widened 8->16".into(),
        });
        let p = std::env::temp_dir().join("hbfp_metrics_recovery_test.csv");
        h.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s.lines().count(), 1 + 10 + 2 + 1);
        let row = s.lines().last().unwrap();
        assert!(row.starts_with("recovery,7,,non-finite-loss,rollback-widen,"));
        assert!(row.contains("loss=NaN; widened"), "detail commas sanitized: {row}");
        let rec = h.to_json();
        let rec = rec.get("recoveries").unwrap().as_arr().unwrap();
        assert_eq!(rec.len(), 1);
        assert_eq!(rec[0].get("kind").unwrap().as_str().unwrap(), "non-finite-loss");
    }

    #[test]
    fn guard_stats_surface_in_csv_and_json_only_when_present() {
        assert!(hist().to_json().get("guard_stats").is_none());
        let mut h = hist();
        h.guard = Some(GuardStatsSnapshot { scans: 12, fp32_fallbacks: 3, ..Default::default() });
        let j = h.to_json();
        let g = j.get("guard_stats").unwrap();
        assert_eq!(g.get("scans").unwrap().as_i64().unwrap(), 12);
        assert_eq!(g.get("fp32_fallbacks").unwrap().as_i64().unwrap(), 3);
        let p = std::env::temp_dir().join("hbfp_metrics_guard_test.csv");
        h.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        let row = s.lines().last().unwrap();
        assert!(row.starts_with("guard,"), "{row}");
        assert!(row.contains("scans=12") && row.contains("fp32=3"), "{row}");
    }

    #[test]
    fn latency_histogram_percentiles() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.percentile(0.99), 0, "empty histogram");
        // 99 fast samples and one slow outlier: p50 stays in the fast
        // bucket, p99+ reaches the outlier's bucket (clamped to max)
        for _ in 0..99 {
            h.record(100);
        }
        h.record(10_000);
        assert_eq!(h.count(), 100);
        assert_eq!(h.max(), 10_000);
        assert!(h.p50() >= 100 && h.p50() < 200, "p50 {} in the 100s bucket", h.p50());
        assert!(h.p95() < 200, "p95 {} still fast", h.p95());
        assert_eq!(h.p99(), 10_000, "p99 clamps to the observed max");
        assert!((h.mean() - 199.0).abs() < 1.0, "mean {}", h.mean());
        // exact-zero samples live in bucket 0
        let mut z = LatencyHistogram::new();
        z.record(0);
        assert_eq!(z.p99(), 0);
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().as_i64().unwrap(), 100);
        assert_eq!(j.get("buckets").unwrap().as_arr().unwrap().len(), 2, "two nonzero buckets");
    }

    #[test]
    fn serve_metrics_json_and_csv() {
        let mut m = ServeMetrics {
            admitted: 10,
            rejected_queue_full: 2,
            rejected_shedding: 1,
            completed: 9,
            degraded_served: 4,
            ..Default::default()
        };
        m.note_depth(7);
        m.note_depth(3);
        m.latency.record(50);
        m.rejected_quarantined = 2;
        m.rejected_draining = 1;
        m.models.push(ModelMetrics {
            name: "tenant-a".into(),
            share: 3,
            admitted: 6,
            served: 5,
            quarantined: 2,
            ..Default::default()
        });
        assert_eq!(m.rejected_total(), 6);
        assert_eq!(m.max_queue_depth, 7);
        let j = m.to_json();
        assert_eq!(j.get("admitted").unwrap().as_i64().unwrap(), 10);
        assert_eq!(j.get("degraded_served").unwrap().as_i64().unwrap(), 4);
        assert_eq!(j.get("latency").unwrap().get("count").unwrap().as_i64().unwrap(), 1);
        let models = j.get("models").unwrap().as_arr().unwrap();
        assert_eq!(models.len(), 1);
        assert_eq!(models[0].get("name").unwrap().as_str().unwrap(), "tenant-a");
        assert_eq!(models[0].get("share").unwrap().as_i64().unwrap(), 3);
        assert_eq!(models[0].get("quarantined").unwrap().as_i64().unwrap(), 2);
        let p = std::env::temp_dir().join("hbfp_serve_metrics_test.csv");
        m.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.starts_with("counter,value"));
        assert!(s.contains("admitted,10") && s.contains("latency_count,1"), "{s}");
        assert!(s.contains("model.tenant-a.served,5"), "per-model CSV rows: {s}");
        // equality is the whole-run determinism check
        assert_eq!(m, m.clone());
        assert_ne!(m, ServeMetrics::default());
    }

    #[test]
    fn latency_histogram_edge_cases() {
        // empty histogram: every percentile is 0, mean is 0.0 (not NaN)
        let e = LatencyHistogram::new();
        for p in [0.0, 0.5, 1.0, -1.0, 2.0, f64::NAN] {
            assert_eq!(e.percentile(p), 0, "empty at p={p}");
        }
        assert_eq!(e.mean(), 0.0);
        assert_eq!(e.max(), 0);

        let mut h = LatencyHistogram::new();
        h.record(3); // bucket 2 (bits=2), upper bound 3
        h.record(100); // bucket 7, upper bound 127
        h.record(10_000); // bucket 14, upper bound 16383 → clamped to max
        // p <= 0 clamps rank to 1: the smallest sample's bucket bound
        assert_eq!(h.percentile(0.0), 3);
        assert_eq!(h.percentile(-0.5), 3);
        // p >= 1 clamps to the exact observed max, not the bucket bound
        assert_eq!(h.percentile(1.0), 10_000);
        assert_eq!(h.percentile(7.0), 10_000);
        // non-finite p behaves like p = 0 (clamp keeps NaN, cast → rank 1)
        assert_eq!(h.percentile(f64::NAN), h.percentile(0.0));
        // mid percentiles stay within one doubling of the true value
        assert_eq!(h.percentile(0.5), 127);
    }

    #[test]
    fn guard_stats_json_matches_hand_rolled_shape() {
        let g = GuardStatsSnapshot {
            scans: 4,
            nonfinite_inputs: 1,
            saturated_tensors: 2,
            clamp_flagged: 3,
            fp32_fallbacks: 5,
            widenings: 6,
        };
        let j = guard_stats_json(&g);
        // registry-routed export keeps the exact historical key list
        let expected = Json::obj(vec![
            ("scans", Json::num(4.0)),
            ("nonfinite_inputs", Json::num(1.0)),
            ("saturated_tensors", Json::num(2.0)),
            ("clamp_flagged", Json::num(3.0)),
            ("fp32_fallbacks", Json::num(5.0)),
            ("widenings", Json::num(6.0)),
        ]);
        assert_eq!(j.to_string(), expected.to_string());
    }
}
