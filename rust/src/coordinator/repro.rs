//! Reproduction harnesses — one per table/figure in the paper's evaluation
//! (DESIGN.md §4 maps each to its modules). Every harness prints the same
//! rows the paper reports, side by side with the paper's numbers where the
//! comparison is meaningful, and returns the measured rows for tests /
//! EXPERIMENTS.md.
//!
//! Absolute errors differ from the paper (our substrates are scaled-down —
//! DESIGN.md §5); the *shape* is what must hold: which configs match fp32,
//! which degrade, which diverge, and the hardware ratios.

use std::collections::BTreeMap;

use anyhow::Result;

use super::config::{default_base_lr, LrSchedule, RunConfig};
use super::sweep::{Sweep, SweepRow};
use crate::accel::{size_design, AccelConfig, MacFormat};

fn run_cfg(combo: &str, steps: usize, seed: u64) -> RunConfig {
    let model = combo.split('-').next().unwrap_or("");
    let base = default_base_lr(model);
    RunConfig::new(combo, steps).with_seed(seed).with_lr(LrSchedule::default_for(steps, base))
}

fn pct(x: f32) -> String {
    format!("{:.2}%", x * 100.0)
}

fn by_combo(rows: &[SweepRow]) -> BTreeMap<String, &SweepRow> {
    rows.iter().map(|r| (r.combo.clone(), r)).collect()
}

/// Table 1: ResNet on CIFAR-10-like with narrow *floating point* formats.
/// Paper row: mantissa {2: N/A, 4: 9.77%, 8: 8.05%, 24: 8.42%},
/// exponent {2: N/A, 6: 14.67%, 8: 8.42%}.
pub fn table1(sweep: &Sweep, steps: usize, seed: u64) -> Result<Vec<SweepRow>> {
    let combos = [
        ("fp_m2_e8", "m=2  e=8", "N/A (diverges)"),
        ("fp_m4_e8", "m=4  e=8", "9.77%"),
        ("fp_m8_e8", "m=8  e=8", "8.05%"),
        ("fp32", "m=24 e=8", "8.42% (fp32)"),
        ("fp_m24_e6", "m=24 e=6", "14.67%"),
        ("fp_m24_e2", "m=24 e=2", "N/A (diverges)"),
    ];
    let cfgs: Vec<RunConfig> = combos
        .iter()
        .map(|(c, _, _)| run_cfg(&format!("resnet_mini-cifar10like-{c}"), steps, seed))
        .collect();
    let rows = sweep.run_all(&cfgs)?;
    println!("\nTable 1 — validation error vs narrow-FP format (ResNet / CIFAR-10-like)");
    println!("{:<12} {:>14} {:>14}  {}", "format", "paper", "ours", "note");
    for ((_, label, paper), row) in combos.iter().zip(&rows) {
        let ours = if row.diverged { "diverged".to_string() } else { pct(row.final_error) };
        println!("{label:<12} {paper:>14} {ours:>14}");
    }
    Ok(rows)
}

/// Table 2: image-classification test error, fp32 vs hbfp8_16 vs hbfp12_16.
pub fn table2(sweep: &Sweep, steps: usize, seed: u64) -> Result<Vec<SweepRow>> {
    let grid: Vec<(&str, &str)> = vec![
        ("resnet_mini", "cifar100like"),
        ("wrn_mini", "cifar100like"),
        ("densenet_mini", "cifar100like"),
        ("resnet_mini", "svhnlike"),
        ("wrn_mini", "svhnlike"),
        ("densenet_mini", "svhnlike"),
        ("resnet_mini", "imagenetlike"),
    ];
    let cfgs: Vec<RunConfig> = grid
        .iter()
        .flat_map(|(m, d)| {
            ["fp32", "hbfp8_16_t24", "hbfp12_16_t24"]
                .iter()
                .map(|c| run_cfg(&format!("{m}-{d}-{c}"), steps, seed))
                .collect::<Vec<_>>()
        })
        .collect();
    let rows = sweep.run_all(&cfgs)?;
    let map = by_combo(&rows);
    println!("\nTable 2 — test error (paper: hbfp8_16 / hbfp12_16 within ~1% of fp32)");
    println!(
        "{:<30} {:>10} {:>12} {:>12}  {}",
        "model-dataset", "fp32", "hbfp8_16", "hbfp12_16", "max gap"
    );
    for (m, d) in &grid {
        let get = |c: &str| map.get(&format!("{m}-{d}-{c}")).map(|r| r.final_error);
        let (f, h8, h12) = (
            get("fp32").unwrap_or(f32::NAN),
            get("hbfp8_16_t24").unwrap_or(f32::NAN),
            get("hbfp12_16_t24").unwrap_or(f32::NAN),
        );
        let gap = (h8 - f).abs().max((h12 - f).abs());
        println!(
            "{:<30} {:>10} {:>12} {:>12}  {:+.2}pp",
            format!("{m}-{d}"),
            pct(f),
            pct(h8),
            pct(h12),
            gap * 100.0
        );
    }
    Ok(rows)
}

/// Table 3: LSTM LM perplexity, fp32 vs hbfp8_16 vs hbfp12_16.
/// Paper: 61.31 / 61.86 / 61.35 on PTB.
pub fn table3(sweep: &Sweep, steps: usize, seed: u64) -> Result<Vec<SweepRow>> {
    let cfgs: Vec<RunConfig> = ["fp32", "hbfp8_16_t24", "hbfp12_16_t24"]
        .iter()
        .map(|c| run_cfg(&format!("lstm-ptblike-{c}"), steps, seed))
        .collect();
    let rows = sweep.run_all(&cfgs)?;
    println!("\nTable 3 — LM validation perplexity (paper: 61.31 / 61.86 / 61.35 on PTB)");
    println!("{:<16} {:>12} {:>12}", "config", "perplexity", "vs fp32");
    let base = rows[0].perplexity;
    for (c, row) in ["fp32", "hbfp8_16", "hbfp12_16"].iter().zip(&rows) {
        println!("{c:<16} {:>12.3} {:>11.2}%", row.perplexity, (row.perplexity / base - 1.0) * 100.0);
    }
    Ok(rows)
}

/// Figure 3: training curves, HBFP vs FP32, three workloads. Writes the
/// per-step/eval CSVs under `results/` (the figure's data series) and
/// prints a convergence summary.
pub fn fig3(sweep: &Sweep, steps: usize, seed: u64) -> Result<Vec<SweepRow>> {
    let workloads =
        ["wrn_mini-cifar100like", "resnet_mini-imagenetlike", "lstm-ptblike"];
    let cfgs: Vec<RunConfig> = workloads
        .iter()
        .flat_map(|w| {
            ["fp32", "hbfp8_16_t24", "hbfp12_16_t24"].iter().map(|c| {
                run_cfg(&format!("{w}-{c}"), steps, seed)
                    .with_eval_every((steps / 8).max(1))
            }).collect::<Vec<_>>()
        })
        .collect();
    let rows = sweep.run_all(&cfgs)?;
    println!("\nFigure 3 — convergence curves written to results/*.csv");
    println!("{:<44} {:>10} {:>12}", "run", "final err", "final loss");
    for r in &rows {
        println!("{:<44} {:>10} {:>12.4}", r.combo, pct(r.final_error), r.final_loss);
    }
    Ok(rows)
}

/// §6 design space: mantissa width sweep on WRN/CIFAR-100-like, including
/// the wide-vs-narrow weight-storage comparison. Paper: >= 8-bit mantissas
/// within 1% of fp32; 4-bit has a ~4.1% gap; 16-bit storage buys ~0.2-0.4%.
pub fn mantissa_sweep(sweep: &Sweep, steps: usize, seed: u64) -> Result<Vec<SweepRow>> {
    let configs = [
        "fp32",
        "hbfp4_4_t24",
        "hbfp4_16_t24",
        "hbfp8_8_t24",
        "hbfp8_16_t24",
        "hbfp12_12_t24",
        "hbfp12_16_t24",
        "hbfp16_16_t24",
    ];
    let cfgs: Vec<RunConfig> = configs
        .iter()
        .map(|c| run_cfg(&format!("wrn_mini-cifar100like-{c}"), steps, seed))
        .collect();
    let rows = sweep.run_all(&cfgs)?;
    println!("\nDesign space — mantissa width (WRN / CIFAR-100-like)");
    println!("{:<16} {:>10} {:>12}", "config", "val err", "gap vs fp32");
    let base = rows[0].final_error;
    for (c, r) in configs.iter().zip(&rows) {
        println!("{c:<16} {:>10} {:>+11.2}pp", pct(r.final_error), (r.final_error - base) * 100.0);
    }
    Ok(rows)
}

/// §6 design space: tile size sweep. Paper: t=24 and t=64 within 0.5% of
/// fp32; no tiling costs ~0.8%.
pub fn tile_sweep(sweep: &Sweep, steps: usize, seed: u64) -> Result<Vec<SweepRow>> {
    let configs =
        ["fp32", "hbfp8_16_tnone", "hbfp8_16_t8", "hbfp8_16_t24", "hbfp8_16_t64"];
    let cfgs: Vec<RunConfig> = configs
        .iter()
        .map(|c| run_cfg(&format!("wrn_mini-cifar100like-{c}"), steps, seed))
        .collect();
    let rows = sweep.run_all(&cfgs)?;
    println!("\nDesign space — exponent-sharing tile size (WRN / CIFAR-100-like, hbfp8_16)");
    println!("{:<16} {:>10} {:>12}", "tile", "val err", "gap vs fp32");
    let base = rows[0].final_error;
    let labels = ["fp32", "whole tensor", "8x8", "24x24", "64x64"];
    for (l, r) in labels.iter().zip(&rows) {
        println!("{l:<16} {:>10} {:>+11.2}pp", pct(r.final_error), (r.final_error - base) * 100.0);
    }
    Ok(rows)
}

/// Extension: HBFP-W on attention (not in the paper — its natural
/// follow-up). Weight matmuls quantized, activation-activation score/AV
/// matmuls FP32; claim under test: perplexity tracks fp32 like the LSTM's.
pub fn attention(sweep: &Sweep, steps: usize, seed: u64) -> Result<Vec<SweepRow>> {
    let cfgs: Vec<RunConfig> = ["fp32", "hbfp8_16_t24", "hbfp12_16_t24"]
        .iter()
        .map(|c| {
            let mut r = run_cfg(&format!("transformer_mini-ptblike-{c}"), steps, seed);
            r.lr = LrSchedule::Cosine { base: 0.3, floor: 0.003, total: steps };
            r
        })
        .collect();
    let rows = sweep.run_all(&cfgs)?;
    println!("\nExtension — HBFP-W transformer LM (weight matmuls in BFP)");
    println!("{:<16} {:>12} {:>12}", "config", "perplexity", "vs fp32");
    let base = rows[0].perplexity;
    for (c, row) in ["fp32", "hbfp8_16", "hbfp12_16"].iter().zip(&rows) {
        println!("{c:<16} {:>12.3} {:>11.2}%", row.perplexity, (row.perplexity / base - 1.0) * 100.0);
    }
    Ok(rows)
}

/// §6 hardware: the area/throughput table. No training involved — this is
/// the accelerator model (DESIGN.md §4 row HW / T1-FP).
pub fn throughput() -> Vec<(String, f64, f64, f64, f64)> {
    println!("\n§6 hardware — accelerator area/throughput model (Stratix-V-class budget, 200 MHz)");
    println!(
        "{:<14} {:>8} {:>12} {:>10} {:>10} {:>10}",
        "format", "array", "peak TOp/s", "mac %", "act %", "conv %"
    );
    let formats = [
        MacFormat::Bfp { mantissa_bits: 8 },
        MacFormat::Bfp { mantissa_bits: 12 },
        MacFormat::Bfp { mantissa_bits: 16 },
        MacFormat::Fp { m: 11, e: 5 },
        MacFormat::Fp32,
    ];
    let mut out = Vec::new();
    for f in formats {
        let r = size_design(&AccelConfig::stratix_v_like(f));
        println!(
            "{:<14} {:>5}x{:<3} {:>11.3} {:>9.1}% {:>9.2}% {:>9.3}%",
            r.config_name,
            r.array_edge,
            r.array_edge,
            r.peak_ops / 1e12,
            r.mac_frac * 100.0,
            r.act_frac * 100.0,
            r.conv_frac * 100.0
        );
        out.push((r.config_name.clone(), r.peak_ops, r.mac_frac, r.act_frac, r.conv_frac));
    }
    let ratio = crate::accel::throughput_ratio(
        MacFormat::Bfp { mantissa_bits: 8 },
        MacFormat::Fp { m: 11, e: 5 },
    );
    println!("\nbfp8 vs fp16 throughput ratio: {ratio:.2}x   (paper: 8.5x)");
    let r_mult = crate::hw::anchors::FP16_MULT.area_um2 / crate::hw::anchors::INT8_MULT.area_um2;
    println!("fp16/int8 multiplier area ratio: {r_mult:.1}x (paper: 5.8x)");

    // §6 bandwidth discussion: per-layer traffic under fp32 vs hbfp.
    use crate::accel::{bandwidth_ratio, step_traffic, FormatBits, LayerShape};
    println!("\n§6 memory traffic — per training step (fwd+dgrad+wgrad+update)");
    println!(
        "{:<26} {:>12} {:>12} {:>12} {:>10}",
        "layer", "fp32 Mbit", "hbfp8_16", "reduction", "MACs/bit"
    );
    let layers = [
        ("FC 4096x4096 (b=32)", LayerShape::Dense { batch: 32, d_in: 4096, d_out: 4096 }),
        ("conv 3x3x128->128", LayerShape::Conv { batch: 32, h_out: 16, w_out: 16, k: 3, cin: 128, cout: 128 }),
        ("conv 3x3x16->16", LayerShape::Conv { batch: 32, h_out: 16, w_out: 16, k: 3, cin: 16, cout: 16 }),
    ];
    let fmt = FormatBits::hbfp(8, 16, 24);
    for (name, shape) in layers {
        let base = step_traffic(&shape, &FormatBits::fp32());
        let ours = step_traffic(&shape, &fmt);
        println!(
            "{name:<26} {:>12.1} {:>12.1} {:>11.2}x {:>10.1}",
            base.total_bits as f64 / 1e6,
            ours.total_bits as f64 / 1e6,
            bandwidth_ratio(&shape, &fmt),
            ours.macs_per_bit
        );
    }
    println!("(paper: up to 4x fwd/bwd bandwidth reduction; FC traffic weight-dominated;\n conv layers compute-bound so activation traffic immaterial)");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_table_shape() {
        let rows = throughput();
        assert_eq!(rows.len(), 5);
        // bfp8 fastest, fp32 slowest
        assert!(rows[0].1 > rows[3].1, "bfp8 should beat fp16");
        assert!(rows[3].1 > rows[4].1, "fp16 should beat fp32");
        // area fractions sane for the bfp8 design
        assert!(rows[0].3 < 0.10 && rows[0].4 < 0.01);
    }
}
