//! Fault-tolerant training loop: a watchdog-wrapped step driver that
//! detects numeric hazards (non-finite or exploding loss), contained
//! worker panics, and step errors, then recovers by rolling back to the
//! newest valid checkpoint and widening the mantissa width class.
//!
//! The XLA-artifact trainer ([`super::trainer`]) carries the same
//! watchdog for real model runs; this module provides the
//! artifact-independent loop used by the fault-injection demo and tests:
//! a [`FaultTolerantModel`] is anything that can snapshot/restore its
//! state as checkpoint leaves and run one optimizer step.
//!
//! Recovery policy (`RunConfig::max_recoveries` interventions, then give
//! up):
//!
//! 1. A hazard at step `s` rolls state back to the newest checkpoint that
//!    passes CRC + manifest validation (`latest`, then `prev`; corrupt
//!    files are skipped and recorded as
//!    [`RecoveryKind::CorruptCheckpoint`] events, never trusted).
//! 2. The mantissa width class widens one step
//!    ([`crate::bfp::next_wider_class`]) — the paper's §5.3 observation
//!    that divergence under narrow mantissas is a quantization-noise
//!    problem, so the remedy is more mantissa, not more retries.
//! 3. Replay resumes from the checkpoint's step. Batches derive from
//!    `seed ^ step`, so the replayed schedule is identical and the whole
//!    run is deterministic under a fixed seed (fault injection included:
//!    the [`crate::util::fault`] schedule is a pure function of the
//!    per-site probe counter).
//!
//! Every intervention lands in [`History::recoveries`] and flows to the
//! same CSV/JSON artifacts as the loss curve.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::checkpoint::{Checkpoint, CheckpointStore, CkptError};
use super::config::RunConfig;
use super::metrics::{EvalRecord, History, RecoveryAction, RecoveryEvent, RecoveryKind, StepRecord};
use crate::bfp::{
    next_wider_class, BfpContext, GuardAction, GuardPolicy, GuardStats, GuardStatsSnapshot,
    Rounding, TileSize,
};
use crate::runtime::engine::HostTensor;
use crate::runtime::manifest::{DType, TensorSpec};
use crate::util::fault::{self, FaultSite};
use crate::util::rng::SplitMix64;

/// Loss value beyond which the watchdog calls a finite loss "exploding"
/// (the same threshold [`History::diverged`] reports on).
pub const EXPLOSION_THRESHOLD: f32 = 50.0;

/// A training state the resilient loop can drive: snapshot/restore as
/// checkpoint leaves, one optimizer step at a time, with a widenable
/// mantissa width class.
pub trait FaultTolerantModel {
    /// Manifest of the state leaves, in [`FaultTolerantModel::state`]
    /// order (checkpoints validate against this).
    fn specs(&self) -> Vec<TensorSpec>;
    /// Snapshot the training state.
    fn state(&self) -> Vec<HostTensor>;
    /// Replace the training state from checkpoint leaves (spec order).
    fn restore(&mut self, leaves: &[HostTensor]) -> Result<()>;
    /// Run one optimizer step at `step` with learning rate `lr`;
    /// returns `(loss, accuracy)`.
    fn step(&mut self, step: usize, lr: f32) -> Result<(f32, f32)>;
    /// Current mantissa width class (bits).
    fn width(&self) -> u32;
    /// Widen the mantissa width class one step; `false` when already at
    /// the widest class.
    fn widen(&mut self) -> bool;
    /// Guard-layer counters accumulated by the model's datapath,
    /// surfaced into [`History::guard`] after the run (`None` = the
    /// model keeps no guard stats).
    fn guard_stats(&self) -> Option<GuardStatsSnapshot> {
        None
    }
    /// Forward-only validation pass, `(mean loss, mean error)`. `None` =
    /// the model has no validation split; the loop then skips the
    /// `RunConfig::eval_every` cadence entirely.
    fn eval(&mut self) -> Option<Result<(f32, f32)>> {
        None
    }
}

/// What one wrapped step produced.
enum StepOutcome {
    Clean(f32, f32),
    Hazard {
        kind: RecoveryKind,
        detail: String,
        /// The step record when the step did complete (non-finite or
        /// exploding loss) — recorded if the watchdog is disabled.
        record: Option<(f32, f32)>,
    },
}

fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Try `latest` then `prev`, skipping (and noting) anything that fails
/// CRC/format/version validation. A combo/spec mismatch is a caller bug
/// (wrong artifact), not corruption, and propagates.
fn restore_newest(
    store: &CheckpointStore,
    combo: &str,
    specs: &[TensorSpec],
    notes: &mut Vec<String>,
) -> Result<Option<Checkpoint>> {
    for path in [store.latest_path(), store.prev_path()] {
        if !path.exists() {
            continue;
        }
        let loaded = Checkpoint::load(&path).and_then(|ck| {
            ck.check_against(combo, specs)?;
            Ok(ck)
        });
        match loaded {
            Ok(ck) => return Ok(Some(ck)),
            Err(e @ CkptError::Mismatch { .. }) => return Err(e.into()),
            Err(e) => notes.push(format!("skipped {}: {e}", path.display())),
        }
    }
    Ok(None)
}

/// Drive `model` for `cfg.steps` steps under the watchdog. Resumes from
/// the newest valid checkpoint in `cfg.checkpoint_dir` when one exists,
/// checkpoints every `cfg.checkpoint_every` steps (plus once at the end),
/// and spends at most `cfg.max_recoveries` rollback-and-widen
/// interventions before giving up with an error. With
/// `max_recoveries == 0` the watchdog is off: a non-finite loss is
/// recorded and the run continues (legacy behaviour, visible through
/// [`History::diverged`]), while a step error still fails the run.
/// Models exposing an eval hook ([`FaultTolerantModel::eval`]) are
/// evaluated every `cfg.eval_every` clean steps and once at the end;
/// evals past a rollback point are replayed like the steps they follow.
pub fn run_resilient<M: FaultTolerantModel>(model: &mut M, cfg: &RunConfig) -> Result<History> {
    let specs = model.specs();
    let store =
        cfg.checkpoint_dir.as_ref().map(|d| CheckpointStore::new(d.clone(), cfg.combo.clone()));
    let initial = model.state();
    let mut history = History::default();
    let mut step = 0usize;

    if let Some(store) = &store {
        if let Some((ck, _)) = store.load_newest_valid(&cfg.combo, &specs)? {
            model.restore(&ck.leaves)?;
            step = ck.step;
        }
    }

    let mut recoveries_used = 0usize;
    while step < cfg.steps {
        let lr = cfg.lr.at(step);
        let t0 = Instant::now();
        let outcome = match catch_unwind(AssertUnwindSafe(|| model.step(step, lr))) {
            Err(payload) => StepOutcome::Hazard {
                kind: RecoveryKind::StepError,
                detail: format!("step panicked: {}", panic_msg(payload.as_ref())),
                record: None,
            },
            Ok(Err(e)) => StepOutcome::Hazard {
                kind: RecoveryKind::StepError,
                detail: format!("step failed: {e:#}"),
                record: None,
            },
            Ok(Ok((loss, acc))) if !loss.is_finite() => StepOutcome::Hazard {
                kind: RecoveryKind::NonFiniteLoss,
                detail: format!("loss={loss}"),
                record: Some((loss, acc)),
            },
            Ok(Ok((loss, acc))) if loss > EXPLOSION_THRESHOLD => StepOutcome::Hazard {
                kind: RecoveryKind::ExplodingLoss,
                detail: format!("loss={loss}"),
                record: Some((loss, acc)),
            },
            Ok(Ok((loss, acc))) => StepOutcome::Clean(loss, acc),
        };
        let secs = t0.elapsed().as_secs_f64();
        match outcome {
            StepOutcome::Clean(loss, acc) => {
                history.steps.push(StepRecord { step, loss, acc, lr, step_secs: secs });
                step += 1;
                if let Some(store) = &store {
                    if cfg.checkpoint_every > 0 && step % cfg.checkpoint_every == 0 {
                        let ck =
                            Checkpoint { combo: cfg.combo.clone(), step, leaves: model.state() };
                        store.save(&ck, &specs)?;
                    }
                }
                if cfg.eval_every > 0 && step % cfg.eval_every == 0 {
                    if let Some(ev) = model.eval() {
                        let (loss, error) = ev?;
                        history.evals.push(EvalRecord { step, loss, error });
                    }
                }
            }
            StepOutcome::Hazard { kind, detail, record } if cfg.max_recoveries == 0 => {
                match record {
                    Some((loss, acc)) => {
                        history.steps.push(StepRecord { step, loss, acc, lr, step_secs: secs });
                        step += 1;
                    }
                    None => {
                        return Err(anyhow!(
                            "step {step} failed with the watchdog disabled ({}): {detail}",
                            kind.name()
                        ))
                    }
                }
            }
            StepOutcome::Hazard { kind, detail, .. } => {
                recoveries_used += 1;
                if recoveries_used > cfg.max_recoveries {
                    history.recoveries.push(RecoveryEvent {
                        step,
                        kind,
                        action: RecoveryAction::Abort,
                        detail: detail.clone(),
                    });
                    return Err(anyhow!(
                        "recovery budget ({}) exhausted at step {step} ({}): {detail}",
                        cfg.max_recoveries,
                        kind.name()
                    ));
                }
                let mut notes = Vec::new();
                let restored = match &store {
                    Some(store) => restore_newest(store, &cfg.combo, &specs, &mut notes)?,
                    None => None,
                };
                let (action, resume) = match restored {
                    Some(ck) => {
                        model.restore(&ck.leaves)?;
                        let widened = model.widen();
                        let action = if widened {
                            RecoveryAction::RollbackWiden
                        } else {
                            RecoveryAction::Rollback
                        };
                        (action, ck.step)
                    }
                    None => {
                        model.restore(&initial)?;
                        model.widen();
                        (RecoveryAction::Restart, 0)
                    }
                };
                for note in notes {
                    history.recoveries.push(RecoveryEvent {
                        step,
                        kind: RecoveryKind::CorruptCheckpoint,
                        action,
                        detail: note,
                    });
                }
                history.recoveries.push(RecoveryEvent {
                    step,
                    kind,
                    action,
                    detail: format!(
                        "{detail}; resumed at step {resume} with width {}",
                        model.width()
                    ),
                });
                history.steps.retain(|r| r.step < resume);
                // An eval at exactly `resume` was computed from the
                // checkpointed state and stays valid; later ones replay.
                history.evals.retain(|e| e.step <= resume);
                step = resume;
            }
        }
    }
    // Final checkpoint — unless the cadence just wrote one at this exact
    // step (saving again would rotate the genuinely-older `prev` away).
    let already_saved = cfg.checkpoint_every > 0 && step % cfg.checkpoint_every == 0 && step > 0;
    if let Some(store) = &store {
        if !already_saved {
            let ck = Checkpoint { combo: cfg.combo.clone(), step, leaves: model.state() };
            store.save(&ck, &specs)?;
        }
    }
    // Final eval (always, per `RunConfig::eval_every` semantics) unless
    // the cadence just evaluated at this exact step.
    if history.evals.last().map(|e| e.step) != Some(step) {
        if let Some(ev) = model.eval() {
            let (loss, error) = ev?;
            history.evals.push(EvalRecord { step, loss, error });
        }
    }
    history.guard = model.guard_stats();
    Ok(history)
}

/// The demo model behind `examples/fault_demo.rs` and the acceptance
/// test: softmax regression on a synthetic centroid-classification task,
/// with the forward GEMM running through the guarded BFP datapath
/// ([`crate::bfp::MatmulPlan::quantize_execute_guarded`], FP32 fallback
/// on non-finite input so a hazard reaches the loss instead of the tile
/// exponents).
///
/// Batches are a pure function of `seed ^ step`, so a rollback replays
/// the exact schedule. Fault hooks: the [`FaultSite::NanActivation`] and
/// [`FaultSite::MantissaBitflip`] sites fire only at the narrowest width
/// class (≤ 8 bits) — modelling hazards born of aggressive quantization —
/// so the watchdog's rollback-and-widen actually clears them, the same
/// shape as the paper's narrow-mantissa divergence remedy.
pub struct SoftmaxDemo {
    ctx: BfpContext,
    w: Vec<f32>,
    bits: u32,
    features: usize,
    classes: usize,
    batch: usize,
    seed: u64,
    /// Guard counters for the run (scans, fallbacks, …).
    pub stats: GuardStats,
}

impl SoftmaxDemo {
    pub fn new(seed: u64, bits: u32) -> SoftmaxDemo {
        let (features, classes, batch) = (16, 4, 8);
        let mut rng = SplitMix64::new(seed);
        let w = (0..features * classes).map(|_| rng.normal() * 0.1).collect();
        let ctx = BfpContext::from_env().with_tile(TileSize::Edge(8)).with_guard(GuardPolicy {
            action: GuardAction::Fp32Fallback,
            ..GuardPolicy::default()
        });
        SoftmaxDemo { ctx, w, bits, features, classes, batch, seed, stats: GuardStats::new() }
    }

    /// Deterministic batch for `step`: per-class centroids plus noise.
    fn batch_for(&self, step: usize) -> (Vec<f32>, Vec<usize>) {
        let mut rng =
            SplitMix64::new(self.seed ^ (step as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut x = vec![0.0f32; self.batch * self.features];
        let mut y = vec![0usize; self.batch];
        for i in 0..self.batch {
            let label = (rng.next_u64() as usize) % self.classes;
            y[i] = label;
            for j in 0..self.features {
                let centroid = if j % self.classes == label { 1.5 } else { 0.0 };
                x[i * self.features + j] = centroid + rng.normal() * 0.3;
            }
        }
        (x, y)
    }
}

impl FaultTolerantModel for SoftmaxDemo {
    fn specs(&self) -> Vec<TensorSpec> {
        vec![
            TensorSpec {
                name: "w".to_string(),
                shape: vec![self.features, self.classes],
                dtype: DType::F32,
            },
            TensorSpec { name: "width_bits".to_string(), shape: vec![], dtype: DType::I32 },
        ]
    }

    fn state(&self) -> Vec<HostTensor> {
        vec![
            HostTensor::F32(self.w.clone(), vec![self.features, self.classes]),
            HostTensor::scalar_i32(self.bits as i32),
        ]
    }

    fn restore(&mut self, leaves: &[HostTensor]) -> Result<()> {
        if leaves.len() != 2 {
            return Err(anyhow!("expected 2 leaves, got {}", leaves.len()));
        }
        self.w = leaves[0].as_f32()?.to_vec();
        match &leaves[1] {
            HostTensor::I32(v, _) if v.len() == 1 && (2..=24).contains(&v[0]) => {
                self.bits = v[0] as u32;
            }
            other => return Err(anyhow!("bad width leaf {other:?}")),
        }
        Ok(())
    }

    fn step(&mut self, step: usize, lr: f32) -> Result<(f32, f32)> {
        let (mut x, y) = self.batch_for(step);
        if self.bits <= 8 && fault::fire(FaultSite::NanActivation) {
            x[0] = f32::NAN;
        }
        if self.bits <= 8 && fault::fire(FaultSite::MantissaBitflip) {
            let i = (step * 7) % self.w.len();
            self.w[i] = f32::from_bits(self.w[i].to_bits() ^ (1 << 28));
        }
        let qw = self.ctx.quantize(
            &self.w,
            self.features,
            self.classes,
            self.bits,
            &mut Rounding::NearestEven,
        )?;
        let plan = self.ctx.plan_matmul(
            self.batch,
            self.features,
            self.classes,
            (self.bits, self.bits),
        )?;
        let mut logits = vec![0.0f32; self.batch * self.classes];
        plan.quantize_execute_guarded(
            &x,
            &mut Rounding::NearestEven,
            &qw,
            &mut logits,
            Some(&self.stats),
        )?;

        let mut loss = 0.0f32;
        let mut correct = 0usize;
        let mut grad_logits = vec![0.0f32; self.batch * self.classes];
        for i in 0..self.batch {
            let row = &logits[i * self.classes..(i + 1) * self.classes];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = row.iter().map(|v| (v - max).exp()).collect();
            let sum: f32 = exps.iter().sum();
            let mut pred = 0usize;
            for c in 0..self.classes {
                if row[c] > row[pred] {
                    pred = c;
                }
            }
            if pred == y[i] {
                correct += 1;
            }
            loss += -(exps[y[i]] / sum).max(1e-12).ln();
            for c in 0..self.classes {
                let p = exps[c] / sum;
                let target = if c == y[i] { 1.0 } else { 0.0 };
                grad_logits[i * self.classes + c] = (p - target) / self.batch as f32;
            }
        }
        loss /= self.batch as f32;

        // Non-finite loss: skip the apply (the standard mixed-precision
        // overflow-skip) so the poison stays in this step's activations
        // and never reaches the weights — the watchdog decides what
        // happens next.
        if !loss.is_finite() {
            return Ok((loss, correct as f32 / self.batch as f32));
        }

        // grad_w = x^T · grad_logits, applied in place (SGD)
        for i in 0..self.batch {
            for j in 0..self.features {
                let xv = x[i * self.features + j];
                for c in 0..self.classes {
                    self.w[j * self.classes + c] -= lr * xv * grad_logits[i * self.classes + c];
                }
            }
        }
        Ok((loss, correct as f32 / self.batch as f32))
    }

    fn width(&self) -> u32 {
        self.bits
    }

    fn widen(&mut self) -> bool {
        match next_wider_class(self.bits) {
            Some(w) => {
                self.bits = w;
                true
            }
            None => false,
        }
    }

    fn guard_stats(&self) -> Option<GuardStatsSnapshot> {
        Some(self.stats.snapshot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::LrSchedule;
    use crate::util::fault::{FaultInjector, FaultSpec};

    fn demo_cfg(name: &str, steps: usize) -> (RunConfig, std::path::PathBuf) {
        let dir = std::env::temp_dir()
            .join(format!("hbfp_resilient_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = RunConfig::new("demo-centroids-hbfp8", steps)
            .with_seed(11)
            .with_lr(LrSchedule::Constant { lr: 0.5 })
            .with_checkpoint_every(5)
            .with_max_recoveries(3);
        cfg.checkpoint_dir = Some(dir.clone());
        (cfg, dir)
    }

    #[test]
    fn clean_run_learns_and_checkpoints() {
        let _guard = crate::util::fault::install(FaultInjector::none());
        let (cfg, dir) = demo_cfg("clean", 30);
        let mut model = SoftmaxDemo::new(cfg.seed, 8);
        let h = run_resilient(&mut model, &cfg).unwrap();
        assert_eq!(h.steps.len(), 30);
        assert!(h.recoveries.is_empty());
        assert!(!h.diverged());
        let guard_snap = h.guard.expect("SoftmaxDemo surfaces guard stats into the history");
        assert_eq!(guard_snap.scans, 30, "one guarded GEMM scan per step");
        assert!(h.to_json().get("guard_stats").is_some(), "guard counters reach the artifact");
        assert!(
            h.tail_loss(5).unwrap() < h.steps[0].loss,
            "loss should fall on a separable task"
        );
        assert!(dir.join("demo-centroids-hbfp8.ckpt").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn clean_run_is_deterministic() {
        let _guard = crate::util::fault::install(FaultInjector::none());
        let (cfg, dir) = demo_cfg("det_a", 20);
        let mut m1 = SoftmaxDemo::new(cfg.seed, 8);
        let h1 = run_resilient(&mut m1, &cfg).unwrap();
        let (cfg2, dir2) = demo_cfg("det_b", 20);
        let mut m2 = SoftmaxDemo::new(cfg2.seed, 8);
        let h2 = run_resilient(&mut m2, &cfg2).unwrap();
        let l1: Vec<f32> = h1.steps.iter().map(|s| s.loss).collect();
        let l2: Vec<f32> = h2.steps.iter().map(|s| s.loss).collect();
        assert!(l1 == l2, "same seed must reproduce the loss curve exactly");
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir2);
    }

    #[test]
    fn nan_hazard_rolls_back_widens_and_finishes() {
        // rate 1.0 at width 8: the first step at the narrow class always
        // poisons an activation. The watchdog restores (restart at step 0
        // here — no checkpoint yet), widens to 16, and the injected site
        // goes quiet (it only fires at <= 8 bits), so the run completes.
        let _guard = crate::util::fault::install(FaultInjector::from_specs(&[FaultSpec {
            site: FaultSite::NanActivation,
            rate: 1.0,
            seed: 1,
        }]));
        let (cfg, dir) = demo_cfg("nan", 25);
        let mut model = SoftmaxDemo::new(cfg.seed, 8);
        let h = run_resilient(&mut model, &cfg).unwrap();
        assert_eq!(h.steps.len(), 25);
        assert!(!h.diverged(), "recovered history must not contain the NaN step");
        assert_eq!(h.recoveries.len(), 1);
        let r = &h.recoveries[0];
        assert_eq!(r.kind, RecoveryKind::NonFiniteLoss);
        assert_eq!(r.action, RecoveryAction::Restart);
        assert!(r.detail.contains("width 16"), "detail: {}", r.detail);
        assert_eq!(model.width(), 16);
        assert!(model.stats.fp32_fallbacks() >= 1, "guard must have caught the NaN GEMM");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recovery_budget_exhaustion_aborts_with_event() {
        // A model pinned at the widest class cannot widen away a hazard
        // that fires at every width — exhaust the budget and fail loudly.
        struct AlwaysNan(SoftmaxDemo);
        impl FaultTolerantModel for AlwaysNan {
            fn specs(&self) -> Vec<TensorSpec> {
                self.0.specs()
            }
            fn state(&self) -> Vec<HostTensor> {
                self.0.state()
            }
            fn restore(&mut self, leaves: &[HostTensor]) -> Result<()> {
                self.0.restore(leaves)
            }
            fn step(&mut self, _step: usize, _lr: f32) -> Result<(f32, f32)> {
                Ok((f32::NAN, 0.0))
            }
            fn width(&self) -> u32 {
                self.0.width()
            }
            fn widen(&mut self) -> bool {
                self.0.widen()
            }
        }
        let _guard = crate::util::fault::install(FaultInjector::none());
        let (cfg, dir) = demo_cfg("budget", 10);
        let mut model = AlwaysNan(SoftmaxDemo::new(cfg.seed, 8));
        let err = run_resilient(&mut model, &cfg).unwrap_err();
        assert!(err.to_string().contains("recovery budget"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn watchdog_disabled_records_divergence() {
        let _guard = crate::util::fault::install(FaultInjector::from_specs(&[FaultSpec {
            site: FaultSite::NanActivation,
            rate: 1.0,
            seed: 1,
        }]));
        let (mut cfg, dir) = demo_cfg("off", 5);
        cfg.max_recoveries = 0;
        let mut model = SoftmaxDemo::new(cfg.seed, 8);
        let h = run_resilient(&mut model, &cfg).unwrap();
        assert_eq!(h.steps.len(), 5);
        assert!(h.diverged(), "with the watchdog off the NaN must surface in history");
        assert!(h.recoveries.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
